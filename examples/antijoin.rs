//! §2.4 — distributed antijoin `R1 ▷ R2` over a shared-nothing table partition.
//!
//! Alice holds `R1(order_id, …)`, Bob holds `R2(order_id, …)`; Alice needs the tuples of
//! `R1` whose key never appears in `R2` — exactly her side (`A \ B`) of bidirectional SetX
//! over the key columns. Neither side knows (or estimates by hand) how many keys differ:
//! the builder's default `DiffSize::Estimated` handshake takes care of it.
//!
//! Run: `cargo run --release --offline --example antijoin`

use commonsense::hash::{SipHash13, Xoshiro256};
use commonsense::setx::Setx;
use std::collections::HashMap;

#[derive(Clone, Debug)]
struct Row {
    order_id: u64,
    amount: u64,
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(0xa2d);
    // R1: 80k orders; R2: the 79.4k of them that shipped, plus 1.2k phantom shipments.
    let r1: Vec<Row> = (0..80_000u64)
        .map(|i| Row { order_id: 10_000_000 + i, amount: rng.gen_range(100_000) })
        .collect();
    let mut shipped: Vec<u64> = r1.iter().map(|r| r.order_id).collect();
    rng.shuffle(&mut shipped);
    shipped.truncate(79_400); // 600 unshipped orders
    let mut r2_keys = shipped;
    r2_keys.extend((0..1_200u64).map(|i| 90_000_000 + i)); // shipments with no known order

    // Key columns → id sets via a keyed hash (the candidate-key assumption of §2.4).
    let h = SipHash13::from_seed(0x7ab1e);
    let key_id = |k: u64| h.hash(&k.to_le_bytes());
    let a_ids: Vec<u64> = r1.iter().map(|r| key_id(r.order_id)).collect();
    let b_ids: Vec<u64> = r2_keys.iter().map(|&k| key_id(k)).collect();
    let back: HashMap<u64, u64> = r1.iter().map(|r| (key_id(r.order_id), r.order_id)).collect();

    let alice = Setx::builder(&a_ids).build().expect("config");
    let bob = Setx::builder(&b_ids).build().expect("config");
    let (ra, _rb) = alice.run_pair(&bob).expect("setx");

    // R1 ▷ R2 = rows of R1 whose key is in A \ B.
    let anti: Vec<u64> = ra.local_unique.iter().map(|id| back[id]).collect();
    println!("|R1| = {}, |R2| = {}", r1.len(), r2_keys.len());
    println!("R1 ▷ R2 = {} unshipped orders (exact)", anti.len());
    assert_eq!(anti.len(), 600);
    println!(
        "communication: {} bytes over {} rounds in {} attempt(s) ({})",
        ra.total_bytes(),
        ra.rounds,
        ra.attempts,
        ra.breakdown()
    );
    println!(
        "shipping the full key column instead: {} bytes — {:.1}x more",
        8 * r2_keys.len(),
        8.0 * r2_keys.len() as f64 / ra.total_bytes() as f64
    );
    // Keep the sample row type honest (amounts ride along in the real join).
    let _ = r1.first().map(|r| r.amount);
}
