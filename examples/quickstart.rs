//! Quickstart: compute an exact set intersection with CommonSense in a dozen lines.
//!
//! Run: `cargo run --release --offline --example quickstart`

use commonsense::data::synth;
use commonsense::protocol::bidi::{self, BidiOptions};
use commonsense::protocol::{uni, CsParams};

fn main() {
    // --- Unidirectional (A ⊆ B): one message, Bob learns B \ A exactly. -----------------
    let (a, b) = synth::subset_pair(100_000, 1_000, 42);
    let params = CsParams::tuned_uni(b.len(), 1_000);
    let out = uni::run(&a, &b, &params).expect("decode");
    println!("— unidirectional SetX (A ⊆ B) —");
    println!("|A| = {}, |B| = {}, d = 1000", a.len(), b.len());
    println!("recovered |B\\A| = {}", out.b_minus_a.len());
    println!("communication: {} bytes in {} message(s)", out.comm.total_bytes(), out.comm.rounds());
    assert_eq!(out.b_minus_a, synth::difference(&b, &a));

    // --- Bidirectional (general case): ping-pong decoding. ------------------------------
    let (a, b) = synth::overlap_pair(100_000, 500, 1_500, 43);
    let params = CsParams::tuned_bidi(102_000, 500, 1_500);
    let out = bidi::run(&a, &b, &params, BidiOptions::default());
    println!("\n— bidirectional SetX —");
    println!("|A∩B| = 100000, |A\\B| = 500, |B\\A| = 1500");
    println!(
        "converged = {}, rounds = {}, communication = {} bytes",
        out.converged,
        out.rounds,
        out.comm.total_bytes()
    );
    assert!(out.converged);
    assert_eq!(out.a_minus_b, synth::difference(&a, &b));
    assert_eq!(out.b_minus_a, synth::difference(&b, &a));
    println!("exact intersection of {} elements ✓", out.intersection.len());
}
