//! Quickstart: compute an exact set intersection with CommonSense in a dozen lines.
//!
//! The front door is `Setx::builder`: declare your set, run against the peer. Nobody
//! supplies `d = |AΔB|` — the endpoints estimate it in the handshake (Strata + MinHash)
//! — and `Mode::Auto` picks the one-message unidirectional protocol when the workload
//! allows it.
//!
//! Run: `cargo run --release --offline --example quickstart`

use commonsense::data::synth;
use commonsense::setx::{ProtocolKind, Setx};

fn main() {
    // --- Subset workload (A ⊆ B): Auto detects it and runs one-message SetX. ------------
    let (a, b) = synth::subset_pair(100_000, 1_000, 42);
    let alice = Setx::builder(&a).build().expect("config");
    let bob = Setx::builder(&b).build().expect("config");
    let (ra, rb) = alice.run_pair(&bob).expect("setx");
    println!("— subset workload (A ⊆ B, d estimated in-handshake) —");
    println!("|A| = {}, |B| = {}, true d = 1000", a.len(), b.len());
    println!(
        "protocol = {:?}, recovered |B\\A| = {}, attempts = {}",
        rb.kind,
        rb.local_unique.len(),
        rb.attempts
    );
    println!("communication: {} bytes ({})", ra.total_bytes(), ra.breakdown());
    assert_eq!(rb.local_unique, synth::difference(&b, &a));
    assert_eq!(ra.intersection, rb.intersection);
    assert_eq!(rb.kind, ProtocolKind::Uni, "Auto must detect the subset shape");

    // --- General workload: two-sided difference, ping-pong decoding. --------------------
    let (a, b) = synth::overlap_pair(100_000, 500, 1_500, 43);
    let alice = Setx::builder(&a).build().expect("config");
    let bob = Setx::builder(&b).build().expect("config");
    let (ra, rb) = alice.run_pair(&bob).expect("setx");
    println!("\n— general bidirectional workload —");
    println!("|A∩B| = 100000, |A\\B| = 500, |B\\A| = 1500");
    println!(
        "protocol = {:?}, rounds = {}, communication = {} bytes",
        ra.kind,
        ra.rounds,
        ra.total_bytes()
    );
    assert_eq!(ra.local_unique, synth::difference(&a, &b));
    assert_eq!(rb.local_unique, synth::difference(&b, &a));
    assert_eq!(ra.intersection, synth::intersect(&a, &b));
    println!("exact intersection of {} elements ✓", ra.intersection.len());
}
