//! §2.5 — delta synchronization for cloud file storage (the rsync matching stage).
//!
//! A client (Alice) edited files; the server (Bob) holds the previous version. Files are
//! content-defined-chunked; each side's chunk-checksum set feeds bidirectional SetX:
//! Alice learns `A \ B` (chunks to upload), Bob learns `B \ A` (obsolete chunks to patch).
//! The sync service *knows* an upper bound on the difference (edits are journaled), so
//! this example uses `DiffSize::Explicit` — the builder's escape hatch for workloads with
//! domain knowledge, skipping the estimator handshake entirely.
//!
//! Run: `cargo run --release --offline --example delta_sync`

use commonsense::hash::{SipHash13, Xoshiro256};
use commonsense::setx::{DiffSize, Setx};

/// Content-defined chunking with a Gear rolling hash: `h = (h << 1) + GEAR[byte]`, cut when
/// the top `log2(avg)` bits are all ones. Old bytes shift out of `h`, so boundaries depend
/// only on a ~64-byte local window — an insertion/edit re-synchronizes within one window
/// (the property §2.5 cites content-defined chunking for).
fn cdc_chunks(data: &[u8], avg: usize) -> Vec<&[u8]> {
    let bits = avg.next_power_of_two().trailing_zeros();
    let mask: u64 = ((1u64 << bits) - 1) << (64 - bits);
    let gear: Vec<u64> = (0..256u64)
        .map(commonsense::hash::split_mix64)
        .collect();
    let min = avg / 4;
    let mut chunks = Vec::new();
    let mut start = 0usize;
    let mut h = 0u64;
    for (i, &byte) in data.iter().enumerate() {
        h = (h << 1).wrapping_add(gear[byte as usize]);
        let len = i - start + 1;
        if (h & mask == mask && len >= min) || len >= 4 * avg {
            chunks.push(&data[start..=i]);
            start = i + 1;
            h = 0;
        }
    }
    if start < data.len() {
        chunks.push(&data[start..]);
    }
    chunks
}

fn main() {
    // Build a "file system": 2 MB of content; the client edits ~25 scattered spots.
    let mut rng = Xoshiro256::seed_from_u64(0xd317a);
    let server_data: Vec<u8> = (0..2_000_000).map(|_| rng.next_u64() as u8).collect();
    let mut client_data = server_data.clone();
    let mut edits = 0;
    for _ in 0..25 {
        let pos = rng.gen_range(client_data.len() as u64 - 100) as usize;
        for off in 0..40 {
            client_data[pos + off] ^= 0x5a;
        }
        edits += 1;
    }

    let hasher = SipHash13::from_seed(0xc4ec);
    let chunk_ids = |data: &[u8]| -> Vec<u64> {
        cdc_chunks(data, 1024).iter().map(|c| hasher.hash(c)).collect()
    };
    let server_chunks = chunk_ids(&server_data);
    let client_chunks = chunk_ids(&client_data);
    println!(
        "server: {} chunks, client: {} chunks, {} edits applied",
        server_chunks.len(),
        client_chunks.len(),
        edits
    );

    // Each edit touches 1–2 chunks (CDC locality) ⇒ d ≲ 4 × edits in total, journaled by
    // the sync service — caller-supplied, so the handshake carries no estimators.
    let d_bound = 8 * edits;
    let build = |chunks: &[u64]| {
        Setx::builder(chunks)
            .diff_size(DiffSize::Explicit(d_bound))
            .build()
            .expect("config")
    };
    let client = build(&client_chunks);
    let server = build(&server_chunks);
    let (rc, rs) = client.run_pair(&server).expect("setx");

    let upload_bytes: usize = rc.local_unique.len() * 1024; // chunks the client pushes
    println!(
        "matching stage : {} bytes over {} rounds (CommonSense, {})",
        rc.total_bytes(),
        rc.rounds,
        rc.breakdown()
    );
    println!(
        "deltas found   : client-unique {} chunks, server-obsolete {} chunks",
        rc.local_unique.len(),
        rs.local_unique.len()
    );
    println!("delta upload   : ≈ {} bytes (vs {} full file)", upload_bytes, client_data.len());
    // Naive matching ships every checksum: |B|·8 bytes.
    println!(
        "naive matching : {} bytes (all checksums) — CommonSense saves {:.1}x",
        8 * server_chunks.len(),
        8.0 * server_chunks.len() as f64 / rc.total_bytes() as f64
    );
}
