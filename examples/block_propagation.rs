//! §2.1 — blockchain block propagation (the Graphene use case), head-to-head.
//!
//! A miner (Alice) announces a new block whose transactions are all already in the peer's
//! (Bob's) mempool (`A ⊆ B`, thanks to aggressive tx relay). Bob reconstructs the full
//! block content from one CommonSense sketch, vs Graphene's BF+IBLT.
//!
//! **Advanced: manual tuning.** This is the one example that constructs [`CsParams`] by
//! hand instead of going through `Setx::builder`: a head-to-head against Graphene wants
//! the engine-layer protocol with an exact, caller-known `d` and zero handshake bytes
//! (block relay already knows the mempool sizes). Every other example uses the builder.
//!
//! Run: `cargo run --release --offline --example block_propagation`

use commonsense::baselines::graphene::graphene_setx;
use commonsense::baselines::iblt::IbltParams;
use commonsense::data::synth;
use commonsense::hash::SipHash13;
use commonsense::protocol::{uni, CsParams};

fn main() {
    // A realistic shape: 3000-tx block, 30k-tx mempool (so d = |mempool \ block| = 27k)…
    // and the inverse regime: a large block against a slightly larger mempool.
    for (block_txs, mempool_txs) in [(3_000usize, 30_000usize), (20_000, 22_000)] {
        let d = mempool_txs - block_txs;
        let (block, mempool) = synth::subset_pair(block_txs, d, 0xb10c);

        // Transaction ids in real systems are hashes of tx content; demonstrate with
        // SipHash over synthetic payloads (ids in `block`/`mempool` stand for those).
        let hasher = SipHash13::from_seed(7);
        let _txid_example = hasher.hash(b"raw transaction bytes...");

        // Manual engine-layer tuning (see the module docs): exact d, no handshake.
        let params = CsParams::tuned_uni(mempool.len(), d);
        let out = uni::run(&block, &mempool, &params).expect("decode");
        assert_eq!(out.intersection.len(), block_txs, "Bob reconstructs the block");

        let g = graphene_setx(
            &block,
            &mempool,
            239.0 / 240.0,
            IbltParams::paper_synthetic(),
            1,
        );
        assert_eq!(g.b_minus_a.len(), d);

        println!("block = {block_txs} txs, mempool = {mempool_txs} txs (d = {d}):");
        println!("  CommonSense : {:>8} bytes, 1 round", out.comm.total_bytes());
        println!(
            "  Graphene    : {:>8} bytes (BF {} + IBLT {})",
            g.total_bytes, g.bf_bytes, g.iblt_bytes
        );
        println!(
            "  full block  : {:>8} bytes (32 B/txid)\n",
            32 * block_txs
        );
    }
}
