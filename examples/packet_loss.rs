//! §2.2 — LossRadar-style packet-loss detection with streaming CommonSense digests.
//!
//! Two switches on a path each maintain a tiny data-plane digest (O(m) per packet); the
//! control plane decodes the digest difference against the feasible packet superset and
//! pinpoints *exactly which* packets were lost. Compare memory against an IBLT sized for
//! the same loss count. A builder-API cross-check then recomputes the loss set with a
//! full `Setx` conversation between the two observation sets (downstream ⊆ upstream —
//! `Mode::Auto` detects the subset shape and runs the one-message protocol).
//!
//! Run: `cargo run --release --offline --example packet_loss`

use commonsense::baselines::iblt::IbltParams;
use commonsense::hash::{hash_u64, Xoshiro256};
use commonsense::setx::{ProtocolKind, Setx};
use commonsense::streaming::{digest_params, lossradar};

fn main() {
    // 200 flows × ≤ 250 packets each; 0.5% loss rate on the hop.
    let flows = 200u64;
    let pkts_per_flow = 250u64;
    let loss_rate = 0.005;
    let mut rng = Xoshiro256::seed_from_u64(0x10ad);

    // The packet superset B′: every (flow, packet-id) signature the control plane can
    // enumerate (flow IDs from FlowRadar + conservative per-flow id ranges, per §2.2).
    let superset: Vec<u64> = (0..flows)
        .flat_map(|f| (0..pkts_per_flow).map(move |p| hash_u64(f << 32 | p, 0xf10e)))
        .collect();

    let expected_losses = (superset.len() as f64 * loss_rate * 1.6) as usize;
    let params = digest_params(superset.len(), expected_losses);
    let mut upstream = lossradar::Meter::new(&params);
    let mut downstream = lossradar::Meter::new(&params);

    let mut lost = Vec::new();
    for &sig in &superset {
        upstream.observe(sig);
        if rng.gen_f64() < loss_rate {
            lost.push(sig); // dropped on the wire
        } else {
            downstream.observe(sig);
        }
    }
    lost.sort_unstable();

    let detected = lossradar::detect_losses(&upstream, &downstream, &superset)
        .expect("digest decode");
    assert_eq!(detected, lost, "exact loss set recovered");

    // Both structures provisioned for the same expected loss count. The CS digest's cells
    // are small counters (≤ |packets|·m/l ≈ 60 here), so 8-bit data-plane cells suffice —
    // that is the apples-to-apples memory figure against the IBLT's 104-bit cells.
    let iblt_bytes = IbltParams::paper_synthetic().size_bytes(
        IbltParams::paper_synthetic().cells_for(expected_losses),
    );
    println!("packets on path : {}", superset.len());
    println!("packets lost    : {} ({}%)", lost.len(), 100.0 * loss_rate);
    println!("detected        : {} (exact ✓)", detected.len());
    println!(
        "digest memory   : {} bytes per switch (8-bit cells; {} as i32)",
        params.l,
        upstream.digest.memory_bytes()
    );
    println!("IBLT same prov. : {} bytes per switch", iblt_bytes);
    println!(
        "per-packet work : {} row updates (O(m))",
        params.m
    );

    // Cross-check with the front-door API: the downstream switch's observations are a
    // subset of the upstream's, so Auto + in-handshake estimation reproduces the same
    // loss set as the streaming digests — with zero parameters supplied.
    let upstream_seen: Vec<u64> = superset.clone();
    let downstream_seen: Vec<u64> = {
        let lost_set: std::collections::HashSet<u64> = lost.iter().copied().collect();
        superset.iter().copied().filter(|sig| !lost_set.contains(sig)).collect()
    };
    let up = Setx::builder(&upstream_seen).build().expect("config");
    let down = Setx::builder(&downstream_seen).build().expect("config");
    let (r_up, r_down) = up.run_pair(&down).expect("setx");
    assert_eq!(r_up.local_unique, lost, "facade agrees with the digest decode");
    assert_eq!(r_down.kind, ProtocolKind::Uni, "Auto detects the subset shape");
    println!(
        "setx cross-check: {:?} protocol, {} bytes ({}) — same {} losses ✓",
        r_up.kind,
        r_up.total_bytes(),
        r_up.breakdown(),
        r_up.local_unique.len()
    );
}
