//! END-TO-END DRIVER — all layers composed on a real workload, through the one front door.
//!
//! * Workload: two Ethereum-sim world-state snapshots (the §7.3 scenario, DESIGN.md §4).
//! * Layer 1+2: the AOT-compiled Pallas/JAX dense-block artifacts (`make artifacts`),
//!   loaded and executed from rust via PJRT — used here to accelerate sketch encoding per
//!   universe partition, cross-checked against the sparse path.
//! * Layer 3: the `Setx` builder API end to end — Alice and Bob as real TCP peers
//!   (difference size *estimated in the handshake*, no ground truth supplied), plus the
//!   PBS-style partitioned parallel driver behind the identical builder config.
//!
//! Reports the paper's headline metric (communication cost vs the IBLT baseline and the
//! SetR bound) plus wall-clock and throughput. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --offline --example end_to_end [accounts]`

use commonsense::baselines::iblt::{iblt_setx, IbltParams};
use commonsense::bounds;
use commonsense::coordinator::{connect, serve};
use commonsense::data::ethereum::{diff_stats, EthSim};
use commonsense::metrics::Phase;
use commonsense::runtime::Runtime;
use commonsense::setx::{parallel, Setx};
use commonsense::sketch::Sketch;
use std::net::TcpListener;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n_accounts: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200_000);

    println!("=== CommonSense end-to-end driver ===\n");
    println!("[1/4] workload: Ethereum-sim, {n_accounts} accounts, 1 day of staleness");
    let t0 = Instant::now();
    let mut sim = EthSim::genesis(n_accounts, 0xe2e);
    let b = sim.snapshot_ids(); // Bob: yesterday's snapshot
    sim.advance_day();
    let a = sim.snapshot_ids(); // Alice: fresh snapshot
    let st = diff_stats(&b, &a);
    println!(
        "      |A| = {}, |B| = {}, |B\\A| = {}, |A\\B| = {}, built in {:?}\n",
        a.len(),
        b.len(),
        st.s_minus_a,
        st.a_minus_s,
        t0.elapsed()
    );

    // ---------------------------------------------------------------- L1/L2 via PJRT ---
    println!("[2/4] PJRT artifacts (L1 Pallas + L2 JAX, AOT):");
    match Runtime::load_default() {
        Ok(rt) => {
            let shapes = rt.shapes;
            println!(
                "      platform = {}, block = {}x{} (steps {})",
                rt.platform(),
                shapes.l,
                shapes.nb,
                shapes.steps
            );
            // Accelerated partition encode, cross-checked against the sparse path.
            let matrix = commonsense::matrix::CsMatrix::new(shapes.l as u32, 5, 0xacce1);
            let part: Vec<u64> = a.iter().copied().take(4 * shapes.nb).collect();
            let t = Instant::now();
            let accel = rt.encode_set(matrix, &part)?;
            let t_accel = t.elapsed();
            let t = Instant::now();
            let sparse = Sketch::encode(matrix, &part);
            let t_sparse = t.elapsed();
            assert_eq!(accel, sparse.counts, "PJRT and sparse encodes agree");
            println!(
                "      encode {} ids: PJRT dense-block {:?} vs sparse scatter {:?} — identical counts ✓\n",
                part.len(),
                t_accel,
                t_sparse
            );
        }
        Err(e) => println!("      SKIPPED ({e:#}) — run `make artifacts`\n"),
    }

    // ------------------------------------------------------------------ L3 over TCP ---
    println!("[3/4] TCP session (builder API; d estimated in the handshake):");
    // One declarative config on both hosts — nobody supplies d or CsParams.
    let alice = Setx::builder(&a).universe_bits(256).build().expect("config");
    let bob = Setx::builder(&b).universe_bits(256).build().expect("config");
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let alice2 = alice.clone();
    let alice_thread = std::thread::spawn(move || serve(&listener, &alice2));
    let t = Instant::now();
    let bob_report = connect(addr, &bob)?;
    let alice_report = alice_thread.join().expect("alice thread")?;
    let wall = t.elapsed();
    let total_bytes = bob_report.total_bytes();
    assert!(bob_report.converged && alice_report.converged);
    assert_eq!(bob_report.local_unique.len(), st.s_minus_a);
    assert_eq!(alice_report.local_unique.len(), st.a_minus_s);
    let payload_bytes = total_bytes - bob_report.phase_total(Phase::Handshake);
    println!(
        "      exact ✓  bytes on wire = {} ({} handshake + {} protocol), wall = {:?}, throughput = {:.0} elems/s",
        total_bytes,
        bob_report.phase_total(Phase::Handshake),
        payload_bytes,
        wall,
        (a.len() + b.len()) as f64 / wall.as_secs_f64()
    );
    println!("      breakdown: {}", bob_report.breakdown());

    // Baselines for the headline comparison.
    let t = Instant::now();
    let (amb, bma, iblt_bytes, _) = iblt_setx(&a, &b, st.sym_diff, IbltParams::paper_ethereum());
    let iblt_wall = t.elapsed();
    assert_eq!((amb.len(), bma.len()), (st.a_minus_s, st.s_minus_a));
    let setr_bound = bounds::setr_lower_bound_bits(256, st.sym_diff as u64) / 8.0;
    println!(
        "      vs IBLT: {} bytes ({:.1}x more; decode wall {:?}); vs SetR lower bound: {:.0} bytes ({:.1}x)\n",
        iblt_bytes,
        iblt_bytes as f64 / total_bytes as f64,
        iblt_wall,
        setr_bound,
        setr_bound / total_bytes as f64
    );

    // ------------------------------------------------------- partitioned scale-out ---
    println!("[4/4] PBS-style partitioned parallel SetX (8 partitions, same builder config):");
    let t = Instant::now();
    let par = parallel::run_partitioned(&alice, &bob, 8, 8)?;
    assert!(par.client.converged && par.server.converged);
    assert_eq!(par.client.local_unique.len(), st.a_minus_s);
    assert_eq!(par.client.intersection, alice_report.intersection);
    println!(
        "      exact ✓  bytes = {} ({:.2}x single-session), wall = {:?} (8 threads, peak {} workers)",
        par.client.total_bytes(),
        par.client.total_bytes() as f64 / total_bytes as f64,
        t.elapsed(),
        par.peak_workers
    );

    println!("\n=== all layers composed; see EXPERIMENTS.md §E2E ===");
    Ok(())
}
