//! Five hosts, one round: multi-party `∩ᵢSᵢ` over real sockets.
//!
//! One coordinator thread hosts the round on an ephemeral loopback listener; four spoke
//! threads join it with `setx::multi::net::join_round`. Every party's answer is verified
//! against the exact intersection, then the per-party byte shards are printed.
//!
//! Run: `cargo run --release --example multi_sync`

use commonsense::data::synth;
use commonsense::setx::multi::net::{host_round, join_round};
use commonsense::setx::Setx;
use std::net::TcpListener;
use std::time::Duration;

const PARTIES: usize = 5;
const COMMON: usize = 5_000;
const UNIQUE: usize = 60;

fn main() {
    let sets = synth::overlap_n(PARTIES, COMMON, UNIQUE, 0x5EED);
    let mut expected = sets[0].clone();
    for s in &sets[1..] {
        expected = synth::intersect(&expected, s);
    }
    let cfg = *Setx::builder(&sets[0]).build().expect("valid default config").config();
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral loopback port");
    let addr = listener.local_addr().expect("listener address");

    let report = std::thread::scope(|scope| {
        for id in 1..PARTIES as u32 {
            let set = sets[id as usize].clone();
            let expected = &expected;
            scope.spawn(move || {
                let r = join_round(addr, &cfg, set, id, PARTIES as u32).expect("spoke completes");
                assert_eq!(&r.intersection, expected, "spoke {id} answer");
            });
        }
        host_round(&listener, &cfg, sets[0].clone(), PARTIES as u32, Duration::from_secs(30))
            .expect("coordinator completes")
    });

    assert_eq!(report.intersection, expected, "coordinator answer");
    let per_party: usize = report.parties.iter().map(|p| p.total_bytes()).sum();
    assert_eq!(per_party, report.total_bytes(), "byte shards sum to the round total");

    println!("multi-party SetX: {PARTIES} parties, |core| = {COMMON}, {UNIQUE} unique each");
    println!(
        "intersection: {} elements, {} bytes total",
        report.intersection.len(),
        report.total_bytes()
    );
    for p in &report.parties {
        let status = match &p.error {
            None => "synced".to_string(),
            Some(e) => format!("FAILED: {e}"),
        };
        println!("  party {:>2}: {:>7} bytes  {}", p.party, p.total_bytes(), status);
    }
}
