//! Fleet sync: one long-lived `SetxServer` holding a hot set, many clients delta-syncing
//! against it — the one-server-many-clients shape of the paper's deployment scenarios
//! (block propagation, data-center sync).
//!
//! Each client drifts between rounds (it gains a few local writes and misses the
//! server's newest elements), reconciles over TCP, and verifies the intersection
//! exactly. The server's decoder pool turns the fleet's repeated same-geometry sessions
//! into cache hits — watch the `pool_hit_rate` in the final stats line.
//!
//! Run: `cargo run --release --offline --example fleet_sync`

use commonsense::data::synth;
use commonsense::server::SetxServer;
use commonsense::setx::transport::TcpTransport;
use commonsense::setx::{DiffSize, Setx};

const COMMON: usize = 10_000;
const CLIENT_UNIQUE: usize = 80;
const SERVER_UNIQUE: usize = 120;
const CLIENTS: u64 = 6;
const ROUNDS: u64 = 3;

/// Every endpoint of the fleet shares this builder shape (equal config fingerprints).
/// Declaring the known difference size keeps all sessions on one matrix geometry — the
/// decoder-pool sweet spot; see the `server` module docs.
fn endpoint(set: &[u64]) -> Setx {
    Setx::builder(set)
        .diff_size(DiffSize::Explicit(CLIENT_UNIQUE + SERVER_UNIQUE))
        .build()
        .expect("valid fleet config")
}

fn main() {
    // Host set: a common core every client knows, plus SERVER_UNIQUE fresh elements.
    let mut rng = commonsense::hash::Xoshiro256::seed_from_u64(4242);
    let ids = synth::distinct_ids(
        COMMON + SERVER_UNIQUE + (CLIENTS * ROUNDS * CLIENT_UNIQUE as u64) as usize,
        &mut rng,
    );
    let core = &ids[..COMMON];
    let mut host = core.to_vec();
    host.extend_from_slice(&ids[COMMON..COMMON + SERVER_UNIQUE]);

    let server = SetxServer::builder(endpoint(&host))
        .workers(3)
        .bind("127.0.0.1:0")
        .expect("bind fleet server");
    let addr = server.local_addr();
    println!("fleet server on {addr}: |host| = {}, {CLIENTS} clients × {ROUNDS} rounds", host.len());

    std::thread::scope(|scope| {
        for c in 0..CLIENTS {
            let ids = &ids;
            let core_len = COMMON;
            scope.spawn(move || {
                for round in 0..ROUNDS {
                    // Delta drift: this round's local writes are CLIENT_UNIQUE ids nobody
                    // else holds (disjoint slices of the shared pool).
                    let offset = COMMON
                        + SERVER_UNIQUE
                        + ((c * ROUNDS + round) * CLIENT_UNIQUE as u64) as usize;
                    let mut set = ids[..core_len].to_vec();
                    set.extend_from_slice(&ids[offset..offset + CLIENT_UNIQUE]);
                    let alice = endpoint(&set);
                    let mut transport =
                        TcpTransport::connect(addr).expect("connect to fleet server");
                    let report = alice.run(&mut transport).expect("fleet sync");
                    // The exact answer is known: client ∩ host = the common core.
                    let mut expected = ids[..core_len].to_vec();
                    expected.sort_unstable();
                    assert_eq!(report.intersection, expected, "client {c} round {round}");
                    println!(
                        "client {c} round {round}: verified |∩| = {} in {} B ({:?}, {} attempt(s))",
                        report.intersection.len(),
                        report.total_bytes(),
                        report.kind,
                        report.attempts
                    );
                }
            });
        }
    });

    let stats = server.shutdown();
    println!("\nfinal server stats:\n{}", stats.to_json());
    assert_eq!(stats.sessions_served, CLIENTS * ROUNDS);
    assert_eq!(stats.sessions_failed, 0);
    println!(
        "decoder pool: {} hits / {} misses (hit rate {:.2}) — construction paid ~once per worker, \
         not once per session",
        stats.pool.hits,
        stats.pool.misses,
        stats.pool_hit_rate()
    );
}
