"""Pallas kernels vs pure-jnp oracles — the compile-path correctness gate.

Hypothesis sweeps tile-aligned shapes and dense-block contents; assert_allclose against
ref.py. A failure here means the HLO the rust runtime executes is wrong.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import matvec, ref

TL, TN = matvec.TILE_L, matvec.TILE_N


def random_block(rng, l, nb, m_ones):
    """A CS-style dense 0/1 block: m_ones ones per column at random rows."""
    block = np.zeros((l, nb), dtype=np.float32)
    for c in range(nb):
        rows = rng.choice(l, size=m_ones, replace=False)
        block[rows, c] = 1.0
    return block


shape_strategy = st.tuples(
    st.integers(min_value=1, max_value=3),  # l multiplier
    st.integers(min_value=1, max_value=2),  # nb multiplier
    st.integers(min_value=1, max_value=7),  # ones per column
    st.integers(min_value=0, max_value=2**31 - 1),
)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_encode_matches_ref(params):
    lm, nm, m_ones, seed = params
    l, nb = TL * lm, TN * nm
    rng = np.random.default_rng(seed)
    block = random_block(rng, l, nb, m_ones)
    x = rng.integers(0, 2, size=nb).astype(np.float32)
    got = np.asarray(matvec.encode(jnp.asarray(block), jnp.asarray(x)))
    want = np.asarray(ref.encode_ref(jnp.asarray(block), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(shape_strategy)
def test_correlate_matches_ref(params):
    lm, nm, m_ones, seed = params
    l, nb = TL * lm, TN * nm
    rng = np.random.default_rng(seed)
    block = random_block(rng, l, nb, m_ones)
    r = rng.integers(-3, 4, size=l).astype(np.float32)
    got = np.asarray(matvec.correlate(jnp.asarray(block), jnp.asarray(r), float(m_ones)))
    want = np.asarray(ref.correlate_ref(jnp.asarray(block), jnp.asarray(r), float(m_ones)))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_encode_real_dtype_and_shape():
    rng = np.random.default_rng(0)
    block = random_block(rng, TL, TN, 5)
    x = np.ones(TN, dtype=np.float32)
    y = matvec.encode(jnp.asarray(block), jnp.asarray(x))
    assert y.shape == (TL,)
    assert y.dtype == jnp.float32
    # Row sums of an m-regular block sum to m·nb overall.
    assert float(jnp.sum(y)) == 5 * TN


@pytest.mark.parametrize("seed", range(4))
def test_decode_steps_recovers_planted_block_signal(seed):
    """Full L2 graph: plant a sparse binary signal, decode it back on one block."""
    from compile import model

    rng = np.random.default_rng(seed)
    l, nb, m_ones, d = TL * 2, TN, 5, 12
    block = random_block(rng, l, nb, m_ones)
    truth = np.zeros(nb, dtype=np.float32)
    truth[rng.choice(nb, size=d, replace=False)] = 1.0
    r0 = block @ truth
    x0 = np.zeros(nb, dtype=np.float32)
    r, x = model.decode_steps(
        jnp.asarray(block), jnp.asarray(r0), jnp.asarray(x0),
        jnp.float32(m_ones), steps=3 * d,
    )
    np.testing.assert_allclose(np.asarray(r), np.zeros(l), atol=1e-5)
    np.testing.assert_allclose(np.asarray(x), truth, atol=1e-6)


def test_decode_step_matches_ref_single_iteration():
    from compile import model

    rng = np.random.default_rng(7)
    l, nb, m_ones = TL, TN, 4
    block = random_block(rng, l, nb, m_ones)
    truth = np.zeros(nb, dtype=np.float32)
    truth[[3, 99, 500]] = 1.0
    r0 = (block @ truth).astype(np.float32)
    x0 = np.zeros(nb, dtype=np.float32)
    r_got, x_got = model.decode_steps(
        jnp.asarray(block), jnp.asarray(r0), jnp.asarray(x0), jnp.float32(m_ones), steps=1
    )
    r_want, x_want = ref.decode_step_ref(
        jnp.asarray(block), jnp.asarray(r0), jnp.asarray(x0), float(m_ones)
    )
    np.testing.assert_allclose(np.asarray(r_got), np.asarray(r_want), atol=1e-6)
    np.testing.assert_allclose(np.asarray(x_got), np.asarray(x_want), atol=1e-6)


def test_noop_iterations_are_safe():
    """Surplus decode steps must leave a converged state untouched."""
    from compile import model

    rng = np.random.default_rng(11)
    l, nb, m_ones = TL, TN, 5
    block = random_block(rng, l, nb, m_ones)
    r0 = np.zeros(l, dtype=np.float32)
    x0 = np.zeros(nb, dtype=np.float32)
    r, x = model.decode_steps(
        jnp.asarray(block), jnp.asarray(r0), jnp.asarray(x0), jnp.float32(m_ones), steps=8
    )
    assert float(jnp.abs(r).sum()) == 0.0
    assert float(jnp.abs(x).sum()) == 0.0
