"""AOT lowering: JAX graphs → HLO *text* artifacts for the rust PJRT runtime.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits protos with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.
See /opt/xla-example/README.md and the ``load_hlo`` reference.

Usage (normally via ``make artifacts``):
    python -m compile.aot --out-dir ../artifacts [--l 1024] [--nb 2048] [--steps 32]

Emits:
    encode_<l>x<nb>.hlo.txt       — y = M_block @ x
    correlate_<l>x<nb>.hlo.txt    — δ = M_blockᵀ r / m
    decode_<l>x<nb>_s<K>.hlo.txt  — K MP iterations (lax.scan)
    manifest.txt                  — shapes, one artifact per line
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--l", type=int, default=1024, help="sketch rows per partition block")
    ap.add_argument("--nb", type=int, default=2048, help="candidate columns per block")
    ap.add_argument("--steps", type=int, default=32, help="MP iterations per decode call")
    args = ap.parse_args()

    l, nb, steps = args.l, args.nb, args.steps
    assert l % 128 == 0 and nb % 512 == 0, "shapes must respect kernel tiling"
    os.makedirs(args.out_dir, exist_ok=True)

    mb = jax.ShapeDtypeStruct((l, nb), jnp.float32)
    vx = jax.ShapeDtypeStruct((nb,), jnp.float32)
    vr = jax.ShapeDtypeStruct((l,), jnp.float32)
    sm = jax.ShapeDtypeStruct((), jnp.float32)

    manifest = []

    def emit(name: str, fn, *specs):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest.append(name)
        print(f"wrote {name}: {len(text)} chars")

    emit(
        f"encode_{l}x{nb}.hlo.txt",
        lambda m, x: (model.encode_block(m, x),),
        mb,
        vx,
    )
    emit(
        f"correlate_{l}x{nb}.hlo.txt",
        lambda m, r, mo: (model.correlate_block(m, r, mo),),
        mb,
        vr,
        sm,
    )
    emit(
        f"decode_{l}x{nb}_s{steps}.hlo.txt",
        lambda m, r, x, mo: model.decode_steps(m, r, x, mo, steps=steps),
        mb,
        vr,
        vx,
        sm,
    )

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write(f"l={l} nb={nb} steps={steps}\n")
        for name in manifest:
            f.write(name + "\n")
    print(f"manifest: {len(manifest)} artifacts (l={l}, nb={nb}, steps={steps})")


if __name__ == "__main__":
    main()
