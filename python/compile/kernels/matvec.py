"""Layer-1 Pallas kernels: the CS dense-block compute hot-spots.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CPU hot-spots are sparse
scatter/gather updates (each element touches m random sketch rows). A systolic MXU wants
dense tiles, so the TPU-shaped formulation partitions the universe (as the paper itself
suggests for parallelism, §7.3) and materializes per-partition dense 0/1 column blocks:

* ``encode``:    y = M_block @ x          — batched sketch encoding (M·1_S per partition);
* ``correlate``: δ = M_blockᵀ @ r / m     — the MP matching stage's scores for *all*
                                            candidates of the block at once (eq. B.1).

Both are tiled matmuls whose BlockSpecs express the HBM↔VMEM schedule; on a real TPU the
(TL×TN)·(TN×1) tiles hit the MXU. Here they are lowered with ``interpret=True`` (CPU PJRT
cannot run Mosaic custom-calls) — numerics are identical, and the VMEM/MXU estimates live
in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: multiples of the (8, 128) f32 TPU tiling; 128×512 f32 tiles keep
# (128·512 + 512 + 128)·4 B ≈ 265 KiB in VMEM per instance — comfortably under 16 MiB.
TILE_L = 128
TILE_N = 512


def _matvec_kernel(m_ref, x_ref, o_ref):
    """One (i, j) grid step: accumulate M[i·TL:(i+1)·TL, j·TN:(j+1)·TN] @ x[j·TN:(j+1)·TN]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += m_ref[...] @ x_ref[...]


@functools.partial(jax.jit, static_argnames=())
def encode(m_block: jax.Array, x: jax.Array) -> jax.Array:
    """y = M_block @ x for an l×nb dense 0/1 block and an nb-vector.

    l and nb must be multiples of the tile sizes (the AOT wrapper pads).
    """
    l, nb = m_block.shape
    assert l % TILE_L == 0 and nb % TILE_N == 0, (l, nb)
    x2 = x.reshape(nb, 1)
    grid = (l // TILE_L, nb // TILE_N)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_L, TILE_N), lambda i, j: (i, j)),
            pl.BlockSpec((TILE_N, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_L, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, 1), jnp.float32),
        interpret=True,
    )(m_block, x2)
    return out.reshape(l)


def _correlate_kernel(m_ref, r_ref, o_ref):
    """One (j, i) grid step of δ = Mᵀ r: accumulate M_tileᵀ @ r_tile."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += m_ref[...].T @ r_ref[...]


@functools.partial(jax.jit, static_argnames=())
def correlate(m_block: jax.Array, r: jax.Array, m_ones: float) -> jax.Array:
    """δ = M_blockᵀ @ r / m — the optimal L2 pursuit step for every block candidate."""
    l, nb = m_block.shape
    assert l % TILE_L == 0 and nb % TILE_N == 0, (l, nb)
    r2 = r.reshape(l, 1)
    grid = (nb // TILE_N, l // TILE_L)
    out = pl.pallas_call(
        _correlate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_L, TILE_N), lambda j, i: (i, j)),
            pl.BlockSpec((TILE_L, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, 1), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        interpret=True,
    )(m_block, r2)
    return out.reshape(nb) / m_ones
