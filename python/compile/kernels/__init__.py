# L1: Pallas kernels for the paper's compute hot-spots + their pure-jnp oracles.
from . import matvec, ref  # noqa: F401
