"""Pure-jnp oracles for the Pallas kernels — the build-time correctness ground truth.

Every kernel in this package must match its oracle to float32 tolerance across the
hypothesis shape sweeps in ``python/tests/``; that is the CORE correctness signal of the
compile path (the rust runtime then loads the very HLO these functions lower into).
"""

import jax.numpy as jnp


def encode_ref(m_block, x):
    """y = M x."""
    return m_block @ x


def correlate_ref(m_block, r, m_ones):
    """δ = Mᵀ r / m (eq. B.1)."""
    return (m_block.T @ r) / m_ones


def decode_step_ref(m_block, r, x, m_ones):
    """One binary-MP iteration (Procedure 1 + Modification 9) on a dense block.

    Greedy: compute every candidate's gain (in units of m), flip the argmax if its gain is
    positive, update the residue. Mirrors rust ``MpDecoder::run`` restricted to one step.
    """
    delta = correlate_ref(m_block, r, m_ones)
    # Gain/m: setting needs delta > 1/2 (rule 2), unsetting needs delta < -1/2 (rule 1).
    gains = jnp.where(x < 0.5, 2.0 * delta - 1.0, -2.0 * delta - 1.0)
    j = jnp.argmax(gains)
    best = gains[j]
    do = best > 0.0
    setting = x[j] < 0.5
    sign = jnp.where(setting, 1.0, -1.0)  # set => r -= col, unset => r += col
    col = m_block[:, j]
    r_new = jnp.where(do, r - sign * col, r)
    x_new = x.at[j].set(jnp.where(do, 1.0 - x[j], x[j]))
    return r_new, x_new


def decode_steps_ref(m_block, r, x, m_ones, steps):
    for _ in range(steps):
        r, x = decode_step_ref(m_block, r, x, m_ones)
    return r, x
