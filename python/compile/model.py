"""Layer-2 JAX graphs: the CS encode and dense-block MP-decode computations.

These are the fixed-shape compute graphs AOT-lowered (``aot.py``) to HLO text that the rust
runtime (``rust/src/runtime``) loads and executes via PJRT — Python never runs at request
time. Both call the Layer-1 Pallas kernels in ``kernels/matvec.py`` so they lower into the
same HLO module.

Shapes are static: ``l × nb`` dense 0/1 column blocks (a universe partition, DESIGN.md
§Hardware-Adaptation); the coordinator pads the last block with zero columns.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import matvec


def encode_block(m_block: jax.Array, x: jax.Array) -> jax.Array:
    """Sketch contribution of one dense block: y = M_block @ x (Pallas L1 kernel)."""
    return matvec.encode(m_block, x)


@functools.partial(jax.jit, static_argnames=("steps",))
def decode_steps(
    m_block: jax.Array,
    r: jax.Array,
    x: jax.Array,
    m_ones: jax.Array,
    steps: int = 32,
) -> tuple[jax.Array, jax.Array]:
    """``steps`` greedy binary-MP iterations (Procedure 1 + Modification 9) on one block.

    Each iteration: δ = Mᵀr/m via the Pallas correlate kernel (the matching stage over all
    candidates at once), then the best positive-gain flip is applied. A no-op iteration
    (best gain ≤ 0) leaves the carry unchanged, so calling with surplus steps is safe —
    the rust coordinator loops until the residue stops improving.
    """
    l, nb = m_block.shape

    def step(carry, _):
        r, x = carry
        delta = matvec.correlate(m_block, r, 1.0) / m_ones
        gains = jnp.where(x < 0.5, 2.0 * delta - 1.0, -2.0 * delta - 1.0)
        j = jnp.argmax(gains)
        best = gains[j]
        do = best > 0.0
        setting = x[j] < 0.5
        sign = jnp.where(setting, 1.0, -1.0)
        col = jax.lax.dynamic_slice(m_block, (0, j), (l, 1)).reshape(l)
        r_new = jnp.where(do, r - sign * col, r)
        x_new = x.at[j].set(jnp.where(do, 1.0 - x[j], x[j]))
        return (r_new, x_new), None

    (r, x), _ = jax.lax.scan(step, (r, x), None, length=steps)
    return r, x


def correlate_block(m_block: jax.Array, r: jax.Array, m_ones: jax.Array) -> jax.Array:
    """Standalone matching-stage scores δ = Mᵀr/m for one block (Pallas L1 kernel)."""
    return matvec.correlate(m_block, r, 1.0) / m_ones
