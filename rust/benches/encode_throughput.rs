//! Bench D2 — encode/update/codec throughput backing Theorem 2's complexity claims:
//! O(m) per streaming update, O(m·|S|) one-shot encode, plus the rANS and truncation
//! codec costs and the PJRT dense-block encode path.
//!
//! Run: `cargo bench --offline --bench encode_throughput [-- --json] [-- --smoke]`
//! (`--json` appends to the root `BENCH_decode.json` trajectory.)

use commonsense::data::synth;
use commonsense::entropy::{
    compress_residue, compress_sketch, decompress_residue, recover_sketch, SketchCodecParams,
};
use commonsense::matrix::CsMatrix;
use commonsense::metrics::{self, Bench, BenchProfile, BenchResult};
use commonsense::protocol::CsParams;
use commonsense::sketch::Sketch;
use commonsense::streaming::StreamDigest;

fn main() {
    let profile = BenchProfile::from_env_args();
    let mut results: Vec<BenchResult> = Vec::new();
    let n = 200_000usize;
    let d = 2_000usize;
    let params = CsParams::tuned_uni(n, d);
    let mat = params.matrix();
    let (_, b) = synth::subset_pair(n - d, d, 5);

    // One-shot encode: O(m)/element (Theorem 2's encoding complexity).
    let (w, me) = profile.times(300, 2000);
    let r = Bench::new(&format!("sketch_encode |S|={n} m={}", params.m))
        .with_times(w, me)
        .run(|| Sketch::encode(mat, &b).counts.len());
    let per_elem = r.mean.as_nanos() as f64 / n as f64;
    println!("  → {per_elem:.1} ns/element");
    results.push(r);

    // Streaming update: the §4 data-plane operation.
    let mut digest = StreamDigest::new(mat);
    let mut i = 0usize;
    let (w, me) = profile.times(300, 1500);
    let r = Bench::new("stream_update (add+remove)")
        .with_times(w, me)
        .run(|| {
            let id = b[i % b.len()];
            digest.add(id);
            digest.remove(id);
            i += 1;
        });
    println!("  → {:.1} ns per add+remove pair", r.mean.as_nanos());
    results.push(r);

    // Residue codec.
    let sk = Sketch::encode(mat, &synth::difference(&b, &b[..n - d]));
    let residue: Vec<i32> = sk.counts.clone();
    let bytes = compress_residue(&residue);
    println!(
        "residue codec: {} coords → {} bytes ({:.2} bits/coord)",
        residue.len(),
        bytes.len(),
        8.0 * bytes.len() as f64 / residue.len() as f64
    );
    let (w, me) = profile.times(200, 1200);
    results.push(
        Bench::new(&format!("rans_compress l={}", residue.len()))
            .with_times(w, me)
            .run(|| compress_residue(&residue).len()),
    );
    let (w, me) = profile.times(200, 1200);
    results.push(
        Bench::new(&format!("rans_decompress l={}", residue.len()))
            .with_times(w, me)
            .run(|| decompress_residue(&bytes, residue.len()).unwrap().len()),
    );

    // Truncation codec (Alice's sketch → wire and back).
    let full = Sketch::encode(mat, &b);
    let codec = SketchCodecParams::derive(d, 0, params.l, params.m);
    let msg = compress_sketch(&full.counts, &codec);
    println!(
        "truncation codec: raw {} bytes → {} bytes",
        4 * full.counts.len(),
        msg.size_bytes()
    );
    let (w, me) = profile.times(200, 1200);
    results.push(
        Bench::new("truncate_compress")
            .with_times(w, me)
            .run(|| compress_sketch(&full.counts, &codec).size_bytes()),
    );
    let y = full.counts.clone();
    let (w, me) = profile.times(200, 1200);
    results.push(
        Bench::new("truncate_recover")
            .with_times(w, me)
            .run(|| recover_sketch(&msg, &y, &codec).unwrap().0.len()),
    );

    // PJRT dense-block encode (L1 Pallas kernel through XLA), if built.
    if let Ok(rt) = commonsense::runtime::Runtime::load_default() {
        let shapes = rt.shapes;
        let pmat = CsMatrix::new(shapes.l as u32, 5, 9);
        let ids: Vec<u64> = (0..shapes.nb as u64).collect();
        let (w, me) = profile.times(300, 1500);
        let r = Bench::new(&format!("pjrt_encode_block {}x{}", shapes.l, shapes.nb))
            .with_times(w, me)
            .run(|| rt.encode_set(pmat, &ids).unwrap().len());
        println!(
            "  → {:.1} ns/element (incl. block materialization)",
            r.mean.as_nanos() as f64 / shapes.nb as f64
        );
        results.push(r);
    } else {
        println!("(pjrt encode bench skipped: run `make artifacts`)");
    }

    if profile.json {
        metrics::append_bench_json(
            metrics::BENCH_DECODE_JSON,
            &results,
            profile.fingerprint("encode_throughput"),
        )
        .expect("append bench trajectory");
        println!(
            "(trajectory: {} records appended to {})",
            results.len(),
            metrics::BENCH_DECODE_JSON
        );
    }
}
