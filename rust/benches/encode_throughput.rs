//! Bench E1 — the encode-side hot path backing Theorem 2's complexity claims and the
//! server's host-sketch reuse: serial (batched-sampling) vs parallel `Sketch::encode`
//! at the headline `n = 100000`, `SketchStore` hit vs miss, the §4 streaming update,
//! the rANS and truncation codec costs, and the PJRT dense-block encode path.
//!
//! Run: `cargo bench --offline --bench encode_throughput [-- --json] [-- --smoke]`
//! (`--json` appends to the root `BENCH_encode.json` trajectory. Headline series:
//! `sketch_encode n=100000` serial baseline plus `sketch_encode_par` threads = {1, 4},
//! so the parallel speedup ratio stays computable, and `sketch_store_hit` vs
//! `sketch_store_miss`, the store's per-session payoff.)

use commonsense::data::synth;
use commonsense::entropy::{
    compress_residue, compress_sketch, decompress_residue, recover_sketch, SketchCodecParams,
};
use commonsense::matrix::CsMatrix;
use commonsense::metrics::{self, Bench, BenchProfile, BenchResult};
use commonsense::protocol::CsParams;
use commonsense::server::SketchStore;
use commonsense::sketch::{EncodeConfig, Sketch, SketchSource};
use commonsense::streaming::StreamDigest;
use std::sync::Arc;

fn main() {
    let profile = BenchProfile::from_env_args();
    let mut results: Vec<BenchResult> = Vec::new();
    // The headline geometry, aligned with the decode bench's `mp_build n=100000 d=1000`.
    let n = 100_000usize;
    let d = 1_000usize;
    let params = CsParams::tuned_uni(n, d);
    let mat = params.matrix();
    let (_, b) = synth::subset_pair(n - d, d, 5);

    // Serial one-shot encode: O(m)/element (Theorem 2), batched column sampling.
    let (w, me) = profile.times(300, 2000);
    let r = Bench::new(&format!("sketch_encode n={n} m={} serial", params.m))
        .with_times(w, me)
        .run(|| Sketch::encode(mat, &b).counts.len());
    let per_elem = r.mean.as_nanos() as f64 / n as f64;
    println!("  → {per_elem:.1} ns/element");
    results.push(r);

    // Parallel encode at pinned thread counts. threads=1 resolves to the serial path
    // by construction (it should track the `serial` row exactly — a drift between the
    // two rows flags a dispatch regression); threads=4 is the speedup row, and its
    // ratio vs threads=1 is the pool's payoff.
    for threads in [1usize, 4] {
        let (w, me) = profile.times(300, 2000);
        let r = Bench::new(&format!("sketch_encode_par n={n} threads={threads}"))
            .with_times(w, me)
            .run(|| Sketch::encode_par(mat, &b, EncodeConfig { threads }).counts.len());
        println!("  → {:.1} ns/element", r.mean.as_nanos() as f64 / n as f64);
        results.push(r);
    }

    // Host-sketch store: a warm checkout (the steady-state server session) vs a forced
    // miss (cold geometry → full encode + insert). The hit/miss ratio is the store's
    // per-session payoff.
    let host: Arc<Vec<u64>> = Arc::new(b.clone());
    let store = SketchStore::new(4, Arc::clone(&host));
    store.host_sketch(&mat, &host, EncodeConfig::serial()); // warm the entry
    let (w, me) = profile.times(100, 800);
    results.push(
        Bench::new(&format!("sketch_store_hit n={n}"))
            .with_times(w, me)
            .run(|| store.host_sketch(&mat, &host, EncodeConfig::serial()).counts.len()),
    );
    // Forced misses: a capacity-1 store ping-ponged between two geometries never hits.
    let store1 = SketchStore::new(1, Arc::clone(&host));
    let other = CsMatrix::new(mat.l(), mat.m(), mat.sampler.seed ^ 1);
    let mut flip = false;
    let (w, me) = profile.times(300, 2000);
    results.push(Bench::new(&format!("sketch_store_miss n={n}")).with_times(w, me).run(|| {
        flip = !flip;
        let m = if flip { other } else { mat };
        store1.host_sketch(&m, &host, EncodeConfig::serial()).counts.len()
    }));

    // Streaming update: the §4 data-plane operation (also what keeps resident store
    // sketches warm through `replace_set` churn).
    let mut digest = StreamDigest::new(mat);
    let mut i = 0usize;
    let (w, me) = profile.times(300, 1500);
    let r = Bench::new("stream_update (add+remove)")
        .with_times(w, me)
        .run(|| {
            let id = b[i % b.len()];
            digest.add(id);
            digest.remove(id);
            i += 1;
        });
    println!("  → {:.1} ns per add+remove pair", r.mean.as_nanos());
    results.push(r);

    // Residue codec.
    let sk = Sketch::encode(mat, &synth::difference(&b, &b[..n - d]));
    let residue: Vec<i32> = sk.counts.clone();
    let bytes = compress_residue(&residue);
    println!(
        "residue codec: {} coords → {} bytes ({:.2} bits/coord)",
        residue.len(),
        bytes.len(),
        8.0 * bytes.len() as f64 / residue.len() as f64
    );
    let (w, me) = profile.times(200, 1200);
    results.push(
        Bench::new(&format!("rans_compress l={}", residue.len()))
            .with_times(w, me)
            .run(|| compress_residue(&residue).len()),
    );
    let (w, me) = profile.times(200, 1200);
    results.push(
        Bench::new(&format!("rans_decompress l={}", residue.len()))
            .with_times(w, me)
            .run(|| decompress_residue(&bytes, residue.len()).unwrap().len()),
    );

    // Truncation codec (Alice's sketch → wire and back).
    let full = Sketch::encode(mat, &b);
    let codec = SketchCodecParams::derive(d, 0, params.l, params.m);
    let msg = compress_sketch(&full.counts, &codec);
    println!(
        "truncation codec: raw {} bytes → {} bytes",
        4 * full.counts.len(),
        msg.size_bytes()
    );
    let (w, me) = profile.times(200, 1200);
    results.push(
        Bench::new("truncate_compress")
            .with_times(w, me)
            .run(|| compress_sketch(&full.counts, &codec).size_bytes()),
    );
    let y = full.counts.clone();
    let (w, me) = profile.times(200, 1200);
    results.push(
        Bench::new("truncate_recover")
            .with_times(w, me)
            .run(|| recover_sketch(&msg, &y, &codec).unwrap().0.len()),
    );

    // PJRT dense-block encode (L1 Pallas kernel through XLA), if built.
    if let Ok(rt) = commonsense::runtime::Runtime::load_default() {
        let shapes = rt.shapes;
        let pmat = CsMatrix::new(shapes.l as u32, 5, 9);
        let ids: Vec<u64> = (0..shapes.nb as u64).collect();
        let (w, me) = profile.times(300, 1500);
        let r = Bench::new(&format!("pjrt_encode_block {}x{}", shapes.l, shapes.nb))
            .with_times(w, me)
            .run(|| rt.encode_set(pmat, &ids).unwrap().len());
        println!(
            "  → {:.1} ns/element (incl. block materialization)",
            r.mean.as_nanos() as f64 / shapes.nb as f64
        );
        results.push(r);
    } else {
        println!("(pjrt encode bench skipped: run `make artifacts`)");
    }

    if profile.json {
        metrics::append_bench_json(
            metrics::BENCH_ENCODE_JSON,
            &results,
            profile.fingerprint("encode_throughput"),
        )
        .expect("append bench trajectory");
        println!(
            "(trajectory: {} records appended to {})",
            results.len(),
            metrics::BENCH_ENCODE_JSON
        );
    }
}
