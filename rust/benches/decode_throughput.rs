//! Bench D1 — decode-time comparison backing the §1.1/§7.3 running-time claims:
//! the MP decoder should sit within a small factor of IBLT peeling (the paper: "a couple
//! of times slower than D.Digest") while PinSketch's BCH decode is orders slower at large d.
//! Also covers the SSMP (L1) and BMP ablations and the PJRT dense-block decode path.
//!
//! Run: `cargo bench --offline --bench decode_throughput [-- --json] [-- --smoke]`
//!
//! `--json` appends every result to the root `BENCH_decode.json` trajectory. The
//! headline pair there is `mp_build n=100000 d=1000 threads={1,4}`: the serial baseline
//! vs the parallel decoder construction, so the speedup ratio is tracked run over run.

use commonsense::baselines::iblt::{Iblt, IbltParams};
use commonsense::data::synth;
use commonsense::decoder::{DecoderConfig, MpDecoder, Side};
use commonsense::matrix::CsMatrix;
use commonsense::metrics::{self, Bench, BenchProfile, BenchResult};
use commonsense::protocol::CsParams;
use commonsense::sketch::Sketch;

fn main() {
    let profile = BenchProfile::from_env_args();
    let mut results: Vec<BenchResult> = Vec::new();
    let n = 100_000usize;
    // The smoke profile keeps the headline d=1000 point so CI tracks it on every push.
    let ds: &[usize] = if profile.smoke { &[1_000] } else { &[100, 1_000, 5_000] };
    for &d in ds {
        let params = CsParams::tuned_uni(n, d);
        let mat = params.matrix();
        let (a, b) = synth::subset_pair(n - d, d, 7);
        let want = synth::difference(&b, &a);
        let residue: Vec<i32> = Sketch::encode(mat, &want).counts;

        // Decoder construction (CSR + reverse lookup) is a one-time per-session cost;
        // bench it separately from the pursuit loop — serial baseline first, then the
        // parallel build, so the JSON trajectory records both sides of the ratio.
        for threads in [1usize, 4] {
            let config = DecoderConfig { build_threads: threads, ..DecoderConfig::default() };
            let (w, me) = profile.times(200, 1200);
            results.push(
                Bench::new(&format!("mp_build n={n} d={d} threads={threads}"))
                    .with_times(w, me)
                    .run(|| {
                        MpDecoder::with_config(&mat, &b, Side::Positive, config).num_candidates()
                    }),
            );
        }

        let mut dec = MpDecoder::new(&mat, &b, Side::Positive);
        dec.set_config(DecoderConfig::commonsense());
        let (w, me) = profile.times(200, 1500);
        results.push(
            Bench::new(&format!("mp_decode(L2) n={n} d={d}"))
                .with_times(w, me)
                .run(|| {
                    dec.reset_signal();
                    dec.load_residue(&residue);
                    let stats = dec.run();
                    assert!(stats.converged);
                    stats.iterations
                }),
        );

        let mut ssmp = MpDecoder::new(&mat, &b, Side::Positive);
        ssmp.set_config(DecoderConfig::ssmp());
        let (w, me) = profile.times(200, 1500);
        results.push(
            Bench::new(&format!("ssmp_decode(L1) n={n} d={d}"))
                .with_times(w, me)
                .run(|| {
                    ssmp.reset_signal();
                    ssmp.load_residue(&residue);
                    ssmp.run().iterations
                }),
        );

        // IBLT peel at the same d (the D.Digest decode step).
        let iparams = IbltParams::paper_synthetic();
        let mut ia = Iblt::for_difference(d, iparams);
        ia.insert_all(&a);
        let mut ib = Iblt::for_difference(d, iparams);
        ib.insert_all(&b);
        let diff = ia.sub(&ib);
        let (w, me) = profile.times(200, 1200);
        results.push(
            Bench::new(&format!("iblt_peel d={d}"))
                .with_times(w, me)
                .run(|| {
                    let (p, ng) = diff.clone().peel().expect("peel");
                    p.len() + ng.len()
                }),
        );
    }

    // PinSketch (BCH) decode: O(d²) BM + Chien — the reason the paper only *estimates*
    // ECC costs. Position space 2^14 per partition, d errors.
    let pinsketch_ds: &[usize] = if profile.smoke { &[50] } else { &[50, 200, 800] };
    for &d in pinsketch_ds {
        use commonsense::baselines::pinsketch::PinSketch;
        let ps = PinSketch::new(14, d + 8);
        let positions: Vec<u32> = (0..d as u32).map(|i| i * 17 + 3).collect();
        let mine = ps.sketch(positions.iter().copied());
        let theirs = ps.sketch(std::iter::empty());
        let (w, me) = profile.times(200, 1200);
        results.push(
            Bench::new(&format!("pinsketch_decode d={d}"))
                .with_times(w, me)
                .run(|| ps.diff(&mine, &theirs).expect("decode").len()),
        );
    }

    // PJRT dense-block decode (the L1/L2 artifact), if built.
    if let Ok(rt) = commonsense::runtime::Runtime::load_default() {
        let shapes = rt.shapes;
        let mat = CsMatrix::new(shapes.l as u32, 5, 3);
        let ids: Vec<u64> = (0..shapes.nb as u64).collect();
        let block = mat.dense_block_rowmajor(&ids, shapes.nb);
        let planted: Vec<u64> = (0..24u64).map(|i| i * 83 + 1).collect();
        let r0: Vec<f32> = Sketch::encode(mat, &planted)
            .counts
            .iter()
            .map(|&c| c as f32)
            .collect();
        let x0 = vec![0.0f32; shapes.nb];
        let (w, me) = profile.times(300, 1500);
        results.push(
            Bench::new(&format!(
                "pjrt_decode_block {}x{} steps={}",
                shapes.l, shapes.nb, shapes.steps
            ))
            .with_times(w, me)
            .run(|| {
                let (r, _x) = rt.decode_block(&block, &r0, &x0, 5.0).unwrap();
                r.len()
            }),
        );
    } else {
        println!("(pjrt decode bench skipped: run `make artifacts`)");
    }

    if profile.json {
        metrics::append_bench_json(
            metrics::BENCH_DECODE_JSON,
            &results,
            profile.fingerprint("decode_throughput"),
        )
        .expect("append bench trajectory");
        println!("(trajectory: {} records appended to {})", results.len(), metrics::BENCH_DECODE_JSON);
    }
}
