//! Bench: one N-party [`setx::multi`](commonsense::setx::multi) round at N = {3, 5, 8}
//! — wall-clock per round plus bytes-per-party, every iteration verified against the
//! exact intersection before it is allowed to count.
//!
//! Run: `cargo bench --offline --bench multi_round [-- --json] [-- --smoke]`
//! (`--json` appends the results to the root `BENCH_protocol.json` trajectory next to
//! the two-party fig2a/fig2b rows; `--smoke` is the CI profile.)

use commonsense::data::synth;
use commonsense::metrics::{self, BenchProfile, BenchResult};
use commonsense::setx::Setx;
use std::time::{Duration, Instant};

fn main() {
    let profile = BenchProfile::from_env_args();
    let common = if profile.smoke { 2_000 } else { 20_000 };
    let unique = if profile.smoke { 25 } else { 200 };
    let iters = if profile.smoke { 1u32 } else { 3 };
    let mut results: Vec<BenchResult> = Vec::new();
    for parties in [3usize, 5, 8] {
        let sets = synth::overlap_n(parties, common, unique, 0xA115 + parties as u64);
        let mut expected = sets[0].clone();
        for s in &sets[1..] {
            expected = synth::intersect(&expected, s);
        }
        let mut total = Duration::ZERO;
        let mut min = Duration::MAX;
        let mut bytes_per_party = 0usize;
        let (mut enc, mut raw) = (0usize, 0usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            let report = Setx::multi(&sets).expect("multi round");
            let dt = t0.elapsed();
            assert_eq!(report.intersection, expected, "unverified timing is worthless");
            assert_eq!(report.completed(), parties - 1);
            total += dt;
            min = min.min(dt);
            bytes_per_party = report.total_bytes() / (parties - 1);
            enc = report.total_bytes();
            raw = report.total_raw_bytes();
        }
        let ratio = enc as f64 / raw as f64;
        let name = format!(
            "multi_round parties={parties} common={common} unique={unique} \
             bytes_per_party={bytes_per_party} codec=on raw={raw} enc={enc} ratio={ratio:.4}"
        );
        println!("bench {name:<84} {:>10.1?} / round", total / iters);
        results.push(BenchResult {
            name,
            mean: total / iters,
            min,
            p50: total / iters,
            p99: total / iters,
            iters: iters as u64,
        });

        // Codec-off ablation: same sets, columnar framing disabled on every endpoint.
        // Its wire total must equal the codec-on run's raw-bytes column exactly.
        let t0 = Instant::now();
        let off = Setx::builder(&sets[0])
            .codec(false)
            .parties(&sets[1..])
            .expect("multi builder")
            .run()
            .expect("multi round (codec off)");
        let dt = t0.elapsed();
        assert_eq!(off.intersection, expected, "codec must not change the answer");
        assert_eq!(off.total_bytes(), raw, "codec-off wire must equal codec-on raw bytes");
        let name = format!(
            "multi_round parties={parties} common={common} unique={unique} \
             bytes_per_party={} codec=off raw={raw} enc={raw} ratio=1.0000",
            off.total_bytes() / (parties - 1)
        );
        println!("bench {name:<84} {:>10.1?} / round", dt);
        results.push(BenchResult { name, mean: dt, min: dt, p50: dt, p99: dt, iters: 1 });
    }
    if profile.json {
        metrics::append_bench_json(
            metrics::BENCH_PROTOCOL_JSON,
            &results,
            profile.fingerprint("multi_round"),
        )
        .expect("append BENCH_protocol.json");
    }
}
