//! Bench: one N-party [`setx::multi`](commonsense::setx::multi) round at N = {3, 5, 8}
//! — wall-clock per round plus bytes-per-party, every iteration verified against the
//! exact intersection before it is allowed to count.
//!
//! Run: `cargo bench --offline --bench multi_round [-- --json] [-- --smoke]`
//! (`--json` appends the results to the root `BENCH_protocol.json` trajectory next to
//! the two-party fig2a/fig2b rows; `--smoke` is the CI profile.)

use commonsense::data::synth;
use commonsense::metrics::{self, BenchProfile, BenchResult};
use commonsense::setx::Setx;
use std::time::{Duration, Instant};

fn main() {
    let profile = BenchProfile::from_env_args();
    let common = if profile.smoke { 2_000 } else { 20_000 };
    let unique = if profile.smoke { 25 } else { 200 };
    let iters = if profile.smoke { 1u32 } else { 3 };
    let mut results: Vec<BenchResult> = Vec::new();
    for parties in [3usize, 5, 8] {
        let sets = synth::overlap_n(parties, common, unique, 0xA115 + parties as u64);
        let mut expected = sets[0].clone();
        for s in &sets[1..] {
            expected = synth::intersect(&expected, s);
        }
        let mut total = Duration::ZERO;
        let mut min = Duration::MAX;
        let mut bytes_per_party = 0usize;
        for _ in 0..iters {
            let t0 = Instant::now();
            let report = Setx::multi(&sets).expect("multi round");
            let dt = t0.elapsed();
            assert_eq!(report.intersection, expected, "unverified timing is worthless");
            assert_eq!(report.completed(), parties - 1);
            total += dt;
            min = min.min(dt);
            bytes_per_party = report.total_bytes() / (parties - 1);
        }
        let name = format!(
            "multi_round parties={parties} common={common} unique={unique} \
             bytes_per_party={bytes_per_party}"
        );
        println!("bench {name:<84} {:>10.1?} / round", total / iters);
        results.push(BenchResult { name, mean: total / iters, min, iters: iters as u64 });
    }
    if profile.json {
        metrics::append_bench_json(
            metrics::BENCH_PROTOCOL_JSON,
            &results,
            profile.fingerprint("multi_round"),
        )
        .expect("append BENCH_protocol.json");
    }
}
