//! Bench F2b — regenerates Figure 2b (bidirectional comm-cost sweep: CommonSense vs IBLT
//! vs ECC bound) and times the ping-pong pipeline, plus the O10 rounds observation.
//!
//! Run: `cargo bench --offline --bench fig2b_bidirectional
//!       [-- --scale N --instances K] [-- --json] [-- --smoke]`
//! (`--json` appends the timing results to the root `BENCH_protocol.json` trajectory;
//! `--smoke` is the CI profile: small scale, one instance per point.)

use commonsense::data::synth;
use commonsense::experiments;
use commonsense::metrics::{self, Bench, BenchProfile, BenchResult};
use commonsense::protocol::bidi::{self, BidiOptions};
use commonsense::protocol::CsParams;

fn flag(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let profile = BenchProfile::from_env_args();
    let scale = flag("--scale", if profile.smoke { 4_000 } else { 20_000 });
    let instances = flag("--instances", if profile.smoke { 1 } else { 3 });
    let a_unique = scale / 100;
    let fractions: &[f64] = if profile.smoke {
        &[0.001, 0.01, 0.1]
    } else {
        &[0.0001, 0.0003, 0.001, 0.003, 0.01, 0.1, 0.3]
    };
    let bu: Vec<usize> = fractions
        .iter()
        .map(|f| ((scale as f64 * f) as usize).max(2))
        .collect();
    println!("== Figure 2b regeneration (|A∩B| = {scale}, |A\\B| = {a_unique}) ==");
    let rows = experiments::fig2b(scale, a_unique, &bu, instances, true);
    let (lo, hi) = (&rows[0], rows.last().unwrap());
    println!(
        "\nshape: IBLT/CS {:.1}x → {:.1}x across the sweep (paper: 7.8x → 14.8x); \
         rounds avg {:.1}–{:.1} (paper: 7.0–8.6, cap 10)",
        lo.iblt_bytes / lo.commonsense_bytes,
        hi.iblt_bytes / hi.commonsense_bytes,
        lo.commonsense_rounds,
        hi.commonsense_rounds
    );

    println!("\n== end-to-end bidirectional timing ==");
    let mut results: Vec<BenchResult> = Vec::new();
    let pairs: &[(usize, usize)] =
        if profile.smoke { &[(100, 200)] } else { &[(100, 200), (500, 500)] };
    for &(au, bu) in pairs {
        let (a, b) = synth::overlap_pair(scale, au, bu, 0xbf);
        let params = CsParams::tuned_bidi(scale + au + bu, au, bu);
        let (w, me) = profile.times(200, 1500);
        results.push(
            Bench::new(&format!("bidi_run n={scale} au={au} bu={bu}"))
                .with_times(w, me)
                .run(|| {
                    let out = bidi::run(&a, &b, &params, BidiOptions::default());
                    assert!(out.converged);
                    out.comm.total_bytes()
                }),
        );
    }

    // Columnar-codec ablation: identical ping-pong, codec-on vs codec-off framing; the
    // SMF boolean-RLE re-encode makes the bidirectional path a guaranteed net win.
    println!("\n== columnar codec ablation ==");
    for &(au, bu) in pairs {
        let (a, b) = synth::overlap_pair(scale, au, bu, 0xbf);
        let params = CsParams::tuned_bidi(scale + au + bu, au, bu);
        let opts_on = BidiOptions::default();
        let opts_off = BidiOptions { codec: false, ..BidiOptions::default() };
        let on = bidi::run(&a, &b, &params, opts_on);
        let off = bidi::run(&a, &b, &params, opts_off);
        assert!(on.converged && off.converged);
        let (enc, raw) = (on.comm.total_bytes(), on.comm.total_raw_bytes());
        assert_eq!(raw, off.comm.total_bytes(), "raw accounting must equal codec-off wire");
        let ratio = enc as f64 / raw as f64;
        println!("bidi au={au} bu={bu}: raw {raw} B, encoded {enc} B, ratio {ratio:.4}");
        let (w, me) = profile.times(200, 1500);
        results.push(
            Bench::new(&format!(
                "bidi_codec n={scale} au={au} bu={bu} codec=on raw={raw} enc={enc} \
                 ratio={ratio:.4}"
            ))
            .with_times(w, me)
            .run(|| bidi::run(&a, &b, &params, opts_on).comm.total_bytes()),
        );
        let (w, me) = profile.times(200, 1500);
        results.push(
            Bench::new(&format!(
                "bidi_codec n={scale} au={au} bu={bu} codec=off raw={raw} enc={raw} \
                 ratio=1.0000"
            ))
            .with_times(w, me)
            .run(|| bidi::run(&a, &b, &params, opts_off).comm.total_bytes()),
        );
    }

    if profile.json {
        metrics::append_bench_json(
            metrics::BENCH_PROTOCOL_JSON,
            &results,
            profile.fingerprint("fig2b_bidirectional"),
        )
        .expect("append bench trajectory");
        println!(
            "(trajectory: {} records appended to {})",
            results.len(),
            metrics::BENCH_PROTOCOL_JSON
        );
    }
}
