//! Bench T2 — regenerates Tables 1 & 2 (Ethereum-sim SetX: CommonSense vs IBLT) and times
//! the full Ethereum-workload session including the partitioned parallel variant (§7.3).
//!
//! Run: `cargo bench --offline --bench table2_ethereum
//!       [-- --accounts N] [-- --json] [-- --smoke]`
//! (`--json` appends the timing results to the root `BENCH_protocol.json` trajectory;
//! `--smoke` is the CI profile: a small account population.)

use commonsense::coordinator::parallel;
use commonsense::data::ethereum::{diff_stats, EthSim};
use commonsense::experiments;
use commonsense::metrics::{self, Bench, BenchProfile, BenchResult};
use commonsense::protocol::bidi::{self, BidiOptions};
use commonsense::protocol::CsParams;

fn flag(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let profile = BenchProfile::from_env_args();
    let accounts = flag("--accounts", if profile.smoke { 30_000 } else { 150_000 });
    println!("== Tables 1+2 regeneration (Ethereum-sim, {accounts} accounts) ==");
    let (_t1, t2) = experiments::ethereum(accounts, true);
    println!(
        "\nshape: IBLT/CS = {:.1}x and {:.1}x (paper: 8.3x, 10.1x); CS rounds {} and {} (paper: 5)",
        t2[0].3 / t2[0].1,
        t2[1].3 / t2[1].1,
        t2[0].2,
        t2[1].2
    );

    println!("\n== session timing (1-day staleness pair) ==");
    let mut results: Vec<BenchResult> = Vec::new();
    let mut sim = EthSim::genesis(accounts / 3, 0xbeac);
    let b = sim.snapshot_ids();
    sim.advance_day();
    let a = sim.snapshot_ids();
    let st = diff_stats(&b, &a);
    let params = CsParams::tuned_bidi(a.len().max(b.len()), st.s_minus_a, st.a_minus_s);
    let (w, me) = profile.times(300, 2000);
    results.push(
        Bench::new(&format!("eth_bidi n={} d={}", a.len(), st.sym_diff))
            .with_times(w, me)
            .run(|| {
                let out = bidi::run(&b, &a, &params, BidiOptions::default());
                assert!(out.converged);
                out.comm.total_bytes()
            }),
    );
    // Columnar-codec ablation on the Table-2 workload — the acceptance gate for the
    // wire::column layer: codec-on must be a strict byte win on this realistic diff, and
    // the codec-on transcript's raw-bytes column must reproduce the codec-off wire.
    let opts_on = BidiOptions::default();
    let opts_off = BidiOptions { codec: false, ..BidiOptions::default() };
    let on = bidi::run(&b, &a, &params, opts_on);
    let off = bidi::run(&b, &a, &params, opts_off);
    assert!(on.converged && off.converged);
    assert_eq!(on.a_minus_b, off.a_minus_b, "codec must not change protocol decisions");
    let (enc, raw) = (on.comm.total_bytes(), on.comm.total_raw_bytes());
    assert_eq!(raw, off.comm.total_bytes(), "raw accounting must equal codec-off wire");
    assert!(enc < raw, "codec on ({enc} B) must strictly beat codec off ({raw} B)");
    let ratio = enc as f64 / raw as f64;
    println!("codec ablation: raw {raw} B, encoded {enc} B, ratio {ratio:.4}");
    let (w, me) = profile.times(300, 2000);
    results.push(
        Bench::new(&format!(
            "eth_codec n={} d={} codec=on raw={raw} enc={enc} ratio={ratio:.4}",
            a.len(),
            st.sym_diff
        ))
        .with_times(w, me)
        .run(|| bidi::run(&b, &a, &params, opts_on).comm.total_bytes()),
    );
    let (w, me) = profile.times(300, 2000);
    results.push(
        Bench::new(&format!(
            "eth_codec n={} d={} codec=off raw={raw} enc={raw} ratio=1.0000",
            a.len(),
            st.sym_diff
        ))
        .with_times(w, me)
        .run(|| bidi::run(&b, &a, &params, opts_off).comm.total_bytes()),
    );
    let (w, me) = profile.times(300, 2000);
    results.push(
        Bench::new("eth_parallel_8x")
            .with_times(w, me)
            .run(|| {
                let out = parallel::setx(
                    &a,
                    &b,
                    st.a_minus_s,
                    st.s_minus_a,
                    8,
                    8,
                    BidiOptions::default(),
                );
                assert!(out.converged);
                out.total_bytes
            }),
    );

    if profile.json {
        metrics::append_bench_json(
            metrics::BENCH_PROTOCOL_JSON,
            &results,
            profile.fingerprint("table2_ethereum"),
        )
        .expect("append bench trajectory");
        println!(
            "(trajectory: {} records appended to {})",
            results.len(),
            metrics::BENCH_PROTOCOL_JSON
        );
    }
}
