//! Multi-client server throughput: sessions/sec of a [`SetxServer`] under the verifying
//! loadgen fleet, at clients = {1, 8, 32}, with the shared decoder pool on vs off.
//!
//! The pool-off column is the ablation: it pays full decoder construction per session,
//! so the on/off ratio is the server-side payoff of PR 3's decoder-reuse machinery at
//! fleet scale. Every session's intersection is verified — a throughput number from
//! wrong answers would be worthless.
//!
//! `cargo bench --bench server_throughput -- [--json] [--smoke]` — `--json` appends one
//! record per configuration to the repo-root `BENCH_server.json` trajectory
//! ([`commonsense::metrics::BENCH_SERVER_JSON`]): `mean_ns`/`min_ns` are wall-clock per
//! session (the inverse of sessions/sec; concurrency included), `iters` the sessions
//! completed.

use commonsense::metrics::{append_bench_json, BenchProfile, BenchResult, BENCH_SERVER_JSON};
use commonsense::server::loadgen::{self, LoadgenConfig};
use commonsense::server::SetxServer;
use std::time::Instant;

const WORKERS: usize = 4;

fn main() {
    let profile = BenchProfile::from_env_args();
    // Smoke keeps the headline shape (same clients sweep, pool on vs off) at CI scale.
    let common = if profile.smoke { 4_000 } else { 50_000 };
    let rounds = if profile.smoke { 2 } else { 4 };
    let mut results = Vec::new();
    for pool_on in [true, false] {
        for clients in [1usize, 8, 32] {
            let cfg = LoadgenConfig { clients, rounds, common, ..LoadgenConfig::default() };
            let (host, _, _) = cfg.workload();
            let endpoint = cfg.endpoint(&host).expect("loadgen config is always valid");
            let server = SetxServer::builder(endpoint)
                .workers(WORKERS)
                .max_inflight_sessions(2 * clients + 8)
                .pool_capacity(if pool_on { 4 * WORKERS } else { 0 })
                .bind("127.0.0.1:0")
                .expect("bind ephemeral loopback listener");
            let t0 = Instant::now();
            let report = loadgen::run(server.local_addr(), &cfg);
            let elapsed = t0.elapsed();
            let stats = server.shutdown();
            assert!(
                report.verified(),
                "throughput of wrong answers is meaningless: {:?}",
                report.failures
            );
            let sessions = report.sessions_ok.max(1);
            let per_session = elapsed / sessions as u32;
            let name = format!(
                "server_throughput common={common} clients={clients} rounds={rounds} \
                 workers={WORKERS} pool={}",
                if pool_on { "on" } else { "off" }
            );
            println!(
                "bench {name:<72} {:>8.1} sessions/s (pool hit rate {:.3}, peak workers {})",
                report.sessions_per_sec(),
                stats.pool_hit_rate(),
                stats.peak_workers
            );
            results.push(BenchResult {
                name,
                mean: per_session,
                min: per_session,
                iters: sessions as u64,
            });
        }
    }
    if profile.json {
        append_bench_json(
            BENCH_SERVER_JSON,
            &results,
            profile.fingerprint("server_throughput"),
        )
        .expect("append BENCH_server.json");
    }
}
