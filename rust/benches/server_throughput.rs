//! Multi-client server throughput: sessions/sec of a [`SetxServer`] under the verifying
//! loadgen fleet, at clients = {1, 8, 32}, with the shared decoder pool and the
//! host-sketch store on vs off, plus a `workers` sweep at the fleet shape.
//!
//! The off columns are the ablations: pool-off pays full decoder construction per
//! session, store-off pays a full host-set encode per session, so the on/off ratios are
//! the server-side payoff of the reuse machinery at fleet scale. The workers sweep
//! (clients = 8, everything on) shows how that payoff scales with server parallelism.
//! Every session's intersection is verified — a throughput number from wrong answers
//! would be worthless.
//!
//! `cargo bench --bench server_throughput -- [--json] [--smoke]` — `--json` appends one
//! record per configuration to the repo-root `BENCH_server.json` trajectory
//! ([`commonsense::metrics::BENCH_SERVER_JSON`]): `mean_ns`/`min_ns` are wall-clock per
//! session (the inverse of sessions/sec; concurrency included), `iters` the sessions
//! completed.

use commonsense::metrics::{append_bench_json, BenchProfile, BenchResult, BENCH_SERVER_JSON};
use commonsense::server::loadgen::{self, LoadgenConfig};
use commonsense::server::SetxServer;
use std::time::Instant;

const WORKERS: usize = 4;

/// One verified fleet run; returns the per-session wall-clock record.
fn run_config(
    common: usize,
    rounds: usize,
    clients: usize,
    workers: usize,
    pool_on: bool,
    store_on: bool,
) -> BenchResult {
    let cfg = LoadgenConfig { clients, rounds, common, ..LoadgenConfig::default() };
    let (host, _, _) = cfg.workload();
    let endpoint = cfg.endpoint(&host).expect("loadgen config is always valid");
    let server = SetxServer::builder(endpoint)
        .workers(workers)
        .max_inflight_sessions(2 * clients + 8)
        .pool_capacity(if pool_on { 4 * workers } else { 0 })
        .sketch_store_capacity(if store_on { 8 } else { 0 })
        .bind("127.0.0.1:0")
        .expect("bind ephemeral loopback listener");
    let t0 = Instant::now();
    let report = loadgen::run(server.local_addr(), &cfg);
    let elapsed = t0.elapsed();
    let stats = server.shutdown();
    assert!(
        report.verified(),
        "throughput of wrong answers is meaningless: {:?}",
        report.failures
    );
    let sessions = report.sessions_ok.max(1);
    let per_session = elapsed / sessions as u32;
    let name = format!(
        "server_throughput common={common} clients={clients} rounds={rounds} \
         workers={workers} pool={} store={}",
        if pool_on { "on" } else { "off" },
        if store_on { "on" } else { "off" }
    );
    println!(
        "bench {name:<84} {:>8.1} sessions/s (pool hit {:.3}, store hit {:.3}, peak workers {})",
        report.sessions_per_sec(),
        stats.pool_hit_rate(),
        stats.sketch_store_hit_rate(),
        stats.peak_workers
    );
    BenchResult { name, mean: per_session, min: per_session, iters: sessions as u64 }
}

fn main() {
    let profile = BenchProfile::from_env_args();
    // Smoke keeps the headline shape (same sweeps, reuse on vs off) at CI scale.
    let common = if profile.smoke { 4_000 } else { 50_000 };
    let rounds = if profile.smoke { 2 } else { 4 };
    let mut results = Vec::new();
    // Clients sweep × reuse ablations: everything-on, store-off (encode ablation),
    // everything-off (the PR 3-era baseline).
    for (pool_on, store_on) in [(true, true), (true, false), (false, false)] {
        for clients in [1usize, 8, 32] {
            results.push(run_config(common, rounds, clients, WORKERS, pool_on, store_on));
        }
    }
    // Workers sweep at the fleet shape (clients = 8, reuse on): the ROADMAP's
    // scale-with-parallelism axis.
    for workers in [1usize, 2, 8] {
        results.push(run_config(common, rounds, 8, workers, true, true));
    }
    if profile.json {
        append_bench_json(
            BENCH_SERVER_JSON,
            &results,
            profile.fingerprint("server_throughput"),
        )
        .expect("append BENCH_server.json");
    }
}
