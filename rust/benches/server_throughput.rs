//! Multi-client server throughput: sessions/sec of a [`SetxServer`] under the verifying
//! loadgen fleet, at clients = {1, 8, 32}, with the shared decoder pool and the
//! host-sketch store on vs off, plus a `workers` sweep at the fleet shape, an
//! `--estimate-d` mixed-geometry column at clients = {8, 32}, a connection-scaling
//! column at clients = {64, 256, 1024} × workers = {2, 4} over a mixed-tenant fleet,
//! and a `replace_set`-churn-under-load row.
//!
//! The off columns are the ablations: pool-off pays full decoder construction per
//! session, store-off pays a full host-set encode per session, so the on/off ratios are
//! the server-side payoff of the reuse machinery at fleet scale. The workers sweep
//! (clients = 8, everything on) shows how that payoff scales with server parallelism.
//! The scaling column measures the readiness-based driver itself (small sets, one
//! round): how sessions/sec holds up as resident connections outnumber poller threads
//! by 2-3 orders of magnitude. The churn row hot-swaps tenant 0's host set every ~2ms
//! while the fleet runs — resident sketches are diff-maintained mid-flight and every
//! answer still verifies. The estimate-d rows drop the explicit difference-size
//! declaration: clients estimate `d` from sketch moments during the handshake, so
//! estimator noise spreads sessions across matrix geometries and the pool/store shards
//! actually contend instead of all sessions hitting one hot geometry. Every session's
//! intersection is verified in all rows — a throughput number from wrong answers would
//! be worthless.
//!
//! The `session_latency` rows are the observability column: client-observed per-session
//! wall-time tails (p50/p99 off the loadgen's [`LogHistogram`]) at clients = {64, 256},
//! plus a `tracing=off` ablation at the same shape — every endpoint built with the span
//! timeline disabled — so the on/off pair bounds the instrumentation overhead (<2%).
//!
//! `cargo bench --bench server_throughput -- [--json] [--smoke]` — `--json` appends one
//! record per configuration to the repo-root `BENCH_server.json` trajectory
//! ([`commonsense::metrics::BENCH_SERVER_JSON`]): `mean_ns`/`min_ns` are wall-clock per
//! session (the inverse of sessions/sec; concurrency included), `p50_ns`/`p99_ns` the
//! client-observed per-session latency tails (concurrency NOT divided out), `iters` the
//! sessions completed.
//!
//! [`LogHistogram`]: commonsense::obs::hist::LogHistogram

use commonsense::metrics::{append_bench_json, BenchProfile, BenchResult, BENCH_SERVER_JSON};
use commonsense::server::loadgen::{self, LoadgenConfig};
use commonsense::server::SetxServer;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::{Duration, Instant};

const WORKERS: usize = 4;

// setrlimit(2), hand-rolled (mirrors the integration tests): the 1024-client scaling
// rows need ~3 fds per live session and the default soft cap is often exactly 1024.
const RLIMIT_NOFILE: i32 = 7;

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

extern "C" {
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

/// Raise the fd soft limit toward `want` (bounded by the hard limit); returns the
/// effective soft limit so the sweep can scale down instead of failing.
fn raise_nofile(want: u64) -> u64 {
    unsafe {
        let mut lim = RLimit { rlim_cur: 0, rlim_max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 1024;
        }
        if lim.rlim_cur < want {
            let raised = RLimit { rlim_cur: want.min(lim.rlim_max), rlim_max: lim.rlim_max };
            if setrlimit(RLIMIT_NOFILE, &raised) == 0 {
                return raised.rlim_cur;
            }
        }
        lim.rlim_cur
    }
}

/// One verified fleet run; returns the per-session wall-clock record. `tenants > 1`
/// spreads the fleet round-robin over that many resident namespaces (each with its own
/// host set and pool/store shards); `estimate_d` makes every client estimate the
/// difference size in the handshake instead of declaring it, so sessions negotiate
/// mixed matrix geometries.
fn run_config(
    common: usize,
    rounds: usize,
    clients: usize,
    workers: usize,
    tenants: usize,
    pool_on: bool,
    store_on: bool,
    estimate_d: bool,
) -> BenchResult {
    let cfg = LoadgenConfig {
        clients,
        rounds,
        common,
        tenants,
        estimate_diff: estimate_d,
        ..LoadgenConfig::default()
    };
    let (hosts, _, _) = cfg.tenant_workload();
    let endpoint = cfg.endpoint(&hosts[0]).expect("loadgen config is always valid");
    let server = SetxServer::builder(endpoint)
        .workers(workers)
        .max_inflight_sessions(2 * clients + 8)
        .pool_capacity(if pool_on { 4 * workers } else { 0 })
        .sketch_store_capacity(if store_on { 8 } else { 0 })
        .bind("127.0.0.1:0")
        .expect("bind ephemeral loopback listener");
    for (ns, host) in hosts.iter().enumerate().skip(1) {
        assert!(server.add_tenant(ns as u32, host.clone()), "duplicate tenant {ns}");
    }
    let t0 = Instant::now();
    let report = loadgen::run(server.local_addr(), &cfg);
    let elapsed = t0.elapsed();
    let stats = server.shutdown();
    assert!(
        report.verified(),
        "throughput of wrong answers is meaningless: {:?}",
        report.failures.iter().take(5).collect::<Vec<_>>()
    );
    let sessions = report.sessions_ok.max(1);
    let per_session = elapsed / sessions as u32;
    let mut name = format!(
        "server_throughput common={common} clients={clients} rounds={rounds} \
         workers={workers} pool={} store={}",
        if pool_on { "on" } else { "off" },
        if store_on { "on" } else { "off" }
    );
    if tenants > 1 {
        name.push_str(&format!(" tenants={tenants}"));
    }
    if estimate_d {
        name.push_str(" estimate_d=on");
    }
    println!(
        "bench {name:<84} {:>8.1} sessions/s (pool hit {:.3}, store hit {:.3}, peak workers {})",
        report.sessions_per_sec(),
        stats.pool_hit_rate(),
        stats.sketch_store_hit_rate(),
        stats.peak_workers
    );
    BenchResult {
        name,
        mean: per_session,
        min: per_session,
        p50: Duration::from_nanos(report.p50_ns()),
        p99: Duration::from_nanos(report.p99_ns()),
        iters: sessions as u64,
    }
}

/// The churn row: the fleet syncs while a control thread hot-swaps tenant 0's host set
/// every ~2ms. Only server-unique tail elements are swapped (the common core every
/// client checks is untouched) and the set length is preserved, so in-flight sessions
/// keep their negotiated geometry and the resident sketch is §4-diff-maintained rather
/// than rebuilt — the encode cache must stay warm *and* every answer must stay exact.
fn run_churn(common: usize, clients: usize, workers: usize) -> BenchResult {
    let cfg = LoadgenConfig { clients, rounds: 2, common, ..LoadgenConfig::default() };
    let (host, _, _) = cfg.workload();
    let endpoint = cfg.endpoint(&host).expect("loadgen config is always valid");
    let server = SetxServer::builder(endpoint)
        .workers(workers)
        .max_inflight_sessions(2 * clients + 8)
        .bind("127.0.0.1:0")
        .expect("bind ephemeral loopback listener");
    let stop = AtomicBool::new(false);
    let t0 = Instant::now();
    let (report, swaps) = std::thread::scope(|scope| {
        let churner = scope.spawn(|| {
            let mut swaps = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let mut churned = host[..host.len() - 50].to_vec();
                let base = 1_000_000_000 + swaps * 64;
                churned.extend(base..base + 50);
                server.replace_set(churned);
                swaps += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
            swaps
        });
        let report = loadgen::run(server.local_addr(), &cfg);
        stop.store(true, Ordering::Relaxed);
        (report, churner.join().expect("churn thread"))
    });
    let elapsed = t0.elapsed();
    let stats = server.shutdown();
    assert!(
        report.verified(),
        "churn must not corrupt answers: {:?}",
        report.failures.iter().take(5).collect::<Vec<_>>()
    );
    assert!(swaps >= 1, "the churner never got a swap in");
    let sessions = report.sessions_ok.max(1);
    let per_session = elapsed / sessions as u32;
    let name =
        format!("server_throughput churn common={common} clients={clients} workers={workers}");
    println!(
        "bench {name:<84} {:>8.1} sessions/s ({swaps} set swaps mid-run, {} incremental updates, {} rebuilds)",
        report.sessions_per_sec(),
        stats.sketch_store.incremental_updates,
        stats.sketch_store.full_rebuilds
    );
    BenchResult {
        name,
        mean: per_session,
        min: per_session,
        p50: Duration::from_nanos(report.p50_ns()),
        p99: Duration::from_nanos(report.p99_ns()),
        iters: sessions as u64,
    }
}

/// The fault-rate ablation: the same fleet with a seeded per-attempt disconnect rate
/// injected at the client transport — every drop absorbed by the shared retry layer.
/// Goodput (sessions/s of *verified* answers) and the retry count land in the row, so
/// the trajectory shows what 5% connection churn costs against the 0% baseline. Seed 7
/// is chosen so the coin fires at both the smoke and full shapes without ever
/// exhausting the default budget (worst streak 1 vs budget 3).
fn run_faults(
    common: usize,
    rounds: usize,
    clients: usize,
    workers: usize,
    rate: f64,
) -> BenchResult {
    let cfg = LoadgenConfig {
        clients,
        rounds,
        common,
        seed: 7,
        disconnect_rate: rate,
        ..LoadgenConfig::default()
    };
    let (host, _, _) = cfg.workload();
    let endpoint = cfg.endpoint(&host).expect("loadgen config is always valid");
    let server = SetxServer::builder(endpoint)
        .workers(workers)
        .max_inflight_sessions(2 * clients + 8)
        .bind("127.0.0.1:0")
        .expect("bind ephemeral loopback listener");
    let t0 = Instant::now();
    let report = loadgen::run(server.local_addr(), &cfg);
    let elapsed = t0.elapsed();
    server.shutdown();
    assert!(
        report.verified(),
        "the retry layer must absorb every injected drop: {:?}",
        report.failures.iter().take(5).collect::<Vec<_>>()
    );
    assert_eq!(report.gave_up, 0, "no session may exhaust the budget at {rate}");
    if rate > 0.0 {
        assert!(report.retries > 0, "seed 7 must inject at least one drop");
    }
    let sessions = report.sessions_ok.max(1);
    let per_session = elapsed / sessions as u32;
    let name = format!(
        "server_throughput faults disconnect={}% clients={clients} rounds={rounds} \
         workers={workers} retries={}",
        (rate * 100.0).round() as u32,
        report.retries
    );
    println!(
        "bench {name:<84} {:>8.1} sessions/s ({} retries, {} gave up, {} B total)",
        report.sessions_per_sec(),
        report.retries,
        report.gave_up,
        report.total_bytes
    );
    BenchResult {
        name,
        mean: per_session,
        min: per_session,
        p50: Duration::from_nanos(report.p50_ns()),
        p99: Duration::from_nanos(report.p99_ns()),
        iters: sessions as u64,
    }
}

/// The observability rows: per-session latency tails over a three-tenant fleet, with
/// the span timeline on (default) or off on every endpoint. Headline numbers are the
/// histogram tails, not sessions/sec — mean/min still record wall-clock per session so
/// the trajectory schema stays uniform.
fn run_latency(common: usize, clients: usize, workers: usize, tracing: bool) -> BenchResult {
    let cfg = LoadgenConfig {
        clients,
        rounds: 1,
        common,
        tenants: 3,
        tracing,
        ..LoadgenConfig::default()
    };
    let (hosts, _, _) = cfg.tenant_workload();
    let endpoint = cfg.endpoint(&hosts[0]).expect("loadgen config is always valid");
    let server = SetxServer::builder(endpoint)
        .workers(workers)
        .max_inflight_sessions(2 * clients + 8)
        .bind("127.0.0.1:0")
        .expect("bind ephemeral loopback listener");
    for (ns, host) in hosts.iter().enumerate().skip(1) {
        assert!(server.add_tenant(ns as u32, host.clone()), "duplicate tenant {ns}");
    }
    let t0 = Instant::now();
    let report = loadgen::run(server.local_addr(), &cfg);
    let elapsed = t0.elapsed();
    server.shutdown();
    assert!(
        report.verified(),
        "latency of wrong answers is meaningless: {:?}",
        report.failures.iter().take(5).collect::<Vec<_>>()
    );
    let sessions = report.sessions_ok.max(1);
    let name = format!(
        "session_latency clients={clients} workers={workers} tracing={}",
        if tracing { "on" } else { "off" }
    );
    println!(
        "bench {name:<84} p50={:?} p95={:?} p99={:?} over {sessions} sessions",
        Duration::from_nanos(report.p50_ns()),
        Duration::from_nanos(report.p95_ns()),
        Duration::from_nanos(report.p99_ns())
    );
    BenchResult {
        name,
        mean: elapsed / sessions as u32,
        min: elapsed / sessions as u32,
        p50: Duration::from_nanos(report.p50_ns()),
        p99: Duration::from_nanos(report.p99_ns()),
        iters: sessions as u64,
    }
}

fn main() {
    let profile = BenchProfile::from_env_args();
    // Smoke keeps the headline shape (same sweeps, reuse on vs off) at CI scale.
    let common = if profile.smoke { 4_000 } else { 50_000 };
    let rounds = if profile.smoke { 2 } else { 4 };
    let mut results = Vec::new();
    // Clients sweep × reuse ablations: everything-on, store-off (encode ablation),
    // everything-off (the PR 3-era baseline).
    for (pool_on, store_on) in [(true, true), (true, false), (false, false)] {
        for clients in [1usize, 8, 32] {
            results.push(run_config(common, rounds, clients, WORKERS, 1, pool_on, store_on, false));
        }
    }
    // Workers sweep at the fleet shape (clients = 8, reuse on): the ROADMAP's
    // scale-with-parallelism axis.
    for workers in [1usize, 2, 8] {
        results.push(run_config(common, rounds, 8, workers, 1, true, true, false));
    }
    // Mixed-geometry column (the ROADMAP's `--estimate-d` row): clients estimate d from
    // sketch moments during the handshake instead of declaring it, so estimator noise
    // spreads sessions across matrix geometries — stressing the reuse layer's sharding
    // instead of the one-hot-geometry sweet spot every explicit-d row above sits in.
    for clients in [8usize, 32] {
        results.push(run_config(common, rounds, clients, WORKERS, 1, true, true, true));
    }
    // Connection-scaling column: a three-tenant fleet at clients = {64, 256, 1024} on
    // workers = {2, 4} pollers, one round over small sets — this measures the
    // readiness-based driver, not the codec.
    let scale_common = if profile.smoke { 600 } else { 4_000 };
    let limit = raise_nofile(4 * 1024 + 256);
    let client_cap = ((limit.saturating_sub(256) / 3) as usize).max(16);
    for workers in [2usize, 4] {
        for clients in [64usize, 256, 1024] {
            results.push(run_config(
                scale_common,
                1,
                clients.min(client_cap),
                workers,
                3,
                true,
                true,
                false,
            ));
        }
    }
    // Observability column: session-latency tails at clients = {64, 256}, then the
    // tracing-off ablation at clients = 64 — the on/off pair bounds the span-timeline
    // overhead (budgeted < 2%, well inside fleet noise at this shape).
    for clients in [64usize, 256] {
        results.push(run_latency(scale_common, clients.min(client_cap), WORKERS, true));
    }
    results.push(run_latency(scale_common, 64.min(client_cap), WORKERS, false));
    // Fault-rate ablation: 0% baseline vs 5% injected disconnects at the fleet shape —
    // goodput with the retry cost in the row name.
    for rate in [0.0, 0.05] {
        results.push(run_faults(common, rounds, 8, WORKERS, rate));
    }
    // Churn-under-load: replace_set every ~2ms while the fleet runs.
    results.push(run_churn(if profile.smoke { 2_000 } else { 20_000 }, 8, WORKERS));
    if profile.json {
        append_bench_json(
            BENCH_SERVER_JSON,
            &results,
            profile.fingerprint("server_throughput"),
        )
        .expect("append BENCH_server.json");
    }
}
