//! Bench F2a — regenerates Figure 2a (unidirectional comm-cost sweep: CommonSense vs
//! Graphene vs bounds) and times the end-to-end unidirectional pipeline.
//!
//! Run: `cargo bench --offline --bench fig2a_unidirectional
//!       [-- --scale N --instances K] [-- --json] [-- --smoke]`
//! (`--json` appends the timing results to the root `BENCH_protocol.json` trajectory;
//! `--smoke` is the CI profile: small scale, one instance per point.)

use commonsense::data::synth;
use commonsense::experiments;
use commonsense::metrics::{self, Bench, BenchProfile, BenchResult};
use commonsense::protocol::{uni, CsParams};

fn flag(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let profile = BenchProfile::from_env_args();
    let scale = flag("--scale", if profile.smoke { 4_000 } else { 20_000 });
    let instances = flag("--instances", if profile.smoke { 1 } else { 3 });
    let fractions: &[f64] = if profile.smoke {
        &[0.01, 0.1, 1.0]
    } else {
        &[0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5]
    };
    println!("== Figure 2a regeneration (scale {scale}, {instances} instances/point) ==");
    let rows = experiments::fig2a(scale, fractions, instances, true);
    // Paper shape checks (who wins, where the crossover goes).
    let first = &rows[0];
    println!(
        "\nshape: CS/Graphene gap at d=1%: {:.1}x (paper: 7.4x); CS vs SetR-bound: {:.1}x under",
        first.graphene_bytes / first.commonsense_bytes,
        first.setr_bound_bytes / first.commonsense_bytes
    );

    println!("\n== end-to-end unidirectional timing ==");
    let mut results: Vec<BenchResult> = Vec::new();
    let ds: &[usize] = if profile.smoke { &[200] } else { &[200, 1_000] };
    for &d in ds {
        let (a, b) = synth::subset_pair(scale, d, 0xbe);
        let params = CsParams::tuned_uni(b.len(), d);
        let (w, me) = profile.times(200, 1500);
        results.push(
            Bench::new(&format!("uni_run n={scale} d={d}"))
                .with_times(w, me)
                .run(|| uni::run(&a, &b, &params).unwrap().comm.total_bytes()),
        );
    }

    // Columnar-codec ablation: the same sketch framed codec-on vs codec-off, with the
    // per-frame raw/encoded accounting baked into the trajectory row names.
    println!("\n== columnar codec ablation ==");
    for &d in ds {
        let (a, b) = synth::subset_pair(scale, d, 0xbe);
        let params = CsParams::tuned_uni(b.len(), d);
        let on = uni::run_with_codec(&a, &b, &params, true).unwrap();
        let off = uni::run(&a, &b, &params).unwrap();
        let (enc, raw) = (on.comm.total_bytes(), on.comm.total_raw_bytes());
        assert_eq!(raw, off.comm.total_bytes(), "raw accounting must equal codec-off wire");
        let ratio = enc as f64 / raw as f64;
        println!("uni d={d}: raw {raw} B, encoded {enc} B, ratio {ratio:.4}");
        let (w, me) = profile.times(200, 1500);
        results.push(
            Bench::new(&format!(
                "uni_codec n={scale} d={d} codec=on raw={raw} enc={enc} ratio={ratio:.4}"
            ))
            .with_times(w, me)
            .run(|| uni::run_with_codec(&a, &b, &params, true).unwrap().comm.total_bytes()),
        );
        let (w, me) = profile.times(200, 1500);
        results.push(
            Bench::new(&format!(
                "uni_codec n={scale} d={d} codec=off raw={raw} enc={raw} ratio=1.0000"
            ))
            .with_times(w, me)
            .run(|| uni::run(&a, &b, &params).unwrap().comm.total_bytes()),
        );
    }

    if profile.json {
        metrics::append_bench_json(
            metrics::BENCH_PROTOCOL_JSON,
            &results,
            profile.fingerprint("fig2a_unidirectional"),
        )
        .expect("append bench trajectory");
        println!(
            "(trajectory: {} records appended to {})",
            results.len(),
            metrics::BENCH_PROTOCOL_JSON
        );
    }
}
