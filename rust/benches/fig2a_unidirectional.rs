//! Bench F2a — regenerates Figure 2a (unidirectional comm-cost sweep: CommonSense vs
//! Graphene vs bounds) and times the end-to-end unidirectional pipeline.
//!
//! Run: `cargo bench --offline --bench fig2a_unidirectional [-- --scale N --instances K]`

use commonsense::data::synth;
use commonsense::experiments;
use commonsense::metrics::Bench;
use commonsense::protocol::{uni, CsParams};

fn flag(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let scale = flag("--scale", 20_000);
    let instances = flag("--instances", 3);
    println!("== Figure 2a regeneration (scale {scale}, {instances} instances/point) ==");
    let rows = experiments::fig2a(
        scale,
        &[0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5],
        instances,
        true,
    );
    // Paper shape checks (who wins, where the crossover goes).
    let first = &rows[0];
    println!(
        "\nshape: CS/Graphene gap at d=1%: {:.1}x (paper: 7.4x); CS vs SetR-bound: {:.1}x under",
        first.graphene_bytes / first.commonsense_bytes,
        first.setr_bound_bytes / first.commonsense_bytes
    );

    println!("\n== end-to-end unidirectional timing ==");
    for d in [200usize, 1_000] {
        let (a, b) = synth::subset_pair(scale, d, 0xbe);
        let params = CsParams::tuned_uni(b.len(), d);
        Bench::new(&format!("uni_run n={scale} d={d}"))
            .with_times(200, 1500)
            .run(|| uni::run(&a, &b, &params).unwrap().comm.total_bytes());
    }
}
