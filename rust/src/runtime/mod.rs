//! PJRT runtime: load the AOT-compiled HLO artifacts and run them from rust.
//!
//! `make artifacts` lowers the Layer-2 JAX graphs (which call the Layer-1 Pallas kernels)
//! to HLO text; this module compiles them once on the PJRT CPU client and exposes typed
//! entry points. Python never runs at request time — the rust binary is self-contained
//! once `artifacts/` exists.
//!
//! The accelerated path operates on *dense universe-partition blocks* (DESIGN.md
//! §Hardware-Adaptation): `l × nb` 0/1 column blocks in row-major f32, matching the JAX
//! array layout.

use crate::matrix::CsMatrix;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shapes baked into the artifacts (from `artifacts/manifest.txt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockShapes {
    pub l: usize,
    pub nb: usize,
    pub steps: usize,
}

/// A compiled-artifact registry bound to a PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    pub shapes: BlockShapes,
    dir: PathBuf,
}

impl Runtime {
    /// Default artifact directory (repo-relative), overridable via `COMMONSENSE_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("COMMONSENSE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Load every artifact listed in `manifest.txt` and compile it on the CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt (run `make artifacts`)", dir.display()))?;
        let mut lines = manifest.lines();
        let header = lines.next().ok_or_else(|| anyhow!("empty manifest"))?;
        let mut l = 0usize;
        let mut nb = 0usize;
        let mut steps = 0usize;
        for kv in header.split_whitespace() {
            let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("bad manifest header"))?;
            let v: usize = v.parse()?;
            match k {
                "l" => l = v,
                "nb" => nb = v,
                "steps" => steps = v,
                _ => {}
            }
        }
        let client = xla::PjRtClient::cpu()?;
        let mut execs = HashMap::new();
        for name in lines {
            let name = name.trim();
            if name.is_empty() {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(dir.join(name))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            let key = name
                .split_once('_')
                .map(|(k, _)| k.to_string())
                .unwrap_or_else(|| name.to_string());
            execs.insert(key, exe);
        }
        if l == 0 || nb == 0 {
            return Err(anyhow!("manifest missing shapes"));
        }
        Ok(Runtime { client, execs, shapes: BlockShapes { l, nb, steps }, dir })
    }

    /// Convenience: load from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(Self::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    fn exec(&self, key: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.execs
            .get(key)
            .ok_or_else(|| anyhow!("artifact `{key}` not in manifest at {}", self.dir.display()))
    }

    /// y = M_block @ x. `m_block` is row-major `l × nb` f32; `x` has length `nb`.
    pub fn encode_block(&self, m_block: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let BlockShapes { l, nb, .. } = self.shapes;
        assert_eq!(m_block.len(), l * nb);
        assert_eq!(x.len(), nb);
        let m = xla::Literal::vec1(m_block).reshape(&[l as i64, nb as i64])?;
        let xv = xla::Literal::vec1(x);
        let result = self.exec("encode")?.execute::<xla::Literal>(&[m, xv])?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// δ = M_blockᵀ r / m.
    pub fn correlate_block(&self, m_block: &[f32], r: &[f32], m_ones: f32) -> Result<Vec<f32>> {
        let BlockShapes { l, nb, .. } = self.shapes;
        assert_eq!(m_block.len(), l * nb);
        assert_eq!(r.len(), l);
        let m = xla::Literal::vec1(m_block).reshape(&[l as i64, nb as i64])?;
        let rv = xla::Literal::vec1(r);
        let mo = xla::Literal::vec1(&[m_ones]).reshape(&[])?;
        let result = self.exec("correlate")?.execute::<xla::Literal>(&[m, rv, mo])?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple1()?.to_vec::<f32>()?)
    }

    /// Run `steps` MP iterations on a block: returns `(r, x)` after the scan.
    pub fn decode_block(
        &self,
        m_block: &[f32],
        r: &[f32],
        x: &[f32],
        m_ones: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let BlockShapes { l, nb, .. } = self.shapes;
        assert_eq!(m_block.len(), l * nb);
        assert_eq!(r.len(), l);
        assert_eq!(x.len(), nb);
        let m = xla::Literal::vec1(m_block).reshape(&[l as i64, nb as i64])?;
        let rv = xla::Literal::vec1(r);
        let xv = xla::Literal::vec1(x);
        let mo = xla::Literal::vec1(&[m_ones]).reshape(&[])?;
        let result = self.exec("decode")?.execute::<xla::Literal>(&[m, rv, xv, mo])?[0][0]
            .to_literal_sync()?;
        let (r_out, x_out) = result.to_tuple2()?;
        Ok((r_out.to_vec::<f32>()?, x_out.to_vec::<f32>()?))
    }

    /// Accelerated set encoding for a partition whose matrix has exactly `shapes.l` rows:
    /// chunks ids into `nb`-column dense blocks (zero-padded) and accumulates `M·1_S`
    /// through the AOT encode executable.
    pub fn encode_set(&self, matrix: CsMatrix, ids: &[u64]) -> Result<Vec<i32>> {
        let BlockShapes { l, nb, .. } = self.shapes;
        assert_eq!(matrix.l() as usize, l, "partition matrix must match artifact l");
        let mut acc = vec![0i64; l];
        let ones = vec![1.0f32; nb];
        for chunk in ids.chunks(nb) {
            let block = matrix.dense_block_rowmajor(chunk, nb);
            let y = self.encode_block(&block, &ones)?;
            for (a, v) in acc.iter_mut().zip(&y) {
                *a += *v as i64;
            }
        }
        Ok(acc.into_iter().map(|v| v as i32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::Sketch;

    fn runtime() -> Option<Runtime> {
        // Skip (not fail) when artifacts haven't been built in this checkout.
        Runtime::load_default().ok()
    }

    #[test]
    fn artifacts_load_and_report_platform() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        assert!(rt.shapes.l >= 128 && rt.shapes.nb >= 512);
    }

    #[test]
    fn encode_block_matches_sparse_sketch() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let BlockShapes { l, nb, .. } = rt.shapes;
        let matrix = CsMatrix::new(l as u32, 5, 99);
        let ids: Vec<u64> = (0..nb as u64 / 2).map(|i| i * 31 + 7).collect();
        let accel = rt.encode_set(matrix, &ids).unwrap();
        let sparse = Sketch::encode(matrix, &ids);
        assert_eq!(accel, sparse.counts);
    }

    #[test]
    fn decode_block_recovers_planted_signal() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let BlockShapes { l, nb, steps } = rt.shapes;
        let matrix = CsMatrix::new(l as u32, 5, 123);
        let ids: Vec<u64> = (0..nb as u64).collect();
        let block = matrix.dense_block_rowmajor(&ids, nb);
        // Plant 10 elements.
        let planted: Vec<u64> = (0..10u64).map(|i| i * 101 + 3).collect();
        let sk = Sketch::encode(matrix, &planted);
        let r0: Vec<f32> = sk.counts.iter().map(|&c| c as f32).collect();
        let x0 = vec![0.0f32; nb];
        let mut r = r0;
        let mut x = x0;
        for _ in 0..(20usize).div_ceil(steps).max(1) {
            let (r2, x2) = rt.decode_block(&block, &r, &x, 5.0).unwrap();
            r = r2;
            x = x2;
            if r.iter().all(|&v| v == 0.0) {
                break;
            }
        }
        assert!(r.iter().all(|&v| v == 0.0), "residue not cleared");
        let got: Vec<u64> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > 0.5)
            .map(|(i, _)| ids[i])
            .collect();
        let mut want = planted;
        want.sort_unstable();
        let mut got = got;
        got.sort_unstable();
        assert_eq!(got, want);
    }
}
