//! Artifact runtime: load the AOT-compiled block-kernel artifacts and run them from rust.
//!
//! `make artifacts` lowers the Layer-2 JAX graphs (which call the Layer-1 Pallas kernels)
//! to HLO text plus a `manifest.txt` of shapes. The offline image's crate set carries no
//! PJRT/XLA bindings (no `xla` crate — see DESIGN.md §4), so this module executes the
//! artifact graphs with a **bit-faithful native executor**: the three graphs are dense
//! matvecs and a greedy binary-MP scan, implemented here exactly as in the build-time
//! oracle `python/compile/kernels/ref.py` (which the Pallas kernels are verified against).
//! The manifest is still the source of truth for shapes, and the listed HLO files must be
//! present, so `make artifacts` remains the gate for this path.
//!
//! The accelerated path operates on *dense universe-partition blocks* (DESIGN.md
//! §Hardware-Adaptation): `l × nb` 0/1 column blocks in row-major f32, matching the JAX
//! array layout.

use crate::matrix::CsMatrix;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Shapes baked into the artifacts (from `artifacts/manifest.txt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockShapes {
    pub l: usize,
    pub nb: usize,
    pub steps: usize,
}

/// An artifact registry bound to the native block executor.
pub struct Runtime {
    /// Graph names present in the manifest (`encode`, `correlate`, `decode`).
    graphs: Vec<String>,
    pub shapes: BlockShapes,
    dir: PathBuf,
}

impl Runtime {
    /// Default artifact directory (repo-relative), overridable via `COMMONSENSE_ARTIFACTS`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("COMMONSENSE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Load the manifest, validate every listed artifact file, and bind the executor.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading {}/manifest.txt (run `make artifacts`)", dir.display()))?;
        let mut lines = manifest.lines();
        let header = lines.next().ok_or_else(|| anyhow!("empty manifest"))?;
        let mut l = 0usize;
        let mut nb = 0usize;
        let mut steps = 0usize;
        for kv in header.split_whitespace() {
            let (k, v) = kv.split_once('=').ok_or_else(|| anyhow!("bad manifest header"))?;
            let v: usize = v.parse()?;
            match k {
                "l" => l = v,
                "nb" => nb = v,
                "steps" => steps = v,
                _ => {}
            }
        }
        let mut graphs = Vec::new();
        for name in lines {
            let name = name.trim();
            if name.is_empty() {
                continue;
            }
            let path = dir.join(name);
            if !path.is_file() {
                return Err(anyhow!("artifact `{}` listed but missing", path.display()));
            }
            let key = name
                .split_once('_')
                .map(|(k, _)| k.to_string())
                .unwrap_or_else(|| name.to_string());
            graphs.push(key);
        }
        if l == 0 || nb == 0 {
            return Err(anyhow!("manifest missing shapes"));
        }
        Ok(Runtime { graphs, shapes: BlockShapes { l, nb, steps: steps.max(1) }, dir })
    }

    /// Convenience: load from the default directory.
    pub fn load_default() -> Result<Self> {
        Self::load(Self::default_dir())
    }

    /// Execution platform. The native executor runs on the host CPU (the artifacts are
    /// CPU-lowered HLO as well, so reported results are comparable).
    pub fn platform(&self) -> String {
        "cpu".to_string()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    fn require(&self, key: &str) -> Result<()> {
        if self.graphs.iter().any(|g| g == key) {
            Ok(())
        } else {
            Err(anyhow!("artifact `{key}` not in manifest at {}", self.dir.display()))
        }
    }

    /// y = M_block @ x. `m_block` is row-major `l × nb` f32; `x` has length `nb`.
    pub fn encode_block(&self, m_block: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let BlockShapes { l, nb, .. } = self.shapes;
        assert_eq!(m_block.len(), l * nb);
        assert_eq!(x.len(), nb);
        self.require("encode")?;
        let mut y = vec![0.0f32; l];
        for (row, yr) in y.iter_mut().enumerate() {
            let base = row * nb;
            let mut acc = 0.0f32;
            for (c, &xc) in x.iter().enumerate() {
                acc += m_block[base + c] * xc;
            }
            *yr = acc;
        }
        Ok(y)
    }

    /// δ = M_blockᵀ r / m (eq. B.1).
    pub fn correlate_block(&self, m_block: &[f32], r: &[f32], m_ones: f32) -> Result<Vec<f32>> {
        let BlockShapes { l, nb, .. } = self.shapes;
        assert_eq!(m_block.len(), l * nb);
        assert_eq!(r.len(), l);
        self.require("correlate")?;
        Ok(Self::correlate_raw(m_block, r, m_ones, nb))
    }

    fn correlate_raw(m_block: &[f32], r: &[f32], m_ones: f32, nb: usize) -> Vec<f32> {
        let mut delta = vec![0.0f32; nb];
        for (row, &rv) in r.iter().enumerate() {
            if rv == 0.0 {
                continue;
            }
            let base = row * nb;
            for (c, d) in delta.iter_mut().enumerate() {
                *d += m_block[base + c] * rv;
            }
        }
        for d in &mut delta {
            *d /= m_ones;
        }
        delta
    }

    /// Run `shapes.steps` greedy binary-MP iterations on a block (Procedure 1 +
    /// Modification 9, exactly `decode_steps_ref` in the Python oracle): per step,
    /// compute every candidate's gain, flip the argmax if positive, update the residue.
    /// Returns `(r, x)` after the scan.
    pub fn decode_block(
        &self,
        m_block: &[f32],
        r: &[f32],
        x: &[f32],
        m_ones: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let BlockShapes { l, nb, steps } = self.shapes;
        assert_eq!(m_block.len(), l * nb);
        assert_eq!(r.len(), l);
        assert_eq!(x.len(), nb);
        self.require("decode")?;
        let mut r = r.to_vec();
        let mut x = x.to_vec();
        for _ in 0..steps {
            let delta = Self::correlate_raw(m_block, &r, m_ones, nb);
            // Gain in units of m: setting needs δ > 1/2 (rule 2), unsetting δ < −1/2.
            let mut best_j = 0usize;
            let mut best_gain = f32::NEG_INFINITY;
            for (j, &d) in delta.iter().enumerate() {
                let gain = if x[j] < 0.5 { 2.0 * d - 1.0 } else { -2.0 * d - 1.0 };
                if gain > best_gain {
                    best_gain = gain;
                    best_j = j;
                }
            }
            if best_gain <= 0.0 {
                break; // fixed point: the scan would be a no-op from here on
            }
            let setting = x[best_j] < 0.5;
            let sign = if setting { 1.0 } else { -1.0 };
            for (row, rv) in r.iter_mut().enumerate() {
                *rv -= sign * m_block[row * nb + best_j];
            }
            x[best_j] = if setting { 1.0 } else { 0.0 };
        }
        Ok((r, x))
    }

    /// Accelerated set encoding for a partition whose matrix has exactly `shapes.l` rows:
    /// chunks ids into `nb`-column dense blocks (zero-padded) and accumulates `M·1_S`
    /// through the encode graph.
    pub fn encode_set(&self, matrix: CsMatrix, ids: &[u64]) -> Result<Vec<i32>> {
        let BlockShapes { l, nb, .. } = self.shapes;
        assert_eq!(matrix.l() as usize, l, "partition matrix must match artifact l");
        let mut acc = vec![0i64; l];
        let ones = vec![1.0f32; nb];
        for chunk in ids.chunks(nb) {
            let block = matrix.dense_block_rowmajor(chunk, nb);
            let y = self.encode_block(&block, &ones)?;
            for (a, v) in acc.iter_mut().zip(&y) {
                *a += *v as i64;
            }
        }
        Ok(acc.into_iter().map(|v| v as i32).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::Sketch;

    fn runtime() -> Option<Runtime> {
        // Skip (not fail) when artifacts haven't been built in this checkout.
        Runtime::load_default().ok()
    }

    /// A manifest-free runtime for exercising the executor itself.
    fn native(l: usize, nb: usize, steps: usize) -> Runtime {
        Runtime {
            graphs: vec!["encode".into(), "correlate".into(), "decode".into()],
            shapes: BlockShapes { l, nb, steps },
            dir: PathBuf::from("artifacts"),
        }
    }

    #[test]
    fn artifacts_load_and_report_platform() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        assert!(rt.shapes.l >= 128 && rt.shapes.nb >= 512);
    }

    #[test]
    fn encode_block_matches_sparse_sketch() {
        let rt = native(256, 512, 8);
        let matrix = CsMatrix::new(256, 5, 99);
        let ids: Vec<u64> = (0..700u64).map(|i| i * 31 + 7).collect();
        let accel = rt.encode_set(matrix, &ids).unwrap();
        let sparse = Sketch::encode(matrix, &ids);
        assert_eq!(accel, sparse.counts);
    }

    #[test]
    fn correlate_matches_sparse_dot() {
        let rt = native(256, 128, 8);
        let matrix = CsMatrix::new(256, 5, 17);
        let ids: Vec<u64> = (0..128u64).collect();
        let block = matrix.dense_block_rowmajor(&ids, 128);
        let sk = Sketch::encode(matrix, &ids[..40]);
        let r: Vec<f32> = sk.counts.iter().map(|&c| c as f32).collect();
        let delta = rt.correlate_block(&block, &r, 5.0).unwrap();
        for (j, &id) in ids.iter().enumerate() {
            let mut dot = 0i32;
            for row in matrix.column(id) {
                dot += sk.counts[row as usize];
            }
            let want = dot as f32 / 5.0;
            assert!((delta[j] - want).abs() < 1e-4, "j={j}: {} vs {want}", delta[j]);
        }
    }

    #[test]
    fn decode_block_recovers_planted_signal() {
        let rt = native(512, 256, 8);
        let BlockShapes { nb, steps, .. } = rt.shapes;
        let matrix = CsMatrix::new(512, 5, 123);
        let ids: Vec<u64> = (0..nb as u64).collect();
        let block = matrix.dense_block_rowmajor(&ids, nb);
        // Plant 10 elements.
        let planted: Vec<u64> = (0..10u64).map(|i| i * 17 + 3).collect();
        let sk = Sketch::encode(matrix, &planted);
        let mut r: Vec<f32> = sk.counts.iter().map(|&c| c as f32).collect();
        let mut x = vec![0.0f32; nb];
        for _ in 0..(40usize).div_ceil(steps).max(1) {
            let (r2, x2) = rt.decode_block(&block, &r, &x, 5.0).unwrap();
            r = r2;
            x = x2;
            if r.iter().all(|&v| v == 0.0) {
                break;
            }
        }
        assert!(r.iter().all(|&v| v == 0.0), "residue not cleared");
        let mut got: Vec<u64> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| v > 0.5)
            .map(|(i, _)| ids[i])
            .collect();
        let mut want = planted;
        want.sort_unstable();
        got.sort_unstable();
        assert_eq!(got, want);
    }
}
