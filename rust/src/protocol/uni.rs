//! Unidirectional CommonSense (§3): one message, exact `A ∩ B` when `A ⊆ B`.
//!
//! 1. Alice encodes `A` into the CS sketch `M·1_A`, truncation-compresses it (Appendix C.2),
//!    and sends it — the single round of communication.
//! 2. Bob recovers `M·1_A`, forms `r = M·1_B − M·1_A = M·1_{B\A}`, and losslessly
//!    reconstructs `1_{B\A}` with the binary MP decoder (falling back to L1 pursuit /
//!    SSMP if the L2 pursuit stalls). Then `A ∩ B = B \ (B\A)`.

use crate::decoder::{run_with_fallback, DecoderConfig, MpDecoder, Side};
use crate::entropy::{compress_sketch, recover_sketch, SketchCodecParams};
use crate::metrics::CommLog;
use crate::protocol::{wire::Msg, CsParams};
use crate::sketch::Sketch;

/// Result of a unidirectional run.
#[derive(Clone, Debug)]
pub struct UniOutcome {
    /// Bob's recovered `B \ A` (sorted).
    pub b_minus_a: Vec<u64>,
    /// `A ∩ B` (sorted) — equal to `A` when the protocol succeeds and `A ⊆ B`.
    pub intersection: Vec<u64>,
    /// Full message accounting.
    pub comm: CommLog,
    /// Decoder fell back to L1 pursuit.
    pub used_fallback: bool,
}

/// Alice's half: produce the (framed) sketch message.
pub fn alice_encode(a: &[u64], params: &CsParams) -> (Msg, usize) {
    let sketch = Sketch::encode(params.matrix(), a);
    let codec = SketchCodecParams::derive(params.est_b_unique, params.est_a_unique, params.l, params.m);
    let msg = Msg::Sketch(compress_sketch(&sketch.counts, &codec));
    let size = msg.to_bytes().len();
    (msg, size)
}

/// Bob's half: decode `B \ A` from the received sketch message.
pub fn bob_decode(msg: &Msg, b: &[u64], params: &CsParams) -> Option<(Vec<u64>, bool)> {
    let Msg::Sketch(sketch_msg) = msg else {
        return None;
    };
    let matrix = params.matrix();
    let my_sketch = Sketch::encode(matrix, b);
    let codec = SketchCodecParams::derive(params.est_b_unique, params.est_a_unique, params.l, params.m);
    let (x_hat, _repaired, _unresolved) = recover_sketch(sketch_msg, &my_sketch.counts, &codec)?;
    // r = M·1_B − M̂·1_A, canonical orientation (Bob-positive).
    let residue: Vec<i32> = my_sketch
        .counts
        .iter()
        .zip(&x_hat)
        .map(|(y, x)| y - x)
        .collect();

    let mut dec = MpDecoder::new(&matrix, b, Side::Positive);
    dec.set_config(DecoderConfig::commonsense());
    dec.load_residue(&residue);
    // §3.4: fall back to the RIP-1-safe L1 pursuit (SSMP) when vanilla MP stalls — the
    // same escalation ladder the ping-pong session engine uses (without its kicks: a
    // one-shot decode has no later rounds to absorb a wrong kick).
    let (_stats, used_fallback) = run_with_fallback(&mut dec, true, 0);
    let mut b_minus_a = dec.estimate();
    b_minus_a.sort_unstable();
    Some((b_minus_a, used_fallback))
}

/// End-to-end in-memory run with exact byte accounting.
pub fn run(a: &[u64], b: &[u64], params: &CsParams) -> Option<UniOutcome> {
    let mut comm = CommLog::new();
    let (msg, size) = alice_encode(a, params);
    comm.record(true, "sketch", size);
    // Serialize/deserialize through the real wire format (what TCP would carry).
    let bytes = msg.to_bytes();
    let (received, _) = Msg::from_bytes(&bytes)?;
    let (b_minus_a, used_fallback) = bob_decode(&received, b, params)?;
    let exclude: std::collections::HashSet<u64> = b_minus_a.iter().copied().collect();
    let mut intersection: Vec<u64> = b.iter().copied().filter(|x| !exclude.contains(x)).collect();
    intersection.sort_unstable();
    Some(UniOutcome { b_minus_a, intersection, comm, used_fallback })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn exact_intersection_small() {
        let (a, b) = synth::subset_pair(5_000, 50, 1);
        let params = CsParams::tuned_uni(b.len(), 50);
        let out = run(&a, &b, &params).unwrap();
        let mut want = a.clone();
        want.sort_unstable();
        assert_eq!(out.intersection, want);
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert_eq!(out.comm.rounds(), 1, "unidirectional = one message");
    }

    #[test]
    fn exact_intersection_many_seeds() {
        for seed in 0..10 {
            let (a, b) = synth::subset_pair(20_000, 200, seed);
            let params = CsParams::tuned_uni(b.len(), 200);
            let out = run(&a, &b, &params).unwrap();
            assert_eq!(out.b_minus_a, synth::difference(&b, &a), "seed {seed}");
        }
    }

    #[test]
    fn comm_cost_beats_raw_sketch_and_scales_with_d() {
        let (a1, b1) = synth::subset_pair(30_000, 100, 3);
        let p1 = CsParams::tuned_uni(b1.len(), 100);
        let c1 = run(&a1, &b1, &p1).unwrap().comm.total_bytes();
        let (a2, b2) = synth::subset_pair(30_000, 800, 3);
        let p2 = CsParams::tuned_uni(b2.len(), 800);
        let c2 = run(&a2, &b2, &p2).unwrap().comm.total_bytes();
        assert!(c1 < 4 * p1.l as usize, "compression must beat raw i32 sketch");
        assert!(c2 > c1, "cost grows with d");
        assert!(c2 < 12 * c1, "roughly linear in d (log factor slack)");
    }

    #[test]
    fn empty_difference_degenerate() {
        // A == B: d-estimate of 0 still has to work (l floors at 128).
        let (a, _) = synth::subset_pair(2_000, 0, 9);
        let params = CsParams::tuned_uni(a.len(), 1);
        let out = run(&a, &a, &params).unwrap();
        assert!(out.b_minus_a.is_empty());
        assert_eq!(out.intersection.len(), 2_000);
    }
}
