//! Unidirectional CommonSense (§3): one message, exact `A ∩ B` when `A ⊆ B`.
//!
//! 1. Alice encodes `A` into the CS sketch `M·1_A`, truncation-compresses it (Appendix C.2),
//!    and sends it — the single round of communication.
//! 2. Bob recovers `M·1_A`, forms `r = M·1_B − M·1_A = M·1_{B\A}`, and losslessly
//!    reconstructs `1_{B\A}` with the binary MP decoder (falling back to L1 pursuit /
//!    SSMP if the L2 pursuit stalls). Then `A ∩ B = B \ (B\A)`.
//!
//! This module is the *engine* layer: explicit [`CsParams`], in-memory only. The facade
//! ([`crate::setx::Setx`]) is the front door — it estimates the difference size, runs the
//! same code over real transports, and climbs the escalation ladder on the typed
//! failures reported here. Failures carry *why*:
//! [`DecodeFailure::SketchRecovery`] (the truncation/verification layer rejected the
//! sketch) vs [`DecodeFailure::ResidueDecode`] (the MP decoder could not reach a zero
//! residue — an undersized sketch).

use crate::decoder::{run_with_fallback, DecoderCache, DecoderConfig, Side};
use crate::entropy::{compress_sketch, recover_sketch, SketchCodecParams};
use crate::metrics::{CommLog, Phase};
use crate::protocol::{wire::Msg, CsParams, DecodeFailure};
use crate::sketch::{EncodeConfig, Sketch};

/// Engine-level unidirectional error: either the frame itself was unusable, or the
/// decode failed with a layer-specific [`DecodeFailure`]. The facade wraps this into its
/// own [`crate::setx::SetxError`] surface (and climbs the escalation ladder on `Decode`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UniError {
    /// The message was not a (parseable) sketch frame.
    Frame(&'static str),
    /// The decode failed; the payload says which layer.
    Decode(DecodeFailure),
}

impl std::fmt::Display for UniError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UniError::Frame(what) => write!(f, "bad frame: {what}"),
            UniError::Decode(failure) => write!(f, "{}", failure.name()),
        }
    }
}

impl std::error::Error for UniError {}

/// Result of a unidirectional run.
#[derive(Clone, Debug)]
pub struct UniOutcome {
    /// Bob's recovered `B \ A` (sorted).
    pub b_minus_a: Vec<u64>,
    /// `A ∩ B` (sorted) — equal to `A` when the protocol succeeds and `A ⊆ B`.
    pub intersection: Vec<u64>,
    /// Full message accounting.
    pub comm: CommLog,
    /// Decoder fell back to L1 pursuit.
    pub used_fallback: bool,
}

/// Alice's half: produce the (framed) sketch message (serial encode, codec-off framing;
/// the facade paths use [`alice_encode_with`]).
pub fn alice_encode(a: &[u64], params: &CsParams) -> (Msg, usize) {
    alice_encode_with(a, params, EncodeConfig::serial(), None, false)
}

/// [`alice_encode`] with the encode-side knobs — `host` (a pre-resolved sketch of `a`
/// under exactly `params.matrix()`, validated here) skips the O(m·|a|) encode — the
/// host-sketch-store fast path for a serving initiator — and `enc` parallelizes it
/// otherwise — plus the negotiated `wire_codec` framing flag (run-length table framing
/// when on; byte-identical legacy framing when off).
pub fn alice_encode_with(
    a: &[u64],
    params: &CsParams,
    enc: EncodeConfig,
    host: Option<&Sketch>,
    wire_codec: bool,
) -> (Msg, usize) {
    let owned;
    let sketch = match host.filter(|sk| sk.matrix == params.matrix()) {
        Some(sk) => sk,
        None => {
            owned = Sketch::encode_par(params.matrix(), a, enc);
            &owned
        }
    };
    let codec = SketchCodecParams::derive(params.est_b_unique, params.est_a_unique, params.l, params.m);
    let sketch_msg = compress_sketch(&sketch.counts, &codec);
    let msg = Msg::Sketch { sketch: sketch_msg, codec: wire_codec };
    let size = msg.to_bytes().len();
    (msg, size)
}

/// Bob's half: decode `B \ A` from the received sketch message. The error pins down the
/// failing layer: sketch recovery/verification vs residue decode.
pub fn bob_decode(msg: &Msg, b: &[u64], params: &CsParams) -> Result<(Vec<u64>, bool), UniError> {
    bob_decode_cached(msg, b, params, &mut DecoderCache::new())
}

/// [`bob_decode`] consulting (and refilling) a [`DecoderCache`]: when the cache holds a
/// decoder for the same (matrix, candidate set) the dominant CSR construction is skipped
/// via `reset_signal`. The decoder is parked back in the cache on every decode outcome —
/// including a failed residue decode, where the following escalation-ladder attempt may
/// keep the matrix.
pub fn bob_decode_cached(
    msg: &Msg,
    b: &[u64],
    params: &CsParams,
    cache: &mut DecoderCache,
) -> Result<(Vec<u64>, bool), UniError> {
    bob_decode_with(msg, b, params, cache, None, EncodeConfig::serial())
}

/// [`bob_decode_cached`] with the encode-side knobs: `host` (a pre-resolved sketch of
/// `b` under exactly `params.matrix()`, validated here) skips Bob's own O(m·|b|)
/// self-encode — the server host-sketch-store fast path — and `enc` parallelizes the
/// encode otherwise.
pub fn bob_decode_with(
    msg: &Msg,
    b: &[u64],
    params: &CsParams,
    cache: &mut DecoderCache,
    host: Option<&Sketch>,
    enc: EncodeConfig,
) -> Result<(Vec<u64>, bool), UniError> {
    let Msg::Sketch { sketch: sketch_msg, .. } = msg else {
        return Err(UniError::Frame("expected sketch frame"));
    };
    let matrix = params.matrix();
    let owned;
    let my_sketch = match host.filter(|sk| sk.matrix == matrix) {
        Some(sk) => sk,
        None => {
            owned = Sketch::encode_par(matrix, b, enc);
            &owned
        }
    };
    if sketch_msg.n != my_sketch.counts.len() {
        // Mis-negotiated geometry: `recover_sketch` asserts on a length mismatch; refuse
        // here so callers get a typed error instead of a panic.
        return Err(UniError::Decode(DecodeFailure::SketchRecovery));
    }
    let codec = SketchCodecParams::derive(params.est_b_unique, params.est_a_unique, params.l, params.m);
    let Some((x_hat, _repaired, _unresolved)) =
        recover_sketch(sketch_msg, &my_sketch.counts, &codec)
    else {
        // The truncation/BCH layer could not reconcile the sketch with our counts — the
        // verification-mismatch failure shape.
        return Err(UniError::Decode(DecodeFailure::SketchRecovery));
    };
    // r = M·1_B − M̂·1_A, canonical orientation (Bob-positive).
    let residue: Vec<i32> = my_sketch
        .counts
        .iter()
        .zip(&x_hat)
        .map(|(y, x)| y - x)
        .collect();

    let mut dec = cache.checkout(&matrix, b, Side::Positive, DecoderConfig::commonsense());
    dec.load_residue(&residue);
    // §3.4: fall back to the RIP-1-safe L1 pursuit (SSMP) when vanilla MP stalls — the
    // same escalation ladder the ping-pong session engine uses (without its kicks: a
    // one-shot decode has no later rounds to absorb a wrong kick).
    let (stats, used_fallback) = run_with_fallback(&mut dec, true, 0);
    if !stats.converged {
        cache.store(dec);
        // The sketch verified but the residue would not peel to zero — the
        // undecodable-residue failure shape (undersized `l` for the true difference).
        return Err(UniError::Decode(DecodeFailure::ResidueDecode));
    }
    let mut b_minus_a = dec.estimate();
    b_minus_a.sort_unstable();
    cache.store(dec);
    Ok((b_minus_a, used_fallback))
}

/// End-to-end in-memory run with exact byte accounting (codec-off framing, so the cost
/// is directly comparable to the pre-codec wire format; [`run_with_codec`] is the
/// ablation knob).
pub fn run(a: &[u64], b: &[u64], params: &CsParams) -> Result<UniOutcome, UniError> {
    run_with_codec(a, b, params, false)
}

/// [`run`] with the columnar wire codec on or off — the fig2a codec-ablation entry
/// point. The comm log charges the frame's encoded bytes and its codec-off equivalent.
pub fn run_with_codec(
    a: &[u64],
    b: &[u64],
    params: &CsParams,
    codec: bool,
) -> Result<UniOutcome, UniError> {
    let mut comm = CommLog::new();
    let (msg, size) = alice_encode_with(a, params, EncodeConfig::serial(), None, codec);
    comm.record_framed(true, Phase::Sketch, size, msg.raw_wire_len());
    // Serialize/deserialize through the real wire format (what TCP would carry).
    let bytes = msg.to_bytes();
    let (received, _) =
        Msg::from_bytes(&bytes).ok_or(UniError::Frame("sketch self-roundtrip"))?;
    let (b_minus_a, used_fallback) = bob_decode(&received, b, params)?;
    let exclude: std::collections::HashSet<u64> = b_minus_a.iter().copied().collect();
    let mut intersection: Vec<u64> = b.iter().copied().filter(|x| !exclude.contains(x)).collect();
    intersection.sort_unstable();
    Ok(UniOutcome { b_minus_a, intersection, comm, used_fallback })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn exact_intersection_small() {
        let (a, b) = synth::subset_pair(5_000, 50, 1);
        let params = CsParams::tuned_uni(b.len(), 50);
        let out = run(&a, &b, &params).unwrap();
        let mut want = a.clone();
        want.sort_unstable();
        assert_eq!(out.intersection, want);
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert_eq!(out.comm.rounds(), 1, "unidirectional = one message");
    }

    #[test]
    fn exact_intersection_many_seeds() {
        for seed in 0..10 {
            let (a, b) = synth::subset_pair(20_000, 200, seed);
            let params = CsParams::tuned_uni(b.len(), 200);
            let out = run(&a, &b, &params).unwrap();
            assert_eq!(out.b_minus_a, synth::difference(&b, &a), "seed {seed}");
        }
    }

    #[test]
    fn codec_framing_roundtrips_with_exact_raw_accounting() {
        // The one-shot protocol has little columnar structure to exploit (the rANS
        // table is already near-entropy), so the codec guarantee here is the adaptive
        // floor: same answer, raw accounting equal to the measured codec-off wire, and
        // at worst the mode byte of overhead.
        let (a, b) = synth::subset_pair(5_000, 50, 7);
        let params = CsParams::tuned_uni(b.len(), 50);
        let off = run_with_codec(&a, &b, &params, false).unwrap();
        let on = run_with_codec(&a, &b, &params, true).unwrap();
        assert_eq!(on.b_minus_a, off.b_minus_a);
        assert_eq!(off.comm.total_raw_bytes(), off.comm.total_bytes());
        assert_eq!(on.comm.total_raw_bytes(), off.comm.total_bytes());
        assert!(
            on.comm.total_bytes() <= off.comm.total_bytes() + 2,
            "codec on {} vs off {}",
            on.comm.total_bytes(),
            off.comm.total_bytes()
        );
    }

    #[test]
    fn comm_cost_beats_raw_sketch_and_scales_with_d() {
        let (a1, b1) = synth::subset_pair(30_000, 100, 3);
        let p1 = CsParams::tuned_uni(b1.len(), 100);
        let c1 = run(&a1, &b1, &p1).unwrap().comm.total_bytes();
        let (a2, b2) = synth::subset_pair(30_000, 800, 3);
        let p2 = CsParams::tuned_uni(b2.len(), 800);
        let c2 = run(&a2, &b2, &p2).unwrap().comm.total_bytes();
        assert!(c1 < 4 * p1.l as usize, "compression must beat raw i32 sketch");
        assert!(c2 > c1, "cost grows with d");
        assert!(c2 < 12 * c1, "roughly linear in d (log factor slack)");
    }

    #[test]
    fn empty_difference_degenerate() {
        // A == B: d-estimate of 0 still has to work (l floors at 128).
        let (a, _) = synth::subset_pair(2_000, 0, 9);
        let params = CsParams::tuned_uni(a.len(), 1);
        let out = run(&a, &a, &params).unwrap();
        assert!(out.b_minus_a.is_empty());
        assert_eq!(out.intersection.len(), 2_000);
    }

    #[test]
    fn undersized_sketch_fails_as_residue_decode() {
        // Starve l far below the calibrated minimum for the true d: the sketch layer
        // still reconciles, but MP cannot peel the residue — the undecodable-residue
        // failure shape, carrying *why* instead of a bare None.
        let (a, b) = synth::subset_pair(20_000, 500, 4);
        let mut params = CsParams::tuned_uni(b.len(), 500);
        params.l = 160;
        match run(&a, &b, &params) {
            Err(UniError::Decode(failure)) => {
                assert!(
                    matches!(
                        failure,
                        DecodeFailure::ResidueDecode | DecodeFailure::SketchRecovery
                    ),
                    "unexpected failure shape {failure:?}"
                );
            }
            Ok(out) => panic!("l=160 for d=500 must not decode ({} found)", out.b_minus_a.len()),
            Err(e) => panic!("wrong error type: {e}"),
        }
    }

    #[test]
    fn corrupted_sketch_fails_as_sketch_recovery() {
        // Flip payload bytes in the framed sketch: the truncation/verification layer
        // must reject it (verification mismatch), not hand garbage to the decoder.
        let (a, b) = synth::subset_pair(10_000, 100, 5);
        let params = CsParams::tuned_uni(b.len(), 100);
        let (msg, _) = alice_encode(&a, &params);
        let Msg::Sketch { sketch: mut sk, .. } = msg else { panic!("alice encodes a sketch") };
        for byte in sk.payload.iter_mut().take(24) {
            *byte ^= 0xa5;
        }
        let corrupt = Msg::Sketch { sketch: sk, codec: false };
        match bob_decode(&corrupt, &b, &params) {
            // Either the truncation/verification layer rejects the payload outright, or
            // it slips through as garbage and the residue decode fails — both must be
            // typed `Decode` errors, never a panic or a silent wrong answer.
            Err(UniError::Decode(_)) => {}
            Ok((got, _)) => {
                assert_eq!(got, synth::difference(&b, &a), "wrong answer accepted");
            }
            Err(e) => panic!("wrong error type: {e}"),
        }
        // A geometry mismatch (wrong l) is also a sketch-recovery failure, not a panic.
        let (msg2, _) = alice_encode(&a, &params);
        let mut wrong = params;
        wrong.l += 64;
        match bob_decode(&msg2, &b, &wrong) {
            Err(UniError::Decode(failure)) => {
                assert_eq!(failure, DecodeFailure::SketchRecovery);
            }
            other => panic!("geometry mismatch must be SketchRecovery, got {other:?}"),
        }
    }
}
