//! Wire format — hand-rolled, dependency-free, byte-exact.
//!
//! Frame layout: `type:u8 | body_len:varint | body`. Every field that crosses the wire is
//! serialized here so the experiment harnesses charge real sizes. (The image's crate set
//! has no serde; this module doubles as the protocol's stable interchange format for the
//! TCP coordinator.)

use crate::entropy::{get_varint, put_varint, SketchMsg};

/// A protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Session handshake: CS parameters + role metadata.
    Hello {
        l: u32,
        m: u32,
        seed: u64,
        universe_bits: u32,
        est_initiator_unique: u64,
        est_responder_unique: u64,
        set_len: u64,
    },
    /// The initiator's compressed, truncation-coded sketch (message 1).
    Sketch(SketchMsg),
    /// One ping-pong round (§5.1–5.2).
    Round {
        /// Entropy-compressed canonical residue.
        residue: Vec<u8>,
        /// Serialized Bloom filter of the sender's current estimate set (absent on the
        /// final confirmation).
        smf: Option<Vec<u8>>,
        /// "Last inquiry": signatures of tentatively-updated SMF-positive coordinates.
        inquiry: Vec<u64>,
        /// Answers to the peer's previous inquiry (true = conflict, i.e. the peer's
        /// tentative element is in our estimate — a common hallucination).
        answers: Vec<bool>,
        /// Sender believes the session is complete (residue zero, nothing outstanding).
        done: bool,
    },
}

const TYPE_HELLO: u8 = 1;
const TYPE_SKETCH: u8 = 2;
const TYPE_ROUND: u8 = 3;

impl Msg {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::new();
        let ty = match self {
            Msg::Hello {
                l,
                m,
                seed,
                universe_bits,
                est_initiator_unique,
                est_responder_unique,
                set_len,
            } => {
                put_varint(&mut body, *l as u64);
                put_varint(&mut body, *m as u64);
                body.extend_from_slice(&seed.to_le_bytes());
                put_varint(&mut body, *universe_bits as u64);
                put_varint(&mut body, *est_initiator_unique);
                put_varint(&mut body, *est_responder_unique);
                put_varint(&mut body, *set_len);
                TYPE_HELLO
            }
            Msg::Sketch(sk) => {
                body = sk.to_bytes();
                TYPE_SKETCH
            }
            Msg::Round { residue, smf, inquiry, answers, done } => {
                put_varint(&mut body, residue.len() as u64);
                body.extend_from_slice(residue);
                match smf {
                    Some(bytes) => {
                        body.push(1);
                        put_varint(&mut body, bytes.len() as u64);
                        body.extend_from_slice(bytes);
                    }
                    None => body.push(0),
                }
                put_varint(&mut body, inquiry.len() as u64);
                for sig in inquiry {
                    body.extend_from_slice(&sig.to_le_bytes());
                }
                put_varint(&mut body, answers.len() as u64);
                // Bit-packed answers.
                let mut packed = vec![0u8; answers.len().div_ceil(8)];
                for (i, &a) in answers.iter().enumerate() {
                    if a {
                        packed[i / 8] |= 1 << (i % 8);
                    }
                }
                body.extend_from_slice(&packed);
                body.push(*done as u8);
                TYPE_ROUND
            }
        };
        let mut out = Vec::with_capacity(body.len() + 6);
        out.push(ty);
        put_varint(&mut out, body.len() as u64);
        out.extend_from_slice(&body);
        out
    }

    /// Parse one frame; returns `(msg, bytes_consumed)`.
    pub fn from_bytes(data: &[u8]) -> Option<(Msg, usize)> {
        let ty = *data.first()?;
        let (body_len, used) = get_varint(&data[1..])?;
        let start = 1 + used;
        let body = data.get(start..start + body_len as usize)?;
        let total = start + body_len as usize;
        let msg = match ty {
            TYPE_HELLO => {
                let mut off = 0usize;
                let (l, u) = get_varint(&body[off..])?;
                off += u;
                let (m, u) = get_varint(&body[off..])?;
                off += u;
                let seed = u64::from_le_bytes(body.get(off..off + 8)?.try_into().ok()?);
                off += 8;
                let (ub, u) = get_varint(&body[off..])?;
                off += u;
                let (ei, u) = get_varint(&body[off..])?;
                off += u;
                let (er, u) = get_varint(&body[off..])?;
                off += u;
                let (sl, _) = get_varint(&body[off..])?;
                Msg::Hello {
                    l: l as u32,
                    m: m as u32,
                    seed,
                    universe_bits: ub as u32,
                    est_initiator_unique: ei,
                    est_responder_unique: er,
                    set_len: sl,
                }
            }
            TYPE_SKETCH => Msg::Sketch(SketchMsg::from_bytes(body)?),
            TYPE_ROUND => {
                let mut off = 0usize;
                let (rl, u) = get_varint(&body[off..])?;
                off += u;
                let residue = body.get(off..off + rl as usize)?.to_vec();
                off += rl as usize;
                let has_smf = *body.get(off)? == 1;
                off += 1;
                let smf = if has_smf {
                    let (sl, u) = get_varint(&body[off..])?;
                    off += u;
                    let bytes = body.get(off..off + sl as usize)?.to_vec();
                    off += sl as usize;
                    Some(bytes)
                } else {
                    None
                };
                let (nq, u) = get_varint(&body[off..])?;
                off += u;
                let mut inquiry = Vec::with_capacity(nq as usize);
                for _ in 0..nq {
                    inquiry.push(u64::from_le_bytes(body.get(off..off + 8)?.try_into().ok()?));
                    off += 8;
                }
                let (na, u) = get_varint(&body[off..])?;
                off += u;
                let packed = body.get(off..off + (na as usize).div_ceil(8))?;
                off += (na as usize).div_ceil(8);
                let answers = (0..na as usize)
                    .map(|i| packed[i / 8] >> (i % 8) & 1 == 1)
                    .collect();
                let done = *body.get(off)? == 1;
                Msg::Round { residue, smf, inquiry, answers, done }
            }
            _ => return None,
        };
        Some((msg, total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::compress_residue;

    #[test]
    fn hello_roundtrip() {
        let msg = Msg::Hello {
            l: 1234,
            m: 7,
            seed: 0xdead_beef,
            universe_bits: 256,
            est_initiator_unique: 10,
            est_responder_unique: 999,
            set_len: 1_000_000,
        };
        let bytes = msg.to_bytes();
        let (back, used) = Msg::from_bytes(&bytes).unwrap();
        assert_eq!(back, msg);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn round_roundtrip_full_fields() {
        let msg = Msg::Round {
            residue: compress_residue(&[0, 1, -1, 0, 2]),
            smf: Some(vec![1, 2, 3, 4, 5]),
            inquiry: vec![0xAAAA, 0xBBBB],
            answers: vec![true, false, true, true, false, false, false, true, true],
            done: false,
        };
        let bytes = msg.to_bytes();
        let (back, used) = Msg::from_bytes(&bytes).unwrap();
        assert_eq!(back, msg);
        assert_eq!(used, bytes.len());
    }

    #[test]
    fn round_roundtrip_minimal() {
        let msg = Msg::Round {
            residue: vec![],
            smf: None,
            inquiry: vec![],
            answers: vec![],
            done: true,
        };
        let bytes = msg.to_bytes();
        let (back, _) = Msg::from_bytes(&bytes).unwrap();
        assert_eq!(back, msg);
    }

    #[test]
    fn truncated_frames_rejected() {
        let msg = Msg::Round {
            residue: vec![9; 40],
            smf: Some(vec![7; 10]),
            inquiry: vec![1],
            answers: vec![true],
            done: false,
        };
        let bytes = msg.to_bytes();
        for cut in [0usize, 1, 5, bytes.len() - 1] {
            assert!(Msg::from_bytes(&bytes[..cut]).is_none(), "cut {cut}");
        }
    }

    #[test]
    fn frames_concatenate() {
        let m1 = Msg::Round { residue: vec![1], smf: None, inquiry: vec![], answers: vec![], done: false };
        let m2 = Msg::Round { residue: vec![2, 3], smf: None, inquiry: vec![], answers: vec![], done: true };
        let mut stream = m1.to_bytes();
        stream.extend(m2.to_bytes());
        let (b1, used1) = Msg::from_bytes(&stream).unwrap();
        let (b2, used2) = Msg::from_bytes(&stream[used1..]).unwrap();
        assert_eq!(b1, m1);
        assert_eq!(b2, m2);
        assert_eq!(used1 + used2, stream.len());
    }
}
