//! Wire format — hand-rolled, dependency-free, byte-exact.
//!
//! Frame layout: `type:u8 | body_len:varint | body`. Every field that crosses the wire is
//! serialized here so the experiment harnesses charge real sizes. (The image's crate set
//! has no serde; this module doubles as the protocol's stable interchange format for the
//! TCP coordinator.)
//!
//! Repeated values inside a body — id sequences, count vectors, bitmaps — are encoded
//! through the columnar codecs in [`crate::wire::column`]. Each payload frame exists in
//! two forms selected by the negotiated `codec` flag (see the module docs there): the
//! codec-off form is byte-identical to the PR 7 wire format and uses the original type
//! bytes, the codec-on form uses a dedicated type byte (`TYPE_*_C`) with columnar field
//! encodings. [`Msg::raw_wire_len`] reports the codec-off-equivalent size of any frame,
//! which is how [`crate::metrics::CommLog`] measures the compression ratio on real
//! traffic instead of estimating it.

use crate::entropy::{get_varint, put_varint, take, take_varint, SketchMsg};
use crate::wire::column::{BoolRleCol, Column, DeltaU64Col, Fixed64Col, RleU64Col};

/// Hard cap on a frame body's advertised length. Adversarial frames can claim up to
/// `u64::MAX` bytes; every reader — the in-memory parser here and the TCP framer in
/// [`crate::coordinator::tcp`] — must reject the claim *before* reserving memory for it.
pub const MAX_FRAME_BYTES: usize = 64 << 20;

/// Cap on the inquiry signatures / answer bits a single codec-on `Round` frame may
/// claim. The legacy form is naturally bounded (8 body bytes per signature); a columnar
/// run can decode far more elements than it has payload bytes, so the codec arms need an
/// explicit ceiling. Real inquiry lists are at most a few × d.
const MAX_ROUND_ITEMS: usize = 1 << 20;

/// Cap on sketch-table coordinates in a codec-on sketch body (parity with the
/// `MAX_COORDS` guard inside [`SketchMsg::from_bytes`]).
const MAX_TABLE_COORDS: usize = 1 << 24;

/// A protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Pre-session estimator handshake (the `Setx` facade's opening frame, both
    /// directions): a fingerprint of the declarative config (both endpoints must agree),
    /// the sender's set cardinality, and — when the diff size is to be *estimated* rather
    /// than caller-supplied — serialized Strata + MinHash estimators (§7.1's "handily
    /// estimated … by sending a few hundred bytes during a handshake step").
    EstHello {
        /// Hash of the sender's `SetxConfig`; a mismatch aborts before any protocol work.
        config_fingerprint: u64,
        /// `|set|` of the sender (role election + d̂ splitting).
        set_len: u64,
        /// Caller-supplied `d = |AΔB|` (present iff the config says `DiffSize::Explicit`).
        explicit_d: Option<u64>,
        /// Serialized [`crate::protocol::estimate::StrataEstimator`] (iff `Estimated`).
        /// Columnar form iff `codec` is set — the flag tells the receiver how to parse.
        strata: Option<Vec<u8>>,
        /// Serialized [`crate::protocol::estimate::MinHashEstimator`] (iff `Estimated`).
        minhash: Option<Vec<u8>>,
        /// Tenant namespace the sender wants to reconcile against. Versioned encoding:
        /// the field is on the wire (flags bit 3 + trailing varint) iff non-zero, so a
        /// PR-5-era frame without it parses as tenant 0 and a tenant-0 frame is
        /// byte-identical to the old format — old clients and old servers interop.
        namespace: u32,
        /// Multi-party join: `(party_id, party_count)`. Rides the same trailing-varint
        /// versioning pattern as `namespace` (flags bit 4 + two varints after the
        /// namespace one): absent for every two-party frame, so PR-6-era frames stay
        /// byte-identical. Parse enforces `party_count ≥ 2 && party_id < party_count`;
        /// id 0 is the coordinator.
        party: Option<(u32, u32)>,
        /// Sender supports (and, for its own estimator blobs, is using) the columnar
        /// wire codec. Flags bit 5 — the same versioned pattern as `namespace`/`party`:
        /// the bit is zero on every pre-codec frame, so PR-7-era frames parse as
        /// `codec: false` and a codec-off frame stays byte-identical. The session runs
        /// codec-on iff **both** hellos carry the bit.
        codec: bool,
    },
    /// Session handshake: CS parameters + role metadata.
    Hello {
        l: u32,
        m: u32,
        seed: u64,
        universe_bits: u32,
        est_initiator_unique: u64,
        est_responder_unique: u64,
        set_len: u64,
        /// Tenant namespace (same versioned encoding as [`Msg::EstHello`]: a trailing
        /// varint present iff non-zero; absent means tenant 0).
        namespace: u32,
    },
    /// The initiator's compressed, truncation-coded sketch (message 1).
    Sketch {
        sketch: SketchMsg,
        /// Columnar codec negotiated for this session. Not a body field: codec-on
        /// frames use the dedicated `TYPE_SKETCH_C` type byte (run-length table
        /// column), codec-off frames are byte-identical to PR 7.
        codec: bool,
    },
    /// One ping-pong round (§5.1–5.2).
    Round {
        /// Entropy-compressed canonical residue (already rANS-coded — identical bytes
        /// in both codec modes).
        residue: Vec<u8>,
        /// Serialized Bloom filter of the sender's current estimate set (absent on the
        /// final confirmation). Codec-on rounds carry the boolean-RLE form produced by
        /// [`crate::smf::BloomFilter::to_codec_bytes`]; codec-off rounds the flat form.
        smf: Option<Vec<u8>>,
        /// "Last inquiry": signatures of tentatively-updated SMF-positive coordinates.
        inquiry: Vec<u64>,
        /// Answers to the peer's previous inquiry (true = conflict, i.e. the peer's
        /// tentative element is in our estimate — a common hallucination).
        answers: Vec<bool>,
        /// Sender believes the session is complete (residue zero, nothing outstanding).
        done: bool,
        /// Columnar codec negotiated: delta+varint inquiry column and boolean-RLE
        /// answers under `TYPE_ROUND_C`; raw words + bitpacked bytes under the PR-7
        /// `TYPE_ROUND` layout otherwise.
        codec: bool,
    },
    /// End-of-attempt verdict (the `Setx` facade). Both endpoints exchange one `Confirm`
    /// per attempt; a failed attempt (`ok = false`) triggers the l-escalation ladder —
    /// the initiator re-opens with a larger sketch *on the same connection* — instead of
    /// an opaque teardown. Carries no id list (only this verdict triple), so it is the
    /// one payload frame with nothing to run through the columnar codecs: both codec
    /// modes serialize it identically.
    Confirm {
        /// The sender's attempt succeeded (decode exact / session settled).
        ok: bool,
        /// Why the attempt failed (one of the `REASON_*` constants; `REASON_OK` iff `ok`).
        reason: u8,
        /// 0-based index of the attempt being confirmed (both sides must agree).
        attempt: u32,
    },
    /// Admission-control rejection: a [`crate::server::SetxServer`] at its
    /// `max_inflight_sessions` cap answers a new connection with this frame and closes,
    /// instead of letting the client hang on a never-served handshake (or see a bare
    /// connection reset). The client surfaces it as
    /// [`crate::setx::SetxError::ServerBusy`].
    Busy {
        /// Server's back-off hint in milliseconds (0 = no hint; clients should add their
        /// own jitter either way).
        retry_after_ms: u32,
        /// Tenant namespace whose admission quota rejected the session (0 = the global
        /// cap / the default tenant). Same versioned trailing-varint encoding as
        /// [`Msg::Hello`], so PR-5-era peers interop.
        namespace: u32,
    },
    /// Multi-party round barrier (coordinator → each spoke): announces the aggregate
    /// sketch `Σᵢ sk(Sᵢ)` formed from `parties` collected sketches, and tells the spoke
    /// whether its own sketch matched the coordinator's (in which case the inner repair
    /// session is skipped). The aggregate counts ride along when they fit the frame cap —
    /// a digest-only frame is valid too (the counts are telemetry / cross-check; sync
    /// decisions rest on per-party residues, which a sum cannot certify: two honest
    /// parties off by `+x` and `−x` cancel).
    AggSketch {
        /// Number of party sketches folded into the aggregate (coordinator included).
        parties: u32,
        /// Shared collect-phase sketch length.
        l: u32,
        /// Shared collect-phase row weight.
        m: u32,
        /// Shared collect-phase matrix seed.
        seed: u64,
        /// Sequential hash fold over the aggregate counts (cross-check only).
        digest: u64,
        /// What the receiving spoke should do next: one of the `DIRECTIVE_*` constants.
        directive: u8,
        /// The aggregate counts themselves, present iff they fit the frame budget
        /// (zigzag varints codec-off; a zigzag run-length column under
        /// `TYPE_AGG_SKETCH_C`). When present, the count **must** equal `l` — a
        /// mismatched length is a malformed frame, not a short read.
        counts: Option<Vec<i32>>,
        /// Columnar codec negotiated with the receiving spoke.
        codec: bool,
    },
    /// Multi-party exact-membership round (coordinator → one spoke): a compressed sketch
    /// of the coordinator's current intersection estimate, decoded by the spoke against
    /// its pairwise-common candidates `Kᵢ = C ∩ Sᵢ` to learn exactly which candidates
    /// dropped out of the N-way intersection. Carries its own geometry because each
    /// spoke's membership ladder escalates independently.
    MultiResidue {
        /// Receiving spoke's party id.
        party: u32,
        /// 0-based rung of this spoke's membership-escalation ladder.
        attempt: u32,
        l: u32,
        m: u32,
        seed: u64,
        universe_bits: u32,
        /// Exact `|Kᵢ ∖ ∩|` — the spoke derives the shared codec from it.
        est_drop: u64,
        /// The truncation-coded sketch of the intersection estimate.
        sketch: SketchMsg,
        /// Columnar codec negotiated with the receiving spoke (same embedded-sketch
        /// column reuse as [`Msg::Sketch`], under `TYPE_MULTI_RESIDUE_C`).
        codec: bool,
    },
}

/// `AggSketch::directive`: the spoke's collect sketch matched the coordinator's set —
/// skip the inner repair session and wait for the membership round.
pub const DIRECTIVE_IN_SYNC: u8 = 0;
/// `AggSketch::directive`: differences detected — run the inner two-party session.
pub const DIRECTIVE_SESSION: u8 = 1;

/// `Confirm::reason` values.
pub const REASON_OK: u8 = 0;
/// The truncated sketch failed recovery / verification against the receiver's counts.
pub const REASON_SKETCH_RECOVERY: u8 = 1;
/// The MP decoder could not drive the residue to zero (one-shot unidirectional decode).
pub const REASON_RESIDUE_DECODE: u8 = 2;
/// The bidirectional ping-pong exhausted its round budget without settling.
pub const REASON_NOT_CONVERGED: u8 = 3;

const TYPE_HELLO: u8 = 1;
const TYPE_SKETCH: u8 = 2;
const TYPE_ROUND: u8 = 3;
const TYPE_EST_HELLO: u8 = 4;
const TYPE_CONFIRM: u8 = 5;
const TYPE_BUSY: u8 = 6;
const TYPE_AGG_SKETCH: u8 = 7;
const TYPE_MULTI_RESIDUE: u8 = 8;
// Codec-on forms of the payload frames. A dedicated type byte (rather than a body flag)
// keeps `from_bytes` context-free and the codec-off byte streams untouched: a PR-7
// binary that sees type 9–12 rejects the frame outright instead of misparsing it.
const TYPE_SKETCH_C: u8 = 9;
const TYPE_ROUND_C: u8 = 10;
const TYPE_AGG_SKETCH_C: u8 = 11;
const TYPE_MULTI_RESIDUE_C: u8 = 12;

/// Encoded length of a LEB128 varint.
fn varint_len(v: u64) -> usize {
    ((64 - v.leading_zeros()).max(1) as usize).div_ceil(7)
}

/// Wire cost of the versioned trailing `namespace` field: zero bytes for tenant 0 (the
/// field is simply absent, keeping tenant-0 frames byte-identical to the PR-5 format).
fn opt_namespace_len(ns: u32) -> usize {
    if ns == 0 {
        0
    } else {
        varint_len(ns as u64)
    }
}

/// Parse a *present* trailing `namespace` varint. Canonical-form hardening: tenant 0 is
/// encoded by omission, so a frame that carries the field with value 0 is malformed —
/// accepting it would make two byte strings decode to the same message and break the
/// `wire_len == to_bytes().len()` accounting invariant.
fn parse_namespace(body: &[u8], off: &mut usize) -> Option<u32> {
    let ns = u32::try_from(take_varint(body, off)?).ok()?;
    if ns == 0 {
        return None;
    }
    Some(ns)
}

/// Zigzag-map a signed count onto the varint-friendly non-negative range
/// (`0, -1, 1, -2, … → 0, 1, 2, 3, …`). Sketch counts in an aggregate are small and
/// centered near zero, so this keeps most of them to one byte each.
fn zigzag(v: i32) -> u64 {
    (((v as i64) << 1) ^ ((v as i64) >> 63)) as u64
}

/// Inverse of [`zigzag`]; rejects values outside `i32` (an adversarial varint can
/// encode anything up to `u64::MAX`).
fn unzigzag(z: u64) -> Option<i32> {
    i32::try_from(((z >> 1) as i64) ^ -((z & 1) as i64)).ok()
}

/// Serialized size of an embedded [`SketchMsg`] (mirrors `SketchMsg::to_bytes`).
fn sketch_msg_len(sk: &SketchMsg) -> usize {
    varint_len(sk.n as u64)
        + varint_len(sk.table.len() as u64)
        + sk.table.len()
        + varint_len(sk.payload.len() as u64)
        + sk.payload.len()
        + varint_len(sk.syndromes.len() as u64)
        + sk.syndromes.len()
}

/// Total frame size around a body of `body` bytes.
fn frame_len(body: usize) -> usize {
    1 + varint_len(body as u64) + body
}

/// The rANS table of a sketch widened to the column item type. The table is a dense
/// per-symbol byte vector that collapses hard under run-length framing whenever the
/// truncation alphabet is narrow.
fn table_words(sk: &SketchMsg) -> Vec<u64> {
    sk.table.iter().map(|&b| b as u64).collect()
}

/// Zigzagged aggregate counts as column items.
fn counts_words(c: &[i32]) -> Vec<u64> {
    c.iter().map(|&v| zigzag(v)).collect()
}

/// Codec-on serialized size of an embedded [`SketchMsg`] (mirrors
/// [`put_sketch_msg_codec`]).
fn sketch_msg_codec_len(sk: &SketchMsg) -> usize {
    varint_len(sk.n as u64)
        + RleU64Col::encoded_len(&table_words(sk))
        + varint_len(sk.payload.len() as u64)
        + sk.payload.len()
        + varint_len(sk.syndromes.len() as u64)
        + sk.syndromes.len()
}

/// Codec-on form of an embedded sketch: same field order as `SketchMsg::to_bytes`, but
/// the table rides a run-length column (the rANS payload and BCH syndromes are already
/// entropy-coded — recoding them buys nothing, so their bytes pass through unchanged,
/// exactly like the rANS residue blob in `Round`).
fn put_sketch_msg_codec(body: &mut Vec<u8>, sk: &SketchMsg) {
    put_varint(body, sk.n as u64);
    RleU64Col::encode(&table_words(sk), body);
    put_varint(body, sk.payload.len() as u64);
    body.extend_from_slice(&sk.payload);
    put_varint(body, sk.syndromes.len() as u64);
    body.extend_from_slice(&sk.syndromes);
}

/// Parse a codec-on embedded sketch (no trailing-byte check — the caller owns the
/// enclosing extent). Mirrors the validation of `SketchMsg::from_bytes`: coordinate
/// count capped, every length checked before the bytes are taken, and table entries
/// must fit the `u8` symbol alphabet.
fn take_sketch_msg_codec(body: &[u8], off: &mut usize) -> Option<SketchMsg> {
    let n = usize::try_from(take_varint(body, off)?).ok()?;
    if n > MAX_TABLE_COORDS {
        return None;
    }
    let words = RleU64Col::decode(body, off, MAX_TABLE_COORDS)?;
    let mut table = Vec::with_capacity(words.len());
    for w in words {
        table.push(u8::try_from(w).ok()?);
    }
    let pl = usize::try_from(take_varint(body, off)?).ok()?;
    let payload = take(body, off, pl)?.to_vec();
    let sl = usize::try_from(take_varint(body, off)?).ok()?;
    let syndromes = take(body, off, sl)?.to_vec();
    Some(SketchMsg { n, table, payload, syndromes })
}

/// Legacy wire cost of aggregate counts (varint count + zigzag varints).
fn agg_counts_legacy_len(c: &[i32]) -> usize {
    varint_len(c.len() as u64) + c.iter().map(|&v| varint_len(zigzag(v))).sum::<usize>()
}

impl Msg {
    /// Exact wire size of this frame — equals `self.to_bytes().len()` without building
    /// the buffer. The session engine charges every frame through this; on the per-round
    /// hot path the computation allocates nothing (column `encoded_len`s iterate in
    /// place — only the once-per-attempt sketch/aggregate frames widen their tables to
    /// column items first).
    pub fn wire_len(&self) -> usize {
        let body = match self {
            Msg::EstHello { set_len, explicit_d, strata, minhash, namespace, party, .. } => {
                8 + varint_len(*set_len)
                    + 1
                    + explicit_d.map_or(0, |d| varint_len(d))
                    + strata.as_ref().map_or(0, |b| varint_len(b.len() as u64) + b.len())
                    + minhash.as_ref().map_or(0, |b| varint_len(b.len() as u64) + b.len())
                    + opt_namespace_len(*namespace)
                    + party
                        .map_or(0, |(id, count)| varint_len(id as u64) + varint_len(count as u64))
            }
            Msg::Confirm { attempt, .. } => 2 + varint_len(*attempt as u64),
            Msg::Busy { retry_after_ms, namespace } => {
                varint_len(*retry_after_ms as u64) + opt_namespace_len(*namespace)
            }
            Msg::Hello {
                l,
                m,
                universe_bits,
                est_initiator_unique,
                est_responder_unique,
                set_len,
                namespace,
                ..
            } => {
                varint_len(*l as u64)
                    + varint_len(*m as u64)
                    + 8
                    + varint_len(*universe_bits as u64)
                    + varint_len(*est_initiator_unique)
                    + varint_len(*est_responder_unique)
                    + varint_len(*set_len)
                    + opt_namespace_len(*namespace)
            }
            Msg::Sketch { sketch, codec } => {
                if *codec {
                    sketch_msg_codec_len(sketch)
                } else {
                    sketch_msg_len(sketch)
                }
            }
            Msg::AggSketch {
                parties, l, m, digest: _, seed: _, directive: _, counts, codec,
            } => {
                varint_len(*parties as u64)
                    + varint_len(*l as u64)
                    + varint_len(*m as u64)
                    + 8
                    + 8
                    + 1
                    + 1
                    + counts.as_ref().map_or(0, |c| {
                        if *codec {
                            RleU64Col::encoded_len(&counts_words(c))
                        } else {
                            agg_counts_legacy_len(c)
                        }
                    })
            }
            Msg::MultiResidue {
                party,
                attempt,
                l,
                m,
                seed: _,
                universe_bits,
                est_drop,
                sketch,
                codec,
            } => {
                varint_len(*party as u64)
                    + varint_len(*attempt as u64)
                    + varint_len(*l as u64)
                    + varint_len(*m as u64)
                    + 8
                    + varint_len(*universe_bits as u64)
                    + varint_len(*est_drop)
                    + {
                        let sk = if *codec {
                            sketch_msg_codec_len(sketch)
                        } else {
                            sketch_msg_len(sketch)
                        };
                        varint_len(sk as u64) + sk
                    }
            }
            Msg::Round { residue, smf, inquiry, answers, codec, .. } => {
                varint_len(residue.len() as u64)
                    + residue.len()
                    + 1
                    + smf.as_ref().map_or(0, |b| varint_len(b.len() as u64) + b.len())
                    + if *codec {
                        DeltaU64Col::encoded_len(inquiry) + BoolRleCol::encoded_len(answers)
                    } else {
                        Fixed64Col::encoded_len(inquiry)
                            + varint_len(answers.len() as u64)
                            + answers.len().div_ceil(8)
                    }
                    + 1
            }
        };
        frame_len(body)
    }

    /// Codec-off-equivalent wire size of this frame: what the same message would have
    /// cost on the PR 7 wire format. Equals [`Msg::wire_len`] for every codec-off frame;
    /// for codec-on frames it recomputes the legacy field framing (including the flat
    /// size of a boolean-RLE SMF blob and the per-cell legacy cost of a columnar strata
    /// blob). [`crate::metrics::CommLog`] charges both numbers per frame, which is where
    /// the end-to-end compression ratio comes from.
    pub fn raw_wire_len(&self) -> usize {
        match self {
            Msg::Sketch { sketch, codec: true } => frame_len(sketch_msg_len(sketch)),
            Msg::Round { residue, smf, inquiry, answers, codec: true, .. } => {
                let smf_cost = smf.as_ref().map_or(0, |b| {
                    let flat = crate::smf::codec_bytes_flat_len(b).unwrap_or(b.len());
                    varint_len(flat as u64) + flat
                });
                frame_len(
                    varint_len(residue.len() as u64)
                        + residue.len()
                        + 1
                        + smf_cost
                        + Fixed64Col::encoded_len(inquiry)
                        + varint_len(answers.len() as u64)
                        + answers.len().div_ceil(8)
                        + 1,
                )
            }
            Msg::AggSketch { parties, l, m, counts, codec: true, .. } => frame_len(
                varint_len(*parties as u64)
                    + varint_len(*l as u64)
                    + varint_len(*m as u64)
                    + 8
                    + 8
                    + 1
                    + 1
                    + counts.as_ref().map_or(0, |c| agg_counts_legacy_len(c)),
            ),
            Msg::MultiResidue {
                party, attempt, l, m, universe_bits, est_drop, sketch, codec: true, ..
            } => {
                let sk = sketch_msg_len(sketch);
                frame_len(
                    varint_len(*party as u64)
                        + varint_len(*attempt as u64)
                        + varint_len(*l as u64)
                        + varint_len(*m as u64)
                        + 8
                        + varint_len(*universe_bits as u64)
                        + varint_len(*est_drop)
                        + varint_len(sk as u64)
                        + sk,
                )
            }
            Msg::EstHello {
                set_len,
                explicit_d,
                strata,
                minhash,
                namespace,
                party,
                codec: true,
                ..
            } => {
                // The codec bit itself is free (a flag bit) and the MinHash blob is
                // byte-identical in both modes; only the strata blob re-expands.
                let strata_cost = strata.as_ref().map_or(0, |b| {
                    let flat =
                        crate::protocol::estimate::strata_columnar_legacy_len(b)
                            .unwrap_or(b.len());
                    varint_len(flat as u64) + flat
                });
                frame_len(
                    8 + varint_len(*set_len)
                        + 1
                        + explicit_d.map_or(0, |d| varint_len(d))
                        + strata_cost
                        + minhash.as_ref().map_or(0, |b| varint_len(b.len() as u64) + b.len())
                        + opt_namespace_len(*namespace)
                        + party.map_or(0, |(id, count)| {
                            varint_len(id as u64) + varint_len(count as u64)
                        }),
                )
            }
            _ => self.wire_len(),
        }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::new();
        let ty = match self {
            Msg::EstHello {
                config_fingerprint,
                set_len,
                explicit_d,
                strata,
                minhash,
                namespace,
                party,
                codec,
            } => {
                body.extend_from_slice(&config_fingerprint.to_le_bytes());
                put_varint(&mut body, *set_len);
                let flags = (explicit_d.is_some() as u8)
                    | (strata.is_some() as u8) << 1
                    | (minhash.is_some() as u8) << 2
                    | ((*namespace != 0) as u8) << 3
                    | (party.is_some() as u8) << 4
                    | (*codec as u8) << 5;
                body.push(flags);
                if let Some(d) = explicit_d {
                    put_varint(&mut body, *d);
                }
                if let Some(bytes) = strata {
                    put_varint(&mut body, bytes.len() as u64);
                    body.extend_from_slice(bytes);
                }
                if let Some(bytes) = minhash {
                    put_varint(&mut body, bytes.len() as u64);
                    body.extend_from_slice(bytes);
                }
                if *namespace != 0 {
                    put_varint(&mut body, *namespace as u64);
                }
                if let Some((id, count)) = party {
                    put_varint(&mut body, *id as u64);
                    put_varint(&mut body, *count as u64);
                }
                TYPE_EST_HELLO
            }
            Msg::Confirm { ok, reason, attempt } => {
                body.push(*ok as u8);
                body.push(*reason);
                put_varint(&mut body, *attempt as u64);
                TYPE_CONFIRM
            }
            Msg::Busy { retry_after_ms, namespace } => {
                put_varint(&mut body, *retry_after_ms as u64);
                if *namespace != 0 {
                    put_varint(&mut body, *namespace as u64);
                }
                TYPE_BUSY
            }
            Msg::Hello {
                l,
                m,
                seed,
                universe_bits,
                est_initiator_unique,
                est_responder_unique,
                set_len,
                namespace,
            } => {
                put_varint(&mut body, *l as u64);
                put_varint(&mut body, *m as u64);
                body.extend_from_slice(&seed.to_le_bytes());
                put_varint(&mut body, *universe_bits as u64);
                put_varint(&mut body, *est_initiator_unique);
                put_varint(&mut body, *est_responder_unique);
                put_varint(&mut body, *set_len);
                if *namespace != 0 {
                    put_varint(&mut body, *namespace as u64);
                }
                TYPE_HELLO
            }
            Msg::Sketch { sketch, codec } => {
                if *codec {
                    put_sketch_msg_codec(&mut body, sketch);
                    TYPE_SKETCH_C
                } else {
                    body = sketch.to_bytes();
                    TYPE_SKETCH
                }
            }
            Msg::AggSketch { parties, l, m, seed, digest, directive, counts, codec } => {
                put_varint(&mut body, *parties as u64);
                put_varint(&mut body, *l as u64);
                put_varint(&mut body, *m as u64);
                body.extend_from_slice(&seed.to_le_bytes());
                body.extend_from_slice(&digest.to_le_bytes());
                body.push(*directive);
                match counts {
                    Some(c) => {
                        body.push(1);
                        if *codec {
                            RleU64Col::encode(&counts_words(c), &mut body);
                        } else {
                            put_varint(&mut body, c.len() as u64);
                            for &v in c {
                                put_varint(&mut body, zigzag(v));
                            }
                        }
                    }
                    None => body.push(0),
                }
                if *codec {
                    TYPE_AGG_SKETCH_C
                } else {
                    TYPE_AGG_SKETCH
                }
            }
            Msg::MultiResidue {
                party,
                attempt,
                l,
                m,
                seed,
                universe_bits,
                est_drop,
                sketch,
                codec,
            } => {
                put_varint(&mut body, *party as u64);
                put_varint(&mut body, *attempt as u64);
                put_varint(&mut body, *l as u64);
                put_varint(&mut body, *m as u64);
                body.extend_from_slice(&seed.to_le_bytes());
                put_varint(&mut body, *universe_bits as u64);
                put_varint(&mut body, *est_drop);
                if *codec {
                    put_varint(&mut body, sketch_msg_codec_len(sketch) as u64);
                    put_sketch_msg_codec(&mut body, sketch);
                    TYPE_MULTI_RESIDUE_C
                } else {
                    let sk = sketch.to_bytes();
                    put_varint(&mut body, sk.len() as u64);
                    body.extend_from_slice(&sk);
                    TYPE_MULTI_RESIDUE
                }
            }
            Msg::Round { residue, smf, inquiry, answers, done, codec } => {
                put_varint(&mut body, residue.len() as u64);
                body.extend_from_slice(residue);
                match smf {
                    Some(bytes) => {
                        body.push(1);
                        put_varint(&mut body, bytes.len() as u64);
                        body.extend_from_slice(bytes);
                    }
                    None => body.push(0),
                }
                if *codec {
                    DeltaU64Col::encode(inquiry, &mut body);
                    BoolRleCol::encode(answers, &mut body);
                } else {
                    Fixed64Col::encode(inquiry, &mut body);
                    put_varint(&mut body, answers.len() as u64);
                    // Bit-packed answers.
                    let mut packed = vec![0u8; answers.len().div_ceil(8)];
                    for (i, &a) in answers.iter().enumerate() {
                        if a {
                            packed[i / 8] |= 1 << (i % 8);
                        }
                    }
                    body.extend_from_slice(&packed);
                }
                body.push(*done as u8);
                if *codec {
                    TYPE_ROUND_C
                } else {
                    TYPE_ROUND
                }
            }
        };
        let mut out = Vec::with_capacity(body.len() + 6);
        out.push(ty);
        put_varint(&mut out, body.len() as u64);
        out.extend_from_slice(&body);
        out
    }

    /// Parse one frame; returns `(msg, bytes_consumed)`.
    ///
    /// Adversarial-frame hardened: all offset arithmetic is checked (no debug-build
    /// overflow panics), every length field is validated against the bytes actually
    /// present *before* any allocation sized by it (columnar fields additionally cap
    /// their decoded element counts), and trailing garbage inside a body is rejected.
    pub fn from_bytes(data: &[u8]) -> Option<(Msg, usize)> {
        let ty = *data.first()?;
        let (body_len, used) = get_varint(data.get(1..)?)?;
        let body_len = usize::try_from(body_len).ok()?;
        if body_len > MAX_FRAME_BYTES {
            return None;
        }
        let start = 1 + used;
        let body = data.get(start..start.checked_add(body_len)?)?;
        let total = start + body_len;
        let mut off = 0usize;
        let msg = match ty {
            TYPE_EST_HELLO => {
                let fp = u64::from_le_bytes(take(body, &mut off, 8)?.try_into().ok()?);
                let set_len = take_varint(body, &mut off)?;
                let flags = take(body, &mut off, 1)?[0];
                if flags & !0b11_1111 != 0 {
                    return None;
                }
                let explicit_d = if flags & 1 != 0 {
                    Some(take_varint(body, &mut off)?)
                } else {
                    None
                };
                let mut opt_bytes = |present: bool| -> Option<Option<Vec<u8>>> {
                    if !present {
                        return Some(None);
                    }
                    let len = usize::try_from(take_varint(body, &mut off)?).ok()?;
                    Some(Some(take(body, &mut off, len)?.to_vec()))
                };
                let strata = opt_bytes(flags & 2 != 0)?;
                let minhash = opt_bytes(flags & 4 != 0)?;
                let namespace = if flags & 8 != 0 {
                    parse_namespace(body, &mut off)?
                } else {
                    0
                };
                let party = if flags & 16 != 0 {
                    let id = u32::try_from(take_varint(body, &mut off)?).ok()?;
                    let count = u32::try_from(take_varint(body, &mut off)?).ok()?;
                    // A "multi-party" round of fewer than two parties is meaningless, and
                    // an id at or past the count can never have been assigned.
                    if count < 2 || id >= count {
                        return None;
                    }
                    Some((id, count))
                } else {
                    None
                };
                if off != body.len() {
                    return None;
                }
                Msg::EstHello {
                    config_fingerprint: fp,
                    set_len,
                    explicit_d,
                    strata,
                    minhash,
                    namespace,
                    party,
                    codec: flags & 0b10_0000 != 0,
                }
            }
            TYPE_CONFIRM => {
                let ok = match take(body, &mut off, 1)?[0] {
                    0 => false,
                    1 => true,
                    _ => return None,
                };
                let reason = take(body, &mut off, 1)?[0];
                if reason > REASON_NOT_CONVERGED || (ok != (reason == REASON_OK)) {
                    return None;
                }
                let attempt = u32::try_from(take_varint(body, &mut off)?).ok()?;
                if off != body.len() {
                    return None;
                }
                Msg::Confirm { ok, reason, attempt }
            }
            TYPE_BUSY => {
                let retry_after_ms = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let namespace =
                    if off < body.len() { parse_namespace(body, &mut off)? } else { 0 };
                if off != body.len() {
                    return None;
                }
                Msg::Busy { retry_after_ms, namespace }
            }
            TYPE_HELLO => {
                let l = take_varint(body, &mut off)?;
                let m = take_varint(body, &mut off)?;
                let seed = u64::from_le_bytes(take(body, &mut off, 8)?.try_into().ok()?);
                let ub = take_varint(body, &mut off)?;
                let ei = take_varint(body, &mut off)?;
                let er = take_varint(body, &mut off)?;
                let sl = take_varint(body, &mut off)?;
                let namespace =
                    if off < body.len() { parse_namespace(body, &mut off)? } else { 0 };
                if off != body.len() {
                    return None;
                }
                Msg::Hello {
                    l: l as u32,
                    m: m as u32,
                    seed,
                    universe_bits: ub as u32,
                    est_initiator_unique: ei,
                    est_responder_unique: er,
                    set_len: sl,
                    namespace,
                }
            }
            TYPE_SKETCH => Msg::Sketch { sketch: SketchMsg::from_bytes(body)?, codec: false },
            TYPE_SKETCH_C => {
                let sketch = take_sketch_msg_codec(body, &mut off)?;
                if off != body.len() {
                    return None;
                }
                Msg::Sketch { sketch, codec: true }
            }
            TYPE_AGG_SKETCH | TYPE_AGG_SKETCH_C => {
                let codec = ty == TYPE_AGG_SKETCH_C;
                let parties = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let l = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let m = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let seed = u64::from_le_bytes(take(body, &mut off, 8)?.try_into().ok()?);
                let digest = u64::from_le_bytes(take(body, &mut off, 8)?.try_into().ok()?);
                let directive = take(body, &mut off, 1)?[0];
                if parties < 2 || directive > DIRECTIVE_SESSION {
                    return None;
                }
                let counts = match take(body, &mut off, 1)?[0] {
                    0 => None,
                    1 if codec => {
                        // The aggregate must cover exactly the announced geometry — the
                        // column's cap is `l` and a shorter decode is a malformed frame,
                        // the same posture as the legacy arm below.
                        let words = RleU64Col::decode(body, &mut off, l as usize)?;
                        if words.len() != l as usize {
                            return None;
                        }
                        let mut c = Vec::with_capacity(words.len());
                        for w in words {
                            c.push(unzigzag(w)?);
                        }
                        Some(c)
                    }
                    1 => {
                        let n = usize::try_from(take_varint(body, &mut off)?).ok()?;
                        // The aggregate must cover exactly the announced geometry — a
                        // count/`l` mismatch is a malformed frame, not a short read.
                        // Each zigzag varint is ≥ 1 byte, so this also kills inflated
                        // counts before allocation.
                        if n != l as usize || n > body.len().saturating_sub(off) {
                            return None;
                        }
                        let mut c = Vec::with_capacity(n);
                        for _ in 0..n {
                            c.push(unzigzag(take_varint(body, &mut off)?)?);
                        }
                        Some(c)
                    }
                    _ => return None,
                };
                if off != body.len() {
                    return None;
                }
                Msg::AggSketch { parties, l, m, seed, digest, directive, counts, codec }
            }
            TYPE_MULTI_RESIDUE | TYPE_MULTI_RESIDUE_C => {
                let codec = ty == TYPE_MULTI_RESIDUE_C;
                let party = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let attempt = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let l = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let m = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let seed = u64::from_le_bytes(take(body, &mut off, 8)?.try_into().ok()?);
                let universe_bits = u32::try_from(take_varint(body, &mut off)?).ok()?;
                let est_drop = take_varint(body, &mut off)?;
                let sk_len = usize::try_from(take_varint(body, &mut off)?).ok()?;
                let sk_bytes = take(body, &mut off, sk_len)?;
                let sketch = if codec {
                    let mut soff = 0usize;
                    let sk = take_sketch_msg_codec(sk_bytes, &mut soff)?;
                    if soff != sk_bytes.len() {
                        return None;
                    }
                    sk
                } else {
                    SketchMsg::from_bytes(sk_bytes)?
                };
                if off != body.len() {
                    return None;
                }
                Msg::MultiResidue {
                    party,
                    attempt,
                    l,
                    m,
                    seed,
                    universe_bits,
                    est_drop,
                    sketch,
                    codec,
                }
            }
            TYPE_ROUND | TYPE_ROUND_C => {
                let codec = ty == TYPE_ROUND_C;
                let rl = usize::try_from(take_varint(body, &mut off)?).ok()?;
                let residue = take(body, &mut off, rl)?.to_vec();
                let smf = match take(body, &mut off, 1)?[0] {
                    0 => None,
                    1 => {
                        let sl = usize::try_from(take_varint(body, &mut off)?).ok()?;
                        Some(take(body, &mut off, sl)?.to_vec())
                    }
                    _ => return None,
                };
                let (inquiry, answers) = if codec {
                    let inquiry = DeltaU64Col::decode(body, &mut off, MAX_ROUND_ITEMS)?;
                    let answers = BoolRleCol::decode(body, &mut off, MAX_ROUND_ITEMS)?;
                    (inquiry, answers)
                } else {
                    // The legacy column is naturally byte-bounded (8 body bytes per
                    // signature); `Fixed64Col::decode` performs the same
                    // inflated-count-dies-before-allocation check this arm always had.
                    let inquiry = Fixed64Col::decode(body, &mut off, usize::MAX)?;
                    let na = usize::try_from(take_varint(body, &mut off)?).ok()?;
                    let packed_len = na.div_ceil(8);
                    if packed_len > body.len().saturating_sub(off) {
                        return None;
                    }
                    let packed = take(body, &mut off, packed_len)?;
                    let answers = (0..na).map(|i| packed[i / 8] >> (i % 8) & 1 == 1).collect();
                    (inquiry, answers)
                };
                let done = match take(body, &mut off, 1)?[0] {
                    0 => false,
                    1 => true,
                    _ => return None,
                };
                if off != body.len() {
                    return None;
                }
                Msg::Round { residue, smf, inquiry, answers, done, codec }
            }
            _ => return None,
        };
        Some((msg, total))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::entropy::compress_residue;

    #[test]
    fn hello_roundtrip() {
        for namespace in [0, 1, 127, 128, u32::MAX] {
            let msg = Msg::Hello {
                l: 1234,
                m: 7,
                seed: 0xdead_beef,
                universe_bits: 256,
                est_initiator_unique: 10,
                est_responder_unique: 999,
                set_len: 1_000_000,
                namespace,
            };
            let bytes = msg.to_bytes();
            let (back, used) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(back, msg);
            assert_eq!(used, bytes.len());
            assert_eq!(msg.wire_len(), bytes.len());
        }
    }

    #[test]
    fn est_hello_roundtrip_all_field_combinations() {
        let variants = [
            Msg::EstHello {
                config_fingerprint: 0x1234_5678_9abc_def0,
                set_len: 1_000_000,
                explicit_d: None,
                strata: Some(vec![7; 300]),
                minhash: Some(vec![9; 64]),
                namespace: 0,
                party: None,
                codec: false,
            },
            Msg::EstHello {
                config_fingerprint: u64::MAX,
                set_len: 0,
                explicit_d: Some(12_345),
                strata: None,
                minhash: None,
                namespace: 3,
                party: None,
                codec: false,
            },
            Msg::EstHello {
                config_fingerprint: 0,
                set_len: 1,
                explicit_d: None,
                strata: None,
                minhash: None,
                namespace: u32::MAX,
                party: None,
                codec: true,
            },
            Msg::EstHello {
                config_fingerprint: 7,
                set_len: 2,
                explicit_d: Some(9),
                strata: Some(vec![1; 12]),
                minhash: Some(vec![2; 8]),
                namespace: 200,
                party: None,
                codec: true,
            },
        ];
        for msg in &variants {
            let bytes = msg.to_bytes();
            let (back, used) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(&back, msg);
            assert_eq!(used, bytes.len());
            assert_eq!(msg.wire_len(), bytes.len(), "{msg:?}");
        }
    }

    #[test]
    fn confirm_roundtrip_and_validation() {
        for msg in [
            Msg::Confirm { ok: true, reason: REASON_OK, attempt: 0 },
            Msg::Confirm { ok: false, reason: REASON_NOT_CONVERGED, attempt: 300 },
            Msg::Confirm { ok: false, reason: REASON_SKETCH_RECOVERY, attempt: 2 },
        ] {
            let bytes = msg.to_bytes();
            let (back, used) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(back, msg);
            assert_eq!(used, bytes.len());
            assert_eq!(msg.wire_len(), bytes.len());
        }
        // An inconsistent ok/reason pair must not parse (ok = true requires REASON_OK).
        let bad = Msg::Confirm { ok: true, reason: REASON_RESIDUE_DECODE, attempt: 1 };
        assert!(Msg::from_bytes(&bad.to_bytes()).is_none());
        // Unknown reason codes are rejected.
        let bad = Msg::Confirm { ok: false, reason: 99, attempt: 1 };
        assert!(Msg::from_bytes(&bad.to_bytes()).is_none());
    }

    #[test]
    fn busy_roundtrip_and_validation() {
        for msg in [
            Msg::Busy { retry_after_ms: 0, namespace: 0 },
            Msg::Busy { retry_after_ms: 120_000, namespace: 0 },
            Msg::Busy { retry_after_ms: 50, namespace: 7 },
            Msg::Busy { retry_after_ms: 0, namespace: u32::MAX },
        ] {
            let bytes = msg.to_bytes();
            let (back, used) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(back, msg);
            assert_eq!(used, bytes.len());
            assert_eq!(msg.wire_len(), bytes.len());
        }
        // Trailing garbage in the body is rejected.
        let mut body = Vec::new();
        put_varint(&mut body, 100);
        body.push(0xEE);
        let mut frame = vec![TYPE_BUSY];
        put_varint(&mut frame, body.len() as u64);
        frame.extend_from_slice(&body);
        assert!(Msg::from_bytes(&frame).is_none());
        // A hint that overflows u32 is rejected.
        let mut body = Vec::new();
        put_varint(&mut body, u64::MAX);
        let mut frame = vec![TYPE_BUSY];
        put_varint(&mut frame, body.len() as u64);
        frame.extend_from_slice(&body);
        assert!(Msg::from_bytes(&frame).is_none());
    }

    #[test]
    fn est_hello_truncation_and_garbage_rejected() {
        let msg = Msg::EstHello {
            config_fingerprint: 42,
            set_len: 9_999,
            explicit_d: None,
            strata: Some(vec![5; 40]),
            minhash: Some(vec![6; 24]),
            namespace: 0,
            party: None,
            codec: false,
        };
        let bytes = msg.to_bytes();
        for cut in 0..bytes.len() {
            assert!(Msg::from_bytes(&bytes[..cut]).is_none(), "cut {cut} parsed");
        }
        // Reserved flag bits (above the codec bit) must be zero.
        let mut body = bytes[2..].to_vec(); // type byte + 1-byte varint length here
        let flags_off = 8 + varint_len(9_999);
        body[flags_off] |= 0b100_0000;
        let mut frame = vec![TYPE_EST_HELLO];
        put_varint(&mut frame, body.len() as u64);
        frame.extend_from_slice(&body);
        assert!(Msg::from_bytes(&frame).is_none());
        // The party flag (bit 4) announcing varints that are not there is a truncation.
        let mut body = bytes[2..].to_vec();
        body[flags_off] |= 0b1_0000;
        let mut frame = vec![TYPE_EST_HELLO];
        put_varint(&mut frame, body.len() as u64);
        frame.extend_from_slice(&body);
        assert!(Msg::from_bytes(&frame).is_none());
        // Trailing garbage in the body is rejected.
        let mut body = bytes[2..].to_vec();
        body.push(0xEE);
        let mut frame = vec![TYPE_EST_HELLO];
        put_varint(&mut frame, body.len() as u64);
        frame.extend_from_slice(&body);
        assert!(Msg::from_bytes(&frame).is_none());
    }

    #[test]
    fn round_roundtrip_full_fields() {
        for codec in [false, true] {
            let msg = Msg::Round {
                residue: compress_residue(&[0, 1, -1, 0, 2]),
                smf: Some(vec![1, 2, 3, 4, 5]),
                inquiry: vec![0xAAAA, 0xBBBB],
                answers: vec![true, false, true, true, false, false, false, true, true],
                done: false,
                codec,
            };
            let bytes = msg.to_bytes();
            let (back, used) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(back, msg);
            assert_eq!(used, bytes.len());
        }
    }

    #[test]
    fn round_roundtrip_minimal() {
        for codec in [false, true] {
            let msg = Msg::Round {
                residue: vec![],
                smf: None,
                inquiry: vec![],
                answers: vec![],
                done: true,
                codec,
            };
            let bytes = msg.to_bytes();
            let (back, _) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(back, msg);
        }
    }

    #[test]
    fn truncated_frames_rejected() {
        let msg = Msg::Round {
            residue: vec![9; 40],
            smf: Some(vec![7; 10]),
            inquiry: vec![1],
            answers: vec![true],
            done: false,
            codec: false,
        };
        let bytes = msg.to_bytes();
        for cut in [0usize, 1, 5, bytes.len() - 1] {
            assert!(Msg::from_bytes(&bytes[..cut]).is_none(), "cut {cut}");
        }
    }

    /// Craft a Round frame whose body is built by `build` (for adversarial field tests).
    fn round_frame_with_body(body: Vec<u8>) -> Vec<u8> {
        let mut out = vec![TYPE_ROUND];
        put_varint(&mut out, body.len() as u64);
        out.extend_from_slice(&body);
        out
    }

    #[test]
    fn truncation_at_every_byte_boundary_rejected() {
        for codec in [false, true] {
            let msg = Msg::Round {
                residue: compress_residue(&[5, -5, 7, 0, 0, 1]),
                smf: Some(vec![3; 21]),
                inquiry: vec![1, 2, 3],
                answers: vec![true, false, true],
                done: true,
                codec,
            };
            let bytes = msg.to_bytes();
            for cut in 0..bytes.len() {
                assert!(Msg::from_bytes(&bytes[..cut]).is_none(), "codec {codec} cut {cut}");
            }
            assert!(Msg::from_bytes(&bytes).is_some());
        }
    }

    #[test]
    fn oversized_body_length_rejected() {
        // Frame header claims a body of 2^62 bytes.
        let mut frame = vec![TYPE_ROUND];
        put_varint(&mut frame, 1u64 << 62);
        frame.extend_from_slice(&[0u8; 64]);
        assert!(Msg::from_bytes(&frame).is_none());
        // u64::MAX must not overflow the offset arithmetic either (debug or release).
        let mut frame = vec![TYPE_ROUND];
        put_varint(&mut frame, u64::MAX);
        assert!(Msg::from_bytes(&frame).is_none());
    }

    #[test]
    fn oversized_residue_length_rejected() {
        let mut body = Vec::new();
        put_varint(&mut body, u64::MAX); // residue "length"
        body.extend_from_slice(&[0u8; 32]);
        assert!(Msg::from_bytes(&round_frame_with_body(body)).is_none());
    }

    #[test]
    fn oversized_smf_length_rejected() {
        let mut body = Vec::new();
        put_varint(&mut body, 0); // empty residue
        body.push(1); // smf present
        put_varint(&mut body, u64::MAX - 3); // smf "length"
        body.extend_from_slice(&[0u8; 32]);
        assert!(Msg::from_bytes(&round_frame_with_body(body)).is_none());
    }

    #[test]
    fn inflated_inquiry_count_rejected_before_allocation() {
        let mut body = Vec::new();
        put_varint(&mut body, 0); // empty residue
        body.push(0); // no smf
        put_varint(&mut body, 1u64 << 61); // inquiry "count" → would be a 2^64-byte alloc
        body.extend_from_slice(&[0u8; 64]);
        assert!(Msg::from_bytes(&round_frame_with_body(body)).is_none());
    }

    #[test]
    fn inflated_answer_count_rejected_before_allocation() {
        let mut body = Vec::new();
        put_varint(&mut body, 0); // empty residue
        body.push(0); // no smf
        put_varint(&mut body, 0); // no inquiry
        put_varint(&mut body, u64::MAX); // answer "count"
        body.extend_from_slice(&[0u8; 64]);
        assert!(Msg::from_bytes(&round_frame_with_body(body)).is_none());
    }

    #[test]
    fn trailing_garbage_in_body_rejected() {
        let msg = Msg::Round {
            residue: vec![9],
            smf: None,
            inquiry: vec![],
            answers: vec![],
            done: false,
            codec: false,
        };
        let good = msg.to_bytes();
        // Splice two junk bytes into the body and fix up the length header.
        let mut body = good[2..].to_vec(); // (1-byte type + 1-byte varint len at this size)
        body.extend_from_slice(&[0xAA, 0xBB]);
        assert!(Msg::from_bytes(&round_frame_with_body(body)).is_none());
    }

    #[test]
    fn hello_with_trailing_garbage_rejected() {
        let msg = Msg::Hello {
            l: 9,
            m: 5,
            seed: 3,
            universe_bits: 64,
            est_initiator_unique: 1,
            est_responder_unique: 2,
            set_len: 3,
            namespace: 0,
        };
        let good = msg.to_bytes();
        let reframe = |garbage: &[u8]| {
            let mut body = good[2..].to_vec();
            body.extend_from_slice(garbage);
            let mut frame = vec![TYPE_HELLO];
            put_varint(&mut frame, body.len() as u64);
            frame.extend_from_slice(&body);
            frame
        };
        // A lone `0x7F` IS a valid trailing namespace varint (127) — the versioned
        // encoding claims exactly one optional field. Everything beyond it is garbage:
        let (back, _) = Msg::from_bytes(&reframe(&[0x7F])).unwrap();
        assert!(matches!(back, Msg::Hello { namespace: 127, .. }));
        // … an incomplete varint,
        assert!(Msg::from_bytes(&reframe(&[0x80])).is_none());
        // … a canonical-form violation (tenant 0 must be encoded by omission),
        assert!(Msg::from_bytes(&reframe(&[0x00])).is_none());
        // … bytes after the namespace varint,
        assert!(Msg::from_bytes(&reframe(&[0x7F, 0x7F])).is_none());
        // … and a namespace that overflows u32.
        let mut over = Vec::new();
        put_varint(&mut over, u64::from(u32::MAX) + 1);
        assert!(Msg::from_bytes(&reframe(&over)).is_none());
    }

    /// The satellite's backward-compat proof: a PR-5-era frame (serialized before the
    /// `namespace` field existed) parses to tenant 0, and a tenant-0 frame serializes
    /// byte-identically to the old format — old clients and old servers interop.
    #[test]
    fn pr5_era_frames_without_namespace_parse_to_tenant_zero() {
        // Hello, hand-built exactly as the PR-5 serializer wrote it.
        let mut body = Vec::new();
        put_varint(&mut body, 77u64); // l
        put_varint(&mut body, 5u64); // m
        body.extend_from_slice(&0xfeed_u64.to_le_bytes()); // seed
        put_varint(&mut body, 64u64); // universe_bits
        put_varint(&mut body, 10u64); // est_initiator_unique
        put_varint(&mut body, 20u64); // est_responder_unique
        put_varint(&mut body, 900u64); // set_len
        let mut frame = vec![TYPE_HELLO];
        put_varint(&mut frame, body.len() as u64);
        frame.extend_from_slice(&body);
        let expected = Msg::Hello {
            l: 77,
            m: 5,
            seed: 0xfeed,
            universe_bits: 64,
            est_initiator_unique: 10,
            est_responder_unique: 20,
            set_len: 900,
            namespace: 0,
        };
        let (back, used) = Msg::from_bytes(&frame).unwrap();
        assert_eq!(back, expected);
        assert_eq!(used, frame.len());
        assert_eq!(expected.to_bytes(), frame, "tenant-0 Hello must stay byte-identical");

        // EstHello with the old three-bit flags byte (explicit_d only).
        let mut body = Vec::new();
        body.extend_from_slice(&42u64.to_le_bytes()); // config_fingerprint
        put_varint(&mut body, 500u64); // set_len
        body.push(0b001); // flags: explicit_d present, no namespace bit
        put_varint(&mut body, 33u64); // explicit_d
        let mut frame = vec![TYPE_EST_HELLO];
        put_varint(&mut frame, body.len() as u64);
        frame.extend_from_slice(&body);
        let expected = Msg::EstHello {
            config_fingerprint: 42,
            set_len: 500,
            explicit_d: Some(33),
            strata: None,
            minhash: None,
            namespace: 0,
            party: None,
            codec: false,
        };
        let (back, _) = Msg::from_bytes(&frame).unwrap();
        assert_eq!(back, expected);
        assert_eq!(expected.to_bytes(), frame, "tenant-0 EstHello must stay byte-identical");

        // Busy with only the retry hint.
        let mut body = Vec::new();
        put_varint(&mut body, 50u64);
        let mut frame = vec![TYPE_BUSY];
        put_varint(&mut frame, body.len() as u64);
        frame.extend_from_slice(&body);
        let expected = Msg::Busy { retry_after_ms: 50, namespace: 0 };
        let (back, _) = Msg::from_bytes(&frame).unwrap();
        assert_eq!(back, expected);
        assert_eq!(expected.to_bytes(), frame, "tenant-0 Busy must stay byte-identical");
    }

    /// Namespace hardening: truncated, oversize, and non-canonical encodings of the new
    /// field are rejected on all three frames that carry it.
    #[test]
    fn namespace_field_truncation_and_oversize_rejected() {
        let est = Msg::EstHello {
            config_fingerprint: 1,
            set_len: 10,
            explicit_d: Some(4),
            strata: None,
            minhash: None,
            namespace: 300,
            party: None,
            codec: false,
        };
        let hello = Msg::Hello {
            l: 64,
            m: 5,
            seed: 1,
            universe_bits: 64,
            est_initiator_unique: 3,
            est_responder_unique: 4,
            set_len: 9,
            namespace: 300,
        };
        let busy = Msg::Busy { retry_after_ms: 10, namespace: 300 };
        for msg in [&est, &hello, &busy] {
            let bytes = msg.to_bytes();
            // Every truncation of the frame — including mid-namespace — must die.
            for cut in 0..bytes.len() {
                assert!(Msg::from_bytes(&bytes[..cut]).is_none(), "{msg:?} cut {cut}");
            }
            let (back, _) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(&back, msg);
        }
        // Oversize: a namespace varint wider than u32 is rejected even when the flags
        // byte legitimately announces the field (EstHello path).
        let good = est.to_bytes();
        let body = &good[2..]; // 1-byte type + 1-byte length at this size
        let ns_len = varint_len(300);
        let mut huge = body[..body.len() - ns_len].to_vec();
        put_varint(&mut huge, u64::MAX);
        let mut frame = vec![TYPE_EST_HELLO];
        put_varint(&mut frame, huge.len() as u64);
        frame.extend_from_slice(&huge);
        assert!(Msg::from_bytes(&frame).is_none());
        // Non-canonical: flags announce the field but it encodes tenant 0.
        let mut zero = body[..body.len() - ns_len].to_vec();
        zero.push(0x00);
        let mut frame = vec![TYPE_EST_HELLO];
        put_varint(&mut frame, zero.len() as u64);
        frame.extend_from_slice(&zero);
        assert!(Msg::from_bytes(&frame).is_none());
    }

    /// Craft a frame of arbitrary type around a hand-built body.
    fn frame_with_body(ty: u8, body: &[u8]) -> Vec<u8> {
        let mut out = vec![ty];
        put_varint(&mut out, body.len() as u64);
        out.extend_from_slice(body);
        out
    }

    #[test]
    fn est_hello_party_field_roundtrip() {
        for (party, namespace) in [
            (Some((0u32, 2u32)), 0u32),
            (Some((1, 3)), 0),
            (Some((7, 8)), 42),
            (Some((199, u32::MAX)), u32::MAX),
        ] {
            let msg = Msg::EstHello {
                config_fingerprint: 9,
                set_len: 1_000,
                explicit_d: None,
                strata: Some(vec![4; 17]),
                minhash: Some(vec![5; 9]),
                namespace,
                party,
                codec: false,
            };
            let bytes = msg.to_bytes();
            let (back, used) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(back, msg);
            assert_eq!(used, bytes.len());
            assert_eq!(msg.wire_len(), bytes.len(), "{msg:?}");
            // Every truncation — including mid-party-varint — must die.
            for cut in 0..bytes.len() {
                assert!(Msg::from_bytes(&bytes[..cut]).is_none(), "cut {cut} parsed");
            }
        }
    }

    #[test]
    fn est_hello_party_field_validation_rejects_bad_ids_and_oversize() {
        let base = Msg::EstHello {
            config_fingerprint: 9,
            set_len: 7,
            explicit_d: Some(3),
            strata: None,
            minhash: None,
            namespace: 0,
            party: Some((1, 2)),
            codec: false,
        };
        let good = base.to_bytes();
        let body = &good[2..]; // 1-byte type + 1-byte length at this size
        let stem = &body[..body.len() - 2]; // strip the two single-byte party varints
        let reframe = |id: u64, count: u64| {
            let mut b = stem.to_vec();
            put_varint(&mut b, id);
            put_varint(&mut b, count);
            frame_with_body(TYPE_EST_HELLO, &b)
        };
        // A party "count" of 0 or 1 can never describe a multi-party round.
        assert!(Msg::from_bytes(&reframe(0, 0)).is_none());
        assert!(Msg::from_bytes(&reframe(0, 1)).is_none());
        // An id at or past the count was never assigned.
        assert!(Msg::from_bytes(&reframe(2, 2)).is_none());
        assert!(Msg::from_bytes(&reframe(9, 3)).is_none());
        // Varints that overflow u32 are rejected, not truncated.
        assert!(Msg::from_bytes(&reframe(u64::MAX, 3)).is_none());
        assert!(Msg::from_bytes(&reframe(1, u64::from(u32::MAX) + 1)).is_none());
        // The flag with only one of the two varints present is a truncation.
        let mut b = stem.to_vec();
        put_varint(&mut b, 1u64);
        assert!(Msg::from_bytes(&frame_with_body(TYPE_EST_HELLO, &b)).is_none());
    }

    /// The multi-party satellite's backward-compat proof: a PR-6-era frame (serialized
    /// before the `party` field existed) parses to `party: None`, and a two-party frame
    /// serializes byte-identically to the PR-6 format — old peers interop unchanged.
    #[test]
    fn pr6_era_two_party_frames_byte_identical() {
        // EstHello with namespace but no party bit, exactly as the PR-6 serializer wrote.
        let mut body = Vec::new();
        body.extend_from_slice(&42u64.to_le_bytes()); // config_fingerprint
        put_varint(&mut body, 500u64); // set_len
        body.push(0b1001); // flags: explicit_d + namespace, no party bit
        put_varint(&mut body, 33u64); // explicit_d
        put_varint(&mut body, 6u64); // namespace
        let frame = frame_with_body(TYPE_EST_HELLO, &body);
        let expected = Msg::EstHello {
            config_fingerprint: 42,
            set_len: 500,
            explicit_d: Some(33),
            strata: None,
            minhash: None,
            namespace: 6,
            party: None,
            codec: false,
        };
        let (back, used) = Msg::from_bytes(&frame).unwrap();
        assert_eq!(back, expected);
        assert_eq!(used, frame.len());
        assert_eq!(expected.to_bytes(), frame, "two-party EstHello must stay byte-identical");
    }

    #[test]
    fn agg_sketch_roundtrip_with_and_without_counts() {
        let variants = [
            Msg::AggSketch {
                parties: 3,
                l: 7,
                m: 5,
                seed: 0xfeed,
                digest: 0xabcdef,
                directive: DIRECTIVE_SESSION,
                counts: Some(vec![0, 1, -1, i32::MAX, i32::MIN, 5, -3]),
                codec: false,
            },
            Msg::AggSketch {
                parties: 8,
                l: 1 << 20,
                m: 64,
                seed: u64::MAX,
                digest: 0,
                directive: DIRECTIVE_IN_SYNC,
                counts: None,
                codec: false,
            },
            Msg::AggSketch {
                parties: 3,
                l: 9,
                m: 5,
                seed: 0xfeed,
                digest: 0xabcdef,
                directive: DIRECTIVE_SESSION,
                counts: Some(vec![0, 0, 0, 0, 1, -1, 0, 0, 2]),
                codec: true,
            },
        ];
        for msg in &variants {
            let bytes = msg.to_bytes();
            let (back, used) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(&back, msg);
            assert_eq!(used, bytes.len());
            assert_eq!(msg.wire_len(), bytes.len(), "{msg:?}");
            for cut in 0..bytes.len() {
                assert!(Msg::from_bytes(&bytes[..cut]).is_none(), "cut {cut} parsed");
            }
        }
    }

    #[test]
    fn agg_sketch_count_length_mismatch_rejected() {
        // 6 counts under an announced l of 7: a malformed aggregate, not a short read.
        let mut body = Vec::new();
        put_varint(&mut body, 3u64); // parties
        put_varint(&mut body, 7u64); // l
        put_varint(&mut body, 5u64); // m
        body.extend_from_slice(&1u64.to_le_bytes()); // seed
        body.extend_from_slice(&2u64.to_le_bytes()); // digest
        body.push(DIRECTIVE_SESSION);
        body.push(1); // counts present
        put_varint(&mut body, 6u64);
        for _ in 0..6 {
            body.push(0);
        }
        assert!(Msg::from_bytes(&frame_with_body(TYPE_AGG_SKETCH, &body)).is_none());
        // An inflated count dies before any allocation sized by it.
        let mut body = Vec::new();
        put_varint(&mut body, 3u64);
        put_varint(&mut body, u32::MAX as u64); // l
        put_varint(&mut body, 5u64);
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&2u64.to_le_bytes());
        body.push(DIRECTIVE_SESSION);
        body.push(1);
        put_varint(&mut body, u32::MAX as u64); // matches l, but 4 G counts aren't here
        body.extend_from_slice(&[0u8; 64]);
        assert!(Msg::from_bytes(&frame_with_body(TYPE_AGG_SKETCH, &body)).is_none());
    }

    #[test]
    fn agg_sketch_bad_directive_and_party_count_rejected() {
        let good = Msg::AggSketch {
            parties: 3,
            l: 4,
            m: 5,
            seed: 1,
            digest: 2,
            directive: DIRECTIVE_IN_SYNC,
            counts: Some(vec![1, -1, 0, 2]),
            codec: false,
        };
        let bytes = good.to_bytes();
        let body = &bytes[2..];
        // Unknown directive byte.
        let mut bad = body.to_vec();
        let directive_off = 1 + 1 + 1 + 8 + 8; // parties|l|m varints are 1 byte each here
        bad[directive_off] = 2;
        assert!(Msg::from_bytes(&frame_with_body(TYPE_AGG_SKETCH, &bad)).is_none());
        // A one-party "aggregate" is meaningless.
        let mut bad = body.to_vec();
        bad[0] = 1;
        assert!(Msg::from_bytes(&frame_with_body(TYPE_AGG_SKETCH, &bad)).is_none());
        // Counts-present flag with any value other than 0/1.
        let mut bad = body.to_vec();
        bad[directive_off + 1] = 9;
        assert!(Msg::from_bytes(&frame_with_body(TYPE_AGG_SKETCH, &bad)).is_none());
        // Trailing garbage after the counts.
        let mut bad = body.to_vec();
        bad.push(0xEE);
        assert!(Msg::from_bytes(&frame_with_body(TYPE_AGG_SKETCH, &bad)).is_none());
    }

    #[test]
    fn multi_residue_roundtrip_and_embedded_sketch_validation() {
        let msg = Msg::MultiResidue {
            party: 4,
            attempt: 2,
            l: 300,
            m: 7,
            seed: 0xc0ffee,
            universe_bits: 64,
            est_drop: 11,
            sketch: SketchMsg {
                n: 300,
                table: vec![1; 40],
                payload: vec![2; 129],
                syndromes: vec![3; 7],
            },
            codec: false,
        };
        let bytes = msg.to_bytes();
        let (back, used) = Msg::from_bytes(&bytes).unwrap();
        assert_eq!(back, msg);
        assert_eq!(used, bytes.len());
        assert_eq!(msg.wire_len(), bytes.len());
        for cut in 0..bytes.len() {
            assert!(Msg::from_bytes(&bytes[..cut]).is_none(), "cut {cut} parsed");
        }
        // A sketch-length prefix that undershoots the embedded sketch truncates it —
        // the inner parser's strictness must reject the slice, not resync.
        let body = &bytes[3..]; // 1-byte type + 2-byte varint length at this size
        let header = 1 + 1 + 2 + 1 + 8 + 1 + 1; // party|attempt|l|m|seed|ub|est_drop
        let sk = msg_sketch_bytes(&msg);
        let mut bad = body[..header].to_vec();
        put_varint(&mut bad, (sk.len() - 1) as u64);
        bad.extend_from_slice(&sk);
        assert!(Msg::from_bytes(&frame_with_body(TYPE_MULTI_RESIDUE, &bad)).is_none());
        // An oversized prefix overruns the body.
        let mut bad = body[..header].to_vec();
        put_varint(&mut bad, u64::MAX);
        bad.extend_from_slice(&sk);
        assert!(Msg::from_bytes(&frame_with_body(TYPE_MULTI_RESIDUE, &bad)).is_none());
    }

    fn msg_sketch_bytes(msg: &Msg) -> Vec<u8> {
        match msg {
            Msg::MultiResidue { sketch, .. } => sketch.to_bytes(),
            _ => unreachable!(),
        }
    }

    #[test]
    fn frames_concatenate() {
        let m1 = Msg::Round {
            residue: vec![1],
            smf: None,
            inquiry: vec![],
            answers: vec![],
            done: false,
            codec: false,
        };
        let m2 = Msg::Round {
            residue: vec![2, 3],
            smf: None,
            inquiry: vec![],
            answers: vec![],
            done: true,
            codec: true,
        };
        let mut stream = m1.to_bytes();
        stream.extend(m2.to_bytes());
        let (b1, used1) = Msg::from_bytes(&stream).unwrap();
        let (b2, used2) = Msg::from_bytes(&stream[used1..]).unwrap();
        assert_eq!(b1, m1);
        assert_eq!(b2, m2);
        assert_eq!(used1 + used2, stream.len());
    }

    fn sample_sketch() -> SketchMsg {
        SketchMsg {
            n: 300,
            table: vec![0, 0, 0, 4, 4, 4, 4, 9, 0, 0, 0, 0, 0, 0, 0, 0, 2, 1],
            payload: vec![2; 129],
            syndromes: vec![3; 7],
        }
    }

    /// Satellite: `wire_len() == to_bytes().len()` for **every** variant across all the
    /// versioned trailing fields (namespace / party / codec, present and absent) — the
    /// two are maintained by hand and this is what keeps them from drifting.
    #[test]
    fn wire_len_matches_to_bytes_for_every_variant_and_versioned_field() {
        let mut msgs: Vec<Msg> = Vec::new();
        let estimator_combos: [(Option<u64>, Option<Vec<u8>>, Option<Vec<u8>>); 3] = [
            (Some(123), None, None),
            (None, Some(vec![7; 33]), Some(vec![9; 64])),
            (None, None, None),
        ];
        for codec in [false, true] {
            for namespace in [0u32, 511] {
                for party in [None, Some((1u32, 4u32))] {
                    for (explicit_d, strata, minhash) in estimator_combos.clone() {
                        msgs.push(Msg::EstHello {
                            config_fingerprint: 0xfeed_f00d,
                            set_len: 1 << 33,
                            explicit_d,
                            strata,
                            minhash,
                            namespace,
                            party,
                            codec,
                        });
                    }
                }
                msgs.push(Msg::Hello {
                    l: 1 << 18,
                    m: 127,
                    seed: u64::MAX,
                    universe_bits: 256,
                    est_initiator_unique: 128,
                    est_responder_unique: 1 << 40,
                    set_len: u64::MAX,
                    namespace,
                });
                msgs.push(Msg::Busy { retry_after_ms: 99, namespace });
            }
            msgs.push(Msg::Sketch { sketch: sample_sketch(), codec });
            for smf in [None, Some(vec![9; 200])] {
                msgs.push(Msg::Round {
                    residue: compress_residue(&[1, -2, 0, 3]),
                    smf,
                    inquiry: vec![3, 1 << 60, 0, 7, 7],
                    answers: vec![true; 17],
                    done: true,
                    codec,
                });
            }
            msgs.push(Msg::Round {
                residue: vec![],
                smf: None,
                inquiry: vec![],
                answers: vec![],
                done: false,
                codec,
            });
            for counts in [None, Some(vec![0, 0, 1, -1, 0, 0, 0, 2])] {
                let l = counts.as_ref().map_or(4, |c: &Vec<i32>| c.len() as u32);
                msgs.push(Msg::AggSketch {
                    parties: 5,
                    l,
                    m: 8,
                    seed: 0xfeed,
                    digest: 42,
                    directive: DIRECTIVE_SESSION,
                    counts,
                    codec,
                });
            }
            msgs.push(Msg::MultiResidue {
                party: 3,
                attempt: 1,
                l: 300,
                m: 7,
                seed: 1,
                universe_bits: 64,
                est_drop: 9,
                sketch: sample_sketch(),
                codec,
            });
        }
        msgs.push(Msg::Confirm { ok: false, reason: REASON_NOT_CONVERGED, attempt: 7 });
        for msg in &msgs {
            let bytes = msg.to_bytes();
            assert_eq!(msg.wire_len(), bytes.len(), "wire_len drift: {msg:?}");
            let (back, used) = Msg::from_bytes(&bytes).unwrap();
            assert_eq!(&back, msg);
            assert_eq!(used, bytes.len());
        }
    }

    /// Acceptance: codec-off frames are byte-identical to PR-7 transcripts. The payload
    /// frame bodies are hand-built exactly as the PR-7 serializer wrote them; they must
    /// parse to `codec: false` messages that re-serialize to the same bytes.
    #[test]
    fn pr7_era_codec_off_frames_byte_identical() {
        // Sketch: the body was SketchMsg::to_bytes verbatim.
        let sk = sample_sketch();
        let frame = frame_with_body(TYPE_SKETCH, &sk.to_bytes());
        let expected = Msg::Sketch { sketch: sk.clone(), codec: false };
        let (back, used) = Msg::from_bytes(&frame).unwrap();
        assert_eq!(back, expected);
        assert_eq!(used, frame.len());
        assert_eq!(expected.to_bytes(), frame, "codec-off Sketch must stay byte-identical");

        // Round: raw 8-byte inquiry words, bit-packed answers.
        let residue = compress_residue(&[1, 0, -2]);
        let inquiry = [0xAAAA_BBBB_CCCC_DDDDu64, 42];
        let answers = [true, false, true];
        let mut body = Vec::new();
        put_varint(&mut body, residue.len() as u64);
        body.extend_from_slice(&residue);
        body.push(1); // smf present
        put_varint(&mut body, 5u64);
        body.extend_from_slice(&[1, 2, 3, 4, 5]);
        put_varint(&mut body, inquiry.len() as u64);
        for sig in inquiry {
            body.extend_from_slice(&sig.to_le_bytes());
        }
        put_varint(&mut body, answers.len() as u64);
        body.push(0b101); // answers LSB-first
        body.push(0); // done = false
        let frame = frame_with_body(TYPE_ROUND, &body);
        let expected = Msg::Round {
            residue,
            smf: Some(vec![1, 2, 3, 4, 5]),
            inquiry: inquiry.to_vec(),
            answers: answers.to_vec(),
            done: false,
            codec: false,
        };
        let (back, _) = Msg::from_bytes(&frame).unwrap();
        assert_eq!(back, expected);
        assert_eq!(expected.to_bytes(), frame, "codec-off Round must stay byte-identical");

        // AggSketch: zigzag-varint counts.
        let counts = [0i32, -1, 3, 0];
        let mut body = Vec::new();
        put_varint(&mut body, 3u64); // parties
        put_varint(&mut body, 4u64); // l
        put_varint(&mut body, 5u64); // m
        body.extend_from_slice(&7u64.to_le_bytes()); // seed
        body.extend_from_slice(&9u64.to_le_bytes()); // digest
        body.push(DIRECTIVE_SESSION);
        body.push(1);
        put_varint(&mut body, counts.len() as u64);
        for &v in &counts {
            put_varint(&mut body, zigzag(v));
        }
        let frame = frame_with_body(TYPE_AGG_SKETCH, &body);
        let expected = Msg::AggSketch {
            parties: 3,
            l: 4,
            m: 5,
            seed: 7,
            digest: 9,
            directive: DIRECTIVE_SESSION,
            counts: Some(counts.to_vec()),
            codec: false,
        };
        let (back, _) = Msg::from_bytes(&frame).unwrap();
        assert_eq!(back, expected);
        assert_eq!(expected.to_bytes(), frame, "codec-off AggSketch must stay byte-identical");

        // MultiResidue: length-prefixed legacy sketch blob.
        let mut body = Vec::new();
        put_varint(&mut body, 2u64); // party
        put_varint(&mut body, 0u64); // attempt
        put_varint(&mut body, 300u64); // l
        put_varint(&mut body, 7u64); // m
        body.extend_from_slice(&1u64.to_le_bytes()); // seed
        put_varint(&mut body, 64u64); // universe_bits
        put_varint(&mut body, 11u64); // est_drop
        let sk_bytes = sk.to_bytes();
        put_varint(&mut body, sk_bytes.len() as u64);
        body.extend_from_slice(&sk_bytes);
        let frame = frame_with_body(TYPE_MULTI_RESIDUE, &body);
        let expected = Msg::MultiResidue {
            party: 2,
            attempt: 0,
            l: 300,
            m: 7,
            seed: 1,
            universe_bits: 64,
            est_drop: 11,
            sketch: sk,
            codec: false,
        };
        let (back, _) = Msg::from_bytes(&frame).unwrap();
        assert_eq!(back, expected);
        assert_eq!(
            expected.to_bytes(),
            frame,
            "codec-off MultiResidue must stay byte-identical"
        );
    }

    /// The codec earns its keep on structured payloads: sorted inquiry ids, sparse
    /// answer bitmaps, zero-heavy sketch tables and aggregate counts.
    #[test]
    fn codec_frames_beat_legacy_on_structured_payloads() {
        let round = |codec| Msg::Round {
            residue: vec![5; 30],
            smf: None,
            inquiry: (0..200u64).map(|i| 1_000_000 + i * 13).collect(),
            answers: vec![false; 300],
            done: false,
            codec,
        };
        assert!(round(true).wire_len() < round(false).wire_len());

        let sketch = |codec| Msg::Sketch {
            sketch: SketchMsg {
                n: 4096,
                table: {
                    let mut t = vec![0u8; 600];
                    t[3] = 200;
                    t[400] = 9;
                    t
                },
                payload: vec![0xA5; 900],
                syndromes: vec![0x5A; 60],
            },
            codec,
        };
        assert!(sketch(true).wire_len() < sketch(false).wire_len());

        let agg = |codec| Msg::AggSketch {
            parties: 4,
            l: 2048,
            m: 8,
            seed: 1,
            digest: 2,
            directive: DIRECTIVE_SESSION,
            counts: Some({
                let mut c = vec![0i32; 2048];
                c[5] = 3;
                c[1999] = -2;
                c
            }),
            codec,
        };
        assert!(agg(true).wire_len() < agg(false).wire_len());

        // Adversarially unstructured payloads cost at most the adaptive mode bytes.
        let noisy = |codec| Msg::Round {
            residue: vec![],
            smf: None,
            inquiry: (0..64u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)).collect(),
            answers: (0..64).map(|i| i % 2 == 0).collect(),
            done: false,
            codec,
        };
        assert!(noisy(true).wire_len() <= noisy(false).wire_len() + 2);
    }

    /// `raw_wire_len` reports exactly what the same message costs codec-off, and
    /// degenerates to `wire_len` for codec-off frames.
    #[test]
    fn raw_wire_len_matches_codec_off_equivalent() {
        let round = |codec| Msg::Round {
            residue: vec![1, 2, 3],
            smf: None,
            inquiry: (0..40u64).map(|i| i * 7).collect(),
            answers: vec![false; 33],
            done: false,
            codec,
        };
        assert_eq!(round(true).raw_wire_len(), round(false).wire_len());
        assert_eq!(round(false).raw_wire_len(), round(false).wire_len());

        let sketch = |codec| Msg::Sketch { sketch: sample_sketch(), codec };
        assert_eq!(sketch(true).raw_wire_len(), sketch(false).wire_len());
        assert_eq!(sketch(false).raw_wire_len(), sketch(false).wire_len());

        let agg = |codec| Msg::AggSketch {
            parties: 4,
            l: 6,
            m: 8,
            seed: 1,
            digest: 2,
            directive: DIRECTIVE_SESSION,
            counts: Some(vec![0, 0, 1, -1, 0, 0]),
            codec,
        };
        assert_eq!(agg(true).raw_wire_len(), agg(false).wire_len());

        let mr = |codec| Msg::MultiResidue {
            party: 1,
            attempt: 0,
            l: 300,
            m: 7,
            seed: 1,
            universe_bits: 64,
            est_drop: 9,
            sketch: sample_sketch(),
            codec,
        };
        assert_eq!(mr(true).raw_wire_len(), mr(false).wire_len());

        // With a real SMF blob, each mode serializes its own encoding; raw accounting
        // recovers the flat size from the codec blob's element count.
        let bloom = crate::smf::BloomFilter::with_fpr(64, 0.01, 7);
        let with_smf = |smf: Vec<u8>, codec| Msg::Round {
            residue: vec![1],
            smf: Some(smf),
            inquiry: vec![],
            answers: vec![],
            done: false,
            codec,
        };
        assert_eq!(
            with_smf(bloom.to_codec_bytes(), true).raw_wire_len(),
            with_smf(bloom.to_bytes(), false).wire_len()
        );
    }

    /// The codec handshake bit (flags bit 5) rides the same versioned pattern as
    /// namespace/party: absent on old frames, zero-cost when off, bit 6 stays reserved.
    #[test]
    fn est_hello_codec_flag_negotiation_bit() {
        let hello = |codec| Msg::EstHello {
            config_fingerprint: 42,
            set_len: 500,
            explicit_d: Some(33),
            strata: None,
            minhash: None,
            namespace: 0,
            party: None,
            codec,
        };
        // The bit costs zero bytes: on and off differ only in the flags byte.
        let on = hello(true).to_bytes();
        let off = hello(false).to_bytes();
        assert_eq!(on.len(), off.len());
        assert_eq!(hello(true).wire_len(), hello(false).wire_len());
        let diff: Vec<usize> = (0..on.len()).filter(|&i| on[i] != off[i]).collect();
        let flags_off = 2 + 8 + varint_len(500); // frame header + fingerprint + set_len
        assert_eq!(diff, vec![flags_off]);
        assert_eq!(on[flags_off] ^ off[flags_off], 0b10_0000);
        let (back, _) = Msg::from_bytes(&on).unwrap();
        assert!(matches!(back, Msg::EstHello { codec: true, .. }));
        let (back, _) = Msg::from_bytes(&off).unwrap();
        assert!(matches!(back, Msg::EstHello { codec: false, .. }));
    }

    /// Codec-frame hardening: the columnar arms inherit the same adversarial posture as
    /// the legacy ones.
    #[test]
    fn codec_frame_adversarial_fields_rejected() {
        // A codec sketch whose table column carries a value outside the u8 alphabet.
        let mut body = Vec::new();
        put_varint(&mut body, 4u64); // n
        RleU64Col::encode(&[1, 2, 300, 4], &mut body); // 300 does not fit a table byte
        put_varint(&mut body, 0u64); // payload
        put_varint(&mut body, 0u64); // syndromes
        assert!(Msg::from_bytes(&frame_with_body(TYPE_SKETCH_C, &body)).is_none());

        // Codec aggregate counts shorter than the announced l.
        let mut body = Vec::new();
        put_varint(&mut body, 3u64); // parties
        put_varint(&mut body, 7u64); // l
        put_varint(&mut body, 5u64); // m
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&2u64.to_le_bytes());
        body.push(DIRECTIVE_SESSION);
        body.push(1);
        RleU64Col::encode(&[0, 0, 0, 0, 0, 0], &mut body); // 6 counts, l says 7
        assert!(Msg::from_bytes(&frame_with_body(TYPE_AGG_SKETCH_C, &body)).is_none());

        // A codec round whose inquiry column claims more elements than MAX_ROUND_ITEMS.
        let mut body = Vec::new();
        put_varint(&mut body, 0u64); // empty residue
        body.push(0); // no smf
        put_varint(&mut body, (MAX_ROUND_ITEMS as u64) + 1); // inquiry column count
        body.push(1); // delta mode
        body.extend_from_slice(&[0u8; 64]);
        assert!(Msg::from_bytes(&frame_with_body(TYPE_ROUND_C, &body)).is_none());

        // Trailing garbage after a valid codec body.
        let good = Msg::Round {
            residue: vec![1],
            smf: None,
            inquiry: vec![1, 2, 3],
            answers: vec![true, false],
            done: false,
            codec: true,
        }
        .to_bytes();
        let mut body = good[2..].to_vec();
        body.push(0xEE);
        assert!(Msg::from_bytes(&frame_with_body(TYPE_ROUND_C, &body)).is_none());
    }
}
