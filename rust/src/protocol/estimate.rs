//! The d-estimation handshake (§7.1): "the SDC d is known to all protocols, because it
//! can be handily estimated using min-wise hashing [47], Strata [48], … by sending a few
//! hundred bytes during a handshake step."
//!
//! We implement both referenced estimators so sessions can bootstrap without ground truth:
//!
//! * **Strata estimator** (Eppstein et al. / Flajolet–Martin stratification): 32 strata of
//!   tiny IBLTs; stratum k receives elements whose hash has exactly k *trailing* zero bits
//!   (`stratum_of` uses `trailing_zeros`; the deepest stratum absorbs everything beyond
//!   the stratum count — the geometric law is identical to the leading-zeros convention).
//!   Decode strata from the deepest down; when a stratum's difference IBLT peels, its
//!   count scales by 2^(k+1). A few KB buys a constant-factor estimate of d = |AΔB|.
//! * **Min-wise (MinHash) estimator**: k bottom hashes estimate the Jaccard similarity J;
//!   d ≈ (1−J)/(1+J) · (|A|+|B|). A few hundred bytes; best when d/|A∪B| is not tiny.

use crate::baselines::iblt::{Iblt, IbltParams};
use crate::entropy::{put_varint, take_varint};
use crate::hash::hash_u64;
use crate::wire::column::{take_uvarint, varint_len, Column, Fixed64Col};

/// Strata estimator: `strata` levels × a `cells`-cell IBLT each.
pub struct StrataEstimator {
    pub strata: Vec<Iblt>,
    seed: u64,
}

impl StrataEstimator {
    /// Paper-typical sizing: 32 strata × 80 cells ≈ a few KB.
    pub fn new(seed: u64) -> Self {
        Self::with_shape(32, 80, seed)
    }

    pub fn with_shape(n_strata: usize, cells: usize, seed: u64) -> Self {
        let params = IbltParams { seed: seed ^ 0x57a7a, ..IbltParams::paper_synthetic() };
        StrataEstimator {
            strata: (0..n_strata).map(|_| Iblt::new(cells, params)).collect(),
            seed,
        }
    }

    fn stratum_of(&self, id: u64) -> usize {
        let h = hash_u64(id, self.seed ^ 0x1e7e1);
        (h.trailing_zeros() as usize).min(self.strata.len() - 1)
    }

    pub fn insert_all(&mut self, ids: &[u64]) {
        for &id in ids {
            let s = self.stratum_of(id);
            self.strata[s].insert(id);
        }
    }

    /// Wire size (the handshake cost).
    pub fn size_bytes(&self) -> usize {
        self.strata.iter().map(|t| t.size_bytes()).sum()
    }

    /// Serialize for the `EstHello` handshake frame: stratum count, then each stratum's
    /// IBLT cells. The shape/seed parameters are *not* carried — both peers derive them
    /// from the shared protocol seed, and [`StrataEstimator::shape_matches`] guards
    /// against a peer that sent a different shape anyway.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_varint(&mut out, self.strata.len() as u64);
        for t in &self.strata {
            out.extend_from_slice(&t.to_bytes());
        }
        out
    }

    /// Parse a peer's serialized estimator. `seed` must be the same shared seed this
    /// host built its own estimator with. Hardened: stratum/cell counts are validated
    /// before any allocation, and trailing garbage is rejected.
    pub fn from_bytes(data: &[u8], seed: u64) -> Option<StrataEstimator> {
        let mut off = 0usize;
        let n = usize::try_from(take_varint(data, &mut off)?).ok()?;
        if n == 0 || n > 64 {
            return None;
        }
        let params = IbltParams { seed: seed ^ 0x57a7a, ..IbltParams::paper_synthetic() };
        let mut strata = Vec::with_capacity(n);
        for _ in 0..n {
            strata.push(Iblt::from_bytes(data, &mut off, params)?);
        }
        if off != data.len() {
            return None;
        }
        Some(StrataEstimator { strata, seed })
    }

    /// Columnar serialization for codec-on `EstHello` frames: stratum count, then each
    /// stratum's cells as [`Iblt::to_columnar_bytes`] run-length columns. Strata IBLTs
    /// are overwhelmingly empty cells (each stratum sees a geometrically shrinking slice
    /// of the set), so this is typically several times smaller than
    /// [`StrataEstimator::to_bytes`].
    pub fn to_columnar_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_varint(&mut out, self.strata.len() as u64);
        for t in &self.strata {
            out.extend_from_slice(&t.to_columnar_bytes());
        }
        out
    }

    /// Parse a peer's columnar estimator (codec-on sessions), mirroring
    /// [`StrataEstimator::from_bytes`]'s hardening: stratum count capped, per-column
    /// element caps enforced by the column layer, trailing garbage rejected.
    pub fn from_columnar_bytes(data: &[u8], seed: u64) -> Option<StrataEstimator> {
        let mut off = 0usize;
        let n = usize::try_from(take_varint(data, &mut off)?).ok()?;
        if n == 0 || n > 64 {
            return None;
        }
        let params = IbltParams { seed: seed ^ 0x57a7a, ..IbltParams::paper_synthetic() };
        let mut strata = Vec::with_capacity(n);
        for _ in 0..n {
            strata.push(Iblt::from_columnar_bytes(data, &mut off, params)?);
        }
        if off != data.len() {
            return None;
        }
        Some(StrataEstimator { strata, seed })
    }

    /// Whether `other` has the same stratum count and per-stratum cell counts — the
    /// precondition of [`StrataEstimator::estimate`]; callers deserializing a peer's
    /// estimator must check this instead of letting `estimate` assert.
    pub fn shape_matches(&self, other: &StrataEstimator) -> bool {
        self.strata.len() == other.strata.len()
            && self
                .strata
                .iter()
                .zip(&other.strata)
                .all(|(a, b)| a.num_cells() == b.num_cells())
    }

    /// Estimate `d = |A Δ B|` from our strata vs the peer's.
    ///
    /// Walk from the deepest stratum down, summing decoded differences; the first stratum
    /// that fails to peel caps the exactly-counted range, and the accumulated count scales
    /// by `2^(k+1)` where `k` is the last decoded level (standard Strata estimation).
    pub fn estimate(&self, theirs: &StrataEstimator) -> usize {
        assert_eq!(self.strata.len(), theirs.strata.len());
        let mut count = 0usize;
        for k in (0..self.strata.len()).rev() {
            match self.strata[k].sub(&theirs.strata[k]).peel() {
                Some((pos, neg)) => count += pos.len() + neg.len(),
                None => {
                    // Everything below level k is unobserved: scale up.
                    return (count << (k + 1)).max(1);
                }
            }
        }
        count.max(1)
    }

    /// Directional variant of [`StrataEstimator::estimate`]: `(mine_only, theirs_only)`
    /// estimates of `|A\B|` and `|B\A|` (from `self = A`'s perspective), scaled exactly
    /// like the symmetric estimate. The zero side is a reliable *subset* signal — when
    /// `A ⊆ B`, no decoded stratum ever peels an A-only element — which is what lets
    /// `Mode::Auto` pick the cheaper unidirectional protocol without ground truth.
    pub fn estimate_directional(&self, theirs: &StrataEstimator) -> (usize, usize) {
        assert!(self.shape_matches(theirs), "estimator shapes must match");
        let mut mine = 0usize;
        let mut other = 0usize;
        for k in (0..self.strata.len()).rev() {
            match self.strata[k].sub(&theirs.strata[k]).peel() {
                Some((pos, neg)) => {
                    mine += pos.len();
                    other += neg.len();
                }
                None => return (mine << (k + 1), other << (k + 1)),
            }
        }
        (mine, other)
    }
}

/// Given a [`StrataEstimator::to_columnar_bytes`] blob, the byte length the *legacy*
/// [`StrataEstimator::to_bytes`] encoding of the same estimator would occupy. Used by
/// `Msg::raw_wire_len` to charge codec-off-equivalent bytes for codec-on `EstHello`
/// frames. `None` if the blob is malformed (the parse hardening matches
/// [`StrataEstimator::from_columnar_bytes`]; seed does not affect cell layout, so any
/// params work for this accounting pass).
pub fn strata_columnar_legacy_len(bytes: &[u8]) -> Option<usize> {
    let mut off = 0usize;
    let n = usize::try_from(take_uvarint(bytes, &mut off)?).ok()?;
    if n == 0 || n > 64 {
        return None;
    }
    let mut len = varint_len(n as u64);
    for _ in 0..n {
        len += Iblt::from_columnar_bytes(bytes, &mut off, IbltParams::paper_synthetic())?
            .legacy_len();
    }
    if off != bytes.len() {
        return None;
    }
    Some(len)
}

/// MinHash (bottom-k) estimator of the symmetric difference cardinality.
pub struct MinHashEstimator {
    mins: Vec<u64>,
    pub set_len: usize,
}

impl MinHashEstimator {
    pub fn build(ids: &[u64], k: usize, seed: u64) -> Self {
        // Bottom-k of one hash function (equivalent to k-mins in accuracy class, cheaper).
        let mut hashes: Vec<u64> = ids.iter().map(|&id| hash_u64(id, seed)).collect();
        hashes.sort_unstable();
        hashes.truncate(k);
        MinHashEstimator { mins: hashes, set_len: ids.len() }
    }

    pub fn size_bytes(&self) -> usize {
        8 * self.mins.len() + 8
    }

    /// Serialize for the `EstHello` handshake frame: set cardinality, then the bottom-k
    /// hashes as a [`Fixed64Col`] (`varint k | k × 8 B LE` — byte-identical to the
    /// hand-rolled loop this replaces, so the layout is the same in both codec modes;
    /// the signatures are uniform random, which no packed encoding beats).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 * self.mins.len() + 10);
        put_varint(&mut out, self.set_len as u64);
        Fixed64Col::encode(&self.mins, &mut out);
        out
    }

    /// Parse a peer's serialized estimator (count validated before allocation; trailing
    /// garbage rejected).
    pub fn from_bytes(data: &[u8]) -> Option<MinHashEstimator> {
        let mut off = 0usize;
        let set_len = usize::try_from(take_varint(data, &mut off)?).ok()?;
        let mins = Fixed64Col::decode(data, &mut off, usize::MAX)?;
        if off != data.len() {
            return None;
        }
        Some(MinHashEstimator { mins, set_len })
    }

    /// Jaccard estimate from two bottom-k signatures.
    pub fn jaccard(&self, other: &MinHashEstimator) -> f64 {
        let k = self.mins.len().min(other.mins.len());
        if k == 0 {
            return 1.0;
        }
        // Bottom-k of the union = merge of the two bottom-k lists.
        let mut union: Vec<u64> = self
            .mins
            .iter()
            .chain(&other.mins)
            .copied()
            .collect();
        union.sort_unstable();
        union.dedup();
        union.truncate(k);
        let mine: std::collections::HashSet<u64> = self.mins.iter().copied().collect();
        let theirs: std::collections::HashSet<u64> = other.mins.iter().copied().collect();
        let shared = union
            .iter()
            .filter(|h| mine.contains(h) && theirs.contains(h))
            .count();
        shared as f64 / k as f64
    }

    /// `d̂ = (1−J)/(1+J)·(|A|+|B|)` (from J = |A∩B|/|A∪B| and |A|+|B| = |A∪B|+|A∩B|).
    pub fn estimate_d(&self, other: &MinHashEstimator) -> usize {
        let j = self.jaccard(other).clamp(0.0, 1.0);
        let total = (self.set_len + other.set_len) as f64;
        ((1.0 - j) / (1.0 + j) * total).round().max(0.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn strata_estimates_within_factor_two() {
        for (d, seed) in [(100usize, 1u64), (1_000, 2), (10_000, 3)] {
            let (a, b) = synth::overlap_pair(50_000, d / 2, d - d / 2, seed);
            let mut ea = StrataEstimator::new(7);
            ea.insert_all(&a);
            let mut eb = StrataEstimator::new(7);
            eb.insert_all(&b);
            let est = ea.estimate(&eb);
            assert!(
                est >= d / 3 && est <= d * 3,
                "d={d}: estimate {est} off by more than 3x"
            );
        }
    }

    #[test]
    fn strata_handshake_is_few_kb() {
        let e = StrataEstimator::new(1);
        assert!(e.size_bytes() < 40_000, "{}", e.size_bytes());
    }

    #[test]
    fn strata_identical_sets_estimate_small() {
        let (a, _) = synth::subset_pair(20_000, 0, 4);
        let mut ea = StrataEstimator::new(7);
        ea.insert_all(&a);
        let mut eb = StrataEstimator::new(7);
        eb.insert_all(&a);
        assert!(ea.estimate(&eb) <= 2);
    }

    #[test]
    fn minhash_estimates_large_differences() {
        // MinHash shines when d is a sizable fraction of the union.
        let (a, b) = synth::overlap_pair(20_000, 5_000, 5_000, 5);
        let ma = MinHashEstimator::build(&a, 512, 9);
        let mb = MinHashEstimator::build(&b, 512, 9);
        let est = ma.estimate_d(&mb);
        assert!(ma.size_bytes() < 5_000);
        assert!(
            (5_000..20_000).contains(&est),
            "true d=10000, estimate {est}"
        );
    }

    #[test]
    fn minhash_jaccard_of_identical_sets_is_one() {
        let (a, _) = synth::subset_pair(5_000, 0, 6);
        let ma = MinHashEstimator::build(&a, 128, 9);
        assert!((ma.jaccard(&ma) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn strata_serialization_roundtrips_and_still_estimates() {
        let (a, b) = synth::overlap_pair(10_000, 150, 150, 8);
        let mut ea = StrataEstimator::with_shape(24, 32, 5);
        ea.insert_all(&a);
        let mut eb = StrataEstimator::with_shape(24, 32, 5);
        eb.insert_all(&b);
        let want = ea.estimate(&eb);
        let bytes = eb.to_bytes();
        let back = StrataEstimator::from_bytes(&bytes, 5).expect("roundtrip");
        assert!(ea.shape_matches(&back));
        assert_eq!(ea.estimate(&back), want, "estimate must survive the wire");
        // Truncated payloads and trailing garbage must be rejected.
        assert!(StrataEstimator::from_bytes(&bytes[..bytes.len() - 1], 5).is_none());
        let mut garbage = bytes.clone();
        garbage.push(0);
        assert!(StrataEstimator::from_bytes(&garbage, 5).is_none());
    }

    #[test]
    fn strata_columnar_roundtrips_and_shrinks_the_handshake() {
        let (a, b) = synth::overlap_pair(10_000, 150, 150, 8);
        let mut ea = StrataEstimator::with_shape(24, 32, 5);
        ea.insert_all(&a);
        let mut eb = StrataEstimator::with_shape(24, 32, 5);
        eb.insert_all(&b);
        let want = ea.estimate(&eb);
        let legacy = eb.to_bytes();
        let blob = eb.to_columnar_bytes();
        let back = StrataEstimator::from_columnar_bytes(&blob, 5).expect("roundtrip");
        assert!(ea.shape_matches(&back));
        assert_eq!(ea.estimate(&back), want, "estimate must survive the columnar wire");
        // Accounting: the helper recovers the legacy byte count from the blob alone.
        assert_eq!(strata_columnar_legacy_len(&blob), Some(legacy.len()));
        // Strata tables are mostly empty — the columnar form must be much smaller.
        assert!(blob.len() * 2 < legacy.len(), "columnar {} legacy {}", blob.len(), legacy.len());
        // Truncations and trailing garbage are rejected, same posture as the legacy path.
        assert!(StrataEstimator::from_columnar_bytes(&blob[..blob.len() - 1], 5).is_none());
        assert!(StrataEstimator::from_columnar_bytes(&blob[..3], 5).is_none());
        let mut garbage = blob.clone();
        garbage.push(0);
        assert!(StrataEstimator::from_columnar_bytes(&garbage, 5).is_none());
        assert!(strata_columnar_legacy_len(&garbage).is_none());
        assert!(strata_columnar_legacy_len(&[]).is_none());
    }

    #[test]
    fn minhash_bytes_unchanged_by_column_refactor() {
        // `to_bytes` now routes through `Fixed64Col` — the blob must stay byte-identical
        // to the PR 7 hand-rolled layout (varint set_len | varint k | k × 8 B LE mins).
        let (a, _) = synth::overlap_pair(4_000, 500, 500, 13);
        let ma = MinHashEstimator::build(&a, 64, 3);
        let blob = ma.to_bytes();
        let mut legacy = Vec::new();
        put_varint(&mut legacy, ma.set_len as u64);
        put_varint(&mut legacy, ma.mins.len() as u64);
        for m in &ma.mins {
            legacy.extend_from_slice(&m.to_le_bytes());
        }
        assert_eq!(blob, legacy);
    }

    #[test]
    fn minhash_serialization_roundtrips() {
        let (a, b) = synth::overlap_pair(8_000, 2_000, 2_000, 9);
        let ma = MinHashEstimator::build(&a, 256, 3);
        let mb = MinHashEstimator::build(&b, 256, 3);
        let back = MinHashEstimator::from_bytes(&mb.to_bytes()).expect("roundtrip");
        assert_eq!(back.set_len, mb.set_len);
        assert_eq!(ma.estimate_d(&back), ma.estimate_d(&mb));
        assert!(MinHashEstimator::from_bytes(&mb.to_bytes()[..10]).is_none());
    }

    #[test]
    fn directional_estimate_detects_subset() {
        // A ⊆ B: the A-only side must come out exactly zero — the Mode::Auto signal.
        let (a, b) = synth::subset_pair(20_000, 300, 11);
        let mut ea = StrataEstimator::with_shape(24, 32, 7);
        ea.insert_all(&a);
        let mut eb = StrataEstimator::with_shape(24, 32, 7);
        eb.insert_all(&b);
        let (a_only, b_only) = ea.estimate_directional(&eb);
        assert_eq!(a_only, 0, "subset side must estimate zero uniques");
        assert!(b_only >= 100 && b_only <= 900, "true 300, got {b_only}");
        // And a genuinely two-sided difference reports both sides nonzero.
        let (x, y) = synth::overlap_pair(20_000, 200, 200, 12);
        let mut ex = StrataEstimator::with_shape(24, 32, 7);
        ex.insert_all(&x);
        let mut ey = StrataEstimator::with_shape(24, 32, 7);
        ey.insert_all(&y);
        let (x_only, y_only) = ex.estimate_directional(&ey);
        assert!(x_only > 0 && y_only > 0);
    }
}
