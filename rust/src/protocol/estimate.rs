//! The d-estimation handshake (§7.1): "the SDC d is known to all protocols, because it
//! can be handily estimated using min-wise hashing [47], Strata [48], … by sending a few
//! hundred bytes during a handshake step."
//!
//! We implement both referenced estimators so sessions can bootstrap without ground truth:
//!
//! * **Strata estimator** (Eppstein et al. / Flajolet–Martin stratification): 32 strata of
//!   tiny IBLTs; stratum k receives elements whose hash has exactly k *trailing* zero bits
//!   (`stratum_of` uses `trailing_zeros`; the deepest stratum absorbs everything beyond
//!   the stratum count — the geometric law is identical to the leading-zeros convention).
//!   Decode strata from the deepest down; when a stratum's difference IBLT peels, its
//!   count scales by 2^(k+1). A few KB buys a constant-factor estimate of d = |AΔB|.
//! * **Min-wise (MinHash) estimator**: k bottom hashes estimate the Jaccard similarity J;
//!   d ≈ (1−J)/(1+J) · (|A|+|B|). A few hundred bytes; best when d/|A∪B| is not tiny.

use crate::baselines::iblt::{Iblt, IbltParams};
use crate::hash::hash_u64;

/// Strata estimator: `strata` levels × a `cells`-cell IBLT each.
pub struct StrataEstimator {
    pub strata: Vec<Iblt>,
    seed: u64,
}

impl StrataEstimator {
    /// Paper-typical sizing: 32 strata × 80 cells ≈ a few KB.
    pub fn new(seed: u64) -> Self {
        Self::with_shape(32, 80, seed)
    }

    pub fn with_shape(n_strata: usize, cells: usize, seed: u64) -> Self {
        let params = IbltParams { seed: seed ^ 0x57a7a, ..IbltParams::paper_synthetic() };
        StrataEstimator {
            strata: (0..n_strata).map(|_| Iblt::new(cells, params)).collect(),
            seed,
        }
    }

    fn stratum_of(&self, id: u64) -> usize {
        let h = hash_u64(id, self.seed ^ 0x1e7e1);
        (h.trailing_zeros() as usize).min(self.strata.len() - 1)
    }

    pub fn insert_all(&mut self, ids: &[u64]) {
        for &id in ids {
            let s = self.stratum_of(id);
            self.strata[s].insert(id);
        }
    }

    /// Wire size (the handshake cost).
    pub fn size_bytes(&self) -> usize {
        self.strata.iter().map(|t| t.size_bytes()).sum()
    }

    /// Estimate `d = |A Δ B|` from our strata vs the peer's.
    ///
    /// Walk from the deepest stratum down, summing decoded differences; the first stratum
    /// that fails to peel caps the exactly-counted range, and the accumulated count scales
    /// by `2^(k+1)` where `k` is the last decoded level (standard Strata estimation).
    pub fn estimate(&self, theirs: &StrataEstimator) -> usize {
        assert_eq!(self.strata.len(), theirs.strata.len());
        let mut count = 0usize;
        for k in (0..self.strata.len()).rev() {
            match self.strata[k].sub(&theirs.strata[k]).peel() {
                Some((pos, neg)) => count += pos.len() + neg.len(),
                None => {
                    // Everything below level k is unobserved: scale up.
                    return (count << (k + 1)).max(1);
                }
            }
        }
        count.max(1)
    }
}

/// MinHash (bottom-k) estimator of the symmetric difference cardinality.
pub struct MinHashEstimator {
    mins: Vec<u64>,
    pub set_len: usize,
}

impl MinHashEstimator {
    pub fn build(ids: &[u64], k: usize, seed: u64) -> Self {
        // Bottom-k of one hash function (equivalent to k-mins in accuracy class, cheaper).
        let mut hashes: Vec<u64> = ids.iter().map(|&id| hash_u64(id, seed)).collect();
        hashes.sort_unstable();
        hashes.truncate(k);
        MinHashEstimator { mins: hashes, set_len: ids.len() }
    }

    pub fn size_bytes(&self) -> usize {
        8 * self.mins.len() + 8
    }

    /// Jaccard estimate from two bottom-k signatures.
    pub fn jaccard(&self, other: &MinHashEstimator) -> f64 {
        let k = self.mins.len().min(other.mins.len());
        if k == 0 {
            return 1.0;
        }
        // Bottom-k of the union = merge of the two bottom-k lists.
        let mut union: Vec<u64> = self
            .mins
            .iter()
            .chain(&other.mins)
            .copied()
            .collect();
        union.sort_unstable();
        union.dedup();
        union.truncate(k);
        let mine: std::collections::HashSet<u64> = self.mins.iter().copied().collect();
        let theirs: std::collections::HashSet<u64> = other.mins.iter().copied().collect();
        let shared = union
            .iter()
            .filter(|h| mine.contains(h) && theirs.contains(h))
            .count();
        shared as f64 / k as f64
    }

    /// `d̂ = (1−J)/(1+J)·(|A|+|B|)` (from J = |A∩B|/|A∪B| and |A|+|B| = |A∪B|+|A∩B|).
    pub fn estimate_d(&self, other: &MinHashEstimator) -> usize {
        let j = self.jaccard(other).clamp(0.0, 1.0);
        let total = (self.set_len + other.set_len) as f64;
        ((1.0 - j) / (1.0 + j) * total).round().max(0.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn strata_estimates_within_factor_two() {
        for (d, seed) in [(100usize, 1u64), (1_000, 2), (10_000, 3)] {
            let (a, b) = synth::overlap_pair(50_000, d / 2, d - d / 2, seed);
            let mut ea = StrataEstimator::new(7);
            ea.insert_all(&a);
            let mut eb = StrataEstimator::new(7);
            eb.insert_all(&b);
            let est = ea.estimate(&eb);
            assert!(
                est >= d / 3 && est <= d * 3,
                "d={d}: estimate {est} off by more than 3x"
            );
        }
    }

    #[test]
    fn strata_handshake_is_few_kb() {
        let e = StrataEstimator::new(1);
        assert!(e.size_bytes() < 40_000, "{}", e.size_bytes());
    }

    #[test]
    fn strata_identical_sets_estimate_small() {
        let (a, _) = synth::subset_pair(20_000, 0, 4);
        let mut ea = StrataEstimator::new(7);
        ea.insert_all(&a);
        let mut eb = StrataEstimator::new(7);
        eb.insert_all(&a);
        assert!(ea.estimate(&eb) <= 2);
    }

    #[test]
    fn minhash_estimates_large_differences() {
        // MinHash shines when d is a sizable fraction of the union.
        let (a, b) = synth::overlap_pair(20_000, 5_000, 5_000, 5);
        let ma = MinHashEstimator::build(&a, 512, 9);
        let mb = MinHashEstimator::build(&b, 512, 9);
        let est = ma.estimate_d(&mb);
        assert!(ma.size_bytes() < 5_000);
        assert!(
            (5_000..20_000).contains(&est),
            "true d=10000, estimate {est}"
        );
    }

    #[test]
    fn minhash_jaccard_of_identical_sets_is_one() {
        let (a, _) = synth::subset_pair(5_000, 0, 6);
        let ma = MinHashEstimator::build(&a, 128, 9);
        assert!((ma.jaccard(&ma) - 1.0).abs() < 1e-12);
    }
}
