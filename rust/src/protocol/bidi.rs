//! Bidirectional CommonSense (§5): ping-pong decoding with SMF hallucination control.
//!
//! Roles: the **initiator** is the side with the *smaller* estimated unique count (§5.1 —
//! its signal is the weaker noise for the peer's first decode). The initiator sends its
//! truncated sketch; from then on a single canonical residue
//! `r = M(1_{R\I} − 1_{R̂\I}) − M(1_{I\R} − 1_{Î\R})` (Fact 12) alternates between the
//! hosts, each decoding its own signal component (responder = positive side), each message
//! carrying:
//!
//! * the entropy-compressed residue,
//! * an SMF (Bloom filter) of the sender's current estimate set — the receiver's decoder
//!   refuses to *set* SMF-positive coordinates (collision avoidance, §5.2),
//! * a "last inquiry": signatures of SMF-positive coordinates the sender tentatively set
//!   anyway (collision resolution, after it has become confident),
//! * answers to the peer's previous inquiry (`true` = common hallucination → both revert).
//!
//! The session ends when the residue is zero and nothing is outstanding; zero residue plus
//! the disjointness invariant implies both sides' recoveries are exact (§5.1).
//!
//! All of the above lives in the sans-io engine of [`crate::protocol::session`]; this
//! module is the *in-memory frontend*: [`run`] wires an initiator [`Session`] to a
//! responder [`Session`] through [`session::drive`] and packages the outcome. The TCP and
//! partitioned-parallel frontends ([`crate::coordinator`]) consume the identical engine.

use crate::metrics::CommLog;
use crate::protocol::session::{self, Session};
use crate::protocol::CsParams;

// Re-exported so existing callers of the pre-`Session` API keep working.
pub use crate::protocol::session::{
    codec_params, initiator_sketch, responder_residue, seed_round, Peer,
};

/// Tunables of the ping-pong engine.
#[derive(Clone, Copy, Debug)]
pub struct BidiOptions {
    /// Hard cap on ping-pong messages (the paper observes ≤ 10 rounds; Observation 10).
    pub max_rounds: usize,
    /// Round index from which a stalled decoder tentatively sets SMF-positive coordinates
    /// and verifies them via the last inquiry ("when confident", §5.2 option 2).
    pub confident_round: usize,
    /// Target false-positive rate of each per-message SMF.
    pub smf_fpr: f64,
    /// Switch to L1 pursuit (SSMP) when the L2 pursuit stalls.
    pub ssmp_fallback: bool,
    /// Seed for inquiry signatures.
    pub sig_seed: u64,
    /// Tenant namespace stamped into the session `Hello` (0 = the default tenant; the
    /// field is then absent on the wire). Both endpoints must agree — the responder
    /// rejects a `Hello` for a different namespace. Deliberately *not* part of the
    /// config fingerprint: it routes the session, it does not change the protocol.
    pub namespace: u32,
    /// Frame round/sketch payloads through the [`crate::wire::column`] codec (delta,
    /// run-length, and boolean-RLE columns). Off, every frame is byte-identical to the
    /// pre-codec wire format. The `setx` endpoints set this from the handshake
    /// negotiation (both peers must advertise the codec flags bit); here in the raw
    /// engine both sides must simply agree, like `sig_seed`. Not part of the config
    /// fingerprint: it changes the framing, not the protocol's decisions.
    pub codec: bool,
}

impl Default for BidiOptions {
    fn default() -> Self {
        BidiOptions {
            max_rounds: 24,
            confident_round: 3,
            smf_fpr: 0.01,
            ssmp_fallback: true,
            sig_seed: 0x5167_5eed_0f_c0de,
            namespace: 0,
            codec: true,
        }
    }
}

/// Result of a bidirectional run.
#[derive(Clone, Debug)]
pub struct BidiOutcome {
    /// `A \ B` as computed by Alice (sorted).
    pub a_minus_b: Vec<u64>,
    /// `B \ A` as computed by Bob (sorted).
    pub b_minus_a: Vec<u64>,
    /// `A ∩ B` from Alice's perspective (sorted). (Bob's view is `B \ (B\A)`.)
    pub intersection: Vec<u64>,
    pub comm: CommLog,
    /// Ping-pong messages exchanged (incl. the sketch, matching the paper's round counting).
    pub rounds: usize,
    /// The residue reached zero and all inquiries resolved within the round budget.
    pub converged: bool,
}

/// In-memory end-to-end bidirectional run with exact byte accounting.
///
/// `a`/`b` are Alice's and Bob's sets; the initiator is chosen per §5.1. This is a thin
/// adapter: both endpoints are [`Session`]s and [`session::drive`] is the ping-pong.
pub fn run(a: &[u64], b: &[u64], params: &CsParams, opts: BidiOptions) -> BidiOutcome {
    let alice_initiates = params.est_a_unique <= params.est_b_unique;
    let (i_set, r_set) = if alice_initiates { (a, b) } else { (b, a) };

    let (mut initiator, opening) = Session::initiator(params, i_set, opts, alice_initiates);
    let mut responder = Session::responder(r_set, opts, !alice_initiates);
    // A recovery failure (e.g. an undersized sketch) surfaces as a non-converged outcome.
    let converged = session::drive(&mut initiator, &mut responder, opening).unwrap_or(false);

    let i_out = initiator.outcome();
    let r_out = responder.outcome();
    // Either endpoint's transcript is the full conversation; keep the initiator's.
    let comm = initiator.comm().clone();
    // Paper round counting: every protocol message incl. the sketch, excl. the Hello header.
    let rounds = comm.rounds().saturating_sub(1);

    let (a_minus_b, b_minus_a) = if alice_initiates {
        (i_out.unique, r_out.unique)
    } else {
        (r_out.unique, i_out.unique)
    };
    let exclude: std::collections::HashSet<u64> = a_minus_b.iter().copied().collect();
    let mut intersection: Vec<u64> = a.iter().copied().filter(|x| !exclude.contains(x)).collect();
    intersection.sort_unstable();

    BidiOutcome { a_minus_b, b_minus_a, intersection, comm, rounds, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn check_exact(n_common: usize, a_u: usize, b_u: usize, seed: u64) -> BidiOutcome {
        let (a, b) = synth::overlap_pair(n_common, a_u, b_u, seed);
        let params = CsParams::tuned_bidi(n_common + a_u + b_u, a_u, b_u);
        let out = run(&a, &b, &params, BidiOptions::default());
        assert!(out.converged, "did not converge (seed {seed}, {a_u}/{b_u})");
        assert_eq!(out.a_minus_b, synth::difference(&a, &b), "A\\B wrong (seed {seed})");
        assert_eq!(out.b_minus_a, synth::difference(&b, &a), "B\\A wrong (seed {seed})");
        assert_eq!(out.intersection, synth::intersect(&a, &b), "A∩B wrong (seed {seed})");
        out
    }

    #[test]
    fn exact_balanced() {
        let out = check_exact(10_000, 100, 100, 1);
        assert!(out.rounds <= 12, "rounds {}", out.rounds);
    }

    #[test]
    fn exact_skewed_bob_heavy() {
        check_exact(10_000, 50, 400, 2);
    }

    #[test]
    fn exact_skewed_alice_heavy() {
        // |A\B| > |B\A| ⇒ Bob initiates.
        check_exact(10_000, 400, 50, 3);
    }

    #[test]
    fn exact_many_seeds() {
        for seed in 10..20 {
            check_exact(5_000, 60, 60, seed);
        }
    }

    #[test]
    fn uni_degenerate_case_still_works() {
        // A ⊂ B handled by the bidirectional machinery too.
        check_exact(5_000, 0, 120, 4);
    }

    #[test]
    fn codec_ablation_shrinks_wire_bytes() {
        // Same sets, same params, codec on vs off: identical protocol decisions (the
        // codec changes framing only), strictly fewer bytes on the wire, and the codec
        // log's raw-bytes column reproduces the codec-off total exactly.
        let (a, b) = synth::overlap_pair(10_000, 100, 100, 21);
        let params = CsParams::tuned_bidi(10_200, 100, 100);
        let on = run(&a, &b, &params, BidiOptions::default());
        let off = run(&a, &b, &params, BidiOptions { codec: false, ..BidiOptions::default() });
        assert!(on.converged && off.converged);
        assert_eq!(on.a_minus_b, off.a_minus_b);
        assert_eq!(on.b_minus_a, off.b_minus_a);
        assert_eq!(off.comm.total_raw_bytes(), off.comm.total_bytes(), "codec-off: raw == sent");
        assert_eq!(
            on.comm.total_raw_bytes(),
            off.comm.total_bytes(),
            "raw accounting must equal the measured codec-off wire"
        );
        assert!(
            on.comm.total_bytes() < off.comm.total_bytes(),
            "codec on {} must beat codec off {}",
            on.comm.total_bytes(),
            off.comm.total_bytes()
        );
        assert!(on.comm.compression_ratio() < 1.0);
    }

    #[test]
    fn comm_cost_roughly_double_unidirectional() {
        // Observation 10: bidi ≈ 2× uni at the same d.
        let d = 200usize;
        let (a, b) = synth::overlap_pair(20_000, d / 2, d / 2, 5);
        let params = CsParams::tuned_bidi(20_000 + d, d / 2, d / 2);
        let out = run(&a, &b, &params, BidiOptions::default());
        assert!(out.converged);
        let (a2, b2) = synth::subset_pair(20_000, d, 6);
        let p2 = CsParams::tuned_uni(b2.len(), d);
        let uni = crate::protocol::uni::run(&a2, &b2, &p2).unwrap();
        let ratio = out.comm.total_bytes() as f64 / uni.comm.total_bytes() as f64;
        assert!(ratio < 6.0, "bidi/uni cost ratio {ratio}");
    }
}
