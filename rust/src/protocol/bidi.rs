//! Bidirectional CommonSense (§5): ping-pong decoding with SMF hallucination control.
//!
//! Roles: the **initiator** is the side with the *smaller* estimated unique count (§5.1 —
//! its signal is the weaker noise for the peer's first decode). The initiator sends its
//! truncated sketch; from then on a single canonical residue
//! `r = M(1_{R\I} − 1_{R̂\I}) − M(1_{I\R} − 1_{Î\R})` (Fact 12) alternates between the
//! hosts, each decoding its own signal component (responder = positive side), each message
//! carrying:
//!
//! * the entropy-compressed residue,
//! * an SMF (Bloom filter) of the sender's current estimate set — the receiver's decoder
//!   refuses to *set* SMF-positive coordinates (collision avoidance, §5.2),
//! * a "last inquiry": signatures of SMF-positive coordinates the sender tentatively set
//!   anyway (collision resolution, after it has become confident),
//! * answers to the peer's previous inquiry (`true` = common hallucination → both revert).
//!
//! The session ends when the residue is zero and nothing is outstanding; zero residue plus
//! the disjointness invariant implies both sides' recoveries are exact (§5.1).

use crate::decoder::{DecoderConfig, MpDecoder, Pursuit, Side};
use crate::entropy::{compress_residue, compress_sketch, decompress_residue, recover_sketch, SketchCodecParams};
use crate::hash::hash_u64;
use crate::metrics::CommLog;
use crate::protocol::{wire::Msg, CsParams};
use crate::sketch::Sketch;
use crate::smf::BloomFilter;
use std::collections::HashMap;

/// Tunables of the ping-pong loop.
#[derive(Clone, Copy, Debug)]
pub struct BidiOptions {
    /// Hard cap on ping-pong messages (the paper observes ≤ 10 rounds; Observation 10).
    pub max_rounds: usize,
    /// Round index from which a stalled decoder tentatively sets SMF-positive coordinates
    /// and verifies them via the last inquiry ("when confident", §5.2 option 2).
    pub confident_round: usize,
    /// Target false-positive rate of each per-message SMF.
    pub smf_fpr: f64,
    /// Switch to L1 pursuit (SSMP) when the L2 loop stalls.
    pub ssmp_fallback: bool,
    /// Seed for inquiry signatures.
    pub sig_seed: u64,
}

impl Default for BidiOptions {
    fn default() -> Self {
        BidiOptions {
            max_rounds: 24,
            confident_round: 3,
            smf_fpr: 0.01,
            ssmp_fallback: true,
            sig_seed: 0x5167_5eed_0f_c0de,
        }
    }
}

/// Result of a bidirectional run.
#[derive(Clone, Debug)]
pub struct BidiOutcome {
    /// `A \ B` as computed by Alice (sorted).
    pub a_minus_b: Vec<u64>,
    /// `B \ A` as computed by Bob (sorted).
    pub b_minus_a: Vec<u64>,
    /// `A ∩ B` from Alice's perspective (sorted). (Bob's view is `B \ (B\A)`.)
    pub intersection: Vec<u64>,
    pub comm: CommLog,
    /// Ping-pong messages exchanged (incl. the sketch, matching the paper's round counting).
    pub rounds: usize,
    /// The residue reached zero and all inquiries resolved within the round budget.
    pub converged: bool,
}

/// One host's protocol engine, generic over which side it decodes.
pub struct Peer {
    pub decoder: MpDecoder,
    side: Side,
    opts: BidiOptions,
    round: usize,
    /// Tentatively-set ids, in inquiry order, awaiting the peer's answers.
    tentative: Vec<u64>,
    /// sig → id for our current estimate (rebuilt lazily when answering inquiries).
    pub settled: bool,
}

impl Peer {
    pub fn new(params: &CsParams, set: &[u64], side: Side, opts: BidiOptions) -> Self {
        let matrix = params.matrix();
        let mut decoder = MpDecoder::new(&matrix, set, side);
        decoder.set_config(DecoderConfig::commonsense());
        Peer { decoder, side, opts, round: 0, tentative: Vec::new(), settled: false }
    }

    fn sig(&self, id: u64) -> u64 {
        hash_u64(id, self.opts.sig_seed)
    }

    /// Process an incoming round message and produce the reply (or `None` when the session
    /// is complete and the peer needs nothing further).
    pub fn step(&mut self, incoming: &Msg) -> Option<Msg> {
        let Msg::Round { residue, smf, inquiry, answers, done } = incoming else {
            panic!("Peer::step expects Round messages");
        };
        self.round += 1;

        // 1. Adopt the authoritative residue.
        let res = decompress_residue(residue, self.decoder_len()).expect("residue decode");
        self.decoder.load_residue(&res);

        // 2. Resolve our previous tentative updates from the peer's answers.
        //    `true` = common hallucination: the peer also held the element and has already
        //    reverted its copy; we revert ours, leaving the element in the intersection.
        debug_assert!(answers.len() == self.tentative.len() || answers.is_empty());
        for (i, &conflict) in answers.iter().enumerate() {
            if conflict {
                let id = self.tentative[i];
                self.decoder.force(id, false);
            }
        }
        self.tentative.clear();

        // 3. Answer the peer's inquiry; conflicts are our own hallucinations — revert them.
        let mut my_answers = Vec::with_capacity(inquiry.len());
        if !inquiry.is_empty() {
            let mine: HashMap<u64, u64> =
                self.decoder.estimate().iter().map(|&id| (self.sig(id), id)).collect();
            for q in inquiry {
                match mine.get(q) {
                    Some(&id) => {
                        self.decoder.force(id, false);
                        my_answers.push(true);
                    }
                    None => my_answers.push(false),
                }
            }
        }

        // 4. Collision avoidance: refuse to set coordinates in the peer's estimate filter.
        if let Some(bytes) = smf {
            let bloom = BloomFilter::from_bytes(bytes).expect("smf decode");
            self.decoder.set_banned(move |id| bloom.contains(id));
        }

        // 5. Decode.
        let mut stats = self.decoder.run();
        if stats.stalled && self.opts.ssmp_fallback {
            self.decoder.switch_pursuit(Pursuit::L1);
            self.decoder.run();
            self.decoder.switch_pursuit(Pursuit::L2);
            stats = self.decoder.run();
        }
        // Pairwise-local-minimum escape: kick out the most contradicted set coordinate and
        // re-run (bounded; a wrong kick is just noise the next rounds re-correct).
        let mut kicks = 0;
        while stats.stalled && kicks < 4 {
            if self.decoder.kick_worst().is_none() {
                break;
            }
            kicks += 1;
            stats = self.decoder.run();
        }

        // 6. Collision resolution: once confident, tentatively set gated coordinates and
        //    put their signatures up for verification.
        let mut my_inquiry = Vec::new();
        if !stats.converged && self.round >= self.opts.confident_round {
            for id in self.decoder.banned_positive_gain() {
                self.decoder.force(id, true);
                self.tentative.push(id);
                my_inquiry.push(self.sig(id));
            }
        }

        // 7. Termination bookkeeping.
        self.settled =
            self.decoder.residue_is_zero() && self.tentative.is_empty();
        if *done && self.settled && my_answers.is_empty() && my_inquiry.is_empty() {
            // Peer already declared completion and we owe nothing: end without replying.
            return None;
        }

        // 8. Reply: residue + SMF of our estimate (skipped when we're declaring done with
        //    nothing outstanding — the peer only needs the zero residue and our answers).
        let smf_out = if self.settled && my_inquiry.is_empty() {
            None
        } else {
            let est = self.decoder.estimate();
            let mut bloom = BloomFilter::with_fpr(est.len().max(8), self.opts.smf_fpr, self.opts.sig_seed ^ 0xb100_f11e);
            for id in &est {
                bloom.insert(*id);
            }
            Some(bloom.to_bytes())
        };
        Some(Msg::Round {
            residue: compress_residue(&self.decoder.export_residue()),
            smf: smf_out,
            inquiry: my_inquiry,
            answers: my_answers,
            done: self.settled,
        })
    }

    fn decoder_len(&self) -> usize {
        self.decoder.residue_len()
    }

    /// Final estimate (our unique elements), sorted.
    pub fn result(&self) -> Vec<u64> {
        let mut est = self.decoder.estimate();
        est.sort_unstable();
        est
    }
}

/// The truncation-codec parameters as seen from the responder (whose unique count is the
/// positive Skellam component).
pub fn codec_params(params: &CsParams, initiator_is_alice: bool) -> SketchCodecParams {
    let (r_unique, i_unique) = if initiator_is_alice {
        (params.est_b_unique, params.est_a_unique)
    } else {
        (params.est_a_unique, params.est_b_unique)
    };
    SketchCodecParams::derive(r_unique, i_unique, params.l, params.m)
}

/// Initiator helper: the compressed sketch message for `set`.
pub fn initiator_sketch(params: &CsParams, set: &[u64], initiator_is_alice: bool) -> Msg {
    let sketch = Sketch::encode(params.matrix(), set);
    Msg::Sketch(compress_sketch(&sketch.counts, &codec_params(params, initiator_is_alice)))
}

/// Responder helper: recover the initiator's sketch and form the initial canonical
/// residue `r⃗_(1) = M·1_R − M̂·1_I` (responder-positive).
pub fn responder_residue(
    params: &CsParams,
    set: &[u64],
    sketch: &crate::entropy::SketchMsg,
    initiator_is_alice: bool,
) -> Option<Vec<i32>> {
    let my_sketch = Sketch::encode(params.matrix(), set);
    let (x_hat, _, _) =
        recover_sketch(sketch, &my_sketch.counts, &codec_params(params, initiator_is_alice))?;
    Some(my_sketch.counts.iter().zip(&x_hat).map(|(y, x)| y - x).collect())
}

/// The synthetic first Round message that seeds the responder's ping-pong loop.
pub fn seed_round(residue0: &[i32]) -> Msg {
    Msg::Round {
        residue: compress_residue(residue0),
        smf: None,
        inquiry: Vec::new(),
        answers: Vec::new(),
        done: false,
    }
}

/// In-memory end-to-end bidirectional run with exact byte accounting.
///
/// `a`/`b` are Alice's and Bob's sets; the initiator is chosen per §5.1.
pub fn run(a: &[u64], b: &[u64], params: &CsParams, opts: BidiOptions) -> BidiOutcome {
    let mut comm = CommLog::new();
    let alice_initiates = params.est_a_unique <= params.est_b_unique;
    // Initiator I sends the sketch; responder R decodes the positive component.
    let (i_set, r_set) = if alice_initiates { (a, b) } else { (b, a) };

    // Message 1: I's truncated sketch (plus the tiny Hello header).
    let hello = Msg::Hello {
        l: params.l,
        m: params.m,
        seed: params.seed,
        universe_bits: params.universe_bits,
        est_initiator_unique: if alice_initiates { params.est_a_unique } else { params.est_b_unique } as u64,
        est_responder_unique: if alice_initiates { params.est_b_unique } else { params.est_a_unique } as u64,
        set_len: i_set.len() as u64,
    };
    comm.record(alice_initiates, "hello", hello.to_bytes().len());

    let sketch_msg = initiator_sketch(params, i_set, alice_initiates);
    comm.record(alice_initiates, "sketch", sketch_msg.to_bytes().len());

    // Responder reconstructs the sketch and forms the canonical residue.
    let Msg::Sketch(ref sm) = sketch_msg else { unreachable!() };
    let residue0 = responder_residue(params, r_set, sm, alice_initiates).expect("sketch recovery");

    let mut responder = Peer::new(params, r_set, Side::Positive, opts);
    let mut initiator = Peer::new(params, i_set, Side::Negative, opts);

    // Seed the ping-pong: hand the responder the initial residue as a synthetic round.
    let mut in_flight = Some(seed_round(&residue0));
    let mut responder_turn = true;
    let mut rounds = 1usize; // the sketch message
    let mut converged = false;

    while let Some(msg) = in_flight.take() {
        if rounds > opts.max_rounds {
            break;
        }
        let (peer, from_alice) = if responder_turn {
            (&mut responder, !alice_initiates)
        } else {
            (&mut initiator, alice_initiates)
        };
        let reply = peer.step(&msg);
        match reply {
            Some(reply) => {
                comm.record(from_alice, "round", reply.to_bytes().len());
                rounds += 1;
                in_flight = Some(reply);
            }
            None => {
                converged = true;
            }
        }
        responder_turn = !responder_turn;
    }
    if !converged {
        // Round budget exhausted: report the current state (callers treat as failure).
        converged = responder.settled && initiator.settled;
    }

    let (a_minus_b, b_minus_a) = if alice_initiates {
        (initiator.result(), responder.result())
    } else {
        (responder.result(), initiator.result())
    };
    let exclude: std::collections::HashSet<u64> = a_minus_b.iter().copied().collect();
    let mut intersection: Vec<u64> = a.iter().copied().filter(|x| !exclude.contains(x)).collect();
    intersection.sort_unstable();

    BidiOutcome { a_minus_b, b_minus_a, intersection, comm, rounds, converged }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn check_exact(n_common: usize, a_u: usize, b_u: usize, seed: u64) -> BidiOutcome {
        let (a, b) = synth::overlap_pair(n_common, a_u, b_u, seed);
        let params = CsParams::tuned_bidi(n_common + a_u + b_u, a_u, b_u);
        let out = run(&a, &b, &params, BidiOptions::default());
        assert!(out.converged, "did not converge (seed {seed}, {a_u}/{b_u})");
        assert_eq!(out.a_minus_b, synth::difference(&a, &b), "A\\B wrong (seed {seed})");
        assert_eq!(out.b_minus_a, synth::difference(&b, &a), "B\\A wrong (seed {seed})");
        assert_eq!(out.intersection, synth::intersect(&a, &b), "A∩B wrong (seed {seed})");
        out
    }

    #[test]
    fn exact_balanced() {
        let out = check_exact(10_000, 100, 100, 1);
        assert!(out.rounds <= 12, "rounds {}", out.rounds);
    }

    #[test]
    fn exact_skewed_bob_heavy() {
        check_exact(10_000, 50, 400, 2);
    }

    #[test]
    fn exact_skewed_alice_heavy() {
        // |A\B| > |B\A| ⇒ Bob initiates.
        check_exact(10_000, 400, 50, 3);
    }

    #[test]
    fn exact_many_seeds() {
        for seed in 10..20 {
            check_exact(5_000, 60, 60, seed);
        }
    }

    #[test]
    fn uni_degenerate_case_still_works() {
        // A ⊂ B handled by the bidirectional machinery too.
        check_exact(5_000, 0, 120, 4);
    }

    #[test]
    fn comm_cost_roughly_double_unidirectional() {
        // Observation 10: bidi ≈ 2× uni at the same d.
        let d = 200usize;
        let (a, b) = synth::overlap_pair(20_000, d / 2, d / 2, 5);
        let params = CsParams::tuned_bidi(20_000 + d, d / 2, d / 2);
        let out = run(&a, &b, &params, BidiOptions::default());
        assert!(out.converged);
        let (a2, b2) = synth::subset_pair(20_000, d, 6);
        let p2 = CsParams::tuned_uni(b2.len(), d);
        let uni = crate::protocol::uni::run(&a2, &b2, &p2).unwrap();
        let ratio = out.comm.total_bytes() as f64 / uni.comm.total_bytes() as f64;
        assert!(ratio < 6.0, "bidi/uni cost ratio {ratio}");
    }
}
