//! The sans-io protocol engine: one `Session` state machine drives every transport.
//!
//! [`Session`] owns a complete bidirectional CommonSense endpoint — the `Hello` handshake,
//! the sketch exchange, and the §5 ping-pong decode ([`Peer`]) — as a pure
//! message-in/message-out state machine with built-in byte accounting. Transports stay
//! "sans io": they move opaque [`Msg`] frames and never touch protocol state. Three
//! frontends consume the same core:
//!
//! * [`crate::protocol::bidi::run`] — the in-memory driver ([`drive`] below is the single
//!   ping-pong drive loop in the codebase);
//! * [`crate::coordinator::tcp`] — socket framing only;
//! * [`crate::coordinator::parallel`] — a bounded worker pool of in-memory drives.
//!
//! ```text
//! initiator                                    responder
//! Session::initiator() ── Hello, Sketch ────▶  Session::responder()
//!           ◀────────────── Round ──────────── on_msg → Reply
//! on_msg → Reply ─────────── Round ──────────▶ …
//!           …                                  on_msg → Done(outcome)
//! ```
//!
//! Every frame the session emits or absorbs is charged to its [`CommLog`] at its exact
//! wire size, so all frontends report identical communication costs by construction.

use crate::decoder::{run_with_fallback, DecoderCache, DecoderConfig, MpDecoder, Side};
use crate::entropy::{
    compress_residue, compress_sketch, decompress_residue, recover_sketch, SketchCodecParams,
};
use crate::hash::hash_u64;
use crate::metrics::{CommLog, Phase as CommPhase};
use crate::obs::{SessionTrace, SpanKind, Tracer};
use crate::protocol::bidi::BidiOptions;
use crate::protocol::{wire::Msg, CsParams};
use crate::sketch::{EncodeConfig, Sketch};
use crate::smf::BloomFilter;
use std::collections::HashMap;
use std::sync::Arc;

/// Terminal protocol faults. Any error closes the session: the frame stream is not
/// trustworthy past the first malformed or out-of-phase message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SessionError {
    /// A frame arrived that the current phase cannot accept.
    UnexpectedMessage { phase: &'static str, got: &'static str },
    /// The initiator's truncated sketch failed recovery against our counts.
    SketchRecovery,
    /// A round frame carried an undecodable field.
    Corrupt(&'static str),
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SessionError::UnexpectedMessage { phase, got } => {
                write!(f, "unexpected {got} frame in {phase} phase")
            }
            SessionError::SketchRecovery => write!(f, "sketch recovery failed"),
            SessionError::Corrupt(what) => write!(f, "corrupt {what} field in round frame"),
        }
    }
}

impl std::error::Error for SessionError {}

/// Which end of the handshake this endpoint plays (§5.1: the initiator is the side with
/// the smaller estimated unique count).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Initiator,
    Responder,
}

/// What the state machine wants the transport to do after absorbing a frame.
#[derive(Clone, Debug)]
pub enum SessionEvent {
    /// Transmit this frame, then feed the peer's next frame back in.
    Reply(Msg),
    /// Nothing owed yet; feed the peer's next frame (handshake phases).
    Continue,
    /// Protocol complete — transmit nothing further and tear down the transport.
    Done(SessionOutcome),
}

/// Final (or, on disconnect, current) state of one endpoint.
#[derive(Clone, Debug)]
pub struct SessionOutcome {
    /// This host's recovered unique elements, sorted.
    pub unique: Vec<u64>,
    /// The residue reached zero with nothing outstanding.
    pub converged: bool,
}

enum Phase {
    /// Responder: waiting for the initiator's `Hello`.
    AwaitHello,
    /// Responder: parameters agreed, waiting for the initiator's sketch.
    AwaitSketch(CsParams),
    /// Both roles: the §5 ping-pong decode.
    PingPong(Peer),
    /// Terminal (only reached through an error).
    Closed,
}

fn phase_name(phase: &Phase) -> &'static str {
    match phase {
        Phase::AwaitHello => "await-hello",
        Phase::AwaitSketch(_) => "await-sketch",
        Phase::PingPong(_) => "ping-pong",
        Phase::Closed => "closed",
    }
}

pub(crate) fn label(msg: &Msg) -> &'static str {
    match msg {
        Msg::EstHello { .. } => "est-hello",
        Msg::Hello { .. } => "hello",
        Msg::Sketch { .. } => "sketch",
        Msg::Round { .. } => "round",
        Msg::Confirm { .. } => "confirm",
        Msg::Busy { .. } => "busy",
        Msg::AggSketch { .. } => "agg-sketch",
        Msg::MultiResidue { .. } => "multi-residue",
    }
}

/// Which accounting phase each frame belongs to (shared by every transport and the
/// `Setx` facade, so per-phase breakdowns agree by construction).
pub fn frame_phase(msg: &Msg) -> CommPhase {
    match msg {
        Msg::EstHello { .. } | Msg::Hello { .. } | Msg::Busy { .. } => CommPhase::Handshake,
        Msg::Sketch { .. } | Msg::AggSketch { .. } => CommPhase::Sketch,
        Msg::Round { .. } | Msg::MultiResidue { .. } => CommPhase::Residue,
        Msg::Confirm { .. } => CommPhase::Confirm,
    }
}

/// A sans-io bidirectional CommonSense endpoint.
pub struct Session {
    role: Role,
    opts: BidiOptions,
    /// Whether this endpoint is "Alice" for [`CommLog`] direction labeling.
    is_alice: bool,
    /// The responder holds its set until the `Hello` fixes the shared parameters.
    set: Vec<u64>,
    phase: Phase,
    comm: CommLog,
    /// Decoder reuse slot (see [`DecoderCache`]): consulted when this session builds its
    /// decoder, refilled by [`Session::into_parts`] when the session ends, so callers
    /// that keep the cache across attempts/conversations skip identical rebuilds.
    cache: DecoderCache,
    /// Encode-side parallelism for this session's own-set sketch (see [`EncodeConfig`];
    /// local knob, no wire impact).
    enc: EncodeConfig,
    /// A pre-resolved sketch of this endpoint's set (e.g. checked out of a server's
    /// host-sketch store) consumed when the initiator's sketch arrives; matrix-validated
    /// before use and ignored on mismatch, so a wrong hint degrades to a re-encode, never
    /// to a wrong residue.
    host_sketch: Option<Arc<Sketch>>,
    /// Timeline recorder (see [`crate::obs`]): `SketchEncode`/`DecoderBuild` spans around
    /// the two expensive local steps, plus one instant `Round`/`Confirm` marker per
    /// payload/verdict frame — emitted at the [`CommLog`] recording points, so marker
    /// counts equal frame counts by construction.
    tracer: Tracer,
}

impl Session {
    /// Open a session as the initiator. Returns the engine plus the opening frames
    /// (`Hello` then `Sketch`) the transport must deliver before the first `on_msg`.
    pub fn initiator(
        params: &CsParams,
        set: &[u64],
        opts: BidiOptions,
        is_alice: bool,
    ) -> (Session, Vec<Msg>) {
        Self::initiator_cached(params, set, opts, is_alice, DecoderCache::new())
    }

    /// [`Session::initiator`] with a caller-provided decoder-reuse cache: when the cache
    /// holds a decoder for the same (matrix, set, side) — e.g. a repeat conversation or a
    /// ladder attempt that kept the matrix — construction is skipped via
    /// [`MpDecoder::reset_signal`]. Recover the cache with [`Session::into_parts`].
    pub fn initiator_cached(
        params: &CsParams,
        set: &[u64],
        opts: BidiOptions,
        is_alice: bool,
        cache: DecoderCache,
    ) -> (Session, Vec<Msg>) {
        Self::initiator_with(params, set, opts, is_alice, cache, EncodeConfig::default(), None)
    }

    /// [`Session::initiator_cached`] with the encode-side knobs: `enc` parallelizes the
    /// opening sketch encode, and `host_sketch` (when it matches the attempt's matrix —
    /// validated, ignored otherwise) skips that encode entirely, e.g. when a server-side
    /// initiator checks its host set's sketch out of a shared store.
    pub fn initiator_with(
        params: &CsParams,
        set: &[u64],
        opts: BidiOptions,
        is_alice: bool,
        cache: DecoderCache,
        enc: EncodeConfig,
        host_sketch: Option<&Sketch>,
    ) -> (Session, Vec<Msg>) {
        Self::initiator_traced(params, set, opts, is_alice, cache, enc, host_sketch, Tracer::new())
    }

    /// [`Session::initiator_with`] recording into a caller-provided [`Tracer`] (e.g. a
    /// [`Tracer::child`] of an endpoint's timeline, or a [`Tracer::disabled`] one for the
    /// obs-off ablation). The constructor itself does the sketch encode and decoder
    /// build, so the tracer must arrive before construction to time them.
    #[allow(clippy::too_many_arguments)]
    pub fn initiator_traced(
        params: &CsParams,
        set: &[u64],
        opts: BidiOptions,
        is_alice: bool,
        mut cache: DecoderCache,
        enc: EncodeConfig,
        host_sketch: Option<&Sketch>,
        mut tracer: Tracer,
    ) -> (Session, Vec<Msg>) {
        let (est_i, est_r) = if is_alice {
            (params.est_a_unique, params.est_b_unique)
        } else {
            (params.est_b_unique, params.est_a_unique)
        };
        let hello = Msg::Hello {
            l: params.l,
            m: params.m,
            seed: params.seed,
            universe_bits: params.universe_bits,
            est_initiator_unique: est_i as u64,
            est_responder_unique: est_r as u64,
            set_len: set.len() as u64,
            namespace: opts.namespace,
        };
        tracer.open(SpanKind::SketchEncode);
        let sketch = match host_sketch.filter(|sk| sk.matrix == params.matrix()) {
            Some(sk) => sketch_msg(params, &sk.counts, is_alice, opts.codec),
            None => initiator_sketch_with(params, set, is_alice, enc, opts.codec),
        };
        tracer.close(SpanKind::SketchEncode);
        tracer.open(SpanKind::DecoderBuild);
        let peer = Peer::with_cache(params, set, Side::Negative, opts, &mut cache);
        tracer.close(SpanKind::DecoderBuild);
        let mut session = Session {
            role: Role::Initiator,
            opts,
            is_alice,
            set: Vec::new(),
            phase: Phase::PingPong(peer),
            comm: CommLog::new(),
            cache,
            enc,
            host_sketch: None,
            tracer,
        };
        session.record_sent(&hello);
        session.record_sent(&sketch);
        (session, vec![hello, sketch])
    }

    /// Open a session as the responder. Every protocol parameter is learned from the
    /// initiator's `Hello`; only the local set and options are needed up front.
    pub fn responder(set: &[u64], opts: BidiOptions, is_alice: bool) -> Session {
        Self::responder_cached(set, opts, is_alice, DecoderCache::new())
    }

    /// [`Session::responder`] with a decoder-reuse cache (see
    /// [`Session::initiator_cached`]); the responder consults it when the initiator's
    /// sketch arrives and its decoder is built.
    pub fn responder_cached(
        set: &[u64],
        opts: BidiOptions,
        is_alice: bool,
        cache: DecoderCache,
    ) -> Session {
        Session {
            role: Role::Responder,
            opts,
            is_alice,
            set: set.to_vec(),
            phase: Phase::AwaitHello,
            comm: CommLog::new(),
            cache,
            enc: EncodeConfig::default(),
            host_sketch: None,
            tracer: Tracer::new(),
        }
    }

    /// Set the encode-side parallelism for this session's own-set sketch work (drivers
    /// that already run many sessions in parallel pin [`EncodeConfig::serial`]).
    pub fn set_encode_config(&mut self, enc: EncodeConfig) {
        self.enc = enc;
    }

    /// Hand the responder a pre-resolved sketch of its own set (e.g. from a shared
    /// host-sketch store) to use instead of re-encoding when the initiator's sketch
    /// arrives. Matrix-validated at use: a sketch for a different matrix is ignored.
    pub fn set_host_sketch(&mut self, sketch: Arc<Sketch>) {
        self.host_sketch = Some(sketch);
    }

    /// Replace this session's timeline recorder (e.g. with a [`Tracer::child`] of the
    /// driving endpoint's tracer, so the absorbed trace shares one clock). Responder
    /// sessions do their expensive work after construction, so a tracer set here still
    /// times everything; for the initiator use [`Session::initiator_traced`].
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Decompose a finished (or abandoned) session into its transcript, outcome
    /// snapshot, decoder cache, and recorded timeline — with the session's constructed
    /// decoder parked in the cache so the next same-matrix session reuses it instead of
    /// rebuilding.
    pub fn into_parts(self) -> (CommLog, SessionOutcome, DecoderCache, SessionTrace) {
        let Session { phase, comm, mut cache, mut tracer, .. } = self;
        let outcome = match phase {
            Phase::PingPong(peer) => {
                let outcome = SessionOutcome { unique: peer.result(), converged: peer.settled };
                cache.store(peer.into_decoder());
                outcome
            }
            _ => SessionOutcome { unique: Vec::new(), converged: false },
        };
        (comm, outcome, cache, tracer.take())
    }

    /// Absorb one incoming frame and report what the transport should do next.
    ///
    /// Errors are terminal: the session moves to a closed phase and rejects all further
    /// frames (malformed peers don't get retries).
    pub fn on_msg(&mut self, incoming: &Msg) -> Result<SessionEvent, SessionError> {
        self.record_received(incoming);
        match (std::mem::replace(&mut self.phase, Phase::Closed), incoming) {
            (Phase::AwaitHello, Msg::Hello { l, m, seed, universe_bits, est_initiator_unique, est_responder_unique, namespace, .. }) => {
                // Adversarial-geometry hardening: reject rather than panic on a `Hello`
                // whose (l, m) no ColumnSampler would accept (the m ≤ MAX_M stack-buffer
                // invariant), or whose row count would drive a giant allocation.
                if !crate::protocol::wire_geometry_ok(*l, *m, *seed) {
                    return Err(SessionError::Corrupt("hello geometry"));
                }
                // Tenant routing happens before the session opens (the server picks the
                // host set from the EstHello namespace); a session-level Hello for a
                // *different* namespace means the peer is confused about which resident
                // set it is reconciling against — terminal, like any other bad frame.
                if *namespace != self.opts.namespace {
                    return Err(SessionError::Corrupt("hello namespace"));
                }
                // Reconstruct the shared parameter view with the initiator in the "a"
                // slot (`initiator_is_alice = true` keeps the codec orientation fixed
                // regardless of which real host initiated).
                let params = CsParams {
                    l: *l,
                    m: *m,
                    seed: *seed,
                    universe_bits: *universe_bits,
                    est_a_unique: *est_initiator_unique as usize,
                    est_b_unique: *est_responder_unique as usize,
                };
                self.phase = Phase::AwaitSketch(params);
                Ok(SessionEvent::Continue)
            }
            (Phase::AwaitSketch(params), Msg::Sketch { sketch: sm, .. }) => {
                // The decoder copies the candidate ids; release our buffer with it.
                let set = std::mem::take(&mut self.set);
                let host = self.host_sketch.take();
                self.tracer.open(SpanKind::SketchEncode);
                let residue0 =
                    responder_residue_with(&params, &set, sm, true, host.as_deref(), self.enc);
                // Close before the `?` so a failed recovery still leaves the trace
                // balanced.
                self.tracer.close(SpanKind::SketchEncode);
                let residue0 = residue0.ok_or(SessionError::SketchRecovery)?;
                let opts = self.opts;
                self.tracer.open(SpanKind::DecoderBuild);
                let mut peer =
                    Peer::with_cache(&params, &set, Side::Positive, opts, &mut self.cache);
                self.tracer.close(SpanKind::DecoderBuild);
                // The initial canonical residue enters the engine as a synthetic round:
                // it is not a transmitted frame, so it is not charged to the comm log.
                let reply = peer.step(&seed_round(&residue0))?;
                self.phase = Phase::PingPong(peer);
                Ok(self.dispatch(reply))
            }
            (Phase::PingPong(mut peer), Msg::Round { .. }) => {
                if self.non_hello_msgs() > self.opts.max_rounds {
                    // Round budget exhausted (Observation 10 says ≤ 10 in practice):
                    // stop replying; both sides report their current state.
                    self.phase = Phase::PingPong(peer);
                    return Ok(SessionEvent::Done(self.outcome()));
                }
                let reply = peer.step(incoming)?;
                self.phase = Phase::PingPong(peer);
                Ok(self.dispatch(reply))
            }
            (phase, _) => Err(SessionError::UnexpectedMessage {
                phase: phase_name(&phase),
                got: label(incoming),
            }),
        }
    }

    fn dispatch(&mut self, reply: Option<Msg>) -> SessionEvent {
        match reply {
            Some(msg) => {
                self.record_sent(&msg);
                SessionEvent::Reply(msg)
            }
            None => SessionEvent::Done(self.outcome()),
        }
    }

    fn record_sent(&mut self, msg: &Msg) {
        let (enc, raw) = (msg.wire_len(), msg.raw_wire_len());
        let phase = frame_phase(msg);
        self.comm.record_framed(self.is_alice, phase, enc, raw);
        self.mark_frame(phase);
    }

    fn record_received(&mut self, msg: &Msg) {
        let (enc, raw) = (msg.wire_len(), msg.raw_wire_len());
        let phase = frame_phase(msg);
        self.comm.record_framed(!self.is_alice, phase, enc, raw);
        self.mark_frame(phase);
    }

    /// One instant trace marker per accounted frame, emitted at the single point every
    /// frame passes through — so `Round` markers equal `CommLog::payload_frames` (and
    /// hence `SetxReport::rounds`) by construction, not by convention.
    fn mark_frame(&mut self, phase: CommPhase) {
        if phase.is_payload() {
            self.tracer.instant(SpanKind::Round);
        } else if phase == CommPhase::Confirm {
            self.tracer.instant(SpanKind::Confirm);
        }
    }

    /// Messages seen so far that count against the round budget (everything but the
    /// handshake headers).
    fn non_hello_msgs(&self) -> usize {
        self.comm.entries.iter().filter(|e| e.phase != CommPhase::Handshake).count()
    }

    pub fn role(&self) -> Role {
        self.role
    }

    /// Full session transcript: every frame sent *and* received, at exact wire sizes.
    /// Both endpoints of a session record identical totals.
    pub fn comm(&self) -> &CommLog {
        &self.comm
    }

    pub fn bytes_sent(&self) -> usize {
        self.direction_bytes(true)
    }

    pub fn bytes_received(&self) -> usize {
        self.direction_bytes(false)
    }

    fn direction_bytes(&self, sent: bool) -> usize {
        self.comm
            .entries
            .iter()
            .filter(|e| (e.from_alice == self.is_alice) == sent)
            .map(|e| e.bytes)
            .sum()
    }

    pub fn msgs_sent(&self) -> usize {
        self.comm.entries.iter().filter(|e| e.from_alice == self.is_alice).count()
    }

    /// Residue at zero with no outstanding inquiries (the §5.1 termination condition).
    pub fn is_settled(&self) -> bool {
        matches!(&self.phase, Phase::PingPong(peer) if peer.settled)
    }

    /// Snapshot of this endpoint's result — also valid mid-session (a transport calls
    /// this after a peer disconnect to report whatever state was reached).
    pub fn outcome(&self) -> SessionOutcome {
        match &self.phase {
            Phase::PingPong(peer) => {
                SessionOutcome { unique: peer.result(), converged: peer.settled }
            }
            _ => SessionOutcome { unique: Vec::new(), converged: false },
        }
    }
}

/// Drive an initiator/responder pair in memory to completion — **the** ping-pong drive
/// loop every frontend shares (TCP swaps the in-memory hand-off for socket reads/writes;
/// the parallel coordinator runs many of these on a bounded pool). Returns whether both
/// endpoints settled.
pub fn drive(
    initiator: &mut Session,
    responder: &mut Session,
    opening: Vec<Msg>,
) -> Result<bool, SessionError> {
    // Deliver the opening frames (`Hello`, `Sketch`); the responder's first decode seeds
    // the ping-pong.
    let mut in_flight: Option<(Msg, bool)> = None;
    for msg in &opening {
        match responder.on_msg(msg)? {
            SessionEvent::Continue => {}
            SessionEvent::Reply(reply) => in_flight = Some((reply, false)),
            SessionEvent::Done(_) => {}
        }
    }
    // Alternate until a side completes (`Done`) or the round budget trips.
    while let Some((msg, to_responder)) = in_flight.take() {
        let dst: &mut Session = if to_responder { &mut *responder } else { &mut *initiator };
        match dst.on_msg(&msg)? {
            SessionEvent::Reply(reply) => in_flight = Some((reply, !to_responder)),
            SessionEvent::Continue => {}
            SessionEvent::Done(_) => {}
        }
    }
    Ok(initiator.is_settled() && responder.is_settled())
}

/// One host's ping-pong engine, generic over which side it decodes.
///
/// `Peer` is the pure §5 round logic (decode, SMF gating, inquiries, answers); `Session`
/// wraps it with the handshake phases and accounting. It is exposed for tests and for
/// building custom drivers, but transports should consume [`Session`].
pub struct Peer {
    pub decoder: MpDecoder,
    opts: BidiOptions,
    round: usize,
    /// Tentatively-set ids, in inquiry order, awaiting the peer's answers.
    tentative: Vec<u64>,
    /// Residue at zero and nothing outstanding.
    pub settled: bool,
}

impl Peer {
    pub fn new(params: &CsParams, set: &[u64], side: Side, opts: BidiOptions) -> Self {
        Self::with_cache(params, set, side, opts, &mut DecoderCache::new())
    }

    /// [`Peer::new`] consulting a [`DecoderCache`] first: when the cache holds a decoder
    /// for exactly this (matrix, set, side) it is reset and reused — bidi rounds and
    /// ladder attempts that keep the same matrix skip the dominant CSR rebuild. Recover
    /// the decoder for the cache with [`Peer::into_decoder`].
    pub fn with_cache(
        params: &CsParams,
        set: &[u64],
        side: Side,
        opts: BidiOptions,
        cache: &mut DecoderCache,
    ) -> Self {
        let matrix = params.matrix();
        let decoder = cache.checkout(&matrix, set, side, DecoderConfig::commonsense());
        Peer { decoder, opts, round: 0, tentative: Vec::new(), settled: false }
    }

    /// Surrender the constructed decoder (for parking in a [`DecoderCache`]).
    pub fn into_decoder(self) -> MpDecoder {
        self.decoder
    }

    fn sig(&self, id: u64) -> u64 {
        hash_u64(id, self.opts.sig_seed)
    }

    /// Process an incoming round message and produce the reply (or `None` when the
    /// session is complete and the peer needs nothing further).
    pub fn step(&mut self, incoming: &Msg) -> Result<Option<Msg>, SessionError> {
        let Msg::Round { residue, smf, inquiry, answers, done, codec } = incoming else {
            return Err(SessionError::UnexpectedMessage {
                phase: "ping-pong",
                got: label(incoming),
            });
        };
        self.round += 1;

        // 1. Adopt the authoritative residue.
        let res = decompress_residue(residue, self.decoder.residue_len())
            .ok_or(SessionError::Corrupt("residue"))?;
        self.decoder.load_residue(&res);

        // 2. Resolve our previous tentative updates from the peer's answers.
        //    `true` = common hallucination: the peer also held the element and has
        //    already reverted its copy; we revert ours, leaving the element in the
        //    intersection. (Zip: excess answers from a malformed peer are ignored.)
        for (&conflict, &id) in answers.iter().zip(&self.tentative) {
            if conflict {
                self.decoder.force(id, false);
            }
        }
        self.tentative.clear();

        // 3. Answer the peer's inquiry; conflicts are our own hallucinations — revert.
        let mut my_answers = Vec::with_capacity(inquiry.len());
        if !inquiry.is_empty() {
            let mine: HashMap<u64, u64> =
                self.decoder.estimate().iter().map(|&id| (self.sig(id), id)).collect();
            for q in inquiry {
                match mine.get(q) {
                    Some(&id) => {
                        self.decoder.force(id, false);
                        my_answers.push(true);
                    }
                    None => my_answers.push(false),
                }
            }
        }

        // 4. Collision avoidance: refuse to set coordinates in the peer's estimate SMF.
        //    The frame's own codec flag picks the filter layout — codec-on peers ship
        //    the boolean-RLE form, codec-off peers the PR-7 flat bytes.
        if let Some(bytes) = smf {
            let bloom = if *codec {
                BloomFilter::from_codec_bytes(bytes)
            } else {
                BloomFilter::from_bytes(bytes)
            }
            .ok_or(SessionError::Corrupt("smf"))?;
            self.decoder.set_banned(move |id| bloom.contains(id));
        }

        // 5. Decode, with the shared §3.4 escalation ladder (L1 fallback + local-minimum
        //    kicks; a wrong kick is just noise the next rounds re-correct).
        let (stats, _) = run_with_fallback(&mut self.decoder, self.opts.ssmp_fallback, 4);

        // 6. Collision resolution: once confident, tentatively set gated coordinates and
        //    put their signatures up for verification.
        let mut my_inquiry = Vec::new();
        if !stats.converged && self.round >= self.opts.confident_round {
            for id in self.decoder.banned_positive_gain() {
                self.decoder.force(id, true);
                self.tentative.push(id);
                my_inquiry.push(self.sig(id));
            }
        }

        // 7. Termination bookkeeping.
        self.settled = self.decoder.residue_is_zero() && self.tentative.is_empty();
        if *done && self.settled && my_answers.is_empty() && my_inquiry.is_empty() {
            // Peer already declared completion and we owe nothing: end without replying.
            return Ok(None);
        }

        // 8. Reply: residue + SMF of our estimate (skipped when we're declaring done with
        //    nothing outstanding — the peer only needs the zero residue and our answers).
        let smf_out = if self.settled && my_inquiry.is_empty() {
            None
        } else {
            let est = self.decoder.estimate();
            let mut bloom = BloomFilter::with_fpr(
                est.len().max(8),
                self.opts.smf_fpr,
                self.opts.sig_seed ^ 0xb100_f11e,
            );
            for id in &est {
                bloom.insert(*id);
            }
            Some(if self.opts.codec { bloom.to_codec_bytes() } else { bloom.to_bytes() })
        };
        Ok(Some(Msg::Round {
            residue: compress_residue(&self.decoder.export_residue()),
            smf: smf_out,
            inquiry: my_inquiry,
            answers: my_answers,
            done: self.settled,
            codec: self.opts.codec,
        }))
    }

    /// Final estimate (our unique elements), sorted.
    pub fn result(&self) -> Vec<u64> {
        let mut est = self.decoder.estimate();
        est.sort_unstable();
        est
    }
}

/// The truncation-codec parameters as seen from the responder (whose unique count is the
/// positive Skellam component).
pub fn codec_params(params: &CsParams, initiator_is_alice: bool) -> SketchCodecParams {
    let (r_unique, i_unique) = if initiator_is_alice {
        (params.est_b_unique, params.est_a_unique)
    } else {
        (params.est_a_unique, params.est_b_unique)
    };
    SketchCodecParams::derive(r_unique, i_unique, params.l, params.m)
}

/// Initiator helper: the compressed sketch message for `set` (serial encode, codec-off
/// framing; the session paths use [`initiator_sketch_with`]).
pub fn initiator_sketch(params: &CsParams, set: &[u64], initiator_is_alice: bool) -> Msg {
    initiator_sketch_with(params, set, initiator_is_alice, EncodeConfig::serial(), false)
}

/// [`initiator_sketch`] with an [`EncodeConfig`] — the sketch encode, the initiator's
/// dominant local cost at large |set|, runs on the bounded encode pool — and the
/// negotiated `codec` framing flag.
pub fn initiator_sketch_with(
    params: &CsParams,
    set: &[u64],
    initiator_is_alice: bool,
    enc: EncodeConfig,
    codec: bool,
) -> Msg {
    let sketch = Sketch::encode_par(params.matrix(), set, enc);
    sketch_msg(params, &sketch.counts, initiator_is_alice, codec)
}

/// Compress already-encoded sketch counts into the wire frame.
fn sketch_msg(params: &CsParams, counts: &[i32], initiator_is_alice: bool, codec: bool) -> Msg {
    let sketch = compress_sketch(counts, &codec_params(params, initiator_is_alice));
    Msg::Sketch { sketch, codec }
}

/// Responder helper: recover the initiator's sketch and form the initial canonical
/// residue `r⃗_(1) = M·1_R − M̂·1_I` (responder-positive). Serial self-encode; the
/// session paths use [`responder_residue_with`].
pub fn responder_residue(
    params: &CsParams,
    set: &[u64],
    sketch: &crate::entropy::SketchMsg,
    initiator_is_alice: bool,
) -> Option<Vec<i32>> {
    responder_residue_with(params, set, sketch, initiator_is_alice, None, EncodeConfig::serial())
}

/// [`responder_residue`] with the encode-side knobs: when `host` holds a pre-resolved
/// sketch of `set` under exactly `params.matrix()` (validated here) the O(m·|set|)
/// self-encode is skipped entirely — the server host-sketch-store fast path; otherwise
/// the encode runs under `enc`.
pub fn responder_residue_with(
    params: &CsParams,
    set: &[u64],
    sketch: &crate::entropy::SketchMsg,
    initiator_is_alice: bool,
    host: Option<&Sketch>,
    enc: EncodeConfig,
) -> Option<Vec<i32>> {
    let owned;
    let my_sketch = match host.filter(|sk| sk.matrix == params.matrix()) {
        Some(sk) => sk,
        None => {
            owned = Sketch::encode_par(params.matrix(), set, enc);
            &owned
        }
    };
    if sketch.n != my_sketch.counts.len() {
        // Mis-negotiated or adversarial frame: `recover_sketch` asserts on a length
        // mismatch; refuse here so transports get an error instead of a panic.
        return None;
    }
    let (x_hat, _, _) =
        recover_sketch(sketch, &my_sketch.counts, &codec_params(params, initiator_is_alice))?;
    Some(my_sketch.counts.iter().zip(&x_hat).map(|(y, x)| y - x).collect())
}

/// The synthetic first Round message that seeds the responder's ping-pong engine.
pub fn seed_round(residue0: &[i32]) -> Msg {
    Msg::Round {
        residue: compress_residue(residue0),
        smf: None,
        inquiry: Vec::new(),
        answers: Vec::new(),
        done: false,
        codec: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn session_pair_converges_with_mirror_accounting() {
        let (a, b) = synth::overlap_pair(5_000, 60, 90, 11);
        let params = CsParams::tuned_bidi(5_150, 60, 90);
        let (mut ini, opening) = Session::initiator(&params, &a, BidiOptions::default(), true);
        let mut res = Session::responder(&b, BidiOptions::default(), false);
        let converged = drive(&mut ini, &mut res, opening).unwrap();
        assert!(converged);
        assert_eq!(ini.outcome().unique, synth::difference(&a, &b));
        assert_eq!(res.outcome().unique, synth::difference(&b, &a));
        // Mirror-image accounting: what one endpoint sends the other receives, and both
        // transcripts total the same.
        assert_eq!(ini.bytes_sent(), res.bytes_received());
        assert_eq!(res.bytes_sent(), ini.bytes_received());
        assert_eq!(ini.comm().total_bytes(), res.comm().total_bytes());
        assert!(ini.msgs_sent() >= 2, "hello + sketch at minimum");
    }

    #[test]
    fn session_traces_are_well_formed_with_one_marker_per_payload_frame() {
        let (a, b) = synth::overlap_pair(5_000, 60, 90, 12);
        let params = CsParams::tuned_bidi(5_150, 60, 90);
        let (mut ini, opening) = Session::initiator(&params, &a, BidiOptions::default(), true);
        let mut res = Session::responder(&b, BidiOptions::default(), false);
        drive(&mut ini, &mut res, opening).unwrap();
        for s in [ini, res] {
            let (comm, _, _, trace) = s.into_parts();
            assert!(trace.is_well_formed());
            // The marker/frame identity: emitted at the CommLog recording points, so the
            // counts cannot drift apart.
            assert_eq!(trace.count_spans(|k| k == SpanKind::Round), comm.payload_frames());
            assert_eq!(trace.count_spans(|k| k == SpanKind::SketchEncode), 1);
            assert_eq!(trace.count_spans(|k| k == SpanKind::DecoderBuild), 1);
        }
    }

    #[test]
    fn out_of_order_frames_close_the_session() {
        let set: Vec<u64> = (0..100).collect();
        let round = seed_round(&[0i32; 128]);
        let mut res = Session::responder(&set, BidiOptions::default(), false);
        assert!(matches!(
            res.on_msg(&round),
            Err(SessionError::UnexpectedMessage { phase: "await-hello", got: "round" })
        ));
        // The session is closed afterwards: even a well-formed Hello is now rejected.
        let hello = Msg::Hello {
            l: 128,
            m: 5,
            seed: 1,
            universe_bits: 64,
            est_initiator_unique: 1,
            est_responder_unique: 1,
            set_len: 100,
            namespace: 0,
        };
        assert!(matches!(
            res.on_msg(&hello),
            Err(SessionError::UnexpectedMessage { phase: "closed", .. })
        ));
    }

    #[test]
    fn hello_for_a_different_namespace_is_rejected() {
        let set: Vec<u64> = (0..100).collect();
        let mut res = Session::responder(&set, BidiOptions::default(), false);
        let hello = Msg::Hello {
            l: 128,
            m: 5,
            seed: 1,
            universe_bits: 64,
            est_initiator_unique: 1,
            est_responder_unique: 1,
            set_len: 100,
            namespace: 9,
        };
        assert!(matches!(res.on_msg(&hello), Err(SessionError::Corrupt("hello namespace"))));

        // And a matched non-zero namespace is accepted (the session proceeds to
        // await-sketch, i.e. the Hello itself was not the problem).
        let opts = BidiOptions { namespace: 9, ..BidiOptions::default() };
        let mut res = Session::responder(&set, opts, false);
        assert!(matches!(res.on_msg(&hello), Ok(SessionEvent::Continue)));
    }

    #[test]
    fn corrupt_round_fields_error_instead_of_panicking() {
        let set: Vec<u64> = (0..500).collect();
        let params = CsParams::tuned_bidi(1_000, 10, 10);
        // Initiator sessions enter the ping-pong phase immediately.
        let (mut ini, _opening) = Session::initiator(&params, &set, BidiOptions::default(), true);
        let garbage_residue = Msg::Round {
            residue: vec![0xff; 7],
            smf: None,
            inquiry: vec![],
            answers: vec![],
            done: false,
            codec: false,
        };
        assert!(matches!(ini.on_msg(&garbage_residue), Err(SessionError::Corrupt("residue"))));

        let (mut ini, _opening) = Session::initiator(&params, &set, BidiOptions::default(), true);
        let zero_residue = vec![0i32; params.l as usize];
        let garbage_smf = Msg::Round {
            residue: compress_residue(&zero_residue),
            smf: Some(vec![1, 2, 3]),
            inquiry: vec![],
            answers: vec![],
            done: false,
            codec: false,
        };
        assert!(matches!(ini.on_msg(&garbage_smf), Err(SessionError::Corrupt("smf"))));
    }

    #[test]
    fn round_budget_terminates_nonconverging_sessions() {
        let (a, b) = synth::overlap_pair(2_000, 40, 40, 17);
        let mut params = CsParams::tuned_bidi(2_080, 40, 40);
        // Starve the sketch so the decode cannot complete, then check the budget trips.
        params.l = 128;
        let mut opts = BidiOptions::default();
        opts.max_rounds = 6;
        let (mut ini, opening) = Session::initiator(&params, &a, opts, true);
        let mut res = Session::responder(&b, opts, false);
        let converged = drive(&mut ini, &mut res, opening).unwrap_or(false);
        assert!(!converged);
        // Budget counts non-hello frames on both endpoints identically.
        assert!(ini.comm().rounds() <= opts.max_rounds + 3);
    }
}
