//! The CommonSense SetX protocol *engine*: unidirectional (§3) and bidirectional
//! ping-pong (§5), as explicit-parameter state machines.
//!
//! Both are implemented as *pure message-passing state machines*: every byte that would
//! cross the network is actually framed (see [`wire`]) and charged to a
//! [`crate::metrics::CommLog`], so the communication costs reported by the experiment
//! harnesses are measured, not estimated.
//!
//! The bidirectional protocol's single source of truth is the sans-io [`session::Session`]
//! engine: handshake, sketch exchange, and ping-pong decode as one `Msg`-in/`Msg`-out
//! state machine; [`bidi::run`] is its in-memory harness. The §7.1 difference-size
//! estimators live in [`estimate`].
//!
//! This layer demands a caller-supplied [`CsParams`] (including the very `d` the
//! protocol exists to discover) — it is for experiments, calibration, and manual tuning.
//! **Applications should use the [`crate::setx`] facade**, which estimates `d` in the
//! handshake, elects roles, escalates failed decodes, and runs the identical engine over
//! in-memory, TCP, and partitioned-parallel transports.

pub mod bidi;
pub mod estimate;
pub mod session;
pub mod uni;
pub mod wire;

pub use bidi::{BidiOptions, BidiOutcome};
pub use session::{Role, Session, SessionError, SessionEvent, SessionOutcome};
pub use uni::UniOutcome;

use crate::hash::ColumnSampler;
use crate::matrix::CsMatrix;

/// Largest row count accepted from a wire `Hello` (2^28 rows ≈ 1 GiB of i32 residue):
/// above this an adversarial frame would drive giant allocations before any decode runs.
pub const MAX_WIRE_L: u32 = 1 << 28;

/// The single trust-boundary check for wire-supplied CS geometry, shared by every
/// `Hello` acceptor (the session engine and the facade endpoint) so the two boundaries
/// cannot drift: typed [`crate::hash::GeometryError`] rules (`1 ≤ m ≤ min(l, MAX_M)` —
/// the stack-buffer invariant) plus the [`MAX_WIRE_L`] allocation cap.
pub fn wire_geometry_ok(l: u32, m: u32, seed: u64) -> bool {
    l <= MAX_WIRE_L && ColumnSampler::try_new(l, m, seed).is_ok()
}

/// Why a decode attempt failed — the engine-level diagnosis both the unidirectional
/// one-shot ([`uni`]) and the facade's escalation ladder report, so failures always
/// carry *which layer* gave out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeFailure {
    /// The truncated sketch failed recovery/verification against the receiver's counts
    /// (mis-sized codec or corrupted payload — the verification-mismatch shape).
    SketchRecovery,
    /// The MP decoder could not drive the residue to zero (undersized sketch — the
    /// undecodable-residue shape).
    ResidueDecode,
    /// The bidirectional ping-pong exhausted its round budget without settling.
    NotConverged,
}

impl DecodeFailure {
    pub fn name(self) -> &'static str {
        match self {
            DecodeFailure::SketchRecovery => "sketch recovery/verification failed",
            DecodeFailure::ResidueDecode => "residue undecodable",
            DecodeFailure::NotConverged => "ping-pong did not converge",
        }
    }
}

/// Shared CS parameters of a session. Alice and Bob must agree on all fields (in the wire
/// protocol they travel in the handshake header).
#[derive(Clone, Copy, Debug)]
pub struct CsParams {
    /// Sketch length (rows of M).
    pub l: u32,
    /// Ones per column (7 for unidirectional, 5 for bidirectional — §7.1).
    pub m: u32,
    /// Shared matrix seed.
    pub seed: u64,
    /// Nominal universe bit-width `u` (64 for §7.2-uni, 256 for §7.2-bidi/§7.3); used by
    /// accounting (signature widths) — internal ids are always 64-bit.
    pub universe_bits: u32,
    /// d-estimate handshake outputs (the paper assumes the SDC is known to all protocols).
    pub est_a_unique: usize,
    pub est_b_unique: usize,
}

impl CsParams {
    pub fn matrix(&self) -> CsMatrix {
        CsMatrix::new(self.l, self.m, self.seed)
    }

    /// Empirically calibrated sketch length for reliable lossless MP decode:
    /// `l ≈ d·m·(6 + log2(n/d))/7`, the shape `O(d·log(n/d))` of Theorem 8 with constants
    /// fit by the tuner (`commonsense tune`); `safety` multiplies on top (1.0 = calibrated
    /// minimum that always decoded in our runs).
    pub fn l_for(d: usize, n: usize, m: u32, safety: f64) -> u32 {
        let d = d.max(1) as f64;
        let n = (n.max(2) as f64).max(d * 2.0);
        let l = d * m as f64 * (6.0 + (n / d).log2()) / 7.0 * safety;
        (l.ceil() as u32).max(128)
    }

    /// d-dependent safety factor: the empirical minimal factor (tuner, 20-trial perfect
    /// decode) *decreases* with d — MP error-correction strengthens with more signal:
    /// measured minima 1.05 / 0.80 / 0.60 at d = 200 / 1k / 5k (n = 100k). We keep a
    /// ≈ 20% margin on top (§Perf log in EXPERIMENTS.md).
    fn uni_safety(d: usize) -> f64 {
        (1.2 - 0.32 * ((d.max(1) as f64) / 200.0).log10()).clamp(0.72, 1.3)
    }

    /// Bidirectional needs more rows (the opposite-signed component is decode noise):
    /// measured minima 1.50 / 1.20 at d = 200 / 1k.
    fn bidi_safety(d: usize) -> f64 {
        (1.85 - 0.5 * ((d.max(1) as f64) / 200.0).log10()).clamp(1.15, 2.0)
    }

    /// Defaults for unidirectional SetX over `|B| = n` with `d = |B\A|`.
    pub fn tuned_uni(n: usize, d: usize) -> Self {
        Self::tuned_uni_with_safety(n, d, 1.0)
    }

    /// [`CsParams::tuned_uni`] with an extra multiplier on the calibrated safety factor —
    /// the knob the `Setx` facade's escalation ladder turns (each failed attempt retries
    /// with a larger multiplier instead of failing opaquely).
    pub fn tuned_uni_with_safety(n: usize, d: usize, extra_safety: f64) -> Self {
        let m = 7;
        CsParams {
            l: Self::l_for(d, n, m, Self::uni_safety(d) * extra_safety),
            m,
            seed: 0xC0FFEE,
            universe_bits: 64,
            est_a_unique: 0,
            est_b_unique: d,
        }
    }

    /// Defaults for bidirectional SetX over `n = |A∪B|` with the given unique counts.
    pub fn tuned_bidi(n: usize, a_unique: usize, b_unique: usize) -> Self {
        Self::tuned_bidi_with_safety(n, a_unique, b_unique, 1.0)
    }

    /// [`CsParams::tuned_bidi`] with an extra safety multiplier (see
    /// [`CsParams::tuned_uni_with_safety`]).
    pub fn tuned_bidi_with_safety(n: usize, a_unique: usize, b_unique: usize, extra_safety: f64) -> Self {
        let m = 5;
        let d = a_unique + b_unique;
        CsParams {
            // Bidirectional decoding fights the opposite-signed component as noise; the
            // calibrated constant is larger than the unidirectional one.
            l: Self::l_for(d, n, m, Self::bidi_safety(d) * extra_safety),
            m,
            seed: 0xC0FFEE,
            universe_bits: 256,
            est_a_unique: a_unique,
            est_b_unique: b_unique,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l_scales_like_d_log_n_over_d() {
        let l1 = CsParams::l_for(100, 100_000, 7, 1.0);
        let l2 = CsParams::l_for(200, 100_000, 7, 1.0);
        let l3 = CsParams::l_for(100, 1_000_000, 7, 1.0);
        assert!(l2 > l1 && (l2 as f64) < 2.2 * l1 as f64);
        assert!(l3 > l1, "larger universe ⇒ more rows");
        assert!((l3 as f64) < 1.4 * l1 as f64, "only logarithmically more");
    }

    #[test]
    fn tuned_params_match_paper_m() {
        assert_eq!(CsParams::tuned_uni(10_000, 100).m, 7);
        assert_eq!(CsParams::tuned_bidi(10_000, 50, 50).m, 5);
    }
}
