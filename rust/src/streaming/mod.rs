//! Streaming CommonSense (§4) and its two motivating applications (§2.2, §2.3).
//!
//! The streaming digest keeps only the `l`-dimensional measurement in memory, applies every
//! stream event as a 1-sparse update in O(m), and decodes offline against a predetermined
//! superset `B′` (the decoder's candidate set). This is the drop-in replacement for the
//! IBLTs in LossRadar [23] (packet-loss detection) and straggler identification [25].

use crate::decoder::{DecoderConfig, MpDecoder, Pursuit, Side};
use crate::matrix::CsMatrix;
use crate::protocol::CsParams;
use crate::sketch::Sketch;

/// A streaming digest: the in-memory state is exactly `l` counters (`4l` bytes).
#[derive(Clone, Debug)]
pub struct StreamDigest {
    sketch: Sketch,
}

impl StreamDigest {
    pub fn new(matrix: CsMatrix) -> Self {
        StreamDigest { sketch: Sketch::zero(matrix) }
    }

    /// Element arrival (borrow, packet at upstream meter, …). O(m).
    #[inline]
    pub fn add(&mut self, id: u64) {
        self.sketch.update(id, 1);
    }

    /// Element departure (return, packet seen downstream, …). O(m).
    #[inline]
    pub fn remove(&mut self, id: u64) {
        self.sketch.update(id, -1);
    }

    /// Memory footprint (the paper's key metric for the data-plane digest).
    pub fn memory_bytes(&self) -> usize {
        self.sketch.counts.len() * std::mem::size_of::<i32>()
    }

    pub fn matrix(&self) -> CsMatrix {
        self.sketch.matrix
    }

    pub fn counts(&self) -> &[i32] {
        &self.sketch.counts
    }

    /// Difference digest `self − other` (e.g. upstream − downstream meters in LossRadar).
    pub fn diff(&self, other: &StreamDigest) -> Vec<i32> {
        self.sketch.sub(&other.sketch).values
    }

    /// Offline decode of the digest state against the superset `b_prime`: returns the set
    /// the digest currently encodes (positives only — e.g. outstanding books/lost packets).
    pub fn decode(&self, b_prime: &[u64]) -> Option<Vec<u64>> {
        decode_measurement(self.matrix(), &self.sketch.counts, b_prime)
    }
}

/// Decode a raw measurement vector against candidate superset `b_prime` (used both by
/// `StreamDigest::decode` and by LossRadar-style digest differences).
pub fn decode_measurement(matrix: CsMatrix, counts: &[i32], b_prime: &[u64]) -> Option<Vec<u64>> {
    let mut dec = MpDecoder::new(&matrix, b_prime, Side::Positive);
    dec.set_config(DecoderConfig::commonsense());
    dec.load_residue(counts);
    let stats = dec.run();
    if !stats.converged {
        dec.switch_pursuit(Pursuit::L1);
        dec.run();
        dec.switch_pursuit(Pursuit::L2);
        let stats = dec.run();
        if !stats.converged {
            return None;
        }
    }
    let mut out = dec.estimate();
    out.sort_unstable();
    Some(out)
}

/// Sizing helper: the digest for an expected difference `d` against a superset of size `n`.
pub fn digest_params(n: usize, d: usize) -> CsParams {
    CsParams::tuned_uni(n, d)
}

/// §2.2 — LossRadar-style packet-loss detection between an upstream and a downstream meter.
pub mod lossradar {
    use super::*;

    /// The per-switch data-plane state.
    pub struct Meter {
        pub digest: StreamDigest,
    }

    impl Meter {
        pub fn new(params: &CsParams) -> Self {
            Meter { digest: StreamDigest::new(params.matrix()) }
        }

        /// A packet (identified by its 5-tuple+packet-id signature) traverses this meter.
        #[inline]
        pub fn observe(&mut self, packet_sig: u64) {
            self.digest.add(packet_sig);
        }
    }

    /// Control-plane loss detection: decode `upstream − downstream` against the packet
    /// superset `b_prime` (flow IDs × conservatively-estimated packet-id ranges, per §2.2).
    pub fn detect_losses(
        upstream: &Meter,
        downstream: &Meter,
        b_prime: &[u64],
    ) -> Option<Vec<u64>> {
        let diff = upstream.digest.diff(&downstream.digest);
        decode_measurement(upstream.digest.matrix(), &diff, b_prime)
    }
}

/// §2.3 — straggler identification (the library example: borrowed-but-not-returned books).
pub mod straggler {
    use super::*;

    /// The bounded-memory tracker the librarian's computer keeps.
    pub struct Tracker {
        pub digest: StreamDigest,
    }

    impl Tracker {
        pub fn new(params: &CsParams) -> Self {
            Tracker { digest: StreamDigest::new(params.matrix()) }
        }

        pub fn borrow(&mut self, book: u64) {
            self.digest.add(book);
        }

        pub fn return_book(&mut self, book: u64) {
            self.digest.remove(book);
        }

        /// End-of-day decode against the full catalog.
        pub fn stragglers(&self, catalog: &[u64]) -> Option<Vec<u64>> {
            self.digest.decode(catalog)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::hash::Xoshiro256;

    #[test]
    fn stragglers_recovered_exactly() {
        let catalog: Vec<u64> = (0..30_000u64).map(|i| i * 97 + 5).collect();
        let params = digest_params(catalog.len(), 64);
        let mut tracker = straggler::Tracker::new(&params);
        let mut rng = Xoshiro256::seed_from_u64(3);
        // 5000 borrow events; 40 books never returned.
        let mut outstanding = std::collections::HashSet::new();
        for i in 0..5000usize {
            let book = catalog[rng.gen_range(catalog.len() as u64) as usize];
            if outstanding.contains(&book) {
                continue; // already out — can't borrow again
            }
            tracker.borrow(book);
            if i % 125 == 0 && outstanding.len() < 40 {
                outstanding.insert(book); // straggler: never returned
            } else {
                tracker.return_book(book);
            }
        }
        let mut want: Vec<u64> = outstanding.into_iter().collect();
        want.sort_unstable();
        let got = tracker.stragglers(&catalog).expect("decode");
        assert_eq!(got, want);
    }

    #[test]
    fn lossradar_detects_dropped_packets() {
        // 20k packets traverse upstream; 150 are dropped before downstream.
        let (lost, all_packets) = synth::subset_pair(150, 19_850, 8);
        let params = digest_params(all_packets.len(), 150);
        let mut up = lossradar::Meter::new(&params);
        let mut down = lossradar::Meter::new(&params);
        let lost_set: std::collections::HashSet<u64> = lost.iter().copied().collect();
        for &p in &all_packets {
            up.observe(p);
            if !lost_set.contains(&p) {
                down.observe(p);
            }
        }
        let got = lossradar::detect_losses(&up, &down, &all_packets).expect("decode");
        let mut want = lost.clone();
        want.sort_unstable();
        assert_eq!(got, want);
        // The headline: digest memory ≪ tracking all packets (8B id each).
        assert!(up.digest.memory_bytes() < 8 * all_packets.len() / 4);
    }

    #[test]
    fn digest_memory_is_4l() {
        let params = digest_params(100_000, 100);
        let d = StreamDigest::new(params.matrix());
        assert_eq!(d.memory_bytes(), 4 * params.l as usize);
    }

    #[test]
    fn add_remove_cancels() {
        let params = digest_params(1000, 10);
        let mut d = StreamDigest::new(params.matrix());
        for i in 0..500u64 {
            d.add(i);
        }
        for i in 0..500u64 {
            d.remove(i);
        }
        assert!(d.counts().iter().all(|&c| c == 0));
        assert_eq!(d.decode(&(0..1000u64).collect::<Vec<_>>()).unwrap(), vec![]);
    }
}
