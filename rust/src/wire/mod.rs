//! Shared wire-level building blocks, independent of any one protocol frame.
//!
//! Today this hosts [`column`], the columnar codec layer every frame encoder in
//! [`crate::protocol::wire`] routes through. Frame *layout* (type bytes, body length
//! prefixes, field order) stays with the protocol; this layer owns only the byte-level
//! encodings of repeated values — id sequences, count vectors, bitmaps.

pub mod column;
