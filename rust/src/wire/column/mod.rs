//! Columnar codecs: the one compression layer under every protocol frame.
//!
//! The paper's headline result is communication cost, yet frames naively ship id lists
//! as raw 8-byte words, count vectors with long zero runs, and Bloom bitmaps as flat
//! bytes. This module is the rowblock-style answer (after automerge's `columnar`
//! encoders): a small set of self-describing column encodings, each a
//! [`Column`] encoder/decoder pair with checked offsets and length-capped parsing, that
//! the frame encoders in [`crate::protocol::wire`] (and the estimator/SMF serializers)
//! compose instead of hand-rolling byte loops.
//!
//! # The encoders
//!
//! Every column starts with a LEB128 varint element count `n`. When `n > 0` the
//! adaptive columns follow with a one-byte **mode** tag and the payload; the encoder
//! always picks the cheaper mode, so no column ever exceeds its fixed-width framing by
//! more than that single byte:
//!
//! * [`Fixed64Col`] — `n` raw 8-byte LE words, no mode byte. Byte-identical to the
//!   legacy (pre-codec) id-list framing; the codec-off paths route through it so the
//!   byte-identity guarantee below is enforced by construction, not by parallel code.
//! * [`DeltaU64Col`] — mode 0: raw 8-byte words; mode 1: zigzag varints of
//!   *wrapping* deltas between consecutive values. **Order-preserving** (never
//!   sort-then-delta): `Msg::Round` inquiry signatures must stay aligned with the
//!   peer's answer bits by index. Sorted id sequences get short positive deltas; a
//!   random signature list falls back to mode 0.
//! * [`RleU64Col`] — mode 0: raw 8-byte words; mode 1: run-length framing for sparse
//!   integer columns (sketch count vectors are mostly zeros at low d). Each run header
//!   is a varint `h`: low bit 0 ⇒ a repeat run of `h >> 1` copies of one varint value,
//!   low bit 1 ⇒ a literal run of `h >> 1` varint values. Runs are non-empty and must
//!   sum exactly to `n`.
//! * [`BoolRleCol`] — mode 0: LSB-first bitpacked (byte-identical to the legacy answer
//!   bitmap); mode 1: a start-bit byte plus alternating varint run lengths (boolean-RLE
//!   for bitmaps — a half-full Bloom filter stays bitpacked, a sparse one collapses).
//!
//! # Negotiation and the byte-identity guarantee
//!
//! Whether a conversation uses the columnar frame bodies at all is negotiated by a
//! dedicated `EstHello` handshake flags bit (bit 5, the same versioned-trailing-field
//! pattern as the `namespace`/`party` fields): the codec runs only when **both** ends
//! advertise it, and a codec-off conversation emits frames **byte-identical** to the
//! PR 7 wire format — old transcripts parse unchanged, and a codec-off peer negotiates
//! any codec-capable peer down. Codec-on frames use dedicated frame type bytes, so
//! `Msg::from_bytes` stays context-free. (`Msg::Confirm` carries no id list — only a
//! verdict triple — so the "Confirm id lists" of the columnar blueprint have nothing to
//! encode; the frame is untouched in both modes.)
//!
//! # Parsing posture
//!
//! Decoders mirror the frame-hardening rules of `protocol::wire`: every read is
//! checked, claimed counts are validated against the caller's `cap` (and the global
//! [`MAX_COLUMN_ELEMS`] backstop) *before* any allocation is sized by them, varints
//! longer than 10 bytes are rejected, and run lengths may never overflow the declared
//! element count. A run-length column legitimately decodes more elements than it has
//! payload bytes — that is the point of compression — so `cap` is the allocation bound
//! and callers pass the tightest value their frame context knows.

/// Hard ceiling on the element count any single column will decode (2^24 ≈ 16.7M;
/// 128 MiB of u64s), a backstop under the per-call `cap` so a handful of adversarial
/// bytes can never demand an unbounded allocation.
pub const MAX_COLUMN_ELEMS: usize = 1 << 24;

/// Encoded size of one LEB128 varint.
#[inline]
pub fn varint_len(v: u64) -> usize {
    ((64 - v.leading_zeros()).max(1) as usize).div_ceil(7)
}

/// Append one LEB128 varint.
pub fn put_uvarint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read one LEB128 varint from `data[*off..]`, advancing the cursor. Rejects
/// truncation and over-long encodings (anything whose continuation runs past the
/// 10 bytes a `u64` can need — an 11-byte varint is always malformed).
pub fn take_uvarint(data: &[u8], off: &mut usize) -> Option<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let byte = *data.get(*off)?;
        *off = off.checked_add(1)?;
        if shift >= 64 || (shift == 63 && byte > 1) {
            return None;
        }
        v |= ((byte & 0x7f) as u64) << shift;
        if byte & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
    }
}

#[inline]
fn zigzag64(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
fn unzigzag64(z: u64) -> i64 {
    ((z >> 1) as i64) ^ -((z & 1) as i64)
}

#[inline]
fn take<'a>(data: &'a [u8], off: &mut usize, len: usize) -> Option<&'a [u8]> {
    let end = off.checked_add(len)?;
    let slice = data.get(*off..end)?;
    *off = end;
    Some(slice)
}

/// Read the leading element count of any column without decoding it (all columns open
/// with a varint `n`) — used by the raw-bytes accounting to size a column's fixed-width
/// equivalent without a full decode.
pub fn peek_count(data: &[u8], off: &mut usize) -> Option<usize> {
    usize::try_from(take_uvarint(data, off)?).ok()
}

/// Shared element-count preamble of every decoder: parse `n` and validate it against
/// the caller's cap and the global backstop before anything is allocated.
fn take_count(data: &[u8], off: &mut usize, cap: usize) -> Option<usize> {
    let n = peek_count(data, off)?;
    if n > cap.min(MAX_COLUMN_ELEMS) {
        return None;
    }
    Some(n)
}

const MODE_FIXED: u8 = 0;
const MODE_PACKED: u8 = 1;

/// One column encoding: a value type plus a byte-level codec. All methods are
/// associated functions — columns are stateless; the trait exists so every encoding
/// exposes the same three-operation surface (`encoded_len` must equal exactly what
/// `encode` appends, and `decode` must consume exactly that many bytes).
pub trait Column {
    type Item;

    /// Exact number of bytes [`Column::encode`] will append for `items`.
    fn encoded_len(items: &[Self::Item]) -> usize;

    /// Append the column encoding of `items` to `out`.
    fn encode(items: &[Self::Item], out: &mut Vec<u8>);

    /// Parse one column from `data[*off..]`, advancing the cursor past exactly the
    /// bytes [`Column::encode`] wrote. `cap` bounds the decoded element count (and
    /// thus the allocation); malformed, truncated, or oversized input yields `None`
    /// with no partial allocation of the claimed size.
    fn decode(data: &[u8], off: &mut usize, cap: usize) -> Option<Vec<Self::Item>>;
}

/// Raw fixed-width column: varint `n` + `n` little-endian 8-byte words. Byte-identical
/// to the legacy id-list framing (this is the *only* place the wire stack serializes
/// an id list as raw words — see the CI lint).
pub struct Fixed64Col;

impl Column for Fixed64Col {
    type Item = u64;

    fn encoded_len(items: &[u64]) -> usize {
        varint_len(items.len() as u64) + 8 * items.len()
    }

    fn encode(items: &[u64], out: &mut Vec<u8>) {
        put_uvarint(out, items.len() as u64);
        for v in items {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    fn decode(data: &[u8], off: &mut usize, cap: usize) -> Option<Vec<u64>> {
        let n = take_count(data, off, cap)?;
        if n > data.len().saturating_sub(*off) / 8 {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(u64::from_le_bytes(take(data, off, 8)?.try_into().ok()?));
        }
        Some(out)
    }
}

fn fixed_words(data: &[u8], off: &mut usize, n: usize) -> Option<Vec<u64>> {
    if n > data.len().saturating_sub(*off) / 8 {
        return None;
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(u64::from_le_bytes(take(data, off, 8)?.try_into().ok()?));
    }
    Some(out)
}

/// Order-preserving delta column: varint `n`, then (for `n > 0`) a mode byte — raw
/// words, or zigzag varints of wrapping deltas between consecutive values. The encoder
/// picks whichever is smaller, so a random signature list costs legacy + 1 byte while
/// a sorted id sequence collapses to a couple of bytes per id.
pub struct DeltaU64Col;

impl DeltaU64Col {
    fn delta_payload_len(items: &[u64]) -> usize {
        let mut prev = 0u64;
        let mut len = 0usize;
        for &v in items {
            len += varint_len(zigzag64(v.wrapping_sub(prev) as i64));
            prev = v;
        }
        len
    }
}

impl Column for DeltaU64Col {
    type Item = u64;

    fn encoded_len(items: &[u64]) -> usize {
        if items.is_empty() {
            return varint_len(0);
        }
        let delta = Self::delta_payload_len(items);
        varint_len(items.len() as u64) + 1 + delta.min(8 * items.len())
    }

    fn encode(items: &[u64], out: &mut Vec<u8>) {
        put_uvarint(out, items.len() as u64);
        if items.is_empty() {
            return;
        }
        if Self::delta_payload_len(items) < 8 * items.len() {
            out.push(MODE_PACKED);
            let mut prev = 0u64;
            for &v in items {
                put_uvarint(out, zigzag64(v.wrapping_sub(prev) as i64));
                prev = v;
            }
        } else {
            out.push(MODE_FIXED);
            for v in items {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    fn decode(data: &[u8], off: &mut usize, cap: usize) -> Option<Vec<u64>> {
        let n = take_count(data, off, cap)?;
        if n == 0 {
            return Some(Vec::new());
        }
        match *take(data, off, 1)?.first()? {
            MODE_FIXED => fixed_words(data, off, n),
            MODE_PACKED => {
                // Every delta varint is ≥ 1 byte, so the count is byte-bounded too.
                if n > data.len().saturating_sub(*off) {
                    return None;
                }
                let mut out = Vec::with_capacity(n);
                let mut prev = 0u64;
                for _ in 0..n {
                    let d = unzigzag64(take_uvarint(data, off)?);
                    prev = prev.wrapping_add(d as u64);
                    out.push(prev);
                }
                Some(out)
            }
            _ => None,
        }
    }
}

/// Run-length column for sparse integer sequences: varint `n`, then (for `n > 0`) a
/// mode byte — raw words, or the run framing described in the module docs. Values are
/// varint-coded inside runs, so small magnitudes (zigzagged counts, fingerprints) cost
/// 1–2 bytes and zero runs collapse to ~3 bytes regardless of length; columns of
/// large random words (occupied IBLT key slots) fall back to raw.
pub struct RleU64Col;

enum Run<'a> {
    Repeat { len: usize, value: u64 },
    Literal(&'a [u64]),
}

/// Walk `items` as maximal runs: stretches of ≥ 2 identical values become repeat runs,
/// everything between them pools into literal runs. Encoder and `encoded_len` share
/// this walk so they cannot disagree.
fn for_each_run(items: &[u64], mut f: impl FnMut(Run<'_>)) {
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i < items.len() {
        let mut j = i + 1;
        while j < items.len() && items[j] == items[i] {
            j += 1;
        }
        if j - i >= 2 {
            if lit_start < i {
                f(Run::Literal(&items[lit_start..i]));
            }
            f(Run::Repeat { len: j - i, value: items[i] });
            lit_start = j;
        }
        i = j;
    }
    if lit_start < items.len() {
        f(Run::Literal(&items[lit_start..]));
    }
}

impl RleU64Col {
    fn rle_payload_len(items: &[u64]) -> usize {
        let mut len = 0usize;
        for_each_run(items, |run| match run {
            Run::Repeat { len: rl, value } => {
                len += varint_len((rl as u64) << 1) + varint_len(value);
            }
            Run::Literal(vals) => {
                len += varint_len(((vals.len() as u64) << 1) | 1);
                for &v in vals {
                    len += varint_len(v);
                }
            }
        });
        len
    }
}

impl Column for RleU64Col {
    type Item = u64;

    fn encoded_len(items: &[u64]) -> usize {
        if items.is_empty() {
            return varint_len(0);
        }
        let rle = Self::rle_payload_len(items);
        varint_len(items.len() as u64) + 1 + rle.min(8 * items.len())
    }

    fn encode(items: &[u64], out: &mut Vec<u8>) {
        put_uvarint(out, items.len() as u64);
        if items.is_empty() {
            return;
        }
        if Self::rle_payload_len(items) < 8 * items.len() {
            out.push(MODE_PACKED);
            for_each_run(items, |run| match run {
                Run::Repeat { len, value } => {
                    put_uvarint(out, (len as u64) << 1);
                    put_uvarint(out, value);
                }
                Run::Literal(vals) => {
                    put_uvarint(out, ((vals.len() as u64) << 1) | 1);
                    for &v in vals {
                        put_uvarint(out, v);
                    }
                }
            });
        } else {
            out.push(MODE_FIXED);
            for v in items {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }

    fn decode(data: &[u8], off: &mut usize, cap: usize) -> Option<Vec<u64>> {
        let n = take_count(data, off, cap)?;
        if n == 0 {
            return Some(Vec::new());
        }
        match *take(data, off, 1)?.first()? {
            MODE_FIXED => fixed_words(data, off, n),
            MODE_PACKED => {
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    let header = take_uvarint(data, off)?;
                    let len = usize::try_from(header >> 1).ok()?;
                    // Empty runs are malformed, and no run may overflow the declared
                    // element count.
                    if len == 0 || len > n - out.len() {
                        return None;
                    }
                    if header & 1 == 0 {
                        let value = take_uvarint(data, off)?;
                        out.resize(out.len() + len, value);
                    } else {
                        for _ in 0..len {
                            out.push(take_uvarint(data, off)?);
                        }
                    }
                }
                Some(out)
            }
            _ => None,
        }
    }
}

/// Boolean column: varint `n`, then (for `n > 0`) a mode byte — LSB-first bitpacked
/// (byte-identical to the legacy answer bitmap), or boolean-RLE: one start-bit byte
/// plus alternating varint run lengths. An optimally-loaded Bloom filter (fill ≈ 0.5)
/// stays bitpacked; sparse or skewed bitmaps collapse.
pub struct BoolRleCol;

impl BoolRleCol {
    fn rle_payload_len(items: &[bool]) -> usize {
        let mut len = 1usize; // start-bit byte
        let mut run = 0u64;
        let mut current = items[0];
        for &b in items {
            if b == current {
                run += 1;
            } else {
                len += varint_len(run);
                current = b;
                run = 1;
            }
        }
        len + varint_len(run)
    }
}

impl Column for BoolRleCol {
    type Item = bool;

    fn encoded_len(items: &[bool]) -> usize {
        if items.is_empty() {
            return varint_len(0);
        }
        let rle = Self::rle_payload_len(items);
        varint_len(items.len() as u64) + 1 + rle.min(items.len().div_ceil(8))
    }

    fn encode(items: &[bool], out: &mut Vec<u8>) {
        put_uvarint(out, items.len() as u64);
        if items.is_empty() {
            return;
        }
        if Self::rle_payload_len(items) < items.len().div_ceil(8) {
            out.push(MODE_PACKED);
            out.push(items[0] as u8);
            let mut run = 0u64;
            let mut current = items[0];
            for &b in items {
                if b == current {
                    run += 1;
                } else {
                    put_uvarint(out, run);
                    current = b;
                    run = 1;
                }
            }
            put_uvarint(out, run);
        } else {
            out.push(MODE_FIXED);
            let mut packed = vec![0u8; items.len().div_ceil(8)];
            for (i, &b) in items.iter().enumerate() {
                if b {
                    packed[i / 8] |= 1 << (i % 8);
                }
            }
            out.extend_from_slice(&packed);
        }
    }

    fn decode(data: &[u8], off: &mut usize, cap: usize) -> Option<Vec<bool>> {
        let n = take_count(data, off, cap)?;
        if n == 0 {
            return Some(Vec::new());
        }
        match *take(data, off, 1)?.first()? {
            MODE_FIXED => {
                let packed = take(data, off, n.div_ceil(8))?;
                Some((0..n).map(|i| packed[i / 8] >> (i % 8) & 1 == 1).collect())
            }
            MODE_PACKED => {
                let start = *take(data, off, 1)?.first()?;
                if start > 1 {
                    return None;
                }
                let mut bit = start == 1;
                let mut out = Vec::with_capacity(n);
                while out.len() < n {
                    let run = usize::try_from(take_uvarint(data, off)?).ok()?;
                    if run == 0 || run > n - out.len() {
                        return None;
                    }
                    out.resize(out.len() + run, bit);
                    bit = !bit;
                }
                Some(out)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_u64<C: Column<Item = u64>>(items: &[u64]) {
        let mut buf = Vec::new();
        C::encode(items, &mut buf);
        assert_eq!(buf.len(), C::encoded_len(items), "encoded_len must match encode");
        let mut off = 0;
        let back = C::decode(&buf, &mut off, MAX_COLUMN_ELEMS).expect("decode");
        assert_eq!(off, buf.len(), "decode must consume exactly the column");
        assert_eq!(back, items);
    }

    fn u64_cases() -> Vec<Vec<u64>> {
        vec![
            vec![],
            vec![0],
            vec![u64::MAX],
            vec![7; 500],
            (0..200u64).map(|i| i * 3 + 1).collect(),
            (0..100u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)).collect(),
            vec![0, 0, 0, 5, 5, 1, 0, 0, 9, 9, 9, 9, 2],
            vec![3, 1, 4, 1, 5, 9, 2, 6],
        ]
    }

    #[test]
    fn all_u64_columns_roundtrip() {
        for case in u64_cases() {
            roundtrip_u64::<Fixed64Col>(&case);
            roundtrip_u64::<DeltaU64Col>(&case);
            roundtrip_u64::<RleU64Col>(&case);
        }
    }

    #[test]
    fn bool_column_roundtrips() {
        let cases: Vec<Vec<bool>> = vec![
            vec![],
            vec![true],
            vec![false; 1000],
            vec![true; 77],
            (0..256).map(|i| i % 2 == 0).collect(),
            (0..300).map(|i| i % 97 < 3).collect(),
        ];
        for case in cases {
            let mut buf = Vec::new();
            BoolRleCol::encode(&case, &mut buf);
            assert_eq!(buf.len(), BoolRleCol::encoded_len(&case));
            let mut off = 0;
            let back = BoolRleCol::decode(&buf, &mut off, MAX_COLUMN_ELEMS).expect("decode");
            assert_eq!(off, buf.len());
            assert_eq!(back, case);
        }
    }

    #[test]
    fn fixed64_is_byte_identical_to_legacy_id_list_framing() {
        let ids = [0x1122_3344_5566_7788u64, 42, u64::MAX];
        let mut col = Vec::new();
        Fixed64Col::encode(&ids, &mut col);
        let mut legacy = Vec::new();
        put_uvarint(&mut legacy, ids.len() as u64);
        for id in ids {
            legacy.extend_from_slice(&id.to_le_bytes());
        }
        assert_eq!(col, legacy);
    }

    #[test]
    fn adaptive_columns_pick_the_smaller_mode() {
        // Sorted ids: delta mode must beat raw words by a wide margin.
        let sorted: Vec<u64> = (0..1000u64).map(|i| 1_000_000 + i * 17).collect();
        assert!(DeltaU64Col::encoded_len(&sorted) < 8 * sorted.len() / 2);
        // Random signatures: cost is capped at legacy + 1 mode byte.
        let random: Vec<u64> =
            (0..1000u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ (i << 40)).collect();
        assert_eq!(DeltaU64Col::encoded_len(&random), Fixed64Col::encoded_len(&random) + 1);
        // Mostly-zero counts: RLE collapses.
        let mut sparse = vec![0u64; 4096];
        sparse[17] = 3;
        sparse[900] = 1;
        assert!(RleU64Col::encoded_len(&sparse) < 64);
        // Half-full bitmap: bitpacked + 1 mode byte, never 1-byte-per-bit RLE.
        let noisy: Vec<bool> = (0..4096).map(|i| (i * 2_654_435_761u64 as usize) & 8 != 0).collect();
        assert!(BoolRleCol::encoded_len(&noisy) <= varint_len(4096) + 1 + 4096 / 8);
    }

    #[test]
    fn varint_rejects_overlong_and_truncated() {
        // 11-byte varint: ten continuation bytes then a terminator.
        let overlong = [0x80u8, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x01];
        let mut off = 0;
        assert!(take_uvarint(&overlong, &mut off).is_none());
        // A 10-byte varint whose last byte overflows bit 63 is also malformed.
        let overflow = [0xffu8, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x02];
        let mut off = 0;
        assert!(take_uvarint(&overflow, &mut off).is_none());
        // ... while u64::MAX itself roundtrips.
        let mut buf = Vec::new();
        put_uvarint(&mut buf, u64::MAX);
        let mut off = 0;
        assert_eq!(take_uvarint(&buf, &mut off), Some(u64::MAX));
        assert_eq!(off, buf.len());
        // Truncated continuation.
        let mut off = 0;
        assert!(take_uvarint(&[0x80], &mut off).is_none());
        let mut off = 0;
        assert!(take_uvarint(&[], &mut off).is_none());
    }

    #[test]
    fn decoded_length_cap_rejects_before_allocation() {
        // A 4-byte column claiming 2^30 elements must die on the cap check, for every
        // column type — including a run-length column whose payload could legally be
        // tiny.
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 1u64 << 30);
        buf.push(MODE_PACKED);
        put_uvarint(&mut buf, (1u64 << 30) << 1); // one giant zero run
        put_uvarint(&mut buf, 0);
        let mut off = 0;
        assert!(RleU64Col::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
        let mut off = 0;
        assert!(Fixed64Col::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
        let mut off = 0;
        assert!(DeltaU64Col::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
        let mut off = 0;
        assert!(BoolRleCol::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
        // The caller's cap binds even under the global backstop.
        let small = [5u64, 6, 7, 8];
        let mut col = Vec::new();
        RleU64Col::encode(&small, &mut col);
        let mut off = 0;
        assert!(RleU64Col::decode(&col, &mut off, 3).is_none());
        let mut off = 0;
        assert_eq!(RleU64Col::decode(&col, &mut off, 4).as_deref(), Some(&small[..]));
    }

    #[test]
    fn run_length_overflow_and_truncation_rejected() {
        // Declared n = 4 but a run claims 5 elements.
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 4);
        buf.push(MODE_PACKED);
        put_uvarint(&mut buf, 5 << 1);
        put_uvarint(&mut buf, 0);
        let mut off = 0;
        assert!(RleU64Col::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
        // Zero-length runs are malformed, not an infinite loop.
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 4);
        buf.push(MODE_PACKED);
        put_uvarint(&mut buf, 0);
        put_uvarint(&mut buf, 9);
        let mut off = 0;
        assert!(RleU64Col::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
        // Truncated run header / payload at every byte boundary.
        let items = [0u64, 0, 0, 0, 7, 7, 7, 1, 2, 3];
        let mut col = Vec::new();
        RleU64Col::encode(&items, &mut col);
        for cut in 0..col.len() {
            let mut off = 0;
            assert!(
                RleU64Col::decode(&col[..cut], &mut off, MAX_COLUMN_ELEMS).is_none(),
                "cut {cut}"
            );
        }
        // Same for the boolean runs: overflow, truncation, and a bad start byte.
        let bits = [true, true, true, false, false, true, false, false, false, false];
        let mut col = Vec::new();
        BoolRleCol::encode(&bits, &mut col);
        for cut in 0..col.len() {
            let mut off = 0;
            assert!(
                BoolRleCol::decode(&col[..cut], &mut off, MAX_COLUMN_ELEMS).is_none(),
                "cut {cut}"
            );
        }
        let mut buf = Vec::new();
        put_uvarint(&mut buf, 3);
        buf.push(MODE_PACKED);
        buf.push(2); // start bit must be 0 or 1
        put_uvarint(&mut buf, 3);
        let mut off = 0;
        assert!(BoolRleCol::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
    }

    #[test]
    fn unknown_mode_bytes_rejected() {
        for mode in [2u8, 0x7f, 0xff] {
            let mut buf = Vec::new();
            put_uvarint(&mut buf, 2);
            buf.push(mode);
            buf.extend_from_slice(&[0u8; 16]);
            let mut off = 0;
            assert!(DeltaU64Col::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
            let mut off = 0;
            assert!(RleU64Col::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
            let mut off = 0;
            assert!(BoolRleCol::decode(&buf, &mut off, MAX_COLUMN_ELEMS).is_none());
        }
    }

    #[test]
    fn columns_concatenate_and_leave_trailing_bytes_alone() {
        let ids: Vec<u64> = (0..50u64).map(|i| i * 11).collect();
        let bits: Vec<bool> = (0..50).map(|i| i % 7 == 0).collect();
        let mut buf = Vec::new();
        DeltaU64Col::encode(&ids, &mut buf);
        BoolRleCol::encode(&bits, &mut buf);
        buf.push(0xEE); // caller's trailing byte, not ours
        let mut off = 0;
        assert_eq!(DeltaU64Col::decode(&buf, &mut off, 64).as_deref(), Some(&ids[..]));
        assert_eq!(BoolRleCol::decode(&buf, &mut off, 64).as_deref(), Some(&bits[..]));
        assert_eq!(off, buf.len() - 1);
    }
}
