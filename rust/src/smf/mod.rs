//! Set-membership filters (§8.1): Bloom filter, counting Bloom filter, compressed Bloom.
//!
//! Used three ways in this repo:
//! * the bidirectional protocol attaches a Bloom filter of the sender's current estimate set
//!   to each residue message to prevent *common hallucinations* (§5.2);
//! * Graphene (the unidirectional baseline) couples a Bloom filter with an IBLT;
//! * the CBF approximate-SetX baseline [Guo & Li 2013] is a counting Bloom filter protocol.

use crate::hash::double_hash;
use crate::wire::column::{peek_count, put_uvarint, take_uvarint, BoolRleCol, Column};

/// Classic Bloom filter over 64-bit ids.
#[derive(Clone, Debug)]
pub struct BloomFilter {
    bits: Vec<u64>,
    nbits: u64,
    k: u32,
    seed: u64,
}

impl BloomFilter {
    /// Filter with `nbits` bits and `k` hash functions.
    pub fn new(nbits: u64, k: u32, seed: u64) -> Self {
        let nbits = nbits.max(8);
        BloomFilter {
            bits: vec![0u64; nbits.div_ceil(64) as usize],
            nbits,
            k: k.max(1),
            seed,
        }
    }

    /// Size a filter for `n` items at false-positive rate `fpr` (standard formulas:
    /// bits = −n·ln(fpr)/ln²2, k = (bits/n)·ln2).
    pub fn with_fpr(n: usize, fpr: f64, seed: u64) -> Self {
        let n = n.max(1) as f64;
        let fpr = fpr.clamp(1e-9, 0.5);
        let nbits = (-n * fpr.ln() / (std::f64::consts::LN_2 * std::f64::consts::LN_2)).ceil();
        let k = ((nbits / n) * std::f64::consts::LN_2).round().max(1.0);
        BloomFilter::new(nbits as u64, k as u32, seed)
    }

    #[inline]
    pub fn insert(&mut self, id: u64) {
        for h in double_hash(id, self.seed, self.k, self.nbits) {
            self.bits[(h / 64) as usize] |= 1u64 << (h % 64);
        }
    }

    #[inline]
    pub fn contains(&self, id: u64) -> bool {
        double_hash(id, self.seed, self.k, self.nbits)
            .all(|h| self.bits[(h / 64) as usize] & (1u64 << (h % 64)) != 0)
    }

    /// Number of bits (the communication cost of sending this filter uncompressed).
    pub fn nbits(&self) -> u64 {
        self.nbits
    }

    pub fn size_bytes(&self) -> usize {
        self.nbits.div_ceil(8) as usize
    }

    pub fn k(&self) -> u32 {
        self.k
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Serialize: header (nbits, k, seed) + bit array.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(20 + self.bits.len() * 8);
        out.extend_from_slice(&self.nbits.to_le_bytes());
        out.extend_from_slice(&self.k.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        let nbytes = self.nbits.div_ceil(8) as usize;
        let mut bytes = vec![0u8; self.bits.len() * 8];
        for (i, w) in self.bits.iter().enumerate() {
            bytes[8 * i..8 * i + 8].copy_from_slice(&w.to_le_bytes());
        }
        bytes.truncate(nbytes);
        out.extend_from_slice(&bytes);
        out
    }

    pub fn from_bytes(data: &[u8]) -> Option<Self> {
        if data.len() < 20 {
            return None;
        }
        let nbits = u64::from_le_bytes(data[0..8].try_into().ok()?);
        // A zero-width filter can never have been built (`new` floors nbits at 8) and
        // would panic the first `contains` query (`h % nbits`).
        if nbits == 0 {
            return None;
        }
        let k = u32::from_le_bytes(data[8..12].try_into().ok()?);
        // Sanity bound on the hash count: `with_fpr` yields k = ⌈−log₂ fpr⌉ (≈ 7 at the
        // protocol's defaults); an adversarial k would turn every `contains` query into
        // billions of hash evaluations.
        if k == 0 || k > 64 {
            return None;
        }
        let seed = u64::from_le_bytes(data[12..20].try_into().ok()?);
        let nbytes = nbits.div_ceil(8) as usize;
        if data.len() < 20 + nbytes {
            return None;
        }
        let mut bits = vec![0u64; nbits.div_ceil(64) as usize];
        for (i, b) in data[20..20 + nbytes].iter().enumerate() {
            bits[i / 8] |= (*b as u64) << (8 * (i % 8));
        }
        Some(BloomFilter { bits, nbits, k, seed })
    }

    /// Fraction of set bits (used to estimate the realized FPR: fpr ≈ fill^k).
    pub fn fill_ratio(&self) -> f64 {
        let ones: u64 = self.bits.iter().map(|w| w.count_ones() as u64).sum();
        ones as f64 / self.nbits as f64
    }

    /// Columnar serialization (codec-on sessions): `varint k | seed:8B | boolean-RLE
    /// bitmap`. `nbits` is carried by the column's element count instead of a fixed
    /// 8-byte header word, so even a half-full filter (the optimally-sized steady state,
    /// where run-length framing can't beat bitpacking) costs ~8 bytes less than
    /// [`BloomFilter::to_bytes`]; underloaded filters collapse much further.
    pub fn to_codec_bytes(&self) -> Vec<u8> {
        let bools: Vec<bool> = (0..self.nbits)
            .map(|i| self.bits[(i / 64) as usize] >> (i % 64) & 1 == 1)
            .collect();
        let mut out = Vec::with_capacity(12 + self.nbits.div_ceil(8) as usize);
        put_uvarint(&mut out, self.k as u64);
        out.extend_from_slice(&self.seed.to_le_bytes());
        BoolRleCol::encode(&bools, &mut out);
        out
    }

    /// Parse the [`BloomFilter::to_codec_bytes`] form. Stricter than the legacy parser:
    /// trailing bytes are rejected (the frame envelope already delimits the blob), as is
    /// an empty bitmap.
    pub fn from_codec_bytes(data: &[u8]) -> Option<Self> {
        let mut off = 0usize;
        let k = u32::try_from(take_uvarint(data, &mut off)?).ok()?;
        if k == 0 || k > 64 {
            return None;
        }
        let seed = u64::from_le_bytes(data.get(off..off.checked_add(8)?)?.try_into().ok()?);
        off += 8;
        let bools = BoolRleCol::decode(data, &mut off, usize::MAX)?;
        if off != data.len() || bools.is_empty() {
            return None;
        }
        let nbits = bools.len() as u64;
        let mut bits = vec![0u64; nbits.div_ceil(64) as usize];
        for (i, &b) in bools.iter().enumerate() {
            if b {
                bits[i / 64] |= 1u64 << (i % 64);
            }
        }
        Some(BloomFilter { bits, nbits, k, seed })
    }
}

/// Flat (legacy) serialized size of a filter given only its codec blob — a cheap header
/// peek, no bitmap decode. This is how `Msg::raw_wire_len` charges the
/// codec-off-equivalent cost of an SMF attachment.
pub fn codec_bytes_flat_len(data: &[u8]) -> Option<usize> {
    let mut off = 0usize;
    let _k = take_uvarint(data, &mut off)?;
    off = off.checked_add(8)?; // seed
    if off > data.len() {
        return None;
    }
    let nbits = peek_count(data, &mut off)?;
    Some(20 + nbits.div_ceil(8))
}

/// Counting Bloom filter (§8.1): counters instead of bits; supports deletion and
/// subtraction — the substrate of the approximate-SetX baseline of [Guo & Li 2013].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CountingBloomFilter {
    pub counts: Vec<i32>,
    k: u32,
    seed: u64,
}

impl CountingBloomFilter {
    pub fn new(ncells: u64, k: u32, seed: u64) -> Self {
        CountingBloomFilter { counts: vec![0; ncells.max(8) as usize], k: k.max(1), seed }
    }

    #[inline]
    fn cells(&self, id: u64) -> impl Iterator<Item = u64> + '_ {
        double_hash(id, self.seed, self.k, self.counts.len() as u64)
    }

    pub fn insert(&mut self, id: u64) {
        let idx: Vec<u64> = self.cells(id).collect();
        for h in idx {
            self.counts[h as usize] += 1;
        }
    }

    pub fn remove(&mut self, id: u64) {
        let idx: Vec<u64> = self.cells(id).collect();
        for h in idx {
            self.counts[h as usize] -= 1;
        }
    }

    /// Membership test treating nonzero counters as set bits.
    pub fn contains(&self, id: u64) -> bool {
        self.cells(id).all(|h| self.counts[h as usize] != 0)
    }

    /// Cell-wise difference (`CBF(B) − CBF(A)` in the [Guo & Li] protocol).
    pub fn sub(&self, other: &CountingBloomFilter) -> CountingBloomFilter {
        assert_eq!(self.counts.len(), other.counts.len());
        assert_eq!((self.k, self.seed), (other.k, other.seed));
        CountingBloomFilter {
            counts: self
                .counts
                .iter()
                .zip(&other.counts)
                .map(|(a, b)| a - b)
                .collect(),
            k: self.k,
            seed: self.seed,
        }
    }

    /// "Positive" membership test in a *difference* CBF: all cells strictly positive.
    /// This is how [Guo & Li] approximates `B \ A` from `CBF(B) − CBF(A)`.
    pub fn contains_positive(&self, id: u64) -> bool {
        self.cells(id).all(|h| self.counts[h as usize] > 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bloom_no_false_negatives() {
        let mut bf = BloomFilter::with_fpr(1000, 0.01, 5);
        for id in 0..1000u64 {
            bf.insert(id * 3);
        }
        for id in 0..1000u64 {
            assert!(bf.contains(id * 3));
        }
    }

    #[test]
    fn bloom_fpr_near_target() {
        let mut bf = BloomFilter::with_fpr(10_000, 0.01, 6);
        for id in 0..10_000u64 {
            bf.insert(id);
        }
        let fps = (10_000..110_000u64).filter(|&id| bf.contains(id)).count();
        let fpr = fps as f64 / 100_000.0;
        assert!(fpr < 0.02, "fpr {fpr}");
        assert!(fpr > 0.002, "fpr suspiciously low {fpr}");
    }

    #[test]
    fn bloom_roundtrip_bytes() {
        let mut bf = BloomFilter::new(1001, 3, 9);
        for id in [5u64, 17, 255, 1 << 40] {
            bf.insert(id);
        }
        let bytes = bf.to_bytes();
        let back = BloomFilter::from_bytes(&bytes).unwrap();
        assert_eq!(back.nbits, bf.nbits);
        assert_eq!(back.k, bf.k);
        for id in 0..2000u64 {
            assert_eq!(bf.contains(id), back.contains(id), "id {id}");
        }
    }

    #[test]
    fn bloom_from_bytes_rejects_short() {
        assert!(BloomFilter::from_bytes(&[1, 2, 3]).is_none());
        let bf = BloomFilter::new(128, 2, 1);
        let mut bytes = bf.to_bytes();
        bytes.truncate(bytes.len() - 1);
        assert!(BloomFilter::from_bytes(&bytes).is_none());
    }

    #[test]
    fn bloom_codec_bytes_roundtrip_and_flat_len() {
        for (n, fpr) in [(50usize, 0.01), (1000, 0.001), (8, 0.1)] {
            let mut bf = BloomFilter::with_fpr(n, fpr, 0xb100_f11e);
            for id in 0..n as u64 {
                bf.insert(id.wrapping_mul(0x9e37_79b9_7f4a_7c15));
            }
            let blob = bf.to_codec_bytes();
            let back = BloomFilter::from_codec_bytes(&blob).unwrap();
            assert_eq!((back.nbits, back.k, back.seed), (bf.nbits, bf.k, bf.seed));
            for id in 0..2 * n as u64 {
                let probe = id.wrapping_mul(0x9e37_79b9_7f4a_7c15);
                assert_eq!(bf.contains(probe), back.contains(probe), "id {id}");
            }
            // The raw-accounting peek recovers the exact legacy size without a decode,
            // and the codec form is strictly smaller even at optimal (~0.5) fill.
            assert_eq!(codec_bytes_flat_len(&blob), Some(bf.to_bytes().len()));
            assert!(blob.len() < bf.to_bytes().len(), "n={n} fpr={fpr}");
        }
        // A barely-loaded filter's bitmap collapses to a handful of run lengths.
        let mut sparse = BloomFilter::new(4096, 4, 7);
        sparse.insert(99);
        assert!(sparse.to_codec_bytes().len() < sparse.to_bytes().len() / 10);
    }

    #[test]
    fn bloom_codec_bytes_rejects_malformed() {
        let mut bf = BloomFilter::new(256, 3, 9);
        bf.insert(1);
        let blob = bf.to_codec_bytes();
        // Truncation at every byte boundary.
        for cut in 0..blob.len() {
            assert!(BloomFilter::from_codec_bytes(&blob[..cut]).is_none(), "cut {cut}");
        }
        // Trailing garbage (the frame envelope delimits the blob exactly).
        let mut long = blob.clone();
        long.push(0xEE);
        assert!(BloomFilter::from_codec_bytes(&long).is_none());
        // k outside [1, 64].
        let mut bad_k = blob.clone();
        bad_k[0] = 0;
        assert!(BloomFilter::from_codec_bytes(&bad_k).is_none());
        bad_k[0] = 65;
        assert!(BloomFilter::from_codec_bytes(&bad_k).is_none());
        // An empty bitmap can never have been produced.
        let mut empty = vec![3u8]; // k
        empty.extend_from_slice(&9u64.to_le_bytes());
        empty.push(0); // bitmap column: n = 0
        assert!(BloomFilter::from_codec_bytes(&empty).is_none());
        // The legacy parser now also rejects a zero-width filter header.
        let mut zero = vec![0u8; 20];
        zero[8] = 3; // k = 3, nbits = 0
        assert!(BloomFilter::from_bytes(&zero).is_none());
    }

    #[test]
    fn cbf_insert_remove_roundtrip() {
        let mut cbf = CountingBloomFilter::new(4096, 4, 3);
        for id in 0..100u64 {
            cbf.insert(id);
        }
        assert!(cbf.contains(50));
        for id in 0..100u64 {
            cbf.remove(id);
        }
        assert_eq!(cbf, CountingBloomFilter::new(4096, 4, 3));
    }

    #[test]
    fn cbf_difference_identifies_unique_mostly() {
        let mut a = CountingBloomFilter::new(1 << 14, 4, 3);
        let mut b = CountingBloomFilter::new(1 << 14, 4, 3);
        let common: Vec<u64> = (0..500).collect();
        for &id in &common {
            a.insert(id);
            b.insert(id);
        }
        for id in 1000..1050u64 {
            b.insert(id); // unique to B
        }
        let diff = b.sub(&a);
        // All truly-unique elements pass the positive test (no false negatives on B\A when
        // counts don't collide destructively; with this load factor collisions are rare).
        let hits = (1000..1050u64).filter(|&id| diff.contains_positive(id)).count();
        assert!(hits >= 48, "hits {hits}");
        // Most common elements do NOT pass.
        let false_hits = common.iter().filter(|&&id| diff.contains_positive(id)).count();
        assert!(false_hits <= 5, "false hits {false_hits}");
    }
}
