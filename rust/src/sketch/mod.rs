//! CS linear sketches: `sk(S) = M·1_S` as an integer-valued l-vector.
//!
//! Because `M` is binary and sparse, the sketch of a set is exactly a counting-Bloom-filter-
//! shaped vector (a coincidence the paper notes in §3.3), every coordinate is a small
//! non-negative integer, and encoding is cheap three ways, engaged in this order:
//!
//! * **Batched one-shot encode** — [`Sketch::encode`] walks the id slice in blocks
//!   through [`crate::hash::ColumnSampler::rows_batch`], which hoists the PRNG seed
//!   pre-mix and the bounds checks out of the per-element loop. Still O(m·|S|)
//!   (Theorem 2's encoding complexity), just with a smaller constant than the old
//!   one-column-at-a-time loop.
//! * **Parallel encode** — [`Sketch::encode_par`] shards the id slice across a bounded
//!   worker pool ([`EncodeConfig::threads`]; `0` = auto, mirroring
//!   [`crate::decoder::DecoderConfig::build_threads`]) into thread-local count vectors
//!   merged by addition — bit-identical to the serial encode (integer adds commute;
//!   property-tested across geometries including the `m = 64` boundary). Sets smaller
//!   than [`PAR_ENCODE_MIN_IDS`] always encode serially: the work cannot amortize the
//!   thread spawn + merge. Drivers that already saturate the machine (the partitioned
//!   pool, the server worker pool) pin `threads = 1`, exactly as they do for decoder
//!   construction.
//! * **Streaming ±1 updates** — [`Sketch::update`] is the §4 data-streaming operation,
//!   O(m) per call; it is also what lets a *cached* sketch be maintained incrementally
//!   under set churn instead of re-encoded (the [`SketchSource`] consumers, e.g. the
//!   server's host-sketch store, apply it over a set diff on `replace_set`).
//!
//! The streaming operations (`Sketch::update`, `Residue::add_column`,
//! `Residue::dot_column`) are O(m) per call **because** they sample the column into a
//! fixed `[u32; MAX_M]` stack buffer instead of allocating — valid only for
//! `m ≤ `[`crate::hash::MAX_M`]` = 64`, an invariant every [`crate::hash::ColumnSampler`]
//! (hence every `CsMatrix`) enforces at construction time, so no allocation-free path here
//! can ever see a larger `m`. (This guard was once a debug-only assertion that release
//! builds skipped, leaving a slice panic deep inside the hot loop; validation now happens
//! once, up front, with a typed error for wire-derived geometry.)
//!
//! Coordinates are `i32`: residues (differences of sketches) are signed, and counts beyond
//! ±2^31 would require |S| ≫ 10^9·l/m, far outside any regime we run.

use crate::hash::MAX_M;
use crate::matrix::CsMatrix;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Below this id count, [`Sketch::encode_par`] always encodes serially — sampling a few
/// thousand columns is microseconds of work and cannot amortize thread spawn + merge.
pub const PAR_ENCODE_MIN_IDS: usize = 4096;

/// Ids per [`crate::hash::ColumnSampler::rows_batch`] block in the encode loops: large
/// enough to amortize the per-call overhead, small enough that the row buffer
/// (`BLOCK × m` u32s ≤ 128 KiB at `m = MAX_M`) stays cache-resident.
const ENCODE_BLOCK_IDS: usize = 512;

/// Encode-side parallelism knob, mirroring [`crate::decoder::DecoderConfig::build_threads`]:
/// `0` = auto (available parallelism), `1` = serial, clamped to 64. This is a **local**
/// performance setting with no wire or result impact — `encode_par` is bit-identical to
/// the serial encode at every thread count, so the two endpoints of a conversation may
/// configure it differently.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EncodeConfig {
    /// Worker threads for one-shot encodes (`0` = auto, the `Default`; small inputs
    /// stay serial regardless — see [`PAR_ENCODE_MIN_IDS`]).
    pub threads: usize,
}

impl EncodeConfig {
    /// Auto parallelism (`threads = 0`) — the default.
    pub fn auto() -> Self {
        EncodeConfig { threads: 0 }
    }

    /// Always-serial encoding — what nested drivers (partitioned workers, server worker
    /// pools) pin so encode threads don't multiply with their own pool.
    pub fn serial() -> Self {
        EncodeConfig { threads: 1 }
    }

    /// Resolve the knob into a worker count for `n` ids (1 ⇒ take the serial path).
    fn resolve(self, n: usize) -> usize {
        if n < PAR_ENCODE_MIN_IDS {
            return 1;
        }
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.clamp(1, 64).min(n.div_ceil(ENCODE_BLOCK_IDS))
    }
}

/// A provider of host-set sketches that may cache across sessions.
///
/// The encode-side sibling of [`crate::decoder::DecoderStore`]: a server answering many
/// clients against one hot set re-derives `M·1_host` for every session that negotiates a
/// geometry it has already seen — pure waste, since the sketch is a function of
/// `(matrix, set)` alone. Implementations (e.g. `server::SketchStore`) hand back a shared
/// [`Arc<Sketch>`] in O(1) on a cache hit. The contract is strict: the returned sketch
/// **must** equal `Sketch::encode(*matrix, set)` exactly — consumers feed it straight
/// into residue arithmetic and sketch recovery, where a stale coordinate corrupts the
/// decode silently.
pub trait SketchSource: Send + Sync {
    /// The sketch of `set` under `matrix` (encoding with `enc` on a miss).
    fn host_sketch(&self, matrix: &CsMatrix, set: &[u64], enc: EncodeConfig) -> Arc<Sketch>;
}

/// An integer CS sketch `M·x` for an integer-valued signal `x` (usually 0/1).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Sketch {
    pub matrix: CsMatrix,
    pub counts: Vec<i32>,
}

impl Sketch {
    /// Zero sketch.
    pub fn zero(matrix: CsMatrix) -> Self {
        Sketch { matrix, counts: vec![0; matrix.l() as usize] }
    }

    /// One-shot encode of a set: `M·1_S`. O(m·|S|), serial; columns are sampled in
    /// 512-id batches ([`crate::hash::ColumnSampler::rows_batch`]) so the per-element
    /// loop carries no PRNG seeding or bounds-check overhead.
    pub fn encode(matrix: CsMatrix, ids: &[u64]) -> Self {
        let mut sk = Self::zero(matrix);
        accumulate(&matrix, ids, &mut sk.counts);
        sk
    }

    /// [`Sketch::encode`] on a bounded worker pool: chunk `ids` across `enc.threads`
    /// workers (0 = auto), each accumulating into a thread-local count vector, and merge
    /// by addition. Bit-identical to the serial encode — the count of a row is a sum of
    /// independent per-id contributions, and integer addition is exact and commutative —
    /// which the property tests pin across geometries including `m = `[`MAX_M`].
    /// Inputs below [`PAR_ENCODE_MIN_IDS`] take the serial path unconditionally.
    pub fn encode_par(matrix: CsMatrix, ids: &[u64], enc: EncodeConfig) -> Self {
        let threads = enc.resolve(ids.len());
        if threads == 1 {
            return Self::encode(matrix, ids);
        }
        let l = matrix.l() as usize;
        // Workers race on an atomic chunk counter (the same bounded-pool discipline as
        // decoder construction); chunk assignment does not affect the result, so no
        // ordered merge is needed — locals just sum into the final counts.
        let num_chunks = ids.len().div_ceil(ENCODE_BLOCK_IDS);
        let next = AtomicUsize::new(0);
        let locals: Mutex<Vec<Vec<i32>>> = Mutex::new(Vec::with_capacity(threads));
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| {
                    let mut counts = vec![0i32; l];
                    let mut rows = vec![0u32; ENCODE_BLOCK_IDS * matrix.m() as usize];
                    loop {
                        let c = next.fetch_add(1, Ordering::Relaxed);
                        if c >= num_chunks {
                            break;
                        }
                        let lo = c * ENCODE_BLOCK_IDS;
                        let hi = (lo + ENCODE_BLOCK_IDS).min(ids.len());
                        accumulate_with(&matrix, &ids[lo..hi], &mut counts, &mut rows);
                    }
                    locals.lock().expect("encode worker locals").push(counts);
                });
            }
        });
        let mut sk = Self::zero(matrix);
        for local in locals.into_inner().expect("encode worker locals") {
            for (dst, src) in sk.counts.iter_mut().zip(&local) {
                *dst += src;
            }
        }
        sk
    }

    /// Streaming 1-sparse update: add `delta` (±1 for insert/delete) times column `id`.
    /// This is the §4 data-streaming operation; O(m).
    #[inline]
    pub fn update(&mut self, id: u64, delta: i32) {
        // m ≤ MAX_M is enforced at ColumnSampler construction (see the module docs), so
        // the stack buffer always fits the column.
        let mut buf = [0u32; MAX_M as usize];
        let m = self.matrix.m() as usize;
        for &r in self.matrix.column_into(id, &mut buf[..m]) {
            self.counts[r as usize] += delta;
        }
    }

    /// `self - other`, e.g. Bob computes `M·1_B − M·1_A` = the measurement of `1_{B\A} − 1_{A\B}`.
    pub fn sub(&self, other: &Sketch) -> Residue {
        assert_eq!(self.matrix, other.matrix, "sketches from different matrices");
        Residue {
            matrix: self.matrix,
            values: self
                .counts
                .iter()
                .zip(&other.counts)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// L1 norm of the sketch (= m·|S| for a set sketch; used in sanity checks).
    pub fn l1(&self) -> u64 {
        self.counts.iter().map(|&c| c.unsigned_abs() as u64).sum()
    }
}

/// Scatter-add every id's column into `counts`, block-batching the column sampling.
/// The shared inner loop of [`Sketch::encode`] and each [`Sketch::encode_par`] worker.
fn accumulate(matrix: &CsMatrix, ids: &[u64], counts: &mut [i32]) {
    let m = matrix.m() as usize;
    let mut rows = vec![0u32; ENCODE_BLOCK_IDS.min(ids.len().max(1)) * m];
    accumulate_with(matrix, ids, counts, &mut rows);
}

/// [`accumulate`] with a caller-owned row scratch (`≥ min(|ids|, block) · m` long), so
/// the parallel workers allocate it once per worker instead of once per chunk.
fn accumulate_with(matrix: &CsMatrix, ids: &[u64], counts: &mut [i32], rows: &mut [u32]) {
    let m = matrix.m() as usize;
    for block in ids.chunks(ENCODE_BLOCK_IDS) {
        let filled = &mut rows[..block.len() * m];
        matrix.sampler.rows_batch(block, filled);
        for &r in filled.iter() {
            counts[r as usize] += 1;
        }
    }
}

/// A signed residue vector — the measurement a decoder works on. Identical storage to a
/// sketch but semantically a *difference* of sketches that the MP decoder drives to zero.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Residue {
    pub matrix: CsMatrix,
    pub values: Vec<i32>,
}

impl Residue {
    pub fn from_values(matrix: CsMatrix, values: Vec<i32>) -> Self {
        assert_eq!(values.len(), matrix.l() as usize);
        Residue { matrix, values }
    }

    pub fn zero(matrix: CsMatrix) -> Self {
        Residue { matrix, values: vec![0; matrix.l() as usize] }
    }

    #[inline]
    pub fn is_zero(&self) -> bool {
        self.values.iter().all(|&v| v == 0)
    }

    /// Squared L2 norm (fits u64: values are small).
    pub fn l2_sq(&self) -> u64 {
        self.values.iter().map(|&v| (v as i64 * v as i64) as u64).sum()
    }

    pub fn l1(&self) -> u64 {
        self.values.iter().map(|&v| v.unsigned_abs() as u64).sum()
    }

    /// Add `delta`·column(id). Used by decoders when (un)pursuing a coordinate.
    #[inline]
    pub fn add_column(&mut self, id: u64, delta: i32) {
        // m ≤ MAX_M by ColumnSampler construction (module docs).
        let mut buf = [0u32; MAX_M as usize];
        let m = self.matrix.m() as usize;
        for &r in self.matrix.column_into(id, &mut buf[..m]) {
            self.values[r as usize] += delta;
        }
    }

    /// Negate in place (used when the decoding side's signal has the opposite sign).
    pub fn negate(&mut self) {
        for v in &mut self.values {
            *v = -*v;
        }
    }

    /// Dot product of the residue with column `id` — `m·δ_i` in the paper's notation
    /// (eq. B.1: the optimal L2 pursuit step is `δ_i = rᵀm_i / m`).
    #[inline]
    pub fn dot_column(&self, id: u64) -> i32 {
        // m ≤ MAX_M by ColumnSampler construction (module docs).
        let mut buf = [0u32; MAX_M as usize];
        let m = self.matrix.m() as usize;
        let mut dot = 0i32;
        for &r in self.matrix.column_into(id, &mut buf[..m]) {
            dot += self.values[r as usize];
        }
        dot
    }

    /// Sample mean and (population) variance of coordinates — the method-of-moments inputs
    /// for the Skellam entropy model (Appendix C.1).
    pub fn moments(&self) -> (f64, f64) {
        let n = self.values.len() as f64;
        let mean = self.values.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var = self
            .values
            .iter()
            .map(|&v| {
                let d = v as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / n;
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat() -> CsMatrix {
        CsMatrix::new(256, 5, 7)
    }

    #[test]
    fn encode_linear_in_elements() {
        let m = mat();
        let a = Sketch::encode(m, &[1, 2, 3]);
        let b = Sketch::encode(m, &[3, 4]);
        let union_with_multiplicity = Sketch::encode(m, &[1, 2, 3, 3, 4]);
        let sum: Vec<i32> = a.counts.iter().zip(&b.counts).map(|(x, y)| x + y).collect();
        assert_eq!(sum, union_with_multiplicity.counts);
    }

    #[test]
    fn sketch_l1_is_m_times_cardinality() {
        let m = mat();
        let sk = Sketch::encode(m, &(0..100u64).collect::<Vec<_>>());
        assert_eq!(sk.l1(), 5 * 100);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let m = mat();
        let ids: Vec<u64> = (0..50).map(|i| i * 977).collect();
        let oneshot = Sketch::encode(m, &ids);
        let mut streaming = Sketch::zero(m);
        for &id in &ids {
            streaming.update(id, 1);
        }
        assert_eq!(oneshot, streaming);
        // Deleting everything returns to zero.
        for &id in &ids {
            streaming.update(id, -1);
        }
        assert_eq!(streaming, Sketch::zero(m));
    }

    #[test]
    fn subtraction_cancels_intersection() {
        let m = mat();
        // A = {common} ∪ {10}, B = {common} ∪ {20, 30}
        let common: Vec<u64> = (100..200).collect();
        let mut a = common.clone();
        a.push(10);
        let mut b = common.clone();
        b.extend([20, 30]);
        let r = Sketch::encode(m, &b).sub(&Sketch::encode(m, &a));
        // r = M(1_{B\A} - 1_{A\B}) — only 3 columns' worth of mass.
        assert_eq!(r.l1() <= 3 * 5, true);
        let mut expect = Residue::zero(m);
        expect.add_column(20, 1);
        expect.add_column(30, 1);
        expect.add_column(10, -1);
        assert_eq!(r, expect);
    }

    #[test]
    fn dot_column_equals_manual() {
        let m = mat();
        let mut r = Residue::zero(m);
        r.add_column(42, 1);
        assert_eq!(r.dot_column(42), 5); // full self-overlap
        assert_eq!(r.l2_sq(), 5);
    }

    #[test]
    fn moments_of_zero_residue() {
        let r = Residue::zero(mat());
        assert_eq!(r.moments(), (0.0, 0.0));
    }

    #[test]
    fn encode_par_is_bit_identical_to_serial_across_geometries() {
        // The tentpole property: for random geometries — including the m = MAX_M = 64
        // stack-buffer boundary — and sets straddling the PAR_ENCODE_MIN_IDS threshold,
        // the parallel encode equals the serial one coordinate-for-coordinate at every
        // thread count (0 = auto included).
        let geometries =
            [(256u32, 5u32, 7u64), (1024, 7, 1), (64, 64, 3), (4096, MAX_M, 9), (128, 1, 11)];
        for &(l, m, seed) in &geometries {
            let matrix = CsMatrix::new(l, m, seed);
            for n in [0usize, 17, PAR_ENCODE_MIN_IDS - 1, PAR_ENCODE_MIN_IDS + 513] {
                let ids: Vec<u64> =
                    (0..n as u64).map(|i| i.wrapping_mul(0x9e3779b97f4a7c15) ^ seed).collect();
                let serial = Sketch::encode(matrix, &ids);
                for threads in [0usize, 1, 2, 4, 7] {
                    let par = Sketch::encode_par(matrix, &ids, EncodeConfig { threads });
                    assert_eq!(
                        par, serial,
                        "l={l} m={m} n={n} threads={threads} diverged from serial"
                    );
                }
            }
        }
    }

    #[test]
    fn encode_par_handles_duplicate_ids_like_serial() {
        // Multiplicities are legal inputs (encode is linear, not set-semantic): chunk
        // boundaries must not change how repeated columns accumulate.
        let matrix = CsMatrix::new(512, 6, 21);
        let ids: Vec<u64> = (0..(PAR_ENCODE_MIN_IDS as u64 + 1000)).map(|i| i % 97).collect();
        let serial = Sketch::encode(matrix, &ids);
        let par = Sketch::encode_par(matrix, &ids, EncodeConfig { threads: 4 });
        assert_eq!(par, serial);
        assert_eq!(serial.l1(), 6 * ids.len() as u64);
    }

    #[test]
    fn party_sketch_sums_equal_the_multiset_union_sketch() {
        // The multi-party aggregation invariant (see `setx::multi`): Σᵢ sk(Sᵢ) is
        // bit-exactly the sketch of the multiset union — across geometries including the
        // m = MAX_M boundary, with ids shared between parties (multiplicities add, never
        // saturate) and duplicated within a single party.
        let geometries = [(256u32, 5u32, 7u64), (1024, 7, 13), (512, MAX_M, 3), (128, 1, 19)];
        for &(l, m, seed) in &geometries {
            let matrix = CsMatrix::new(l, m, seed);
            let core: Vec<u64> = (0..120u64).map(|i| i.wrapping_mul(0x9e37_79b9) ^ seed).collect();
            let parties: Vec<Vec<u64>> = (0..4u64)
                .map(|p| {
                    let mut s = core.clone();
                    s.extend((0..40u64).map(|i| (1_000_000 + p * 1_000 + i).wrapping_mul(31)));
                    s.push(core[0]); // within-party duplicate: encode is multiset-linear
                    s
                })
                .collect();
            let mut sum = vec![0i32; l as usize];
            let mut union: Vec<u64> = Vec::new();
            for s in &parties {
                let sk = Sketch::encode(matrix, s);
                for (d, c) in sum.iter_mut().zip(&sk.counts) {
                    *d += c;
                }
                union.extend_from_slice(s);
            }
            let direct = Sketch::encode(matrix, &union);
            assert_eq!(sum, direct.counts, "l={l} m={m}: sum of party sketches != union sketch");
            // The parallel encode agrees on the aggregate input too.
            assert_eq!(Sketch::encode_par(matrix, &union, EncodeConfig { threads: 4 }), direct);
        }
    }

    #[test]
    fn encode_config_resolution_floors_and_clamps() {
        assert_eq!(EncodeConfig::serial().resolve(1 << 20), 1, "serial stays serial");
        assert_eq!(EncodeConfig { threads: 8 }.resolve(100), 1, "small inputs stay serial");
        assert_eq!(EncodeConfig { threads: 999 }.resolve(1 << 20), 64, "clamped to 64");
        assert!(EncodeConfig::auto().resolve(1 << 20) >= 1);
        // Never more workers than batch-sized chunks of work.
        assert!(EncodeConfig { threads: 64 }.resolve(PAR_ENCODE_MIN_IDS) <= 64);
    }
}
