//! CS linear sketches: `sk(S) = M·1_S` as an integer-valued l-vector.
//!
//! Because `M` is binary and sparse, the sketch of a set is exactly a counting-Bloom-filter-
//! shaped vector (a coincidence the paper notes in §3.3), every coordinate is a small
//! non-negative integer, and both one-shot encoding (O(m) per element) and streaming ±1-sparse
//! updates (§4) are cheap.
//!
//! The streaming operations (`Sketch::update`, `Residue::add_column`,
//! `Residue::dot_column`) are O(m) per call **because** they sample the column into a
//! fixed `[u32; MAX_M]` stack buffer instead of allocating — valid only for
//! `m ≤ `[`crate::hash::MAX_M`]` = 64`, an invariant every [`crate::hash::ColumnSampler`]
//! (hence every `CsMatrix`) enforces at construction time, so no allocation-free path here
//! can ever see a larger `m`. (This guard was once a debug-only assertion that release
//! builds skipped, leaving a slice panic deep inside the hot loop; validation now happens
//! once, up front, with a typed error for wire-derived geometry.)
//!
//! Coordinates are `i32`: residues (differences of sketches) are signed, and counts beyond
//! ±2^31 would require |S| ≫ 10^9·l/m, far outside any regime we run.

use crate::hash::MAX_M;
use crate::matrix::CsMatrix;

/// An integer CS sketch `M·x` for an integer-valued signal `x` (usually 0/1).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Sketch {
    pub matrix: CsMatrix,
    pub counts: Vec<i32>,
}

impl Sketch {
    /// Zero sketch.
    pub fn zero(matrix: CsMatrix) -> Self {
        Sketch { matrix, counts: vec![0; matrix.l() as usize] }
    }

    /// One-shot encode of a set: `M·1_S`. O(m·|S|).
    pub fn encode(matrix: CsMatrix, ids: &[u64]) -> Self {
        let mut sk = Self::zero(matrix);
        let mut buf = vec![0u32; matrix.m() as usize];
        for &id in ids {
            for &r in matrix.column_into(id, &mut buf) {
                sk.counts[r as usize] += 1;
            }
        }
        sk
    }

    /// Streaming 1-sparse update: add `delta` (±1 for insert/delete) times column `id`.
    /// This is the §4 data-streaming operation; O(m).
    #[inline]
    pub fn update(&mut self, id: u64, delta: i32) {
        // m ≤ MAX_M is enforced at ColumnSampler construction (see the module docs), so
        // the stack buffer always fits the column.
        let mut buf = [0u32; MAX_M as usize];
        let m = self.matrix.m() as usize;
        for &r in self.matrix.column_into(id, &mut buf[..m]) {
            self.counts[r as usize] += delta;
        }
    }

    /// `self - other`, e.g. Bob computes `M·1_B − M·1_A` = the measurement of `1_{B\A} − 1_{A\B}`.
    pub fn sub(&self, other: &Sketch) -> Residue {
        assert_eq!(self.matrix, other.matrix, "sketches from different matrices");
        Residue {
            matrix: self.matrix,
            values: self
                .counts
                .iter()
                .zip(&other.counts)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// L1 norm of the sketch (= m·|S| for a set sketch; used in sanity checks).
    pub fn l1(&self) -> u64 {
        self.counts.iter().map(|&c| c.unsigned_abs() as u64).sum()
    }
}

/// A signed residue vector — the measurement a decoder works on. Identical storage to a
/// sketch but semantically a *difference* of sketches that the MP decoder drives to zero.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Residue {
    pub matrix: CsMatrix,
    pub values: Vec<i32>,
}

impl Residue {
    pub fn from_values(matrix: CsMatrix, values: Vec<i32>) -> Self {
        assert_eq!(values.len(), matrix.l() as usize);
        Residue { matrix, values }
    }

    pub fn zero(matrix: CsMatrix) -> Self {
        Residue { matrix, values: vec![0; matrix.l() as usize] }
    }

    #[inline]
    pub fn is_zero(&self) -> bool {
        self.values.iter().all(|&v| v == 0)
    }

    /// Squared L2 norm (fits u64: values are small).
    pub fn l2_sq(&self) -> u64 {
        self.values.iter().map(|&v| (v as i64 * v as i64) as u64).sum()
    }

    pub fn l1(&self) -> u64 {
        self.values.iter().map(|&v| v.unsigned_abs() as u64).sum()
    }

    /// Add `delta`·column(id). Used by decoders when (un)pursuing a coordinate.
    #[inline]
    pub fn add_column(&mut self, id: u64, delta: i32) {
        // m ≤ MAX_M by ColumnSampler construction (module docs).
        let mut buf = [0u32; MAX_M as usize];
        let m = self.matrix.m() as usize;
        for &r in self.matrix.column_into(id, &mut buf[..m]) {
            self.values[r as usize] += delta;
        }
    }

    /// Negate in place (used when the decoding side's signal has the opposite sign).
    pub fn negate(&mut self) {
        for v in &mut self.values {
            *v = -*v;
        }
    }

    /// Dot product of the residue with column `id` — `m·δ_i` in the paper's notation
    /// (eq. B.1: the optimal L2 pursuit step is `δ_i = rᵀm_i / m`).
    #[inline]
    pub fn dot_column(&self, id: u64) -> i32 {
        // m ≤ MAX_M by ColumnSampler construction (module docs).
        let mut buf = [0u32; MAX_M as usize];
        let m = self.matrix.m() as usize;
        let mut dot = 0i32;
        for &r in self.matrix.column_into(id, &mut buf[..m]) {
            dot += self.values[r as usize];
        }
        dot
    }

    /// Sample mean and (population) variance of coordinates — the method-of-moments inputs
    /// for the Skellam entropy model (Appendix C.1).
    pub fn moments(&self) -> (f64, f64) {
        let n = self.values.len() as f64;
        let mean = self.values.iter().map(|&v| v as f64).sum::<f64>() / n;
        let var = self
            .values
            .iter()
            .map(|&v| {
                let d = v as f64 - mean;
                d * d
            })
            .sum::<f64>()
            / n;
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat() -> CsMatrix {
        CsMatrix::new(256, 5, 7)
    }

    #[test]
    fn encode_linear_in_elements() {
        let m = mat();
        let a = Sketch::encode(m, &[1, 2, 3]);
        let b = Sketch::encode(m, &[3, 4]);
        let union_with_multiplicity = Sketch::encode(m, &[1, 2, 3, 3, 4]);
        let sum: Vec<i32> = a.counts.iter().zip(&b.counts).map(|(x, y)| x + y).collect();
        assert_eq!(sum, union_with_multiplicity.counts);
    }

    #[test]
    fn sketch_l1_is_m_times_cardinality() {
        let m = mat();
        let sk = Sketch::encode(m, &(0..100u64).collect::<Vec<_>>());
        assert_eq!(sk.l1(), 5 * 100);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let m = mat();
        let ids: Vec<u64> = (0..50).map(|i| i * 977).collect();
        let oneshot = Sketch::encode(m, &ids);
        let mut streaming = Sketch::zero(m);
        for &id in &ids {
            streaming.update(id, 1);
        }
        assert_eq!(oneshot, streaming);
        // Deleting everything returns to zero.
        for &id in &ids {
            streaming.update(id, -1);
        }
        assert_eq!(streaming, Sketch::zero(m));
    }

    #[test]
    fn subtraction_cancels_intersection() {
        let m = mat();
        // A = {common} ∪ {10}, B = {common} ∪ {20, 30}
        let common: Vec<u64> = (100..200).collect();
        let mut a = common.clone();
        a.push(10);
        let mut b = common.clone();
        b.extend([20, 30]);
        let r = Sketch::encode(m, &b).sub(&Sketch::encode(m, &a));
        // r = M(1_{B\A} - 1_{A\B}) — only 3 columns' worth of mass.
        assert_eq!(r.l1() <= 3 * 5, true);
        let mut expect = Residue::zero(m);
        expect.add_column(20, 1);
        expect.add_column(30, 1);
        expect.add_column(10, -1);
        assert_eq!(r, expect);
    }

    #[test]
    fn dot_column_equals_manual() {
        let m = mat();
        let mut r = Residue::zero(m);
        r.add_column(42, 1);
        assert_eq!(r.dot_column(42), 5); // full self-overlap
        assert_eq!(r.l2_sq(), 5);
    }

    #[test]
    fn moments_of_zero_residue() {
        let r = Residue::zero(mat());
        assert_eq!(r.moments(), (0.0, 0.0));
    }
}
