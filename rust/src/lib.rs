//! # CommonSense — Efficient Set Intersection (SetX) Protocol Based on Compressed Sensing
//!
//! A full reproduction of the CS.DC 2025 paper *"CommonSense: Efficient Set Intersection
//! (SetX) Protocol Based on Compressed Sensing"* (Meng, Yang, Xu).
//!
//! Two network-connected hosts, Alice (holding set `A`) and Bob (holding set `B`),
//! collaboratively compute the **exact** intersection `A ∩ B` using communication close to the
//! SetX information-theoretic lower bound `d·log2(e·|A|/d)` — far below the SetR lower bound.
//!
//! The library is organized in layers:
//!
//! * **Substrates** — [`hash`] (PRNGs, SipHash, SHA-256, the `g∘h` column sampler),
//!   [`matrix`] (the implicit sparse binary RIP-1 CS matrix), [`sketch`] (CS linear sketches),
//!   [`smf`] (Bloom-family set-membership filters), [`entropy`] (rANS + Skellam models +
//!   statistical truncation), [`ecc`] (GF(2^m)/BCH syndrome decoding).
//! * **Core algorithm** — [`decoder`]: the binary-adapted matching-pursuit (MP) decoder with
//!   the priority-queue + reverse-lookup data structures of Appendix B, plus SSMP and BMP.
//! * **Protocols** — [`protocol`]: unidirectional (§3) and bidirectional ping-pong (§5)
//!   CommonSense, with exact wire-format communication accounting.
//! * **Baselines** — [`baselines`]: IBLT/Difference Digest, Graphene, CBF approximate SetX,
//!   PinSketch, and the information-theoretic [`bounds`].
//! * **Systems layer** — [`streaming`] (§4 digests), [`data`] (synthetic + Ethereum-sim
//!   workloads), [`runtime`] (PJRT/XLA AOT artifact execution), [`coordinator`] (threaded,
//!   dependency-free TCP Alice/Bob nodes and the bounded-pool partitioned parallel SetX;
//!   no tokio — the offline image's crate set doesn't carry it, see DESIGN.md §4).
//!
//! ## Architecture: the sans-io `Session` engine
//!
//! The bidirectional protocol is implemented exactly once, as the sans-io state machine
//! [`protocol::session::Session`]: frames ([`protocol::wire::Msg`]) go in via
//! `Session::on_msg`, and a [`protocol::session::SessionEvent`] comes out — `Reply(Msg)`
//! to transmit, `Continue` while the handshake is still feeding, or `Done(outcome)` at
//! termination. The engine owns the handshake, the sketch exchange, the ping-pong
//! decoder ([`protocol::session::Peer`]), and per-frame byte accounting. Every transport
//! is a thin adapter: [`protocol::bidi::run`] hands frames across in memory
//! ([`protocol::session::drive`] is the one ping-pong loop in the codebase),
//! [`coordinator::tcp`] does socket framing only, and [`coordinator::parallel`] fans
//! sessions over a bounded worker pool. New transports (async, sharded, multi-tenant)
//! need only move bytes.
//!
//! ## Workspace layout
//!
//! The Cargo workspace maps the repo's split source tree explicitly: the library lives at
//! `rust/src/lib.rs`, the `commonsense` CLI at `rust/src/main.rs`, integration tests in
//! `rust/tests/`, self-harnessed bench targets (`harness = false`, run with
//! `cargo bench`) in `rust/benches/`, and runnable examples in `examples/` at the repo
//! root (auto-discovered; run with `cargo run --release --example <name>`). The sibling
//! `python/` tree (AOT kernel compilation) is not part of the Cargo build.
//!
//! ## Quickstart
//!
//! ```
//! use commonsense::protocol::{uni, CsParams};
//! use commonsense::data::synth;
//!
//! // A ⊆ B with 100 elements unique to Bob.
//! let (a, b) = synth::subset_pair(10_000, 100, 42);
//! let params = CsParams::tuned_uni(b.len(), 100);
//! let outcome = uni::run(&a, &b, &params).expect("decode");
//! assert_eq!(outcome.intersection.len(), a.len());
//! ```

pub mod baselines;
pub mod bounds;
pub mod coordinator;
pub mod data;
pub mod decoder;
pub mod ecc;
pub mod entropy;
pub mod experiments;
pub mod hash;
pub mod matrix;
pub mod metrics;
pub mod protocol;
pub mod runtime;
pub mod sketch;
pub mod smf;
pub mod streaming;

/// Element identifiers. Objects are identified by (hashes of) their content; internally we
/// operate on 64-bit ids. When the nominal universe is larger (e.g. `2^256` for Ethereum
/// signatures), communication accounting is parameterized by the nominal universe bit-width
/// `u` while dedup/equality uses the 64-bit internal id (collision probability is negligible
/// at the cardinalities we run; see DESIGN.md §4).
pub type Id = u64;
