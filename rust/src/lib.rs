//! # CommonSense — Efficient Set Intersection (SetX) Protocol Based on Compressed Sensing
//!
//! A full reproduction of the CS.DC 2025 paper *"CommonSense: Efficient Set Intersection
//! (SetX) Protocol Based on Compressed Sensing"* (Meng, Yang, Xu).
//!
//! Two network-connected hosts, Alice (holding set `A`) and Bob (holding set `B`),
//! collaboratively compute the **exact** intersection `A ∩ B` using communication close to the
//! SetX information-theoretic lower bound `d·log2(e·|A|/d)` — far below the SetR lower bound.
//!
//! ## Quickstart
//!
//! The front door is the builder-first [`setx`] facade: declare your set and (optionally)
//! the mode and difference-size policy — by default the endpoints *estimate* `d = |AΔB|`
//! in the handshake, so you never supply it — and run over any transport:
//!
//! ```
//! use commonsense::setx::Setx;
//! use commonsense::data::synth;
//!
//! let (a, b) = synth::overlap_pair(2_000, 40, 60, 42);
//! let alice = Setx::builder(&a).build().unwrap();
//! let bob = Setx::builder(&b).build().unwrap();
//! // In-process run (the in-memory transport); `Setx::run(&mut transport)` drives the
//! // identical endpoint over TCP, and `setx::parallel::run_partitioned` over the
//! // partitioned worker pool.
//! let (ra, rb) = alice.run_pair(&bob).unwrap();
//! assert_eq!(ra.intersection, synth::intersect(&a, &b));
//! assert_eq!(rb.local_unique, synth::difference(&b, &a));
//! println!("{} bytes total ({})", ra.total_bytes(), ra.breakdown());
//! ```
//!
//! Every path returns one [`setx::SetxReport`] (intersection, rounds, attempts, and the
//! per-phase/per-direction byte breakdown) or one typed [`setx::SetxError`]; on a decode
//! failure the endpoints retry on the same connection with the sketch length escalated
//! along a calibrated safety ladder before ever surfacing an error.
//!
//! ## Layers
//!
//! * **Substrates** — [`hash`] (PRNGs, SipHash, SHA-256, the `g∘h` column sampler),
//!   [`matrix`] (the implicit sparse binary RIP-1 CS matrix), [`sketch`] (CS linear sketches),
//!   [`smf`] (Bloom-family set-membership filters), [`entropy`] (rANS + Skellam models +
//!   statistical truncation), [`ecc`] (GF(2^m)/BCH syndrome decoding).
//! * **Core algorithm** — [`decoder`]: the binary-adapted matching-pursuit (MP) decoder with
//!   the priority-queue + reverse-lookup data structures of Appendix B, plus SSMP and BMP.
//! * **Engine** — [`protocol`]: unidirectional (§3) and bidirectional ping-pong (§5)
//!   CommonSense as explicit-parameter state machines with exact wire-format accounting,
//!   plus the §7.1 difference-size estimators ([`protocol::estimate`]).
//! * **Front door** — [`setx`]: the builder API, the [`setx::transport::Transport`]
//!   trait with in-memory and TCP implementations (plus the deterministic
//!   [`setx::transport::fault`] injection decorator), the client retry layer
//!   ([`setx::retry`]), the partitioned-parallel driver, and the escalation ladder.
//!   **Start here**; drop to [`protocol`] only for manual tuning.
//! * **Baselines** — [`baselines`]: IBLT/Difference Digest, Graphene, CBF approximate SetX,
//!   PinSketch, and the information-theoretic [`bounds`].
//! * **Systems layer** — [`server`] (the multi-client reconciliation daemon below),
//!   [`streaming`] (§4 digests), [`data`] (synthetic + Ethereum-sim
//!   workloads), [`runtime`] (PJRT/XLA AOT artifact execution), [`coordinator`] (thin
//!   one-shot TCP serve/connect helpers and the legacy-shaped parallel entry point;
//!   threaded, dependency-free — no tokio in the offline image's crate set, see
//!   DESIGN.md §4).
//!
//! ## Architecture: sans-io all the way down
//!
//! The bidirectional protocol is implemented exactly once, as the sans-io state machine
//! [`protocol::session::Session`]: frames ([`protocol::wire::Msg`]) go in via
//! `Session::on_msg`, and a [`protocol::session::SessionEvent`] comes out. The facade
//! repeats the pattern one level up: a `setx` endpoint wraps the session engine with the
//! estimator handshake (`EstHello`), per-attempt verdicts (`Confirm`), and the escalation
//! ladder — still pure message-in/step-out. Transports therefore stay trivial: the
//! in-memory pair, the TCP framer, and the partitioned pool all just move [`protocol::wire::Msg`]
//! frames, and byte accounting is identical across them *by construction*. New transports
//! (async, sharded, multi-tenant) implement `send`/`recv`/`is_client` and inherit the
//! whole protocol, including parameter estimation and self-healing retries.
//!
//! ## Serving many clients
//!
//! Two server shapes exist, and they are not interchangeable:
//!
//! * **One-shot** — [`coordinator::tcp::serve`] accepts a single connection, runs a
//!   single session, and returns. A debugging and test convenience, not a service.
//! * **Daemon** — [`server::SetxServer`] keeps any number of hot host sets online —
//!   one per *tenant namespace* — and reconciles any number of concurrent clients
//!   against them. The driver is readiness-based, not thread-per-session: a fixed pool
//!   of `workers` poller threads multiplexes every resident connection over
//!   non-blocking sockets and `poll(2)`, each connection a small state machine around
//!   the same sans-io endpoint the point-to-point paths use, so a thousand concurrent
//!   clients cost a thousand small buffers, not a thousand threads. Stalled clients are
//!   reaped by per-connection deadlines (a wedged peer can never pin a poller);
//!   connections beyond `max_inflight_sessions` — or beyond a tenant's quota — receive
//!   a typed `Busy` frame that clients see as [`setx::SetxError::ServerBusy`] (with a
//!   retry hint and the rejecting namespace) rather than a hang or a reset.
//!
//! Clients pick their tenant with `Setx::builder(…).namespace(n)` — carried in the
//! handshake as a versioned field, so namespace-less clients (and the pre-tenancy wire
//! format) land on tenant 0 unchanged. Tenants are administered at runtime through
//! [`server::ServerHandle::add_tenant`] / [`server::ServerHandle::remove_tenant`] /
//! [`server::ServerHandle::replace_tenant_set`]; each gets its own host set, decoder
//! pool and sketch-store shard, quota, and a per-tenant block in
//! [`server::ServerStats`] (shards sum exactly to the globals).
//! [`server::ServerHandle::shutdown`] stops accepting, drains every resident
//! connection to completion, and returns the final stats.
//!
//! The daemon's performance core is two reuse layers over one observation — clients
//! syncing against one hot set keep negotiating the same matrix geometry:
//!
//! * [`server::DecoderPool`]: decoder construction over the host set dominates each
//!   session's local cost, so finished decoders are parked in a concurrency-safe LRU
//!   pool keyed by exact geometry `(seed, l, m)` and revalidated on checkout by the
//!   full decoder cache key (matrix + candidates + side; the same double check the
//!   one-slot [`decoder::DecoderCache`] performs). Thousands of same-geometry sessions
//!   then pay for construction only `workers` times.
//! * [`server::SketchStore`]: the next cost down is re-encoding the (unchanged) host
//!   set's sketch `M·1_host` per session and per escalation rung, so the store
//!   memoizes it per geometry — encoded once (single-flight under a cold burst),
//!   checked out afterwards as an O(1) shared `Arc` clone, and **maintained
//!   incrementally** through [`server::ServerHandle::replace_set`] by §4 streaming
//!   updates over the per-id set delta (entries are invalidated and re-encoded on
//!   demand when the delta outweighs the set).
//!
//! Both layers are sharded per tenant — a tenant's churn or eviction pressure cannot
//! flush a neighbour's warm decoders or sketches. Hit/miss/eviction/incremental-update
//! counters surface in `ServerStats` (globally and per shard), and [`server::loadgen`]
//! (also the `commonsense loadgen` CLI) provides a verifying many-client, many-tenant
//! workload with capped-exponential-backoff retries on `Busy`; the `server_throughput`
//! bench tracks sessions/sec with each layer on vs off, across `workers` and
//! connection-scaling sweeps, plus a `replace_set`-churn-under-load row.
//!
//! ## Multi-party intersection
//!
//! Sketch linearity is what makes the two-party protocol work — `sk(B) − sk(A)` *is*
//! the sketch of the symmetric difference — and it is also what generalizes it: a sum
//! of integer CS sketches is the sketch of the multiset union, so one coordinator can
//! collect every party's sketch under a shared matrix, aggregate them, and repair each
//! spoke against its own residue. [`setx::multi`] implements that as a star — one
//! coordinator (party 0), N−1 spokes, every party ending the round with the exact
//! `∩ᵢSᵢ` and a typed [`setx::multi::MultiError::PartyTimeout`] isolating any spoke
//! that stalls instead of wedging the other N−1:
//!
//! ```text
//!        S₁          S₂        join: two-party EstHello + (party i, N) varints
//!          ╲        ╱          collect: Σᵢ sk(Sᵢ) under one shared geometry
//!           C (S₀) ──→ ∩ᵢSᵢ    repair: per-spoke residue + escalation ladder
//!          ╱        ╲          membership: ∩ = S₀ ∖ ⋃ᵢ(S₀∖Sᵢ), broadcast back
//!        S₃          S₄        confirm: all N certify the same intersection
//! ```
//!
//! ```
//! use commonsense::setx::Setx;
//! use commonsense::data::synth;
//!
//! // Five parties around a 500-element core, each holding a 10-element private tail.
//! let sets = synth::overlap_n(5, 500, 10, 7);
//! let mut expected = sets[0].clone();
//! for s in &sets[1..] {
//!     expected = synth::intersect(&expected, s);
//! }
//! let report = Setx::multi(&sets).unwrap();
//! assert_eq!(report.intersection, expected);
//! assert_eq!(report.completed(), 4);
//! // Per-spoke transcripts shard the round's bytes exactly.
//! let per_party: usize = report.parties.iter().map(|p| p.total_bytes()).sum();
//! assert_eq!(per_party, report.total_bytes());
//! ```
//!
//! The same round runs over real sockets via [`setx::multi::net::host_round`] /
//! [`setx::multi::net::join_round`] (the `commonsense multi` CLI subcommand), and as a
//! daemon through the server's coordinator mode:
//! [`server::ServerBuilder::multi_tenant`] turns a tenant namespace into a standing
//! round that spokes join with `join_round`, with completed [`setx::multi::MultiReport`]s
//! collected off [`server::ServerHandle::take_multi_reports`]. The `multi_round` bench
//! tracks wall-clock and bytes-per-party at N = {3, 5, 8} in `BENCH_protocol.json`.
//!
//! ## Failure model & retries
//!
//! Every failure surfaces as one typed [`setx::SetxError`], and the error's *class*
//! decides what happens next — [`setx::SetxError::is_transient`] draws the line:
//!
//! * **Transient** (`Io`, `ServerBusy`, `PeerClosed`): the connection is gone but the
//!   protocol was not contradicted — reconnecting and replaying is safe and likely to
//!   succeed. [`setx::Setx::run_with_retry`] does exactly that: on a transient error
//!   it drops the dead transport (folding its byte counters into
//!   [`setx::SetxReport::retry_bytes`]), waits out a capped exponential backoff with
//!   deterministic per-client jitter ([`setx::RetryPolicy::backoff_ms`], honoring the
//!   server's `retry_after_ms` pushback hint), and asks the caller's `connect` factory
//!   for a fresh transport — up to `max_retries` times. The final
//!   [`setx::SetxReport`] carries `retries` and `retry_bytes`, so the cost of
//!   convergence is visible, not silent.
//! * **Fatal** (`MalformedFrame`, `Protocol`, `Config*`, `Decode`): either the wire
//!   carried garbage this endpoint *parsed*, or the two ends genuinely disagree —
//!   replaying would fail identically (or worse, mask corruption), so these surface
//!   immediately without burning the retry budget. [`setx::multi::MultiError`] mirrors
//!   the same contract for N-party rounds.
//!
//! The classification is *proven* rather than assumed: [`setx::transport::fault`]
//! wraps any transport in a declarative, seeded [`setx::transport::FaultPlan`]
//! (connection drops, truncated/corrupted frames, simulated delays, duplicated
//! frames — targetable per protocol phase, per direction, per n-th frame), and the
//! `chaos` test suite sweeps every fault kind × phase × workload shape × codec
//! setting asserting that each run terminates with the exact intersection or a typed
//! error — never a panic, never a wrong answer — and that `run_with_retry` converges
//! whenever a plan leaves one fault-free attempt. Server-side, wire damage lands in
//! the `protocol_faults` counters ([`server::ServerStats`], per tenant shard +
//! unrouted remainder), half-open connections are reaped by an unconditional
//! pre-routing deadline, and `loadgen`'s `disconnect_rate` drives whole fleets
//! through seeded fault schedules to keep the 100%-success-under-chaos bar honest.
//!
//! ## Performance
//!
//! The dominant local costs of a session are **decoder construction** (column sampling +
//! CSR + reverse lookup over all n candidates) and **sketch encoding** (O(m·|S|),
//! Theorem 2), and the repo attacks both the same three ways:
//!
//! * **Parallel construction and encoding** — [`decoder::MpDecoder::with_config`]
//!   shards the build across a bounded worker pool
//!   ([`decoder::DecoderConfig::build_threads`]; `0` = auto) with a counting-sort merge
//!   that is bit-identical to the serial path (property-tested via
//!   [`decoder::MpDecoder::structure_digest`]); [`sketch::Sketch::encode_par`] does the
//!   same for encoding ([`sketch::EncodeConfig`]; `0` = auto; `Setx::builder(…)
//!   .encode_threads(n)` is the facade knob) with thread-local count vectors merged by
//!   addition — also bit-identical, property-tested through the `m = 64` boundary. The
//!   serial encode itself samples columns in batches
//!   ([`hash::ColumnSampler::rows_batch`]), hoisting PRNG seeding and bounds checks out
//!   of the per-element loop. Nested drivers (partitioned workers, server worker pools)
//!   pin both knobs to 1 so pools don't multiply.
//! * **Reuse** — a [`decoder::DecoderCache`] threads through the [`setx`] endpoint,
//!   sessions, and the unidirectional decode: ladder attempts and repeat conversations
//!   that keep the same matrix reset the constructed decoder (`reset_signal`,
//!   decode-for-decode identical to a fresh build) instead of rebuilding; the
//!   encode-side twin is the server's [`server::SketchStore`] (host sketch per
//!   geometry, O(1) checkout, §4-incremental under `replace_set`). Per-id hot
//!   operations (`force`, §5.2 collision resolution,
//!   [`decoder::MpDecoder::set_banned_ids`]) are O(1) via an open-addressing id→slot
//!   table ([`hash::IdIndex`]).
//! * **A persistent perf trajectory** — every bench target supports
//!   `cargo bench --bench <name> -- --json [--smoke]`; results (name, mean_ns, min_ns,
//!   iters, config fingerprint) append to the repo-root `BENCH_decode.json` (decode
//!   microbenches), `BENCH_encode.json` (encode/store microbenches),
//!   `BENCH_protocol.json` (protocol sweeps), and `BENCH_server.json` (server operating
//!   points) as one growing JSON array each. CI runs the `--smoke` profile on every
//!   push, restores the accumulated files across runs (cache), and uploads them as the
//!   `bench-trajectory` artifact, so perf regressions show up as data — the headline
//!   series are `mp_build n=100000 d=1000 threads={1,4}` and
//!   `sketch_encode[_par] n=100000` serial/threads={1,4} (serial baselines vs parallel),
//!   plus `sketch_store_hit` vs `sketch_store_miss`. See [`metrics::append_bench_json`].
//!
//! ## Observability
//!
//! The byte ledger above answers *how much*; the [`obs`] layer answers *where the time
//! went* — zero dependencies, zero wire impact, injectable clocks so the sans-io layers
//! stay deterministic under test (CI lints `rust/src/protocol` + `rust/src/setx` for
//! raw `Instant::now()`):
//!
//! * **Session traces** — every session records a timestamped [`obs::SessionTrace`]
//!   timeline, returned on [`setx::SetxReport::trace`] and folded into per-phase wall
//!   times by [`setx::SetxReport::phase_durations`]:
//!
//!   ```text
//!   Handshake  ├────────────┤                         (EstHello ⇄, negotiate)
//!   Estimate     ├───┤                                (strata/minhash build + d̂)
//!   Attempt(0)              ├──────────────┤          (one span per ladder rung)
//!     SketchEncode            ├──┤
//!     DecoderBuild                 ├──┤
//!     Round                    ·  ·   ·  ·            (one marker per payload frame)
//!     Confirm                              ··         (verdict frames)
//!   ```
//!
//!   `Attempt` spans equal `report.attempts` and `Round` markers equal `report.rounds`
//!   by construction (property-tested in `rust/tests/trace_properties.rs`);
//!   `Setx::builder(…).tracing(false)` turns recording off entirely (the bench
//!   ablation; the knob is local, not fingerprinted, so mixed peers interop).
//! * **Latency histograms** — [`obs::LogHistogram`] (64 power-of-two buckets,
//!   mergeable, `quantile(q)`) backs `loadgen`'s p50/p95/p99, `BenchResult` tails, and
//!   the server's per-tenant latency shards, which merge exactly to the global
//!   histogram (the same shard-sum invariant as the byte counters).
//! * **Live exposition** — [`server::ServerBuilder::metrics_addr`] serves
//!   [`server::ServerStats::to_prometheus`] over a minimal HTTP/1.0 responder on its
//!   own named thread (`curl http://…/metrics`). Metric naming:
//!
//!   | metric | type | labels |
//!   |---|---|---|
//!   | `setx_sessions_{accepted,served,failed,rejected}` | counter | global |
//!   | `setx_tenant_sessions_{accepted,served,failed,rejected}` | counter | `tenant` |
//!   | `setx_bytes_total{phase=…}` / `setx_raw_bytes_total` | counter | global |
//!   | `setx_inflight_sessions` | gauge | global |
//!   | `setx_session_latency_ns` | histogram | global |
//!   | `setx_tenant_session_latency_ns` | histogram | `tenant` |
//!
//!   Sessions slower than [`server::ServerBuilder::slow_session_threshold`] dump their
//!   full trace to stderr, e.g.:
//!
//!   ```text
//!   [slow-session] sid=17 tenant=2 elapsed=312ms
//!     +        0us open  Handshake
//!     +      411us close Handshake
//!     +      430us open  Attempt(0)
//!     …
//!   ```
//!
//! ## Wire format & compression
//!
//! Every frame is `type:u8 | body_len:varint | body`, parsed with checked offsets and a
//! hard frame cap ([`protocol::wire::MAX_FRAME_BYTES`]). Under the frame layer sits one
//! columnar codec layer, [`wire::column`]: LEB128 varints, delta+varint columns for
//! sorted id sequences, run-length columns for sparse integer vectors (CS sketch count
//! tables are mostly zeros at low d), and boolean-RLE for bitmaps — each behind the
//! [`wire::column::Column`] trait with length-capped, offset-checked decoding. The
//! compact encodings are **negotiated**, not assumed: a flags bit in the `EstHello`
//! handshake (the same versioned trailing-field pattern that carries `namespace` and the
//! multi-party fields) turns them on only when both endpoints advertise support, so
//! codec-off frames are byte-identical to the pre-codec wire format and old peers
//! interop unchanged. Sessions charge every frame both its encoded bytes and its
//! codec-off-equivalent ([`protocol::wire::Msg::raw_wire_len`]) to the
//! [`metrics::CommLog`], so [`setx::SetxReport::compression_ratio`] and the server's
//! per-tenant stats report the measured — not estimated — wire savings; the
//! `fig2a`/`fig2b`/`table2_ethereum`/`multi_round` benches record codec-on vs codec-off
//! rows in `BENCH_protocol.json`.
//!
//! ## Workspace layout
//!
//! The Cargo workspace maps the repo's split source tree explicitly: the library lives at
//! `rust/src/lib.rs`, the `commonsense` CLI at `rust/src/main.rs`, integration tests in
//! `rust/tests/`, self-harnessed bench targets (`harness = false`, run with
//! `cargo bench`) in `rust/benches/`, and runnable examples in `examples/` at the repo
//! root (auto-discovered; run with `cargo run --release --example <name>`). The sibling
//! `python/` tree (AOT kernel compilation) is not part of the Cargo build.

pub mod baselines;
pub mod bounds;
pub mod coordinator;
pub mod data;
pub mod decoder;
pub mod ecc;
pub mod entropy;
pub mod experiments;
pub mod hash;
pub mod matrix;
pub mod metrics;
pub mod obs;
pub mod protocol;
pub mod runtime;
pub mod server;
pub mod setx;
pub mod sketch;
pub mod smf;
pub mod streaming;
pub mod wire;

/// Element identifiers. Objects are identified by (hashes of) their content; internally we
/// operate on 64-bit ids. When the nominal universe is larger (e.g. `2^256` for Ethereum
/// signatures), communication accounting is parameterized by the nominal universe bit-width
/// `u` while dedup/equality uses the 64-bit internal id (collision probability is negligible
/// at the cardinalities we run; see DESIGN.md §4).
pub type Id = u64;
