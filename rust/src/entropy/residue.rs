//! Residue compression (Appendix C.1): Skellam-modelled rANS with escape coding.
//!
//! The sender estimates (μ̂₁, μ̂₂) from the residue's own sample moments (method of moments),
//! ships them as two f32s, and both sides derive the identical quantized symbol model from
//! the analytic Skellam pmf over a high-coverage range. Out-of-range coordinates (rare) are
//! escape-coded: an escape symbol in the rANS stream plus a zigzag-varint side channel.
//!
//! This payload is already entropy-coded to near the Skellam model's entropy, so the
//! [`crate::wire::column`] codec deliberately leaves it alone: residue bytes are
//! byte-identical whether a session negotiated the codec on or off (the codec re-frames
//! the *surrounding* id lists, bitmaps, and headers, where the redundancy actually is).

use super::rans::{RansDecoder, RansEncoder, SymbolModel};
use super::skellam::{skellam_pmf, skellam_range, SkellamParams};
use super::{get_varint, put_varint, unzigzag, zigzag};

/// Build the shared model for given parameters: returns (lo, hi, model-with-escape).
/// Symbol `i` encodes value `lo + i`; the last symbol is the escape.
fn shared_model(params: SkellamParams) -> (i32, i32, SymbolModel) {
    // Clamp parameters so a variance estimate poisoned by outliers (which are escape-coded
    // anyway) cannot blow up the alphabet or the pmf computation.
    let params = SkellamParams::new(params.mu1.min(500.0), params.mu2.min(500.0));
    let (lo, hi) = skellam_range(params, 1e-5);
    // Keep the alphabet comfortably under the rANS 2^12 ceiling.
    let mean = params.mean().round() as i32;
    let lo = lo.max(mean - 1500);
    let hi = hi.min(mean + 1500).max(lo);
    let mut pmf = skellam_pmf(params, lo, hi);
    pmf.push(2e-5); // escape probability floor
    (lo, hi, SymbolModel::from_pmf(&pmf))
}

/// Compress a residue vector. Layout:
/// `mu1:f32 | mu2:f32 | n_escapes:varint | escapes(zigzag varints) | rans payload`.
pub fn compress_residue(values: &[i32]) -> Vec<u8> {
    let n = values.len() as f64;
    let mean = values.iter().map(|&v| v as f64).sum::<f64>() / n.max(1.0);
    let var = values
        .iter()
        .map(|&v| {
            let d = v as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n.max(1.0);
    let params = SkellamParams::estimate(mean, var);
    let (lo, hi, model) = shared_model(params);
    let escape_sym = (hi - lo + 1) as u16;

    let mut symbols = Vec::with_capacity(values.len());
    let mut escapes = Vec::new();
    for &v in values {
        if v >= lo && v <= hi {
            symbols.push((v - lo) as u16);
        } else {
            symbols.push(escape_sym);
            escapes.push(v);
        }
    }
    let payload = RansEncoder::encode_all(&model, &symbols);

    let mut out = Vec::with_capacity(payload.len() + 16);
    out.extend_from_slice(&(params.mu1 as f32).to_le_bytes());
    out.extend_from_slice(&(params.mu2 as f32).to_le_bytes());
    put_varint(&mut out, escapes.len() as u64);
    for &e in &escapes {
        put_varint(&mut out, zigzag(e as i64));
    }
    out.extend_from_slice(&payload);
    out
}

/// Decompress a residue of known length `n`.
pub fn decompress_residue(data: &[u8], n: usize) -> Option<Vec<i32>> {
    if data.len() < 8 {
        return None;
    }
    let mu1 = f32::from_le_bytes(data[0..4].try_into().ok()?) as f64;
    let mu2 = f32::from_le_bytes(data[4..8].try_into().ok()?) as f64;
    let params = SkellamParams::new(mu1, mu2);
    let (lo, hi, model) = shared_model(params);
    let escape_sym = (hi - lo + 1) as u16;

    let mut off = 8;
    let (n_esc, used) = get_varint(&data[off..])?;
    off += used;
    // Each escape costs ≥ 1 byte of the remaining stream; an inflated count from an
    // adversarial frame must not reach `Vec::with_capacity`.
    if n_esc > (data.len() - off) as u64 {
        return None;
    }
    let mut escapes = Vec::with_capacity(n_esc as usize);
    for _ in 0..n_esc {
        let (z, used) = get_varint(&data[off..])?;
        off += used;
        escapes.push(unzigzag(z) as i32);
    }
    let symbols = RansDecoder::decode_all(&model, &data[off..], n)?;
    let mut esc_iter = escapes.into_iter();
    let mut out = Vec::with_capacity(n);
    for s in symbols {
        if s == escape_sym {
            out.push(esc_iter.next()?);
        } else {
            out.push(lo + s as i32);
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Xoshiro256;

    fn skellam_sample(rng: &mut Xoshiro256, mu1: f64, mu2: f64) -> i32 {
        rng.gen_poisson(mu1) as i32 - rng.gen_poisson(mu2) as i32
    }

    #[test]
    fn roundtrip_typical_residue() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let values: Vec<i32> = (0..5000).map(|_| skellam_sample(&mut rng, 0.4, 0.1)).collect();
        let bytes = compress_residue(&values);
        let back = decompress_residue(&bytes, values.len()).unwrap();
        assert_eq!(back, values);
        // Entropy of Skellam(0.4,0.1) ≈ 1.2 bits ⇒ ≪ 4 bytes/coord raw.
        assert!(bytes.len() < 5000, "compressed size {}", bytes.len());
    }

    #[test]
    fn roundtrip_with_outliers() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut values: Vec<i32> = (0..2000).map(|_| skellam_sample(&mut rng, 1.0, 1.0)).collect();
        values[17] = 100_000;
        values[999] = -77_777;
        let bytes = compress_residue(&values);
        let back = decompress_residue(&bytes, values.len()).unwrap();
        assert_eq!(back, values);
    }

    #[test]
    fn roundtrip_all_zero() {
        let values = vec![0i32; 1000];
        let bytes = compress_residue(&values);
        assert!(bytes.len() < 80, "near-degenerate residue should be tiny: {}", bytes.len());
        assert_eq!(decompress_residue(&bytes, 1000).unwrap(), values);
    }

    #[test]
    fn roundtrip_empty() {
        let bytes = compress_residue(&[]);
        assert_eq!(decompress_residue(&bytes, 0).unwrap(), Vec::<i32>::new());
    }

    #[test]
    fn truncated_stream_fails_cleanly() {
        let values = vec![1i32; 100];
        let bytes = compress_residue(&values);
        assert!(decompress_residue(&bytes[..4], 100).is_none());
    }

    #[test]
    fn beats_raw_encoding_substantially() {
        // The headline property: a sparse difference residue compresses far below 32 bits
        // per coordinate (this is what makes the first message cheap).
        let mut rng = Xoshiro256::seed_from_u64(9);
        let values: Vec<i32> = (0..20_000).map(|_| skellam_sample(&mut rng, 0.05, 0.0)).collect();
        let bytes = compress_residue(&values);
        let raw = 4 * values.len();
        assert!(bytes.len() * 8 < raw, "compressed {} raw {}", bytes.len(), raw);
    }
}
