//! Statistical truncation of Alice's sketch `M·1_A` (Appendix C.2).
//!
//! A coordinate `X` of Alice's sketch is Poisson(|A|·m/l) — many bits of entropy — but Bob
//! holds the strongly correlated `Y` (his own sketch coordinate), and `Y − X` is
//! Skellam(μ₁, μ₂) with tiny parameters (μᵢ = |unique|·m/l). Statistical truncation exploits
//! the mutual information [70]:
//!
//! 1. both sides agree on a high-coverage range `[v, w]` for `Y − X` (from the d-estimate
//!    handshake), `W = w − v + 1`;
//! 2. Alice sends `X̃ = X mod W`, entropy-coded (≈ log₂W ≪ H(X) bits/coordinate);
//! 3. Bob recovers `X̂`: the unique value congruent to `X̃` mod `W` with `Y − X̂ ∈ [v, w]` —
//!    correct exactly when `Y − X ∈ [v, w]`;
//! 4. the rare out-of-range coordinates flip the parity of the quotient `⌊X/W⌋`; Alice ships
//!    BCH syndromes of her quotient-parity bit-vector, Bob locates the mismatches against
//!    his own parities (Berlekamp–Massey) and repairs `X̂ → X̂ ± W` by Skellam likelihood.
//!
//! Residual errors (even shifts, or BCH overload) are tolerated downstream: the MP decoder
//! treats them as noise and the protocol can fall back to L1 pursuit, exactly as §App. C.2
//! prescribes.

use super::rans::{RansDecoder, RansEncoder, SymbolModel};
use super::skellam::{skellam_pmf, skellam_range, SkellamParams};
use super::{put_varint, take, take_varint};
use crate::ecc::{BchSyndrome, GF2m};
use std::sync::Arc;

/// Field extension degree for parity syndromes; the parity vector is split into blocks of
/// `2^14 − 1` positions so any sketch length is supported with one table.
const PARITY_GF_M: u32 = 14;
const PARITY_BLOCK: usize = (1 << PARITY_GF_M) - 1;

/// Codec parameters both sides must agree on (derived from the d-estimate handshake).
#[derive(Clone, Copy, Debug)]
pub struct SketchCodecParams {
    /// Expected Skellam parameters of `Y − X`: μ₁ = |B\A|·m/l, μ₂ = |A\B|·m/l.
    pub diff: SkellamParams,
    /// Per-coordinate tail mass outside `[v, w]` (each side).
    pub tail_eps: f64,
    /// BCH correction capacity per parity block.
    pub bch_t: usize,
}

impl SketchCodecParams {
    /// Paper-faithful defaults: 10⁻³ tails, t sized ≈ 4× the expected out-of-range count.
    pub fn derive(est_b_unique: usize, est_a_unique: usize, l: u32, m: u32) -> Self {
        let diff = SkellamParams::for_signal(est_b_unique, est_a_unique, l, m);
        let tail_eps = 1e-3;
        let blocks = (l as usize).div_ceil(PARITY_BLOCK);
        let expected_oor = 2.0 * tail_eps * l as f64 / blocks as f64;
        let bch_t = ((4.0 * expected_oor).ceil() as usize).clamp(8, 256);
        SketchCodecParams { diff, tail_eps, bch_t }
    }

    /// Truncation range `[v, w]` and width `W`.
    pub fn range(&self) -> (i32, i32, u32) {
        let (v, w) = skellam_range(self.diff, self.tail_eps);
        (v, w, (w - v + 1) as u32)
    }
}

/// The wire message for a truncated sketch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SketchMsg {
    /// Sketch length l (coordinates).
    pub n: usize,
    /// Quantized rANS table for the X̃ alphabet (W symbols).
    pub table: Vec<u8>,
    /// rANS payload of the X̃ sequence.
    pub payload: Vec<u8>,
    /// Concatenated per-block parity syndromes.
    pub syndromes: Vec<u8>,
}

impl SketchMsg {
    /// Total wire size in bytes (what the experiments account).
    pub fn size_bytes(&self) -> usize {
        // n and small framing are already charged by the protocol envelope.
        self.table.len() + self.payload.len() + self.syndromes.len()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_varint(&mut out, self.n as u64);
        put_varint(&mut out, self.table.len() as u64);
        out.extend_from_slice(&self.table);
        put_varint(&mut out, self.payload.len() as u64);
        out.extend_from_slice(&self.payload);
        put_varint(&mut out, self.syndromes.len() as u64);
        out.extend_from_slice(&self.syndromes);
        out
    }

    /// Parse; adversarial-frame hardened: offsets are checked and the claimed coordinate
    /// count is capped so a hostile header cannot drive the receiver's decode-buffer
    /// allocation (`recover_sketch` reserves `n` slots up front).
    pub fn from_bytes(data: &[u8]) -> Option<Self> {
        /// No real sketch comes close (l is a few-×-d rows); 2^24 coordinates would
        /// already be a 64 MiB decode buffer.
        const MAX_COORDS: u64 = 1 << 24;
        let mut off = 0usize;
        let n = take_varint(data, &mut off)?;
        if n > MAX_COORDS {
            return None;
        }
        let tl = usize::try_from(take_varint(data, &mut off)?).ok()?;
        let table = take(data, &mut off, tl)?.to_vec();
        let pl = usize::try_from(take_varint(data, &mut off)?).ok()?;
        let payload = take(data, &mut off, pl)?.to_vec();
        let sl = usize::try_from(take_varint(data, &mut off)?).ok()?;
        let syndromes = take(data, &mut off, sl)?.to_vec();
        if off != data.len() {
            return None; // trailing garbage — same strictness as the frame envelope
        }
        Some(SketchMsg { n: n as usize, table, payload, syndromes })
    }
}

fn parity_field() -> Arc<GF2m> {
    Arc::new(GF2m::new(PARITY_GF_M))
}

fn parity_syndromes(parities: &[bool], t: usize, gf: &Arc<GF2m>) -> Vec<u8> {
    let mut out = Vec::new();
    for block in parities.chunks(PARITY_BLOCK) {
        let positions = block
            .iter()
            .enumerate()
            .filter(|(_, &p)| p)
            .map(|(i, _)| i as u32);
        out.extend_from_slice(&BchSyndrome::compute(gf.clone(), t, positions).to_bytes());
    }
    out
}

/// Alice: compress her sketch counts (non-negative) under shared `params`.
pub fn compress_sketch(x: &[i32], params: &SketchCodecParams) -> SketchMsg {
    let (_v, _w, width) = params.range();
    let w = width as i32;
    let mut symbols = Vec::with_capacity(x.len());
    let mut parities = Vec::with_capacity(x.len());
    let mut histogram = vec![0u64; width as usize];
    for &xi in x {
        debug_assert!(xi >= 0, "sketch counts are non-negative");
        let xt = (xi % w) as u16;
        symbols.push(xt);
        histogram[xt as usize] += 1;
        parities.push((xi / w) & 1 == 1);
    }
    let model = SymbolModel::from_histogram(&histogram);
    let payload = RansEncoder::encode_all(&model, &symbols);
    let gf = parity_field();
    let syndromes = parity_syndromes(&parities, params.bch_t, &gf);
    SketchMsg { n: x.len(), table: model.table_bytes(), payload, syndromes }
}

/// Bob: recover Alice's sketch `X̂` given his own sketch `y` and the shared params.
/// Returns `(x_hat, repaired, unresolved_blocks)`: `repaired` counts parity-patched
/// coordinates, `unresolved_blocks` counts BCH blocks whose patch failed (their residual
/// errors are left for the MP decoder to absorb as noise).
pub fn recover_sketch(
    msg: &SketchMsg,
    y: &[i32],
    params: &SketchCodecParams,
) -> Option<(Vec<i32>, usize, usize)> {
    assert_eq!(msg.n, y.len(), "sketch lengths disagree");
    let (v, wq, width) = params.range();
    let w = width as i32;
    let model = SymbolModel::from_table_bytes(&msg.table, width as usize)?;
    let symbols = RansDecoder::decode_all(&model, &msg.payload, msg.n)?;

    // Step 3: congruence + range recovery.
    let mut x_hat = Vec::with_capacity(msg.n);
    for (i, &yi) in y.iter().enumerate() {
        let xt = symbols[i] as i32;
        let t = (yi - xt - v).rem_euclid(w);
        let mut xi = yi - v - t; // Y − X̂ = v + t ∈ [v, w]
        if xi < 0 {
            // True X is non-negative; take the smallest non-negative congruent value.
            xi = xt;
        }
        x_hat.push(xi);
    }

    // Step 4: parity patch.
    let gf = parity_field();
    let syn_bytes_per_block = (params.bch_t * PARITY_GF_M as usize).div_ceil(8);
    let nblocks = msg.n.div_ceil(PARITY_BLOCK);
    if msg.syndromes.len() < nblocks * syn_bytes_per_block {
        return None;
    }
    // Likelihood table for choosing the repair direction.
    let pmf_lo = skellam_pmf(params.diff, v - w, v - 1); // below-range region
    let pmf_hi = skellam_pmf(params.diff, wq + 1, wq + w); // above-range region
    let mut repaired = 0usize;
    let mut unresolved = 0usize;
    for b in 0..nblocks {
        let start = b * PARITY_BLOCK;
        let end = (start + PARITY_BLOCK).min(msg.n);
        let my_positions = (start..end)
            .filter(|&i| ((x_hat[i] - symbols[i] as i32) / w) & 1 == 1)
            .map(|i| (i - start) as u32);
        let mine = BchSyndrome::compute(gf.clone(), params.bch_t, my_positions);
        let theirs = BchSyndrome::from_bytes(
            gf.clone(),
            params.bch_t,
            &msg.syndromes[b * syn_bytes_per_block..(b + 1) * syn_bytes_per_block],
        )?;
        let diff = mine.xor(&theirs);
        match diff.decode((end - start) as u32) {
            Ok(errs) => {
                for e in errs {
                    let i = start + e as usize;
                    // The true X is an odd number of W-steps away; ±1 step is overwhelmingly
                    // likely. Choose by Skellam likelihood of the implied Y − X.
                    let yx = y[i] - x_hat[i]; // in [v, w]
                    let up = x_hat[i] + w; // implies Y − X = yx − w < v
                    let down = x_hat[i] - w; // implies Y − X = yx + w > w
                    let p_up = pmf_lo.get((yx - w - (v - w)) as usize).copied().unwrap_or(0.0);
                    let p_down = pmf_hi.get((yx + w - (wq + 1)) as usize).copied().unwrap_or(0.0);
                    x_hat[i] = if down < 0 || p_up >= p_down { up } else { down };
                    repaired += 1;
                }
            }
            Err(_) => unresolved += 1,
        }
    }
    Some((x_hat, repaired, unresolved))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Xoshiro256;
    use crate::matrix::CsMatrix;
    use crate::sketch::Sketch;

    /// End-to-end: Alice's real sketch vs Bob's real sketch on overlapping sets.
    fn roundtrip_case(
        n_common: usize,
        n_a_only: usize,
        n_b_only: usize,
        l: u32,
        m: u32,
        seed: u64,
    ) -> (Vec<i32>, Vec<i32>, usize, usize) {
        let mat = CsMatrix::new(l, m, seed);
        let common: Vec<u64> = (0..n_common as u64).map(|i| i * 3 + 1_000_000).collect();
        let a_only: Vec<u64> = (0..n_a_only as u64).map(|i| i * 7 + 5_000_000).collect();
        let b_only: Vec<u64> = (0..n_b_only as u64).map(|i| i * 11 + 9_000_000).collect();
        let a: Vec<u64> = common.iter().chain(&a_only).copied().collect();
        let b: Vec<u64> = common.iter().chain(&b_only).copied().collect();
        let ska = Sketch::encode(mat, &a);
        let skb = Sketch::encode(mat, &b);
        let params = SketchCodecParams::derive(n_b_only, n_a_only, l, m);
        let msg = compress_sketch(&ska.counts, &params);
        let (x_hat, repaired, unresolved) =
            recover_sketch(&msg, &skb.counts, &params).expect("recover");
        (ska.counts.clone(), x_hat, repaired, unresolved)
    }

    #[test]
    fn exact_recovery_typical() {
        let (x, x_hat, _rep, unresolved) = roundtrip_case(20_000, 50, 120, 2400, 7, 3);
        assert_eq!(unresolved, 0);
        let errors = x.iter().zip(&x_hat).filter(|(a, b)| a != b).count();
        assert_eq!(errors, 0, "all coordinates recovered exactly");
    }

    #[test]
    fn exact_recovery_uni_case() {
        // A ⊆ B: μ₂ = 0, range is one-sided.
        let (x, x_hat, _rep, unresolved) = roundtrip_case(10_000, 0, 200, 3000, 7, 5);
        assert_eq!(unresolved, 0);
        assert_eq!(x, x_hat);
    }

    #[test]
    fn message_is_small() {
        let l = 2400u32;
        let mat = CsMatrix::new(l, 7, 3);
        let a: Vec<u64> = (0..20_000u64).collect();
        let ska = Sketch::encode(mat, &a);
        let params = SketchCodecParams::derive(150, 50, l, 7);
        let msg = compress_sketch(&ska.counts, &params);
        // Raw sketch would be 4·l = 9600 bytes; truncation should cut it by ≥ 2×
        // (each coordinate carries ≈ log2(W) < 5 bits + tables + syndromes).
        assert!(
            msg.size_bytes() < 4800,
            "truncated sketch too big: {} bytes",
            msg.size_bytes()
        );
    }

    #[test]
    fn wire_roundtrip() {
        let params = SketchCodecParams::derive(100, 10, 500, 5);
        let mat = CsMatrix::new(500, 5, 1);
        let sk = Sketch::encode(mat, &(0..3000u64).collect::<Vec<_>>());
        let msg = compress_sketch(&sk.counts, &params);
        let bytes = msg.to_bytes();
        let back = SketchMsg::from_bytes(&bytes).unwrap();
        assert_eq!(back.n, msg.n);
        assert_eq!(back.table, msg.table);
        assert_eq!(back.payload, msg.payload);
        assert_eq!(back.syndromes, msg.syndromes);
        assert!(SketchMsg::from_bytes(&bytes[..bytes.len() - 2]).is_none());
    }

    #[test]
    fn parity_patch_fixes_synthetic_out_of_range() {
        // Force out-of-range coordinates by handing Bob a shifted Y at a few positions.
        let l = 1000u32;
        let mat = CsMatrix::new(l, 5, 9);
        let a: Vec<u64> = (0..8000u64).collect();
        let ska = Sketch::encode(mat, &a);
        let params = SketchCodecParams::derive(60, 20, l, 5);
        let (_v, w, width) = params.range();
        let msg = compress_sketch(&ska.counts, &params);
        // Bob's Y = X + noise; craft noise beyond w at 3 coordinates (single W-step).
        let mut rng = Xoshiro256::seed_from_u64(10);
        let mut y = ska.counts.clone();
        for i in 0..y.len() {
            y[i] += rng.gen_range(2) as i32; // in-range noise
        }
        for &i in &[10usize, 500, 900] {
            y[i] = ska.counts[i] + w + 1; // just outside the range
        }
        let (x_hat, repaired, unresolved) = recover_sketch(&msg, &y, &params).unwrap();
        assert_eq!(unresolved, 0);
        assert!(repaired >= 3, "repaired {repaired}");
        let errors = ska.counts.iter().zip(&x_hat).filter(|(a, b)| a != b).count();
        assert_eq!(errors, 0, "width {width}");
    }
}
