//! Entropy coding (Appendix C): rANS, Skellam symbol models, statistical truncation.
//!
//! Almost every CommonSense message is a vector of small integers whose per-coordinate
//! distribution both sides can (approximately) agree on:
//!
//! * residues `r⃗_(t)` are coordinatewise ≈ Skellam(μ₁, μ₂) with parameters estimated by the
//!   *sender* via the method of moments (μ̂₁ = (S²+X̄)/2, μ̂₂ = (S²−X̄)/2) and shipped in the
//!   header — 8 bytes buy both sides the same model ([`residue`]);
//! * Alice's sketch `M·1_A` is huge per-coordinate (Poisson(|A|m/l)) but *shares almost all
//!   its information with Bob's* `M·1_B`; the statistical-truncation codec ([`truncate`])
//!   transmits only `X mod W` plus a BCH parity patch (Appendix C.2).
//!
//! The coder is rANS (range asymmetric numeral systems) with 12-bit quantized frequencies —
//! the paper's choice [12, 66] — implemented from scratch in [`rans`].

pub mod rans;
pub mod residue;
pub mod skellam;
pub mod truncate;

pub use rans::{RansDecoder, RansEncoder, SymbolModel};
pub use residue::{compress_residue, decompress_residue};
pub use skellam::{skellam_pmf, skellam_range, SkellamParams};
pub use truncate::{compress_sketch, recover_sketch, SketchCodecParams, SketchMsg};

/// Shannon entropy (bits/symbol) of a pmf — used in analysis and EXPERIMENTS.md tables.
pub fn entropy_bits(pmf: &[f64]) -> f64 {
    pmf.iter()
        .filter(|&&p| p > 0.0)
        .map(|&p| -p * p.log2())
        .sum()
}

/// Zigzag-encode a signed integer into an unsigned one (small |v| → small code).
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// LEB128 varint append.
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Checked cursor-style take for frame parsers: `len` bytes at `*off`, advancing the
/// cursor. `None` on truncation *or* offset overflow — adversarial length fields must
/// never panic, not even via debug-build overflow checks.
pub(crate) fn take<'a>(data: &'a [u8], off: &mut usize, len: usize) -> Option<&'a [u8]> {
    let end = off.checked_add(len)?;
    let out = data.get(*off..end)?;
    *off = end;
    Some(out)
}

/// Checked cursor-style varint read (see [`take`]).
pub(crate) fn take_varint(data: &[u8], off: &mut usize) -> Option<u64> {
    let (v, used) = get_varint(data.get(*off..)?)?;
    *off += used;
    Some(v)
}

/// LEB128 varint read; returns (value, bytes consumed) or None on truncation.
pub fn get_varint(data: &[u8]) -> Option<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &b) in data.iter().enumerate() {
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
        if shift >= 64 {
            return None;
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zigzag_roundtrip() {
        for v in [-1i64, 0, 1, -100, 100, i64::MIN / 2, i64::MAX / 2] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let values = [0u64, 1, 127, 128, 300, 1 << 20, u64::MAX];
        for &v in &values {
            put_varint(&mut buf, v);
        }
        let mut off = 0;
        for &v in &values {
            let (got, used) = get_varint(&buf[off..]).unwrap();
            assert_eq!(got, v);
            off += used;
        }
        assert_eq!(off, buf.len());
        assert!(get_varint(&[0x80]).is_none(), "truncated varint must fail");
    }

    #[test]
    fn entropy_of_uniform() {
        let pmf = vec![0.25; 4];
        assert!((entropy_bits(&pmf) - 2.0).abs() < 1e-12);
    }
}
