//! Skellam distribution — the per-coordinate law of residue vectors (Appendix C.1).
//!
//! A residue coordinate is a difference of two (approximately independent) Poisson counts:
//! `r_k ~ Poisson(μ₁) − Poisson(μ₂)` with `μ₁ = |P|·m/l`, `μ₂ = |N|·m/l` (P/N the positive/
//! negative signal components). We compute pmfs by numeric convolution of truncated Poisson
//! pmfs (exact to machine precision at these small μ) rather than via Bessel functions.

/// Skellam parameters. Also carries the method-of-moments estimator of Appendix C.1.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SkellamParams {
    pub mu1: f64,
    pub mu2: f64,
}

impl SkellamParams {
    pub fn new(mu1: f64, mu2: f64) -> Self {
        SkellamParams { mu1: mu1.max(1e-9), mu2: mu2.max(1e-9) }
    }

    /// Method-of-moments estimate from a sample mean and variance:
    /// `μ̂₁ = (S² + X̄)/2`, `μ̂₂ = (S² − X̄)/2` (mean = μ₁−μ₂, var = μ₁+μ₂).
    pub fn estimate(mean: f64, var: f64) -> Self {
        let var = var.max(mean.abs()); // a Skellam's variance is ≥ |mean|
        SkellamParams::new((var + mean) / 2.0, (var - mean) / 2.0)
    }

    /// The expected parameters for a residue encoding `n_pos` positive and `n_neg` negative
    /// signal elements through an (l, m) matrix.
    pub fn for_signal(n_pos: usize, n_neg: usize, l: u32, m: u32) -> Self {
        let scale = m as f64 / l as f64;
        SkellamParams::new(n_pos as f64 * scale, n_neg as f64 * scale)
    }

    pub fn mean(&self) -> f64 {
        self.mu1 - self.mu2
    }

    pub fn var(&self) -> f64 {
        self.mu1 + self.mu2
    }
}

/// Truncated Poisson pmf `[P(0), …, P(kmax)]` (renormalization-free; the tail is tiny by
/// construction of `kmax`).
fn poisson_pmf(mu: f64, kmax: usize) -> Vec<f64> {
    let mut pmf = Vec::with_capacity(kmax + 1);
    // Work in log space for large mu to avoid underflow of e^{-mu}.
    if mu < 500.0 {
        let mut p = (-mu).exp();
        for k in 0..=kmax {
            pmf.push(p);
            p *= mu / (k as f64 + 1.0);
        }
    } else {
        let lmu = mu.ln();
        let mut lp = -mu; // log P(0)
        for k in 0..=kmax {
            pmf.push(lp.exp());
            lp += lmu - ((k + 1) as f64).ln();
        }
    }
    pmf
}

fn kmax_for(mu: f64) -> usize {
    (mu + 12.0 * mu.sqrt() + 30.0).ceil() as usize
}

/// Skellam pmf over the integer range `[lo, hi]` inclusive.
pub fn skellam_pmf(params: SkellamParams, lo: i32, hi: i32) -> Vec<f64> {
    assert!(lo <= hi);
    let p1 = poisson_pmf(params.mu1, kmax_for(params.mu1).max(hi.max(0) as usize + 8));
    let p2 = poisson_pmf(params.mu2, kmax_for(params.mu2).max((-lo).max(0) as usize + 8));
    let mut out = vec![0.0f64; (hi - lo + 1) as usize];
    for (j, &q) in p2.iter().enumerate() {
        if q < 1e-300 {
            continue;
        }
        for k in lo..=hi {
            let idx = k as i64 + j as i64;
            if idx >= 0 && (idx as usize) < p1.len() {
                out[(k - lo) as usize] += p1[idx as usize] * q;
            }
        }
    }
    out
}

/// Smallest symmetric-tail range `[v, w]` such that the probability outside is < `eps` on
/// each side. This is the truncation range of Appendix C.2.
pub fn skellam_range(params: SkellamParams, eps: f64) -> (i32, i32) {
    // Generous candidate range: mean ± (10σ + 10).
    let sigma = params.var().sqrt();
    let lo = (params.mean() - 10.0 * sigma - 10.0).floor() as i32;
    let hi = (params.mean() + 10.0 * sigma + 10.0).ceil() as i32;
    let pmf = skellam_pmf(params, lo, hi);
    // Walk inward from each end until the cumulative tail would exceed eps.
    let mut v_idx = 0usize;
    let mut acc = 0.0;
    while v_idx + 1 < pmf.len() && acc + pmf[v_idx] < eps {
        acc += pmf[v_idx];
        v_idx += 1;
    }
    let mut w_idx = pmf.len() - 1;
    let mut acc = 0.0;
    while w_idx > v_idx && acc + pmf[w_idx] < eps {
        acc += pmf[w_idx];
        w_idx -= 1;
    }
    (lo + v_idx as i32, lo + w_idx as i32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pmf_sums_to_one() {
        for (mu1, mu2) in [(0.5, 0.1), (3.0, 3.0), (0.01, 7.0)] {
            let p = SkellamParams::new(mu1, mu2);
            let lo = -200;
            let hi = 200;
            let pmf = skellam_pmf(p, lo, hi);
            let total: f64 = pmf.iter().sum();
            assert!((total - 1.0).abs() < 1e-9, "μ=({mu1},{mu2}) total={total}");
        }
    }

    #[test]
    fn pmf_mean_and_var_match() {
        let p = SkellamParams::new(2.5, 1.0);
        let pmf = skellam_pmf(p, -100, 100);
        let mean: f64 = pmf.iter().enumerate().map(|(i, &q)| (i as f64 - 100.0) * q).sum();
        let var: f64 = pmf
            .iter()
            .enumerate()
            .map(|(i, &q)| {
                let d = i as f64 - 100.0 - mean;
                d * d * q
            })
            .sum();
        assert!((mean - 1.5).abs() < 1e-6, "mean {mean}");
        assert!((var - 3.5).abs() < 1e-5, "var {var}");
    }

    #[test]
    fn pure_poisson_degenerate_case() {
        // μ₂ → 0: Skellam reduces to Poisson(μ₁).
        let p = SkellamParams::new(1.0, 0.0);
        let pmf = skellam_pmf(p, 0, 10);
        let e = (-1.0f64).exp();
        assert!((pmf[0] - e).abs() < 1e-6);
        assert!((pmf[1] - e).abs() < 1e-6);
        assert!((pmf[2] - e / 2.0).abs() < 1e-6);
    }

    #[test]
    fn estimate_inverts_moments() {
        let p = SkellamParams::new(3.0, 1.25);
        let est = SkellamParams::estimate(p.mean(), p.var());
        assert!((est.mu1 - 3.0).abs() < 1e-9);
        assert!((est.mu2 - 1.25).abs() < 1e-9);
    }

    #[test]
    fn range_covers_mass() {
        let p = SkellamParams::new(2.0, 0.5);
        let (v, w) = skellam_range(p, 1e-3);
        assert!(v < 0 || v <= 1); // mean 1.5, some left spread
        assert!(w >= 4);
        let pmf = skellam_pmf(p, v, w);
        let inside: f64 = pmf.iter().sum();
        assert!(inside > 1.0 - 3e-3, "inside {inside}");
        // Tighter eps ⇒ wider range.
        let (v2, w2) = skellam_range(p, 1e-6);
        assert!(v2 <= v && w2 >= w);
    }

    #[test]
    fn large_mu_log_space_path() {
        let pmf = poisson_pmf(800.0, 1200);
        let total: f64 = pmf.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "total {total}");
        // Mode near mu.
        let argmax = pmf
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!((799..=801).contains(&argmax), "argmax {argmax}");
    }
}
