//! rANS — range asymmetric numeral systems [Duda 2015], 32-bit state, byte renormalization.
//!
//! The paper's implementation uses rANS [66] "requiring only one integer multiplication per
//! symbol"; ours follows the same classic layout (Fabian Giesen's `rans_byte` construction):
//! encoding runs over the symbols in reverse, the byte stream is then reversed so the
//! decoder streams forward.

/// Frequency scale: all models quantize to 2^12 total.
pub const SCALE_BITS: u32 = 12;
const TOT: u32 = 1 << SCALE_BITS;
const RANS_L: u32 = 1 << 23; // normalized interval lower bound

/// A quantized symbol distribution usable by both encoder and decoder.
#[derive(Clone, Debug)]
pub struct SymbolModel {
    freqs: Vec<u32>,
    cum: Vec<u32>,        // cum[s] = Σ_{s'<s} freqs[s'], len = alphabet+1, cum[last] = TOT
    slot2sym: Vec<u16>,   // TOT entries
}

impl SymbolModel {
    /// Quantize a pmf to 12-bit frequencies (every symbol gets ≥ 1 so it stays encodable).
    pub fn from_pmf(pmf: &[f64]) -> Self {
        assert!(!pmf.is_empty() && pmf.len() <= TOT as usize, "alphabet size {}", pmf.len());
        let sum: f64 = pmf.iter().map(|p| p.max(0.0)).sum();
        let sum = if sum > 0.0 { sum } else { 1.0 };
        let n = pmf.len();
        let mut freqs: Vec<u32> = pmf
            .iter()
            .map(|&p| ((p.max(0.0) / sum) * TOT as f64).round().max(1.0) as u32)
            .collect();
        // Fix the total to exactly TOT by nudging the largest entries.
        loop {
            let total: i64 = freqs.iter().map(|&f| f as i64).sum();
            match total.cmp(&(TOT as i64)) {
                std::cmp::Ordering::Equal => break,
                std::cmp::Ordering::Greater => {
                    // Shave from the largest entry that stays ≥ 1.
                    let excess = (total - TOT as i64) as u32;
                    let (idx, _) = freqs
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, &f)| f)
                        .unwrap();
                    let take = excess.min(freqs[idx] - 1).max(1);
                    freqs[idx] -= take.min(freqs[idx] - 1);
                    if freqs[idx] == 1 && excess > 0 && n == 1 {
                        panic!("cannot quantize: alphabet of 1 needs TOT");
                    }
                }
                std::cmp::Ordering::Less => {
                    let deficit = (TOT as i64 - total) as u32;
                    let (idx, _) = freqs
                        .iter()
                        .enumerate()
                        .max_by_key(|(_, &f)| f)
                        .unwrap();
                    freqs[idx] += deficit;
                }
            }
        }
        Self::from_freqs(freqs)
    }

    /// Build from already-quantized frequencies summing to 2^12.
    pub fn from_freqs(freqs: Vec<u32>) -> Self {
        let total: u32 = freqs.iter().sum();
        assert_eq!(total, TOT, "frequencies must sum to {TOT}");
        let mut cum = Vec::with_capacity(freqs.len() + 1);
        cum.push(0u32);
        for &f in &freqs {
            cum.push(cum.last().unwrap() + f);
        }
        let mut slot2sym = vec![0u16; TOT as usize];
        for (s, w) in freqs.iter().enumerate() {
            for slot in cum[s]..cum[s] + w {
                slot2sym[slot as usize] = s as u16;
            }
        }
        SymbolModel { freqs, cum, slot2sym }
    }

    /// Build from an empirical histogram (smoothed so every symbol stays encodable).
    pub fn from_histogram(counts: &[u64]) -> Self {
        let pmf: Vec<f64> = counts.iter().map(|&c| c as f64 + 0.2).collect();
        Self::from_pmf(&pmf)
    }

    pub fn alphabet_size(&self) -> usize {
        self.freqs.len()
    }

    /// Serialize the quantized table (2 bytes/symbol) — what a histogram-mode message ships.
    pub fn table_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(2 * self.freqs.len());
        for &f in &self.freqs {
            out.extend_from_slice(&(f as u16).to_le_bytes());
        }
        out
    }

    pub fn from_table_bytes(data: &[u8], alphabet: usize) -> Option<Self> {
        if data.len() < 2 * alphabet {
            return None;
        }
        let mut freqs = Vec::with_capacity(alphabet);
        for i in 0..alphabet {
            let f = u16::from_le_bytes([data[2 * i], data[2 * i + 1]]) as u32;
            if f == 0 {
                return None;
            }
            freqs.push(f);
        }
        if freqs.iter().sum::<u32>() != TOT {
            return None;
        }
        Some(Self::from_freqs(freqs))
    }

    /// Ideal compressed size of `symbols` under this model, in bits (for diagnostics).
    pub fn ideal_bits(&self, symbols: &[u16]) -> f64 {
        symbols
            .iter()
            .map(|&s| (TOT as f64 / self.freqs[s as usize] as f64).log2())
            .sum()
    }
}

/// Streaming rANS encoder. Feed symbols in *forward* order via [`encode_all`](Self::encode_all)
/// (it reverses internally), or push reversed yourself with [`put`](Self::put).
pub struct RansEncoder {
    state: u32,
    bytes: Vec<u8>, // renormalization bytes, in emission order (will be reversed)
}

impl Default for RansEncoder {
    fn default() -> Self {
        Self::new()
    }
}

impl RansEncoder {
    pub fn new() -> Self {
        RansEncoder { state: RANS_L, bytes: Vec::new() }
    }

    /// Push one symbol (callers must push in REVERSE symbol order).
    #[inline]
    pub fn put(&mut self, model: &SymbolModel, sym: u16) {
        let f = model.freqs[sym as usize];
        let c = model.cum[sym as usize];
        let x_max = ((RANS_L >> SCALE_BITS) << 8) * f;
        let mut x = self.state;
        while x >= x_max {
            self.bytes.push((x & 0xff) as u8);
            x >>= 8;
        }
        self.state = ((x / f) << SCALE_BITS) + (x % f) + c;
    }

    /// Finish: returns the byte stream the decoder consumes front-to-back.
    pub fn finish(mut self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.bytes.len() + 4);
        out.extend_from_slice(&self.state.to_le_bytes());
        self.bytes.reverse();
        out.append(&mut self.bytes);
        out
    }

    /// One-shot: encode `symbols` (forward order) under `model`.
    pub fn encode_all(model: &SymbolModel, symbols: &[u16]) -> Vec<u8> {
        let mut enc = RansEncoder::new();
        for &s in symbols.iter().rev() {
            enc.put(model, s);
        }
        enc.finish()
    }
}

/// Streaming rANS decoder over a byte slice produced by [`RansEncoder::finish`].
pub struct RansDecoder<'a> {
    state: u32,
    data: &'a [u8],
    pos: usize,
}

impl<'a> RansDecoder<'a> {
    pub fn new(data: &'a [u8]) -> Option<Self> {
        if data.len() < 4 {
            return None;
        }
        let state = u32::from_le_bytes(data[0..4].try_into().ok()?);
        Some(RansDecoder { state, data, pos: 4 })
    }

    /// Decode the next symbol.
    #[inline]
    pub fn get(&mut self, model: &SymbolModel) -> u16 {
        let slot = self.state & (TOT - 1);
        let sym = model.slot2sym[slot as usize];
        let f = model.freqs[sym as usize];
        let c = model.cum[sym as usize];
        self.state = f * (self.state >> SCALE_BITS) + slot - c;
        while self.state < RANS_L {
            let byte = if self.pos < self.data.len() {
                let b = self.data[self.pos];
                self.pos += 1;
                b
            } else {
                0 // stream exhausted: robust decode of a corrupted stream yields garbage, not UB
            };
            self.state = (self.state << 8) | byte as u32;
        }
        sym
    }

    /// One-shot: decode `n` symbols.
    pub fn decode_all(model: &SymbolModel, data: &[u8], n: usize) -> Option<Vec<u16>> {
        let mut dec = RansDecoder::new(data)?;
        Some((0..n).map(|_| dec.get(model)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Xoshiro256;

    #[test]
    fn roundtrip_uniform() {
        let model = SymbolModel::from_pmf(&[0.25; 4]);
        let syms: Vec<u16> = (0..1000).map(|i| (i % 4) as u16).collect();
        let bytes = RansEncoder::encode_all(&model, &syms);
        let back = RansDecoder::decode_all(&model, &bytes, syms.len()).unwrap();
        assert_eq!(back, syms);
        // Uniform over 4 symbols ≈ 2 bits each ⇒ ~250 bytes + 4-byte state.
        assert!(bytes.len() < 270, "size {}", bytes.len());
    }

    #[test]
    fn roundtrip_skewed_compresses() {
        let model = SymbolModel::from_pmf(&[0.9, 0.05, 0.03, 0.02]);
        let mut rng = Xoshiro256::seed_from_u64(5);
        let syms: Vec<u16> = (0..10_000)
            .map(|_| {
                let r = rng.gen_f64();
                if r < 0.9 {
                    0
                } else if r < 0.95 {
                    1
                } else if r < 0.98 {
                    2
                } else {
                    3
                }
            })
            .collect();
        let bytes = RansEncoder::encode_all(&model, &syms);
        let back = RansDecoder::decode_all(&model, &bytes, syms.len()).unwrap();
        assert_eq!(back, syms);
        // Entropy ≈ 0.67 bits/sym ⇒ ~840 bytes; allow slack.
        assert!(bytes.len() < 1000, "size {}", bytes.len());
    }

    #[test]
    fn roundtrip_random_over_large_alphabet() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let alphabet = 300usize;
        let pmf: Vec<f64> = (0..alphabet).map(|_| rng.gen_f64() + 0.01).collect();
        let model = SymbolModel::from_pmf(&pmf);
        let syms: Vec<u16> = (0..5000)
            .map(|_| rng.gen_range(alphabet as u64) as u16)
            .collect();
        let bytes = RansEncoder::encode_all(&model, &syms);
        let back = RansDecoder::decode_all(&model, &bytes, syms.len()).unwrap();
        assert_eq!(back, syms);
    }

    #[test]
    fn empty_sequence() {
        let model = SymbolModel::from_pmf(&[0.5, 0.5]);
        let bytes = RansEncoder::encode_all(&model, &[]);
        assert_eq!(bytes.len(), 4);
        let back = RansDecoder::decode_all(&model, &bytes, 0).unwrap();
        assert!(back.is_empty());
    }

    #[test]
    fn model_table_roundtrip() {
        let model = SymbolModel::from_histogram(&[100, 5, 0, 42, 1]);
        let bytes = model.table_bytes();
        let back = SymbolModel::from_table_bytes(&bytes, 5).unwrap();
        assert_eq!(back.freqs, model.freqs);
        assert!(SymbolModel::from_table_bytes(&bytes[..4], 5).is_none());
    }

    #[test]
    fn quantization_keeps_all_symbols_alive() {
        // Extremely skewed pmf: tiny symbols still get freq ≥ 1 and remain decodable.
        let mut pmf = vec![1e-9; 100];
        pmf[0] = 1.0;
        let model = SymbolModel::from_pmf(&pmf);
        let syms: Vec<u16> = (0..100).map(|i| i as u16).collect();
        let bytes = RansEncoder::encode_all(&model, &syms);
        let back = RansDecoder::decode_all(&model, &bytes, 100).unwrap();
        assert_eq!(back, syms);
    }

    #[test]
    fn compressed_size_near_ideal() {
        let model = SymbolModel::from_pmf(&[0.7, 0.2, 0.1]);
        let mut rng = Xoshiro256::seed_from_u64(8);
        let syms: Vec<u16> = (0..20_000)
            .map(|_| {
                let r = rng.gen_f64();
                if r < 0.7 {
                    0
                } else if r < 0.9 {
                    1
                } else {
                    2
                }
            })
            .collect();
        let bytes = RansEncoder::encode_all(&model, &syms);
        let ideal_bits = model.ideal_bits(&syms);
        let actual_bits = 8.0 * bytes.len() as f64;
        // rANS should be within ~1% of the model-ideal size (plus 32-bit state).
        assert!(actual_bits < ideal_bits * 1.01 + 64.0, "{actual_bits} vs {ideal_bits}");
    }
}
