//! Experiment harnesses that regenerate every table and figure of §7 (see DESIGN.md §3 for
//! the experiment index). Each function prints a markdown table and returns the rows so
//! the bench targets and the CLI share one implementation.
//!
//! Scale: the paper fixes `|A∩B| = 10⁶` and averages 10,000 instances; we default to a
//! `scale`-configurable `|A∩B|` (50k in the CLI, smaller in benches) and a handful of
//! seeded instances per point — comm cost per instance is deterministic given the seed and
//! concentrates tightly (see EXPERIMENTS.md).

use crate::baselines::graphene::graphene_setx;
use crate::baselines::iblt::{iblt_setx, IbltParams};
use crate::bounds;
use crate::data::ethereum::{diff_stats, EthSim};
use crate::data::synth;
use crate::metrics::Stats;
use crate::protocol::bidi::{self, BidiOptions};
use crate::protocol::{uni, CsParams};

/// One data point of Figure 2a.
#[derive(Clone, Debug)]
pub struct Fig2aRow {
    pub d: usize,
    pub commonsense_bytes: f64,
    pub graphene_bytes: f64,
    pub setx_bound_bytes: f64,
    pub setr_bound_bytes: f64,
}

/// Figure 2a — unidirectional SetX: CommonSense vs Graphene, |A| fixed, d sweeps.
/// `fractions` are d/|A| (paper: 1% → 250%).
pub fn fig2a(a_len: usize, fractions: &[f64], instances: usize, verbose: bool) -> Vec<Fig2aRow> {
    let mut rows = Vec::new();
    if verbose {
        println!("\n### Figure 2a — unidirectional SetX (|A| = {a_len}, u = 64)\n");
        println!("| d=|B\\A| | CommonSense | Graphene | CS/Graphene | SetX bound | SetR bound |");
        println!("|---|---|---|---|---|---|");
    }
    for &frac in fractions {
        let d = ((a_len as f64 * frac) as usize).max(1);
        let mut cs = Stats::new();
        let mut gr = Stats::new();
        for seed in 0..instances as u64 {
            let (a, b) = synth::subset_pair(a_len, d, 0xf2a + seed);
            let params = CsParams::tuned_uni(b.len(), d);
            let out = uni::run(&a, &b, &params).expect("uni run");
            assert_eq!(out.b_minus_a.len(), d, "exactness violated");
            cs.push(out.comm.total_bytes() as f64);
            let g = graphene_setx(&a, &b, 239.0 / 240.0, IbltParams::paper_synthetic(), seed);
            assert_eq!(g.b_minus_a.len(), d);
            gr.push(g.total_bytes as f64);
        }
        let row = Fig2aRow {
            d,
            commonsense_bytes: cs.mean(),
            graphene_bytes: gr.mean(),
            setx_bound_bytes: bounds::setx_lower_bound_bits(a_len as u64, (a_len + d) as u64, 0, d as u64) / 8.0,
            setr_bound_bytes: bounds::setr_lower_bound_bits(64, d as u64) / 8.0,
        };
        if verbose {
            println!(
                "| {} | {:.0} | {:.0} | {:.2}x | {:.0} | {:.0} |",
                row.d,
                row.commonsense_bytes,
                row.graphene_bytes,
                row.graphene_bytes / row.commonsense_bytes,
                row.setx_bound_bytes,
                row.setr_bound_bytes
            );
        }
        rows.push(row);
    }
    rows
}

/// One data point of Figure 2b.
#[derive(Clone, Debug)]
pub struct Fig2bRow {
    pub b_unique: usize,
    pub commonsense_bytes: f64,
    pub commonsense_rounds: f64,
    pub iblt_bytes: f64,
    pub ecc_bound_bytes: f64,
    pub setx_bound_bytes: f64,
}

/// Figure 2b — bidirectional SetX: CommonSense vs IBLT vs ECC(-bound), |A\B| fixed,
/// |B\A| sweeps (paper: 100 → 300,000 at |A∩B| ≈ 10⁶, u = 256).
pub fn fig2b(
    common: usize,
    a_unique: usize,
    b_uniques: &[usize],
    instances: usize,
    verbose: bool,
) -> Vec<Fig2bRow> {
    let mut rows = Vec::new();
    if verbose {
        println!("\n### Figure 2b — bidirectional SetX (|A∩B| = {common}, |A\\B| = {a_unique}, u = 256)\n");
        println!("| |B\\A| | CommonSense | rounds | IBLT | ECC bound | IBLT/CS | ECC/CS | SetX bound |");
        println!("|---|---|---|---|---|---|---|---|");
    }
    for &bu in b_uniques {
        let mut cs = Stats::new();
        let mut rounds = Stats::new();
        let mut ib = Stats::new();
        let d = a_unique + bu;
        for seed in 0..instances as u64 {
            let (a, b) = synth::overlap_pair(common, a_unique, bu, 0xf2b + seed);
            let params = CsParams::tuned_bidi(common + d, a_unique, bu);
            let out = bidi::run(&a, &b, &params, BidiOptions::default());
            assert!(out.converged, "bidi failed at bu={bu} seed={seed}");
            assert_eq!(out.b_minus_a.len(), bu);
            assert_eq!(out.a_minus_b.len(), a_unique);
            cs.push(out.comm.total_bytes() as f64);
            rounds.push(out.rounds as f64);
            let (amb, bma, bytes, _r) = iblt_setx(&a, &b, d, IbltParams::paper_ethereum());
            assert_eq!((amb.len(), bma.len()), (a_unique, bu));
            ib.push(bytes as f64);
        }
        let a_len = (common + a_unique) as u64;
        let b_len = (common + bu) as u64;
        let row = Fig2bRow {
            b_unique: bu,
            commonsense_bytes: cs.mean(),
            commonsense_rounds: rounds.mean(),
            iblt_bytes: ib.mean(),
            ecc_bound_bytes: bounds::setr_lower_bound_bits(256, d as u64) / 8.0,
            setx_bound_bytes: bounds::setx_lower_bound_bits(a_len, b_len, a_unique as u64, bu as u64) / 8.0,
        };
        if verbose {
            println!(
                "| {} | {:.0} | {:.1} | {:.0} | {:.0} | {:.1}x | {:.1}x | {:.0} |",
                row.b_unique,
                row.commonsense_bytes,
                row.commonsense_rounds,
                row.iblt_bytes,
                row.ecc_bound_bytes,
                row.iblt_bytes / row.commonsense_bytes,
                row.ecc_bound_bytes / row.commonsense_bytes,
                row.setx_bound_bytes
            );
        }
        rows.push(row);
    }
    rows
}

/// Tables 1+2 — the Ethereum(-sim) experiment. Returns
/// `(table1 rows, [(name, cs_bytes, cs_rounds, iblt_bytes)])`.
pub fn ethereum(n_accounts: usize, verbose: bool) -> (Vec<String>, Vec<(String, f64, usize, f64)>) {
    // Simulate C (old) → 52 days → B (one day stale) → 1 day → A (fresh).
    let mut sim = EthSim::genesis(n_accounts, 0xe7e);
    let c = sim.snapshot_ids();
    sim.advance_days(52);
    let b = sim.snapshot_ids();
    sim.advance_day();
    let a = sim.snapshot_ids();

    let mut table1 = Vec::new();
    if verbose {
        println!("\n### Table 1 — Ethereum-sim snapshot statistics (N = {n_accounts})\n");
        println!("| S | |S| | |S\\A| | |A\\S| | |SΔA| |");
        println!("|---|---|---|---|---|");
    }
    for (name, s) in [("A", &a), ("B", &b), ("C", &c)] {
        let st = diff_stats(s, &a);
        let line = format!(
            "| {} | {} | {} | {} | {} |",
            name, st.s_len, st.s_minus_a, st.a_minus_s, st.sym_diff
        );
        if verbose {
            println!("{line}");
        }
        table1.push(line);
    }

    let mut table2 = Vec::new();
    if verbose {
        println!("\n### Table 2 — SetX comm cost on Ethereum-sim (u = 256)\n");
        println!("| pair | CommonSense | rounds | IBLT | IBLT/CS |");
        println!("|---|---|---|---|---|");
    }
    for (name, other) in [("SetX(A,B)", &b), ("SetX(A,C)", &c)] {
        let st = diff_stats(other, &a);
        let params = CsParams::tuned_bidi(a.len().max(other.len()), st.a_minus_s, st.s_minus_a);
        // Bob (holding the stale snapshot) initiates, as in §7.3 — our role picker does
        // this automatically via the unique-count estimates.
        let out = bidi::run(&a, other, &params, BidiOptions::default());
        assert!(out.converged, "{name} did not converge");
        assert_eq!(out.a_minus_b.len(), st.a_minus_s, "{name} A-side exactness");
        assert_eq!(out.b_minus_a.len(), st.s_minus_a, "{name} B-side exactness");
        let (amb, bma, iblt_bytes, _r) =
            iblt_setx(&a, other, st.sym_diff, IbltParams::paper_ethereum());
        assert_eq!((amb.len(), bma.len()), (st.a_minus_s, st.s_minus_a));
        let cs_bytes = out.comm.total_bytes() as f64;
        if verbose {
            println!(
                "| {} | {:.3} MB | {} | {:.3} MB | {:.1}x |",
                name,
                cs_bytes / 1e6,
                out.rounds,
                iblt_bytes as f64 / 1e6,
                iblt_bytes as f64 / cs_bytes
            );
        }
        table2.push((name.to_string(), cs_bytes, out.rounds, iblt_bytes as f64));
    }
    (table1, table2)
}

/// Example 3 / Example 11 — the paper's worked bound comparisons at our scale.
pub fn examples(scale: usize, verbose: bool) {
    // Example 3 (uni): |A| = scale, d = 1% of |A|, u = 64.
    let d = scale / 100;
    let (a, b) = synth::subset_pair(scale, d, 0xe3);
    let params = CsParams::tuned_uni(b.len(), d);
    let out = uni::run(&a, &b, &params).expect("uni");
    let setr = bounds::setr_lower_bound_bits(64, d as u64) / 8.0;
    let setx = bounds::setx_lower_bound_bits(a.len() as u64, b.len() as u64, 0, d as u64) / 8.0;
    if verbose {
        println!("\n### Example 3 (scaled ×{:.3})\n", scale as f64 / 1e6);
        println!(
            "uni |A|={} d={}: measured {} B; SetX bound {:.0} B; SetR bound {:.0} B; beats-SetR x{:.2}",
            scale,
            d,
            out.comm.total_bytes(),
            setx,
            setr,
            setr / out.comm.total_bytes() as f64
        );
    }

    // Example 11 (bidi): |A| = |B|, d split evenly, u = 256.
    let half = scale / 100;
    let (a, b) = synth::overlap_pair(scale, half, half, 0xe11);
    let params = CsParams::tuned_bidi(scale + 2 * half, half, half);
    let out = bidi::run(&a, &b, &params, BidiOptions::default());
    assert!(out.converged);
    let setr = bounds::setr_lower_bound_bits(256, 2 * half as u64) / 8.0;
    let setx = bounds::setx_lower_bound_bits(
        (scale + half) as u64,
        (scale + half) as u64,
        half as u64,
        half as u64,
    ) / 8.0;
    if verbose {
        println!(
            "bidi |A|=|B|={} d={}: measured {} B ({} rounds); SetX bound {:.0} B; SetR bound {:.0} B; beats-SetR x{:.2}",
            scale + half,
            2 * half,
            out.comm.total_bytes(),
            out.rounds,
            setx,
            setr,
            setr / out.comm.total_bytes() as f64
        );
    }
}

/// Empirical l-tuner: smallest safety factor (granularity 0.05) for which `trials`
/// consecutive seeded instances all decode losslessly. Mirrors §7.1's per-group tuning.
pub fn tune_l(n: usize, d: usize, bidi_mode: bool, trials: usize, verbose: bool) -> f64 {
    let mut safety = 0.5;
    loop {
        let ok = (0..trials as u64).all(|seed| {
            if bidi_mode {
                let (a, b) = synth::overlap_pair(n, d / 2, d - d / 2, 0x707e + seed);
                let mut params = CsParams::tuned_bidi(n + d, d / 2, d - d / 2);
                params.l = CsParams::l_for(d, n + d, params.m, safety);
                let out = bidi::run(&a, &b, &params, BidiOptions::default());
                out.converged
            } else {
                let (a, b) = synth::subset_pair(n, d, 0x707e + seed);
                let mut params = CsParams::tuned_uni(b.len(), d);
                params.l = CsParams::l_for(d, b.len(), params.m, safety);
                uni::run(&a, &b, &params)
                    .map(|o| o.b_minus_a.len() == d)
                    .unwrap_or(false)
            }
        });
        if ok {
            if verbose {
                let mode = if bidi_mode { "bidi" } else { "uni" };
                println!(
                    "tune({mode}, n={n}, d={d}): minimal safety {safety:.2} (l = {})",
                    CsParams::l_for(d, n, if bidi_mode { 5 } else { 7 }, safety)
                );
            }
            return safety;
        }
        safety += 0.05;
        if safety > 4.0 {
            panic!("tuner runaway: n={n} d={d}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2a_small_scale_shape() {
        // The paper's qualitative claims at toy scale: CommonSense wins at small d, the
        // gap narrows as d grows, and CommonSense beats even the SetR lower bound.
        let rows = fig2a(8_000, &[0.01, 0.25], 2, false);
        assert!(rows[0].graphene_bytes / rows[0].commonsense_bytes > 2.0);
        let gap0 = rows[0].graphene_bytes / rows[0].commonsense_bytes;
        let gap1 = rows[1].graphene_bytes / rows[1].commonsense_bytes;
        assert!(gap1 < gap0, "gap must narrow with d: {gap0} -> {gap1}");
        assert!(rows[0].commonsense_bytes < rows[0].setr_bound_bytes);
    }

    #[test]
    fn fig2b_small_scale_shape() {
        let rows = fig2b(8_000, 80, &[20, 400], 2, false);
        for r in &rows {
            assert!(r.iblt_bytes / r.commonsense_bytes > 3.0, "IBLT/CS at {}", r.b_unique);
        }
        // The factor stays in the paper's band (Figure 2b reports 7.8×–14.8×; at toy
        // scale we see the same order, not necessarily monotone).
        for r in &rows {
            assert!(
                r.ecc_bound_bytes / r.commonsense_bytes > 2.0,
                "CS must beat even the SetR lower bound: {}",
                r.b_unique
            );
        }
    }

    #[test]
    fn ethereum_small_scale_shape() {
        let (t1, t2) = ethereum(40_000, false);
        assert_eq!(t1.len(), 3);
        assert_eq!(t2.len(), 2);
        // Table 2's headline: CommonSense is several× leaner than IBLT on both pairs.
        for (name, cs, _rounds, iblt) in &t2 {
            assert!(iblt / cs > 3.0, "{name}: {iblt}/{cs}");
        }
        // SetX(A,C) (50 days stale) costs much more than SetX(A,B) (one day).
        assert!(t2[1].1 > 3.0 * t2[0].1);
    }

    #[test]
    fn tuner_returns_reasonable_safety() {
        let s = tune_l(5_000, 50, false, 3, false);
        assert!((0.5..=2.0).contains(&s), "uni safety {s}");
    }
}

/// AB1 — ablations over the design choices DESIGN.md calls out:
/// decoder variants at marginal l, m sweep, SMF/resolution off, partition counts,
/// and the end-to-end d-estimation handshake.
pub fn ablations(scale: usize, verbose: bool) {
    use crate::decoder::{DecoderConfig, MpDecoder, Side};
    use crate::protocol::estimate::{MinHashEstimator, StrataEstimator};
    use crate::sketch::Sketch;

    // --- Decoder variants: lossless-decode success rate vs l multiplier. ---------------
    if verbose {
        println!("\n### Ablation: decoder variant success rate (n = {scale}, d = 1% of n)\n");
        println!("| l multiplier | MP (ours) | SSMP (L1) | BMP (no unsets) |");
        println!("|---|---|---|---|");
    }
    let d = (scale / 100).max(10);
    for mult in [0.6, 0.8, 1.0] {
        let mut ok = [0u32; 3];
        let trials = 8u64;
        for seed in 0..trials {
            let (a, b) = synth::subset_pair(scale, d, 0xab1 + seed);
            let mut params = CsParams::tuned_uni(b.len(), d);
            params.l = ((params.l as f64) * mult) as u32;
            let mat = params.matrix();
            let want = synth::difference(&b, &a);
            let residue = Sketch::encode(mat, &want).counts;
            for (i, config) in [
                DecoderConfig::commonsense(),
                DecoderConfig::ssmp(),
                DecoderConfig::bmp(),
            ]
            .into_iter()
            .enumerate()
            {
                let mut dec = MpDecoder::new(&mat, &b, Side::Positive);
                dec.set_config(config);
                dec.load_residue(&residue);
                let stats = dec.run();
                let mut got = dec.estimate();
                got.sort_unstable();
                if stats.converged && got == want {
                    ok[i] += 1;
                }
            }
        }
        if verbose {
            println!(
                "| {mult:.1} | {}/{trials} | {}/{trials} | {}/{trials} |",
                ok[0], ok[1], ok[2]
            );
        }
    }

    // --- m sweep (paper fixes m = 7 uni / 5 bidi). --------------------------------------
    if verbose {
        println!("\n### Ablation: column weight m (uni, d = 1%, l fixed at the m=7 tuning)\n");
        println!("| m | comm bytes | exact |");
        println!("|---|---|---|");
    }
    for m in [3u32, 5, 7, 9] {
        let (a, b) = synth::subset_pair(scale, d, 0xab2);
        let mut params = CsParams::tuned_uni(b.len(), d);
        params.m = m;
        let out = uni::run(&a, &b, &params);
        if verbose {
            match out {
                Ok(o) => println!(
                    "| {m} | {} | {} |",
                    o.comm.total_bytes(),
                    o.b_minus_a == synth::difference(&b, &a)
                ),
                Err(e) => println!("| {m} | — | {e} |"),
            }
        }
    }

    // --- Partition-count overhead (§7.3 parallelization). -------------------------------
    if verbose {
        println!("\n### Ablation: PBS-style partitioning overhead (bidi, d = 2%)\n");
        println!("| partitions | total bytes | overhead vs 1 |");
        println!("|---|---|---|");
    }
    let du = scale / 100;
    let (a, b) = synth::overlap_pair(scale, du, du, 0xab3);
    let mut base = 0usize;
    for parts in [1usize, 2, 4, 8, 16] {
        let out = crate::coordinator::parallel::setx(
            &a,
            &b,
            du,
            du,
            parts,
            parts.min(8),
            crate::protocol::bidi::BidiOptions::default(),
        );
        assert!(out.converged, "partitioned run failed at {parts}");
        if parts == 1 {
            base = out.total_bytes;
        }
        if verbose {
            println!(
                "| {parts} | {} | {:.2}x |",
                out.total_bytes,
                out.total_bytes as f64 / base as f64
            );
        }
    }

    // --- d-estimation handshake accuracy (Strata + MinHash, §7.1). ----------------------
    if verbose {
        println!("\n### Ablation: d-estimation handshake (true d = 2%·n = {})\n", 2 * du);
        let mut ea = StrataEstimator::new(7);
        ea.insert_all(&a);
        let mut eb = StrataEstimator::new(7);
        eb.insert_all(&b);
        let strata_est = ea.estimate(&eb);
        let ma = MinHashEstimator::build(&a, 512, 9);
        let mb = MinHashEstimator::build(&b, 512, 9);
        println!(
            "strata: d̂ = {} ({} B handshake); minhash: d̂ = {} ({} B handshake)",
            strata_est,
            ea.size_bytes(),
            ma.estimate_d(&mb),
            ma.size_bytes()
        );
        // Close the loop: run the protocol with the *estimated* d.
        let est = strata_est;
        let params = CsParams::tuned_bidi(scale + 2 * du, est / 2, est / 2);
        let out = bidi::run(&a, &b, &params, crate::protocol::bidi::BidiOptions::default());
        println!(
            "protocol with estimated d: converged = {}, exact = {}, bytes = {}",
            out.converged,
            out.a_minus_b == synth::difference(&a, &b),
            out.comm.total_bytes()
        );
    }
}
