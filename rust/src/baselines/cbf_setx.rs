//! The counting-Bloom-filter *approximate* SetX protocol of Guo & Li [3] (§8.3).
//!
//! Alice sends `CBF(A)`; Bob computes `CBF(B) − CBF(A)` and approximates `B \ A` as the
//! elements of `B` whose cells are all strictly positive in the difference. The paper
//! stresses that this protocol uses the *same sketch* as CommonSense (when M is the CBF
//! matrix) but, lacking the CS decoding view, produces false positives **and** false
//! negatives — this module exists to reproduce that comparison (ablation AB1).

use crate::smf::CountingBloomFilter;

/// Outcome with accuracy accounting (the protocol is approximate by design).
#[derive(Clone, Debug)]
pub struct CbfOutcome {
    pub b_minus_a_approx: Vec<u64>,
    pub false_positives: usize,
    pub false_negatives: usize,
    pub total_bytes: usize,
}

/// Run the [3] protocol. `cells_per_element` controls the CBF size (4–8 typical; the
/// counter width is 4 bits in the usual CBF accounting).
pub fn cbf_setx(
    a: &[u64],
    b: &[u64],
    true_b_minus_a: &[u64],
    cells_per_element: f64,
    seed: u64,
) -> CbfOutcome {
    let ncells = ((a.len().max(1) as f64 * cells_per_element).ceil() as u64).max(64);
    let k = 3;
    let mut cbf_a = CountingBloomFilter::new(ncells, k, seed);
    for &x in a {
        cbf_a.insert(x);
    }
    let mut cbf_b = CountingBloomFilter::new(ncells, k, seed);
    for &x in b {
        cbf_b.insert(x);
    }
    let diff = cbf_b.sub(&cbf_a);
    let mut approx: Vec<u64> = b
        .iter()
        .copied()
        .filter(|&x| diff.contains_positive(x))
        .collect();
    approx.sort_unstable();

    let truth: std::collections::HashSet<u64> = true_b_minus_a.iter().copied().collect();
    let false_positives = approx.iter().filter(|x| !truth.contains(x)).count();
    let found: std::collections::HashSet<u64> = approx.iter().copied().collect();
    let false_negatives = truth.iter().filter(|x| !found.contains(x)).count();

    // 4-bit counters is the standard CBF accounting.
    let total_bytes = (ncells as usize * 4).div_ceil(8);
    CbfOutcome { b_minus_a_approx: approx, false_positives, false_negatives, total_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn mostly_right_but_approximate() {
        let (a, b) = synth::subset_pair(10_000, 100, 1);
        let truth = synth::difference(&b, &a);
        let out = cbf_setx(&a, &b, &truth, 8.0, 3);
        // Recovers the bulk of B\A…
        assert!(out.b_minus_a_approx.len() >= 90);
        // …but is *not* exact in general at practical sizes (this is [3]'s documented
        // limitation; with 8 cells/element some leakage is expected at |A|=10⁴).
        let err_rate = (out.false_positives + out.false_negatives) as f64 / 100.0;
        assert!(err_rate < 0.5, "error rate unexpectedly high: {err_rate}");
    }

    #[test]
    fn smaller_filter_more_errors() {
        let (a, b) = synth::subset_pair(20_000, 200, 2);
        let truth = synth::difference(&b, &a);
        let big = cbf_setx(&a, &b, &truth, 10.0, 3);
        let small = cbf_setx(&a, &b, &truth, 2.0, 3);
        assert!(
            small.false_positives + small.false_negatives
                >= big.false_positives + big.false_negatives,
            "small {}+{} vs big {}+{}",
            small.false_positives,
            small.false_negatives,
            big.false_positives,
            big.false_negatives
        );
        assert!(small.total_bytes < big.total_bytes);
    }
}
