//! Invertible Bloom Lookup Table (IBLT) — the Difference Digest SetR baseline [5].
//!
//! Cell layout follows the Graphene/umass implementation the paper benchmarks against
//! (§7.1): `keySum` (XOR of keys, nominally `u` bits), `hashSum` (fingerprint, 32- or
//! 48-bit), `count` (8-bit in accounting). Peeling decodes the symmetric difference from
//! the cellwise difference of two IBLTs, exactly like erasure-code belief propagation.
//!
//! Communication accounting is parameterized by the *nominal* field widths (the paper's
//! `1.5u` bits per cell remark) while the in-memory representation uses native integers.

use crate::entropy::{put_varint, take, take_varint, unzigzag, zigzag};
use crate::hash::hash_u64;
use crate::wire::column::{varint_len, Column, RleU64Col};

/// Hard ceiling on the cell count accepted by [`Iblt::from_columnar_bytes`]. The
/// run-length column can claim many cells in few bytes (a repeat run is ~3 bytes
/// regardless of length), so unlike the legacy parser the byte count of the input does
/// not bound the allocation — this constant does. Far above any table the estimators
/// ship, far below anything that could hurt.
const MAX_COLUMNAR_CELLS: usize = 1 << 20;

/// Accounting + structural parameters.
#[derive(Clone, Copy, Debug)]
pub struct IbltParams {
    /// Hash functions per element (the paper uses 4).
    pub n_hashes: u32,
    /// Cell-count hedge over d (the paper uses 1.36).
    pub hedge: f64,
    /// Nominal key width in bits for accounting (64 for §7.2-uni, 256 for Ethereum/bidi).
    pub key_bits: u32,
    /// Fingerprint width (32 in synthetic experiments, 48 for Ethereum — §7.1).
    pub fp_bits: u32,
    /// Count field width for accounting.
    pub count_bits: u32,
    pub seed: u64,
}

impl IbltParams {
    pub fn paper_synthetic() -> Self {
        IbltParams { n_hashes: 4, hedge: 1.36, key_bits: 64, fp_bits: 32, count_bits: 8, seed: 0x1b17 }
    }

    pub fn paper_ethereum() -> Self {
        IbltParams { key_bits: 256, fp_bits: 48, ..Self::paper_synthetic() }
    }

    /// Cells provisioned for an expected difference of `d`.
    pub fn cells_for(&self, d: usize) -> usize {
        ((d.max(1) as f64 * self.hedge).ceil() as usize).max(self.n_hashes as usize * 2)
    }

    /// Wire size of an IBLT with `cells` cells, in bytes.
    pub fn size_bytes(&self, cells: usize) -> usize {
        let bits = cells as u64 * (self.key_bits + self.fp_bits + self.count_bits) as u64;
        bits.div_ceil(8) as usize
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
struct Cell {
    key_xor: u64,
    fp_xor: u64,
    count: i64,
}

/// An IBLT over 64-bit internal ids.
#[derive(Clone, Debug)]
pub struct Iblt {
    pub params: IbltParams,
    cells: Vec<Cell>,
}

impl Iblt {
    pub fn new(cells: usize, params: IbltParams) -> Self {
        // Round up to a multiple of n_hashes so the k subtables are equal-sized.
        let k = params.n_hashes as usize;
        let cells = cells.max(k).div_ceil(k) * k;
        Iblt { params, cells: vec![Cell::default(); cells] }
    }

    /// Provisioned for difference cardinality `d`.
    pub fn for_difference(d: usize, params: IbltParams) -> Self {
        Self::new(params.cells_for(d), params)
    }

    pub fn num_cells(&self) -> usize {
        self.cells.len()
    }

    pub fn size_bytes(&self) -> usize {
        self.params.size_bytes(self.cells.len())
    }

    #[inline]
    fn fingerprint(&self, key: u64) -> u64 {
        hash_u64(key, self.params.seed ^ 0xf19e_a8b1) & ((1u64 << self.params.fp_bits.min(63)) - 1)
    }

    /// One cell per hash function, in k *disjoint subtables* (as in the umass
    /// implementation) — a key must never hit the same cell twice or peeling's purity
    /// invariant breaks.
    #[inline]
    fn indices(&self, key: u64) -> impl Iterator<Item = u64> + '_ {
        let k = self.params.n_hashes as u64;
        let sub = (self.cells.len() as u64 / k).max(1);
        (0..k).map(move |j| {
            let h = hash_u64(key, self.params.seed.wrapping_add(j * 0x9e37_79b9));
            (j * sub + h % sub).min(self.cells_len_m1())
        })
    }

    #[inline]
    fn cells_len_m1(&self) -> u64 {
        self.cells.len() as u64 - 1
    }

    fn apply(&mut self, key: u64, delta: i64) {
        let fp = self.fingerprint(key);
        let idx: Vec<u64> = self.indices(key).collect();
        for i in idx {
            let c = &mut self.cells[i as usize];
            c.key_xor ^= key;
            c.fp_xor ^= fp;
            c.count += delta;
        }
    }

    pub fn insert(&mut self, key: u64) {
        self.apply(key, 1);
    }

    pub fn remove(&mut self, key: u64) {
        self.apply(key, -1);
    }

    pub fn insert_all(&mut self, keys: &[u64]) {
        for &k in keys {
            self.insert(k);
        }
    }

    /// Serialize the cell array: cell count, then per cell `key_xor` (8 B LE), `fp_xor`
    /// (varint) and zigzag-varint `count`. Structural parameters (`IbltParams`) are *not*
    /// included — both sides of an exchange must already agree on them (they are part of
    /// the protocol config, like the CS matrix seed).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + self.cells.len() * 12);
        put_varint(&mut out, self.cells.len() as u64);
        for c in &self.cells {
            out.extend_from_slice(&c.key_xor.to_le_bytes());
            put_varint(&mut out, c.fp_xor);
            put_varint(&mut out, zigzag(c.count));
        }
        out
    }

    /// Parse cells written by [`Iblt::to_bytes`] from `data[*off..]`, advancing the
    /// cursor. Adversarial-input hardened: the claimed cell count is validated against
    /// the bytes actually present *before* any allocation is sized by it.
    pub fn from_bytes(data: &[u8], off: &mut usize, params: IbltParams) -> Option<Iblt> {
        let n = usize::try_from(take_varint(data, off)?).ok()?;
        // Every cell occupies ≥ 10 bytes on the wire.
        if n == 0 || n > data.len().saturating_sub(*off) / 10 {
            return None;
        }
        let k = params.n_hashes.max(1) as usize;
        if n % k != 0 {
            return None; // `Iblt::new` always produces a multiple of `n_hashes` cells
        }
        let mut cells = Vec::with_capacity(n);
        for _ in 0..n {
            let key_xor = u64::from_le_bytes(take(data, off, 8)?.try_into().ok()?);
            let fp_xor = take_varint(data, off)?;
            let count = unzigzag(take_varint(data, off)?);
            cells.push(Cell { key_xor, fp_xor, count });
        }
        Some(Iblt { params, cells })
    }

    /// Byte length of [`Iblt::to_bytes`] output, computed without serializing. Used by
    /// the wire layer to charge codec-off-equivalent bytes for columnar frames.
    pub fn legacy_len(&self) -> usize {
        let mut len = varint_len(self.cells.len() as u64);
        for c in &self.cells {
            len += 8 + varint_len(c.fp_xor) + varint_len(zigzag(c.count));
        }
        len
    }

    /// Columnar serialization: the cell array transposed into three [`RleU64Col`]
    /// columns — `key_xor`s, `fp_xor`s, zigzagged `count`s. Strata-estimator tables are
    /// mostly empty cells (all-zero in every field), which the run-length columns
    /// collapse to a few bytes each; the legacy row-major layout pays ≥ 10 bytes per
    /// cell no matter what. Like [`Iblt::to_bytes`], structural parameters are not
    /// included.
    pub fn to_columnar_bytes(&self) -> Vec<u8> {
        let keys: Vec<u64> = self.cells.iter().map(|c| c.key_xor).collect();
        let fps: Vec<u64> = self.cells.iter().map(|c| c.fp_xor).collect();
        let counts: Vec<u64> = self.cells.iter().map(|c| zigzag(c.count)).collect();
        let mut out = Vec::with_capacity(
            RleU64Col::encoded_len(&keys)
                + RleU64Col::encoded_len(&fps)
                + RleU64Col::encoded_len(&counts),
        );
        RleU64Col::encode(&keys, &mut out);
        RleU64Col::encode(&fps, &mut out);
        RleU64Col::encode(&counts, &mut out);
        out
    }

    /// Parse cells written by [`Iblt::to_columnar_bytes`] from `data[*off..]`, advancing
    /// the cursor. The three columns must decode to the same nonzero length, a multiple
    /// of `n_hashes`, at most [`MAX_COLUMNAR_CELLS`].
    pub fn from_columnar_bytes(data: &[u8], off: &mut usize, params: IbltParams) -> Option<Iblt> {
        let keys = RleU64Col::decode(data, off, MAX_COLUMNAR_CELLS)?;
        let fps = RleU64Col::decode(data, off, MAX_COLUMNAR_CELLS)?;
        let counts = RleU64Col::decode(data, off, MAX_COLUMNAR_CELLS)?;
        let n = keys.len();
        if n == 0 || fps.len() != n || counts.len() != n {
            return None;
        }
        let k = params.n_hashes.max(1) as usize;
        if n % k != 0 {
            return None; // `Iblt::new` always produces a multiple of `n_hashes` cells
        }
        let cells = keys
            .into_iter()
            .zip(fps)
            .zip(counts)
            .map(|((key_xor, fp_xor), c)| Cell { key_xor, fp_xor, count: unzigzag(c) })
            .collect();
        Some(Iblt { params, cells })
    }

    /// Cellwise difference `self − other` (both must share params & size).
    pub fn sub(&self, other: &Iblt) -> Iblt {
        assert_eq!(self.cells.len(), other.cells.len());
        let mut out = self.clone();
        for (c, o) in out.cells.iter_mut().zip(&other.cells) {
            c.key_xor ^= o.key_xor;
            c.fp_xor ^= o.fp_xor;
            c.count -= o.count;
        }
        out
    }

    /// Peel the IBLT. Returns `(positives, negatives)`: keys with net count +1 / −1
    /// (for a difference IBLT: `self`'s unique keys and `other`'s unique keys).
    /// `None` if peeling gets stuck (undersized table).
    pub fn peel(mut self) -> Option<(Vec<u64>, Vec<u64>)> {
        let mut pos = Vec::new();
        let mut neg = Vec::new();
        let mut queue: Vec<usize> = (0..self.cells.len()).collect();
        while let Some(i) = queue.pop() {
            let c = self.cells[i];
            if !(c.count == 1 || c.count == -1) {
                continue;
            }
            if self.fingerprint(c.key_xor) != c.fp_xor {
                continue; // not pure
            }
            let key = c.key_xor;
            let sign = c.count;
            if sign == 1 {
                pos.push(key);
            } else {
                neg.push(key);
            }
            let idx: Vec<u64> = self.indices(key).collect();
            let fp = self.fingerprint(key);
            for j in idx {
                let cj = &mut self.cells[j as usize];
                cj.key_xor ^= key;
                cj.fp_xor ^= fp;
                cj.count -= sign;
                queue.push(j as usize);
            }
        }
        if self.cells.iter().all(|c| *c == Cell::default()) {
            Some((pos, neg))
        } else {
            None
        }
    }
}

/// The D.Digest bidirectional SetX-via-SetR protocol the paper benchmarks (§7.1):
/// round 1: Alice → Bob: IBLT(A) sized for d; Bob peels IBLT(A)−IBLT(B) → A\B, B\A.
/// round 2: Bob → Alice: A\B, charged `|A\B|·log2|A|` bits as in the paper.
/// Returns `(a_minus_b, b_minus_a, total_bytes, rounds)`, growing the table on the rare
/// peel failure (counted in the cost).
pub fn iblt_setx(
    a: &[u64],
    b: &[u64],
    d_est: usize,
    params: IbltParams,
) -> (Vec<u64>, Vec<u64>, usize, usize) {
    let mut cells = params.cells_for(d_est);
    let mut total = 0usize;
    let mut rounds = 0usize;
    loop {
        let mut ia = Iblt::new(cells, params);
        ia.insert_all(a);
        total += ia.size_bytes();
        rounds += 1;
        let mut ib = Iblt::new(cells, params);
        ib.insert_all(b);
        match ia.sub(&ib).peel() {
            Some((mut a_minus_b, mut b_minus_a)) => {
                a_minus_b.sort_unstable();
                b_minus_a.sort_unstable();
                // Round 2: Bob returns A\B to Alice.
                let bits = (a_minus_b.len() as f64 * (a.len().max(2) as f64).log2()).ceil();
                total += (bits as usize).div_ceil(8);
                rounds += 1;
                return (a_minus_b, b_minus_a, total, rounds);
            }
            None => {
                // Undersized: double and retry (cost accrues — honest accounting).
                cells *= 2;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn insert_then_remove_is_empty() {
        let mut t = Iblt::new(64, IbltParams::paper_synthetic());
        for k in 0..20u64 {
            t.insert(k * 7 + 1);
        }
        for k in 0..20u64 {
            t.remove(k * 7 + 1);
        }
        let (p, n) = t.peel().unwrap();
        assert!(p.is_empty() && n.is_empty());
    }

    #[test]
    fn difference_peels_exactly() {
        let (a, b) = synth::overlap_pair(5_000, 40, 60, 1);
        let params = IbltParams::paper_synthetic();
        let mut ia = Iblt::for_difference(120, params);
        ia.insert_all(&a);
        let mut ib = Iblt::for_difference(120, params);
        ib.insert_all(&b);
        let (mut pos, mut neg) = ia.sub(&ib).peel().expect("peel");
        pos.sort_unstable();
        neg.sort_unstable();
        assert_eq!(pos, synth::difference(&a, &b));
        assert_eq!(neg, synth::difference(&b, &a));
    }

    #[test]
    fn undersized_table_fails_not_lies() {
        let (a, b) = synth::overlap_pair(2_000, 100, 100, 2);
        let params = IbltParams::paper_synthetic();
        let mut ia = Iblt::new(40, params); // 200 diffs into 40 cells
        ia.insert_all(&a);
        let mut ib = Iblt::new(40, params);
        ib.insert_all(&b);
        assert!(ia.sub(&ib).peel().is_none());
    }

    #[test]
    fn setx_protocol_end_to_end() {
        let (a, b) = synth::overlap_pair(10_000, 100, 150, 3);
        let (amb, bma, bytes, rounds) = iblt_setx(&a, &b, 250, IbltParams::paper_synthetic());
        assert_eq!(amb, synth::difference(&a, &b));
        assert_eq!(bma, synth::difference(&b, &a));
        assert!(rounds >= 2);
        // ~1.36·250 cells × 13 bytes ≈ 4.4 KB.
        assert!(bytes > 3000 && bytes < 20_000, "bytes {bytes}");
    }

    #[test]
    fn serialization_roundtrips_and_peels() {
        let params = IbltParams::paper_synthetic();
        let mut t = Iblt::new(64, params);
        for k in 0..30u64 {
            t.insert(k * 13 + 7);
        }
        let bytes = t.to_bytes();
        let mut off = 0;
        let back = Iblt::from_bytes(&bytes, &mut off, params).unwrap();
        assert_eq!(off, bytes.len());
        assert_eq!(back.num_cells(), t.num_cells());
        // Semantics survive the roundtrip: subtracting the original leaves nothing.
        let (pos, neg) = back.sub(&t).peel().unwrap();
        assert!(pos.is_empty() && neg.is_empty());
    }

    #[test]
    fn columnar_roundtrips_and_beats_legacy_on_sparse_tables() {
        let params = IbltParams::paper_synthetic();
        let mut t = Iblt::new(256, params);
        for k in 0..10u64 {
            t.insert(k * 13 + 7); // 10 keys into 256+ cells: mostly-empty table
        }
        let legacy = t.to_bytes();
        assert_eq!(legacy.len(), t.legacy_len());
        let blob = t.to_columnar_bytes();
        let mut off = 0;
        let back = Iblt::from_columnar_bytes(&blob, &mut off, params).unwrap();
        assert_eq!(off, blob.len());
        assert_eq!(back.num_cells(), t.num_cells());
        let (pos, neg) = back.sub(&t).peel().unwrap();
        assert!(pos.is_empty() && neg.is_empty());
        // The zero runs collapse: the columnar form is a fraction of the row-major one.
        assert!(blob.len() * 4 < legacy.len(), "columnar {} legacy {}", blob.len(), legacy.len());
    }

    #[test]
    fn columnar_parse_rejects_malformed_columns() {
        let params = IbltParams::paper_synthetic();
        let mut t = Iblt::new(16, params);
        t.insert_all(&[3, 5, 9]);
        let blob = t.to_columnar_bytes();
        for cut in 0..blob.len() {
            let mut off = 0;
            assert!(Iblt::from_columnar_bytes(&blob[..cut], &mut off, params).is_none(), "{cut}");
        }
        // Column length mismatch: 16 keys but a second column claiming 8 elements.
        let mut bad = Vec::new();
        RleU64Col::encode(&[0u64; 16], &mut bad);
        RleU64Col::encode(&[0u64; 8], &mut bad);
        RleU64Col::encode(&[0u64; 16], &mut bad);
        let mut off = 0;
        assert!(Iblt::from_columnar_bytes(&bad, &mut off, params).is_none());
        // Not a multiple of n_hashes (4): 6-cell columns.
        let mut bad = Vec::new();
        for _ in 0..3 {
            RleU64Col::encode(&[0u64; 6], &mut bad);
        }
        let mut off = 0;
        assert!(Iblt::from_columnar_bytes(&bad, &mut off, params).is_none());
        // Empty table.
        let mut bad = Vec::new();
        for _ in 0..3 {
            RleU64Col::encode(&[], &mut bad);
        }
        let mut off = 0;
        assert!(Iblt::from_columnar_bytes(&bad, &mut off, params).is_none());
    }

    #[test]
    fn from_bytes_rejects_inflated_cell_count() {
        let mut data = Vec::new();
        put_varint(&mut data, u64::MAX);
        data.extend_from_slice(&[0u8; 64]);
        let mut off = 0;
        assert!(Iblt::from_bytes(&data, &mut off, IbltParams::paper_synthetic()).is_none());
        // Truncated cell payloads are rejected too.
        let t = Iblt::new(16, IbltParams::paper_synthetic());
        let bytes = t.to_bytes();
        for cut in [1usize, 5, bytes.len() - 1] {
            let mut off = 0;
            assert!(
                Iblt::from_bytes(&bytes[..cut], &mut off, IbltParams::paper_synthetic())
                    .is_none(),
                "cut {cut}"
            );
        }
    }

    #[test]
    fn accounting_matches_cell_widths() {
        let params = IbltParams::paper_ethereum();
        let t = Iblt::new(100, params);
        // 100 cells × (256+48+8) bits = 31200 bits = 3900 bytes.
        assert_eq!(t.size_bytes(), 3900);
    }
}
