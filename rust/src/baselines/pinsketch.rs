//! PinSketch [2] — the classic ECC-based SetR protocol (§8.2).
//!
//! Alice sends the BCH syndromes of her set's characteristic vector; Bob XORs them with his
//! own and decodes the symmetric difference (capacity `t ≥ d`). Communication is `t·m` bits
//! — near the SetR lower bound — but decoding is `O(d²)` (Berlekamp–Massey) plus a Chien
//! search over the universe, which is why the paper's Figure 2b *estimates* ECC costs from
//! the lower bound instead of running them, and why D.Digest beats ECC by 100× in time.
//!
//! Our implementation works over a `2^m − 1` position space (m ≤ 16). Larger universes are
//! handled the way PBS [6] does: hash-partition the universe and PinSketch each partition.
//! That is enough for (a) correctness tests and (b) the decode-timing comparison (bench D1);
//! comm-cost comparisons use the lower-bound estimate exactly like the paper.

use crate::ecc::{BchSyndrome, GF2m};
use crate::hash::hash_u64;
use std::sync::Arc;

/// A PinSketch over positions `< 2^m − 1`.
pub struct PinSketch {
    gf: Arc<GF2m>,
    pub t: usize,
}

impl PinSketch {
    pub fn new(m: u32, t: usize) -> Self {
        PinSketch { gf: Arc::new(GF2m::new(m)), t }
    }

    /// Syndromes of a set of positions.
    pub fn sketch(&self, positions: impl IntoIterator<Item = u32>) -> BchSyndrome {
        BchSyndrome::compute(self.gf.clone(), self.t, positions)
    }

    /// Wire size in bytes.
    pub fn size_bytes(&self) -> usize {
        (self.t * self.gf.m as usize).div_ceil(8)
    }

    /// Reconcile: decode the symmetric difference of the two sketched sets.
    pub fn diff(&self, mine: &BchSyndrome, theirs: &BchSyndrome) -> Option<Vec<u32>> {
        mine.xor(theirs).decode(self.gf.n).ok()
    }
}

/// Partitioned PinSketch SetX over 64-bit ids: hash ids into `parts` partitions, each a
/// position space of `2^m − 1` slots, with per-partition capacity `t`.
/// Position collisions within a partition are detected (colliding ids cancel or co-occur);
/// choose `parts` so occupancy keeps collision probability negligible, as PBS does.
pub struct PartitionedPinSketch {
    pub m: u32,
    pub t: usize,
    pub parts: usize,
    pub seed: u64,
}

impl PartitionedPinSketch {
    /// Map an id to (partition, position).
    fn place(&self, id: u64) -> (usize, u32) {
        let h = hash_u64(id, self.seed);
        let part = (h % self.parts as u64) as usize;
        let pos = ((h >> 32) % ((1u64 << self.m) - 1)) as u32;
        (part, pos)
    }

    /// Compute per-partition sketches of a set.
    pub fn sketch_set(&self, ids: &[u64]) -> Vec<BchSyndrome> {
        let ps = PinSketch::new(self.m, self.t);
        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); self.parts];
        for &id in ids {
            let (part, pos) = self.place(id);
            buckets[part].push(pos);
        }
        buckets.into_iter().map(|b| ps.sketch(b)).collect()
    }

    pub fn total_bytes(&self) -> usize {
        PinSketch::new(self.m, self.t).size_bytes() * self.parts
    }

    /// Reconcile two sides' sketches; returns the *positions* of the symmetric difference
    /// per partition (mapping positions back to ids is the caller's lookup, as in PBS).
    pub fn diff(
        &self,
        mine: &[BchSyndrome],
        theirs: &[BchSyndrome],
    ) -> Option<Vec<Vec<u32>>> {
        let ps = PinSketch::new(self.m, self.t);
        mine.iter()
            .zip(theirs)
            .map(|(a, b)| ps.diff(a, b))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use std::collections::HashMap;

    #[test]
    fn single_partition_reconciles() {
        let ps = PinSketch::new(14, 30);
        let a: Vec<u32> = (0..1000).map(|i| i * 13 + 1).collect();
        let mut b = a.clone();
        b.truncate(990); // 10 unique to Alice
        b.extend([16000u32, 16001, 16002]); // 3 unique to Bob
        let sa = ps.sketch(a.iter().copied());
        let sb = ps.sketch(b.iter().copied());
        let mut diff = ps.diff(&sa, &sb).expect("decode");
        diff.sort_unstable();
        let mut want: Vec<u32> = a[990..].to_vec();
        want.extend([16000, 16001, 16002]);
        want.sort_unstable();
        assert_eq!(diff, want);
    }

    #[test]
    fn partitioned_setx_over_u64_ids() {
        let (a, b) = synth::overlap_pair(5_000, 25, 25, 1);
        let pps = PartitionedPinSketch { m: 14, t: 16, parts: 8, seed: 5 };
        let sa = pps.sketch_set(&a);
        let sb = pps.sketch_set(&b);
        let diffs = pps.diff(&sa, &sb).expect("decode");
        // Map positions back via each side's local (partition, pos) → id table.
        let mut table: HashMap<(usize, u32), u64> = HashMap::new();
        for &id in a.iter().chain(&b) {
            table.insert(pps.place(id), id);
        }
        let mut got: Vec<u64> = diffs
            .iter()
            .enumerate()
            .flat_map(|(p, poss)| poss.iter().map(|&pos| table[&(p, pos)]).collect::<Vec<_>>())
            .collect();
        got.sort_unstable();
        got.dedup();
        let mut want = synth::difference(&a, &b);
        want.extend(synth::difference(&b, &a));
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn overload_fails_loudly() {
        let ps = PinSketch::new(13, 4);
        let sa = ps.sketch((0..40u32).map(|i| i * 17 + 3));
        let sb = ps.sketch(std::iter::empty());
        assert!(ps.diff(&sa, &sb).is_none());
    }

    #[test]
    fn comm_cost_is_t_times_m_bits() {
        let ps = PinSketch::new(16, 100);
        assert_eq!(ps.size_bytes(), 200);
    }
}
