//! Graphene [7] — the state-of-the-art *unidirectional* SetX baseline (§8.3).
//!
//! Alice sends a Bloom filter of `A` plus an IBLT of `A` sized for the Bloom filter's
//! expected false positives among Bob's tested elements. Bob filters `B` through the BF
//! (getting `Â ⊇ A`), subtracts the received IBLT from `IBLT(Â)`, and peels out the false
//! positives `Â \ A`; then `B \ A = (B \ Â) ∪ (Â \ A)`.
//!
//! Parameters (the BF false-positive rate `f`) are chosen by minimizing the total size
//! `BF(|A|, f) + IBLT(padded (|B|−|A|)·f)` with a Chernoff pad for the β = 239/240 decode
//! success target — the same optimization the authors' library performs from `(|A|, |B|, β)`.

use super::iblt::{Iblt, IbltParams};
use crate::smf::BloomFilter;

/// Chernoff-padded false-positive count: `μ + √(3μ·ln(1/δ))` with δ = 1 − β.
fn padded_fp_count(mu: f64, beta: f64) -> f64 {
    let delta = (1.0 - beta).max(1e-9);
    mu + (3.0 * mu * (1.0 / delta).ln()).sqrt()
}

/// BF size in bits for n elements at fpr f.
fn bf_bits(n: usize, f: f64) -> f64 {
    if f >= 1.0 {
        return 0.0;
    }
    -(n as f64) * f.ln() / (std::f64::consts::LN_2 * std::f64::consts::LN_2)
}

/// Total Graphene message size (bits) at false-positive rate `f`.
fn total_bits(a_len: usize, b_len: usize, f: f64, beta: f64, iblt: &IbltParams) -> f64 {
    let testers = (b_len - a_len.min(b_len)) as f64;
    let mu = testers * f;
    let a_star = padded_fp_count(mu, beta);
    let cells = iblt.cells_for(a_star.ceil() as usize);
    bf_bits(a_len, f) + (iblt.size_bytes(cells) * 8) as f64
}

/// Pick the optimal BF false-positive rate by golden-section search over log-f, including
/// the `f = 1` endpoint (no BF ⇒ Graphene degenerates to a pure IBLT, as the paper notes
/// happens for very small d).
fn optimize_fpr(a_len: usize, b_len: usize, beta: f64, iblt: &IbltParams) -> f64 {
    let eval = |logf: f64| total_bits(a_len, b_len, logf.exp(), beta, iblt);
    let (mut lo, mut hi) = ((1e-8f64).ln(), (0.999f64).ln());
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    for _ in 0..60 {
        let m1 = hi - phi * (hi - lo);
        let m2 = lo + phi * (hi - lo);
        if eval(m1) <= eval(m2) {
            hi = m2;
        } else {
            lo = m1;
        }
    }
    let f_opt = ((lo + hi) / 2.0).exp();
    // Degenerate endpoint: pure IBLT of the whole symmetric difference.
    if total_bits(a_len, b_len, 1.0, beta, iblt) < total_bits(a_len, b_len, f_opt, beta, iblt) {
        1.0
    } else {
        f_opt
    }
}

/// Outcome of a Graphene run.
#[derive(Clone, Debug)]
pub struct GrapheneOutcome {
    pub b_minus_a: Vec<u64>,
    pub total_bytes: usize,
    pub bf_bytes: usize,
    pub iblt_bytes: usize,
    /// Peel failures that forced a resend with a doubled IBLT.
    pub retries: usize,
}

/// Run Graphene for unidirectional SetX (`A ⊆ B`): returns Bob's exact `B \ A`.
pub fn graphene_setx(
    a: &[u64],
    b: &[u64],
    beta: f64,
    iblt_params: IbltParams,
    seed: u64,
) -> GrapheneOutcome {
    let f = optimize_fpr(a.len(), b.len(), beta, &iblt_params);
    let mut retries = 0usize;
    let mut total_bytes = 0usize;

    // --- Alice's side: BF(A) + IBLT(A).
    let (bf, bf_bytes) = if f < 1.0 {
        let mut bf = BloomFilter::with_fpr(a.len(), f, seed);
        for &x in a {
            bf.insert(x);
        }
        let bytes = bf.to_bytes().len();
        (Some(bf), bytes)
    } else {
        (None, 0)
    };
    total_bytes += bf_bytes;

    let testers = (b.len() - a.len().min(b.len())) as f64;
    let a_star = padded_fp_count(testers * f, beta).ceil() as usize;
    let mut cells = iblt_params.cells_for(a_star.max(1));

    loop {
        let mut iblt_a = Iblt::new(cells, iblt_params);
        iblt_a.insert_all(a);
        let iblt_bytes = iblt_a.size_bytes();
        total_bytes += iblt_bytes;

        // --- Bob's side.
        let (a_hat, mut b_minus_a): (Vec<u64>, Vec<u64>) = match &bf {
            Some(bf) => b.iter().partition(|&&x| bf.contains(x)),
            None => (b.to_vec(), Vec::new()),
        };
        let mut iblt_ahat = Iblt::new(cells, iblt_params);
        iblt_ahat.insert_all(&a_hat);
        match iblt_ahat.sub(&iblt_a).peel() {
            Some((false_positives, missing)) => {
                // `missing` would be elements of A absent from Â — impossible when A ⊆ B
                // and the BF has no false negatives; peeling confirming that is part of
                // correctness.
                debug_assert!(missing.is_empty());
                b_minus_a.extend(false_positives);
                b_minus_a.sort_unstable();
                return GrapheneOutcome {
                    b_minus_a,
                    total_bytes,
                    bf_bytes,
                    iblt_bytes,
                    retries,
                };
            }
            None => {
                retries += 1;
                cells *= 2; // resend a bigger IBLT; cost keeps accruing
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn exact_b_minus_a() {
        let (a, b) = synth::subset_pair(10_000, 100, 1);
        let out = graphene_setx(&a, &b, 239.0 / 240.0, IbltParams::paper_synthetic(), 7);
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
    }

    #[test]
    fn exact_across_seeds_and_sizes() {
        for (d, seed) in [(10usize, 2u64), (500, 3), (3000, 4)] {
            let (a, b) = synth::subset_pair(20_000, d, seed);
            let out = graphene_setx(&a, &b, 239.0 / 240.0, IbltParams::paper_synthetic(), seed);
            assert_eq!(out.b_minus_a, synth::difference(&b, &a), "d={d}");
        }
    }

    #[test]
    fn bf_kicks_in_at_large_d_and_beats_pure_iblt() {
        // At d ≫ |A| the BF trades |A|-proportional bits against the (much larger) IBLT of
        // all of B\A — the regime where Graphene shines (Figure 2a right end).
        let (a, b) = synth::subset_pair(5_000, 25_000, 5);
        let params = IbltParams::paper_synthetic();
        let out = graphene_setx(&a, &b, 239.0 / 240.0, params, 5);
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert!(out.bf_bytes > 0, "BF must be in play at large d");
        let pure_iblt = params.size_bytes(params.cells_for(25_000));
        assert!(
            out.total_bytes < pure_iblt,
            "graphene {} vs pure IBLT {}",
            out.total_bytes,
            pure_iblt
        );
    }

    #[test]
    fn degenerates_to_pure_iblt_at_small_d() {
        // d ≪ |A|: the optimizer drops the BF (f = 1), exactly as §8.3 describes.
        let (a, b) = synth::subset_pair(50_000, 50, 6);
        let out = graphene_setx(&a, &b, 239.0 / 240.0, IbltParams::paper_synthetic(), 6);
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert_eq!(out.bf_bytes, 0, "BF should be dropped at tiny d");
    }

    #[test]
    fn degenerates_to_pure_iblt_when_d_tiny() {
        // Tiny universe of testers: optimizer should pick f = 1 (no BF).
        let f = optimize_fpr(100_000, 100_010, 239.0 / 240.0, &IbltParams::paper_synthetic());
        assert!((f - 1.0).abs() < 1e-9, "f = {f}");
    }

    #[test]
    fn optimizer_picks_interior_f_at_moderate_d() {
        let f = optimize_fpr(100_000, 200_000, 239.0 / 240.0, &IbltParams::paper_synthetic());
        assert!(f < 0.5 && f > 1e-7, "f = {f}");
    }
}
