//! Baseline protocols (§7.1, §8): IBLT, Graphene, CBF approximate SetX, PinSketch.
pub mod iblt;
pub mod graphene;
pub mod cbf_setx;
pub mod pinsketch;
