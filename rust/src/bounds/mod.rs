//! Information-theoretic lower bounds (§6 and the SetR bound of Minsky et al. [1]).
//!
//! * SetX (eq. 6): `log2 C(|A|, |A\B|) + log2 C(|B|, |B\A|)` — the entropy reduction needed
//!   for both sides to learn the partition of their own set into shared/unique.
//! * SetR [1]: `d · log2(e·|U|/d)` bits — what any reconciliation protocol must move.
//!
//! The paper's headline: the SetX bound scales with `log(|set|/d)` while SetR's scales with
//! `log(|U|/d)`, a gap of `d·log2(|U|/|B|)` bits (a factor 24.8 on the Ethereum example).

/// `log2(n choose k)` via the log-gamma function (Lanczos), exact enough for bound
/// reporting at any scale.
pub fn log2_binomial(n: f64, k: f64) -> f64 {
    if k <= 0.0 || k >= n {
        return 0.0;
    }
    (ln_gamma(n + 1.0) - ln_gamma(k + 1.0) - ln_gamma(n - k + 1.0)) / std::f64::consts::LN_2
}

/// Lanczos approximation of ln Γ(x), |err| < 2e-10 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const G: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = G[0];
    let t = x + 7.5;
    for (i, &g) in G.iter().enumerate().skip(1) {
        a += g / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// SetX lower bound (eq. 6), in **bits**.
pub fn setx_lower_bound_bits(a: u64, b: u64, a_unique: u64, b_unique: u64) -> f64 {
    log2_binomial(a as f64, a_unique as f64) + log2_binomial(b as f64, b_unique as f64)
}

/// The closed-form approximation the paper quotes: `d·log2(e|A|/d)` bits.
pub fn setx_lower_bound_approx_bits(a: u64, d: u64) -> f64 {
    if d == 0 {
        return 0.0;
    }
    d as f64 * (std::f64::consts::E * a as f64 / d as f64).log2()
}

/// SetR lower bound of [1]: `d·log2(e|U|/d)` bits, with the universe given as `u = log2|U|`.
pub fn setr_lower_bound_bits(universe_bits: u32, d: u64) -> f64 {
    if d == 0 {
        return 0.0;
    }
    let log2_u = universe_bits as f64;
    d as f64 * (std::f64::consts::E.log2() + log2_u - (d as f64).log2())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_matches_factorials() {
        let mut fact = 1.0f64;
        for n in 1..15u32 {
            fact *= n as f64;
            assert!(
                (ln_gamma(n as f64 + 1.0) - fact.ln()).abs() < 1e-8,
                "n={n}"
            );
        }
    }

    #[test]
    fn binomial_small_exact() {
        assert!((log2_binomial(10.0, 3.0) - (120.0f64).log2()).abs() < 1e-8);
        assert_eq!(log2_binomial(10.0, 0.0), 0.0);
        assert_eq!(log2_binomial(10.0, 10.0), 0.0);
    }

    #[test]
    fn example3_numbers() {
        // §3.1 Example 3: |A|=10^6, |B|=1.01·10^6, d=10^4, |U|=2^64:
        // SetR bound ≈ 65.2 KB, SetX bound ≈ 10.1 KB.
        let setr_kb = setr_lower_bound_bits(64, 10_000) / 8.0 / 1000.0;
        assert!((setr_kb - 65.2).abs() < 1.5, "SetR bound {setr_kb} KB");
        let setx_kb = setx_lower_bound_bits(1_000_000, 1_010_000, 0, 10_000) / 8.0 / 1000.0;
        assert!((setx_kb - 10.1).abs() < 1.5, "SetX bound {setx_kb} KB");
    }

    #[test]
    fn example11_numbers() {
        // §5 Example 11: |A|=|B|=1.01·10^6, d=2·10^4 split evenly, |U|=2^256:
        // SetR ≈ 610.4 KB, SetX ≈ 20.3 KB.
        let setr_kb = setr_lower_bound_bits(256, 20_000) / 8.0 / 1000.0;
        assert!((setr_kb - 610.4).abs() < 8.0, "SetR bound {setr_kb} KB");
        let setx_kb =
            setx_lower_bound_bits(1_010_000, 1_010_000, 10_000, 10_000) / 8.0 / 1000.0;
        assert!((setx_kb - 20.3).abs() < 1.5, "SetX bound {setx_kb} KB");
    }

    #[test]
    fn ethereum_gap_factor() {
        // §1.1: |U|=2^256, |A| ≈ 2.8·10^8, d = 10^6 ⇒ gap ≈ 24.8× (1.2 MB vs 29.7 MB).
        let setr = setr_lower_bound_bits(256, 1_000_000);
        let setx = setx_lower_bound_approx_bits(280_000_000, 1_000_000);
        let ratio = setr / setx;
        assert!((ratio - 24.8).abs() < 1.5, "ratio {ratio}");
        assert!((setr / 8.0 / 1e6 - 29.7).abs() < 1.5, "{}", setr / 8e6);
        assert!((setx / 8.0 / 1e6 - 1.2).abs() < 0.2, "{}", setx / 8e6);
    }
}
