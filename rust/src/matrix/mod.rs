//! The sparse binary CS matrix `M` (Definition 6) — friendly *and* compliant.
//!
//! `M` is the adjacency matrix of a random m-right-regular bipartite graph with `l` left
//! nodes (rows) and `2^u` right nodes (columns = universe elements). It is never
//! materialized: columns are generated implicitly by [`crate::hash::ColumnSampler`].
//! Restricted to any candidate set (e.g. `B`), it is an expander with high probability
//! (Theorem 8), hence RIP-1 (Theorem 7), which underwrites the exactness of the protocol.
//!
//! This module also provides:
//! * dense-block materialization (`dense_block`) used by the PJRT/XLA accelerated path;
//! * an empirical expander-quality probe (`expansion_probe`) used by tests and ablations.

use crate::hash::{hash_u64, ColumnSampler};

/// Anything that can produce CS-matrix columns: the implicit [`CsMatrix`] in production,
/// an [`ExplicitMatrix`] in tests/ablations (e.g. the paper's Appendix A Example 13).
pub trait ColumnOracle {
    /// Number of rows.
    fn l(&self) -> u32;
    /// Ones per column.
    fn m(&self) -> u32;
    /// Row indices of column `id` written into `buf` (length ≥ `m()`); returns filled slice.
    fn column_into<'a>(&self, id: u64, buf: &'a mut [u32]) -> &'a [u32];
    /// Cache discriminator: equal fingerprints (together with equal `(l, m)`, which
    /// [`crate::decoder::DecoderCache`] checks exactly) must imply equal column
    /// functions, so a cached decoder built against one oracle can be reused against
    /// another. Deliberately has **no default**: an implementation that forgot to cover
    /// everything its columns depend on would silently alias distinct matrices in the
    /// cache.
    fn structure_fingerprint(&self) -> u64;
}

/// A fully materialized matrix keyed by small integer ids — for unit tests and the
/// worked example of Appendix A.
#[derive(Clone, Debug)]
pub struct ExplicitMatrix {
    pub l: u32,
    pub cols: Vec<Vec<u32>>,
}

impl ColumnOracle for ExplicitMatrix {
    fn l(&self) -> u32 {
        self.l
    }

    fn m(&self) -> u32 {
        self.cols.iter().map(|c| c.len()).max().unwrap_or(0) as u32
    }

    fn column_into<'a>(&self, id: u64, buf: &'a mut [u32]) -> &'a [u32] {
        let col = &self.cols[id as usize];
        buf[..col.len()].copy_from_slice(col);
        &buf[..col.len()]
    }

    fn structure_fingerprint(&self) -> u64 {
        // Explicit matrices are tiny (tests/worked examples): hash the full contents so
        // two different matrices never alias in a decoder cache.
        let mut h = hash_u64(self.l as u64, 0x0a11_0c58);
        for col in &self.cols {
            h = hash_u64(h ^ col.len() as u64, 0x0a11_0c59);
            for &r in col {
                h = hash_u64(h ^ r as u64, 0x0a11_0c5a);
            }
        }
        h
    }
}

/// Handle to the (implicit) CS matrix: dimensions + the column sampler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CsMatrix {
    pub sampler: ColumnSampler,
}

impl ColumnOracle for CsMatrix {
    fn l(&self) -> u32 {
        self.sampler.l
    }

    fn m(&self) -> u32 {
        self.sampler.m
    }

    fn column_into<'a>(&self, id: u64, buf: &'a mut [u32]) -> &'a [u32] {
        self.sampler.rows_into(id, buf)
    }

    fn structure_fingerprint(&self) -> u64 {
        // Columns are a pure function of (l, m, seed).
        let mut h = hash_u64(self.sampler.seed, 0x0a11_0c5b);
        h = hash_u64(h ^ self.sampler.l as u64, 0x0a11_0c5c);
        hash_u64(h ^ self.sampler.m as u64, 0x0a11_0c5d)
    }
}

impl CsMatrix {
    /// Create an `l × 2^64` implicit matrix with `m` ones per column.
    pub fn new(l: u32, m: u32, seed: u64) -> Self {
        CsMatrix { sampler: ColumnSampler::new(l, m, seed) }
    }

    #[inline]
    pub fn l(&self) -> u32 {
        self.sampler.l
    }

    #[inline]
    pub fn m(&self) -> u32 {
        self.sampler.m
    }

    /// Row indices of column `id` (unsorted), written into `buf`.
    #[inline]
    pub fn column_into<'a>(&self, id: u64, buf: &'a mut [u32]) -> &'a [u32] {
        self.sampler.rows_into(id, buf)
    }

    /// Row indices of column `id` (allocating).
    pub fn column(&self, id: u64) -> Vec<u32> {
        self.sampler.rows(id)
    }

    /// Materialize the dense `l × ids.len()` 0/1 block for a slice of candidate ids,
    /// **column-major** f32 (the layout the AOT-compiled XLA encode/correlate graphs take).
    pub fn dense_block(&self, ids: &[u64]) -> Vec<f32> {
        let l = self.l() as usize;
        let mut block = vec![0.0f32; l * ids.len()];
        let mut buf = vec![0u32; self.m() as usize];
        for (c, &id) in ids.iter().enumerate() {
            for &r in self.column_into(id, &mut buf) {
                block[c * l + r as usize] = 1.0;
            }
        }
        block
    }

    /// Materialize a **row-major** `l × nb` f32 block for `ids` (padded with zero columns
    /// up to `nb`) — the layout the AOT-compiled XLA graphs take (JAX arrays are C-order).
    pub fn dense_block_rowmajor(&self, ids: &[u64], nb: usize) -> Vec<f32> {
        assert!(ids.len() <= nb);
        let l = self.l() as usize;
        let mut block = vec![0.0f32; l * nb];
        let mut buf = vec![0u32; self.m() as usize];
        for (c, &id) in ids.iter().enumerate() {
            for &r in self.column_into(id, &mut buf) {
                block[r as usize * nb + c] = 1.0;
            }
        }
        block
    }

    /// Empirically probe the expansion of the bipartite graph restricted to `ids`:
    /// sample `trials` random subsets of size `s` and return the minimum observed
    /// |N(S)| / (m·|S|) ratio. Theorem 7 wants ≥ 5/6 for subsets up to size 2d.
    pub fn expansion_probe(&self, ids: &[u64], s: usize, trials: usize, seed: u64) -> f64 {
        use crate::hash::Xoshiro256;
        assert!(s <= ids.len());
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut worst = 1.0f64;
        let mut buf = vec![0u32; self.m() as usize];
        let mut mark = vec![false; self.l() as usize];
        // One s·m-capacity scratch for the whole probe: the per-trial allocation used
        // to dominate small-s sweeps (`trials` heap round-trips for a buffer whose size
        // never changes); `clear()` keeps the capacity across trials.
        let mut touched: Vec<u32> = Vec::with_capacity(s * self.m() as usize);
        for _ in 0..trials {
            let mut distinct = 0usize;
            touched.clear();
            for _ in 0..s {
                let id = ids[rng.gen_range(ids.len() as u64) as usize];
                for &r in self.column_into(id, &mut buf) {
                    if !mark[r as usize] {
                        mark[r as usize] = true;
                        touched.push(r);
                        distinct += 1;
                    }
                }
            }
            for &r in &touched {
                mark[r as usize] = false;
            }
            let ratio = distinct as f64 / (s as f64 * self.m() as f64);
            worst = worst.min(ratio);
        }
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_block_matches_columns() {
        let mat = CsMatrix::new(64, 5, 3);
        let ids = [10u64, 20, 30];
        let block = mat.dense_block(&ids);
        for (c, &id) in ids.iter().enumerate() {
            let col = &block[c * 64..(c + 1) * 64];
            let ones: Vec<u32> = col
                .iter()
                .enumerate()
                .filter(|(_, &v)| v == 1.0)
                .map(|(r, _)| r as u32)
                .collect();
            let mut expect = mat.column(id);
            expect.sort_unstable();
            assert_eq!(ones, expect);
            assert_eq!(col.iter().sum::<f32>(), 5.0);
        }
    }

    #[test]
    fn expander_probe_passes_theorem7_threshold() {
        // The 5/6 expansion of Theorem 7 for subsets of size 2d needs l well above 2d·m
        // (balls-in-bins: expected distinct rows = l(1−e^{−2dm/l})). At l = 4096, 2d = 64,
        // m = 7 the expected ratio is ≈ 0.95, comfortably above 5/6. (The *protocol* runs at
        // much smaller l where the paper relies on empirical MP success, not this constant.)
        let mat = CsMatrix::new(4096, 7, 99);
        let ids: Vec<u64> = (0..2000u64).collect();
        let worst = mat.expansion_probe(&ids, 64, 200, 1);
        assert!(worst >= 5.0 / 6.0, "worst expansion ratio {worst}");
    }

    #[test]
    fn expansion_degrades_when_l_too_small() {
        // Sanity: with far too few rows the graph cannot expand.
        let mat = CsMatrix::new(64, 7, 99);
        let ids: Vec<u64> = (0..2000u64).collect();
        let worst = mat.expansion_probe(&ids, 64, 50, 1);
        assert!(worst < 5.0 / 6.0, "expansion unexpectedly high: {worst}");
    }
}
