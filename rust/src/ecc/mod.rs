//! Error-correction substrate: GF(2^m) arithmetic and BCH *syndrome* codes.
//!
//! Two consumers in this repo:
//! * **Appendix C.2** — the quotient-parity patch of the statistical-truncation codec:
//!   Alice sends BCH syndromes of her parity bit-vector; Bob XORs them with his own
//!   syndromes, decodes the (sparse) difference via Berlekamp–Massey + Chien search, and
//!   repairs the mismatching sketch coordinates.
//! * **PinSketch** (§8.2) — the classic ECC-based SetR baseline: syndromes of a set's
//!   characteristic vector; the symmetric difference is the decoded error-location set.
//!
//! Syndromes are linear over GF(2), and in characteristic 2 `S_{2k} = S_k²`, so only the odd
//! syndromes `S_1, S_3, …, S_{2t−1}` need to be communicated — `t·m` bits for capacity `t`
//! (exactly PinSketch's communication cost).

mod bch;
mod gf;

pub use bch::{BchSyndrome, SyndromeDecodeError};
pub use gf::GF2m;
