//! BCH syndrome computation and decoding (Berlekamp–Massey + Chien search).
//!
//! A `BchSyndrome` summarizes a GF(2) vector (given by the *positions* of its ones) into the
//! odd power sums `S_k = Σ_{i∈ones} (α^i)^k`, k = 1, 3, …, 2t−1. XORing two parties'
//! syndromes yields the syndrome of the XOR of their vectors (linearity), whose support can
//! be decoded exactly as long as it has weight ≤ t — this is PinSketch, and also how the
//! Appendix C.2 parity patch travels.

use super::gf::GF2m;
use std::sync::Arc;

/// Decoding failure modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyndromeDecodeError {
    /// The error-locator polynomial degree exceeded the capacity t.
    TooManyErrors,
    /// Chien search found fewer roots than the locator degree (≥ t+1 actual errors).
    RootCountMismatch,
}

impl std::fmt::Display for SyndromeDecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::TooManyErrors => write!(f, "error weight exceeds BCH capacity"),
            Self::RootCountMismatch => write!(f, "error locator has non-field roots"),
        }
    }
}

impl std::error::Error for SyndromeDecodeError {}

/// Syndromes of a GF(2) vector with correction capacity `t` over GF(2^m).
#[derive(Clone)]
pub struct BchSyndrome {
    pub gf: Arc<GF2m>,
    pub t: usize,
    /// Odd syndromes S_1, S_3, …, S_{2t−1}.
    pub odd: Vec<u32>,
}

impl BchSyndrome {
    /// Compute syndromes of the vector with ones at `positions` (each < 2^m − 1).
    pub fn compute(gf: Arc<GF2m>, t: usize, positions: impl IntoIterator<Item = u32>) -> Self {
        let mut odd = vec![0u32; t];
        for pos in positions {
            debug_assert!(pos < gf.n, "position {pos} out of field range {}", gf.n);
            let x = gf.alpha_pow(pos as u64); // α^pos
            let x2 = gf.sq(x);
            let mut xp = x; // x^(2j+1), starting at j=0
            for s in odd.iter_mut() {
                *s ^= xp;
                xp = gf.mul(xp, x2);
            }
        }
        BchSyndrome { gf, t, odd }
    }

    /// Communication size in bits: t syndromes of m bits each.
    pub fn size_bits(&self) -> usize {
        self.t * self.gf.m as usize
    }

    /// Cellwise XOR — the syndrome of the XOR (symmetric difference) of the two vectors.
    pub fn xor(&self, other: &BchSyndrome) -> BchSyndrome {
        assert_eq!(self.t, other.t);
        assert_eq!(self.gf.m, other.gf.m);
        BchSyndrome {
            gf: self.gf.clone(),
            t: self.t,
            odd: self.odd.iter().zip(&other.odd).map(|(a, b)| a ^ b).collect(),
        }
    }

    pub fn is_zero(&self) -> bool {
        self.odd.iter().all(|&s| s == 0)
    }

    /// Serialize to packed bytes (t·m bits, little-endian bit order).
    pub fn to_bytes(&self) -> Vec<u8> {
        let m = self.gf.m as usize;
        let nbits = self.t * m;
        let mut out = vec![0u8; nbits.div_ceil(8)];
        for (i, &s) in self.odd.iter().enumerate() {
            for b in 0..m {
                if s >> b & 1 == 1 {
                    let bit = i * m + b;
                    out[bit / 8] |= 1 << (bit % 8);
                }
            }
        }
        out
    }

    pub fn from_bytes(gf: Arc<GF2m>, t: usize, data: &[u8]) -> Option<Self> {
        let m = gf.m as usize;
        let nbits = t * m;
        if data.len() < nbits.div_ceil(8) {
            return None;
        }
        let mut odd = vec![0u32; t];
        for (i, s) in odd.iter_mut().enumerate() {
            for b in 0..m {
                let bit = i * m + b;
                if data[bit / 8] >> (bit % 8) & 1 == 1 {
                    *s |= 1 << b;
                }
            }
        }
        Some(BchSyndrome { gf, t, odd })
    }

    /// Decode the support of the underlying vector, assuming its weight is ≤ t.
    /// `search_limit` restricts the Chien search to positions `< search_limit`
    /// (positions at or beyond the limit count as missing roots → error).
    pub fn decode(&self, search_limit: u32) -> Result<Vec<u32>, SyndromeDecodeError> {
        let gf = &self.gf;
        if self.is_zero() {
            return Ok(Vec::new());
        }
        // Expand to the full syndrome sequence S_1..S_2t using S_{2k} = S_k².
        let two_t = 2 * self.t;
        let mut s = vec![0u32; two_t + 1]; // 1-indexed
        for (j, &v) in self.odd.iter().enumerate() {
            s[2 * j + 1] = v;
        }
        for k in 1..=self.t {
            s[2 * k] = gf.sq(s[k]);
        }

        // Berlekamp–Massey: find the minimal LFSR Λ(x) generating S_1..S_2t.
        let mut lambda = vec![0u32; two_t + 1];
        let mut b = vec![0u32; two_t + 1];
        lambda[0] = 1;
        b[0] = 1;
        let mut deg_l = 0usize;
        let mut mm = 1usize; // steps since last update
        let mut bb = 1u32; // last nonzero discrepancy
        for n in 0..two_t {
            // Discrepancy d = S_{n+1} + Σ_{i=1..deg_l} Λ_i · S_{n+1−i}
            let mut d = s[n + 1];
            for i in 1..=deg_l {
                d ^= gf.mul(lambda[i], s[n + 1 - i]);
            }
            if d == 0 {
                mm += 1;
            } else if 2 * deg_l <= n {
                let t_poly = lambda.clone();
                let coef = gf.div(d, bb);
                for i in 0..=two_t - mm {
                    lambda[i + mm] ^= gf.mul(coef, b[i]);
                }
                deg_l = n + 1 - deg_l;
                b = t_poly;
                bb = d;
                mm = 1;
            } else {
                let coef = gf.div(d, bb);
                for i in 0..=two_t - mm {
                    lambda[i + mm] ^= gf.mul(coef, b[i]);
                }
                mm += 1;
            }
        }
        if deg_l > self.t {
            return Err(SyndromeDecodeError::TooManyErrors);
        }
        lambda.truncate(deg_l + 1);

        // Chien search: position i is an error iff Λ(α^{−i}) = 0.
        let mut roots = Vec::with_capacity(deg_l);
        for i in 0..search_limit.min(gf.n) {
            let x = gf.alpha_pow((gf.n - i % gf.n) as u64 % gf.n as u64); // α^{−i}
            if gf.poly_eval(&lambda, x) == 0 {
                roots.push(i);
                if roots.len() == deg_l {
                    break;
                }
            }
        }
        if roots.len() != deg_l {
            return Err(SyndromeDecodeError::RootCountMismatch);
        }
        Ok(roots)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Xoshiro256;

    fn gf13() -> Arc<GF2m> {
        Arc::new(GF2m::new(13))
    }

    #[test]
    fn zero_vector_decodes_empty() {
        let s = BchSyndrome::compute(gf13(), 8, std::iter::empty());
        assert!(s.is_zero());
        assert_eq!(s.decode(8000).unwrap(), Vec::<u32>::new());
    }

    #[test]
    fn single_error_roundtrip() {
        for pos in [0u32, 1, 100, 8000] {
            let s = BchSyndrome::compute(gf13(), 4, [pos]);
            assert_eq!(s.decode(8191).unwrap(), vec![pos], "pos {pos}");
        }
    }

    #[test]
    fn random_supports_roundtrip_up_to_t() {
        let gf = gf13();
        let mut rng = Xoshiro256::seed_from_u64(1);
        for t in [4usize, 16, 40] {
            for trial in 0..5 {
                let w = (t as u64).min(1 + rng.gen_range(t as u64));
                let mut positions: Vec<u32> = Vec::new();
                while positions.len() < w as usize {
                    let p = rng.gen_range(8000) as u32;
                    if !positions.contains(&p) {
                        positions.push(p);
                    }
                }
                let s = BchSyndrome::compute(gf.clone(), t, positions.iter().copied());
                let mut got = s.decode(8191).expect("decode");
                got.sort_unstable();
                positions.sort_unstable();
                assert_eq!(got, positions, "t={t} trial={trial}");
            }
        }
    }

    #[test]
    fn xor_gives_symmetric_difference() {
        let gf = gf13();
        let alice = [5u32, 77, 1000, 4000];
        let bob = [77u32, 1000, 2222];
        let sa = BchSyndrome::compute(gf.clone(), 6, alice.iter().copied());
        let sb = BchSyndrome::compute(gf.clone(), 6, bob.iter().copied());
        let mut diff = sa.xor(&sb).decode(8191).unwrap();
        diff.sort_unstable();
        assert_eq!(diff, vec![5, 2222, 4000]);
    }

    #[test]
    fn overload_detected() {
        let gf = gf13();
        let t = 4;
        // Weight 12 ≫ t=4: must error out, not silently return wrong positions.
        let positions: Vec<u32> = (0..12).map(|i| i * 321 + 7).collect();
        let s = BchSyndrome::compute(gf, t, positions);
        assert!(s.decode(8191).is_err());
    }

    #[test]
    fn bytes_roundtrip() {
        let gf = gf13();
        let s = BchSyndrome::compute(gf.clone(), 5, [3u32, 999, 7777]);
        let bytes = s.to_bytes();
        assert_eq!(bytes.len(), (5 * 13usize).div_ceil(8));
        let back = BchSyndrome::from_bytes(gf, 5, &bytes).unwrap();
        assert_eq!(back.odd, s.odd);
    }

    #[test]
    fn search_limit_respected() {
        let gf = gf13();
        let s = BchSyndrome::compute(gf, 2, [6000u32]);
        // Limit below the error position → root not found → error, not a wrong answer.
        assert!(s.decode(100).is_err());
    }
}
