//! GF(2^m) via log/antilog tables, m ∈ [3, 16].

/// Primitive polynomials (low bits; bit m implied) — the standard table used by the Linux
/// kernel BCH module, among others.
fn primitive_poly(m: u32) -> u32 {
    match m {
        3 => 0b1011,
        4 => 0b10011,
        5 => 0b100101,
        6 => 0b1000011,
        7 => 0b10001001,
        8 => 0x11D,
        9 => 0x211,
        10 => 0x409,
        11 => 0x805,
        12 => 0x1053,
        13 => 0x201B,
        14 => 0x4443,
        15 => 0x8003,
        16 => 0x1100B,
        _ => panic!("unsupported GF(2^{m})"),
    }
}

/// The field GF(2^m). Elements are `u32` in `[0, 2^m)`; `0` is the additive identity,
/// `alpha = 2` (the polynomial `x`) is a primitive element.
#[derive(Clone)]
pub struct GF2m {
    pub m: u32,
    /// Field size minus one (the multiplicative group order).
    pub n: u32,
    exp: Vec<u32>, // exp[i] = alpha^i, doubled to avoid a mod in mul
    log: Vec<u32>, // log[x] = discrete log of x (log[0] unused)
}

impl GF2m {
    pub fn new(m: u32) -> Self {
        assert!((3..=16).contains(&m));
        let poly = primitive_poly(m);
        let n = (1u32 << m) - 1;
        let mut exp = vec![0u32; 2 * n as usize];
        let mut log = vec![0u32; (n + 1) as usize];
        let mut x = 1u32;
        for i in 0..n {
            exp[i as usize] = x;
            log[x as usize] = i;
            x <<= 1;
            if x & (1 << m) != 0 {
                x ^= poly;
            }
        }
        for i in 0..n {
            exp[(n + i) as usize] = exp[i as usize];
        }
        GF2m { m, n, exp, log }
    }

    /// alpha^i (i may be ≥ n; reduced mod n).
    #[inline]
    pub fn alpha_pow(&self, i: u64) -> u32 {
        self.exp[(i % self.n as u64) as usize]
    }

    #[inline]
    pub fn mul(&self, a: u32, b: u32) -> u32 {
        if a == 0 || b == 0 {
            0
        } else {
            self.exp[(self.log[a as usize] + self.log[b as usize]) as usize]
        }
    }

    #[inline]
    pub fn sq(&self, a: u32) -> u32 {
        self.mul(a, a)
    }

    #[inline]
    pub fn inv(&self, a: u32) -> u32 {
        assert!(a != 0, "inverse of zero");
        self.exp[(self.n - self.log[a as usize]) as usize]
    }

    #[inline]
    pub fn div(&self, a: u32, b: u32) -> u32 {
        if a == 0 {
            0
        } else {
            self.exp[(self.log[a as usize] + self.n - self.log[b as usize]) as usize]
        }
    }

    /// Discrete log (a ≠ 0).
    #[inline]
    pub fn dlog(&self, a: u32) -> u32 {
        debug_assert!(a != 0);
        self.log[a as usize]
    }

    /// Evaluate polynomial `coeffs[0] + coeffs[1]·x + …` at `x`.
    pub fn poly_eval(&self, coeffs: &[u32], x: u32) -> u32 {
        let mut acc = 0u32;
        for &c in coeffs.iter().rev() {
            acc = self.mul(acc, x) ^ c;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplicative_group_is_cyclic_of_full_order() {
        for m in [3u32, 8, 13] {
            let gf = GF2m::new(m);
            // alpha generates all n distinct nonzero elements.
            let mut seen = std::collections::HashSet::new();
            for i in 0..gf.n as u64 {
                assert!(seen.insert(gf.alpha_pow(i)), "m={m} repeat at {i}");
            }
            assert_eq!(gf.alpha_pow(gf.n as u64), 1);
        }
    }

    #[test]
    fn field_axioms_spot_checks() {
        let gf = GF2m::new(10);
        let n = gf.n;
        for a in [1u32, 2, 3, 57, n - 1, n] {
            let a = a.min(n);
            assert_eq!(gf.mul(a, gf.inv(a)), 1, "a={a}");
            assert_eq!(gf.mul(a, 1), a);
            assert_eq!(gf.mul(a, 0), 0);
            for b in [1u32, 5, 1000.min(n)] {
                assert_eq!(gf.mul(a, b), gf.mul(b, a));
                assert_eq!(gf.div(gf.mul(a, b), b), a);
            }
        }
    }

    #[test]
    fn frobenius_squaring_is_linear() {
        // (a + b)^2 = a^2 + b^2 in characteristic 2.
        let gf = GF2m::new(12);
        for (a, b) in [(3u32, 77u32), (100, 200), (4095, 1)] {
            assert_eq!(gf.sq(a ^ b), gf.sq(a) ^ gf.sq(b));
        }
    }

    #[test]
    fn poly_eval_matches_manual() {
        let gf = GF2m::new(8);
        // p(x) = 1 + 3x + 7x^2 at x = 5: 1 ^ mul(3,5) ^ mul(7, mul(5,5))
        let manual = 1 ^ gf.mul(3, 5) ^ gf.mul(7, gf.mul(5, 5));
        assert_eq!(gf.poly_eval(&[1, 3, 7], 5), manual);
    }
}
