//! Decoder internals: CSR column cache, reverse lookup, lazy priority queue, pursuit loop.
//!
//! Construction (the dominant per-session cost: column sampling + CSR + reverse lookup
//! over all n candidates) is parallelized across a bounded worker pool when
//! [`DecoderConfig::build_threads`] allows it; the parallel path is **bit-identical** to
//! the serial one (property-tested) because chunks are contiguous candidate ranges merged
//! in order and the reverse table is filled per disjoint row range in candidate order —
//! exactly the order the serial counting sort produces.

use super::{DecoderConfig, Pursuit};
use crate::hash::{hash_u64, IdIndex};
use crate::matrix::ColumnOracle;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Which side of the protocol this decoder runs on. The canonical residue orientation is
/// `r = M(1_{B\A} − 1_{B̂\A}) − M(1_{A\B} − 1_{Â\B})` (Fact 12): Bob's signal appears with a
/// `+` sign and Alice's with a `−` sign, so Alice decodes the negated residue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// Decodes coordinates of the positively-signed component (Bob in the paper).
    Positive,
    /// Decodes coordinates of the negatively-signed component (Alice).
    Negative,
}

impl Side {
    #[inline]
    fn sign(self) -> i32 {
        match self {
            Side::Positive => 1,
            Side::Negative => -1,
        }
    }
}

/// Outcome of one `run` call.
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeStats {
    pub iterations: usize,
    pub sets: usize,
    pub unsets: usize,
    /// Residue (restricted to this decoder's view) reached exactly zero.
    pub converged: bool,
    /// No positive-gain move remained but the residue is nonzero.
    pub stalled: bool,
}

/// CSR over `u32` indices.
struct Csr {
    offsets: Vec<u32>,
    items: Vec<u32>,
}

impl Csr {
    #[inline]
    fn row(&self, i: usize) -> &[u32] {
        &self.items[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

/// Below this candidate count, construction always runs serially: the work is too small
/// to amortize thread spawn + merge overhead.
const PAR_BUILD_MIN_CANDIDATES: usize = 2048;

/// Resolve [`DecoderConfig::build_threads`] into a worker count for this build.
fn resolve_build_threads(requested: usize, n: usize) -> usize {
    if n < PAR_BUILD_MIN_CANDIDATES {
        return 1;
    }
    let t = if requested == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        requested
    };
    t.clamp(1, 64)
}

/// Serial column CSR: sample every candidate's column in order.
fn build_columns_serial<C: ColumnOracle>(oracle: &C, candidates: &[u64]) -> (Vec<u32>, Vec<u32>) {
    let m = oracle.m() as usize;
    let n = candidates.len();
    let mut buf = vec![0u32; m.max(1)];
    let mut col_offsets = Vec::with_capacity(n + 1);
    let mut col_items = Vec::with_capacity(n * m);
    col_offsets.push(0u32);
    for &id in candidates {
        for &r in oracle.column_into(id, &mut buf) {
            col_items.push(r);
        }
        col_offsets.push(col_items.len() as u32);
    }
    (col_offsets, col_items)
}

/// One worker's output for a contiguous candidate range: per-column lengths plus the
/// concatenated row indices, in candidate order.
#[derive(Clone)]
struct ColumnChunk {
    lens: Vec<u32>,
    items: Vec<u32>,
}

/// Parallel column CSR: a bounded pool of `threads` workers races on an atomic chunk
/// counter (the same pattern as `setx/parallel.rs`); every chunk is a contiguous
/// candidate range, so concatenating chunk outputs in chunk order reproduces the serial
/// layout exactly.
fn build_columns_parallel<C: ColumnOracle + Sync>(
    oracle: &C,
    candidates: &[u64],
    threads: usize,
) -> (Vec<u32>, Vec<u32>) {
    let n = candidates.len();
    let m = oracle.m() as usize;
    // Oversplit for load balance (column sampling cost is uniform, but the OS isn't).
    let chunk_len = n.div_ceil((threads * 8).min(n));
    let num_chunks = n.div_ceil(chunk_len);
    let next = AtomicUsize::new(0);
    let out: Mutex<Vec<Option<ColumnChunk>>> = Mutex::new(vec![None; num_chunks]);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut buf = vec![0u32; m.max(1)];
                loop {
                    let c = next.fetch_add(1, Ordering::Relaxed);
                    if c >= num_chunks {
                        break;
                    }
                    let lo = c * chunk_len;
                    let hi = ((c + 1) * chunk_len).min(n);
                    let mut lens = Vec::with_capacity(hi - lo);
                    let mut items = Vec::with_capacity((hi - lo) * m);
                    for &id in &candidates[lo..hi] {
                        let rows = oracle.column_into(id, &mut buf);
                        items.extend_from_slice(rows);
                        lens.push(rows.len() as u32);
                    }
                    out.lock().expect("column chunk slot")[c] = Some(ColumnChunk { lens, items });
                }
            });
        }
    });
    // In-order merge (the cheap, serial part): prefix-sum the lengths, memcpy the items.
    let mut col_offsets = Vec::with_capacity(n + 1);
    let mut col_items = Vec::with_capacity(n * m);
    col_offsets.push(0u32);
    let mut total = 0u32;
    for slot in out.into_inner().expect("column chunk slots") {
        let chunk = slot.expect("every chunk index was claimed by a worker");
        for len in chunk.lens {
            total += len;
            col_offsets.push(total);
        }
        col_items.extend_from_slice(&chunk.items);
    }
    (col_offsets, col_items)
}

/// Row-load histogram prefix-summed into reverse-CSR offsets (`len l + 1`).
fn rev_offsets_from_columns(l: u32, col_items: &[u32]) -> Vec<u32> {
    let mut row_load = vec![0u32; l as usize + 1];
    for &r in col_items {
        row_load[r as usize + 1] += 1;
    }
    for i in 1..row_load.len() {
        row_load[i] += row_load[i - 1];
    }
    row_load
}

/// Serial reverse CSR via counting sort (row → candidate indices, ascending).
fn build_rev_serial(l: u32, col_offsets: &[u32], col_items: &[u32]) -> (Vec<u32>, Vec<u32>) {
    let rev_offsets = rev_offsets_from_columns(l, col_items);
    let mut cursor = rev_offsets.clone();
    let mut rev_items = vec![0u32; col_items.len()];
    let n = col_offsets.len() - 1;
    for j in 0..n {
        let start = col_offsets[j] as usize;
        let end = col_offsets[j + 1] as usize;
        for &r in &col_items[start..end] {
            rev_items[cursor[r as usize] as usize] = j as u32;
            cursor[r as usize] += 1;
        }
    }
    (rev_offsets, rev_items)
}

/// Parallel reverse CSR: the row space is cut into `threads` contiguous ranges of
/// roughly equal load; each worker owns the disjoint `rev_items` slice covering its rows
/// and scans the column CSR in candidate order, so per-row candidate lists come out in
/// exactly the ascending-candidate order of the serial counting sort. Workers re-read the
/// whole column CSR (an O(threads·nnz) sequential read), which is far cheaper than the
/// scattered writes it lets them split.
fn build_rev_parallel(
    l: u32,
    col_offsets: &[u32],
    col_items: &[u32],
    threads: usize,
) -> (Vec<u32>, Vec<u32>) {
    let lus = l as usize;
    let rev_offsets = rev_offsets_from_columns(l, col_items);
    let total = col_items.len();
    let mut rev_items = vec![0u32; total];
    // Balanced cut points over rows: the k-th cut is the first row whose offset prefix
    // reaches k/threads of the total load (clamped monotone so ranges stay well-formed).
    let mut cuts = Vec::with_capacity(threads + 1);
    cuts.push(0usize);
    for k in 1..threads {
        let target = (total as u64 * k as u64 / threads as u64) as u32;
        let row = rev_offsets.partition_point(|&o| o < target);
        let prev = *cuts.last().expect("cuts is seeded with 0");
        cuts.push(row.clamp(prev, lus));
    }
    cuts.push(lus);
    std::thread::scope(|scope| {
        let mut rest: &mut [u32] = &mut rev_items;
        let mut consumed = 0usize;
        for w in 0..threads {
            let (r0, r1) = (cuts[w], cuts[w + 1]);
            let base = rev_offsets[r0] as usize;
            let end = rev_offsets[r1] as usize;
            debug_assert_eq!(base, consumed);
            let (mine, tail) = rest.split_at_mut(end - consumed);
            rest = tail;
            consumed = end;
            let rev_offsets = &rev_offsets;
            scope.spawn(move || {
                if r0 == r1 || mine.is_empty() {
                    return;
                }
                // Cursors rebased to this worker's slice.
                let mut cursor: Vec<u32> =
                    rev_offsets[r0..r1].iter().map(|&o| o - base as u32).collect();
                let n = col_offsets.len() - 1;
                for j in 0..n {
                    let start = col_offsets[j] as usize;
                    let stop = col_offsets[j + 1] as usize;
                    for &r in &col_items[start..stop] {
                        let r = r as usize;
                        if r >= r0 && r < r1 {
                            let c = &mut cursor[r - r0];
                            mine[*c as usize] = j as u32;
                            *c += 1;
                        }
                    }
                }
            });
        }
    });
    (rev_offsets, rev_items)
}

#[derive(PartialEq, Eq)]
struct HeapEntry {
    gain: i32,
    j: u32,
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.gain.cmp(&other.gain).then(other.j.cmp(&self.j))
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The matching-pursuit decoder over a fixed candidate set.
///
/// Construction caches every candidate's column (CSR), builds the row→candidates reverse
/// lookup table of Appendix B, and indexes candidate ids in an open-addressing table
/// ([`IdIndex`]) so per-id operations (`force`, `set_banned_ids`, §5.2 collision
/// resolution) are O(1); afterwards the decoder never consults the matrix again, and each
/// pursuit costs `O(m · avg_row_load · log n)` as analyzed in Theorem 14. Construction
/// itself is parallelized per [`DecoderConfig::build_threads`].
pub struct MpDecoder {
    /// Number of rows `l`.
    l: u32,
    /// Column degree `m` of the matrix this decoder was built against (kept for the
    /// exact-dimension check of the reuse cache).
    m: u32,
    /// Candidate ids (signal coordinates this side may decode; Theorem 9 restricts to its own set).
    ids: Vec<u64>,
    /// id → candidate slot (O(1) lookups for `force` & friends).
    index: IdIndex,
    /// Reuse-cache discriminator: hash of (matrix fingerprint, candidates, side).
    key: u64,
    /// The oracle's [`ColumnOracle::structure_fingerprint`] at build time — together
    /// with `(l, m)` this is the exact-geometry key a shared decoder pool files this
    /// decoder under (see [`crate::decoder::GeometryKey`]).
    matrix_fp: u64,
    /// Candidate columns, CSR (j → rows).
    cols: Csr,
    /// Reverse lookup, CSR (row → candidate indices).
    rev: Csr,
    /// Current signal estimate bit per candidate.
    x: Vec<bool>,
    /// Current dot products `rᵀ m_j` in *own* orientation.
    dot: Vec<i32>,
    /// SMF-gated candidates (collision avoidance, §5.2): never auto-pursued.
    banned: Vec<bool>,
    /// Residue in own orientation (`sign · canonical`).
    res: Vec<i32>,
    l2_sq: i64,
    side: Side,
    config: DecoderConfig,
    heap: BinaryHeap<HeapEntry>,
    estimate_count: usize,
    /// Epoch-stamped visited marks for sparse candidate enumeration (avoids O(n) clears).
    seen: Vec<u32>,
    epoch: u32,
    /// Reusable (candidate, dot-before) buffer for `flip`.
    scratch: Vec<(u32, i32)>,
}

impl MpDecoder {
    /// Build a decoder for `candidates` (deduplicated ids) against matrix `oracle` with
    /// the default config (auto-parallel construction).
    pub fn new<C: ColumnOracle + Sync>(oracle: &C, candidates: &[u64], side: Side) -> Self {
        Self::with_config(oracle, candidates, side, DecoderConfig::default())
    }

    /// Build with an explicit config. [`DecoderConfig::build_threads`] governs the
    /// construction pool (it has no effect when set later via [`Self::set_config`]); the
    /// parallel build is bit-identical to the serial one — see [`Self::structure_digest`]
    /// and the property tests.
    pub fn with_config<C: ColumnOracle + Sync>(
        oracle: &C,
        candidates: &[u64],
        side: Side,
        config: DecoderConfig,
    ) -> Self {
        let l = oracle.l();
        let n = candidates.len();
        let threads = resolve_build_threads(config.build_threads, n);
        let (col_offsets, col_items) = if threads > 1 {
            build_columns_parallel(oracle, candidates, threads)
        } else {
            build_columns_serial(oracle, candidates)
        };
        let (rev_offsets, rev_items) = if threads > 1 {
            build_rev_parallel(l, &col_offsets, &col_items, threads)
        } else {
            build_rev_serial(l, &col_offsets, &col_items)
        };
        let index = IdIndex::build(candidates);
        let key = Self::cache_key_for(oracle, candidates, side);

        MpDecoder {
            l,
            m: oracle.m(),
            ids: candidates.to_vec(),
            index,
            key,
            matrix_fp: oracle.structure_fingerprint(),
            cols: Csr { offsets: col_offsets, items: col_items },
            rev: Csr { offsets: rev_offsets, items: rev_items },
            x: vec![false; n],
            dot: vec![0; n],
            banned: vec![false; n],
            res: vec![0; l as usize],
            l2_sq: 0,
            side,
            config,
            heap: BinaryHeap::new(),
            estimate_count: 0,
            seen: vec![0; n],
            epoch: 0,
            scratch: Vec::new(),
        }
    }

    /// The reuse-cache key a decoder built from these inputs will carry — equal keys mean
    /// a cached decoder is interchangeable with a fresh build (same matrix, same
    /// candidate sequence, same side).
    pub fn cache_key_for<C: ColumnOracle + ?Sized>(
        oracle: &C,
        candidates: &[u64],
        side: Side,
    ) -> u64 {
        let mut h = oracle.structure_fingerprint();
        h = hash_u64(h ^ candidates.len() as u64, 0xdec0_de00);
        for &id in candidates {
            h = hash_u64(h ^ id, 0xdec0_de01);
        }
        let side_tag = match side {
            Side::Positive => 1,
            Side::Negative => 2,
        };
        hash_u64(h ^ side_tag, 0xdec0_de02)
    }

    /// This decoder's reuse-cache key (see [`Self::cache_key_for`]).
    pub fn cache_key(&self) -> u64 {
        self.key
    }

    /// Dimensions `(l, m)` of the matrix this decoder was built against. The reuse cache
    /// checks these for **exact equality** alongside the 64-bit key: with the dimensions
    /// pinned, the seed → matrix-fingerprint chain is a composition of bijections, so an
    /// adversarial `Hello` cannot forge a colliding key with different geometry (a plain
    /// invertible-mixer hash alone would be forgeable).
    pub fn matrix_dims(&self) -> (u32, u32) {
        (self.l, self.m)
    }

    /// The build-time matrix structure fingerprint (the geometry half of the reuse keys;
    /// for the production [`crate::matrix::CsMatrix`] it is a pure function of
    /// `(seed, l, m)`).
    pub fn matrix_fingerprint(&self) -> u64 {
        self.matrix_fp
    }

    /// Order-sensitive digest of the constructed CSR structures (column cache + reverse
    /// lookup). Two decoders with equal digests hold byte-identical tables — the
    /// observable behind the parallel-equals-serial construction property tests.
    pub fn structure_digest(&self) -> u64 {
        let mut h = 0x0c5a_d165u64;
        for part in [&self.cols.offsets, &self.cols.items, &self.rev.offsets, &self.rev.items] {
            h = hash_u64(h ^ part.len() as u64, 0xdec0_de10);
            for &v in part.iter() {
                h = hash_u64(h ^ v as u64, 0xdec0_de11);
            }
        }
        h
    }

    /// Update the pursuit config. `build_threads` is construction-time only and ignored
    /// here.
    pub fn set_config(&mut self, config: DecoderConfig) {
        self.config = config;
    }

    pub fn num_candidates(&self) -> usize {
        self.ids.len()
    }

    pub fn candidate_ids(&self) -> &[u64] {
        &self.ids
    }

    /// Mark candidates banned from automatic pursuit (SMF collision avoidance). The predicate
    /// sees candidate ids. Passing `|_| false` clears all bans. O(n) by nature (the
    /// predicate must be consulted for every candidate — e.g. a Bloom-filter membership
    /// test); for an explicit id list use [`Self::set_banned_ids`], which is O(1) per id.
    pub fn set_banned(&mut self, test: impl Fn(u64) -> bool) {
        for (j, &id) in self.ids.iter().enumerate() {
            self.banned[j] = test(id);
        }
        // Newly-banned candidates die lazily at pop time (their stored gain no longer
        // matches); newly-unbanned ones must be (re)enqueued.
        self.rebuild_heap();
    }

    /// Ban (or unban) exactly the listed ids, leaving every other candidate's ban state
    /// untouched. O(1) per id: newly-banned entries die lazily in the queue at pop time,
    /// newly-unbanned ones are re-enqueued if currently profitable — no full heap
    /// rebuild. Ids outside the candidate set are ignored. Returns how many candidates
    /// changed state.
    pub fn set_banned_ids(&mut self, ids: &[u64], banned: bool) -> usize {
        let mut changed = 0usize;
        for &id in ids {
            let Some(j) = self.candidate_index(id) else { continue };
            if self.banned[j] == banned {
                continue;
            }
            self.banned[j] = banned;
            changed += 1;
            if !banned {
                let g = self.gain(j);
                if g > 0 {
                    self.heap.push(HeapEntry { gain: g, j: j as u32 });
                }
            }
        }
        changed
    }

    /// Load a residue given in *canonical* orientation; recomputes dots and rebuilds the
    /// queue (the per-round `O(|B| log |B|)` repopulation of Appendix B).
    pub fn load_residue(&mut self, canonical: &[i32]) {
        assert_eq!(canonical.len(), self.l as usize);
        let s = self.side.sign();
        self.l2_sq = 0;
        for (dst, &v) in self.res.iter_mut().zip(canonical) {
            *dst = s * v;
            self.l2_sq += (*dst as i64) * (*dst as i64);
        }
        // Sparsity-aware dot refresh (§Perf-L3): late ping-pong rounds carry near-empty
        // residues, so accumulating through the reverse table over nonzero rows only makes
        // reloads near-free. Dense initial residues (support ≳ l/8) keep the cache-friendly
        // forward scan — the hybrid beat either pure strategy in the bench log.
        let support = self.res.iter().filter(|&&v| v != 0).count();
        if support * 8 >= self.res.len() {
            for j in 0..self.ids.len() {
                let mut d = 0i32;
                for &r in self.cols.row(j) {
                    d += self.res[r as usize];
                }
                self.dot[j] = d;
            }
            self.rebuild_heap();
            return;
        }
        self.dot.iter_mut().for_each(|d| *d = 0);
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.seen.iter_mut().for_each(|e| *e = 0);
            self.epoch = 1;
        }
        self.heap.clear();
        let mut touched: Vec<u32> = Vec::new();
        for r in 0..self.res.len() {
            let v = self.res[r];
            if v == 0 {
                continue;
            }
            for &j in self.rev.row(r) {
                self.dot[j as usize] += v;
                if self.seen[j as usize] != self.epoch {
                    self.seen[j as usize] = self.epoch;
                    touched.push(j);
                }
            }
        }
        let mut entries: Vec<HeapEntry> = Vec::with_capacity(touched.len());
        for &j in &touched {
            let g = self.gain(j as usize);
            if g > 0 {
                entries.push(HeapEntry { gain: g, j });
            }
        }
        // Set coordinates whose rows all went quiet still need gain re-evaluation after
        // reverts; the x-scan is a cheap O(n) bool pass.
        for j in 0..self.ids.len() {
            if self.x[j] && self.seen[j] != self.epoch {
                let g = self.gain(j);
                if g > 0 {
                    entries.push(HeapEntry { gain: g, j: j as u32 });
                }
            }
        }
        self.heap = BinaryHeap::from(entries);
    }

    /// Export the current residue in canonical orientation.
    pub fn export_residue(&self) -> Vec<i32> {
        let s = self.side.sign();
        self.res.iter().map(|&v| s * v).collect()
    }

    #[inline]
    pub fn residue_is_zero(&self) -> bool {
        self.l2_sq == 0
    }

    pub fn residue_l2_sq(&self) -> i64 {
        self.l2_sq
    }

    /// Length of the residue vector (= l).
    pub fn residue_len(&self) -> usize {
        self.res.len()
    }

    pub fn residue_l1(&self) -> i64 {
        self.res.iter().map(|&v| v.unsigned_abs() as i64).sum()
    }

    /// Current estimate set (ids with x = 1).
    pub fn estimate(&self) -> Vec<u64> {
        self.ids
            .iter()
            .zip(&self.x)
            .filter(|(_, &on)| on)
            .map(|(&id, _)| id)
            .collect()
    }

    pub fn estimate_len(&self) -> usize {
        self.estimate_count
    }

    #[inline]
    pub fn is_set_idx(&self, j: usize) -> bool {
        self.x[j]
    }

    /// Gain of the (unique) legal move on candidate `j` under the configured pursuit norm:
    /// the decrease of the residue norm if we flip `x_j`. Non-positive means "don't".
    #[inline]
    fn gain(&self, j: usize) -> i32 {
        if self.banned[j] && !self.x[j] {
            // SMF collision avoidance (§5.2) gates only *setting*; corrective unsets of an
            // already-set coordinate must stay possible.
            return i32::MIN;
        }
        self.gain_ungated(j)
    }

    /// `gain` evaluated against the decoder's current fields (used by `flip` with a
    /// temporarily restored dot to obtain the pre-update gain).
    #[inline]
    fn gain_snapshot(&self, j: usize) -> i32 {
        self.gain(j)
    }

    /// Gain ignoring the SMF gate (used by collision resolution to find tentative updates).
    #[inline]
    fn gain_ungated(&self, j: usize) -> i32 {
        let mj = (self.cols.offsets[j + 1] - self.cols.offsets[j]) as i32;
        if !self.x[j] {
            // Setting x_j: r ← r − m_j. Modification 9 rule 2 (δ > 1/2 ⟺ 2·dot > m).
            match self.config.pursuit {
                Pursuit::L2 => 2 * self.dot[j] - mj,
                Pursuit::L1 => self
                    .cols
                    .row(j)
                    .iter()
                    .map(|&r| if self.res[r as usize] >= 1 { 1 } else { -1 })
                    .sum(),
            }
        } else {
            // Unsetting x_j: r ← r + m_j. Modification 9 rule 1 (δ < −1/2).
            if !self.config.allow_unset {
                return i32::MIN;
            }
            match self.config.pursuit {
                Pursuit::L2 => -2 * self.dot[j] - mj,
                Pursuit::L1 => self
                    .cols
                    .row(j)
                    .iter()
                    .map(|&r| if self.res[r as usize] <= -1 { 1 } else { -1 })
                    .sum(),
            }
        }
    }

    fn rebuild_heap(&mut self) {
        self.heap.clear();
        let mut entries = Vec::new();
        for j in 0..self.ids.len() {
            let g = self.gain(j);
            if g > 0 {
                entries.push(HeapEntry { gain: g, j: j as u32 });
            }
        }
        self.heap = BinaryHeap::from(entries);
    }

    /// Flip candidate `j` (set if currently 0, unset if 1), updating residue, dots, norms,
    /// and the queue. This is the "update" stage of Procedure 1 under Modification 9.
    fn flip(&mut self, j: usize) {
        let setting = !self.x[j];
        let delta: i32 = if setting { -1 } else { 1 }; // residue change per touched row
        self.x[j] = setting;
        if setting {
            self.estimate_count += 1;
        } else {
            self.estimate_count -= 1;
        }

        let start = self.cols.offsets[j] as usize;
        let end = self.cols.offsets[j + 1] as usize;
        // First pass: update residue rows and dots, collecting each affected candidate
        // once (epoch stamps) together with its pre-update dot.
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.seen.iter_mut().for_each(|e| *e = 0);
            self.epoch = 1;
        }
        self.scratch.clear();
        for idx in start..end {
            let r = self.cols.items[idx] as usize;
            let old = self.res[r];
            let new = old + delta;
            self.res[r] = new;
            self.l2_sq += (new as i64) * (new as i64) - (old as i64) * (old as i64);
            // Reverse lookup: every candidate whose column touches row r sees its dot move.
            for &jj in self.rev.row(r) {
                let ju = jj as usize;
                if self.seen[ju] != self.epoch {
                    self.seen[ju] = self.epoch;
                    self.scratch.push((jj, self.dot[ju]));
                }
                self.dot[ju] += delta;
            }
        }
        // Second pass: re-enqueue only candidates whose gain *increased* (or turned
        // positive). Decreased gains die lazily: a stale higher-priority entry already
        // sits in the heap and is corrected at pop time, so skipping those pushes is
        // safe — and they are the overwhelming majority as the residue drains (§Perf-L3).
        let scratch = std::mem::take(&mut self.scratch);
        for &(jj, dot_before) in &scratch {
            let ju = jj as usize;
            let g = self.gain(ju);
            if g <= 0 {
                continue;
            }
            let increased = match self.config.pursuit {
                Pursuit::L2 => {
                    // g_old under the pre-update dot (x state of jj is unchanged by this
                    // flip unless jj == j, which run() re-pops anyway).
                    let saved = self.dot[ju];
                    self.dot[ju] = dot_before;
                    let g_old = self.gain_snapshot(ju);
                    self.dot[ju] = saved;
                    g > g_old
                }
                // L1 gains are not linear in the dot; push conservatively.
                Pursuit::L1 => true,
            };
            if increased {
                self.heap.push(HeapEntry { gain: g, j: jj });
            }
        }
        self.scratch = scratch;
        // Bound heap growth (lazy deletion can balloon under adversarial churn).
        if self.heap.len() > 64 + 16 * self.ids.len() {
            self.rebuild_heap();
        }
    }

    /// Slot index of candidate `id`, if it is in this decoder's candidate set. O(1)
    /// expected (open-addressing lookup).
    #[inline]
    pub fn candidate_index(&self, id: u64) -> Option<usize> {
        self.index.get(id).map(|j| j as usize)
    }

    /// [`Self::candidate_index`] plus the number of hash-table slots probed — lets tests
    /// assert the O(1)-per-id property deterministically instead of timing it.
    pub fn candidate_index_probed(&self, id: u64) -> (Option<usize>, usize) {
        let (hit, probes) = self.index.get_probed(id);
        (hit.map(|j| j as usize), probes)
    }

    /// Force-set or force-unset a candidate regardless of gain or ban (used by the
    /// collision-resolution step of §5.2 and by tests). No-op if already in that state.
    /// O(1) lookup + O(m · avg_row_load) flip — it no longer scans the candidate vector,
    /// so resolving k collisions costs O(k), not O(n·k).
    pub fn force(&mut self, id: u64, set: bool) -> bool {
        if let Some(j) = self.candidate_index(id) {
            if self.x[j] != set {
                self.flip(j);
                return true;
            }
        }
        false
    }

    /// Run the pursuit loop until the residue is zero, no positive-gain move remains, or the
    /// iteration cap is hit.
    pub fn run(&mut self) -> DecodeStats {
        let mut stats = DecodeStats::default();
        let cap = if self.config.max_iters == 0 {
            8 * self.ids.len() + 64
        } else {
            self.config.max_iters
        };
        while stats.iterations < cap {
            if self.l2_sq == 0 {
                stats.converged = true;
                return stats;
            }
            let Some(top) = self.heap.pop() else {
                stats.stalled = true;
                return stats;
            };
            let j = top.j as usize;
            let g = self.gain(j);
            if g != top.gain {
                // Stale entry: re-enqueue the fresh gain if still profitable.
                if g > 0 {
                    self.heap.push(HeapEntry { gain: g, j: top.j });
                }
                continue;
            }
            if g <= 0 {
                continue;
            }
            self.flip(j);
            stats.iterations += 1;
            if self.x[j] {
                stats.sets += 1;
            } else {
                stats.unsets += 1;
            }
        }
        stats.converged = self.l2_sq == 0;
        stats.stalled = !stats.converged;
        stats
    }

    /// Switch pursuit norm mid-decode (the Appendix C.2 fallback flips to L1 pursuit when the
    /// L2 loop stalls on ECC-damaged residues). Rebuilds the queue.
    pub fn switch_pursuit(&mut self, pursuit: Pursuit) {
        self.config.pursuit = pursuit;
        self.rebuild_heap();
    }

    /// Clear all per-decode state — signal estimate (x := 0), SMF bans, and the queue —
    /// without touching the constructed CSR structures. Callers then `load_residue`
    /// (which recomputes residue, dots, and the queue) to start a fresh decode on the
    /// same candidate set; the result is bit-identical to a freshly built decoder
    /// (property-tested). This is the reuse primitive behind [`super::DecoderCache`]:
    /// construction (CSR + reverse lookup) is the expensive part, resetting is O(n).
    pub fn reset_signal(&mut self) {
        self.x.iter_mut().for_each(|b| *b = false);
        self.banned.iter_mut().for_each(|b| *b = false);
        self.estimate_count = 0;
        self.heap.clear();
    }

    /// Escape hatch for pairwise local minima: when two candidates' columns overlap in
    /// m-2 rows, swapping them is invisible to single-move greedy pursuit (both moves have
    /// gain -1). Kicking out the set coordinate with the most negative dot lets the next
    /// `run` complete the swap (the true coordinate then has the top gain). Returns the
    /// kicked id, or None if no set coordinate has negative evidence.
    pub fn kick_worst(&mut self) -> Option<u64> {
        let mut worst: Option<(i32, usize)> = None;
        for j in 0..self.ids.len() {
            if self.x[j] && self.dot[j] < 0 && worst.map_or(true, |(d, _)| self.dot[j] < d) {
                worst = Some((self.dot[j], j));
            }
        }
        let (_, j) = worst?;
        self.flip(j);
        Some(self.ids[j])
    }

    /// Banned (SMF-positive) candidates that currently *want* pursuit — i.e. would be set
    /// were they not gated. These are exactly the coordinates the §5.2 collision-resolution
    /// step tentatively updates and verifies via the "last inquiry".
    pub fn banned_positive_gain(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for j in 0..self.ids.len() {
            if self.banned[j] && !self.x[j] && self.gain_ungated(j) > 0 {
                out.push(self.ids[j]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::CsMatrix;
    use crate::sketch::Sketch;

    /// Plant B\A of size d among n candidates; check exact recovery (unidirectional core).
    fn planted_recovery(n: u64, d: usize, l: u32, m: u32, seed: u64) -> bool {
        let mat = CsMatrix::new(l, m, seed);
        let candidates: Vec<u64> = (0..n).map(|i| i.wrapping_mul(0x9e3779b97f4a7c15) ^ seed).collect();
        let planted: Vec<u64> = candidates.iter().step_by((n as usize / d).max(1)).copied().take(d).collect();
        let measurement = Sketch::encode(mat, &planted);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.set_config(DecoderConfig::commonsense());
        let canonical: Vec<i32> = measurement.counts.clone();
        dec.load_residue(&canonical);
        let stats = dec.run();
        if !stats.converged {
            return false;
        }
        let mut got = dec.estimate();
        got.sort_unstable();
        let mut want = planted;
        want.sort_unstable();
        got == want
    }

    #[test]
    fn recovers_planted_signal_l2() {
        for seed in 0..5 {
            assert!(planted_recovery(20_000, 100, 1600, 7, seed), "seed {seed}");
        }
    }

    #[test]
    fn recovers_larger_d() {
        assert!(planted_recovery(50_000, 1000, 12_000, 7, 3));
    }

    #[test]
    fn ssmp_also_recovers() {
        let mat = CsMatrix::new(1600, 7, 11);
        let candidates: Vec<u64> = (0..20_000u64).collect();
        let planted: Vec<u64> = (0..100u64).map(|i| i * 199).collect();
        let measurement = Sketch::encode(mat, &planted);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.set_config(DecoderConfig::ssmp());
        dec.load_residue(&measurement.counts);
        let stats = dec.run();
        assert!(stats.converged);
        let mut got = dec.estimate();
        got.sort_unstable();
        let mut want = planted;
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn negative_side_decodes_negated_signal() {
        // Canonical residue −M·1_S: Alice (Side::Negative) must recover S.
        let mat = CsMatrix::new(1000, 5, 21);
        let candidates: Vec<u64> = (0..10_000u64).collect();
        let planted: Vec<u64> = (0..60u64).map(|i| i * 151 + 3).collect();
        let sk = Sketch::encode(mat, &planted);
        let canonical: Vec<i32> = sk.counts.iter().map(|&c| -c).collect();
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Negative);
        dec.load_residue(&canonical);
        let stats = dec.run();
        assert!(stats.converged);
        assert_eq!(dec.estimate().len(), 60);
        // Exported residue must be canonical-zero.
        assert!(dec.export_residue().iter().all(|&v| v == 0));
    }

    #[test]
    fn banned_candidates_are_skipped_until_unbanned() {
        let mat = CsMatrix::new(400, 5, 31);
        let candidates: Vec<u64> = (0..5_000u64).collect();
        let planted: Vec<u64> = vec![10, 20, 30, 40];
        let sk = Sketch::encode(mat, &planted);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.load_residue(&sk.counts);
        dec.set_banned(|id| id == 10);
        dec.run();
        assert!(!dec.estimate().contains(&10));
        // Unban and the decoder finishes the job.
        dec.set_banned(|_| false);
        dec.load_residue(&dec.export_residue());
        let stats = dec.run();
        assert!(stats.converged);
        let mut got = dec.estimate();
        got.sort_unstable();
        assert_eq!(got, planted);
    }

    #[test]
    fn force_roundtrip_restores_residue() {
        let mat = CsMatrix::new(300, 5, 41);
        let candidates: Vec<u64> = (0..1000u64).collect();
        let sk = Sketch::encode(mat, &[7, 8]);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.load_residue(&sk.counts);
        let before = dec.residue_l2_sq();
        assert!(dec.force(500, true));
        assert!(dec.residue_l2_sq() != before);
        assert!(dec.force(500, false));
        assert_eq!(dec.residue_l2_sq(), before);
        assert!(!dec.force(500, false)); // already unset → no-op
    }

    #[test]
    fn parallel_build_is_bit_identical_to_serial_random_shapes() {
        // Property: for random (l, m, n, threads) the parallel construction produces the
        // exact CSR bytes of the serial one (chunk-ordered merge + per-row-range fill
        // preserve the counting-sort order by design).
        use crate::hash::Xoshiro256;
        let mut rng = Xoshiro256::seed_from_u64(0xc5_1d);
        for case in 0..10 {
            let l = 64 + rng.gen_range(4000) as u32;
            let m = 1 + rng.gen_range(8) as u32; // ≤ 8 ≤ l
            let n = 1 + rng.gen_range(30_000) as usize;
            let threads = 2 + rng.gen_range(7) as usize; // 2..=8
            let seed = rng.next_u64();
            let mat = CsMatrix::new(l, m, seed);
            let candidates: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let serial = MpDecoder::with_config(
                &mat,
                &candidates,
                Side::Positive,
                DecoderConfig { build_threads: 1, ..DecoderConfig::default() },
            );
            let parallel = MpDecoder::with_config(
                &mat,
                &candidates,
                Side::Positive,
                DecoderConfig { build_threads: threads, ..DecoderConfig::default() },
            );
            assert_eq!(
                serial.structure_digest(),
                parallel.structure_digest(),
                "case {case}: l={l} m={m} n={n} threads={threads} seed={seed:#x}"
            );
            assert_eq!(serial.cache_key(), parallel.cache_key());
        }
        // One deliberately large case well past the serial-build cutoff.
        let mat = CsMatrix::new(6000, 7, 0xfeed);
        let candidates: Vec<u64> = (0..50_000u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)).collect();
        let serial = MpDecoder::with_config(
            &mat,
            &candidates,
            Side::Positive,
            DecoderConfig { build_threads: 1, ..DecoderConfig::default() },
        );
        let parallel = MpDecoder::with_config(
            &mat,
            &candidates,
            Side::Positive,
            DecoderConfig { build_threads: 4, ..DecoderConfig::default() },
        );
        assert_eq!(serial.structure_digest(), parallel.structure_digest());
    }

    #[test]
    fn reset_signal_reuse_decodes_identically_to_fresh() {
        // Property: decode residue A, reset, decode residue B — the second decode must be
        // decision-for-decision identical to a brand-new decoder decoding B.
        for seed in 0..5u64 {
            let mat = CsMatrix::new(1600, 7, seed);
            let candidates: Vec<u64> = (0..15_000u64).map(|i| i * 31 + seed).collect();
            let planted_a: Vec<u64> = candidates.iter().copied().step_by(151).take(80).collect();
            let planted_b: Vec<u64> = candidates.iter().copied().skip(7).step_by(173).take(90).collect();
            let res_a = Sketch::encode(mat, &planted_a).counts;
            let res_b = Sketch::encode(mat, &planted_b).counts;

            let mut reused = MpDecoder::new(&mat, &candidates, Side::Positive);
            reused.set_config(DecoderConfig::commonsense());
            reused.load_residue(&res_a);
            // Leave mid-decode debris behind on purpose: bans + a partial run.
            reused.set_banned(|id| id % 5 == 0);
            reused.run();
            reused.reset_signal();
            reused.load_residue(&res_b);
            let stats_reused = reused.run();

            let mut fresh = MpDecoder::new(&mat, &candidates, Side::Positive);
            fresh.set_config(DecoderConfig::commonsense());
            fresh.load_residue(&res_b);
            let stats_fresh = fresh.run();

            assert_eq!(stats_reused.converged, stats_fresh.converged, "seed {seed}");
            assert_eq!(stats_reused.iterations, stats_fresh.iterations, "seed {seed}");
            assert_eq!(stats_reused.sets, stats_fresh.sets, "seed {seed}");
            assert_eq!(stats_reused.unsets, stats_fresh.unsets, "seed {seed}");
            let (mut got_r, mut got_f) = (reused.estimate(), fresh.estimate());
            got_r.sort_unstable();
            got_f.sort_unstable();
            assert_eq!(got_r, got_f, "seed {seed}");
            assert_eq!(reused.export_residue(), fresh.export_residue(), "seed {seed}");
        }
    }

    #[test]
    fn decoder_cache_reuses_on_match_and_rebuilds_on_mismatch() {
        use super::super::DecoderCache;
        let mat = CsMatrix::new(1200, 5, 9);
        let candidates: Vec<u64> = (0..10_000u64).collect();
        let planted: Vec<u64> = (0..40u64).map(|i| i * 211 + 5).collect();
        let residue = Sketch::encode(mat, &planted).counts;

        let mut cache = DecoderCache::new();
        let mut first = cache.checkout(&mat, &candidates, Side::Positive, DecoderConfig::commonsense());
        let key = first.cache_key();
        first.load_residue(&residue);
        assert!(first.run().converged);
        cache.store(first);
        assert!(cache.is_loaded());

        // Hit: same (matrix, candidates, side) → same construction, clean slate.
        let mut again = cache.checkout(&mat, &candidates, Side::Positive, DecoderConfig::commonsense());
        assert_eq!(again.cache_key(), key);
        assert_eq!(again.estimate_len(), 0, "reused decoder must start clean");
        again.load_residue(&residue);
        assert!(again.run().converged);
        let mut got = again.estimate();
        got.sort_unstable();
        assert_eq!(got, planted);
        cache.store(again);

        // Miss: a redrawn matrix (the escalation ladder's seed perturbation) must rebuild.
        let other = CsMatrix::new(1200, 5, 10);
        let rebuilt = cache.checkout(&other, &candidates, Side::Positive, DecoderConfig::commonsense());
        assert_ne!(rebuilt.cache_key(), key);
    }

    #[test]
    fn force_lookup_is_constant_probe_on_100k_candidates() {
        // §5.2 regression: collision resolution does one `force` per inquiry/answer. The
        // id→index table must answer each lookup in O(1) expected probes — the old
        // `ids.iter().position(..)` scan averaged n/2 = 50_000 comparisons per call,
        // making a k-inquiry round O(n·k). Probe counts are deterministic, so this
        // asserts sub-linearity without wall-clock flakiness.
        let mat = CsMatrix::new(2048, 5, 77);
        let candidates: Vec<u64> =
            (0..100_000u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)).collect();
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        let mut total_probes = 0usize;
        for &id in &candidates {
            let (hit, probes) = dec.candidate_index_probed(id);
            assert!(hit.is_some());
            total_probes += probes;
        }
        assert!(
            total_probes < 4 * candidates.len(),
            "avg probes {:.2} — lookup degenerated toward a scan",
            total_probes as f64 / candidates.len() as f64
        );
        // Misses are O(1) too (ids from the same injective map, outside the built range).
        for i in 100_000..100_016u64 {
            let (hit, probes) = dec.candidate_index_probed(i.wrapping_mul(0x9e37_79b9_7f4a_7c15));
            assert!(hit.is_none());
            assert!(probes < 64, "miss probes {probes}");
        }
        // And force itself round-trips through the table.
        let sk = Sketch::encode(mat, &[candidates[17], candidates[93]]);
        dec.load_residue(&sk.counts);
        let before = dec.residue_l2_sq();
        assert!(dec.force(candidates[50_000], true));
        assert!(dec.force(candidates[50_000], false));
        assert_eq!(dec.residue_l2_sq(), before);
        assert!(!dec.force(0xdead_0000_0000_0001, true), "unknown id is a no-op");
    }

    #[test]
    fn set_banned_ids_is_incremental() {
        let mat = CsMatrix::new(400, 5, 31);
        let candidates: Vec<u64> = (0..5_000u64).collect();
        let planted: Vec<u64> = vec![10, 20, 30, 40];
        let sk = Sketch::encode(mat, &planted);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.load_residue(&sk.counts);
        // Ban two planted ids by list; the decoder must not set them.
        assert_eq!(dec.set_banned_ids(&[10, 30, 999_999], true), 2);
        dec.run();
        let est = dec.estimate();
        assert!(!est.contains(&10) && !est.contains(&30));
        // Unban by list re-enqueues them; the decode completes without a heap rebuild.
        assert_eq!(dec.set_banned_ids(&[10, 30], false), 2);
        dec.load_residue(&dec.export_residue());
        let stats = dec.run();
        assert!(stats.converged);
        let mut got = dec.estimate();
        got.sort_unstable();
        assert_eq!(got, planted);
        // Re-applying the same state is a no-op.
        assert_eq!(dec.set_banned_ids(&[10, 30], false), 0);
    }

    #[test]
    fn bmp_cannot_correct_its_own_errors_but_full_mp_can() {
        // Statistical statement: at a marginal l, full MP (with unsets) should succeed at
        // least as often as BMP, and strictly more over enough seeds.
        let mut bmp_ok = 0;
        let mut mp_ok = 0;
        for seed in 0..30u64 {
            let mat = CsMatrix::new(700, 5, seed);
            let candidates: Vec<u64> = (0..4_000u64).collect();
            let planted: Vec<u64> = (0..50u64).map(|i| i * 79 + seed).collect();
            let sk = Sketch::encode(mat, &planted);
            for bmp in [false, true] {
                let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
                dec.set_config(if bmp { DecoderConfig::bmp() } else { DecoderConfig::commonsense() });
                dec.load_residue(&sk.counts);
                let stats = dec.run();
                let mut got = dec.estimate();
                got.sort_unstable();
                let mut want = planted.clone();
                want.sort_unstable();
                if stats.converged && got == want {
                    if bmp {
                        bmp_ok += 1;
                    } else {
                        mp_ok += 1;
                    }
                }
            }
        }
        assert!(mp_ok >= bmp_ok, "mp {mp_ok} < bmp {bmp_ok}");
        assert!(mp_ok >= 25, "full MP too weak at this l: {mp_ok}/30");
    }
}
