//! Decoder internals: CSR column cache, reverse lookup, lazy priority queue, pursuit loop.

use super::{DecoderConfig, Pursuit};
use crate::matrix::ColumnOracle;
use std::collections::BinaryHeap;

/// Which side of the protocol this decoder runs on. The canonical residue orientation is
/// `r = M(1_{B\A} − 1_{B̂\A}) − M(1_{A\B} − 1_{Â\B})` (Fact 12): Bob's signal appears with a
/// `+` sign and Alice's with a `−` sign, so Alice decodes the negated residue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Side {
    /// Decodes coordinates of the positively-signed component (Bob in the paper).
    Positive,
    /// Decodes coordinates of the negatively-signed component (Alice).
    Negative,
}

impl Side {
    #[inline]
    fn sign(self) -> i32 {
        match self {
            Side::Positive => 1,
            Side::Negative => -1,
        }
    }
}

/// Outcome of one `run` call.
#[derive(Clone, Copy, Debug, Default)]
pub struct DecodeStats {
    pub iterations: usize,
    pub sets: usize,
    pub unsets: usize,
    /// Residue (restricted to this decoder's view) reached exactly zero.
    pub converged: bool,
    /// No positive-gain move remained but the residue is nonzero.
    pub stalled: bool,
}

/// CSR over `u32` indices.
struct Csr {
    offsets: Vec<u32>,
    items: Vec<u32>,
}

impl Csr {
    #[inline]
    fn row(&self, i: usize) -> &[u32] {
        &self.items[self.offsets[i] as usize..self.offsets[i + 1] as usize]
    }
}

#[derive(PartialEq, Eq)]
struct HeapEntry {
    gain: i32,
    j: u32,
}

impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.gain.cmp(&other.gain).then(other.j.cmp(&self.j))
    }
}

impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The matching-pursuit decoder over a fixed candidate set.
///
/// Construction caches every candidate's column (CSR) and builds the row→candidates reverse
/// lookup table of Appendix B; afterwards the decoder never consults the matrix again, and
/// each pursuit costs `O(m · avg_row_load · log n)` as analyzed in Theorem 14.
pub struct MpDecoder {
    /// Number of rows `l`.
    l: u32,
    /// Candidate ids (signal coordinates this side may decode; Theorem 9 restricts to its own set).
    ids: Vec<u64>,
    /// Candidate columns, CSR (j → rows).
    cols: Csr,
    /// Reverse lookup, CSR (row → candidate indices).
    rev: Csr,
    /// Current signal estimate bit per candidate.
    x: Vec<bool>,
    /// Current dot products `rᵀ m_j` in *own* orientation.
    dot: Vec<i32>,
    /// SMF-gated candidates (collision avoidance, §5.2): never auto-pursued.
    banned: Vec<bool>,
    /// Residue in own orientation (`sign · canonical`).
    res: Vec<i32>,
    l2_sq: i64,
    side: Side,
    config: DecoderConfig,
    heap: BinaryHeap<HeapEntry>,
    estimate_count: usize,
    /// Epoch-stamped visited marks for sparse candidate enumeration (avoids O(n) clears).
    seen: Vec<u32>,
    epoch: u32,
    /// Reusable (candidate, dot-before) buffer for `flip`.
    scratch: Vec<(u32, i32)>,
}

impl MpDecoder {
    /// Build a decoder for `candidates` (deduplicated ids) against matrix `oracle`.
    pub fn new<C: ColumnOracle>(oracle: &C, candidates: &[u64], side: Side) -> Self {
        let l = oracle.l();
        let m = oracle.m() as usize;
        let n = candidates.len();
        let mut buf = vec![0u32; m.max(1)];

        // Column CSR + row loads in one pass.
        let mut col_offsets = Vec::with_capacity(n + 1);
        let mut col_items = Vec::with_capacity(n * m);
        let mut row_load = vec![0u32; l as usize + 1];
        col_offsets.push(0u32);
        for &id in candidates {
            for &r in oracle.column_into(id, &mut buf) {
                col_items.push(r);
                row_load[r as usize + 1] += 1;
            }
            col_offsets.push(col_items.len() as u32);
        }

        // Reverse CSR via counting sort.
        for i in 1..row_load.len() {
            row_load[i] += row_load[i - 1];
        }
        let rev_offsets = row_load.clone();
        let mut cursor = row_load;
        let mut rev_items = vec![0u32; col_items.len()];
        for j in 0..n {
            let start = col_offsets[j] as usize;
            let end = col_offsets[j + 1] as usize;
            for &r in &col_items[start..end] {
                rev_items[cursor[r as usize] as usize] = j as u32;
                cursor[r as usize] += 1;
            }
        }

        MpDecoder {
            l,
            ids: candidates.to_vec(),
            cols: Csr { offsets: col_offsets, items: col_items },
            rev: Csr { offsets: rev_offsets, items: rev_items },
            x: vec![false; n],
            dot: vec![0; n],
            banned: vec![false; n],
            res: vec![0; l as usize],
            l2_sq: 0,
            side,
            config: DecoderConfig::default(),
            heap: BinaryHeap::new(),
            estimate_count: 0,
            seen: vec![0; n],
            epoch: 0,
            scratch: Vec::new(),
        }
    }

    pub fn set_config(&mut self, config: DecoderConfig) {
        self.config = config;
    }

    pub fn num_candidates(&self) -> usize {
        self.ids.len()
    }

    pub fn candidate_ids(&self) -> &[u64] {
        &self.ids
    }

    /// Mark candidates banned from automatic pursuit (SMF collision avoidance). The predicate
    /// sees candidate ids. Passing `|_| false` clears all bans.
    pub fn set_banned(&mut self, test: impl Fn(u64) -> bool) {
        for (j, &id) in self.ids.iter().enumerate() {
            self.banned[j] = test(id);
        }
        // Newly-banned candidates die lazily at pop time (their stored gain no longer
        // matches); newly-unbanned ones must be (re)enqueued.
        self.rebuild_heap();
    }

    /// Load a residue given in *canonical* orientation; recomputes dots and rebuilds the
    /// queue (the per-round `O(|B| log |B|)` repopulation of Appendix B).
    pub fn load_residue(&mut self, canonical: &[i32]) {
        assert_eq!(canonical.len(), self.l as usize);
        let s = self.side.sign();
        self.l2_sq = 0;
        for (dst, &v) in self.res.iter_mut().zip(canonical) {
            *dst = s * v;
            self.l2_sq += (*dst as i64) * (*dst as i64);
        }
        // Sparsity-aware dot refresh (§Perf-L3): late ping-pong rounds carry near-empty
        // residues, so accumulating through the reverse table over nonzero rows only makes
        // reloads near-free. Dense initial residues (support ≳ l/8) keep the cache-friendly
        // forward scan — the hybrid beat either pure strategy in the bench log.
        let support = self.res.iter().filter(|&&v| v != 0).count();
        if support * 8 >= self.res.len() {
            for j in 0..self.ids.len() {
                let mut d = 0i32;
                for &r in self.cols.row(j) {
                    d += self.res[r as usize];
                }
                self.dot[j] = d;
            }
            self.rebuild_heap();
            return;
        }
        self.dot.iter_mut().for_each(|d| *d = 0);
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.seen.iter_mut().for_each(|e| *e = 0);
            self.epoch = 1;
        }
        self.heap.clear();
        let mut touched: Vec<u32> = Vec::new();
        for r in 0..self.res.len() {
            let v = self.res[r];
            if v == 0 {
                continue;
            }
            for &j in self.rev.row(r) {
                self.dot[j as usize] += v;
                if self.seen[j as usize] != self.epoch {
                    self.seen[j as usize] = self.epoch;
                    touched.push(j);
                }
            }
        }
        let mut entries: Vec<HeapEntry> = Vec::with_capacity(touched.len());
        for &j in &touched {
            let g = self.gain(j as usize);
            if g > 0 {
                entries.push(HeapEntry { gain: g, j });
            }
        }
        // Set coordinates whose rows all went quiet still need gain re-evaluation after
        // reverts; the x-scan is a cheap O(n) bool pass.
        for j in 0..self.ids.len() {
            if self.x[j] && self.seen[j] != self.epoch {
                let g = self.gain(j);
                if g > 0 {
                    entries.push(HeapEntry { gain: g, j: j as u32 });
                }
            }
        }
        self.heap = BinaryHeap::from(entries);
    }

    /// Export the current residue in canonical orientation.
    pub fn export_residue(&self) -> Vec<i32> {
        let s = self.side.sign();
        self.res.iter().map(|&v| s * v).collect()
    }

    #[inline]
    pub fn residue_is_zero(&self) -> bool {
        self.l2_sq == 0
    }

    pub fn residue_l2_sq(&self) -> i64 {
        self.l2_sq
    }

    /// Length of the residue vector (= l).
    pub fn residue_len(&self) -> usize {
        self.res.len()
    }

    pub fn residue_l1(&self) -> i64 {
        self.res.iter().map(|&v| v.unsigned_abs() as i64).sum()
    }

    /// Current estimate set (ids with x = 1).
    pub fn estimate(&self) -> Vec<u64> {
        self.ids
            .iter()
            .zip(&self.x)
            .filter(|(_, &on)| on)
            .map(|(&id, _)| id)
            .collect()
    }

    pub fn estimate_len(&self) -> usize {
        self.estimate_count
    }

    #[inline]
    pub fn is_set_idx(&self, j: usize) -> bool {
        self.x[j]
    }

    /// Gain of the (unique) legal move on candidate `j` under the configured pursuit norm:
    /// the decrease of the residue norm if we flip `x_j`. Non-positive means "don't".
    #[inline]
    fn gain(&self, j: usize) -> i32 {
        if self.banned[j] && !self.x[j] {
            // SMF collision avoidance (§5.2) gates only *setting*; corrective unsets of an
            // already-set coordinate must stay possible.
            return i32::MIN;
        }
        self.gain_ungated(j)
    }

    /// `gain` evaluated against the decoder's current fields (used by `flip` with a
    /// temporarily restored dot to obtain the pre-update gain).
    #[inline]
    fn gain_snapshot(&self, j: usize) -> i32 {
        self.gain(j)
    }

    /// Gain ignoring the SMF gate (used by collision resolution to find tentative updates).
    #[inline]
    fn gain_ungated(&self, j: usize) -> i32 {
        let mj = (self.cols.offsets[j + 1] - self.cols.offsets[j]) as i32;
        if !self.x[j] {
            // Setting x_j: r ← r − m_j. Modification 9 rule 2 (δ > 1/2 ⟺ 2·dot > m).
            match self.config.pursuit {
                Pursuit::L2 => 2 * self.dot[j] - mj,
                Pursuit::L1 => self
                    .cols
                    .row(j)
                    .iter()
                    .map(|&r| if self.res[r as usize] >= 1 { 1 } else { -1 })
                    .sum(),
            }
        } else {
            // Unsetting x_j: r ← r + m_j. Modification 9 rule 1 (δ < −1/2).
            if !self.config.allow_unset {
                return i32::MIN;
            }
            match self.config.pursuit {
                Pursuit::L2 => -2 * self.dot[j] - mj,
                Pursuit::L1 => self
                    .cols
                    .row(j)
                    .iter()
                    .map(|&r| if self.res[r as usize] <= -1 { 1 } else { -1 })
                    .sum(),
            }
        }
    }

    fn rebuild_heap(&mut self) {
        self.heap.clear();
        let mut entries = Vec::new();
        for j in 0..self.ids.len() {
            let g = self.gain(j);
            if g > 0 {
                entries.push(HeapEntry { gain: g, j: j as u32 });
            }
        }
        self.heap = BinaryHeap::from(entries);
    }

    /// Flip candidate `j` (set if currently 0, unset if 1), updating residue, dots, norms,
    /// and the queue. This is the "update" stage of Procedure 1 under Modification 9.
    fn flip(&mut self, j: usize) {
        let setting = !self.x[j];
        let delta: i32 = if setting { -1 } else { 1 }; // residue change per touched row
        self.x[j] = setting;
        if setting {
            self.estimate_count += 1;
        } else {
            self.estimate_count -= 1;
        }

        let start = self.cols.offsets[j] as usize;
        let end = self.cols.offsets[j + 1] as usize;
        // First pass: update residue rows and dots, collecting each affected candidate
        // once (epoch stamps) together with its pre-update dot.
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.seen.iter_mut().for_each(|e| *e = 0);
            self.epoch = 1;
        }
        self.scratch.clear();
        for idx in start..end {
            let r = self.cols.items[idx] as usize;
            let old = self.res[r];
            let new = old + delta;
            self.res[r] = new;
            self.l2_sq += (new as i64) * (new as i64) - (old as i64) * (old as i64);
            // Reverse lookup: every candidate whose column touches row r sees its dot move.
            for &jj in self.rev.row(r) {
                let ju = jj as usize;
                if self.seen[ju] != self.epoch {
                    self.seen[ju] = self.epoch;
                    self.scratch.push((jj, self.dot[ju]));
                }
                self.dot[ju] += delta;
            }
        }
        // Second pass: re-enqueue only candidates whose gain *increased* (or turned
        // positive). Decreased gains die lazily: a stale higher-priority entry already
        // sits in the heap and is corrected at pop time, so skipping those pushes is
        // safe — and they are the overwhelming majority as the residue drains (§Perf-L3).
        let scratch = std::mem::take(&mut self.scratch);
        for &(jj, dot_before) in &scratch {
            let ju = jj as usize;
            let g = self.gain(ju);
            if g <= 0 {
                continue;
            }
            let increased = match self.config.pursuit {
                Pursuit::L2 => {
                    // g_old under the pre-update dot (x state of jj is unchanged by this
                    // flip unless jj == j, which run() re-pops anyway).
                    let saved = self.dot[ju];
                    self.dot[ju] = dot_before;
                    let g_old = self.gain_snapshot(ju);
                    self.dot[ju] = saved;
                    g > g_old
                }
                // L1 gains are not linear in the dot; push conservatively.
                Pursuit::L1 => true,
            };
            if increased {
                self.heap.push(HeapEntry { gain: g, j: jj });
            }
        }
        self.scratch = scratch;
        // Bound heap growth (lazy deletion can balloon under adversarial churn).
        if self.heap.len() > 64 + 16 * self.ids.len() {
            self.rebuild_heap();
        }
    }

    /// Force-set or force-unset a candidate regardless of gain or ban (used by the
    /// collision-resolution step of §5.2 and by tests). No-op if already in that state.
    pub fn force(&mut self, id: u64, set: bool) -> bool {
        if let Some(j) = self.ids.iter().position(|&x| x == id) {
            if self.x[j] != set {
                self.flip(j);
                return true;
            }
        }
        false
    }

    /// Run the pursuit loop until the residue is zero, no positive-gain move remains, or the
    /// iteration cap is hit.
    pub fn run(&mut self) -> DecodeStats {
        let mut stats = DecodeStats::default();
        let cap = if self.config.max_iters == 0 {
            8 * self.ids.len() + 64
        } else {
            self.config.max_iters
        };
        while stats.iterations < cap {
            if self.l2_sq == 0 {
                stats.converged = true;
                return stats;
            }
            let Some(top) = self.heap.pop() else {
                stats.stalled = true;
                return stats;
            };
            let j = top.j as usize;
            let g = self.gain(j);
            if g != top.gain {
                // Stale entry: re-enqueue the fresh gain if still profitable.
                if g > 0 {
                    self.heap.push(HeapEntry { gain: g, j: top.j });
                }
                continue;
            }
            if g <= 0 {
                continue;
            }
            self.flip(j);
            stats.iterations += 1;
            if self.x[j] {
                stats.sets += 1;
            } else {
                stats.unsets += 1;
            }
        }
        stats.converged = self.l2_sq == 0;
        stats.stalled = !stats.converged;
        stats
    }

    /// Switch pursuit norm mid-decode (the Appendix C.2 fallback flips to L1 pursuit when the
    /// L2 loop stalls on ECC-damaged residues). Rebuilds the queue.
    pub fn switch_pursuit(&mut self, pursuit: Pursuit) {
        self.config.pursuit = pursuit;
        self.rebuild_heap();
    }

    /// Clear the signal estimate (x := 0) without touching the loaded residue state.
    /// Callers then `load_residue` to start a fresh decode on the same candidate set —
    /// the pattern benches and multi-session reuse rely on (construction is the expensive
    /// part: CSR + reverse lookup).
    pub fn reset_signal(&mut self) {
        self.x.iter_mut().for_each(|b| *b = false);
        self.estimate_count = 0;
    }

    /// Escape hatch for pairwise local minima: when two candidates' columns overlap in
    /// m-2 rows, swapping them is invisible to single-move greedy pursuit (both moves have
    /// gain -1). Kicking out the set coordinate with the most negative dot lets the next
    /// `run` complete the swap (the true coordinate then has the top gain). Returns the
    /// kicked id, or None if no set coordinate has negative evidence.
    pub fn kick_worst(&mut self) -> Option<u64> {
        let mut worst: Option<(i32, usize)> = None;
        for j in 0..self.ids.len() {
            if self.x[j] && self.dot[j] < 0 && worst.map_or(true, |(d, _)| self.dot[j] < d) {
                worst = Some((self.dot[j], j));
            }
        }
        let (_, j) = worst?;
        self.flip(j);
        Some(self.ids[j])
    }

    /// Banned (SMF-positive) candidates that currently *want* pursuit — i.e. would be set
    /// were they not gated. These are exactly the coordinates the §5.2 collision-resolution
    /// step tentatively updates and verifies via the "last inquiry".
    pub fn banned_positive_gain(&self) -> Vec<u64> {
        let mut out = Vec::new();
        for j in 0..self.ids.len() {
            if self.banned[j] && !self.x[j] && self.gain_ungated(j) > 0 {
                out.push(self.ids[j]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::CsMatrix;
    use crate::sketch::Sketch;

    /// Plant B\A of size d among n candidates; check exact recovery (unidirectional core).
    fn planted_recovery(n: u64, d: usize, l: u32, m: u32, seed: u64) -> bool {
        let mat = CsMatrix::new(l, m, seed);
        let candidates: Vec<u64> = (0..n).map(|i| i.wrapping_mul(0x9e3779b97f4a7c15) ^ seed).collect();
        let planted: Vec<u64> = candidates.iter().step_by((n as usize / d).max(1)).copied().take(d).collect();
        let measurement = Sketch::encode(mat, &planted);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.set_config(DecoderConfig::commonsense());
        let canonical: Vec<i32> = measurement.counts.clone();
        dec.load_residue(&canonical);
        let stats = dec.run();
        if !stats.converged {
            return false;
        }
        let mut got = dec.estimate();
        got.sort_unstable();
        let mut want = planted;
        want.sort_unstable();
        got == want
    }

    #[test]
    fn recovers_planted_signal_l2() {
        for seed in 0..5 {
            assert!(planted_recovery(20_000, 100, 1600, 7, seed), "seed {seed}");
        }
    }

    #[test]
    fn recovers_larger_d() {
        assert!(planted_recovery(50_000, 1000, 12_000, 7, 3));
    }

    #[test]
    fn ssmp_also_recovers() {
        let mat = CsMatrix::new(1600, 7, 11);
        let candidates: Vec<u64> = (0..20_000u64).collect();
        let planted: Vec<u64> = (0..100u64).map(|i| i * 199).collect();
        let measurement = Sketch::encode(mat, &planted);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.set_config(DecoderConfig::ssmp());
        dec.load_residue(&measurement.counts);
        let stats = dec.run();
        assert!(stats.converged);
        let mut got = dec.estimate();
        got.sort_unstable();
        let mut want = planted;
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn negative_side_decodes_negated_signal() {
        // Canonical residue −M·1_S: Alice (Side::Negative) must recover S.
        let mat = CsMatrix::new(1000, 5, 21);
        let candidates: Vec<u64> = (0..10_000u64).collect();
        let planted: Vec<u64> = (0..60u64).map(|i| i * 151 + 3).collect();
        let sk = Sketch::encode(mat, &planted);
        let canonical: Vec<i32> = sk.counts.iter().map(|&c| -c).collect();
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Negative);
        dec.load_residue(&canonical);
        let stats = dec.run();
        assert!(stats.converged);
        assert_eq!(dec.estimate().len(), 60);
        // Exported residue must be canonical-zero.
        assert!(dec.export_residue().iter().all(|&v| v == 0));
    }

    #[test]
    fn banned_candidates_are_skipped_until_unbanned() {
        let mat = CsMatrix::new(400, 5, 31);
        let candidates: Vec<u64> = (0..5_000u64).collect();
        let planted: Vec<u64> = vec![10, 20, 30, 40];
        let sk = Sketch::encode(mat, &planted);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.load_residue(&sk.counts);
        dec.set_banned(|id| id == 10);
        dec.run();
        assert!(!dec.estimate().contains(&10));
        // Unban and the decoder finishes the job.
        dec.set_banned(|_| false);
        dec.load_residue(&dec.export_residue());
        let stats = dec.run();
        assert!(stats.converged);
        let mut got = dec.estimate();
        got.sort_unstable();
        assert_eq!(got, planted);
    }

    #[test]
    fn force_roundtrip_restores_residue() {
        let mat = CsMatrix::new(300, 5, 41);
        let candidates: Vec<u64> = (0..1000u64).collect();
        let sk = Sketch::encode(mat, &[7, 8]);
        let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
        dec.load_residue(&sk.counts);
        let before = dec.residue_l2_sq();
        assert!(dec.force(500, true));
        assert!(dec.residue_l2_sq() != before);
        assert!(dec.force(500, false));
        assert_eq!(dec.residue_l2_sq(), before);
        assert!(!dec.force(500, false)); // already unset → no-op
    }

    #[test]
    fn bmp_cannot_correct_its_own_errors_but_full_mp_can() {
        // Statistical statement: at a marginal l, full MP (with unsets) should succeed at
        // least as often as BMP, and strictly more over enough seeds.
        let mut bmp_ok = 0;
        let mut mp_ok = 0;
        for seed in 0..30u64 {
            let mat = CsMatrix::new(700, 5, seed);
            let candidates: Vec<u64> = (0..4_000u64).collect();
            let planted: Vec<u64> = (0..50u64).map(|i| i * 79 + seed).collect();
            let sk = Sketch::encode(mat, &planted);
            for bmp in [false, true] {
                let mut dec = MpDecoder::new(&mat, &candidates, Side::Positive);
                dec.set_config(if bmp { DecoderConfig::bmp() } else { DecoderConfig::commonsense() });
                dec.load_residue(&sk.counts);
                let stats = dec.run();
                let mut got = dec.estimate();
                got.sort_unstable();
                let mut want = planted.clone();
                want.sort_unstable();
                if stats.converged && got == want {
                    if bmp {
                        bmp_ok += 1;
                    } else {
                        mp_ok += 1;
                    }
                }
            }
        }
        assert!(mp_ok >= bmp_ok, "mp {mp_ok} < bmp {bmp_ok}");
        assert!(mp_ok >= 25, "full MP too weak at this l: {mp_ok}/30");
    }
}
