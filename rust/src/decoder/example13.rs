//! The worked example of Appendix A (Example 13), as an executable test.
//!
//! `M` is the 7×3 sparse binary matrix whose columns are {0,1,2}, {0,3,4}, {0,5,6};
//! the ground-truth signal is x₀ = (1,1,1)ᵀ, so r₀ = M·x₀ = (3,1,1,1,1,1,1)ᵀ.
//!
//! * Analog L2 pursuit would take δ* = mean(3,1,1) = 5/3 on the first coordinate — a 2/3
//!   pursuit error.
//! * L1 pursuit (SSMP) takes δ* = median(3,1,1) = 1 — exact.
//! * Our binary-constrained L2 pursuit (Modification 9) snaps to 1 — also exact.

#[cfg(test)]
mod tests {
    use crate::decoder::{DecoderConfig, MpDecoder, Pursuit, Side};
    use crate::matrix::ExplicitMatrix;

    fn example_matrix() -> ExplicitMatrix {
        ExplicitMatrix {
            l: 7,
            cols: vec![vec![0, 1, 2], vec![0, 3, 4], vec![0, 5, 6]],
        }
    }

    fn r0() -> Vec<i32> {
        vec![3, 1, 1, 1, 1, 1, 1]
    }

    #[test]
    fn analog_l2_step_would_err() {
        // Documented property, checked numerically: mean of (3,1,1) is 5/3, error 2/3.
        let delta_star = (3.0 + 1.0 + 1.0) / 3.0f64;
        assert!((delta_star - 5.0 / 3.0).abs() < 1e-12);
        assert!((delta_star - 1.0).abs() > 0.5);
    }

    #[test]
    fn binary_l2_pursuit_recovers_exactly() {
        let mat = example_matrix();
        let mut dec = MpDecoder::new(&mat, &[0, 1, 2], Side::Positive);
        dec.set_config(DecoderConfig::commonsense());
        dec.load_residue(&r0());
        let stats = dec.run();
        assert!(stats.converged, "residue must reach zero");
        let mut got = dec.estimate();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
        assert_eq!(stats.sets, 3);
        assert_eq!(stats.unsets, 0, "no corrections needed on this instance");
    }

    #[test]
    fn l1_pursuit_recovers_exactly() {
        let mat = example_matrix();
        let mut dec = MpDecoder::new(&mat, &[0, 1, 2], Side::Positive);
        dec.set_config(DecoderConfig {
            pursuit: Pursuit::L1,
            ..DecoderConfig::default()
        });
        dec.load_residue(&r0());
        let stats = dec.run();
        assert!(stats.converged);
        let mut got = dec.estimate();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
    }
}
