//! The binary-adapted matching-pursuit decoder (§3.4, Modification 9, Appendices A–B).
//!
//! This is the paper's third contribution in executable form: an MP decoder specialized to
//! binary signals measured through a sparse binary RIP-1 matrix, powered by the SSMP-style
//! data structures of Appendix B — a priority queue over candidate pursuit gains plus a
//! reverse lookup table from rows to candidate columns — which (per the paper) had no public
//! implementation before.
//!
//! Three pursuit variants are provided, matching the paper's taxonomy:
//! * **L2 pursuit on binary signals** (the CommonSense decoder): pursue coordinate `i` when
//!   `δ_i = rᵀm_i/m` crosses ±1/2 (Modification 9), both 0→1 and 1→0 updates allowed;
//! * **L1 pursuit** (SSMP, Berinde–Indyk): the deterministic fallback with RIP-1 guarantees;
//! * **BMP**: the binary matching pursuit of [Wen & Li 2021], 0→1 updates only — kept as an
//!   ablation baseline showing why bidirectional decoding needs reversible updates.

mod core;
mod example13;

pub use self::core::{DecodeStats, MpDecoder, Side};

use crate::matrix::ColumnOracle;

/// Which residue norm the matching stage greedily minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pursuit {
    /// Vanilla MP: minimize the L2 residue error. O(1) gain maintenance per touched row.
    L2,
    /// SSMP-style: minimize the L1 residue error. O(m) gain recomputation per touched
    /// candidate — slower, but deterministic-capable under RIP-1 (used as fallback).
    L1,
}

/// Decoder configuration.
#[derive(Clone, Copy, Debug)]
pub struct DecoderConfig {
    pub pursuit: Pursuit,
    /// Allow 1→0 corrections (rule 1 of Modification 9). `false` reproduces BMP.
    pub allow_unset: bool,
    /// Hard cap on pursuit iterations for one `run` call (0 ⇒ `8·candidates + 64`).
    pub max_iters: usize,
    /// Worker threads for decoder *construction* (column sampling + CSR + reverse
    /// lookup — the dominant per-session cost). `0` ⇒ auto (available parallelism),
    /// `1` ⇒ serial; clamped to 64. Construction-time only: the parallel build produces
    /// bit-identical structures to the serial one, and small candidate sets always build
    /// serially regardless.
    pub build_threads: usize,
}

impl Default for DecoderConfig {
    fn default() -> Self {
        DecoderConfig { pursuit: Pursuit::L2, allow_unset: true, max_iters: 0, build_threads: 0 }
    }
}

/// Exact-geometry key of a constructed decoder's matrix: the oracle's
/// [`ColumnOracle::structure_fingerprint`] plus the exact `(l, m)` dimensions. For the
/// production [`crate::matrix::CsMatrix`] the fingerprint is a pure function of
/// `(seed, l, m)`, so this key *is* the `(seed, l, m)` geometry — a shared decoder pool
/// ([`crate::server::DecoderPool`]) files parked decoders under it. The key deliberately
/// excludes the candidate set: geometry narrows the search, and the full
/// [`MpDecoder::cache_key`] (matrix + candidates + side) still decides actual reuse.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GeometryKey {
    pub matrix_fingerprint: u64,
    pub l: u32,
    pub m: u32,
}

impl GeometryKey {
    /// The geometry key decoders built against `oracle` will carry.
    pub fn of_oracle<C: ColumnOracle + ?Sized>(oracle: &C) -> GeometryKey {
        GeometryKey { matrix_fingerprint: oracle.structure_fingerprint(), l: oracle.l(), m: oracle.m() }
    }

    /// The geometry key a built decoder files under.
    pub fn of_decoder(dec: &MpDecoder) -> GeometryKey {
        let (l, m) = dec.matrix_dims();
        GeometryKey { matrix_fingerprint: dec.matrix_fingerprint(), l, m }
    }
}

/// A concurrency-safe store of parked decoders shared across sessions (and threads).
///
/// [`DecoderCache`] consults one of these (when attached via
/// [`DecoderCache::with_shared_store`]) so that *independent* sessions — e.g. the worker
/// pool of [`crate::server::SetxServer`], where thousands of clients reconcile against
/// one hot set — reuse each other's constructed decoders, not just their own
/// conversation's. `take` must return only a decoder that is interchangeable with a
/// fresh `(oracle, candidates, side)` build: geometry equal to `geo` *and*
/// [`MpDecoder::cache_key`] equal to `want_key` (the same double check the one-slot
/// cache performs).
pub trait DecoderStore: Send + Sync {
    /// Remove and return a parked decoder validating against (`geo`, `want_key`), if any.
    fn take(&self, geo: GeometryKey, want_key: u64) -> Option<MpDecoder>;
    /// Park a finished decoder under its geometry for future `take`s.
    fn put(&self, geo: GeometryKey, dec: MpDecoder);
}

/// A one-slot reuse cache for constructed decoders.
///
/// Decoder construction (CSR + reverse lookup over all n candidates) dwarfs everything
/// else a session does locally, yet consecutive protocol attempts and repeat
/// conversations often want a decoder over the *same* (matrix, candidate set, side)
/// triple. The cache keeps the most recently finished decoder; [`DecoderCache::checkout`]
/// hands it back — reset via [`MpDecoder::reset_signal`], which together with
/// `load_residue` is decode-for-decode identical to a fresh build (property-tested) —
/// when the cache key matches, and builds anew otherwise (e.g. after an escalation-ladder
/// rung redraws the matrix). The `setx` facade threads one of these through its endpoint
/// and sessions so the hot path skips rebuilds wherever the matrix survives.
///
/// With a [`DecoderStore`] attached ([`DecoderCache::with_shared_store`]) the cache
/// becomes a *view onto a shared pool*: checkouts that miss the local slot consult the
/// store, and finished decoders are parked in the store (instead of the slot) so other
/// sessions can pick them up — the [`crate::server`] reuse path.
#[derive(Default)]
pub struct DecoderCache {
    slot: Option<MpDecoder>,
    /// When set, overrides [`DecoderConfig::build_threads`] for every build this cache
    /// performs — drivers that are already running many sessions in parallel (the
    /// partitioned pool, the server worker pool) pin this to 1 so nested construction
    /// pools don't oversubscribe the machine `parts × cores`-fold.
    build_threads: Option<usize>,
    /// Cross-session reuse: consulted after the local slot on checkout, and the park
    /// target on `store` (see the type docs).
    shared: Option<std::sync::Arc<dyn DecoderStore>>,
}

impl DecoderCache {
    pub fn new() -> Self {
        DecoderCache::default()
    }

    /// A cache whose builds always use exactly `threads` construction workers,
    /// regardless of the per-checkout config (see the field docs).
    pub fn with_build_threads(threads: usize) -> Self {
        DecoderCache { slot: None, build_threads: Some(threads), shared: None }
    }

    /// Attach a shared [`DecoderStore`]: checkouts fall back to it and finished decoders
    /// are parked in it, so concurrent sessions pool their construction work.
    pub fn with_shared_store(mut self, store: std::sync::Arc<dyn DecoderStore>) -> Self {
        self.shared = Some(store);
        self
    }

    /// A decoder for exactly `(oracle, candidates, side)`: the cached one when its key
    /// matches (reset, with `config` applied), else one from the shared store (same
    /// validation), else a fresh build.
    pub fn checkout<C: ColumnOracle + Sync>(
        &mut self,
        oracle: &C,
        candidates: &[u64],
        side: Side,
        mut config: DecoderConfig,
    ) -> MpDecoder {
        if let Some(threads) = self.build_threads {
            config.build_threads = threads;
        }
        let want = MpDecoder::cache_key_for(oracle, candidates, side);
        if let Some(mut dec) = self.slot.take() {
            // Exact-dimension check on top of the 64-bit key: with (l, m) pinned, the
            // seed → fingerprint chain is injective (a composition of bijections), so a
            // wire peer cannot forge a colliding key with different matrix geometry and
            // trick us into reusing mismatched CSR tables.
            if dec.cache_key() == want && dec.matrix_dims() == (oracle.l(), oracle.m()) {
                dec.set_config(config);
                dec.reset_signal();
                return dec;
            }
        }
        if let Some(store) = &self.shared {
            if let Some(mut dec) = store.take(GeometryKey::of_oracle(oracle), want) {
                dec.set_config(config);
                dec.reset_signal();
                return dec;
            }
        }
        MpDecoder::with_config(oracle, candidates, side, config)
    }

    /// Park a finished decoder for future reuse: in the shared store when one is
    /// attached (so any session can reuse it), else in the local slot (replacing any
    /// previous occupant).
    pub fn store(&mut self, dec: MpDecoder) {
        match &self.shared {
            Some(store) => store.put(GeometryKey::of_decoder(&dec), dec),
            None => self.slot = Some(dec),
        }
    }

    /// Whether a decoder is currently parked in the local slot (a shared store keeps its
    /// own inventory).
    pub fn is_loaded(&self) -> bool {
        self.slot.is_some()
    }
}

impl std::fmt::Debug for DecoderCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DecoderCache")
            .field("loaded", &self.slot.is_some())
            .field("candidates", &self.slot.as_ref().map(|d| d.num_candidates()))
            .field("shared", &self.shared.is_some())
            .finish()
    }
}

/// Run the pursuit loop with the §3.4 escalation ladder shared by every protocol
/// frontend: vanilla L2 pursuit; on a stall, one L1 (SSMP) pass followed by an L2
/// polish; then up to `max_kicks` pairwise-local-minimum kicks
/// (see [`MpDecoder::kick_worst`]). Returns the final stats and whether the L1
/// fallback fired.
pub fn run_with_fallback(
    dec: &mut MpDecoder,
    ssmp_fallback: bool,
    max_kicks: usize,
) -> (DecodeStats, bool) {
    let mut stats = dec.run();
    let mut fell_back = false;
    if stats.stalled && ssmp_fallback {
        fell_back = true;
        dec.switch_pursuit(Pursuit::L1);
        dec.run();
        dec.switch_pursuit(Pursuit::L2);
        stats = dec.run();
    }
    // Escape pairwise local minima: kick out the most contradicted set coordinate and
    // re-run (bounded; a wrong kick is just noise that later rounds re-correct).
    let mut kicks = 0;
    while stats.stalled && kicks < max_kicks {
        if dec.kick_worst().is_none() {
            break;
        }
        kicks += 1;
        stats = dec.run();
    }
    (stats, fell_back)
}

impl DecoderConfig {
    /// The CommonSense decoder (Procedure 1 + Modification 9).
    pub fn commonsense() -> Self {
        Self::default()
    }

    /// SSMP fallback (L1 pursuit, reversible updates).
    pub fn ssmp() -> Self {
        DecoderConfig { pursuit: Pursuit::L1, ..Self::default() }
    }

    /// Binary matching pursuit [40]: zero-to-one only.
    pub fn bmp() -> Self {
        DecoderConfig { allow_unset: false, ..Self::default() }
    }
}
