//! The binary-adapted matching-pursuit decoder (§3.4, Modification 9, Appendices A–B).
//!
//! This is the paper's third contribution in executable form: an MP decoder specialized to
//! binary signals measured through a sparse binary RIP-1 matrix, powered by the SSMP-style
//! data structures of Appendix B — a priority queue over candidate pursuit gains plus a
//! reverse lookup table from rows to candidate columns — which (per the paper) had no public
//! implementation before.
//!
//! Three pursuit variants are provided, matching the paper's taxonomy:
//! * **L2 pursuit on binary signals** (the CommonSense decoder): pursue coordinate `i` when
//!   `δ_i = rᵀm_i/m` crosses ±1/2 (Modification 9), both 0→1 and 1→0 updates allowed;
//! * **L1 pursuit** (SSMP, Berinde–Indyk): the deterministic fallback with RIP-1 guarantees;
//! * **BMP**: the binary matching pursuit of [Wen & Li 2021], 0→1 updates only — kept as an
//!   ablation baseline showing why bidirectional decoding needs reversible updates.

mod core;
mod example13;

pub use self::core::{DecodeStats, MpDecoder, Side};

use crate::matrix::ColumnOracle;

/// Which residue norm the matching stage greedily minimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pursuit {
    /// Vanilla MP: minimize the L2 residue error. O(1) gain maintenance per touched row.
    L2,
    /// SSMP-style: minimize the L1 residue error. O(m) gain recomputation per touched
    /// candidate — slower, but deterministic-capable under RIP-1 (used as fallback).
    L1,
}

/// Decoder configuration.
#[derive(Clone, Copy, Debug)]
pub struct DecoderConfig {
    pub pursuit: Pursuit,
    /// Allow 1→0 corrections (rule 1 of Modification 9). `false` reproduces BMP.
    pub allow_unset: bool,
    /// Hard cap on pursuit iterations for one `run` call (0 ⇒ `8·candidates + 64`).
    pub max_iters: usize,
    /// Worker threads for decoder *construction* (column sampling + CSR + reverse
    /// lookup — the dominant per-session cost). `0` ⇒ auto (available parallelism),
    /// `1` ⇒ serial; clamped to 64. Construction-time only: the parallel build produces
    /// bit-identical structures to the serial one, and small candidate sets always build
    /// serially regardless.
    pub build_threads: usize,
}

impl Default for DecoderConfig {
    fn default() -> Self {
        DecoderConfig { pursuit: Pursuit::L2, allow_unset: true, max_iters: 0, build_threads: 0 }
    }
}

/// A one-slot reuse cache for constructed decoders.
///
/// Decoder construction (CSR + reverse lookup over all n candidates) dwarfs everything
/// else a session does locally, yet consecutive protocol attempts and repeat
/// conversations often want a decoder over the *same* (matrix, candidate set, side)
/// triple. The cache keeps the most recently finished decoder; [`DecoderCache::checkout`]
/// hands it back — reset via [`MpDecoder::reset_signal`], which together with
/// `load_residue` is decode-for-decode identical to a fresh build (property-tested) —
/// when the cache key matches, and builds anew otherwise (e.g. after an escalation-ladder
/// rung redraws the matrix). The `setx` facade threads one of these through its endpoint
/// and sessions so the hot path skips rebuilds wherever the matrix survives.
#[derive(Default)]
pub struct DecoderCache {
    slot: Option<MpDecoder>,
    /// When set, overrides [`DecoderConfig::build_threads`] for every build this cache
    /// performs — drivers that are already running many sessions in parallel (the
    /// partitioned pool) pin this to 1 so nested construction pools don't oversubscribe
    /// the machine `parts × cores`-fold.
    build_threads: Option<usize>,
}

impl DecoderCache {
    pub fn new() -> Self {
        DecoderCache::default()
    }

    /// A cache whose builds always use exactly `threads` construction workers,
    /// regardless of the per-checkout config (see the field docs).
    pub fn with_build_threads(threads: usize) -> Self {
        DecoderCache { slot: None, build_threads: Some(threads) }
    }

    /// A decoder for exactly `(oracle, candidates, side)`: the cached one when its key
    /// matches (reset, with `config` applied), a fresh build otherwise.
    pub fn checkout<C: ColumnOracle + Sync>(
        &mut self,
        oracle: &C,
        candidates: &[u64],
        side: Side,
        mut config: DecoderConfig,
    ) -> MpDecoder {
        if let Some(threads) = self.build_threads {
            config.build_threads = threads;
        }
        let want = MpDecoder::cache_key_for(oracle, candidates, side);
        if let Some(mut dec) = self.slot.take() {
            // Exact-dimension check on top of the 64-bit key: with (l, m) pinned, the
            // seed → fingerprint chain is injective (a composition of bijections), so a
            // wire peer cannot forge a colliding key with different matrix geometry and
            // trick us into reusing mismatched CSR tables.
            if dec.cache_key() == want && dec.matrix_dims() == (oracle.l(), oracle.m()) {
                dec.set_config(config);
                dec.reset_signal();
                return dec;
            }
        }
        MpDecoder::with_config(oracle, candidates, side, config)
    }

    /// Park a finished decoder for future reuse (replaces any previous occupant).
    pub fn store(&mut self, dec: MpDecoder) {
        self.slot = Some(dec);
    }

    /// Whether a decoder is currently parked.
    pub fn is_loaded(&self) -> bool {
        self.slot.is_some()
    }
}

impl std::fmt::Debug for DecoderCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DecoderCache")
            .field("loaded", &self.slot.is_some())
            .field("candidates", &self.slot.as_ref().map(|d| d.num_candidates()))
            .finish()
    }
}

/// Run the pursuit loop with the §3.4 escalation ladder shared by every protocol
/// frontend: vanilla L2 pursuit; on a stall, one L1 (SSMP) pass followed by an L2
/// polish; then up to `max_kicks` pairwise-local-minimum kicks
/// (see [`MpDecoder::kick_worst`]). Returns the final stats and whether the L1
/// fallback fired.
pub fn run_with_fallback(
    dec: &mut MpDecoder,
    ssmp_fallback: bool,
    max_kicks: usize,
) -> (DecodeStats, bool) {
    let mut stats = dec.run();
    let mut fell_back = false;
    if stats.stalled && ssmp_fallback {
        fell_back = true;
        dec.switch_pursuit(Pursuit::L1);
        dec.run();
        dec.switch_pursuit(Pursuit::L2);
        stats = dec.run();
    }
    // Escape pairwise local minima: kick out the most contradicted set coordinate and
    // re-run (bounded; a wrong kick is just noise that later rounds re-correct).
    let mut kicks = 0;
    while stats.stalled && kicks < max_kicks {
        if dec.kick_worst().is_none() {
            break;
        }
        kicks += 1;
        stats = dec.run();
    }
    (stats, fell_back)
}

impl DecoderConfig {
    /// The CommonSense decoder (Procedure 1 + Modification 9).
    pub fn commonsense() -> Self {
        Self::default()
    }

    /// SSMP fallback (L1 pursuit, reversible updates).
    pub fn ssmp() -> Self {
        DecoderConfig { pursuit: Pursuit::L1, ..Self::default() }
    }

    /// Binary matching pursuit [40]: zero-to-one only.
    pub fn bmp() -> Self {
        DecoderConfig { allow_unset: false, ..Self::default() }
    }
}
