//! Server-side observability: lock-free counters aggregated across poller threads,
//! snapshotted as [`ServerStats`] and serialized through the same flat-JSON conventions
//! as the [`crate::metrics`] bench trajectory (one record per line, numeric fields only),
//! so the `server_throughput` bench and the `commonsense serve` CLI can emit
//! machine-readable operating points without a serde dependency.
//!
//! With multi-tenancy there are two accounting scopes:
//!
//! * **global** counters in [`StatsInner`] — every connection lands here, and
//! * **per-tenant shards** in [`TenantCounters`] — a connection is charged to a shard
//!   once its `EstHello` has been routed to a tenant.
//!
//! A connection that dies *before* routing (malformed opening frame, admission-cap
//! rejection, unknown namespace) has no tenant; its failure/rejection is recorded in the
//! global `unrouted_*` counters. At quiescence the shard sums plus the unrouted counters
//! always equal the globals — both update paths go through the same helpers
//! ([`StatsInner::route_accepted`] / [`serve`](StatsInner::serve) /
//! [`fail`](StatsInner::fail) / [`reject`](StatsInner::reject)), and the property test
//! below drives random sequences of them to pin the invariant.

use super::pool::PoolStats;
use super::sketch_store::SketchStoreStats;
use crate::metrics::{CommLog, Phase};
use crate::obs::hist::{AtomicHistogram, LogHistogram};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Charge one finished session's transcript to a per-phase byte array plus the
/// codec-off-equivalent (raw) total (shared by the global and per-tenant scopes).
pub(crate) fn charge(phase_bytes: &[AtomicU64; 4], raw_bytes: &AtomicU64, comm: &CommLog) {
    for (i, &phase) in Phase::ALL.iter().enumerate() {
        let b = comm.bytes_by_phase(phase) as u64;
        if b > 0 {
            phase_bytes[i].fetch_add(b, Ordering::Relaxed);
        }
    }
    let raw = comm.total_raw_bytes() as u64;
    if raw > 0 {
        raw_bytes.fetch_add(raw, Ordering::Relaxed);
    }
}

/// Per-tenant counter shard. Owned by the tenant entry in the server's tenant map;
/// every routed connection is charged here *and* to the global [`StatsInner`].
#[derive(Default)]
pub(crate) struct TenantCounters {
    pub(crate) accepted: AtomicU64,
    pub(crate) served: AtomicU64,
    pub(crate) failed: AtomicU64,
    pub(crate) rejected: AtomicU64,
    /// Subset of `failed` whose error was a *protocol fault* (malformed or
    /// out-of-phase frame — a corrupting link or a hostile peer), as opposed to
    /// timeouts/disconnects. The chaos suite asserts these are counted, the
    /// slot freed, and the tenant's pool/store left unpoisoned.
    pub(crate) protocol_faults: AtomicU64,
    pub(crate) phase_bytes: [AtomicU64; 4],
    /// Codec-off-equivalent bytes of the same transcripts (what the sessions would
    /// have cost without the columnar wire codec).
    pub(crate) raw_bytes: AtomicU64,
    /// Routed, unfinished sessions of this tenant — the quota gauge.
    pub(crate) inflight: AtomicUsize,
    /// Wall time of this tenant's *served* sessions, in nanoseconds.
    pub(crate) latency: AtomicHistogram,
}

/// The atomics every poller thread updates (shared behind one `Arc`).
#[derive(Default)]
pub(crate) struct StatsInner {
    /// Connections routed into a session (== served + failed + in flight, per tenant
    /// and globally).
    pub(crate) sessions_accepted: AtomicU64,
    pub(crate) sessions_served: AtomicU64,
    pub(crate) sessions_failed: AtomicU64,
    pub(crate) sessions_rejected: AtomicU64,
    /// Failures of connections that never reached a tenant (torn down pre-routing).
    pub(crate) unrouted_failed: AtomicU64,
    /// Rejections issued before routing (admission cap, unknown namespace).
    pub(crate) unrouted_rejected: AtomicU64,
    /// Subset of `sessions_failed` that died to a malformed or out-of-phase frame
    /// (globally; the per-tenant split lives in the shards plus
    /// `unrouted_protocol_faults`).
    pub(crate) protocol_faults: AtomicU64,
    /// Protocol faults of connections that never routed (e.g. garbage instead of an
    /// `EstHello`).
    pub(crate) unrouted_protocol_faults: AtomicU64,
    /// Conversation bytes by protocol phase, indexed in [`Phase::ALL`] order
    /// (successful sessions only — a torn-down conversation has no agreed transcript).
    pub(crate) phase_bytes: [AtomicU64; 4],
    /// Codec-off-equivalent bytes of the same transcripts (successful sessions only).
    pub(crate) raw_bytes: AtomicU64,
    /// Live connections (admitted at accept, not yet closed) — the global
    /// admission-control gauge.
    pub(crate) inflight: AtomicUsize,
    pub(crate) peak_inflight: AtomicUsize,
    /// Poller threads currently processing readiness events; high-water mark ≤ the
    /// poller count (the same bounded-pool regression guard `coordinator::parallel`
    /// keeps).
    pub(crate) busy_workers: AtomicUsize,
    pub(crate) peak_workers: AtomicUsize,
    /// Wall time of every *served* session, in nanoseconds. Only routed sessions are
    /// timed, so at quiescence this histogram is exactly the merge of the tenant
    /// shards (the histogram face of the shard-sum invariant above).
    pub(crate) latency: AtomicHistogram,
}

impl StatsInner {
    /// Charge one finished session's transcript to the global per-phase byte counters.
    pub(crate) fn charge_comm(&self, comm: &CommLog) {
        charge(&self.phase_bytes, &self.raw_bytes, comm);
    }

    /// A connection's `EstHello` was routed to a tenant: count the session as accepted
    /// in both scopes.
    pub(crate) fn route_accepted(&self, t: &TenantCounters) {
        self.sessions_accepted.fetch_add(1, Ordering::Relaxed);
        t.accepted.fetch_add(1, Ordering::Relaxed);
    }

    /// A routed session finished with a verified report: count it served and charge its
    /// transcript, in both scopes.
    pub(crate) fn serve(&self, t: &TenantCounters, comm: &CommLog) {
        self.sessions_served.fetch_add(1, Ordering::Relaxed);
        t.served.fetch_add(1, Ordering::Relaxed);
        charge(&self.phase_bytes, &self.raw_bytes, comm);
        charge(&t.phase_bytes, &t.raw_bytes, comm);
    }

    /// Record one served session's wall time in both scopes' latency histograms.
    /// Always paired with [`StatsInner::serve`], so the tenant shards merge exactly
    /// to the global histogram.
    pub(crate) fn record_latency(&self, t: &TenantCounters, ns: u64) {
        self.latency.record(ns);
        t.latency.record(ns);
    }

    /// A session ended in a typed error. `None` = the connection never routed to a
    /// tenant (charged to `unrouted_failed`).
    pub(crate) fn fail(&self, t: Option<&TenantCounters>) {
        self.sessions_failed.fetch_add(1, Ordering::Relaxed);
        match t {
            Some(t) => {
                t.failed.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                self.unrouted_failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// The failure being recorded was a protocol fault (malformed/out-of-phase
    /// frame). Always *in addition to* [`StatsInner::fail`] — `protocol_faults`
    /// classifies a failure, it does not replace the failure count. `None` = the
    /// fault arrived before routing (charged to `unrouted_protocol_faults`).
    pub(crate) fn protocol_fault(&self, t: Option<&TenantCounters>) {
        self.protocol_faults.fetch_add(1, Ordering::Relaxed);
        match t {
            Some(t) => {
                t.protocol_faults.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                self.unrouted_protocol_faults.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// A connection was turned away with a `Busy` frame. `None` = rejected before
    /// routing (admission cap, unknown namespace — charged to `unrouted_rejected`);
    /// `Some` = a known tenant was over its quota.
    pub(crate) fn reject(&self, t: Option<&TenantCounters>) {
        self.sessions_rejected.fetch_add(1, Ordering::Relaxed);
        match t {
            Some(t) => {
                t.rejected.fetch_add(1, Ordering::Relaxed);
            }
            None => {
                self.unrouted_rejected.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// A point-in-time snapshot of one tenant's shard: routed-session outcomes, per-phase
/// wire bytes, the quota gauge, and the tenant's private decoder-pool and
/// host-sketch-store counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantStats {
    /// The tenant's wire namespace id.
    pub namespace: u32,
    pub sessions_accepted: u64,
    pub sessions_served: u64,
    pub sessions_failed: u64,
    pub sessions_rejected: u64,
    /// Subset of `sessions_failed` that died to a malformed/out-of-phase frame.
    pub protocol_faults: u64,
    /// Conversation bytes by phase (successful sessions), in [`Phase::ALL`] order.
    pub phase_bytes: [u64; 4],
    /// Codec-off-equivalent bytes of the same transcripts.
    pub raw_bytes: u64,
    /// Routed, unfinished sessions of this tenant.
    pub inflight: usize,
    /// Per-tenant concurrency quota.
    pub quota: usize,
    /// This tenant's decoder-pool shard (zeros when disabled).
    pub pool: PoolStats,
    /// This tenant's host-sketch-store shard (zeros when disabled).
    pub sketch_store: SketchStoreStats,
    /// Wall-time histogram of this tenant's served sessions (nanoseconds).
    pub latency: LogHistogram,
}

impl TenantStats {
    /// Total conversation bytes across phases for this tenant.
    pub fn total_bytes(&self) -> u64 {
        self.phase_bytes.iter().sum()
    }

    /// Encoded ÷ raw bytes for this tenant's successful sessions (1.0 when nothing
    /// was charged, or the codec saved nothing).
    pub fn compression_ratio(&self) -> f64 {
        if self.raw_bytes == 0 {
            1.0
        } else {
            self.total_bytes() as f64 / self.raw_bytes as f64
        }
    }
}

impl TenantCounters {
    pub(crate) fn snapshot(
        &self,
        namespace: u32,
        quota: usize,
        pool: PoolStats,
        sketch_store: SketchStoreStats,
    ) -> TenantStats {
        TenantStats {
            namespace,
            sessions_accepted: self.accepted.load(Ordering::Relaxed),
            sessions_served: self.served.load(Ordering::Relaxed),
            sessions_failed: self.failed.load(Ordering::Relaxed),
            sessions_rejected: self.rejected.load(Ordering::Relaxed),
            protocol_faults: self.protocol_faults.load(Ordering::Relaxed),
            phase_bytes: [
                self.phase_bytes[0].load(Ordering::Relaxed),
                self.phase_bytes[1].load(Ordering::Relaxed),
                self.phase_bytes[2].load(Ordering::Relaxed),
                self.phase_bytes[3].load(Ordering::Relaxed),
            ],
            raw_bytes: self.raw_bytes.load(Ordering::Relaxed),
            inflight: self.inflight.load(Ordering::Relaxed),
            quota,
            pool,
            sketch_store,
            latency: self.latency.snapshot(),
        }
    }
}

/// A point-in-time snapshot of a running (or stopped) [`crate::server::SetxServer`]:
/// admission and outcome counters, per-phase wire bytes, decoder-pool effectiveness,
/// the poller-pool high-water marks, and one [`TenantStats`] per resident tenant.
///
/// `pool` and `sketch_store` are *aggregates* summed across the tenant shards
/// (capacities and resident counts included), preserving the pre-tenancy meaning of the
/// flat JSON record.
#[derive(Clone, Debug)]
pub struct ServerStats {
    /// Connections routed into a session (== served + failed + in flight).
    pub sessions_accepted: u64,
    /// Sessions that completed with a verified report.
    pub sessions_served: u64,
    /// Sessions that ended in a typed error (timeout, malformed peer, decode exhaustion).
    pub sessions_failed: u64,
    /// Connections turned away with a `Busy` frame (admission cap, unknown namespace,
    /// or tenant quota).
    pub sessions_rejected: u64,
    /// Failures of connections torn down before routing to a tenant.
    pub unrouted_failed: u64,
    /// Rejections issued before routing (admission cap, unknown namespace).
    pub unrouted_rejected: u64,
    /// Subset of [`ServerStats::sessions_failed`] that died to a malformed or
    /// out-of-phase frame (a corrupting link or hostile peer) rather than a
    /// timeout/disconnect. Shard-summed like every counter: tenant
    /// `protocol_faults` plus [`ServerStats::unrouted_protocol_faults`] equal
    /// this at quiescence.
    pub protocol_faults: u64,
    /// Protocol faults of connections that never routed to a tenant.
    pub unrouted_protocol_faults: u64,
    /// Conversation bytes by phase (successful sessions), in [`Phase::ALL`] order:
    /// handshake, sketch, residue, confirm.
    pub phase_bytes: [u64; 4],
    /// Codec-off-equivalent bytes of the same transcripts — together with
    /// [`ServerStats::total_bytes`] this is the server-wide view of what the columnar
    /// wire codec saved.
    pub raw_bytes: u64,
    /// Decoder-pool counters summed across tenant shards (all zeros when disabled).
    pub pool: PoolStats,
    /// Host-sketch-store counters summed across tenant shards (all zeros when
    /// disabled): hits are whole host-set encodes skipped, incremental updates are
    /// resident sketches maintained through `replace_set` churn by §4 streaming diffs.
    pub sketch_store: SketchStoreStats,
    /// Currently admitted, unclosed connections (the live admission gauge).
    pub inflight: usize,
    /// High-water mark of concurrently admitted connections.
    pub peak_inflight: usize,
    /// High-water mark of concurrently busy poller threads (≤ configured `workers`).
    pub peak_workers: usize,
    /// Configured poller-thread count.
    pub workers: usize,
    /// Configured global admission cap.
    pub max_inflight_sessions: usize,
    /// Wall-time histogram of every served session (nanoseconds). At quiescence it is
    /// exactly the merge of the per-tenant histograms in [`ServerStats::tenants`].
    pub latency: LogHistogram,
    /// Per-tenant shard snapshots, sorted by namespace.
    pub tenants: Vec<TenantStats>,
}

impl ServerStats {
    /// Total conversation bytes across phases (successful sessions).
    pub fn total_bytes(&self) -> u64 {
        self.phase_bytes.iter().sum()
    }

    /// Encoded ÷ raw bytes across every successful session (1.0 when nothing was
    /// charged, or every session negotiated the codec off).
    pub fn compression_ratio(&self) -> f64 {
        if self.raw_bytes == 0 {
            1.0
        } else {
            self.total_bytes() as f64 / self.raw_bytes as f64
        }
    }

    /// Decoder-pool hit rate (0.0 when the pool was never consulted or is disabled).
    pub fn pool_hit_rate(&self) -> f64 {
        self.pool.hit_rate()
    }

    /// Host-sketch-store hit rate (0.0 when the store was never consulted or disabled).
    pub fn sketch_store_hit_rate(&self) -> f64 {
        self.sketch_store.hit_rate()
    }

    /// The shard for `namespace`, if resident at snapshot time.
    pub fn tenant(&self, namespace: u32) -> Option<&TenantStats> {
        self.tenants.iter().find(|t| t.namespace == namespace)
    }

    /// One flat JSON record (the schema style of the `BENCH_*.json` trajectory): every
    /// field numeric, keys stable, no nesting — ready to append to a log or paste into
    /// the bench tooling. Per-tenant shards are summarized by `tenant_count` plus the
    /// `unrouted_*` remainders; the full breakdown lives in [`ServerStats::tenants`].
    ///
    /// Every ratio field is a finite number by construction (zero denominators take
    /// documented sentinels — 1.0 for compression, 0.0 for hit rates and quantiles of
    /// an empty histogram), so the record always parses as strict JSON.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"sessions_accepted\":{},\"sessions_served\":{},\"sessions_failed\":{},\
             \"sessions_rejected\":{},\"unrouted_failed\":{},\"unrouted_rejected\":{},\
             \"protocol_faults\":{},\"unrouted_protocol_faults\":{},\
             \"tenant_count\":{},\"bytes_handshake\":{},\"bytes_sketch\":{},\
             \"bytes_residue\":{},\"bytes_confirm\":{},\"raw_bytes\":{},\
             \"compression_ratio\":{:.4},\"pool_hits\":{},\"pool_misses\":{},\
             \"pool_evictions\":{},\"pool_parked\":{},\"pool_capacity\":{},\
             \"pool_hit_rate\":{:.4},\"store_hits\":{},\"store_misses\":{},\
             \"store_stale_bypasses\":{},\"store_encodes\":{},\
             \"store_incremental_updates\":{},\"store_full_rebuilds\":{},\
             \"store_resident\":{},\"store_capacity\":{},\"store_hit_rate\":{:.4},\
             \"inflight\":{},\"peak_inflight\":{},\
             \"peak_workers\":{},\"workers\":{},\"max_inflight_sessions\":{},\
             \"latency_count\":{},\"latency_p50_ns\":{},\"latency_p99_ns\":{}}}",
            self.sessions_accepted,
            self.sessions_served,
            self.sessions_failed,
            self.sessions_rejected,
            self.unrouted_failed,
            self.unrouted_rejected,
            self.protocol_faults,
            self.unrouted_protocol_faults,
            self.tenants.len(),
            self.phase_bytes[0],
            self.phase_bytes[1],
            self.phase_bytes[2],
            self.phase_bytes[3],
            self.raw_bytes,
            self.compression_ratio(),
            self.pool.hits,
            self.pool.misses,
            self.pool.evictions,
            self.pool.parked,
            self.pool.capacity,
            self.pool_hit_rate(),
            self.sketch_store.hits,
            self.sketch_store.misses,
            self.sketch_store.stale_bypasses,
            self.sketch_store.encodes,
            self.sketch_store.incremental_updates,
            self.sketch_store.full_rebuilds,
            self.sketch_store.resident,
            self.sketch_store.capacity,
            self.sketch_store_hit_rate(),
            self.inflight,
            self.peak_inflight,
            self.peak_workers,
            self.workers,
            self.max_inflight_sessions,
            self.latency.count(),
            self.latency.quantile(0.5),
            self.latency.quantile(0.99),
        )
    }

    /// Render the snapshot in the Prometheus text exposition format (version 0.0.4):
    /// `# HELP`/`# TYPE` headers, counters and gauges as bare samples, and the
    /// session-latency histograms with *cumulative* `_bucket{le="…"}` series plus
    /// `_sum`/`_count`, globally and per tenant (`tenant="<namespace>"` label). The
    /// per-tenant latency series merge exactly to the global family — the same
    /// shard-sum invariant the counters keep, so a scraper can cross-check either
    /// scope against the other.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let counter = |out: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        counter(
            &mut out,
            "setx_sessions_accepted",
            "Connections routed into a SetX session.",
            self.sessions_accepted,
        );
        counter(
            &mut out,
            "setx_sessions_served",
            "Sessions that completed with a verified report.",
            self.sessions_served,
        );
        counter(
            &mut out,
            "setx_sessions_failed",
            "Sessions that ended in a typed error.",
            self.sessions_failed,
        );
        counter(
            &mut out,
            "setx_sessions_rejected",
            "Connections turned away with a Busy frame.",
            self.sessions_rejected,
        );
        counter(
            &mut out,
            "setx_protocol_faults",
            "Failed sessions that died to a malformed or out-of-phase frame.",
            self.protocol_faults,
        );
        let tenant_counters: [(&str, &str, fn(&TenantStats) -> u64); 5] = [
            ("setx_tenant_sessions_accepted", "Routed per tenant.", |t| t.sessions_accepted),
            ("setx_tenant_sessions_served", "Served sessions per tenant.", |t| t.sessions_served),
            ("setx_tenant_sessions_failed", "Failed sessions per tenant.", |t| t.sessions_failed),
            ("setx_tenant_sessions_rejected", "Rejections per tenant.", |t| t.sessions_rejected),
            ("setx_tenant_protocol_faults", "Protocol faults per tenant.", |t| t.protocol_faults),
        ];
        for (name, help, get) in tenant_counters {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            for t in &self.tenants {
                let _ = writeln!(out, "{name}{{tenant=\"{}\"}} {}", t.namespace, get(t));
            }
        }
        let _ = writeln!(
            out,
            "# HELP setx_bytes_total Conversation bytes of served sessions, by phase."
        );
        let _ = writeln!(out, "# TYPE setx_bytes_total counter");
        for (i, phase) in ["handshake", "sketch", "residue", "confirm"].iter().enumerate() {
            let _ = writeln!(out, "setx_bytes_total{{phase=\"{phase}\"}} {}", self.phase_bytes[i]);
        }
        counter(
            &mut out,
            "setx_raw_bytes_total",
            "Codec-off-equivalent bytes of the same transcripts.",
            self.raw_bytes,
        );
        let _ = writeln!(
            out,
            "# HELP setx_inflight_sessions Currently admitted, unclosed connections."
        );
        let _ = writeln!(out, "# TYPE setx_inflight_sessions gauge");
        let _ = writeln!(out, "setx_inflight_sessions {}", self.inflight);
        let _ = writeln!(
            out,
            "# HELP setx_session_latency_ns Wall time of served sessions in nanoseconds."
        );
        let _ = writeln!(out, "# TYPE setx_session_latency_ns histogram");
        prom_histogram(&mut out, "setx_session_latency_ns", "", &self.latency);
        let _ = writeln!(
            out,
            "# HELP setx_tenant_session_latency_ns Per-tenant wall time of served \
             sessions in nanoseconds."
        );
        let _ = writeln!(out, "# TYPE setx_tenant_session_latency_ns histogram");
        for t in &self.tenants {
            let labels = format!("tenant=\"{}\",", t.namespace);
            prom_histogram(&mut out, "setx_tenant_session_latency_ns", &labels, &t.latency);
        }
        out
    }
}

/// Append one Prometheus histogram family: cumulative `_bucket{…le="…"}` samples (the
/// exposition format's `le` is cumulative, unlike [`LogHistogram::buckets`]), the
/// mandatory `le="+Inf"` bucket, then `_sum` and `_count`. `extra` is either empty or
/// a `key="value",` prefix spliced before the `le` label.
fn prom_histogram(out: &mut String, name: &str, extra: &str, h: &LogHistogram) {
    use std::fmt::Write;
    let mut cum = 0u64;
    for (upper, count) in h.buckets() {
        cum += count;
        let _ = writeln!(out, "{name}_bucket{{{extra}le=\"{upper}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{{extra}le=\"+Inf\"}} {}", h.count());
    let bare = extra.trim_end_matches(',');
    let labels = if bare.is_empty() { String::new() } else { format!("{{{bare}}}") };
    let _ = writeln!(out, "{name}_sum{labels} {}", h.sum());
    let _ = writeln!(out, "{name}_count{labels} {}", h.count());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_comm_buckets_by_phase() {
        let inner = StatsInner::default();
        let mut comm = CommLog::new();
        comm.record(true, Phase::Handshake, 10);
        comm.record(false, Phase::Sketch, 100);
        comm.record(true, Phase::Residue, 40);
        comm.record(false, Phase::Residue, 5);
        comm.record(true, Phase::Confirm, 3);
        // One codec-on frame: encoded 40, would-have-been 55 raw.
        comm.record_framed(false, Phase::Residue, 40, 55);
        inner.charge_comm(&comm);
        let got: Vec<u64> =
            inner.phase_bytes.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        assert_eq!(got, vec![10, 100, 85, 3]);
        // Raw total: plain records charge raw == bytes; the framed record adds 55.
        assert_eq!(inner.raw_bytes.load(Ordering::Relaxed), 10 + 100 + 45 + 3 + 55);
    }

    #[test]
    fn stats_json_is_flat_and_complete() {
        let stats = ServerStats {
            sessions_accepted: 34,
            sessions_served: 32,
            sessions_failed: 1,
            sessions_rejected: 1,
            unrouted_failed: 0,
            unrouted_rejected: 1,
            protocol_faults: 1,
            unrouted_protocol_faults: 0,
            phase_bytes: [1, 2, 3, 4],
            raw_bytes: 20,
            pool: PoolStats { hits: 30, misses: 2, evictions: 0, parked: 2, capacity: 8 },
            sketch_store: SketchStoreStats {
                hits: 28,
                misses: 2,
                stale_bypasses: 2,
                encodes: 4,
                incremental_updates: 3,
                full_rebuilds: 1,
                resident: 2,
                capacity: 8,
            },
            inflight: 1,
            peak_inflight: 5,
            peak_workers: 4,
            workers: 4,
            max_inflight_sessions: 64,
            latency: LogHistogram::new(),
            tenants: vec![TenantStats { namespace: 0, quota: 64, ..TenantStats::default() }],
        };
        let json = stats.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        for key in [
            "sessions_accepted",
            "sessions_served",
            "sessions_failed",
            "sessions_rejected",
            "unrouted_failed",
            "unrouted_rejected",
            "protocol_faults",
            "unrouted_protocol_faults",
            "tenant_count",
            "bytes_handshake",
            "bytes_sketch",
            "bytes_residue",
            "bytes_confirm",
            "raw_bytes",
            "compression_ratio",
            "pool_hits",
            "pool_misses",
            "pool_hit_rate",
            "store_hits",
            "store_misses",
            "store_incremental_updates",
            "store_full_rebuilds",
            "store_hit_rate",
            "inflight",
            "peak_inflight",
            "peak_workers",
            "max_inflight_sessions",
        ] {
            assert!(json.contains(&format!("\"{key}\":")), "missing {key} in {json}");
        }
        assert_eq!(stats.total_bytes(), 10);
        assert!((stats.compression_ratio() - 0.5).abs() < 1e-12);
        assert!(json.contains("\"raw_bytes\":20"));
        assert!(json.contains("\"compression_ratio\":0.5000"));
        assert!((stats.pool_hit_rate() - 30.0 / 32.0).abs() < 1e-12);
        assert!((stats.sketch_store_hit_rate() - 28.0 / 32.0).abs() < 1e-12);
        assert!(json.contains("\"tenant_count\":1"));
    }

    /// Drive random sequences of the shared update helpers against a global
    /// [`StatsInner`] and a handful of tenant shards, then check the accounting
    /// invariant the server relies on: shard sums plus the unrouted remainders equal
    /// the globals, for every counter and every phase-byte bucket.
    #[test]
    fn tenant_shards_plus_unrouted_sum_to_globals() {
        let mut rng = crate::hash::Xoshiro256::seed_from_u64(0x7e4a_17);
        let inner = StatsInner::default();
        let shards: Vec<TenantCounters> =
            (0..4).map(|_| TenantCounters::default()).collect();

        let mut comm = CommLog::new();
        comm.record(true, Phase::Handshake, 7);
        comm.record(false, Phase::Sketch, 31);
        comm.record(true, Phase::Residue, 13);
        comm.record(false, Phase::Confirm, 2);

        for _ in 0..10_000 {
            let shard = match rng.next_u64() % 5 {
                4 => None,
                i => Some(&shards[i as usize]),
            };
            match rng.next_u64() % 4 {
                0 => {
                    // route_accepted + serve only make sense for routed connections.
                    if let Some(t) = shard {
                        inner.route_accepted(t);
                        inner.serve(t, &comm);
                        inner.record_latency(t, 1 + rng.next_u64() % 1_000_000_000);
                    }
                }
                1 => {
                    if let Some(t) = shard {
                        inner.route_accepted(t);
                    }
                    inner.fail(shard);
                    // Half the failures are protocol faults (the typed subset the
                    // chaos suite watches); the classification must shard-sum too.
                    if rng.next_u64() % 2 == 0 {
                        inner.protocol_fault(shard);
                    }
                }
                2 => inner.reject(shard),
                _ => {
                    if let Some(t) = shard {
                        inner.route_accepted(t);
                    }
                }
            }
        }

        let sum = |f: fn(&TenantCounters) -> &AtomicU64| -> u64 {
            shards.iter().map(|t| f(t).load(Ordering::Relaxed)).sum()
        };
        assert_eq!(
            inner.sessions_accepted.load(Ordering::Relaxed),
            sum(|t| &t.accepted),
            "accepted != shard sum (every accepted session is routed)"
        );
        assert_eq!(
            inner.sessions_served.load(Ordering::Relaxed),
            sum(|t| &t.served),
            "served != shard sum"
        );
        assert_eq!(
            inner.sessions_failed.load(Ordering::Relaxed),
            sum(|t| &t.failed) + inner.unrouted_failed.load(Ordering::Relaxed),
            "failed != shard sum + unrouted"
        );
        assert_eq!(
            inner.sessions_rejected.load(Ordering::Relaxed),
            sum(|t| &t.rejected) + inner.unrouted_rejected.load(Ordering::Relaxed),
            "rejected != shard sum + unrouted"
        );
        assert_eq!(
            inner.protocol_faults.load(Ordering::Relaxed),
            sum(|t| &t.protocol_faults)
                + inner.unrouted_protocol_faults.load(Ordering::Relaxed),
            "protocol faults != shard sum + unrouted"
        );
        assert!(
            inner.protocol_faults.load(Ordering::Relaxed)
                <= inner.sessions_failed.load(Ordering::Relaxed),
            "protocol faults classify failures, they cannot exceed them"
        );
        for i in 0..4 {
            let shard_bytes: u64 =
                shards.iter().map(|t| t.phase_bytes[i].load(Ordering::Relaxed)).sum();
            assert_eq!(
                inner.phase_bytes[i].load(Ordering::Relaxed),
                shard_bytes,
                "phase bucket {i} != shard sum"
            );
        }
        let shard_raw: u64 = shards.iter().map(|t| t.raw_bytes.load(Ordering::Relaxed)).sum();
        assert_eq!(
            inner.raw_bytes.load(Ordering::Relaxed),
            shard_raw,
            "raw bytes != shard sum"
        );
        // The histogram face of the same invariant: merging the tenant shards
        // reproduces the global latency histogram bucket-for-bucket, because
        // `record_latency` writes both scopes from the same sample.
        let mut merged = LogHistogram::new();
        for t in &shards {
            merged.merge(&t.latency.snapshot());
        }
        let global = inner.latency.snapshot();
        assert_eq!(merged, global, "tenant latency shards must merge to the global");
        for q in [0.5, 0.95, 0.99] {
            assert_eq!(merged.quantile(q), global.quantile(q));
        }
    }

    /// Every ratio accessor takes a documented sentinel on a zero denominator —
    /// finite, never NaN — and the JSON record built from an idle server stays
    /// parseable (no `NaN`/`inf` tokens can appear in the numeric fields).
    #[test]
    fn zero_denominator_ratios_are_finite_sentinels() {
        let idle = ServerStats {
            sessions_accepted: 0,
            sessions_served: 0,
            sessions_failed: 0,
            sessions_rejected: 0,
            unrouted_failed: 0,
            unrouted_rejected: 0,
            protocol_faults: 0,
            unrouted_protocol_faults: 0,
            phase_bytes: [0; 4],
            raw_bytes: 0,
            pool: PoolStats::default(),
            sketch_store: SketchStoreStats::default(),
            inflight: 0,
            peak_inflight: 0,
            peak_workers: 0,
            workers: 0,
            max_inflight_sessions: 0,
            latency: LogHistogram::new(),
            tenants: vec![TenantStats::default()],
        };
        assert_eq!(idle.compression_ratio(), 1.0);
        assert_eq!(idle.pool_hit_rate(), 0.0);
        assert_eq!(idle.sketch_store_hit_rate(), 0.0);
        assert_eq!(TenantStats::default().compression_ratio(), 1.0);
        assert_eq!(CommLog::new().compression_ratio(), 1.0);
        assert_eq!(idle.latency.quantile(0.99), 0);
        for v in [idle.compression_ratio(), idle.pool_hit_rate(), idle.sketch_store_hit_rate()] {
            assert!(v.is_finite());
        }
        let json = idle.to_json();
        assert!(!json.contains("NaN") && !json.contains("inf"), "unparseable: {json}");
        assert!(json.contains("\"compression_ratio\":1.0000"));
        assert!(json.contains("\"latency_count\":0"));
        assert!(json.contains("\"latency_p50_ns\":0"));
    }

    #[test]
    fn prometheus_exposition_has_cumulative_buckets_and_tenant_series() {
        let inner = StatsInner::default();
        let tenant = TenantCounters::default();
        for ns in [100u64, 100, 900, 70_000] {
            inner.record_latency(&tenant, ns);
        }
        let shard = tenant.snapshot(7, 16, PoolStats::default(), SketchStoreStats::default());
        let stats = ServerStats {
            sessions_accepted: 4,
            sessions_served: 4,
            sessions_failed: 0,
            sessions_rejected: 0,
            unrouted_failed: 0,
            unrouted_rejected: 0,
            protocol_faults: 1,
            unrouted_protocol_faults: 1,
            phase_bytes: [10, 200, 40, 8],
            raw_bytes: 300,
            pool: PoolStats::default(),
            sketch_store: SketchStoreStats::default(),
            inflight: 2,
            peak_inflight: 3,
            peak_workers: 2,
            workers: 4,
            max_inflight_sessions: 64,
            latency: inner.latency.snapshot(),
            tenants: vec![shard],
        };
        let text = stats.to_prometheus();
        assert!(text.contains("# TYPE setx_sessions_served counter"));
        assert!(text.contains("setx_sessions_served 4"));
        assert!(text.contains("setx_tenant_sessions_served{tenant=\"7\"} 0"));
        assert!(text.contains("# TYPE setx_protocol_faults counter"));
        assert!(text.contains("setx_protocol_faults 1"));
        assert!(text.contains("setx_tenant_protocol_faults{tenant=\"7\"} 0"));
        assert!(text.contains("setx_bytes_total{phase=\"sketch\"} 200"));
        assert!(text.contains("# TYPE setx_inflight_sessions gauge"));
        assert!(text.contains("setx_inflight_sessions 2"));
        assert!(text.contains("# TYPE setx_session_latency_ns histogram"));
        assert!(text.contains("setx_session_latency_ns_bucket{le=\"+Inf\"} 4"));
        assert!(text.contains("setx_session_latency_ns_count 4"));
        assert!(text.contains("setx_session_latency_ns_sum 71100"));
        assert!(text.contains("latency_ns_bucket{tenant=\"7\",le=\"+Inf\"} 4"));
        assert!(text.contains("setx_tenant_session_latency_ns_count{tenant=\"7\"} 4"));
        // `le` series must be cumulative: extract the global bucket counts in order
        // and check monotonicity, ending at the +Inf total.
        let mut last = 0u64;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("setx_session_latency_ns_bucket{le=") {
                let v: u64 = rest.split_whitespace().last().unwrap().parse().unwrap();
                assert!(v >= last, "non-cumulative bucket in {line}");
                last = v;
            }
        }
        assert_eq!(last, 4);
    }
}
