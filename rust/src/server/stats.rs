//! Server-side observability: lock-free counters aggregated across workers, snapshotted
//! as [`ServerStats`] and serialized through the same flat-JSON conventions as the
//! [`crate::metrics`] bench trajectory (one record per line, numeric fields only), so
//! the `server_throughput` bench and the `commonsense serve` CLI can emit
//! machine-readable operating points without a serde dependency.

use super::pool::PoolStats;
use super::sketch_store::SketchStoreStats;
use crate::metrics::{CommLog, Phase};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// The atomics every worker/accept thread updates (shared behind one `Arc`).
#[derive(Default)]
pub(crate) struct StatsInner {
    pub(crate) sessions_accepted: AtomicU64,
    pub(crate) sessions_served: AtomicU64,
    pub(crate) sessions_failed: AtomicU64,
    pub(crate) sessions_rejected: AtomicU64,
    /// Conversation bytes by protocol phase, indexed in [`Phase::ALL`] order
    /// (successful sessions only — a torn-down conversation has no agreed transcript).
    pub(crate) phase_bytes: [AtomicU64; 4],
    /// Live sessions (accepted, not yet finished) — the admission-control gauge.
    pub(crate) inflight: AtomicUsize,
    pub(crate) peak_inflight: AtomicUsize,
    /// Workers currently driving a session; high-water mark ≤ the worker count (the
    /// same bounded-pool regression guard `coordinator::parallel` keeps).
    pub(crate) busy_workers: AtomicUsize,
    pub(crate) peak_workers: AtomicUsize,
}

impl StatsInner {
    /// Charge one finished session's transcript to the per-phase byte counters.
    pub(crate) fn charge_comm(&self, comm: &CommLog) {
        for (i, &phase) in Phase::ALL.iter().enumerate() {
            let b = comm.bytes_by_phase(phase) as u64;
            if b > 0 {
                self.phase_bytes[i].fetch_add(b, Ordering::Relaxed);
            }
        }
    }
}

/// A point-in-time snapshot of a running (or stopped) [`crate::server::SetxServer`]:
/// admission and outcome counters, per-phase wire bytes, decoder-pool effectiveness,
/// and the worker-pool high-water marks.
#[derive(Clone, Copy, Debug)]
pub struct ServerStats {
    /// Connections accepted into a session (admitted; == served + failed + in flight).
    pub sessions_accepted: u64,
    /// Sessions that completed with a verified report.
    pub sessions_served: u64,
    /// Sessions that ended in a typed error (timeout, malformed peer, decode exhaustion).
    pub sessions_failed: u64,
    /// Connections turned away at admission with a `Busy` frame.
    pub sessions_rejected: u64,
    /// Conversation bytes by phase (successful sessions), in [`Phase::ALL`] order:
    /// handshake, sketch, residue, confirm.
    pub phase_bytes: [u64; 4],
    /// Decoder-pool counters (all zeros when the pool is disabled).
    pub pool: PoolStats,
    /// Host-sketch-store counters (all zeros when the store is disabled): hits are
    /// whole host-set encodes skipped, incremental updates are resident sketches
    /// maintained through `replace_set` churn by §4 streaming diffs.
    pub sketch_store: SketchStoreStats,
    /// Currently admitted, unfinished sessions (the live admission gauge).
    pub inflight: usize,
    /// High-water mark of concurrently admitted sessions.
    pub peak_inflight: usize,
    /// High-water mark of concurrently busy workers (≤ configured `workers`).
    pub peak_workers: usize,
    /// Configured worker count.
    pub workers: usize,
    /// Configured admission cap.
    pub max_inflight_sessions: usize,
}

impl ServerStats {
    /// Total conversation bytes across phases (successful sessions).
    pub fn total_bytes(&self) -> u64 {
        self.phase_bytes.iter().sum()
    }

    /// Decoder-pool hit rate (0.0 when the pool was never consulted or is disabled).
    pub fn pool_hit_rate(&self) -> f64 {
        self.pool.hit_rate()
    }

    /// Host-sketch-store hit rate (0.0 when the store was never consulted or disabled).
    pub fn sketch_store_hit_rate(&self) -> f64 {
        self.sketch_store.hit_rate()
    }

    /// One flat JSON record (the schema style of the `BENCH_*.json` trajectory): every
    /// field numeric, keys stable, no nesting — ready to append to a log or paste into
    /// the bench tooling.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"sessions_accepted\":{},\"sessions_served\":{},\"sessions_failed\":{},\
             \"sessions_rejected\":{},\"bytes_handshake\":{},\"bytes_sketch\":{},\
             \"bytes_residue\":{},\"bytes_confirm\":{},\"pool_hits\":{},\"pool_misses\":{},\
             \"pool_evictions\":{},\"pool_parked\":{},\"pool_capacity\":{},\
             \"pool_hit_rate\":{:.4},\"store_hits\":{},\"store_misses\":{},\
             \"store_stale_bypasses\":{},\"store_encodes\":{},\
             \"store_incremental_updates\":{},\"store_full_rebuilds\":{},\
             \"store_resident\":{},\"store_capacity\":{},\"store_hit_rate\":{:.4},\
             \"inflight\":{},\"peak_inflight\":{},\
             \"peak_workers\":{},\"workers\":{},\"max_inflight_sessions\":{}}}",
            self.sessions_accepted,
            self.sessions_served,
            self.sessions_failed,
            self.sessions_rejected,
            self.phase_bytes[0],
            self.phase_bytes[1],
            self.phase_bytes[2],
            self.phase_bytes[3],
            self.pool.hits,
            self.pool.misses,
            self.pool.evictions,
            self.pool.parked,
            self.pool.capacity,
            self.pool_hit_rate(),
            self.sketch_store.hits,
            self.sketch_store.misses,
            self.sketch_store.stale_bypasses,
            self.sketch_store.encodes,
            self.sketch_store.incremental_updates,
            self.sketch_store.full_rebuilds,
            self.sketch_store.resident,
            self.sketch_store.capacity,
            self.sketch_store_hit_rate(),
            self.inflight,
            self.peak_inflight,
            self.peak_workers,
            self.workers,
            self.max_inflight_sessions,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_comm_buckets_by_phase() {
        let inner = StatsInner::default();
        let mut comm = CommLog::new();
        comm.record(true, Phase::Handshake, 10);
        comm.record(false, Phase::Sketch, 100);
        comm.record(true, Phase::Residue, 40);
        comm.record(false, Phase::Residue, 5);
        comm.record(true, Phase::Confirm, 3);
        inner.charge_comm(&comm);
        let got: Vec<u64> =
            inner.phase_bytes.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        assert_eq!(got, vec![10, 100, 45, 3]);
    }

    #[test]
    fn stats_json_is_flat_and_complete() {
        let stats = ServerStats {
            sessions_accepted: 34,
            sessions_served: 32,
            sessions_failed: 1,
            sessions_rejected: 1,
            phase_bytes: [1, 2, 3, 4],
            pool: PoolStats { hits: 30, misses: 2, evictions: 0, parked: 2, capacity: 8 },
            sketch_store: SketchStoreStats {
                hits: 28,
                misses: 2,
                stale_bypasses: 2,
                encodes: 4,
                incremental_updates: 3,
                full_rebuilds: 1,
                resident: 2,
                capacity: 8,
            },
            inflight: 1,
            peak_inflight: 5,
            peak_workers: 4,
            workers: 4,
            max_inflight_sessions: 64,
        };
        let json = stats.to_json();
        assert!(json.starts_with('{') && json.ends_with('}'));
        for key in [
            "sessions_accepted",
            "sessions_served",
            "sessions_failed",
            "sessions_rejected",
            "bytes_handshake",
            "bytes_sketch",
            "bytes_residue",
            "bytes_confirm",
            "pool_hits",
            "pool_misses",
            "pool_hit_rate",
            "store_hits",
            "store_misses",
            "store_incremental_updates",
            "store_full_rebuilds",
            "store_hit_rate",
            "inflight",
            "peak_inflight",
            "peak_workers",
            "max_inflight_sessions",
        ] {
            assert!(json.contains(&format!("\"{key}\":")), "missing {key} in {json}");
        }
        assert_eq!(stats.total_bytes(), 10);
        assert!((stats.pool_hit_rate() - 30.0 / 32.0).abs() < 1e-12);
        assert!((stats.sketch_store_hit_rate() - 28.0 / 32.0).abs() < 1e-12);
    }
}
