//! A verifying load generator for [`super::SetxServer`]: N concurrent clients, each with
//! its own perturbation of its tenant's host set, each *asserting* the intersection it
//! gets back.
//!
//! The workload is the one-server-many-clients shape of the paper's deployment stories:
//! every client shares a large common core with its tenant's host set, holds
//! `client_unique` elements of its own, and is missing the tenant's `server_unique`
//! elements — so the true difference size is `client_unique + server_unique` for every
//! client, and (with the default explicit-d config) every session negotiates the **same
//! matrix geometry**, which is precisely the regime the shared [`super::DecoderPool`]
//! exists for. With `tenants > 1` the id space is partitioned into per-tenant blocks
//! (client *i* belongs to tenant *i mod tenants*), so a mixed fleet exercises the
//! namespace-sharded server. Each client runs `rounds` back-to-back syncs (the
//! steady-state delta-sync pattern) through [`Setx::run_with_retry_observed`] under the
//! shared [`RetryPolicy`]: any [transient](SetxError::is_transient) failure — a
//! [`SetxError::ServerBusy`] rejection, a dropped connection — is retried under capped
//! exponential back-off with deterministic, seeded per-client jitter (byte-identical to
//! the schedule this module historically owned).
//!
//! `disconnect_rate` turns the fleet into a chaos harness: each attempt flips a seeded
//! coin and, when faulty, runs over a [`FaultPlan`] that drops the connection on an
//! early frame — so retry convergence (and its byte cost) shows up in the report
//! instead of requiring a flaky network.
//!
//! Every returned intersection is compared against the exactly-known answer (the
//! tenant's common core): the generator is a correctness harness first and a throughput
//! meter second. It backs the `commonsense loadgen` CLI and the `server_throughput`
//! bench.

use crate::data::synth;
use crate::hash::{split_mix64, Xoshiro256};
use crate::obs::hist::LogHistogram;
use crate::setx::transport::{FaultInjector, FaultKind, FaultPlan, TcpTransport};
use crate::setx::{DiffSize, RetryPolicy, Setx, SetxError};
use std::net::ToSocketAddrs;
use std::time::{Duration, Instant};

/// Workload + fleet shape. `Default` is the CLI default: 8 clients × 2 rounds over a
/// 20 000-element core with 100 client-unique / 200 server-unique elements, one tenant.
#[derive(Clone, Copy, Debug)]
pub struct LoadgenConfig {
    /// Concurrent client threads.
    pub clients: usize,
    /// Sequential syncs per client (≥ 2 exercises client-side decoder reuse too).
    pub rounds: usize,
    /// `|client ∩ tenant host set|` — the shared core, per tenant.
    pub common: usize,
    /// Unique elements per client (disjoint across clients and tenants).
    pub client_unique: usize,
    /// Host-set elements no client holds, per tenant.
    pub server_unique: usize,
    /// Workload id seed (set contents) — also used as the protocol seed and the
    /// retry-jitter seed.
    pub seed: u64,
    /// Retries after a transient failure (`Busy` rejection, dropped connection) before
    /// counting the session as failed.
    pub busy_retries: usize,
    /// Probability (0.0–1.0) that any individual attempt's connection is dropped on an
    /// early frame by an injected [`FaultPlan`]. The coin is seeded per
    /// `(client, round, attempt)`, so a given fleet's fault schedule reproduces
    /// exactly. 0.0 (the default) injects nothing.
    pub disconnect_rate: f64,
    /// Estimate `d` in the handshake instead of declaring it. The default (`false`)
    /// declares the exactly-known `d = client_unique + server_unique`, which keeps every
    /// session on one shared matrix geometry — the decoder-pool sweet spot. Estimation
    /// adds per-client estimator noise, so geometries (and pool efficiency) vary.
    pub estimate_diff: bool,
    /// Tenant namespaces to spread the fleet across (clamped ≥ 1). Tenant ids are
    /// `0..tenants`; client *i* syncs against tenant *i mod tenants*.
    pub tenants: usize,
    /// Build every endpoint with the span timeline on (the default). Deliberately
    /// outside the config fingerprint, so a tracing-off fleet still speaks to a
    /// tracing-on server — the bench ablation flips only this.
    pub tracing: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            clients: 8,
            rounds: 2,
            common: 20_000,
            client_unique: 100,
            server_unique: 200,
            seed: 42,
            busy_retries: 3,
            disconnect_rate: 0.0,
            estimate_diff: false,
            tenants: 1,
            tracing: true,
        }
    }
}

impl LoadgenConfig {
    /// The exactly-known per-client difference size.
    pub fn true_d(&self) -> usize {
        self.client_unique + self.server_unique
    }

    /// Deterministic disjoint id pools, partitioned by tenant:
    /// `(per-tenant host sets, per-client sets, per-tenant expected intersections)`.
    /// Tenant `t`'s expected intersection (its common core, sorted) is what every
    /// client `i` with `i % tenants == t` must get back. All pools are mutually
    /// disjoint — across tenants and across clients.
    pub fn tenant_workload(&self) -> (Vec<Vec<u64>>, Vec<Vec<u64>>, Vec<Vec<u64>>) {
        let tenants = self.tenants.max(1);
        let mut rng = Xoshiro256::seed_from_u64(self.seed);
        let per_tenant = self.common + self.server_unique;
        let total = tenants * per_tenant + self.clients * self.client_unique;
        let ids = synth::distinct_ids(total, &mut rng);
        let mut hosts = Vec::with_capacity(tenants);
        let mut expected = Vec::with_capacity(tenants);
        for t in 0..tenants {
            let base = t * per_tenant;
            let common = &ids[base..base + self.common];
            let mut host = common.to_vec();
            host.extend_from_slice(&ids[base + self.common..base + per_tenant]);
            hosts.push(host);
            let mut exp = common.to_vec();
            exp.sort_unstable();
            expected.push(exp);
        }
        let mut clients = Vec::with_capacity(self.clients);
        for i in 0..self.clients {
            let base = (i % tenants) * per_tenant;
            let start = tenants * per_tenant + i * self.client_unique;
            let mut set = ids[base..base + self.common].to_vec();
            set.extend_from_slice(&ids[start..start + self.client_unique]);
            clients.push(set);
        }
        (hosts, clients, expected)
    }

    /// The single-tenant projection of [`tenant_workload`](Self::tenant_workload):
    /// `(host set, per-client sets, common core)` — the pre-tenancy shape, kept for
    /// callers that serve one set (its id layout is unchanged, so seeded workloads
    /// reproduce across versions).
    pub fn workload(&self) -> (Vec<u64>, Vec<Vec<u64>>, Vec<u64>) {
        let single = LoadgenConfig { tenants: 1, ..*self };
        let (mut hosts, clients, mut expected) = single.tenant_workload();
        (hosts.remove(0), clients, expected.remove(0))
    }

    /// The `Setx` endpoint this workload runs under for one tenant — used for the
    /// **host** sets by `commonsense serve` and for every client here, so the config
    /// fingerprints match (the namespace is deliberately outside the fingerprint).
    pub fn endpoint_for_tenant(
        &self,
        set: &[u64],
        namespace: u32,
    ) -> Result<Setx, SetxError> {
        let diff = if self.estimate_diff {
            DiffSize::Estimated
        } else {
            DiffSize::Explicit(self.true_d())
        };
        Setx::builder(set)
            .seed(self.seed)
            .diff_size(diff)
            .namespace(namespace)
            .tracing(self.tracing)
            .build()
    }

    /// [`endpoint_for_tenant`](Self::endpoint_for_tenant) for tenant 0 (the
    /// pre-tenancy API).
    pub fn endpoint(&self, set: &[u64]) -> Result<Setx, SetxError> {
        self.endpoint_for_tenant(set, 0)
    }

    /// The fleet's shared retry policy. With `client_key = client index`, its
    /// [`RetryPolicy::backoff_ms`] schedule is byte-identical to the capped
    /// exponential back-off this module computed inline before the policy existed —
    /// seeded workloads reproduce their exact wait sequence across versions.
    pub fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy {
            max_retries: u32::try_from(self.busy_retries).unwrap_or(u32::MAX),
            base_ms: 10,
            cap_ms: 2_000,
            jitter_seed: self.seed,
        }
    }
}

/// What the fleet did. `verified` is the headline: every session's intersection equaled
/// the exactly-known answer for its tenant.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    /// Sessions that completed with the correct intersection.
    pub sessions_ok: usize,
    /// Sessions that failed (transport/protocol error, retry exhaustion) or returned a
    /// *wrong* intersection (also described in `failures`).
    pub sessions_failed: usize,
    /// `Busy` rejections observed (including ones later resolved by a retry).
    pub busy_rejections: usize,
    /// Back-off retries actually performed, busy-pushback and fault retries alike (a
    /// failure past the retry budget is counted in `gave_up` but not here).
    pub retries: usize,
    /// Sessions that exhausted the retry budget on a transient error — the retryable
    /// slice of `sessions_failed` (fatal errors and wrong answers are the rest).
    pub gave_up: usize,
    /// Human-readable description of every failure, `client=<i> round=<r>: <why>`.
    pub failures: Vec<String>,
    /// Client-observed conversation bytes, all sessions.
    pub total_bytes: usize,
    /// Wall-clock for the whole fleet.
    pub elapsed: Duration,
    /// Per-session wall time of every *successful* sync (connect through verified
    /// report, retries included), in nanoseconds — merged across the client threads.
    pub latency: LogHistogram,
}

impl LoadgenReport {
    /// Every session completed and every intersection matched the reference.
    pub fn verified(&self) -> bool {
        self.sessions_failed == 0 && self.failures.is_empty()
    }

    /// Completed sessions per wall-clock second.
    pub fn sessions_per_sec(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs > 0.0 {
            self.sessions_ok as f64 / secs
        } else {
            0.0
        }
    }

    /// Median per-session wall time, nanoseconds (0 when no session succeeded).
    pub fn p50_ns(&self) -> u64 {
        self.latency.quantile(0.5)
    }

    /// 95th-percentile per-session wall time, nanoseconds.
    pub fn p95_ns(&self) -> u64 {
        self.latency.quantile(0.95)
    }

    /// 99th-percentile per-session wall time, nanoseconds.
    pub fn p99_ns(&self) -> u64 {
        self.latency.quantile(0.99)
    }
}

/// Run the fleet against a listening server (typically a [`super::SetxServer`] — but any
/// endpoint speaking the protocol works). Spawns `cfg.clients` OS threads; blocks until
/// every client finishes all its rounds. With `cfg.tenants > 1` the server must have
/// tenants `0..tenants` resident (e.g. via [`super::ServerHandle::add_tenant`]).
pub fn run(addr: impl ToSocketAddrs, cfg: &LoadgenConfig) -> LoadgenReport {
    if cfg.clients == 0 || cfg.rounds == 0 {
        // A zero-session fleet must not vacuously report `verified()`.
        return LoadgenReport {
            failures: vec!["degenerate fleet: clients and rounds must be ≥ 1".to_string()],
            ..LoadgenReport::default()
        };
    }
    let addr = match addr.to_socket_addrs().ok().and_then(|mut a| a.next()) {
        Some(a) => a,
        None => {
            return LoadgenReport {
                sessions_failed: cfg.clients * cfg.rounds,
                failures: vec!["unresolvable server address".to_string()],
                ..LoadgenReport::default()
            }
        }
    };
    let tenants = cfg.tenants.max(1);
    let (_hosts, client_sets, expected) = cfg.tenant_workload();
    let started = Instant::now();
    let outcomes: Vec<ClientOutcome> = std::thread::scope(|scope| {
        let expected = &expected;
        let handles: Vec<_> = client_sets
            .iter()
            .enumerate()
            .map(|(i, set)| {
                let exp = &expected[i % tenants];
                let ns = (i % tenants) as u32;
                scope.spawn(move || run_client(addr, cfg, i, ns, set, exp))
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("loadgen client thread")).collect()
    });
    let mut report = LoadgenReport { elapsed: started.elapsed(), ..LoadgenReport::default() };
    for outcome in outcomes {
        report.sessions_ok += outcome.ok;
        report.sessions_failed += outcome.failed;
        report.busy_rejections += outcome.busy;
        report.retries += outcome.retries;
        report.gave_up += outcome.gave_up;
        report.total_bytes += outcome.bytes;
        report.failures.extend(outcome.failures);
        report.latency.merge(&outcome.latency);
    }
    report
}

#[derive(Default)]
struct ClientOutcome {
    ok: usize,
    failed: usize,
    busy: usize,
    retries: usize,
    gave_up: usize,
    bytes: usize,
    failures: Vec<String>,
    latency: LogHistogram,
}

fn run_client(
    addr: std::net::SocketAddr,
    cfg: &LoadgenConfig,
    index: usize,
    namespace: u32,
    set: &[u64],
    expected: &[u64],
) -> ClientOutcome {
    let mut out = ClientOutcome::default();
    let endpoint = match cfg.endpoint_for_tenant(set, namespace) {
        Ok(e) => e,
        Err(e) => {
            out.failed = cfg.rounds;
            out.failures.push(format!("client={index}: invalid config: {e}"));
            return out;
        }
    };
    for round in 0..cfg.rounds {
        let session_started = Instant::now();
        match sync_once(addr, cfg, &endpoint, index, round, &mut out) {
            Ok(report) => {
                let elapsed = session_started.elapsed();
                out.latency.record(u64::try_from(elapsed.as_nanos()).unwrap_or(u64::MAX));
                out.bytes += report.total_bytes();
                if report.intersection == expected {
                    out.ok += 1;
                } else {
                    out.failed += 1;
                    out.failures.push(format!(
                        "client={index} round={round}: WRONG intersection ({} elements, {} expected)",
                        report.intersection.len(),
                        expected.len()
                    ));
                }
            }
            Err(e) => {
                out.failed += 1;
                out.failures.push(format!("client={index} round={round}: {e}"));
            }
        }
    }
    out
}

/// One sync through [`Setx::run_with_retry_observed`] under
/// [`LoadgenConfig::retry_policy`]: the k-th retry waits `hint·2^(k−1)` milliseconds
/// (hint floored at 10 ms, wait capped at 2 s) plus a deterministic per-client jitter
/// hashed from `(client, attempt, seed)` — so a rejected burst neither re-arrives as a
/// burst nor synchronizes across runs, and a given fleet's retry schedule is exactly
/// reproducible from its seed. Each attempt's transport goes through
/// [`fault_injector`], which is a no-op plan unless the `disconnect_rate` coin fires.
fn sync_once(
    addr: std::net::SocketAddr,
    cfg: &LoadgenConfig,
    endpoint: &Setx,
    index: usize,
    round: usize,
    out: &mut ClientOutcome,
) -> Result<crate::setx::SetxReport, SetxError> {
    let policy = cfg.retry_policy();
    let mut busy = 0usize;
    let mut retries = 0usize;
    let result = endpoint.run_with_retry_observed(
        &policy,
        index as u64,
        |attempt| {
            let transport = TcpTransport::connect(addr)?;
            Ok(fault_injector(cfg, index, round, attempt).wrap(transport))
        },
        |err, _backoff_ms| {
            retries += 1;
            if matches!(err, SetxError::ServerBusy { .. }) {
                busy += 1;
            }
        },
    );
    out.retries += retries;
    out.busy += busy;
    if let Err(err) = &result {
        // The final, budget-exhausting rejection is still a rejection the fleet saw.
        if matches!(err, SetxError::ServerBusy { .. }) {
            out.busy += 1;
        }
        if err.is_transient() {
            out.gave_up += 1;
        }
    }
    result
}

/// The per-attempt fault coin: hashes `(fleet seed, client, round, attempt)` and, with
/// probability `disconnect_rate`, returns an injector that drops the connection on one
/// of the first three frames (covering both send- and recv-side drops). A clean
/// attempt gets an empty plan — every attempt is wrapped so the connect closure has a
/// single transport type either way.
fn fault_injector(
    cfg: &LoadgenConfig,
    index: usize,
    round: usize,
    attempt: u32,
) -> FaultInjector {
    let mut plan = FaultPlan::new(cfg.seed ^ (index as u64) ^ (round as u64));
    if let Some(nth) = fault_coin(cfg, index, round, attempt) {
        plan = plan.fail_nth(FaultKind::DropConnection, None, nth);
    }
    plan.injector()
}

/// The coin itself: `Some(nth frame to drop on)` with probability `disconnect_rate`,
/// `None` for a clean attempt. Pure in its arguments.
fn fault_coin(cfg: &LoadgenConfig, index: usize, round: usize, attempt: u32) -> Option<u32> {
    let h = split_mix64(
        split_mix64(cfg.seed ^ 0xD15C_0881)
            ^ (index as u64)
            ^ ((round as u64) << 20)
            ^ (u64::from(attempt) << 40),
    );
    let coin = (h >> 11) as f64 / (1u64 << 53) as f64;
    (coin < cfg.disconnect_rate).then(|| 1 + (h % 3) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_cardinalities_and_disjointness() {
        let cfg = LoadgenConfig {
            clients: 3,
            common: 500,
            client_unique: 20,
            server_unique: 30,
            ..LoadgenConfig::default()
        };
        let (host, clients, expected) = cfg.workload();
        assert_eq!(host.len(), 530);
        assert_eq!(clients.len(), 3);
        assert_eq!(expected.len(), 500);
        for (i, c) in clients.iter().enumerate() {
            assert_eq!(c.len(), 520);
            // Every client's intersection with the host is exactly the core.
            assert_eq!(synth::intersect(c, &host), expected, "client {i}");
            assert_eq!(synth::difference(c, &host).len(), 20);
        }
        // Client-unique pools are disjoint across clients.
        let u0 = synth::difference(&clients[0], &host);
        let u1 = synth::difference(&clients[1], &host);
        assert!(synth::intersect(&u0, &u1).is_empty());
        assert_eq!(cfg.true_d(), 50);
    }

    #[test]
    fn tenant_workload_partitions_are_disjoint() {
        let cfg = LoadgenConfig {
            clients: 5,
            tenants: 2,
            common: 300,
            client_unique: 10,
            server_unique: 20,
            ..LoadgenConfig::default()
        };
        let (hosts, clients, expected) = cfg.tenant_workload();
        assert_eq!(hosts.len(), 2);
        assert_eq!(expected.len(), 2);
        assert_eq!(clients.len(), 5);
        // Tenant pools never overlap.
        assert!(synth::intersect(&hosts[0], &hosts[1]).is_empty());
        for (i, c) in clients.iter().enumerate() {
            let t = i % 2;
            assert_eq!(c.len(), 310);
            assert_eq!(synth::intersect(c, &hosts[t]), expected[t], "client {i}");
            assert_eq!(synth::difference(c, &hosts[t]).len(), 10);
            // A client shares nothing with the *other* tenant's host set.
            assert!(synth::intersect(c, &hosts[1 - t]).is_empty(), "client {i}");
        }
        // The single-tenant projection is exactly the legacy layout.
        let single = LoadgenConfig { tenants: 1, ..cfg };
        let (host, lc, exp) = single.workload();
        let (th, tc, te) = single.tenant_workload();
        assert_eq!(host, th[0]);
        assert_eq!(lc, tc);
        assert_eq!(exp, te[0]);
    }

    #[test]
    fn retry_policy_matches_the_historical_inline_schedule() {
        let cfg = LoadgenConfig { busy_retries: 6, seed: 99, ..LoadgenConfig::default() };
        let p = cfg.retry_policy();
        assert_eq!(p.max_retries, 6);
        assert_eq!(p.jitter_seed, 99);
        // The formula this module used to compute inline, byte for byte.
        let (index, attempt, hint) = (4u64, 3u32, 25u32);
        let base = u64::from(hint).max(10);
        let backoff = base.saturating_mul(1u64 << (attempt - 1).min(6)).min(2_000);
        let jitter = split_mix64(index ^ (u64::from(attempt) << 32) ^ 99) % (base / 2 + 1);
        assert_eq!(p.backoff_ms(index, attempt, hint), backoff + jitter);
    }

    #[test]
    fn fault_coin_is_deterministic_and_respects_the_rate() {
        let off = LoadgenConfig::default();
        let always = LoadgenConfig { disconnect_rate: 1.0, ..LoadgenConfig::default() };
        for index in 0..8 {
            for round in 0..4 {
                for attempt in 0..3 {
                    assert_eq!(fault_coin(&off, index, round, attempt), None);
                    let nth = fault_coin(&always, index, round, attempt);
                    assert!(matches!(nth, Some(1..=3)), "nth = {nth:?}");
                    // Seeded: the same (fleet, client, round, attempt) re-flips the
                    // same coin.
                    assert_eq!(nth, fault_coin(&always, index, round, attempt));
                }
            }
        }
        // A mid-range rate lands strictly between the extremes.
        let mixed = LoadgenConfig { disconnect_rate: 0.3, ..LoadgenConfig::default() };
        let fired = (0..200)
            .filter(|&i| fault_coin(&mixed, i, 0, 0).is_some())
            .count();
        assert!(fired > 20 && fired < 140, "fired = {fired}");
    }

    #[test]
    fn endpoints_share_a_fingerprint() {
        let cfg = LoadgenConfig { common: 200, ..LoadgenConfig::default() };
        let (host, clients, _) = cfg.workload();
        let server = cfg.endpoint(&host).unwrap();
        let client = cfg.endpoint(&clients[0]).unwrap();
        assert_eq!(server.config().fingerprint(), client.config().fingerprint());
        // Namespaces route, they don't re-shape the protocol: a tenant-3 client still
        // fingerprint-matches a tenant-0 server endpoint.
        let t3 = cfg.endpoint_for_tenant(&clients[0], 3).unwrap();
        assert_eq!(server.config().fingerprint(), t3.config().fingerprint());
        assert_eq!(t3.config().namespace(), 3);
    }
}
