//! A verifying load generator for [`super::SetxServer`]: N concurrent clients, each with
//! its own perturbation of the host set, each *asserting* the intersection it gets back.
//!
//! The workload is the one-server-many-clients shape of the paper's deployment stories:
//! every client shares a large common core with the host set, holds `client_unique`
//! elements of its own, and is missing the server's `server_unique` elements — so the
//! true difference size is `client_unique + server_unique` for every client, and (with
//! the default explicit-d config) every session negotiates the **same matrix geometry**,
//! which is precisely the regime the shared [`super::DecoderPool`] exists for. Each
//! client runs `rounds` back-to-back syncs (the steady-state delta-sync pattern), and a
//! [`SetxError::ServerBusy`] answer is retried with the server's back-off hint.
//!
//! Every returned intersection is compared against the exactly-known answer (the common
//! core): the generator is a correctness harness first and a throughput meter second.
//! It backs the `commonsense loadgen` CLI and the `server_throughput` bench.

use crate::data::synth;
use crate::hash::Xoshiro256;
use crate::setx::transport::TcpTransport;
use crate::setx::{DiffSize, Setx, SetxError};
use std::net::ToSocketAddrs;
use std::time::{Duration, Instant};

/// Workload + fleet shape. `Default` is the CLI default: 8 clients × 2 rounds over a
/// 20 000-element core with 100 client-unique / 200 server-unique elements.
#[derive(Clone, Copy, Debug)]
pub struct LoadgenConfig {
    /// Concurrent client threads.
    pub clients: usize,
    /// Sequential syncs per client (≥ 2 exercises client-side decoder reuse too).
    pub rounds: usize,
    /// `|client ∩ server|` — the shared core.
    pub common: usize,
    /// Unique elements per client (disjoint across clients).
    pub client_unique: usize,
    /// Host-set elements no client holds.
    pub server_unique: usize,
    /// Workload id seed (set contents) — also used as the protocol seed.
    pub seed: u64,
    /// Retries after a `Busy` rejection before counting the session as failed.
    pub busy_retries: usize,
    /// Estimate `d` in the handshake instead of declaring it. The default (`false`)
    /// declares the exactly-known `d = client_unique + server_unique`, which keeps every
    /// session on one shared matrix geometry — the decoder-pool sweet spot. Estimation
    /// adds per-client estimator noise, so geometries (and pool efficiency) vary.
    pub estimate_diff: bool,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            clients: 8,
            rounds: 2,
            common: 20_000,
            client_unique: 100,
            server_unique: 200,
            seed: 42,
            busy_retries: 3,
            estimate_diff: false,
        }
    }
}

impl LoadgenConfig {
    /// The exactly-known per-client difference size.
    pub fn true_d(&self) -> usize {
        self.client_unique + self.server_unique
    }

    /// Deterministic disjoint id pools: `(host set, per-client sets, common core)`.
    /// The core is returned sorted — it *is* every client's expected intersection.
    pub fn workload(&self) -> (Vec<u64>, Vec<Vec<u64>>, Vec<u64>) {
        let mut rng = Xoshiro256::seed_from_u64(self.seed);
        let total = self.common + self.server_unique + self.clients * self.client_unique;
        let ids = synth::distinct_ids(total, &mut rng);
        let common = &ids[..self.common];
        let server_only = &ids[self.common..self.common + self.server_unique];
        let mut host = common.to_vec();
        host.extend_from_slice(server_only);
        let mut clients = Vec::with_capacity(self.clients);
        for i in 0..self.clients {
            let start = self.common + self.server_unique + i * self.client_unique;
            let mut set = common.to_vec();
            set.extend_from_slice(&ids[start..start + self.client_unique]);
            clients.push(set);
        }
        let mut expected = common.to_vec();
        expected.sort_unstable();
        (host, clients, expected)
    }

    /// The `Setx` endpoint this workload runs under — used for the **host** set by
    /// `commonsense serve` and for every client here, so the config fingerprints match.
    pub fn endpoint(&self, set: &[u64]) -> Result<Setx, SetxError> {
        let diff = if self.estimate_diff {
            DiffSize::Estimated
        } else {
            DiffSize::Explicit(self.true_d())
        };
        Setx::builder(set).seed(self.seed).diff_size(diff).build()
    }
}

/// What the fleet did. `verified` is the headline: every session's intersection equaled
/// the exactly-known answer.
#[derive(Clone, Debug, Default)]
pub struct LoadgenReport {
    /// Sessions that completed with the correct intersection.
    pub sessions_ok: usize,
    /// Sessions that failed (transport/protocol error, retry exhaustion) or returned a
    /// *wrong* intersection (also described in `failures`).
    pub sessions_failed: usize,
    /// `Busy` rejections observed (including ones later resolved by a retry).
    pub busy_rejections: usize,
    /// Human-readable description of every failure, `client=<i> round=<r>: <why>`.
    pub failures: Vec<String>,
    /// Client-observed conversation bytes, all sessions.
    pub total_bytes: usize,
    /// Wall-clock for the whole fleet.
    pub elapsed: Duration,
}

impl LoadgenReport {
    /// Every session completed and every intersection matched the reference.
    pub fn verified(&self) -> bool {
        self.sessions_failed == 0 && self.failures.is_empty()
    }

    /// Completed sessions per wall-clock second.
    pub fn sessions_per_sec(&self) -> f64 {
        let secs = self.elapsed.as_secs_f64();
        if secs > 0.0 {
            self.sessions_ok as f64 / secs
        } else {
            0.0
        }
    }
}

/// Run the fleet against a listening server (typically a [`super::SetxServer`] — but any
/// endpoint speaking the protocol works). Spawns `cfg.clients` OS threads; blocks until
/// every client finishes all its rounds.
pub fn run(addr: impl ToSocketAddrs, cfg: &LoadgenConfig) -> LoadgenReport {
    if cfg.clients == 0 || cfg.rounds == 0 {
        // A zero-session fleet must not vacuously report `verified()`.
        return LoadgenReport {
            failures: vec!["degenerate fleet: clients and rounds must be ≥ 1".to_string()],
            ..LoadgenReport::default()
        };
    }
    let addr = match addr.to_socket_addrs().ok().and_then(|mut a| a.next()) {
        Some(a) => a,
        None => {
            return LoadgenReport {
                sessions_failed: cfg.clients * cfg.rounds,
                failures: vec!["unresolvable server address".to_string()],
                ..LoadgenReport::default()
            }
        }
    };
    let (_host, client_sets, expected) = cfg.workload();
    let started = Instant::now();
    let outcomes: Vec<ClientOutcome> = std::thread::scope(|scope| {
        let expected = &expected;
        let handles: Vec<_> = client_sets
            .iter()
            .enumerate()
            .map(|(i, set)| scope.spawn(move || run_client(addr, cfg, i, set, expected)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("loadgen client thread")).collect()
    });
    let mut report = LoadgenReport { elapsed: started.elapsed(), ..LoadgenReport::default() };
    for outcome in outcomes {
        report.sessions_ok += outcome.ok;
        report.sessions_failed += outcome.failed;
        report.busy_rejections += outcome.busy;
        report.total_bytes += outcome.bytes;
        report.failures.extend(outcome.failures);
    }
    report
}

#[derive(Default)]
struct ClientOutcome {
    ok: usize,
    failed: usize,
    busy: usize,
    bytes: usize,
    failures: Vec<String>,
}

fn run_client(
    addr: std::net::SocketAddr,
    cfg: &LoadgenConfig,
    index: usize,
    set: &[u64],
    expected: &[u64],
) -> ClientOutcome {
    let mut out = ClientOutcome::default();
    let endpoint = match cfg.endpoint(set) {
        Ok(e) => e,
        Err(e) => {
            out.failed = cfg.rounds;
            out.failures.push(format!("client={index}: invalid config: {e}"));
            return out;
        }
    };
    for round in 0..cfg.rounds {
        match sync_once(addr, cfg, &endpoint, index, &mut out) {
            Ok(report) => {
                out.bytes += report.total_bytes();
                if report.intersection == expected {
                    out.ok += 1;
                } else {
                    out.failed += 1;
                    out.failures.push(format!(
                        "client={index} round={round}: WRONG intersection ({} elements, {} expected)",
                        report.intersection.len(),
                        expected.len()
                    ));
                }
            }
            Err(e) => {
                out.failed += 1;
                out.failures.push(format!("client={index} round={round}: {e}"));
            }
        }
    }
    out
}

/// One sync, retrying admission rejections with the server's back-off hint (plus a
/// deterministic per-client jitter so a rejected burst does not re-arrive as a burst).
fn sync_once(
    addr: std::net::SocketAddr,
    cfg: &LoadgenConfig,
    endpoint: &Setx,
    index: usize,
    out: &mut ClientOutcome,
) -> Result<crate::setx::SetxReport, SetxError> {
    let mut attempt = 0;
    loop {
        let mut transport = TcpTransport::connect(addr)?;
        match endpoint.run(&mut transport) {
            Err(SetxError::ServerBusy { retry_after_ms }) => {
                out.busy += 1;
                attempt += 1;
                if attempt > cfg.busy_retries {
                    return Err(SetxError::ServerBusy { retry_after_ms });
                }
                let jitter = (index as u64 % 7) * 3;
                std::thread::sleep(Duration::from_millis(
                    u64::from(retry_after_ms).max(10) + jitter,
                ));
            }
            other => return other,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workload_cardinalities_and_disjointness() {
        let cfg = LoadgenConfig {
            clients: 3,
            common: 500,
            client_unique: 20,
            server_unique: 30,
            ..LoadgenConfig::default()
        };
        let (host, clients, expected) = cfg.workload();
        assert_eq!(host.len(), 530);
        assert_eq!(clients.len(), 3);
        assert_eq!(expected.len(), 500);
        for (i, c) in clients.iter().enumerate() {
            assert_eq!(c.len(), 520);
            // Every client's intersection with the host is exactly the core.
            assert_eq!(synth::intersect(c, &host), expected, "client {i}");
            assert_eq!(synth::difference(c, &host).len(), 20);
        }
        // Client-unique pools are disjoint across clients.
        let u0 = synth::difference(&clients[0], &host);
        let u1 = synth::difference(&clients[1], &host);
        assert!(synth::intersect(&u0, &u1).is_empty());
        assert_eq!(cfg.true_d(), 50);
    }

    #[test]
    fn endpoints_share_a_fingerprint() {
        let cfg = LoadgenConfig { common: 200, ..LoadgenConfig::default() };
        let (host, clients, _) = cfg.workload();
        let server = cfg.endpoint(&host).unwrap();
        let client = cfg.endpoint(&clients[0]).unwrap();
        assert_eq!(server.config().fingerprint(), client.config().fingerprint());
    }
}
