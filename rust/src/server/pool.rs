//! The shared decoder pool: the one-slot [`crate::decoder::DecoderCache`] generalized
//! into a concurrency-safe, capacity-bounded LRU pool keyed by exact matrix geometry.
//!
//! Decoder construction is the dominant per-session cost on the server side, and a
//! server answering thousands of clients against one hot set keeps negotiating the same
//! matrix geometry `(seed, l, m)` — so the decoders those sessions build are
//! interchangeable ([`MpDecoder::cache_key`] covers matrix + candidates + side, and the
//! host set is the candidate set of every responder decode). The pool parks finished
//! decoders and hands them back to whichever worker asks next:
//!
//! * **Keyed by exact geometry** — entries file under [`GeometryKey`] (the matrix
//!   structure fingerprint, a pure function of `(seed, l, m)` for the production
//!   matrix, plus the exact dimensions). A `take` additionally validates the full
//!   64-bit cache key, the same double check [`crate::decoder::DecoderCache`] performs,
//!   so a parked decoder for a *stale* host set (after
//!   [`crate::server::ServerHandle::replace_set`])
//!   or the opposite decode side can never be mistaken for a match — it is simply
//!   skipped and ages out by LRU.
//! * **A pool, not a map** — the same geometry may be parked multiple times, one per
//!   concurrently-finishing worker, so `workers` simultaneous sessions on one hot
//!   geometry all hit once warmed (a single-slot map would serve only one of them).
//! * **LRU-bounded** — `capacity` caps parked decoders (each holds O(n·m) CSR tables);
//!   inserting past it evicts the least-recently-parked entry. `capacity == 0` disables
//!   parking entirely (the pool-off ablation of the `server_throughput` bench).
//! * **Counted** — hits, misses, and evictions are exposed ([`PoolStats`]) and surface
//!   in [`crate::server::ServerStats`] as the pool hit rate.
//! * **Sharded per tenant** — the multi-tenant server gives every tenant namespace its
//!   own `DecoderPool` (sized by the builder's `pool_capacity`), so one tenant's churn
//!   or eviction pressure cannot flush a neighbour's warm decoders; the global
//!   `ServerStats` pool block is the sum over shards, with per-shard counters in each
//!   [`crate::server::TenantStats`].

use crate::decoder::{DecoderStore, GeometryKey, MpDecoder};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Counter snapshot of a [`DecoderPool`] (see [`DecoderPool::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// `take`s answered from the pool (a whole decoder construction skipped).
    pub hits: u64,
    /// `take`s that found no interchangeable decoder (the caller built fresh).
    pub misses: u64,
    /// Parked decoders discarded by the LRU capacity bound.
    pub evictions: u64,
    /// Decoders currently parked.
    pub parked: usize,
    /// The capacity bound (0 = pooling disabled).
    pub capacity: usize,
}

impl PoolStats {
    /// `hits / (hits + misses)`; 0.0 for a pool that was never consulted — so a
    /// disabled pool (the `--no-pool` ablation) reads as 0, never as a perfect score.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Parked {
    geo: GeometryKey,
    dec: MpDecoder,
}

/// The concurrency-safe LRU decoder pool (module docs). Share it as an
/// `Arc<DecoderPool>`: it implements [`DecoderStore`], so attaching it to a
/// [`DecoderCache`] via [`DecoderCache::with_shared_store`] makes every session built on
/// that cache pool-backed — which is exactly what [`crate::server::SetxServer`] does for
/// each worker connection.
///
/// [`DecoderCache`]: crate::decoder::DecoderCache
/// [`DecoderCache::with_shared_store`]: crate::decoder::DecoderCache::with_shared_store
pub struct DecoderPool {
    /// Parked decoders, least-recently-parked first (evict index 0).
    entries: Mutex<Vec<Parked>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl DecoderPool {
    /// An empty pool holding at most `capacity` parked decoders (`0` disables parking:
    /// every take misses and every put drops).
    pub fn new(capacity: usize) -> DecoderPool {
        DecoderPool {
            entries: Mutex::new(Vec::new()),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Counter + occupancy snapshot.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            parked: self.entries.lock().map(|e| e.len()).unwrap_or(0),
            capacity: self.capacity,
        }
    }
}

impl DecoderStore for DecoderPool {
    fn take(&self, geo: GeometryKey, want_key: u64) -> Option<MpDecoder> {
        let mut entries = self.entries.lock().expect("decoder pool poisoned");
        // Newest first: the most recently parked decoder is the most likely to be warm
        // in cache and the least likely to be stale.
        let found = entries
            .iter()
            .rposition(|p| p.geo == geo && p.dec.cache_key() == want_key);
        match found {
            Some(i) => {
                let parked = entries.remove(i);
                drop(entries);
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(parked.dec)
            }
            None => {
                drop(entries);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    fn put(&self, geo: GeometryKey, dec: MpDecoder) {
        if self.capacity == 0 {
            return;
        }
        let mut entries = self.entries.lock().expect("decoder pool poisoned");
        entries.push(Parked { geo, dec });
        let mut evicted = 0u64;
        while entries.len() > self.capacity {
            entries.remove(0);
            evicted += 1;
        }
        drop(entries);
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
    }
}

impl std::fmt::Debug for DecoderPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("DecoderPool")
            .field("parked", &s.parked)
            .field("capacity", &s.capacity)
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .field("evictions", &s.evictions)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decoder::{DecoderCache, DecoderConfig, Side};
    use crate::matrix::CsMatrix;
    use std::sync::Arc;

    fn mk_matrix(seed: u64) -> CsMatrix {
        CsMatrix::new(256, 4, seed)
    }

    fn mk_decoder(matrix: &CsMatrix, candidates: &[u64]) -> MpDecoder {
        MpDecoder::with_config(matrix, candidates, Side::Positive, DecoderConfig::commonsense())
    }

    #[test]
    fn take_validates_geometry_and_full_key() {
        let pool = DecoderPool::new(4);
        let matrix = mk_matrix(1);
        let cands: Vec<u64> = (0..100).collect();
        let dec = mk_decoder(&matrix, &cands);
        let geo = GeometryKey::of_decoder(&dec);
        let want = dec.cache_key();
        pool.put(geo, dec);

        // Wrong full key (different candidate set, same geometry): skipped, not returned.
        let other_want =
            MpDecoder::cache_key_for(&matrix, &(0..101).collect::<Vec<u64>>(), Side::Positive);
        assert!(pool.take(geo, other_want).is_none());
        // Wrong geometry: also a miss.
        let other_geo = GeometryKey::of_oracle(&mk_matrix(2));
        assert!(pool.take(other_geo, want).is_none());
        // Exact match: hit — and the entry leaves the pool.
        assert!(pool.take(geo, want).is_some());
        assert!(pool.take(geo, want).is_none());
        let s = pool.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 3);
    }

    #[test]
    fn lru_eviction_at_capacity() {
        let pool = DecoderPool::new(2);
        let cands: Vec<u64> = (0..50).collect();
        let matrices: Vec<CsMatrix> = (1..=3).map(mk_matrix).collect();
        let mut keys = Vec::new();
        for m in &matrices {
            let dec = mk_decoder(m, &cands);
            keys.push((GeometryKey::of_decoder(&dec), dec.cache_key()));
            pool.put(GeometryKey::of_decoder(&dec), dec);
        }
        assert_eq!(pool.stats().parked, 2);
        assert_eq!(pool.stats().evictions, 1);
        // The least-recently-parked entry (matrix 1) was evicted; 2 and 3 survive.
        assert!(pool.take(keys[0].0, keys[0].1).is_none(), "oldest must be evicted");
        assert!(pool.take(keys[1].0, keys[1].1).is_some());
        assert!(pool.take(keys[2].0, keys[2].1).is_some());
    }

    #[test]
    fn untouched_pool_reports_zero_hit_rate() {
        // The --no-pool ablation must never read as a perfect score.
        assert_eq!(DecoderPool::new(8).stats().hit_rate(), 0.0);
        assert_eq!(DecoderPool::new(0).stats().hit_rate(), 0.0);
    }

    #[test]
    fn zero_capacity_disables_parking() {
        let pool = DecoderPool::new(0);
        let matrix = mk_matrix(7);
        let cands: Vec<u64> = (0..50).collect();
        let dec = mk_decoder(&matrix, &cands);
        let geo = GeometryKey::of_decoder(&dec);
        let want = dec.cache_key();
        pool.put(geo, dec);
        assert_eq!(pool.stats().parked, 0);
        assert!(pool.take(geo, want).is_none());
    }

    #[test]
    fn same_geometry_parks_multiple_copies_for_concurrent_workers() {
        // A map keyed by geometry would keep one decoder and starve all but one of the
        // concurrently-running workers; the pool must hold several.
        let pool = DecoderPool::new(4);
        let matrix = mk_matrix(9);
        let cands: Vec<u64> = (0..80).collect();
        let (mut geo, mut want) = (None, 0);
        for _ in 0..3 {
            let dec = mk_decoder(&matrix, &cands);
            geo = Some(GeometryKey::of_decoder(&dec));
            want = dec.cache_key();
            pool.put(geo.unwrap(), dec);
        }
        let geo = geo.unwrap();
        assert_eq!(pool.stats().parked, 3);
        assert!(pool.take(geo, want).is_some());
        assert!(pool.take(geo, want).is_some());
        assert!(pool.take(geo, want).is_some());
        assert!(pool.take(geo, want).is_none());
    }

    #[test]
    fn concurrent_checkout_return_from_four_threads() {
        // ≥4 threads hammer checkout/return through the DecoderCache front (the way
        // server workers do). Invariants: no deadlock, counters account for every
        // checkout, and the pool never exceeds capacity.
        let pool = Arc::new(DecoderPool::new(8));
        let matrix = Arc::new(mk_matrix(11));
        let cands: Arc<Vec<u64>> = Arc::new((0..200).collect());
        let threads = 4;
        let iters = 25;
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let pool = Arc::clone(&pool);
                let matrix = Arc::clone(&matrix);
                let cands = Arc::clone(&cands);
                scope.spawn(move || {
                    let mut cache = DecoderCache::with_build_threads(1)
                        .with_shared_store(pool as Arc<dyn DecoderStore>);
                    for _ in 0..iters {
                        let dec = cache.checkout(
                            matrix.as_ref(),
                            &cands,
                            Side::Positive,
                            DecoderConfig::commonsense(),
                        );
                        cache.store(dec);
                    }
                });
            }
        });
        let s = pool.stats();
        assert_eq!(s.hits + s.misses, (threads * iters) as u64, "every checkout counted");
        // Once each thread has parked a decoder, subsequent checkouts hit; at most one
        // cold miss per thread (plus any races in the very first wave).
        assert!(s.hits >= (threads * (iters - 1)) as u64, "stats {s:?}");
        assert!(s.parked <= 8);
    }

    #[test]
    fn pooled_decode_is_result_identical_to_fresh_build() {
        // Extends PR 3's reuse-equals-fresh property to the shared pool: a uni decode
        // whose decoder came out of the pool must produce exactly the fresh-build answer.
        use crate::data::synth;
        use crate::protocol::{uni, CsParams};
        let (a, b) = synth::subset_pair(4_000, 60, 21);
        let params = CsParams::tuned_uni(b.len(), 60);
        let (msg, _) = uni::alice_encode(&a, &params);

        let fresh = uni::bob_decode(&msg, &b, &params).unwrap().0;
        let pool: Arc<DecoderPool> = Arc::new(DecoderPool::new(2));
        let mut cache =
            DecoderCache::new().with_shared_store(Arc::clone(&pool) as Arc<dyn DecoderStore>);
        let first = uni::bob_decode_cached(&msg, &b, &params, &mut cache).unwrap().0;
        assert_eq!(pool.stats().parked, 1, "decode must park its decoder in the pool");
        let second = uni::bob_decode_cached(&msg, &b, &params, &mut cache).unwrap().0;
        assert_eq!(first, fresh);
        assert_eq!(second, fresh, "pooled decoder must decode identically");
        assert!(pool.stats().hits >= 1, "second decode must hit the pool");
    }
}
