//! The shared host-sketch store: the encode-side sibling of [`super::DecoderPool`].
//!
//! Every session a [`crate::server::SetxServer`] serves needs `M·1_host` — the sketch of
//! the (unchanged) host set under the session's negotiated matrix — and before this store
//! existed each session re-encoded it from scratch: O(m·n) per connection and per
//! l-escalation rung, for a value that is a pure function of `(matrix, host set)`. The
//! store memoizes it:
//!
//! * **Keyed by exact geometry** — entries file under [`GeometryKey`] (matrix structure
//!   fingerprint + exact `(l, m)`), the same key discipline as the decoder pool. A fleet
//!   negotiating one hot geometry pays the encode **once**; every later session checks
//!   the sketch out in O(1) as a shared [`Arc<Sketch>`] clone.
//! * **Single-flight, off-lock** — a missing entry's encode runs *outside* the store
//!   lock under a per-geometry in-flight registry: a cold-start burst of same-geometry
//!   sessions performs exactly one encode (the rest wait on a condvar, then hit), while
//!   sessions negotiating *different* geometries encode concurrently instead of
//!   convoying on the mutex. Encodes use the store's [`EncodeConfig`]. Sketches longer
//!   than [`MAX_CACHED_L`] are served but never cached — a wire peer picks the attempt
//!   geometry, and parking a handful of adversarially-huge count vectors must not pin
//!   gigabytes after the connection dies.
//! * **Set-validated** — the store knows which host set its entries describe (the same
//!   `Arc<Vec<u64>>` snapshot the server hands each session). A session holding a
//!   *different* snapshot (it raced a [`SketchStore::replace_set`]) is detected by slice
//!   identity and answered with a fresh, uncached encode — never a stale sketch.
//! * **Incrementally maintained** — [`SketchStore::replace_set`] applies §4 streaming
//!   updates ([`Sketch::update`]) over the old/new per-id *multiplicity delta* to every
//!   resident sketch (O(m·|delta|) each; exact even for multiset inputs). When the
//!   delta outweighs the new set, entries are dropped and re-encoded on demand by the
//!   next checkout instead — maintenance runs under the store lock, and eager O(m·n)
//!   re-encodes there would stall every worker. Sharing is safe: updates go through
//!   [`Arc::make_mut`], so sessions still holding the pre-churn sketch keep their
//!   (correct, snapshot-consistent) copy untouched.
//! * **LRU-bounded and counted** — `capacity` caps resident sketches (each is O(l)
//!   i32s); hits/misses/encodes/incremental-update/rebuild counters surface in
//!   [`crate::server::ServerStats`] and the `server_throughput` bench's store ablation.
//! * **Sharded per tenant** — as with the decoder pool, the multi-tenant server gives
//!   every tenant namespace its own store over its own host set, so per-tenant
//!   `replace_tenant_set` churn maintains only that tenant's resident sketches; the
//!   global `ServerStats` store block is the sum over shards, with per-shard counters
//!   in each [`crate::server::TenantStats`].

use crate::decoder::GeometryKey;
use crate::matrix::CsMatrix;
use crate::sketch::{EncodeConfig, Sketch, SketchSource};
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Longest sketch (`l` coordinates) the store will keep resident. The attempt geometry
/// comes off the wire, so without a cap a malicious initiator could park
/// `capacity × 4·MAX_WIRE_L` bytes of counts that outlive its connections. Honest tuned
/// geometries sit far below this (l ≈ d·m·log(n/d)/7); an over-cap sketch is still
/// encoded and served — it just isn't cached.
pub const MAX_CACHED_L: usize = 1 << 22;

/// Counter snapshot of a [`SketchStore`] (see [`SketchStore::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct SketchStoreStats {
    /// Checkouts answered from a resident sketch (a whole host-set encode skipped).
    pub hits: u64,
    /// Checkouts that found no resident sketch for the geometry (encoded + cached).
    pub misses: u64,
    /// Checkouts by sessions holding a stale set snapshot (answered with a fresh,
    /// uncached encode — counted separately so they cannot masquerade as misses of a
    /// warmed store).
    pub stale_bypasses: u64,
    /// Full encodes performed (misses + bypasses + rebuilds; the cost the hits avoid).
    pub encodes: u64,
    /// Resident sketches maintained through a `replace_set` by streaming ±1 updates
    /// over the set diff (§4) instead of a re-encode.
    pub incremental_updates: u64,
    /// Resident sketches invalidated by a `replace_set` whose diff exceeded the new set
    /// size: dropped and re-encoded on demand by the next checkout (the off-lock miss
    /// path), instead of eagerly — and worker-stallingly — under the store lock.
    pub full_rebuilds: u64,
    /// Sketches currently resident.
    pub resident: usize,
    /// The capacity bound (0 = store disabled).
    pub capacity: usize,
}

impl SketchStoreStats {
    /// `hits / (hits + misses + stale_bypasses)`; 0.0 for a store never consulted — so
    /// the store-off ablation reads as 0, never as a perfect score.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses + self.stale_bypasses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Resident entries plus the host-set snapshot they are valid for. The lock covers
/// lookup, the in-flight registry, and churn maintenance; the encodes themselves run
/// off-lock (see [`SketchStore::host_sketch`]).
struct StoreInner {
    /// The host set every resident sketch encodes. Compared by slice identity with the
    /// snapshot a session presents.
    set: Arc<Vec<u64>>,
    /// Resident sketches, least-recently-used first (evict index 0).
    entries: Vec<(GeometryKey, Arc<Sketch>)>,
    /// Geometries some session is currently encoding (the single-flight registry):
    /// same-geometry callers wait on [`SketchStore::encoded`] instead of duplicating
    /// the encode.
    in_flight: HashSet<GeometryKey>,
}

/// The concurrency-safe host-sketch store (module docs). Share it as an
/// `Arc<SketchStore>`: it implements [`SketchSource`], so attaching it to a session's
/// endpoint makes every own-set sketch checkout store-backed — which is exactly what
/// [`crate::server::SetxServer`] does for each worker connection.
pub struct SketchStore {
    inner: Mutex<StoreInner>,
    /// Signalled whenever an in-flight encode finishes (successfully cached or not), so
    /// same-geometry waiters re-check the entries.
    encoded: Condvar,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    stale_bypasses: AtomicU64,
    encodes: AtomicU64,
    incremental_updates: AtomicU64,
    full_rebuilds: AtomicU64,
}

impl SketchStore {
    /// An empty store over `set` holding at most `capacity` resident sketches (misses
    /// encode with the [`EncodeConfig`] each checkout supplies). `capacity == 0` keeps
    /// nothing resident — every checkout encodes fresh (the store-off ablation shape).
    pub fn new(capacity: usize, set: Arc<Vec<u64>>) -> SketchStore {
        SketchStore {
            inner: Mutex::new(StoreInner {
                set,
                entries: Vec::new(),
                in_flight: HashSet::new(),
            }),
            encoded: Condvar::new(),
            capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stale_bypasses: AtomicU64::new(0),
            encodes: AtomicU64::new(0),
            incremental_updates: AtomicU64::new(0),
            full_rebuilds: AtomicU64::new(0),
        }
    }

    /// Counter + occupancy snapshot.
    pub fn stats(&self) -> SketchStoreStats {
        SketchStoreStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stale_bypasses: self.stale_bypasses.load(Ordering::Relaxed),
            encodes: self.encodes.load(Ordering::Relaxed),
            incremental_updates: self.incremental_updates.load(Ordering::Relaxed),
            full_rebuilds: self.full_rebuilds.load(Ordering::Relaxed),
            resident: self.inner.lock().map(|i| i.entries.len()).unwrap_or(0),
            capacity: self.capacity,
        }
    }

    /// The host-set snapshot resident sketches currently describe.
    pub fn current_set(&self) -> Arc<Vec<u64>> {
        Arc::clone(&self.inner.lock().expect("sketch store poisoned").set)
    }

    /// Swap the host set, maintaining every resident sketch across the change: apply §4
    /// streaming updates over the old/new multiplicity delta when it is smaller than
    /// the new set, else drop the entries for on-demand re-encode (see the module
    /// docs). Sessions still holding the old snapshot keep their pre-churn sketches
    /// ([`Arc::make_mut`] clones under sharing) and are bypassed on later checkouts.
    pub fn replace_set(&self, new: Arc<Vec<u64>>) {
        let mut inner = self.inner.lock().expect("sketch store poisoned");
        let old = std::mem::replace(&mut inner.set, Arc::clone(&new));
        if inner.entries.is_empty() || Arc::ptr_eq(&old, &new) {
            return;
        }
        // Per-id multiplicity delta, not a set diff: `Sketch::encode` is multiset-linear
        // (a duplicated id contributes its column twice), so maintenance must mirror
        // exact multiplicities or the maintained sketch silently drifts from
        // `encode(new)` on host sets carrying duplicates.
        let mut delta: HashMap<u64, i32> = HashMap::new();
        for &id in new.iter() {
            *delta.entry(id).or_insert(0) += 1;
        }
        for &id in old.iter() {
            *delta.entry(id).or_insert(0) -= 1;
        }
        delta.retain(|_, d| *d != 0);
        let diff_size: usize = delta.values().map(|d| d.unsigned_abs() as usize).sum();
        if diff_size > new.len() {
            // The diff outweighs the set, so maintenance costs more than re-encoding —
            // but re-encoding *here* would run up to `capacity` O(m·n) encodes under
            // the store lock (and, on the server path, under the host-set lock),
            // freezing every worker. Drop the entries instead: the off-lock
            // single-flight miss path re-encodes each geometry on demand.
            let dropped = inner.entries.len() as u64;
            inner.entries.clear();
            self.full_rebuilds.fetch_add(dropped, Ordering::Relaxed);
        } else {
            for (_, sk) in &mut inner.entries {
                let sketch = Arc::make_mut(sk);
                for (&id, &d) in &delta {
                    sketch.update(id, d);
                }
                self.incremental_updates.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl SketchSource for SketchStore {
    fn host_sketch(&self, matrix: &CsMatrix, set: &[u64], enc: EncodeConfig) -> Arc<Sketch> {
        let key = GeometryKey::of_oracle(matrix);
        let mut inner = self.inner.lock().expect("sketch store poisoned");
        loop {
            let same_snapshot =
                inner.set.len() == set.len() && std::ptr::eq(inner.set.as_ptr(), set.as_ptr());
            if !same_snapshot {
                // The caller's set snapshot predates (or otherwise isn't) ours: serve a
                // correct fresh encode for *its* set and cache nothing — off-lock, a
                // stale straggler must not stall the hot path.
                drop(inner);
                self.stale_bypasses.fetch_add(1, Ordering::Relaxed);
                self.encodes.fetch_add(1, Ordering::Relaxed);
                return Arc::new(Sketch::encode_par(*matrix, set, enc));
            }
            if let Some(pos) = inner.entries.iter().position(|(k, _)| *k == key) {
                // Refresh LRU position, hand out a shared clone.
                let entry = inner.entries.remove(pos);
                let sketch = Arc::clone(&entry.1);
                inner.entries.push(entry);
                drop(inner);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return sketch;
            }
            if !inner.in_flight.contains(&key) {
                break;
            }
            // Another session is already encoding this geometry: wait for it rather
            // than duplicating the work, then re-check everything (the host set may
            // have been replaced, or the encoder may have discarded its result).
            inner = self.encoded.wait(inner).expect("sketch store poisoned");
        }
        // Single-flight miss: claim the geometry and encode *outside* the lock, so a
        // same-geometry cold burst performs exactly one encode while sessions on other
        // geometries keep encoding concurrently instead of convoying on the mutex.
        inner.in_flight.insert(key);
        let snapshot = Arc::clone(&inner.set);
        drop(inner);
        let sketch = Arc::new(Sketch::encode_par(*matrix, set, enc));
        let mut inner = self.inner.lock().expect("sketch store poisoned");
        inner.in_flight.remove(&key);
        // Cache only when the host set is still the snapshot we encoded (a concurrent
        // `replace_set` invalidates the result for future sessions — the caller still
        // gets it, correct for *its* snapshot) and the sketch is small enough to park.
        if self.capacity > 0
            && sketch.counts.len() <= MAX_CACHED_L
            && Arc::ptr_eq(&inner.set, &snapshot)
        {
            inner.entries.push((key, Arc::clone(&sketch)));
            while inner.entries.len() > self.capacity {
                inner.entries.remove(0);
            }
        }
        drop(inner);
        self.encoded.notify_all();
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.encodes.fetch_add(1, Ordering::Relaxed);
        sketch
    }
}

impl std::fmt::Debug for SketchStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("SketchStore")
            .field("resident", &s.resident)
            .field("capacity", &s.capacity)
            .field("hits", &s.hits)
            .field("misses", &s.misses)
            .field("incremental_updates", &s.incremental_updates)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Xoshiro256;

    fn mk_store(set: Vec<u64>, capacity: usize) -> (Arc<SketchStore>, Arc<Vec<u64>>) {
        let set = Arc::new(set);
        (Arc::new(SketchStore::new(capacity, Arc::clone(&set))), set)
    }

    #[test]
    fn checkout_equals_fresh_encode_and_hits_after_warmup() {
        let (store, set) = mk_store((0..5_000u64).collect(), 4);
        let matrix = CsMatrix::new(1024, 5, 7);
        let first = store.host_sketch(&matrix, &set, EncodeConfig::serial());
        assert_eq!(*first, Sketch::encode(matrix, &set));
        let second = store.host_sketch(&matrix, &set, EncodeConfig::serial());
        assert!(Arc::ptr_eq(&first, &second), "warm checkout must be the shared Arc");
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.encodes), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn distinct_geometries_cache_independently_with_lru_eviction() {
        let (store, set) = mk_store((0..1_000u64).collect(), 2);
        let m1 = CsMatrix::new(256, 5, 1);
        let m2 = CsMatrix::new(512, 5, 1);
        let m3 = CsMatrix::new(256, 7, 1);
        for m in [m1, m2, m3] {
            store.host_sketch(&m, &set, EncodeConfig::serial());
        }
        assert_eq!(store.stats().resident, 2);
        // m1 (least recently used) was evicted: touching it again is a miss …
        store.host_sketch(&m1, &set, EncodeConfig::serial());
        // … while m3 stayed resident.
        store.host_sketch(&m3, &set, EncodeConfig::serial());
        let s = store.stats();
        assert_eq!(s.misses, 4);
        assert_eq!(s.hits, 1);
    }

    #[test]
    fn concurrent_same_geometry_checkout_performs_exactly_one_encode() {
        // The acceptance shape: 4 threads race on one cold geometry; single-flight must
        // collapse them to one encode, and the counters must account for every checkout.
        let (store, set) = mk_store((0..20_000u64).collect(), 4);
        let matrix = CsMatrix::new(2048, 5, 11);
        let threads = 4;
        let iters = 8;
        let reference = Sketch::encode(matrix, &set);
        std::thread::scope(|scope| {
            for _ in 0..threads {
                let store = Arc::clone(&store);
                let set = Arc::clone(&set);
                let reference = &reference;
                scope.spawn(move || {
                    for _ in 0..iters {
                        let sk = store.host_sketch(&matrix, &set, EncodeConfig::serial());
                        assert_eq!(*sk, *reference, "store returned a wrong sketch");
                    }
                });
            }
        });
        let s = store.stats();
        assert_eq!(s.encodes, 1, "single-flight must collapse the cold burst: {s:?}");
        assert_eq!(s.misses, 1);
        assert_eq!(s.hits, (threads * iters - 1) as u64, "every checkout counted: {s:?}");
        assert_eq!(s.stale_bypasses, 0);
    }

    #[test]
    fn stale_snapshot_is_bypassed_not_served_stale() {
        let (store, old_set) = mk_store((0..2_000u64).collect(), 4);
        let matrix = CsMatrix::new(512, 5, 3);
        store.host_sketch(&matrix, &old_set, EncodeConfig::serial());
        let new_set: Arc<Vec<u64>> = Arc::new((0..2_100u64).collect());
        store.replace_set(Arc::clone(&new_set));
        // A session still holding the old snapshot gets the *old* set's sketch (fresh
        // encode), not the resident sketch of the new set.
        let sk = store.host_sketch(&matrix, &old_set, EncodeConfig::serial());
        assert_eq!(*sk, Sketch::encode(matrix, &old_set));
        assert_eq!(store.stats().stale_bypasses, 1);
        // And a new-snapshot session gets the maintained resident sketch.
        let sk = store.host_sketch(&matrix, &new_set, EncodeConfig::serial());
        assert_eq!(*sk, Sketch::encode(matrix, &new_set));
    }

    #[test]
    fn incremental_replace_set_equals_fresh_encode_under_churn() {
        // The §4 property: across randomized add/remove churn, a resident sketch
        // maintained by streaming ±1 diff updates stays coordinate-identical to a fresh
        // encode of the current set — for every resident geometry.
        let mut rng = Xoshiro256::seed_from_u64(0xc0de);
        let (store, mut current) = mk_store((0..3_000u64).collect(), 4);
        let geometries = [CsMatrix::new(700, 5, 1), CsMatrix::new(1024, 7, 2)];
        for m in &geometries {
            store.host_sketch(m, &current, EncodeConfig::serial());
        }
        for round in 0..12 {
            // Random churn: drop ~1/8 of the set, add a fresh disjoint band.
            let mut next: Vec<u64> =
                current.iter().copied().filter(|_| rng.gen_range(8) != 0).collect();
            let base = 1_000_000 * (round as u64 + 1);
            next.extend(base..base + rng.gen_range(200) + 1);
            let next = Arc::new(next);
            store.replace_set(Arc::clone(&next));
            for m in &geometries {
                let maintained = store.host_sketch(m, &next, EncodeConfig::serial());
                assert_eq!(
                    *maintained,
                    Sketch::encode(*m, &next),
                    "round {round}: incrementally-maintained sketch diverged"
                );
            }
            current = next;
        }
        let s = store.stats();
        assert_eq!(s.incremental_updates, 24, "2 geometries × 12 rounds: {s:?}");
        assert_eq!(s.full_rebuilds, 0, "small diffs must stay incremental: {s:?}");
        // Post-churn checkouts all hit — maintenance never invalidated the entries.
        assert_eq!(s.misses, 2);
        assert_eq!(s.hits, 24);
    }

    #[test]
    fn oversized_diff_invalidates_for_on_demand_reencode() {
        let (store, set) = mk_store((0..1_000u64).collect(), 4);
        let matrix = CsMatrix::new(512, 5, 9);
        store.host_sketch(&matrix, &set, EncodeConfig::serial());
        // Replace with a completely disjoint set: diff (2·1000) > new set (1000), so
        // maintenance must drop the entry (never serve it) rather than patch or eagerly
        // re-encode it under the lock.
        let next: Arc<Vec<u64>> = Arc::new((10_000..11_000u64).collect());
        store.replace_set(Arc::clone(&next));
        let s = store.stats();
        assert_eq!(s.full_rebuilds, 1, "disjoint swap must invalidate: {s:?}");
        assert_eq!(s.incremental_updates, 0);
        assert_eq!(s.resident, 0, "invalidated entries must leave the store");
        // The next checkout re-encodes on demand (a miss) and is hot afterwards.
        let sk = store.host_sketch(&matrix, &next, EncodeConfig::serial());
        assert_eq!(*sk, Sketch::encode(matrix, &next));
        assert_eq!(store.stats().misses, 2);
        store.host_sketch(&matrix, &next, EncodeConfig::serial());
        assert_eq!(store.stats().hits, 1, "re-encoded entry is resident and hot");
    }

    #[test]
    fn zero_capacity_store_encodes_fresh_every_time() {
        let (store, set) = mk_store((0..500u64).collect(), 0);
        let matrix = CsMatrix::new(256, 5, 5);
        for _ in 0..3 {
            let sk = store.host_sketch(&matrix, &set, EncodeConfig::serial());
            assert_eq!(*sk, Sketch::encode(matrix, &set));
        }
        let s = store.stats();
        assert_eq!(s.hits, 0);
        assert_eq!(s.encodes, 3);
        assert_eq!(s.resident, 0);
        assert_eq!(s.hit_rate(), 0.0);
    }
}
