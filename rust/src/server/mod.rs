//! The multi-client SetX reconciliation daemon: one hot host set, any number of
//! concurrent TCP clients.
//!
//! [`crate::coordinator::tcp::serve`] accepts exactly one connection, runs one session,
//! and returns — the right shape for a point-to-point sync, useless for the paper's
//! deployment scenarios (block propagation, data-center sync), where a long-lived
//! service holds the authoritative set and reconciles a fleet against it. This module is
//! that service, assembled from the pieces the earlier layers were built to enable:
//!
//! * **[`SetxServer`]** — an accept loop feeding a bounded worker pool (the same
//!   atomic-counter + `peak_workers` discipline as [`crate::coordinator::parallel`]);
//!   each worker drives a sans-io [`crate::setx`] endpoint over a
//!   [`TcpTransport`] with per-connection session IDs, OS-level read/write timeouts
//!   (one stalled client must never wedge a worker forever), and graceful shutdown
//!   ([`ServerHandle::shutdown`] drains queued sessions before returning).
//! * **[`DecoderPool`]** — PR 3's one-slot decoder cache generalized into a shared,
//!   capacity-bounded LRU pool keyed by exact matrix geometry, so the dominant
//!   per-session cost (decoder construction over the host set) is paid once per
//!   geometry instead of once per connection.
//! * **[`SketchStore`]** — the encode-side sibling of the decoder pool: the host set's
//!   sketch per negotiated geometry, encoded once (single-flight) and checked out in
//!   O(1) by every later session instead of re-encoded O(m·n) per connection;
//!   [`ServerHandle::replace_set`] maintains resident sketches *incrementally* via §4
//!   streaming ±1 updates over the set diff.
//! * **Admission control** — at `max_inflight_sessions` live sessions, new connections
//!   get a typed [`Msg::Busy`] frame (surfaced client-side as
//!   [`SetxError::ServerBusy`] with a retry hint) instead of a hung or reset socket.
//! * **[`ServerStats`]** — sessions served/failed/rejected, per-phase wire bytes,
//!   decoder-pool hit rate, and worker high-water marks, snapshotable at any time and
//!   serializable as one flat JSON record.
//! * **[`loadgen`]** — a verifying load generator (N concurrent clients with perturbed
//!   sets, every returned intersection checked against the exact answer), which also
//!   backs the `commonsense loadgen` CLI and the `server_throughput` bench.
//!
//! ```no_run
//! use commonsense::server::SetxServer;
//! use commonsense::setx::Setx;
//!
//! let host_set: Vec<u64> = (0..100_000).collect();
//! let endpoint = Setx::builder(&host_set).build().unwrap();
//! let server = SetxServer::builder(endpoint).workers(4).bind("0.0.0.0:7700").unwrap();
//! // ... clients run `Setx::run` over `TcpTransport::connect` against us ...
//! let stats = server.shutdown();
//! println!("{}", stats.to_json());
//! ```

pub mod loadgen;
pub mod pool;
pub mod sketch_store;
mod stats;

pub use pool::{DecoderPool, PoolStats};
pub use sketch_store::{SketchStore, SketchStoreStats};
pub use stats::ServerStats;

use crate::decoder::{DecoderCache, DecoderStore};
use crate::protocol::wire::Msg;
use crate::setx::endpoint::Endpoint;
use crate::setx::transport::{TcpTransport, Transport};
use crate::setx::{Setx, SetxConfig, SetxError, SetxReport};
use crate::sketch::SketchSource;
use stats::StatsInner;
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Builder for a [`SetxServer`]; obtain via [`SetxServer::builder`]. Every knob has a
/// service-shaped default, so `SetxServer::builder(endpoint).bind(addr)` is a complete
/// daemon.
#[derive(Debug)]
pub struct ServerBuilder {
    endpoint: Setx,
    workers: usize,
    max_inflight: usize,
    pool_capacity: Option<usize>,
    sketch_store_capacity: Option<usize>,
    read_timeout: Option<Duration>,
    write_timeout: Option<Duration>,
    build_threads: usize,
    encode_threads: usize,
    busy_retry_hint_ms: u32,
}

impl ServerBuilder {
    /// Worker threads driving sessions (default 4; clamped to ≥ 1). This is the
    /// concurrency bound: at most `workers` sessions make protocol progress at once,
    /// the rest queue (but still count against admission).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Admission cap: connections arriving while this many sessions are live (queued or
    /// being served) are turned away with a `Busy` frame (default 64; clamped ≥ 1).
    pub fn max_inflight_sessions(mut self, cap: usize) -> Self {
        self.max_inflight = cap.max(1);
        self
    }

    /// Decoder-pool capacity (default `4 × workers`; `0` disables pooling — every
    /// session then builds its decoders from scratch).
    pub fn pool_capacity(mut self, capacity: usize) -> Self {
        self.pool_capacity = Some(capacity);
        self
    }

    /// Host-sketch-store capacity — resident per-geometry sketches of the host set
    /// (default 8; `0` disables the store, the ablation shape: every session re-encodes
    /// the host set). See [`SketchStore`].
    pub fn sketch_store_capacity(mut self, capacity: usize) -> Self {
        self.sketch_store_capacity = Some(capacity);
        self
    }

    /// OS-level read/write timeouts applied to every accepted connection (default 30 s
    /// each — sane for a service; `None` means block forever, which re-opens the
    /// wedged-worker failure mode and is only sensible for debugging).
    pub fn timeouts(mut self, read: Option<Duration>, write: Option<Duration>) -> Self {
        self.read_timeout = read;
        self.write_timeout = write;
        self
    }

    /// Decoder *construction* threads per session (default 1: the worker pool already
    /// provides the server's parallelism, and nested construction pools would
    /// oversubscribe the machine `workers × cores`-fold; `0` = auto).
    pub fn build_threads(mut self, threads: usize) -> Self {
        self.build_threads = threads;
        self
    }

    /// Sketch *encode* threads per session (default 1, for the same oversubscription
    /// reason as [`ServerBuilder::build_threads`]; `0` = auto). The host-sketch store's
    /// cold encodes run under the checking-out session's setting, so this governs them
    /// too.
    pub fn encode_threads(mut self, threads: usize) -> Self {
        self.encode_threads = threads;
        self
    }

    /// The back-off hint carried in `Busy` rejections, milliseconds (default 50).
    pub fn busy_retry_hint_ms(mut self, ms: u32) -> Self {
        self.busy_retry_hint_ms = ms;
        self
    }

    /// Bind the listener and start the accept loop + worker pool. The returned handle
    /// is the server: drop it (or call [`ServerHandle::shutdown`]) to stop.
    pub fn bind(self, addr: impl ToSocketAddrs) -> Result<ServerHandle, SetxError> {
        let listener = TcpListener::bind(addr)?;
        let addr = listener.local_addr()?;
        let pool_capacity = self.pool_capacity.unwrap_or(4 * self.workers);
        let pool =
            (pool_capacity > 0).then(|| Arc::new(DecoderPool::new(pool_capacity)));
        let mut cfg = *self.endpoint.config();
        // Per-session encodes follow the server's knob, not the endpoint builder's: the
        // worker pool is the daemon's parallelism (a local setting — not fingerprinted).
        cfg.encode_threads = self.encode_threads;
        let set = Arc::new(self.endpoint.set().to_vec());
        let store_capacity = self.sketch_store_capacity.unwrap_or(8);
        let sketch_store = (store_capacity > 0)
            .then(|| Arc::new(SketchStore::new(store_capacity, Arc::clone(&set))));
        let shared = Arc::new(Shared {
            cfg,
            set: Mutex::new(set),
            pool,
            sketch_store,
            stats: StatsInner::default(),
            shutdown: AtomicBool::new(false),
            last_failure: Mutex::new(None),
            next_session_id: AtomicU64::new(1),
            read_timeout: self.read_timeout,
            write_timeout: self.write_timeout,
            build_threads: self.build_threads,
            max_inflight: self.max_inflight,
            workers: self.workers,
            busy_retry_hint_ms: self.busy_retry_hint_ms,
        });

        let (tx, rx) = channel::<(TcpStream, u64)>();
        let rx = Arc::new(Mutex::new(rx));
        let worker_handles: Vec<JoinHandle<()>> = (0..self.workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("setx-worker-{w}"))
                    .spawn(move || worker_loop(&shared, &rx))
                    .expect("spawn server worker")
            })
            .collect();
        let accept_handle = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("setx-accept".to_string())
                .spawn(move || accept_loop(&shared, &listener, tx))
                .expect("spawn server accept loop")
        };
        Ok(ServerHandle {
            shared,
            addr,
            accept: Some(accept_handle),
            workers: worker_handles,
        })
    }
}

/// State shared by the accept loop, the workers, and the handle.
struct Shared {
    cfg: SetxConfig,
    /// The (mutable) host set. Each session snapshots the current `Arc` at start;
    /// [`ServerHandle::replace_set`] swaps it atomically, so in-flight sessions keep
    /// reconciling against the set they started with.
    set: Mutex<Arc<Vec<u64>>>,
    /// `None` when pooling is disabled.
    pool: Option<Arc<DecoderPool>>,
    /// Host-sketch store (encode-side reuse); `None` when disabled (the ablation).
    sketch_store: Option<Arc<SketchStore>>,
    stats: StatsInner,
    shutdown: AtomicBool,
    /// Most recent failed session: `(session_id, error)` — the minimal breadcrumb an
    /// operator needs before turning on real logging.
    last_failure: Mutex<Option<(u64, String)>>,
    next_session_id: AtomicU64,
    read_timeout: Option<Duration>,
    write_timeout: Option<Duration>,
    build_threads: usize,
    max_inflight: usize,
    workers: usize,
    busy_retry_hint_ms: u32,
}

impl Shared {
    fn current_set(&self) -> Arc<Vec<u64>> {
        Arc::clone(&self.set.lock().expect("host set lock poisoned"))
    }
}

/// The namespace entry point: [`SetxServer::builder`] is how a server is made.
pub struct SetxServer;

impl SetxServer {
    /// Start building a server around `endpoint` — a validated [`Setx`] whose config
    /// every client must match (fingerprint-checked in the handshake, exactly as in a
    /// point-to-point run) and whose set becomes the initial host set.
    pub fn builder(endpoint: Setx) -> ServerBuilder {
        ServerBuilder {
            endpoint,
            workers: 4,
            max_inflight: 64,
            pool_capacity: None,
            sketch_store_capacity: None,
            read_timeout: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(30)),
            build_threads: 1,
            encode_threads: 1,
            busy_retry_hint_ms: 50,
        }
    }
}

/// A running server. Dropping the handle shuts the server down (best-effort); call
/// [`ServerHandle::shutdown`] to do it explicitly and receive the final stats.
pub struct ServerHandle {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time stats snapshot.
    pub fn stats(&self) -> ServerStats {
        let s = &self.shared.stats;
        ServerStats {
            sessions_accepted: s.sessions_accepted.load(Ordering::Relaxed),
            sessions_served: s.sessions_served.load(Ordering::Relaxed),
            sessions_failed: s.sessions_failed.load(Ordering::Relaxed),
            sessions_rejected: s.sessions_rejected.load(Ordering::Relaxed),
            phase_bytes: [
                s.phase_bytes[0].load(Ordering::Relaxed),
                s.phase_bytes[1].load(Ordering::Relaxed),
                s.phase_bytes[2].load(Ordering::Relaxed),
                s.phase_bytes[3].load(Ordering::Relaxed),
            ],
            pool: self.shared.pool.as_ref().map(|p| p.stats()).unwrap_or_default(),
            sketch_store: self
                .shared
                .sketch_store
                .as_ref()
                .map(|s| s.stats())
                .unwrap_or_default(),
            inflight: s.inflight.load(Ordering::SeqCst),
            peak_inflight: s.peak_inflight.load(Ordering::Relaxed),
            peak_workers: s.peak_workers.load(Ordering::Relaxed),
            workers: self.shared.workers,
            max_inflight_sessions: self.shared.max_inflight,
        }
    }

    /// The most recent failed session, as `(session_id, error message)`.
    pub fn last_failure(&self) -> Option<(u64, String)> {
        self.shared.last_failure.lock().expect("failure lock poisoned").clone()
    }

    /// Replace the host set. In-flight sessions finish against the set they started
    /// with; new sessions reconcile against the replacement. Decoders parked in the
    /// pool for the old set become unreachable (their cache keys no longer validate)
    /// and age out by LRU; resident host sketches are *maintained* across the change —
    /// the [`SketchStore`] applies §4 streaming ±1 updates over the set diff (or
    /// re-encodes when the diff is larger than the set), so the encode-side cache stays
    /// warm through churn. In-flight sessions holding the old snapshot are detected by
    /// the store and served their own set's sketch, never the replacement's.
    pub fn replace_set(&self, set: Vec<u64>) {
        let set = Arc::new(set);
        // One critical section for both views: concurrent `replace_set` calls must not
        // interleave the store update and the set swap in opposite orders, or the store
        // would validate sessions against a different snapshot than they hold and
        // bypass (fresh-encode) every checkout until the next replacement. Lock order
        // is always set-lock → store-lock (the store's other users never hold both).
        let mut guard = self.shared.set.lock().expect("host set lock poisoned");
        if let Some(store) = &self.shared.sketch_store {
            store.replace_set(Arc::clone(&set));
        }
        *guard = set;
    }

    /// Graceful shutdown: stop accepting, serve every already-queued session to
    /// completion, join all threads, and return the final stats.
    pub fn shutdown(mut self) -> ServerStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        if !self.shared.shutdown.swap(true, Ordering::SeqCst) {
            // Unblock the accept loop: it re-checks the flag per connection, so one
            // throwaway local dial is enough (best-effort — the loop may already be
            // past its accept call). A wildcard bind (0.0.0.0 / ::) is not a dialable
            // destination everywhere, so aim the wake-up at loopback on the same port.
            let mut wake = self.addr;
            if wake.ip().is_unspecified() {
                wake.set_ip(match wake.ip() {
                    std::net::IpAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                    std::net::IpAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
                });
            }
            let _ = TcpStream::connect_timeout(&wake, Duration::from_millis(500));
        }
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

impl std::fmt::Debug for ServerHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerHandle")
            .field("addr", &self.addr)
            .field("workers", &self.shared.workers)
            .field("stats", &self.stats())
            .finish()
    }
}

/// The accept loop: admission control happens here, *before* a worker is occupied, so a
/// full server answers instantly instead of queueing the connection behind the backlog.
/// Dropping `tx` at loop exit is the workers' shutdown signal (they drain the queue
/// first — mpsc delivers buffered jobs even after the sender is gone).
fn accept_loop(shared: &Shared, listener: &TcpListener, tx: Sender<(TcpStream, u64)>) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _peer)) => stream,
            Err(_) if shared.shutdown.load(Ordering::SeqCst) => break,
            Err(_) => {
                // Transient accept error (EMFILE under fd pressure, ECONNABORTED, …):
                // keep serving, but back off briefly — a persistent error would
                // otherwise spin this thread at 100% CPU against the same failure.
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
        };
        if shared.shutdown.load(Ordering::SeqCst) {
            break; // the shutdown wake-up dial (or a late client): drop and exit
        }
        let inflight = shared.stats.inflight.load(Ordering::SeqCst);
        if inflight >= shared.max_inflight {
            reject_busy(shared, stream);
            continue;
        }
        let live = shared.stats.inflight.fetch_add(1, Ordering::SeqCst) + 1;
        shared.stats.peak_inflight.fetch_max(live, Ordering::SeqCst);
        shared.stats.sessions_accepted.fetch_add(1, Ordering::Relaxed);
        let sid = shared.next_session_id.fetch_add(1, Ordering::Relaxed);
        if tx.send((stream, sid)).is_err() {
            // Workers are gone (shutdown race): undo the admission and stop.
            shared.stats.inflight.fetch_sub(1, Ordering::SeqCst);
            break;
        }
    }
}

/// Answer an over-admission connection with the typed `Busy` frame (bounded write so a
/// non-reading client cannot stall the accept thread), then close.
fn reject_busy(shared: &Shared, stream: TcpStream) {
    shared.stats.sessions_rejected.fetch_add(1, Ordering::Relaxed);
    stream.set_nodelay(true).ok();
    let mut transport = TcpTransport::from_stream(stream, false);
    let _ = transport
        .set_timeouts(Some(Duration::from_millis(500)), Some(Duration::from_millis(500)));
    let _ = transport.send(&Msg::Busy { retry_after_ms: shared.busy_retry_hint_ms });
}

fn worker_loop(shared: &Shared, rx: &Mutex<Receiver<(TcpStream, u64)>>) {
    loop {
        // Hold the lock only for the dequeue: exactly one idle worker blocks in `recv`,
        // the rest queue on the mutex — jobs hand off one at a time.
        let job = rx.lock().expect("server work queue poisoned").recv();
        let Ok((stream, sid)) = job else {
            break; // queue closed and drained: shutdown
        };
        let busy = shared.stats.busy_workers.fetch_add(1, Ordering::SeqCst) + 1;
        shared.stats.peak_workers.fetch_max(busy, Ordering::SeqCst);
        match serve_connection(shared, stream) {
            Ok(report) => {
                shared.stats.sessions_served.fetch_add(1, Ordering::Relaxed);
                shared.stats.charge_comm(&report.comm);
            }
            Err(err) => {
                shared.stats.sessions_failed.fetch_add(1, Ordering::Relaxed);
                *shared.last_failure.lock().expect("failure lock poisoned") =
                    Some((sid, err.to_string()));
            }
        }
        shared.stats.busy_workers.fetch_sub(1, Ordering::SeqCst);
        shared.stats.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Drive one accepted connection to completion: snapshot the host set, build a facade
/// endpoint whose decoder cache is backed by the shared pool, and pump it over the
/// timeout-guarded transport — the exact loop `Setx::run` uses, so server sessions and
/// point-to-point runs cannot diverge.
fn serve_connection(shared: &Shared, stream: TcpStream) -> Result<SetxReport, SetxError> {
    stream.set_nodelay(true).ok();
    let mut transport = TcpTransport::from_stream(stream, false);
    transport.set_timeouts(shared.read_timeout, shared.write_timeout)?;
    let set = shared.current_set();
    let mut endpoint = Endpoint::new(&shared.cfg, &set, false);
    let mut cache = DecoderCache::with_build_threads(shared.build_threads);
    if let Some(pool) = &shared.pool {
        cache = cache.with_shared_store(Arc::clone(pool) as Arc<dyn DecoderStore>);
    }
    endpoint.set_cache(cache);
    if let Some(store) = &shared.sketch_store {
        endpoint.set_sketch_source(Arc::clone(store) as Arc<dyn SketchSource>);
    }
    Setx::pump(&mut endpoint, &mut transport)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn bind_and_shutdown_without_clients() {
        let set: Vec<u64> = (0..500).collect();
        let endpoint = Setx::builder(&set).build().unwrap();
        let server =
            SetxServer::builder(endpoint).workers(2).bind("127.0.0.1:0").unwrap();
        assert_ne!(server.local_addr().port(), 0);
        let stats = server.shutdown();
        assert_eq!(stats.sessions_accepted, 0);
        assert_eq!(stats.sessions_rejected, 0);
        assert_eq!(stats.workers, 2);
    }

    #[test]
    fn one_client_round_trip_and_stats() {
        let (a, b) = synth::overlap_pair(2_000, 30, 40, 5);
        let server = SetxServer::builder(Setx::builder(&b).build().unwrap())
            .workers(1)
            .bind("127.0.0.1:0")
            .unwrap();
        let addr = server.local_addr();
        let alice = Setx::builder(&a).build().unwrap();
        let mut transport = TcpTransport::connect(addr).unwrap();
        let report = alice.run(&mut transport).unwrap();
        assert_eq!(report.local_unique, synth::difference(&a, &b));
        assert_eq!(report.intersection, synth::intersect(&a, &b));
        // The worker finishes asynchronously after the client's last frame lands.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while server.stats().sessions_served == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        let stats = server.shutdown();
        assert_eq!(stats.sessions_served, 1, "last failure: {:?}", stats);
        assert_eq!(stats.sessions_failed, 0);
        assert!(stats.total_bytes() > 0);
        assert_eq!(stats.peak_workers, 1);
    }

    #[test]
    fn replace_set_serves_the_new_set() {
        let (a, b1) = synth::overlap_pair(1_500, 20, 30, 8);
        let server = SetxServer::builder(Setx::builder(&b1).build().unwrap())
            .workers(1)
            .bind("127.0.0.1:0")
            .unwrap();
        let addr = server.local_addr();
        let alice = Setx::builder(&a).build().unwrap();
        let r1 = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
        assert_eq!(r1.intersection, synth::intersect(&a, &b1));
        // Mutate the host set: drop half of B's unique elements and half the overlap.
        let mut b2 = b1.clone();
        b2.truncate(b1.len() - 25);
        server.replace_set(b2.clone());
        let r2 = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
        assert_eq!(r2.intersection, synth::intersect(&a, &b2));
        server.shutdown();
    }
}
