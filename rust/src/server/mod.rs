//! The multi-tenant SetX reconciliation daemon: many resident host sets, any number of
//! concurrent TCP clients, driven by a fixed pool of readiness-based poller threads.
//!
//! [`crate::coordinator::tcp::serve`] accepts exactly one connection, runs one session,
//! and returns — the right shape for a point-to-point debug sync, useless for the
//! paper's deployment scenarios (block propagation, data-center sync), where a
//! long-lived service holds authoritative sets and reconciles a fleet against them.
//! This module is that service, assembled from the pieces the earlier layers were built
//! to enable:
//!
//! * **The readiness driver** — there is no thread-per-connection and no blocking
//!   transport on the server side. Each of the `workers` poller threads owns a slice of
//!   the live connections and multiplexes them with `poll(2)` over non-blocking
//!   sockets; every connection is a small state machine
//!   ([`Conn`](self)) wrapping a sans-io [`crate::setx`] endpoint, fed whole frames by
//!   the incremental framer ([`frame_extent`]) and drained through a per-connection
//!   write buffer. Liveness is enforced by *per-connection deadlines* (refreshed on
//!   progress) instead of OS read/write timeouts, so one stalled client costs a poll
//!   slot, never a thread. All pollers poll the shared listener; whoever wakes first
//!   accepts (the herd is the load balancer). Shutdown is graceful: the listener stops
//!   being polled, resident connections drain to completion, then the pollers exit.
//! * **Multi-tenancy** — the client's `EstHello` carries a `namespace` id (absent on
//!   the wire for tenant 0, so pre-namespace clients interoperate unchanged) that
//!   routes the connection to one of many resident tenants. Each tenant owns its host
//!   set, its own [`DecoderPool`] and [`SketchStore`] shard, a concurrency quota, and a
//!   counter shard ([`TenantStats`]); [`ServerHandle::add_tenant`] /
//!   [`remove_tenant`](ServerHandle::remove_tenant) /
//!   [`replace_tenant_set`](ServerHandle::replace_tenant_set) manage the map at
//!   runtime. An unknown namespace or an over-quota tenant answers a typed
//!   [`Msg::Busy`] carrying the tenant id (surfaced client-side as
//!   [`SetxError::ServerBusy`]).
//! * **[`DecoderPool`]** — PR 3's one-slot decoder cache generalized into a shared,
//!   capacity-bounded LRU pool keyed by exact matrix geometry, so the dominant
//!   per-session cost (decoder construction over the host set) is paid once per
//!   geometry instead of once per connection — now one shard per tenant.
//! * **[`SketchStore`]** — the encode-side sibling of the decoder pool: the host set's
//!   sketch per negotiated geometry, encoded once (single-flight) and checked out in
//!   O(1) by every later session; set replacement maintains resident sketches
//!   *incrementally* via §4 streaming ±1 updates — also one shard per tenant.
//! * **Admission control** — two gates: a global `max_inflight_sessions` cap applied at
//!   accept (before any protocol work), and a per-tenant quota applied at routing.
//!   Both answer with `Busy` instead of a hung or reset socket.
//! * **[`ServerStats`]** — global counters plus one [`TenantStats`] shard per resident
//!   tenant (shard sums + the `unrouted_*` remainders equal the globals), snapshotable
//!   at any time and serializable as one flat JSON record.
//! * **[`loadgen`]** — a verifying load generator (N concurrent clients across M
//!   tenants with perturbed sets, every returned intersection checked against the exact
//!   answer, capped-exponential retry on `Busy`), which also backs the
//!   `commonsense loadgen` CLI and the `server_throughput` bench.
//!
//! ```no_run
//! use commonsense::server::SetxServer;
//! use commonsense::setx::Setx;
//!
//! let host_set: Vec<u64> = (0..100_000).collect();
//! let endpoint = Setx::builder(&host_set).build().unwrap();
//! let server = SetxServer::builder(endpoint)
//!     .workers(4)
//!     .tenant(7, (500_000..600_000).collect())
//!     .bind("0.0.0.0:7700")
//!     .unwrap();
//! // ... clients run `Setx::run` over `TcpTransport::connect` against us; a client
//! // built with `.namespace(7)` reconciles against tenant 7's set ...
//! let stats = server.shutdown();
//! println!("{}", stats.to_json());
//! ```

pub mod loadgen;
pub mod pool;
pub mod sketch_store;
mod stats;

pub use pool::{DecoderPool, PoolStats};
pub use sketch_store::{SketchStore, SketchStoreStats};
pub use stats::{ServerStats, TenantStats};

use crate::decoder::{DecoderCache, DecoderStore};
use crate::protocol::wire::Msg;
use crate::setx::endpoint::{Endpoint, Step};
use crate::setx::multi::{MultiCoordinator, MultiError, MultiReport};
use crate::setx::transport::frame_extent;
use crate::setx::{Setx, SetxConfig, SetxError, SetxReport};
use crate::sketch::SketchSource;
use stats::{StatsInner, TenantCounters};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// poll(2) FFI — the only readiness primitive the driver needs, hand-rolled to
// keep the crate dependency-free.
// ---------------------------------------------------------------------------

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;

#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

extern "C" {
    fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
}

/// Builder for a [`SetxServer`]; obtain via [`SetxServer::builder`]. Every knob has a
/// service-shaped default, so `SetxServer::builder(endpoint).bind(addr)` is a complete
/// daemon.
#[derive(Debug)]
pub struct ServerBuilder {
    endpoint: Setx,
    workers: usize,
    max_inflight: usize,
    pool_capacity: Option<usize>,
    sketch_store_capacity: Option<usize>,
    read_timeout: Option<Duration>,
    write_timeout: Option<Duration>,
    build_threads: usize,
    encode_threads: usize,
    busy_retry_hint_ms: u32,
    tenant_quota: Option<usize>,
    extra_tenants: Vec<(u32, Vec<u64>)>,
    multi_tenants: Vec<(u32, Vec<u64>, u32)>,
    metrics_addr: Option<String>,
    slow_session_threshold: Option<Duration>,
}

impl ServerBuilder {
    /// Poller threads driving connections (default 4; clamped to ≥ 1). This is the
    /// concurrency bound: at most `workers` threads make protocol progress at once;
    /// each multiplexes its share of the live connections by readiness.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Global admission cap: connections arriving while this many are live are turned
    /// away with a `Busy` frame before any protocol work (default 64; clamped ≥ 1).
    pub fn max_inflight_sessions(mut self, cap: usize) -> Self {
        self.max_inflight = cap.max(1);
        self
    }

    /// Per-tenant decoder-pool capacity (default `4 × workers`; `0` disables pooling —
    /// every session then builds its decoders from scratch).
    pub fn pool_capacity(mut self, capacity: usize) -> Self {
        self.pool_capacity = Some(capacity);
        self
    }

    /// Per-tenant host-sketch-store capacity — resident per-geometry sketches of the
    /// tenant's set (default 8; `0` disables the store, the ablation shape: every
    /// session re-encodes the host set). See [`SketchStore`].
    pub fn sketch_store_capacity(mut self, capacity: usize) -> Self {
        self.sketch_store_capacity = Some(capacity);
        self
    }

    /// Per-connection inactivity deadline, taken as `read.or(write)` (default 30 s).
    /// The deadline is refreshed whenever a connection makes read or write progress;
    /// a connection that stalls past it is torn down with a timeout error. `None`
    /// disables the deadline for *routed* sessions, which re-opens the parked-forever
    /// failure mode and is only sensible for debugging — unrouted connections (no
    /// `EstHello` yet) always carry a 30 s routing deadline regardless, so a half-open
    /// peer that sends a partial frame header and goes silent can never park an
    /// admission slot indefinitely. (The two-parameter shape is kept for builder
    /// compatibility with the blocking-transport era, which mapped them onto OS socket
    /// timeouts.)
    pub fn timeouts(mut self, read: Option<Duration>, write: Option<Duration>) -> Self {
        self.read_timeout = read;
        self.write_timeout = write;
        self
    }

    /// Decoder *construction* threads per session (default 1: the poller pool already
    /// provides the server's parallelism, and nested construction pools would
    /// oversubscribe the machine `workers × cores`-fold; `0` = auto).
    pub fn build_threads(mut self, threads: usize) -> Self {
        self.build_threads = threads;
        self
    }

    /// Sketch *encode* threads per session (default 1, for the same oversubscription
    /// reason as [`ServerBuilder::build_threads`]; `0` = auto). Each tenant store's
    /// cold encodes run under the checking-out session's setting, so this governs them
    /// too.
    pub fn encode_threads(mut self, threads: usize) -> Self {
        self.encode_threads = threads;
        self
    }

    /// The back-off hint carried in `Busy` rejections, milliseconds (default 50).
    pub fn busy_retry_hint_ms(mut self, ms: u32) -> Self {
        self.busy_retry_hint_ms = ms;
        self
    }

    /// Per-tenant concurrency quota: at most this many routed sessions per tenant at
    /// once, the rest answered `Busy` with the tenant id (default: the global
    /// admission cap, i.e. no per-tenant throttling; clamped ≥ 1). Applies to every
    /// tenant, including ones added at runtime.
    pub fn tenant_quota(mut self, quota: usize) -> Self {
        self.tenant_quota = Some(quota.max(1));
        self
    }

    /// Expose a live metrics endpoint: a minimal HTTP/1.0 responder on its own named
    /// thread (`setx-metrics`) answering every `GET` with the current [`ServerStats`]
    /// rendered by [`ServerStats::to_prometheus`] — counters, gauges, and the
    /// session-latency histograms, global and per tenant. Scrape it with Prometheus or
    /// plain `curl`; the thread costs nothing between requests (each response is one
    /// stats snapshot, taken under the same locks [`ServerHandle::stats`] uses).
    /// Disabled by default; `"127.0.0.1:0"` picks an ephemeral port, reported by
    /// [`ServerHandle::metrics_addr`].
    pub fn metrics_addr(mut self, addr: impl Into<String>) -> Self {
        self.metrics_addr = Some(addr.into());
        self
    }

    /// Dump the full [`crate::obs::SessionTrace`] of any served session whose wall
    /// time meets `threshold` to stderr, prefixed
    /// `[slow-session] sid=<id> tenant=<ns> elapsed=<ms>ms` — the triage breadcrumb
    /// for tail latency: the timeline shows *which phase* (decode rung, residue
    /// round, sketch encode) ate the budget. Disabled by default.
    pub fn slow_session_threshold(mut self, threshold: Duration) -> Self {
        self.slow_session_threshold = Some(threshold);
        self
    }

    /// Pre-register a tenant: clients whose `EstHello` carries `namespace` reconcile
    /// against `set`. Tenant 0 is always the builder endpoint's set; registering
    /// namespace 0 here replaces it. Tenants can also be added after bind via
    /// [`ServerHandle::add_tenant`].
    pub fn tenant(mut self, namespace: u32, set: Vec<u64>) -> Self {
        self.extra_tenants.push((namespace, set));
        self
    }

    /// Pre-register a *coordinator* tenant: spokes joining `namespace` with a
    /// multi-party hello ([`crate::setx::multi::Party`]) are gathered into N-party
    /// rounds (`parties` total, the tenant's resident `set` being party 0) and driven
    /// over the poller pool by a shared sans-io [`MultiCoordinator`]. When a round
    /// completes, the next multi-party join starts a fresh one; completed rounds are
    /// drained via [`ServerHandle::take_multi_reports`]. Ordinary two-party clients of
    /// the same namespace are still served against `set` as usual.
    pub fn multi_tenant(mut self, namespace: u32, set: Vec<u64>, parties: u32) -> Self {
        self.multi_tenants.push((namespace, set, parties));
        self
    }

    /// Bind the listener and start the poller pool. The returned handle is the server:
    /// drop it (or call [`ServerHandle::shutdown`]) to stop.
    pub fn bind(self, addr: impl ToSocketAddrs) -> Result<ServerHandle, SetxError> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let pool_capacity = self.pool_capacity.unwrap_or(4 * self.workers);
        let store_capacity = self.sketch_store_capacity.unwrap_or(8);
        let tenant_quota = self.tenant_quota.unwrap_or(self.max_inflight).max(1);
        let mut cfg = *self.endpoint.config();
        // Per-session encodes follow the server's knob, not the endpoint builder's: the
        // poller pool is the daemon's parallelism (a local setting — not fingerprinted).
        cfg.encode_threads = self.encode_threads;

        let mut tenants = HashMap::new();
        let set0 = Arc::new(self.endpoint.set().to_vec());
        tenants.insert(
            0u32,
            TenantState::new(0, set0, pool_capacity, store_capacity, tenant_quota, None),
        );
        for (ns, set) in self.extra_tenants {
            tenants.insert(
                ns,
                TenantState::new(
                    ns,
                    Arc::new(set),
                    pool_capacity,
                    store_capacity,
                    tenant_quota,
                    None,
                ),
            );
        }
        for (ns, set, parties) in self.multi_tenants {
            tenants.insert(
                ns,
                TenantState::new(
                    ns,
                    Arc::new(set),
                    pool_capacity,
                    store_capacity,
                    tenant_quota,
                    Some(parties),
                ),
            );
        }

        // Wake pipes are created before the pollers so `Shared` can own the write ends:
        // any thread (a poller delivering cross-connection multi-party frames, or the
        // handle shutting down) can then interrupt every `poll` immediately.
        let mut wake_rxs = Vec::with_capacity(self.workers);
        let mut wakers = Vec::with_capacity(self.workers);
        for _ in 0..self.workers {
            let (wake_tx, wake_rx) = UnixStream::pair()?;
            wake_rx.set_nonblocking(true)?;
            // Nonblocking on the write end too: a wake byte dropped against a full pipe
            // is free (the pipe being non-empty is already a pending wake), while a
            // blocking write there could stall a poller mid-delivery.
            wake_tx.set_nonblocking(true)?;
            wakers.push(wake_tx);
            wake_rxs.push(wake_rx);
        }

        let shared = Arc::new(Shared {
            cfg,
            tenants: RwLock::new(tenants),
            stats: StatsInner::default(),
            shutdown: AtomicBool::new(false),
            last_failure: Mutex::new(None),
            next_session_id: AtomicU64::new(1),
            session_timeout: self.read_timeout.or(self.write_timeout),
            slow_session_threshold: self.slow_session_threshold,
            build_threads: self.build_threads,
            max_inflight: self.max_inflight,
            workers: self.workers,
            busy_retry_hint_ms: self.busy_retry_hint_ms,
            pool_capacity,
            store_capacity,
            tenant_quota,
            wakers,
        });

        let listener = Arc::new(listener);
        let mut pollers = Vec::with_capacity(self.workers);
        for (w, wake_rx) in wake_rxs.into_iter().enumerate() {
            let shared = Arc::clone(&shared);
            let listener = Arc::clone(&listener);
            pollers.push(
                std::thread::Builder::new()
                    .name(format!("setx-poller-{w}"))
                    .spawn(move || poller_loop(&shared, &listener, &wake_rx))
                    .expect("spawn server poller"),
            );
        }
        let metrics = match self.metrics_addr {
            Some(maddr) => {
                let ml = TcpListener::bind(maddr.as_str())?;
                ml.set_nonblocking(true)?;
                let bound = ml.local_addr()?;
                let shared = Arc::clone(&shared);
                let handle = std::thread::Builder::new()
                    .name("setx-metrics".into())
                    .spawn(move || metrics_loop(&shared, &ml))
                    .expect("spawn metrics responder");
                Some((bound, handle))
            }
            None => None,
        };
        Ok(ServerHandle { shared, addr, pollers, metrics })
    }
}

/// One resident tenant: its host set, its private pool/store shards, its quota, and
/// its counter shard. Connections hold an `Arc` to the tenant they routed to, so
/// [`ServerHandle::remove_tenant`] never tears down in-flight sessions.
struct TenantState {
    namespace: u32,
    /// The (mutable) host set. Each session snapshots the current `Arc` at routing;
    /// replacement swaps it atomically, so in-flight sessions keep reconciling against
    /// the set they started with.
    set: Mutex<Arc<Vec<u64>>>,
    /// `None` when pooling is disabled.
    pool: Option<Arc<DecoderPool>>,
    /// Host-sketch store (encode-side reuse); `None` when disabled (the ablation).
    store: Option<Arc<SketchStore>>,
    quota: usize,
    counters: TenantCounters,
    /// `Some` iff this is a coordinator tenant (registered via
    /// [`ServerBuilder::multi_tenant`]): the slot through which multi-party joins are
    /// gathered into rounds.
    round: Option<Mutex<RoundSlot>>,
}

impl TenantState {
    fn new(
        namespace: u32,
        set: Arc<Vec<u64>>,
        pool_capacity: usize,
        store_capacity: usize,
        quota: usize,
        parties: Option<u32>,
    ) -> Arc<TenantState> {
        Arc::new(TenantState {
            namespace,
            pool: (pool_capacity > 0).then(|| Arc::new(DecoderPool::new(pool_capacity))),
            store: (store_capacity > 0)
                .then(|| Arc::new(SketchStore::new(store_capacity, Arc::clone(&set)))),
            set: Mutex::new(set),
            quota,
            counters: TenantCounters::default(),
            round: parties.map(|n| Mutex::new(RoundSlot::new(n))),
        })
    }

    fn current_set(&self) -> Arc<Vec<u64>> {
        Arc::clone(&self.set.lock().expect("tenant set lock poisoned"))
    }

    /// Replace the tenant's set. One critical section for both views: concurrent
    /// replacements must not interleave the store update and the set swap in opposite
    /// orders, or the store would validate sessions against a different snapshot than
    /// they hold and bypass (fresh-encode) every checkout until the next replacement.
    /// Lock order is always set-lock → store-lock (the store's other users never hold
    /// both).
    fn replace(&self, set: Arc<Vec<u64>>) {
        let mut guard = self.set.lock().expect("tenant set lock poisoned");
        if let Some(store) = &self.store {
            store.replace_set(Arc::clone(&set));
        }
        *guard = set;
    }
}

/// One coordinator tenant's multi-party machinery. At most one round is in flight per
/// tenant at a time; the [`MultiCoordinator`] itself is sans-io, so the slot also
/// carries per-party outboxes ferrying its emitted frames to whichever poller owns each
/// spoke's connection (one spoke's frame can release barrier frames for spokes polled
/// by other threads).
struct RoundSlot {
    /// Round size (total parties, the tenant's resident set being party 0).
    parties: u32,
    /// `Some` while a round is in flight; `None` between rounds. The first multi-party
    /// join after a round completes starts the next one.
    coordinator: Option<MultiCoordinator>,
    /// Serialized coordinator→spoke frames awaiting pickup, keyed by party id. Every
    /// poller drains its own connections' entries each loop iteration; a wake byte
    /// makes that prompt rather than poll-cap bounded.
    outboxes: HashMap<u32, Vec<u8>>,
    /// Completed rounds, oldest first, until [`ServerHandle::take_multi_reports`]
    /// drains them (bounded so an unobserved server cannot grow without limit).
    reports: Vec<MultiReport>,
    /// When to stop waiting for the roster and run with whoever joined. Set when a
    /// round starts; `None` once it fires (or when the server runs without deadlines).
    join_deadline: Option<Instant>,
}

impl RoundSlot {
    fn new(parties: u32) -> RoundSlot {
        RoundSlot {
            parties,
            coordinator: None,
            outboxes: HashMap::new(),
            reports: Vec::new(),
            join_deadline: None,
        }
    }

    /// Serialize coordinator-emitted frames into the per-party outboxes.
    fn queue(&mut self, frames: Vec<(u32, Msg)>) {
        for (party, msg) in frames {
            self.outboxes.entry(party).or_default().extend_from_slice(&msg.to_bytes());
        }
    }

    /// If the in-flight round just finished, finalize it: charge each party's outcome
    /// to the tenant's stats shard and park the report for
    /// [`ServerHandle::take_multi_reports`].
    fn finish_if_done(&mut self, shared: &Shared, counters: &TenantCounters) {
        if self.coordinator.as_ref().map_or(false, |c| c.is_done()) {
            let report =
                self.coordinator.take().expect("round checked present").into_report();
            for p in &report.parties {
                if p.error.is_none() {
                    shared.stats.serve(counters, &p.comm);
                } else {
                    shared.stats.fail(Some(counters));
                }
            }
            if self.reports.len() >= 64 {
                self.reports.remove(0);
            }
            self.reports.push(report);
        }
    }
}

/// State shared by the poller threads and the handle.
struct Shared {
    cfg: SetxConfig,
    tenants: RwLock<HashMap<u32, Arc<TenantState>>>,
    stats: StatsInner,
    shutdown: AtomicBool,
    /// Most recent failed session: `(session_id, error)` — the minimal breadcrumb an
    /// operator needs before turning on real logging.
    last_failure: Mutex<Option<(u64, String)>>,
    next_session_id: AtomicU64,
    /// Per-connection inactivity deadline (refreshed on progress); `None` = no limit.
    session_timeout: Option<Duration>,
    /// Served sessions at least this slow get their trace dumped to stderr; `None`
    /// disables the dump (latency is still recorded in the histograms).
    slow_session_threshold: Option<Duration>,
    build_threads: usize,
    max_inflight: usize,
    workers: usize,
    busy_retry_hint_ms: u32,
    pool_capacity: usize,
    store_capacity: usize,
    tenant_quota: usize,
    /// One wake-pipe write end per poller; a byte interrupts that poller's `poll`.
    wakers: Vec<UnixStream>,
}

impl Shared {
    fn tenant(&self, namespace: u32) -> Option<Arc<TenantState>> {
        self.tenants.read().expect("tenant map poisoned").get(&namespace).cloned()
    }

    /// Interrupt every poller so cross-thread work (multi-party outbox deliveries,
    /// shutdown) is observed now rather than at the 250 ms poll cap.
    fn wake_all(&self) {
        for w in &self.wakers {
            let mut end: &UnixStream = w;
            let _ = end.write(&[1]);
        }
    }

    fn record_failure(&self, sid: u64, err: &SetxError) {
        *self.last_failure.lock().expect("failure lock poisoned") =
            Some((sid, err.to_string()));
    }
}

/// The namespace entry point: [`SetxServer::builder`] is how a server is made.
pub struct SetxServer;

impl SetxServer {
    /// Start building a server around `endpoint` — a validated [`Setx`] whose config
    /// every client must match (fingerprint-checked in the handshake, exactly as in a
    /// point-to-point run) and whose set becomes tenant 0's initial host set.
    pub fn builder(endpoint: Setx) -> ServerBuilder {
        ServerBuilder {
            endpoint,
            workers: 4,
            max_inflight: 64,
            pool_capacity: None,
            sketch_store_capacity: None,
            read_timeout: Some(Duration::from_secs(30)),
            write_timeout: Some(Duration::from_secs(30)),
            build_threads: 1,
            encode_threads: 1,
            busy_retry_hint_ms: 50,
            tenant_quota: None,
            extra_tenants: Vec::new(),
            multi_tenants: Vec::new(),
            metrics_addr: None,
            slow_session_threshold: None,
        }
    }
}

/// A running server. Dropping the handle shuts the server down (best-effort); call
/// [`ServerHandle::shutdown`] to do it explicitly and receive the final stats.
pub struct ServerHandle {
    shared: Arc<Shared>,
    addr: SocketAddr,
    pollers: Vec<JoinHandle<()>>,
    /// The metrics responder, when [`ServerBuilder::metrics_addr`] was set.
    metrics: Option<(SocketAddr, JoinHandle<()>)>,
}

impl ServerHandle {
    /// The bound address (resolves `:0` to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The bound metrics-endpoint address, when one was configured via
    /// [`ServerBuilder::metrics_addr`] (resolves `:0` to the actual port).
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics.as_ref().map(|(addr, _)| *addr)
    }

    /// Point-in-time stats snapshot: globals plus one shard per resident tenant
    /// (sorted by namespace); the `pool`/`sketch_store` blocks are sums across shards.
    pub fn stats(&self) -> ServerStats {
        snapshot_stats(&self.shared)
    }

    /// The most recent failed session, as `(session_id, error message)`.
    pub fn last_failure(&self) -> Option<(u64, String)> {
        self.shared.last_failure.lock().expect("failure lock poisoned").clone()
    }

    /// Register a new tenant at runtime. Returns `false` (and changes nothing) if the
    /// namespace is already resident. The tenant gets its own pool/store shards sized
    /// by the builder's capacities and the builder's quota.
    pub fn add_tenant(&self, namespace: u32, set: Vec<u64>) -> bool {
        let mut map = self.shared.tenants.write().expect("tenant map poisoned");
        if map.contains_key(&namespace) {
            return false;
        }
        map.insert(
            namespace,
            TenantState::new(
                namespace,
                Arc::new(set),
                self.shared.pool_capacity,
                self.shared.store_capacity,
                self.shared.tenant_quota,
                None,
            ),
        );
        true
    }

    /// Deregister a tenant. In-flight sessions of the tenant finish normally (they
    /// hold the tenant state alive); *new* connections for the namespace are answered
    /// `Busy`. Returns `false` if the namespace was not resident. Note the removed
    /// shard's counters leave the [`ServerStats::tenants`] breakdown with it.
    pub fn remove_tenant(&self, namespace: u32) -> bool {
        self.shared.tenants.write().expect("tenant map poisoned").remove(&namespace).is_some()
    }

    /// Replace one tenant's host set. In-flight sessions finish against the set they
    /// started with; new sessions reconcile against the replacement. Decoders parked
    /// in the tenant's pool for the old set become unreachable (their cache keys no
    /// longer validate) and age out by LRU; resident host sketches are *maintained*
    /// across the change — the [`SketchStore`] applies §4 streaming ±1 updates over
    /// the set diff (or re-encodes when the diff is larger than the set), so the
    /// encode-side cache stays warm through churn. Returns `false` if the namespace is
    /// not resident.
    pub fn replace_tenant_set(&self, namespace: u32, set: Vec<u64>) -> bool {
        match self.shared.tenant(namespace) {
            Some(t) => {
                t.replace(Arc::new(set));
                true
            }
            None => false,
        }
    }

    /// Replace tenant 0's host set (the pre-tenancy API, kept for callers that serve a
    /// single set).
    pub fn replace_set(&self, set: Vec<u64>) {
        self.replace_tenant_set(0, set);
    }

    /// Drain the completed multi-party rounds of a coordinator tenant, oldest first.
    /// Empty for unknown namespaces, for tenants without a coordinator role (see
    /// [`ServerBuilder::multi_tenant`]), and when no round has finished since the last
    /// call.
    pub fn take_multi_reports(&self, namespace: u32) -> Vec<MultiReport> {
        self.shared
            .tenant(namespace)
            .and_then(|t| {
                t.round.as_ref().map(|r| {
                    std::mem::take(&mut r.lock().expect("round slot poisoned").reports)
                })
            })
            .unwrap_or_default()
    }

    /// Graceful shutdown: stop accepting, drain every resident connection to
    /// completion, join the pollers, and return the final stats.
    pub fn shutdown(mut self) -> ServerStats {
        self.shutdown_inner();
        self.stats()
    }

    fn shutdown_inner(&mut self) {
        if !self.shared.shutdown.swap(true, Ordering::SeqCst) {
            // One byte down each wake pipe interrupts the pollers' `poll` immediately;
            // they re-read the flag, stop polling the listener, and drain.
            self.shared.wake_all();
        }
        for handle in self.pollers.drain(..) {
            let _ = handle.join();
        }
        // The metrics responder watches the same shutdown flag; it notices within one
        // accept-poll tick once the pollers are gone.
        if let Some((_, handle)) = self.metrics.take() {
            let _ = handle.join();
        }
    }
}

/// Snapshot the shared counters as a [`ServerStats`] — used by [`ServerHandle::stats`]
/// and by the metrics responder thread, which has no handle.
fn snapshot_stats(shared: &Shared) -> ServerStats {
    let s = &shared.stats;
    let mut tenants: Vec<TenantStats> = {
        let map = shared.tenants.read().expect("tenant map poisoned");
        map.values()
            .map(|t| {
                t.counters.snapshot(
                    t.namespace,
                    t.quota,
                    t.pool.as_ref().map(|p| p.stats()).unwrap_or_default(),
                    t.store.as_ref().map(|st| st.stats()).unwrap_or_default(),
                )
            })
            .collect()
    };
    tenants.sort_by_key(|t| t.namespace);
    let mut pool = PoolStats::default();
    let mut store = SketchStoreStats::default();
    for t in &tenants {
        pool.hits += t.pool.hits;
        pool.misses += t.pool.misses;
        pool.evictions += t.pool.evictions;
        pool.parked += t.pool.parked;
        pool.capacity += t.pool.capacity;
        store.hits += t.sketch_store.hits;
        store.misses += t.sketch_store.misses;
        store.stale_bypasses += t.sketch_store.stale_bypasses;
        store.encodes += t.sketch_store.encodes;
        store.incremental_updates += t.sketch_store.incremental_updates;
        store.full_rebuilds += t.sketch_store.full_rebuilds;
        store.resident += t.sketch_store.resident;
        store.capacity += t.sketch_store.capacity;
    }
    ServerStats {
        sessions_accepted: s.sessions_accepted.load(Ordering::Relaxed),
        sessions_served: s.sessions_served.load(Ordering::Relaxed),
        sessions_failed: s.sessions_failed.load(Ordering::Relaxed),
        sessions_rejected: s.sessions_rejected.load(Ordering::Relaxed),
        unrouted_failed: s.unrouted_failed.load(Ordering::Relaxed),
        unrouted_rejected: s.unrouted_rejected.load(Ordering::Relaxed),
        protocol_faults: s.protocol_faults.load(Ordering::Relaxed),
        unrouted_protocol_faults: s.unrouted_protocol_faults.load(Ordering::Relaxed),
        phase_bytes: [
            s.phase_bytes[0].load(Ordering::Relaxed),
            s.phase_bytes[1].load(Ordering::Relaxed),
            s.phase_bytes[2].load(Ordering::Relaxed),
            s.phase_bytes[3].load(Ordering::Relaxed),
        ],
        raw_bytes: s.raw_bytes.load(Ordering::Relaxed),
        pool,
        sketch_store: store,
        inflight: s.inflight.load(Ordering::SeqCst),
        peak_inflight: s.peak_inflight.load(Ordering::Relaxed),
        peak_workers: s.peak_workers.load(Ordering::Relaxed),
        workers: shared.workers,
        max_inflight_sessions: shared.max_inflight,
        latency: s.latency.snapshot(),
        tenants,
    }
}

/// The metrics responder: a deliberately minimal HTTP/1.0 server on its own thread.
/// Every `GET` answers with one [`ServerStats::to_prometheus`] snapshot; anything else
/// gets a 400. One request per connection (`Connection: close`), bounded read/write
/// timeouts so a stuck scraper cannot wedge the thread, and the listener is
/// non-blocking so the shared shutdown flag is honored within one poll tick.
fn metrics_loop(shared: &Arc<Shared>, listener: &TcpListener) {
    while !shared.shutdown.load(Ordering::SeqCst) {
        let (mut stream, _) = match listener.accept() {
            Ok(pair) => pair,
            Err(_) => {
                // WouldBlock or a transient accept error: sleep one tick, re-check the
                // shutdown flag.
                std::thread::sleep(Duration::from_millis(25));
                continue;
            }
        };
        let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
        let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
        let mut req = [0u8; 1024];
        let n = stream.read(&mut req).unwrap_or(0);
        let (status, body) = if req[..n].starts_with(b"GET ") {
            ("200 OK", snapshot_stats(shared).to_prometheus())
        } else {
            ("400 Bad Request", String::new())
        };
        let resp = format!(
            "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        let _ = stream.write_all(resp.as_bytes());
        let _ = stream.shutdown(std::net::Shutdown::Both);
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

impl std::fmt::Debug for ServerHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerHandle")
            .field("addr", &self.addr)
            .field("workers", &self.shared.workers)
            .field("stats", &self.stats())
            .finish()
    }
}

// ---------------------------------------------------------------------------
// The per-connection state machine.
// ---------------------------------------------------------------------------

enum ConnState {
    /// Admitted; waiting for the opening `EstHello` to learn the tenant.
    AwaitRoute,
    /// Routed: a live sans-io endpoint pinned to its tenant.
    Live { endpoint: Endpoint<'static>, tenant: Arc<TenantState> },
    /// Routed as one spoke of a coordinator tenant's multi-party round: frames flow
    /// through the tenant's shared [`RoundSlot`] rather than a private endpoint.
    MultiParty { tenant: Arc<TenantState>, party: u32 },
    /// Flushing a final `Busy` frame, then closing (never routed to a session).
    Closing,
}

struct Conn {
    stream: TcpStream,
    sid: u64,
    /// Whether this connection occupies a global admission slot (rejected-at-accept
    /// connections do not).
    holds_slot: bool,
    state: ConnState,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    /// Bytes of `write_buf` already written to the socket.
    wpos: usize,
    deadline: Option<Instant>,
    /// Admission time — the start of the session's wall-time measurement
    /// ([`StatsInner::record_latency`] at finalize).
    started: Instant,
    saw_eof: bool,
    done: Option<Result<Box<SetxReport>, SetxError>>,
}

impl Conn {
    fn admitted(stream: TcpStream, sid: u64, timeout: Option<Duration>) -> Conn {
        Conn {
            stream,
            sid,
            holds_slot: true,
            state: ConnState::AwaitRoute,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            wpos: 0,
            deadline: timeout.map(|t| Instant::now() + t),
            started: Instant::now(),
            saw_eof: false,
            done: None,
        }
    }

    /// A connection turned away at accept: owes the peer one `Busy` frame, holds no
    /// admission slot, and is given a short grace deadline to flush.
    fn rejecting(stream: TcpStream, hint: u32, namespace: u32) -> Conn {
        let mut conn = Conn {
            stream,
            sid: 0,
            holds_slot: false,
            state: ConnState::Closing,
            read_buf: Vec::new(),
            write_buf: Vec::new(),
            wpos: 0,
            deadline: Some(Instant::now() + Duration::from_millis(500)),
            started: Instant::now(),
            saw_eof: false,
            done: None,
        };
        conn.queue(&Msg::Busy { retry_after_ms: hint, namespace });
        conn
    }

    fn queue(&mut self, msg: &Msg) {
        self.write_buf.extend_from_slice(&msg.to_bytes());
    }

    fn flushed(&self) -> bool {
        self.wpos == self.write_buf.len()
    }

    /// The poll events this connection currently cares about.
    fn interest(&self) -> i16 {
        let mut ev = 0;
        if self.done.is_none() && !matches!(self.state, ConnState::Closing) {
            ev |= POLLIN;
        }
        if !self.flushed() {
            ev |= POLLOUT;
        }
        ev
    }
}

// ---------------------------------------------------------------------------
// The readiness driver.
// ---------------------------------------------------------------------------

fn poller_loop(shared: &Arc<Shared>, listener: &TcpListener, wake: &UnixStream) {
    let mut conns: Vec<Conn> = Vec::new();
    loop {
        let draining = shared.shutdown.load(Ordering::SeqCst);
        if draining && conns.is_empty() {
            break;
        }
        let mut fds: Vec<PollFd> = Vec::with_capacity(conns.len() + 2);
        fds.push(PollFd { fd: wake.as_raw_fd(), events: POLLIN, revents: 0 });
        let listener_polled = !draining;
        if listener_polled {
            fds.push(PollFd { fd: listener.as_raw_fd(), events: POLLIN, revents: 0 });
        }
        let base = fds.len();
        for c in &conns {
            fds.push(PollFd { fd: c.stream.as_raw_fd(), events: c.interest(), revents: 0 });
        }

        let timeout = poll_timeout(&conns);
        let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u64, timeout) };
        if n < 0 {
            // EINTR or a transient kernel error: re-poll.
            continue;
        }
        if n > 0 {
            let busy = shared.stats.busy_workers.fetch_add(1, Ordering::SeqCst) + 1;
            shared.stats.peak_workers.fetch_max(busy, Ordering::SeqCst);
            if fds[0].revents != 0 {
                drain_wake(wake);
            }
            if listener_polled && fds[1].revents != 0 {
                accept_ready(shared, listener, &mut conns);
            }
            // `accept_ready` only appends, so the fd→conn index mapping of the
            // pre-accept snapshot is still valid.
            for i in 0..(fds.len() - base) {
                let revents = fds[base + i].revents;
                if revents != 0 {
                    handle_events(shared, &mut conns[i], revents);
                }
            }
            shared.stats.busy_workers.fetch_sub(1, Ordering::SeqCst);
        }

        // Cross-poller deliveries: a frame handled on another thread may have queued
        // multi-party bytes for connections this poller owns.
        for conn in conns.iter_mut() {
            if drain_multi_outbox(shared, conn) {
                if let Some(t) = shared.session_timeout {
                    conn.deadline = Some(Instant::now() + t);
                }
            }
        }

        // Close finished connections and enforce deadlines (reverse order so
        // `swap_remove` never disturbs an unvisited index).
        let now = Instant::now();
        let mut j = conns.len();
        while j > 0 {
            j -= 1;
            let mut timed_out = conns[j].deadline.map_or(false, |d| now >= d);
            if timed_out && multi_barrier_parked(&conns[j]) {
                // Alive by construction: the round is waiting on *other* parties.
                conns[j].deadline = shared.session_timeout.map(|t| now + t);
                timed_out = false;
            }
            if timed_out
                && conns[j].done.is_none()
                && !matches!(conns[j].state, ConnState::Closing)
            {
                conns[j].done = Some(Err(SetxError::Io(std::io::Error::new(
                    std::io::ErrorKind::TimedOut,
                    "per-connection deadline elapsed",
                ))));
            }
            if timed_out || should_close(&conns[j]) {
                let conn = conns.swap_remove(j);
                finalize(shared, conn);
            }
        }
    }
}

/// Next poll timeout: the nearest connection deadline, capped at 250 ms so flag
/// changes are observed promptly even without a wake byte.
fn poll_timeout(conns: &[Conn]) -> i32 {
    let mut timeout: u128 = 250;
    if let Some(nearest) = conns.iter().filter_map(|c| c.deadline).min() {
        let now = Instant::now();
        let until =
            if nearest <= now { 0 } else { nearest.duration_since(now).as_millis() + 1 };
        timeout = timeout.min(until);
    }
    timeout as i32
}

fn drain_wake(wake: &UnixStream) {
    let mut buf = [0u8; 64];
    let mut end: &UnixStream = wake;
    while matches!(end.read(&mut buf), Ok(n) if n > 0) {}
}

/// Deadline for an admitted connection to deliver a routable `EstHello`. Applied even
/// when the builder disabled session timeouts: a half-open peer (partial frame header,
/// then silence — no FIN, so no EOF ever arrives) must not park an admission slot
/// forever. Routed sessions fall back to the configured `session_timeout`.
const ROUTING_DEADLINE: Duration = Duration::from_secs(30);

/// Accept everything the listener has ready. Global admission happens here, before any
/// protocol work: an over-cap connection gets a `Busy` frame and (at most) a brief stay
/// in the poll set to flush it.
fn accept_ready(shared: &Shared, listener: &TcpListener, conns: &mut Vec<Conn>) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _peer)) => s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            // Transient (ECONNABORTED, EMFILE, or another poller won the race): let the
            // next readiness event retry rather than spinning here.
            Err(_) => break,
        };
        stream.set_nodelay(true).ok();
        if stream.set_nonblocking(true).is_err() {
            continue;
        }
        let admitted = shared
            .stats
            .inflight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                (v < shared.max_inflight).then(|| v + 1)
            });
        match admitted {
            Err(_) => {
                shared.stats.reject(None);
                let mut conn =
                    Conn::rejecting(stream, shared.busy_retry_hint_ms, 0);
                flush_write(&mut conn);
                if !should_close(&conn) {
                    conns.push(conn);
                }
            }
            Ok(prev) => {
                shared.stats.peak_inflight.fetch_max(prev + 1, Ordering::SeqCst);
                let sid = shared.next_session_id.fetch_add(1, Ordering::Relaxed);
                conns.push(Conn::admitted(
                    stream,
                    sid,
                    shared.session_timeout.or(Some(ROUTING_DEADLINE)),
                ));
            }
        }
    }
}

/// React to one connection's readiness events: read everything available, pump whole
/// frames through the state machine, flush the write buffer, and refresh the deadline
/// on progress.
fn handle_events(shared: &Shared, conn: &mut Conn, revents: i16) {
    let mut progressed = false;
    if conn.done.is_none() && revents & (POLLIN | POLLHUP | POLLERR | POLLNVAL) != 0 {
        progressed |= fill_read(conn);
        pump_frames(shared, conn);
    }
    if !conn.flushed() {
        progressed |= flush_write(conn);
    }
    if conn.saw_eof && conn.done.is_none() && !matches!(conn.state, ConnState::Closing) {
        conn.done = Some(Err(SetxError::PeerClosed { during: "server session" }));
    }
    if progressed && conn.done.is_none() {
        if let Some(t) = shared.session_timeout {
            conn.deadline = Some(Instant::now() + t);
        }
    }
}

/// Drain the socket into the read buffer. Returns whether any bytes arrived.
fn fill_read(conn: &mut Conn) -> bool {
    let mut progressed = false;
    let mut chunk = [0u8; 16 * 1024];
    loop {
        match conn.stream.read(&mut chunk) {
            Ok(0) => {
                conn.saw_eof = true;
                break;
            }
            Ok(n) => {
                conn.read_buf.extend_from_slice(&chunk[..n]);
                progressed = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                if conn.done.is_none() {
                    conn.done = Some(Err(SetxError::Io(e)));
                }
                break;
            }
        }
    }
    progressed
}

/// Feed every complete frame in the read buffer through the connection state machine.
/// [`frame_extent`] distinguishes "need more bytes" from corruption, so a slow sender
/// costs nothing and a malformed one is torn down with a typed error.
fn pump_frames(shared: &Shared, conn: &mut Conn) {
    while conn.done.is_none() && !matches!(conn.state, ConnState::Closing) {
        let extent = match frame_extent(&conn.read_buf) {
            Ok(Some(extent)) => extent,
            Ok(None) => break,
            Err(why) => {
                conn.done = Some(Err(SetxError::MalformedFrame(why)));
                break;
            }
        };
        let parsed = Msg::from_bytes(&conn.read_buf[..extent]);
        let Some((msg, used)) = parsed else {
            conn.done = Some(Err(SetxError::MalformedFrame("unparseable frame")));
            break;
        };
        if used != extent {
            conn.done = Some(Err(SetxError::MalformedFrame("frame length mismatch")));
            break;
        }
        conn.read_buf.drain(..extent);
        match conn.state {
            ConnState::AwaitRoute => route(shared, conn, &msg),
            ConnState::Live { .. } => feed_live(conn, &msg),
            ConnState::MultiParty { .. } => feed_multi(shared, conn, &msg),
            ConnState::Closing => {}
        }
    }
}

/// First frame of an admitted connection: must be an `EstHello`; its namespace selects
/// the tenant. On success the connection becomes a live session whose endpoint owns a
/// snapshot of the tenant's set and borrows the tenant's pool/store shards; the same
/// `EstHello` is then fed to the fresh endpoint (the server's own opening frames are
/// queued first, preserving the order the blocking pump produced).
fn route(shared: &Shared, conn: &mut Conn, msg: &Msg) {
    let (ns, party) = match msg {
        Msg::EstHello { namespace, party, .. } => (*namespace, *party),
        _ => {
            conn.done = Some(Err(SetxError::MalformedFrame("expected est-hello")));
            return;
        }
    };
    let Some(tenant) = shared.tenant(ns) else {
        shared.stats.reject(None);
        reject(shared, conn, ns);
        return;
    };
    if party.is_some() {
        route_multi(shared, conn, msg, tenant);
        return;
    }
    let live = tenant.counters.inflight.fetch_add(1, Ordering::SeqCst) + 1;
    if live > tenant.quota {
        tenant.counters.inflight.fetch_sub(1, Ordering::SeqCst);
        shared.stats.reject(Some(&tenant.counters));
        reject(shared, conn, ns);
        return;
    }
    shared.stats.route_accepted(&tenant.counters);

    let mut cfg = shared.cfg;
    cfg.engine.namespace = ns;
    let mut endpoint = Endpoint::new_owned(cfg, tenant.current_set(), false);
    let mut cache = DecoderCache::with_build_threads(shared.build_threads);
    if let Some(pool) = &tenant.pool {
        cache = cache.with_shared_store(Arc::clone(pool) as Arc<dyn DecoderStore>);
    }
    endpoint.set_cache(cache);
    if let Some(store) = &tenant.store {
        endpoint.set_sketch_source(Arc::clone(store) as Arc<dyn SketchSource>);
    }
    for m in endpoint.start() {
        conn.queue(&m);
    }
    conn.state = ConnState::Live { endpoint, tenant };
    // Routed: swap the unconditional routing deadline for the configured session
    // deadline (clearing it when the builder disabled timeouts).
    conn.deadline = shared.session_timeout.map(|t| Instant::now() + t);
    feed_live(conn, msg);
}

/// Turn a connection away with a `Busy` frame carrying the tenant id, then close once
/// the frame is flushed (bounded by a short grace deadline — a non-reading peer cannot
/// park the slot).
fn reject(shared: &Shared, conn: &mut Conn, namespace: u32) {
    conn.queue(&Msg::Busy { retry_after_ms: shared.busy_retry_hint_ms, namespace });
    conn.state = ConnState::Closing;
    conn.deadline = Some(Instant::now() + Duration::from_millis(500));
    flush_write(conn);
}

/// A multi-party hello on an admitted connection: the spoke joins (or starts) its
/// tenant's round. The shared coordinator answers through the slot's outboxes — this
/// connection's entry is pulled immediately; frames released for *other* spokes stay
/// queued for their owning pollers, which a wake byte summons.
fn route_multi(shared: &Shared, conn: &mut Conn, msg: &Msg, tenant: Arc<TenantState>) {
    let Some(round) = &tenant.round else {
        // Not a coordinator tenant: a multi-party join has nowhere to go.
        shared.stats.reject(Some(&tenant.counters));
        reject(shared, conn, tenant.namespace);
        return;
    };
    let live = tenant.counters.inflight.fetch_add(1, Ordering::SeqCst) + 1;
    if live > tenant.quota {
        tenant.counters.inflight.fetch_sub(1, Ordering::SeqCst);
        shared.stats.reject(Some(&tenant.counters));
        reject(shared, conn, tenant.namespace);
        return;
    }
    let mut slot = round.lock().expect("round slot poisoned");
    if slot.coordinator.is_none() {
        let mut cfg = shared.cfg;
        cfg.engine.namespace = tenant.namespace;
        match MultiCoordinator::new(&cfg, tenant.current_set(), slot.parties) {
            Ok(coord) => {
                slot.outboxes.clear();
                slot.coordinator = Some(coord);
                slot.join_deadline =
                    shared.session_timeout.map(|t| Instant::now() + t);
            }
            Err(_) => {
                drop(slot);
                tenant.counters.inflight.fetch_sub(1, Ordering::SeqCst);
                shared.stats.reject(Some(&tenant.counters));
                reject(shared, conn, tenant.namespace);
                return;
            }
        }
    }
    let coord = slot.coordinator.as_mut().expect("round just ensured");
    match coord.route_hello(msg) {
        Ok((party, frames)) => {
            let fan_out = frames.iter().any(|(p, _)| *p != party);
            slot.queue(frames);
            let mine = slot.outboxes.remove(&party).unwrap_or_default();
            drop(slot);
            shared.stats.route_accepted(&tenant.counters);
            conn.write_buf.extend_from_slice(&mine);
            conn.state = ConnState::MultiParty { tenant, party };
            // Same deadline swap as the two-party route: routing is done.
            conn.deadline = shared.session_timeout.map(|t| Instant::now() + t);
            if fan_out {
                shared.wake_all();
            }
        }
        // Duplicate ids, mid-round joins, count mismatches: this *connection* is turned
        // away with `Busy`; the round and every joined spoke stay intact.
        Err(_) => {
            drop(slot);
            tenant.counters.inflight.fetch_sub(1, Ordering::SeqCst);
            shared.stats.reject(Some(&tenant.counters));
            reject(shared, conn, tenant.namespace);
        }
    }
}

/// Feed one frame from a routed spoke to its tenant's shared coordinator, then pick up
/// whatever the round owes *this* connection (frames for other spokes stay in the
/// outboxes for their owning pollers).
fn feed_multi(shared: &Shared, conn: &mut Conn, msg: &Msg) {
    let (tenant, party) = match &conn.state {
        ConnState::MultiParty { tenant, party } => (Arc::clone(tenant), *party),
        _ => return,
    };
    let Some(round) = &tenant.round else { return };
    let mut slot = round.lock().expect("round slot poisoned");
    let Some(coord) = slot.coordinator.as_mut() else {
        // The round this spoke belonged to already finalized (completed, or the spoke
        // was dropped at a deadline): any straggler frame just closes the connection.
        drop(slot);
        conn.state = ConnState::Closing;
        conn.deadline = Some(Instant::now() + Duration::from_millis(500));
        return;
    };
    let frames = coord.on_msg(party, msg);
    let fan_out = frames.iter().any(|(p, _)| *p != party);
    slot.queue(frames);
    slot.finish_if_done(shared, &tenant.counters);
    let mine = slot.outboxes.remove(&party).unwrap_or_default();
    drop(slot);
    conn.write_buf.extend_from_slice(&mine);
    if fan_out {
        shared.wake_all();
    }
}

/// Deliver any outbox bytes a multi-party round owes this connection — they may have
/// been queued by a frame *another* poller processed — and fire the round's join
/// deadline when it comes due (every poller runs this each loop iteration, so the
/// check is at worst poll-cap late). Returns whether bytes moved.
fn drain_multi_outbox(shared: &Shared, conn: &mut Conn) -> bool {
    let pending = match &conn.state {
        ConnState::MultiParty { tenant, party } => {
            let Some(round) = &tenant.round else { return false };
            let mut slot = round.lock().expect("round slot poisoned");
            let join_due = slot.join_deadline.map_or(false, |d| Instant::now() >= d)
                && slot.coordinator.as_ref().map_or(false, |c| c.roster_open());
            if join_due {
                slot.join_deadline = None;
                let frames =
                    slot.coordinator.as_mut().expect("roster checked").deadline_join();
                let fan_out = !frames.is_empty();
                slot.queue(frames);
                slot.finish_if_done(shared, &tenant.counters);
                if fan_out {
                    shared.wake_all();
                }
            }
            slot.outboxes.remove(party)
        }
        _ => None,
    };
    match pending {
        Some(bytes) if !bytes.is_empty() => {
            conn.write_buf.extend_from_slice(&bytes);
            flush_write(conn);
            true
        }
        _ => false,
    }
}

/// Whether a multi-party spoke's expired deadline should be forgiven: the round is in
/// flight and is *not* waiting on this party — it is parked at a barrier for the other
/// parties, so its silence is legitimate. A party the round *is* awaiting stays subject
/// to the deadline; that is exactly the stalled-spoke case, surfaced as
/// [`MultiError::PartyTimeout`] when [`finalize`] drops it from the round.
fn multi_barrier_parked(conn: &Conn) -> bool {
    match &conn.state {
        ConnState::MultiParty { tenant, party } => match &tenant.round {
            Some(round) => {
                let slot = round.lock().expect("round slot poisoned");
                match &slot.coordinator {
                    Some(c) => c.joined(*party) && !c.awaiting(*party),
                    None => false,
                }
            }
            None => false,
        },
        _ => false,
    }
}

/// Feed one frame to a live endpoint and queue whatever it owes the peer.
fn feed_live(conn: &mut Conn, msg: &Msg) {
    let step = match &mut conn.state {
        ConnState::Live { endpoint, .. } => endpoint.on_msg(msg),
        _ => return,
    };
    match step {
        Step::Send(msgs) => {
            for m in &msgs {
                conn.queue(m);
            }
        }
        Step::Continue => {}
        Step::Finish(msgs, report) => {
            for m in &msgs {
                conn.queue(m);
            }
            conn.done = Some(Ok(report));
        }
        Step::Fatal(msgs, err) => {
            for m in &msgs {
                conn.queue(m);
            }
            conn.done = Some(Err(err));
        }
    }
}

/// Write as much of the pending buffer as the socket accepts. A hard write failure
/// abandons the unflushable tail (so the close is not deferred to the deadline) and
/// records an error unless an outcome is already set. Returns whether bytes moved.
fn flush_write(conn: &mut Conn) -> bool {
    let mut progressed = false;
    while conn.wpos < conn.write_buf.len() {
        match conn.stream.write(&conn.write_buf[conn.wpos..]) {
            Ok(0) => {
                if conn.done.is_none() {
                    conn.done =
                        Some(Err(SetxError::PeerClosed { during: "server write" }));
                }
                conn.wpos = conn.write_buf.len();
                break;
            }
            Ok(n) => {
                conn.wpos += n;
                progressed = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                if conn.done.is_none() {
                    conn.done = Some(Err(SetxError::Io(e)));
                }
                conn.wpos = conn.write_buf.len();
                break;
            }
        }
    }
    if conn.wpos == conn.write_buf.len() && conn.wpos > 0 {
        conn.write_buf.clear();
        conn.wpos = 0;
    }
    progressed
}

fn should_close(conn: &Conn) -> bool {
    match (&conn.state, &conn.done) {
        (ConnState::Closing, done) => conn.flushed() || done.is_some(),
        (_, Some(Err(_))) => true,
        (_, Some(Ok(_))) => conn.flushed(),
        (_, None) => false,
    }
}

/// Whether a session-ending error was a *protocol fault* — a malformed or
/// out-of-phase frame (corrupting link, hostile peer) — as opposed to a
/// timeout/disconnect. The typed subset [`StatsInner::protocol_fault`] counts;
/// the chaos suite asserts a faulted `Conn` frees its slot and lands here
/// without poisoning its tenant's shards.
fn is_protocol_fault(err: &SetxError) -> bool {
    matches!(err, SetxError::MalformedFrame(_) | SetxError::Protocol(_))
}

/// Account for a closed connection: release its admission slots and charge its outcome
/// to the right scope (tenant shard for routed sessions, the unrouted counters for
/// connections that never reached one; `Closing` connections were already counted when
/// rejected).
fn finalize(shared: &Shared, conn: Conn) {
    if conn.holds_slot {
        shared.stats.inflight.fetch_sub(1, Ordering::SeqCst);
    }
    match conn.state {
        ConnState::Closing => {}
        ConnState::AwaitRoute => {
            shared.stats.fail(None);
            let err = match conn.done {
                Some(Err(err)) => err,
                _ => SetxError::PeerClosed { during: "routing" },
            };
            if is_protocol_fault(&err) {
                shared.stats.protocol_fault(None);
            }
            shared.record_failure(conn.sid, &err);
        }
        ConnState::Live { tenant, .. } => {
            tenant.counters.inflight.fetch_sub(1, Ordering::SeqCst);
            match conn.done {
                Some(Ok(report)) => {
                    shared.stats.serve(&tenant.counters, &report.comm);
                    // Wall time accept→finalize: only served sessions are timed, so
                    // the tenant histograms merge exactly to the global one.
                    let elapsed = conn.started.elapsed();
                    shared.stats.record_latency(
                        &tenant.counters,
                        u64::try_from(elapsed.as_nanos()).unwrap_or(u64::MAX),
                    );
                    if shared.slow_session_threshold.is_some_and(|thr| elapsed >= thr) {
                        eprintln!(
                            "[slow-session] sid={} tenant={} elapsed={}ms\n{}",
                            conn.sid,
                            tenant.namespace,
                            elapsed.as_millis(),
                            report.trace.render()
                        );
                    }
                }
                Some(Err(err)) => {
                    shared.stats.fail(Some(&tenant.counters));
                    if is_protocol_fault(&err) {
                        shared.stats.protocol_fault(Some(&tenant.counters));
                    }
                    shared.record_failure(conn.sid, &err);
                }
                None => {
                    shared.stats.fail(Some(&tenant.counters));
                    shared.record_failure(
                        conn.sid,
                        &SetxError::PeerClosed { during: "server session" },
                    );
                }
            }
        }
        ConnState::MultiParty { tenant, party } => {
            tenant.counters.inflight.fetch_sub(1, Ordering::SeqCst);
            let mut dropped = false;
            if let Some(round) = &tenant.round {
                let mut slot = round.lock().expect("round slot poisoned");
                if let Some(coord) = slot.coordinator.as_mut() {
                    // Losing the connection mid-round drops the party so the other
                    // N−1 spokes are not wedged. A spoke that already completed the
                    // round is immune (`drop_party` is a no-op for it), and a round
                    // already finalized has no coordinator to consult.
                    dropped = coord.joined(party);
                    let frames =
                        coord.drop_party(party, MultiError::PartyTimeout { party });
                    slot.queue(frames);
                    slot.finish_if_done(shared, &tenant.counters);
                }
                drop(slot);
                if dropped {
                    shared.wake_all();
                }
            }
            if dropped {
                if let Some(Err(err)) = &conn.done {
                    if is_protocol_fault(err) {
                        shared.stats.protocol_fault(Some(&tenant.counters));
                    }
                    shared.record_failure(conn.sid, err);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::setx::transport::TcpTransport;

    #[test]
    fn bind_and_shutdown_without_clients() {
        let set: Vec<u64> = (0..500).collect();
        let endpoint = Setx::builder(&set).build().unwrap();
        let server =
            SetxServer::builder(endpoint).workers(2).bind("127.0.0.1:0").unwrap();
        assert_ne!(server.local_addr().port(), 0);
        let stats = server.shutdown();
        assert_eq!(stats.sessions_accepted, 0);
        assert_eq!(stats.sessions_rejected, 0);
        assert_eq!(stats.workers, 2);
        assert_eq!(stats.tenants.len(), 1);
        assert_eq!(stats.tenants[0].namespace, 0);
    }

    #[test]
    fn one_client_round_trip_and_stats() {
        let (a, b) = synth::overlap_pair(2_000, 30, 40, 5);
        let server = SetxServer::builder(Setx::builder(&b).build().unwrap())
            .workers(1)
            .bind("127.0.0.1:0")
            .unwrap();
        let addr = server.local_addr();
        let alice = Setx::builder(&a).build().unwrap();
        let mut transport = TcpTransport::connect(addr).unwrap();
        let report = alice.run(&mut transport).unwrap();
        assert_eq!(report.local_unique, synth::difference(&a, &b));
        assert_eq!(report.intersection, synth::intersect(&a, &b));
        // The poller finishes asynchronously after the client's last frame lands.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while server.stats().sessions_served == 0 && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        let stats = server.shutdown();
        assert_eq!(stats.sessions_served, 1, "last failure: {:?}", stats);
        assert_eq!(stats.sessions_failed, 0);
        assert!(stats.total_bytes() > 0);
        assert_eq!(stats.peak_workers, 1);
        let t0 = stats.tenant(0).expect("tenant 0 resident");
        assert_eq!(t0.sessions_served, 1);
        assert_eq!(t0.phase_bytes, stats.phase_bytes);
    }

    #[test]
    fn replace_set_serves_the_new_set() {
        let (a, b1) = synth::overlap_pair(1_500, 20, 30, 8);
        let server = SetxServer::builder(Setx::builder(&b1).build().unwrap())
            .workers(1)
            .bind("127.0.0.1:0")
            .unwrap();
        let addr = server.local_addr();
        let alice = Setx::builder(&a).build().unwrap();
        let r1 = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
        assert_eq!(r1.intersection, synth::intersect(&a, &b1));
        // Mutate the host set: drop half of B's unique elements and half the overlap.
        let mut b2 = b1.clone();
        b2.truncate(b1.len() - 25);
        server.replace_set(b2.clone());
        let r2 = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
        assert_eq!(r2.intersection, synth::intersect(&a, &b2));
        server.shutdown();
    }

    #[test]
    fn tenants_can_be_added_and_removed() {
        let (a, b) = synth::overlap_pair(1_200, 15, 25, 11);
        let host0: Vec<u64> = (10_000_000..10_001_000).collect();
        let server = SetxServer::builder(Setx::builder(&host0).build().unwrap())
            .workers(2)
            .bind("127.0.0.1:0")
            .unwrap();
        let addr = server.local_addr();
        assert!(server.add_tenant(7, b.clone()));
        assert!(!server.add_tenant(7, b.clone()), "duplicate namespace must refuse");

        let alice = Setx::builder(&a).namespace(7).build().unwrap();
        let report = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap();
        assert_eq!(report.intersection, synth::intersect(&a, &b));

        assert!(server.remove_tenant(7));
        assert!(!server.remove_tenant(7));
        let err = alice.run(&mut TcpTransport::connect(addr).unwrap()).unwrap_err();
        match err {
            SetxError::ServerBusy { namespace, .. } => assert_eq!(namespace, 7),
            other => panic!("expected ServerBusy for an evicted tenant, got {other:?}"),
        }
        let stats = server.shutdown();
        assert_eq!(stats.unrouted_rejected, 1);
        assert_eq!(stats.sessions_served, 1);
    }

    #[test]
    fn multi_join_to_a_plain_tenant_is_rejected_busy() {
        use crate::setx::multi::Party;
        let set: Vec<u64> = (0..400).collect();
        let endpoint = Setx::builder(&set).build().unwrap();
        let cfg = *endpoint.config();
        let server =
            SetxServer::builder(endpoint).workers(1).bind("127.0.0.1:0").unwrap();
        let addr = server.local_addr();
        // Tenant 0 is an ordinary two-party tenant; a multi-party join must be turned
        // away with a typed Busy, not a hang or a protocol fault.
        let mut party = Party::new(&cfg, (0..100).collect(), 1, 3).unwrap();
        let mut transport = TcpTransport::connect(addr).unwrap();
        let err = party.run(&mut transport).unwrap_err();
        match err {
            SetxError::ServerBusy { namespace, .. } => assert_eq!(namespace, 0),
            other => panic!("expected ServerBusy for a plain tenant, got {other:?}"),
        }
        let stats = server.shutdown();
        assert_eq!(stats.sessions_served, 0);
        assert_eq!(stats.sessions_rejected, 1);
    }
}
