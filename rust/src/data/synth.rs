//! Synthetic set-pair generation with exactly controlled cardinalities (§7.2 workloads).
//!
//! Every element id is a fresh 64-bit value from a seeded PRNG (the "hash identifier"
//! regime of assumption (1): the universe is astronomically larger than the sets, so random
//! ids never collide in practice — we still deduplicate defensively).

use crate::hash::Xoshiro256;
use std::collections::HashSet;

/// Draw `n` distinct random u64 ids.
pub fn distinct_ids(n: usize, rng: &mut Xoshiro256) -> Vec<u64> {
    let mut seen = HashSet::with_capacity(n * 2);
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let id = rng.next_u64();
        if seen.insert(id) {
            out.push(id);
        }
    }
    out
}

/// A ⊆ B: `|A| = n_a`, `|B| = n_a + b_unique` (the unidirectional SetX workload).
pub fn subset_pair(n_a: usize, b_unique: usize, seed: u64) -> (Vec<u64>, Vec<u64>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let ids = distinct_ids(n_a + b_unique, &mut rng);
    let a = ids[..n_a].to_vec();
    let b = ids;
    (a, b)
}

/// General overlap: `|A∩B| = n_common`, plus disjoint unique parts (bidirectional workload).
pub fn overlap_pair(
    n_common: usize,
    a_unique: usize,
    b_unique: usize,
    seed: u64,
) -> (Vec<u64>, Vec<u64>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let ids = distinct_ids(n_common + a_unique + b_unique, &mut rng);
    let common = &ids[..n_common];
    let a_only = &ids[n_common..n_common + a_unique];
    let b_only = &ids[n_common + a_unique..];
    let mut a = common.to_vec();
    a.extend_from_slice(a_only);
    let mut b = common.to_vec();
    b.extend_from_slice(b_only);
    (a, b)
}

/// N-party overlap: every party holds the same `n_common` core plus its own disjoint
/// `unique`-element tail, so `∩ᵢSᵢ` is exactly the core (the multi-party workload; see
/// [`crate::setx::multi`]). `overlap_n(2, c, u, s)` is the equal-tails special case of
/// [`overlap_pair`].
pub fn overlap_n(parties: usize, n_common: usize, unique: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let ids = distinct_ids(n_common + parties * unique, &mut rng);
    let common = &ids[..n_common];
    (0..parties)
        .map(|i| {
            let mut s = common.to_vec();
            let tail = n_common + i * unique;
            s.extend_from_slice(&ids[tail..tail + unique]);
            s
        })
        .collect()
}

/// Exact intersection of two id slices (reference answer for correctness checks).
pub fn intersect(a: &[u64], b: &[u64]) -> Vec<u64> {
    let bs: HashSet<u64> = b.iter().copied().collect();
    let mut out: Vec<u64> = a.iter().copied().filter(|x| bs.contains(x)).collect();
    out.sort_unstable();
    out
}

/// Exact difference `a \ b`.
pub fn difference(a: &[u64], b: &[u64]) -> Vec<u64> {
    let bs: HashSet<u64> = b.iter().copied().collect();
    let mut out: Vec<u64> = a.iter().copied().filter(|x| !bs.contains(x)).collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_pair_cardinalities() {
        let (a, b) = subset_pair(1000, 37, 1);
        assert_eq!(a.len(), 1000);
        assert_eq!(b.len(), 1037);
        assert_eq!(intersect(&a, &b).len(), 1000);
        assert_eq!(difference(&b, &a).len(), 37);
        assert_eq!(difference(&a, &b).len(), 0);
    }

    #[test]
    fn overlap_pair_cardinalities() {
        let (a, b) = overlap_pair(500, 20, 60, 2);
        assert_eq!(a.len(), 520);
        assert_eq!(b.len(), 560);
        assert_eq!(intersect(&a, &b).len(), 500);
        assert_eq!(difference(&a, &b).len(), 20);
        assert_eq!(difference(&b, &a).len(), 60);
    }

    #[test]
    fn overlap_n_cardinalities_and_exact_core() {
        let sets = overlap_n(4, 300, 25, 3);
        assert_eq!(sets.len(), 4);
        let mut core = sets[0].clone();
        for s in &sets {
            assert_eq!(s.len(), 325);
            core = intersect(&core, s);
        }
        assert_eq!(core.len(), 300, "pairwise-disjoint tails leave exactly the core");
        // Tails are globally disjoint, not just core-disjoint.
        for i in 0..sets.len() {
            for j in (i + 1)..sets.len() {
                assert_eq!(intersect(&sets[i], &sets[j]).len(), 300);
            }
        }
    }

    #[test]
    fn seeds_reproduce_and_differ() {
        let (a1, b1) = overlap_pair(100, 5, 5, 7);
        let (a2, b2) = overlap_pair(100, 5, 5, 7);
        let (a3, _) = overlap_pair(100, 5, 5, 8);
        assert_eq!(a1, a2);
        assert_eq!(b1, b2);
        assert_ne!(a1, a3);
    }
}
