//! Ethereum world-state snapshot simulator (the §7.3 dataset substitute).
//!
//! The paper downloads three snapshots (A: May 03 2025, B: May 02, C: March 11) of the
//! ~292 M-account world state and hashes each account's (address, balance, nonce) 3-tuple
//! into a 256-bit SHA-256 signature. We cannot download PublicNode snapshots here, so we
//! simulate the *churn process* between snapshots, calibrated to reproduce Table 1's ratios:
//!
//! * daily account creation ≈ 0.0787% of the ledger (|A|−|B| = 229,836 on 292 M);
//! * daily distinct-account mutation ≈ 0.1165% (|B\A| = 340,292);
//! * mutation is concentrated: a "hot" ~1.5% of accounts receives ~92% of mutations, which
//!   is what makes the 53-day diff (|C\A| = 5.64 M) much smaller than 53× the daily diff —
//!   the same hot accounts mutate over and over.
//!
//! The protocol under test only ever sees the set of signatures and the diff geometry, so
//! this preserves exactly what Table 2 exercises (see DESIGN.md §4).

use crate::hash::{Sha256, Xoshiro256};

/// One account's state; the signature is SHA-256 of the packed 3-tuple.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Account {
    pub addr: u64,
    pub balance: u64,
    pub nonce: u64,
}

impl Account {
    /// 256-bit signature of the account state (we keep the first 64 bits as the internal
    /// id; communication accounting still charges the nominal 256-bit universe).
    pub fn signature(&self) -> [u8; 32] {
        let mut h = Sha256::new();
        h.update(&self.addr.to_le_bytes());
        h.update(&self.balance.to_le_bytes());
        h.update(&self.nonce.to_le_bytes());
        h.finalize()
    }

    pub fn id(&self) -> u64 {
        u64::from_le_bytes(self.signature()[..8].try_into().unwrap())
    }
}

/// Churn-process parameters (fractions per simulated day).
#[derive(Clone, Copy, Debug)]
pub struct EthParams {
    pub daily_new: f64,
    pub daily_mutations: f64,
    pub hot_fraction: f64,
    pub hot_share: f64,
}

impl Default for EthParams {
    fn default() -> Self {
        EthParams {
            daily_new: 0.000787,
            daily_mutations: 0.001165,
            hot_fraction: 0.015,
            hot_share: 0.92,
        }
    }
}

/// The evolving ledger.
pub struct EthSim {
    pub accounts: Vec<Account>,
    params: EthParams,
    rng: Xoshiro256,
    next_addr: u64,
}

impl EthSim {
    /// A fresh ledger of `n` accounts. (The paper's scale is 2.9·10⁸; default experiments
    /// run a 2²¹-scale replica — ratios, not absolutes, are what Table 2's shape needs.)
    pub fn genesis(n: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let accounts = (0..n as u64)
            .map(|i| Account {
                addr: i,
                balance: rng.next_u64() >> 20,
                nonce: rng.gen_range(100),
            })
            .collect();
        EthSim { accounts, params: EthParams::default(), rng, next_addr: n as u64 }
    }

    pub fn with_params(mut self, params: EthParams) -> Self {
        self.params = params;
        self
    }

    pub fn len(&self) -> usize {
        self.accounts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.accounts.is_empty()
    }

    /// Advance the ledger by one day: mutate hot/cold accounts, create new ones.
    pub fn advance_day(&mut self) {
        let n = self.accounts.len();
        let n_mut = (self.params.daily_mutations * n as f64).round() as usize;
        let hot_cut = ((self.params.hot_fraction * n as f64) as usize).max(1);
        for _ in 0..n_mut {
            let idx = if self.rng.gen_f64() < self.params.hot_share {
                // Hot accounts live at low indices (the oldest accounts — exchanges, etc.).
                self.rng.gen_range(hot_cut as u64) as usize
            } else {
                self.rng.gen_range(n as u64) as usize
            };
            let acct = &mut self.accounts[idx];
            acct.nonce += 1;
            acct.balance = acct.balance.wrapping_add(self.rng.next_u64() >> 40);
        }
        let n_new = (self.params.daily_new * n as f64).round() as usize;
        for _ in 0..n_new {
            let acct = Account {
                addr: self.next_addr,
                balance: self.rng.next_u64() >> 24,
                nonce: 0,
            };
            self.next_addr += 1;
            self.accounts.push(acct);
        }
    }

    pub fn advance_days(&mut self, days: usize) {
        for _ in 0..days {
            self.advance_day();
        }
    }

    /// The snapshot as a set of 64-bit signature ids (the SetX input).
    pub fn snapshot_ids(&self) -> Vec<u64> {
        self.accounts.iter().map(|a| a.id()).collect()
    }
}

/// Cardinality statistics between two snapshots (a Table 1 row).
#[derive(Clone, Copy, Debug)]
pub struct DiffStats {
    pub s_len: usize,
    pub s_minus_a: usize,
    pub a_minus_s: usize,
    pub sym_diff: usize,
}

/// Compute Table 1-style stats of snapshot `s` against the reference snapshot `a`.
pub fn diff_stats(s: &[u64], a: &[u64]) -> DiffStats {
    use std::collections::HashSet;
    let sa: HashSet<u64> = s.iter().copied().collect();
    let aa: HashSet<u64> = a.iter().copied().collect();
    let s_minus_a = sa.difference(&aa).count();
    let a_minus_s = aa.difference(&sa).count();
    DiffStats { s_len: sa.len(), s_minus_a, a_minus_s, sym_diff: s_minus_a + a_minus_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn signatures_change_with_state() {
        let a = Account { addr: 1, balance: 100, nonce: 0 };
        let mut b = a;
        b.nonce = 1;
        assert_ne!(a.signature(), b.signature());
        assert_ne!(a.id(), b.id());
        assert_eq!(a.signature(), a.signature());
    }

    #[test]
    fn one_day_churn_matches_table1_ratios() {
        // Scaled Table 1, B→A row: on 292 M accounts one day produced
        // |B\A|/|B| ≈ 0.1166% and (|A|−|B|)/|B| ≈ 0.0787%.
        let n = 200_000;
        let mut sim = EthSim::genesis(n, 42);
        let b = sim.snapshot_ids();
        sim.advance_day();
        let a = sim.snapshot_ids();
        let stats = diff_stats(&b, &a);
        let churn = stats.s_minus_a as f64 / n as f64;
        assert!((churn - 0.001165).abs() < 0.0004, "daily churn {churn}");
        let growth = (a.len() - b.len()) as f64 / n as f64;
        assert!((growth - 0.000787).abs() < 0.0002, "daily growth {growth}");
    }

    #[test]
    fn long_horizon_sublinear_due_to_hot_accounts() {
        // 50 days of churn must yield a distinct-changed count far below 50× the daily
        // count (Table 1: 5.64 M vs 53 × 0.34 M ≈ 18 M).
        let n = 120_000;
        let mut sim = EthSim::genesis(n, 7);
        let c = sim.snapshot_ids();
        sim.advance_day();
        let daily = diff_stats(&c, &sim.snapshot_ids()).s_minus_a.max(1);
        let mut sim2 = EthSim::genesis(n, 7);
        let c2 = sim2.snapshot_ids();
        sim2.advance_days(50);
        let fifty = diff_stats(&c2, &sim2.snapshot_ids()).s_minus_a;
        assert!(
            (fifty as f64) < 0.65 * 50.0 * daily as f64,
            "50-day distinct churn {fifty} vs daily {daily}"
        );
        assert!(fifty > 5 * daily, "must still grow substantially: {fifty} vs {daily}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut s1 = EthSim::genesis(10_000, 9);
        let mut s2 = EthSim::genesis(10_000, 9);
        s1.advance_days(3);
        s2.advance_days(3);
        assert_eq!(s1.snapshot_ids(), s2.snapshot_ids());
    }
}
