//! Workload generation: seeded synthetic sets (§7.2) and the Ethereum snapshot simulator
//! (§7.3 substitute — see DESIGN.md §4).

pub mod ethereum;
pub mod synth;

pub use ethereum::{EthParams, EthSim};
