//! Distributed-runtime helpers: TCP rendezvous and the legacy-shaped partitioned entry.
//!
//! Both are thin adapters over the facade — no protocol logic lives here.
//!
//! * [`tcp`] — `serve`/`connect` pair a [`crate::setx::Setx`] endpoint with the facade's
//!   hardened [`crate::setx::transport::TcpTransport`] (threaded, dependency-free; the
//!   image's crate set has no tokio — see DESIGN.md §4). Byte counts come from the
//!   endpoint's own accounting, so TCP runs report costs identical to in-memory runs.
//! * [`parallel`] — the §7.3 scale-out in its experiment-harness shape; the partitioning,
//!   bounded worker pool (thread cap tested via a live-worker high-water mark), and
//!   per-partition sessions live in [`crate::setx::parallel`]. The per-partition matrices
//!   have a fixed row count — which is exactly what lets the AOT-compiled dense-block
//!   artifacts accelerate encoding (see [`crate::runtime`]).

pub mod parallel;
pub mod tcp;

pub use tcp::{connect, serve};
