//! The distributed runtime: CommonSense over real sockets, plus partitioned parallel SetX.
//!
//! * [`tcp`] — Alice/Bob nodes speaking the wire protocol of [`crate::protocol::wire`] over
//!   TCP (threaded; the image's crate set has no tokio — see DESIGN.md §4). The *initiator*
//!   connects and sends `Hello` + `Sketch`; the *responder* serves. Byte counts are taken
//!   from actual socket writes/reads, so the E2E driver's reported costs are real.
//! * [`parallel`] — the §7.3 scale-out: hash-partition the universe (as PBS does), run an
//!   independent bidirectional session per partition across OS threads, aggregate. This is
//!   also what makes the PJRT dense-block artifacts applicable: each partition's matrix has
//!   exactly the artifact row count.

pub mod parallel;
pub mod tcp;

pub use tcp::{connect_initiator, serve_responder, SessionReport};
