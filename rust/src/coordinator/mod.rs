//! The distributed runtime: CommonSense over real sockets, plus partitioned parallel SetX.
//!
//! Both frontends are thin adapters over the sans-io [`crate::protocol::session::Session`]
//! engine — no protocol logic lives here.
//!
//! * [`tcp`] — Alice/Bob nodes speaking the wire protocol of [`crate::protocol::wire`] over
//!   TCP (threaded, dependency-free; the image's crate set has no tokio — see DESIGN.md
//!   §4). The *initiator* connects and sends `Hello` + `Sketch`; the *responder* serves.
//!   Framing is hardened against adversarial length fields, and byte counts come from the
//!   session's own accounting, so TCP and in-memory runs report identical costs.
//! * [`parallel`] — the §7.3 scale-out: hash-partition the universe (as PBS does), run an
//!   independent bidirectional session per partition on a **bounded worker pool** that
//!   honors its `threads` cap (tested via a live-worker high-water mark), aggregate. This
//!   is also what makes the PJRT dense-block artifacts applicable: each partition's matrix
//!   has exactly the artifact row count.

pub mod parallel;
pub mod tcp;

pub use tcp::{connect_initiator, serve_responder, SessionReport};
