//! **One-shot** TCP serve/connect helpers — thin wrappers that pair a [`Setx`] endpoint
//! with a [`TcpTransport`] for exactly one session.
//!
//! All protocol logic lives in the facade's endpoint state machine
//! ([`crate::setx`]); all framing lives in [`crate::setx::transport`] (length-prefixed
//! reads hardened against adversarial length fields). This module only does the socket
//! rendezvous: `connect` dials out (becoming the client/tie-break end), `serve` accepts
//! one session on an already-bound listener — through the same
//! [`TcpTransport::accept_with_timeouts`] helper the multi-client daemon uses — and
//! **returns after that single session**. Both return the same [`SetxReport`] every
//! other transport returns, with byte accounting identical to an in-memory run of the
//! same workload *by construction*.
//!
//! These helpers are a **debugging and test convenience** (one blocking session on the
//! caller's thread, no timeouts, no admission control) — handy for a quick manual sync
//! or a protocol experiment, and deliberately *not* a service. To keep hot host sets
//! online and reconcile many concurrent clients against them — the readiness-based
//! poller pool, per-connection deadlines, admission control and tenant quotas, sharded
//! decoder pools and sketch stores — use [`crate::server::SetxServer`]; this module
//! stays the documented point-to-point path.

use crate::setx::transport::TcpTransport;
use crate::setx::{Setx, SetxError, SetxReport};
use std::net::{TcpListener, ToSocketAddrs};

/// Dial a listening peer and run the endpoint to completion (this end is the client).
pub fn connect(addr: impl ToSocketAddrs, setx: &Setx) -> Result<SetxReport, SetxError> {
    let mut transport = TcpTransport::connect(addr)?;
    setx.run(&mut transport)
}

/// Accept **one** connection on `listener` and run the endpoint to completion (this end
/// is the server), then return. The conversation's parameters come from the shared
/// config + handshake; the server needs nothing beyond its own `Setx`. No timeouts are
/// applied (a one-shot caller is already waiting on this session — pass your own via
/// [`TcpTransport::accept_with_timeouts`] + [`Setx::run`] if the peer is untrusted);
/// for a long-lived multi-connection server use [`crate::server::SetxServer`].
pub fn serve(listener: &TcpListener, setx: &Setx) -> Result<SetxReport, SetxError> {
    let mut transport = TcpTransport::accept_with_timeouts(listener, None, None)?;
    setx.run(&mut transport)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::setx::{DiffSize, Mode};

    #[test]
    fn tcp_session_matches_in_memory_protocol() {
        let (a, b) = synth::overlap_pair(4_000, 40, 80, 77);
        let alice = Setx::builder(&a).build().unwrap();
        let bob = Setx::builder(&b).build().unwrap();
        let (mem_a, mem_b) = alice.run_pair(&bob).unwrap();

        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let bob2 = bob.clone();
        let server = std::thread::spawn(move || serve(&listener, &bob2).unwrap());
        let tcp_a = connect(addr, &alice).unwrap();
        let tcp_b = server.join().unwrap();

        assert_eq!(tcp_a.local_unique, synth::difference(&a, &b));
        assert_eq!(tcp_b.local_unique, synth::difference(&b, &a));
        assert_eq!(tcp_a.intersection, mem_a.intersection);
        // One engine behind both transports ⇒ byte-identical conversations.
        assert_eq!(tcp_a.total_bytes(), mem_a.total_bytes());
        assert_eq!(tcp_b.total_bytes(), mem_b.total_bytes());
        // Conservation: what one sends the other receives.
        assert_eq!(tcp_a.bytes_sent(), tcp_b.bytes_received());
        assert_eq!(tcp_b.bytes_sent(), tcp_a.bytes_received());
        assert!(tcp_a.bytes_sent() + tcp_b.bytes_sent() > 0);
    }

    #[test]
    fn tcp_session_uni_shaped_workload() {
        // A ⊆ B over TCP with an explicit d: Mode::Auto routes to the unidirectional
        // protocol (the subset side has zero uniques) and the server learns B \ A.
        let (a, b) = synth::subset_pair(3_000, 50, 9);
        let alice =
            Setx::builder(&a).mode(Mode::Auto).diff_size(DiffSize::Explicit(50)).build().unwrap();
        let bob =
            Setx::builder(&b).mode(Mode::Auto).diff_size(DiffSize::Explicit(50)).build().unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let bob2 = bob.clone();
        let server = std::thread::spawn(move || serve(&listener, &bob2).unwrap());
        let alice_report = connect(addr, &alice).unwrap();
        let bob_report = server.join().unwrap();
        assert!(alice_report.local_unique.is_empty());
        assert_eq!(bob_report.local_unique, synth::difference(&b, &a));
        assert_eq!(alice_report.kind, crate::setx::ProtocolKind::Uni);
        // Both sides agree on the intersection (= A here).
        assert_eq!(alice_report.intersection, bob_report.intersection);
    }

    #[test]
    fn responder_rejects_out_of_order_stream() {
        // A client that skips the handshake and opens with a Round frame must get a
        // protocol error, not a hang or a panic.
        use crate::protocol::wire::Msg;
        use std::io::Write;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            let rogue = Msg::Round {
                residue: vec![],
                smf: None,
                inquiry: vec![],
                answers: vec![],
                done: false,
                codec: false,
            };
            s.write_all(&rogue.to_bytes()).unwrap();
            s
        });
        let set: Vec<u64> = (0..100).collect();
        let bob = Setx::builder(&set).build().unwrap();
        let err = serve(&listener, &bob);
        assert!(err.is_err(), "out-of-order stream must fail the session");
        drop(writer.join().unwrap());
    }
}
