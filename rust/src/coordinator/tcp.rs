//! TCP transport for the bidirectional protocol — **socket framing only** (threaded,
//! dependency-free; the image's crate set has no tokio, see DESIGN.md §4).
//!
//! All protocol logic lives in the sans-io [`Session`] engine
//! ([`crate::protocol::session`]); this module's entire job is moving its frames across a
//! socket: length-prefixed reads hardened against adversarial length fields, writes, and
//! teardown on `Done` or peer disconnect. Byte/message accounting comes from the session
//! itself, so TCP runs report costs identical to the in-memory driver's.

use crate::protocol::bidi::BidiOptions;
use crate::protocol::session::{Session, SessionEvent};
use crate::protocol::{wire, wire::Msg, CsParams};
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};

/// Outcome of one host's side of a TCP session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// This host's unique elements (what the protocol recovered for us).
    pub unique: Vec<u64>,
    /// Bytes written to / read from the socket (payload frames only).
    pub bytes_sent: usize,
    pub bytes_received: usize,
    /// Messages this host sent (hello/sketch count for the initiator).
    pub msgs_sent: usize,
    pub converged: bool,
}

fn write_msg(stream: &mut TcpStream, msg: &Msg) -> Result<()> {
    stream.write_all(&msg.to_bytes())?;
    Ok(())
}

/// Read exactly one frame: type byte + varint length + body. Returns `Ok(None)` on a
/// clean end-of-stream at a frame boundary (the peer tore down after `Done`); anything
/// else — EOF mid-frame, a malformed frame, an adversarial length field — is an error.
/// The advertised body length is validated against [`wire::MAX_FRAME_BYTES`] *before*
/// any buffer is sized by it, so a hostile peer cannot drive a huge allocation with a
/// 10-byte header.
fn read_msg(stream: &mut TcpStream) -> Result<Option<Msg>> {
    let mut byte = [0u8; 1];
    match stream.read_exact(&mut byte) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e).context("reading frame type"),
    }
    let mut frame = vec![byte[0]];
    // Varint body length, byte by byte.
    let mut len = 0u64;
    let mut shift = 0u32;
    let mut more = true;
    while more {
        stream.read_exact(&mut byte).context("reading frame length")?;
        frame.push(byte[0]);
        len |= ((byte[0] & 0x7f) as u64) << shift;
        more = byte[0] & 0x80 != 0;
        if more {
            shift += 7;
            if shift >= 64 {
                return Err(anyhow!("frame length varint overflow"));
            }
        }
    }
    let len = usize::try_from(len).map_err(|_| anyhow!("frame length exceeds address space"))?;
    if len > wire::MAX_FRAME_BYTES {
        return Err(anyhow!("frame length {len} exceeds cap {}", wire::MAX_FRAME_BYTES));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body).context("reading frame body")?;
    frame.extend_from_slice(&body);
    let total = frame.len();
    let (msg, used) = Msg::from_bytes(&frame).ok_or_else(|| anyhow!("malformed frame"))?;
    if used != total {
        return Err(anyhow!("frame parser consumed {used} of {total} bytes"));
    }
    Ok(Some(msg))
}

/// Pump one session over a connected socket until it completes or the peer hangs up.
/// A clean disconnect at a frame boundary ends the session (its own state says whether
/// that was a converged finish); transport corruption surfaces as an error.
fn pump(stream: &mut TcpStream, session: &mut Session) -> Result<()> {
    let mut open = true;
    while open {
        let Some(msg) = read_msg(stream)? else {
            break;
        };
        match session.on_msg(&msg)? {
            SessionEvent::Reply(reply) => write_msg(stream, &reply)?,
            SessionEvent::Continue => {}
            SessionEvent::Done(_) => open = false,
        }
    }
    Ok(())
}

fn report(session: &Session) -> SessionReport {
    SessionReport {
        unique: session.outcome().unique,
        bytes_sent: session.bytes_sent(),
        bytes_received: session.bytes_received(),
        msgs_sent: session.msgs_sent(),
        converged: session.is_settled(),
    }
}

/// Run the initiator (the side with the smaller unique-count estimate): connect, send
/// `Hello` + `Sketch`, then ping-pong (via the shared [`Session`] engine) to completion.
pub fn connect_initiator(
    addr: impl ToSocketAddrs,
    set: &[u64],
    params: &CsParams,
    opts: BidiOptions,
) -> Result<SessionReport> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    // The initiator occupies the "a" slot of the parameter block; the responder mirrors it.
    let (mut session, opening) = Session::initiator(params, set, opts, true);
    for msg in &opening {
        write_msg(&mut stream, msg)?;
    }
    pump(&mut stream, &mut session)?;
    Ok(report(&session))
}

/// Serve one responder session on an already-bound listener. Returns when the session
/// completes. The responder derives every parameter from the initiator's `Hello`.
pub fn serve_responder(
    listener: &TcpListener,
    set: &[u64],
    opts: BidiOptions,
) -> Result<SessionReport> {
    let (mut stream, _addr) = listener.accept()?;
    stream.set_nodelay(true).ok();
    let mut session = Session::responder(set, opts, false);
    pump(&mut stream, &mut session)?;
    Ok(report(&session))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::entropy::put_varint;

    #[test]
    fn tcp_session_matches_in_memory_protocol() {
        let (a, b) = synth::overlap_pair(4_000, 40, 80, 77);
        let params = CsParams::tuned_bidi(4_120, 40, 80);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let b2 = b.clone();
        let bob = std::thread::spawn(move || {
            serve_responder(&listener, &b2, BidiOptions::default()).unwrap()
        });
        let alice = connect_initiator(addr, &a, &params, BidiOptions::default()).unwrap();
        let bob = bob.join().unwrap();

        assert!(alice.converged && bob.converged);
        assert_eq!(alice.unique, synth::difference(&a, &b));
        assert_eq!(bob.unique, synth::difference(&b, &a));
        // Conservation: what one sends the other receives.
        assert_eq!(alice.bytes_sent, bob.bytes_received);
        assert_eq!(bob.bytes_sent, alice.bytes_received);
        assert!(alice.bytes_sent + bob.bytes_sent > 0);
    }

    #[test]
    fn tcp_session_uni_shaped_workload() {
        // A ⊆ B over TCP: initiator has no uniques.
        let (a, b) = synth::subset_pair(3_000, 50, 9);
        let params = CsParams {
            est_a_unique: 0,
            est_b_unique: 50,
            ..CsParams::tuned_bidi(3_050, 0, 50)
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let b2 = b.clone();
        let bob = std::thread::spawn(move || {
            serve_responder(&listener, &b2, BidiOptions::default()).unwrap()
        });
        let alice = connect_initiator(addr, &a, &params, BidiOptions::default()).unwrap();
        let bob = bob.join().unwrap();
        assert!(alice.unique.is_empty());
        assert_eq!(bob.unique, synth::difference(&b, &a));
    }

    #[test]
    fn read_msg_rejects_adversarial_length_before_allocating() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // A Round frame claiming a 2^62-byte body; the socket then stays open, so a
            // reader that trusted the length would hang allocating/reading forever.
            let mut frame = vec![3u8];
            put_varint(&mut frame, 1u64 << 62);
            s.write_all(&frame).unwrap();
            s
        });
        let (mut stream, _) = listener.accept().unwrap();
        assert!(read_msg(&mut stream).is_err());
        drop(writer.join().unwrap());
    }

    #[test]
    fn read_msg_rejects_truncated_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // Claims 16 body bytes, delivers 3, then closes.
            let mut frame = vec![3u8];
            put_varint(&mut frame, 16);
            frame.extend_from_slice(&[1, 2, 3]);
            s.write_all(&frame).unwrap();
        });
        let (mut stream, _) = listener.accept().unwrap();
        assert!(read_msg(&mut stream).is_err());
        writer.join().unwrap();
    }

    #[test]
    fn responder_rejects_out_of_order_stream() {
        // A client that skips the handshake and opens with a Round frame must get a
        // protocol error, not a hang or a panic.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            let rogue = Msg::Round {
                residue: vec![],
                smf: None,
                inquiry: vec![],
                answers: vec![],
                done: false,
            };
            s.write_all(&rogue.to_bytes()).unwrap();
            s
        });
        let set: Vec<u64> = (0..100).collect();
        let err = serve_responder(&listener, &set, BidiOptions::default());
        assert!(err.is_err(), "out-of-order stream must fail the session");
        drop(writer.join().unwrap());
    }
}
