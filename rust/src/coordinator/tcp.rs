//! TCP transport for the bidirectional protocol (threaded, dependency-free).

use crate::decoder::Side;
use crate::protocol::bidi::{
    initiator_sketch, responder_residue, seed_round, BidiOptions, Peer,
};
use crate::protocol::{wire::Msg, CsParams};
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};

/// Outcome of one host's side of a TCP session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    /// This host's unique elements (what the protocol recovered for us).
    pub unique: Vec<u64>,
    /// Bytes written to / read from the socket (payload frames only).
    pub bytes_sent: usize,
    pub bytes_received: usize,
    /// Messages this host sent (sketch/hello count for the initiator).
    pub msgs_sent: usize,
    pub converged: bool,
}

fn write_msg(stream: &mut TcpStream, msg: &Msg) -> Result<usize> {
    let bytes = msg.to_bytes();
    stream.write_all(&bytes)?;
    Ok(bytes.len())
}

/// Read exactly one frame: type byte + varint length + body.
fn read_msg(stream: &mut TcpStream) -> Result<(Msg, usize)> {
    let mut header = vec![0u8; 1];
    stream.read_exact(&mut header).context("reading frame type")?;
    // Varint length, byte by byte.
    let mut len = 0u64;
    let mut shift = 0u32;
    loop {
        let mut b = [0u8; 1];
        stream.read_exact(&mut b)?;
        header.push(b[0]);
        len |= ((b[0] & 0x7f) as u64) << shift;
        if b[0] & 0x80 == 0 {
            break;
        }
        shift += 7;
        if shift >= 64 {
            return Err(anyhow!("varint overflow"));
        }
    }
    let mut body = vec![0u8; len as usize];
    stream.read_exact(&mut body)?;
    let mut frame = header;
    frame.extend_from_slice(&body);
    let total = frame.len();
    let (msg, used) = Msg::from_bytes(&frame).ok_or_else(|| anyhow!("malformed frame"))?;
    debug_assert_eq!(used, total);
    Ok((msg, total))
}

/// Run the initiator (the side with the smaller unique-count estimate): connect, send
/// `Hello` + `Sketch`, then ping-pong as the negative-signed decoder until completion.
pub fn connect_initiator(
    addr: impl ToSocketAddrs,
    set: &[u64],
    params: &CsParams,
    opts: BidiOptions,
) -> Result<SessionReport> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut sent = 0usize;
    let mut received = 0usize;
    let mut msgs = 0usize;

    let hello = Msg::Hello {
        l: params.l,
        m: params.m,
        seed: params.seed,
        universe_bits: params.universe_bits,
        // Initiator-relative estimates (the responder mirrors them back).
        est_initiator_unique: params.est_a_unique as u64,
        est_responder_unique: params.est_b_unique as u64,
        set_len: set.len() as u64,
    };
    sent += write_msg(&mut stream, &hello)?;
    msgs += 1;
    sent += write_msg(&mut stream, &initiator_sketch(params, set, true))?;
    msgs += 1;

    let mut peer = Peer::new(params, set, Side::Negative, opts);
    loop {
        let msg = match read_msg(&mut stream) {
            Ok((msg, n)) => {
                received += n;
                msg
            }
            Err(_) => break, // peer closed: session over
        };
        match peer.step(&msg) {
            Some(reply) => {
                sent += write_msg(&mut stream, &reply)?;
                msgs += 1;
            }
            None => break,
        }
    }
    Ok(SessionReport {
        unique: peer.result(),
        bytes_sent: sent,
        bytes_received: received,
        msgs_sent: msgs,
        converged: peer.settled,
    })
}

/// Serve one responder session on an already-bound listener. Returns when the session
/// completes. The responder derives every parameter from the initiator's `Hello`.
pub fn serve_responder(
    listener: &TcpListener,
    set: &[u64],
    opts: BidiOptions,
) -> Result<SessionReport> {
    let (mut stream, _addr) = listener.accept()?;
    stream.set_nodelay(true).ok();
    let mut sent = 0usize;
    let mut received = 0usize;
    let mut msgs = 0usize;

    let (hello, n) = read_msg(&mut stream)?;
    received += n;
    let Msg::Hello { l, m, seed, universe_bits, est_initiator_unique, est_responder_unique, .. } =
        hello
    else {
        return Err(anyhow!("expected Hello"));
    };
    // Reconstruct the shared parameter view. From the responder's perspective, "a" is the
    // initiator (`initiator_is_alice = true` keeps codec orientation consistent).
    let params = CsParams {
        l,
        m,
        seed,
        universe_bits,
        est_a_unique: est_initiator_unique as usize,
        est_b_unique: est_responder_unique as usize,
    };

    let (sketch, n) = read_msg(&mut stream)?;
    received += n;
    let Msg::Sketch(ref sm) = sketch else {
        return Err(anyhow!("expected Sketch"));
    };
    let residue0 =
        responder_residue(&params, set, sm, true).ok_or_else(|| anyhow!("sketch recovery failed"))?;

    let mut peer = Peer::new(&params, set, Side::Positive, opts);
    let mut in_flight = Some(seed_round(&residue0));
    loop {
        let msg = match in_flight.take() {
            Some(msg) => msg,
            None => match read_msg(&mut stream) {
                Ok((msg, n)) => {
                    received += n;
                    msg
                }
                Err(_) => break,
            },
        };
        match peer.step(&msg) {
            Some(reply) => {
                sent += write_msg(&mut stream, &reply)?;
                msgs += 1;
            }
            None => break,
        }
    }
    Ok(SessionReport {
        unique: peer.result(),
        bytes_sent: sent,
        bytes_received: received,
        msgs_sent: msgs,
        converged: peer.settled,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn tcp_session_matches_in_memory_protocol() {
        let (a, b) = synth::overlap_pair(4_000, 40, 80, 77);
        let params = CsParams::tuned_bidi(4_120, 40, 80);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let b2 = b.clone();
        let bob = std::thread::spawn(move || {
            serve_responder(&listener, &b2, BidiOptions::default()).unwrap()
        });
        let alice = connect_initiator(addr, &a, &params, BidiOptions::default()).unwrap();
        let bob = bob.join().unwrap();

        assert!(alice.converged && bob.converged);
        assert_eq!(alice.unique, synth::difference(&a, &b));
        assert_eq!(bob.unique, synth::difference(&b, &a));
        // Conservation: what one sends the other receives.
        assert_eq!(alice.bytes_sent, bob.bytes_received);
        assert_eq!(bob.bytes_sent, alice.bytes_received);
        assert!(alice.bytes_sent + bob.bytes_sent > 0);
    }

    #[test]
    fn tcp_session_uni_shaped_workload() {
        // A ⊆ B over TCP: initiator has no uniques.
        let (a, b) = synth::subset_pair(3_000, 50, 9);
        let params = CsParams {
            est_a_unique: 0,
            est_b_unique: 50,
            ..CsParams::tuned_bidi(3_050, 0, 50)
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let b2 = b.clone();
        let bob = std::thread::spawn(move || {
            serve_responder(&listener, &b2, BidiOptions::default()).unwrap()
        });
        let alice = connect_initiator(addr, &a, &params, BidiOptions::default()).unwrap();
        let bob = bob.join().unwrap();
        assert!(alice.unique.is_empty());
        assert_eq!(bob.unique, synth::difference(&b, &a));
    }
}
