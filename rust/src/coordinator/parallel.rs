//! Legacy-shaped entry point for partitioned parallel SetX (§7.3, PBS-style).
//!
//! The partitioning, the bounded worker pool, and the per-partition protocol all live in
//! [`crate::setx::parallel`] now — every partition is a pair of facade endpoints driven
//! by the same pump as the in-memory and TCP paths. This module keeps the
//! experiment-harness-shaped signature (`(a, b, est_a, est_b, parts, threads, opts)` →
//! flat [`ParallelOutcome`]) as a thin adapter; new code should build two
//! [`crate::setx::Setx`] endpoints and call [`crate::setx::parallel::run_partitioned`]
//! directly.

pub use crate::setx::parallel::partition;

use crate::metrics::Stats;
use crate::protocol::bidi::BidiOptions;
use crate::setx::{parallel, DiffSize, Mode, Setx};

/// Aggregated outcome across partitions (legacy shape for the experiment harnesses).
#[derive(Clone, Debug)]
pub struct ParallelOutcome {
    pub a_minus_b: Vec<u64>,
    pub b_minus_a: Vec<u64>,
    pub total_bytes: usize,
    pub total_msgs: usize,
    pub partitions: usize,
    pub converged: bool,
    /// Per-partition byte statistics (for the ablation table).
    pub bytes_stats: Stats,
    /// High-water mark of concurrently-live partition workers — always ≤ the `threads`
    /// argument (the regression guard for the bounded pool).
    pub peak_workers: usize,
}

/// Run bidirectional SetX over `parts` hash partitions on a worker pool of at most
/// `threads` OS threads. A decode failure (the facade would climb its ladder; this
/// legacy shape runs a single attempt for cost parity with the old harnesses) reports
/// `converged: false` instead of an error.
pub fn setx(
    a: &[u64],
    b: &[u64],
    est_a_unique: usize,
    est_b_unique: usize,
    parts: usize,
    threads: usize,
    opts: BidiOptions,
) -> ParallelOutcome {
    let build = |set: &[u64]| {
        Setx::builder(set)
            .mode(Mode::Bidi)
            .diff_size(DiffSize::Explicit(est_a_unique + est_b_unique))
            .universe_bits(256)
            .max_attempts(1)
            .engine_options(opts)
            .build()
            .expect("legacy parallel config is always valid")
    };
    let alice = build(a);
    let bob = build(b);
    match parallel::run_partitioned(&alice, &bob, parts, threads) {
        Ok(out) => ParallelOutcome {
            a_minus_b: out.client.local_unique,
            b_minus_a: out.server.local_unique,
            total_bytes: out.client.total_bytes(),
            total_msgs: out.client.comm.rounds(),
            partitions: out.partitions,
            converged: out.client.converged && out.server.converged,
            bytes_stats: out.bytes_stats,
            peak_workers: out.peak_workers,
        },
        Err(_) => ParallelOutcome {
            a_minus_b: Vec::new(),
            b_minus_a: Vec::new(),
            total_bytes: 0,
            total_msgs: 0,
            partitions: parts.max(1),
            converged: false,
            bytes_stats: Stats::new(),
            peak_workers: 0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn parallel_setx_exact() {
        let (a, b) = synth::overlap_pair(12_000, 120, 150, 3);
        let out = setx(&a, &b, 120, 150, 8, 4, BidiOptions::default());
        assert!(out.converged);
        assert_eq!(out.a_minus_b, synth::difference(&a, &b));
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert_eq!(out.partitions, 8);
    }

    #[test]
    fn worker_pool_honors_thread_cap() {
        // Regression for the seed's unbounded spawn: with 64 partitions and a cap of 4,
        // the live-worker high-water mark must never exceed 4.
        let (a, b) = synth::overlap_pair(6_000, 120, 120, 13);
        let out = setx(&a, &b, 120, 120, 64, 4, BidiOptions::default());
        assert!(out.converged);
        assert_eq!(out.a_minus_b, synth::difference(&a, &b));
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert!(
            (1..=4).contains(&out.peak_workers),
            "thread cap violated: peak {} workers",
            out.peak_workers
        );
    }

    #[test]
    fn partitioning_overhead_is_modest() {
        // §7.3: "the increase in communication cost due to this partitioning should be
        // tiny". With Poisson padding it is bounded; assert < 2.2× the single-partition
        // cost at this scale (the padding term dominates at small per-partition d).
        let (a, b) = synth::overlap_pair(12_000, 200, 200, 5);
        let single = setx(&a, &b, 200, 200, 1, 1, BidiOptions::default());
        let multi = setx(&a, &b, 200, 200, 8, 4, BidiOptions::default());
        assert!(single.converged && multi.converged);
        let ratio = multi.total_bytes as f64 / single.total_bytes as f64;
        assert!(ratio < 2.2, "partitioning overhead ratio {ratio}");
    }
}
