//! Partitioned parallel SetX (§7.3's scale-out remark, PBS-style).
//!
//! Hash-partition the universe with a shared seed; each partition is an independent
//! bidirectional SetX instance, so partitions run on separate OS threads with no data
//! dependency. The communication overhead of partitioning is tiny (per-partition headers),
//! and the per-partition matrices have a fixed row count — which is exactly what lets the
//! AOT-compiled dense-block artifacts accelerate encoding (see [`crate::runtime`]).

use crate::hash::hash_u64;
use crate::metrics::Stats;
use crate::protocol::bidi::{self, BidiOptions};
use crate::protocol::CsParams;

/// Aggregated outcome across partitions.
#[derive(Clone, Debug)]
pub struct ParallelOutcome {
    pub a_minus_b: Vec<u64>,
    pub b_minus_a: Vec<u64>,
    pub total_bytes: usize,
    pub total_msgs: usize,
    pub partitions: usize,
    pub converged: bool,
    /// Per-partition byte statistics (for the ablation table).
    pub bytes_stats: Stats,
}

/// Partition a set by `hash(id) % parts`.
pub fn partition(ids: &[u64], parts: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut out = vec![Vec::with_capacity(ids.len() / parts.max(1) + 1); parts];
    for &id in ids {
        out[(hash_u64(id, seed) % parts as u64) as usize].push(id);
    }
    out
}

/// Run bidirectional SetX over `parts` hash partitions using up to `threads` OS threads.
pub fn setx(
    a: &[u64],
    b: &[u64],
    est_a_unique: usize,
    est_b_unique: usize,
    parts: usize,
    threads: usize,
    opts: BidiOptions,
) -> ParallelOutcome {
    let part_seed = 0x9a27_11;
    let a_parts = partition(a, parts, part_seed);
    let b_parts = partition(b, parts, part_seed);

    // Per-partition d estimate: uniques split evenly; pad for Poisson spread
    // (mean + 3σ + 4), exactly how PBS provisions sub-sketches.
    let pad = |d: usize| -> usize {
        let mu = d as f64 / parts as f64;
        (mu + 3.0 * mu.sqrt() + 4.0).ceil() as usize
    };
    let da = pad(est_a_unique);
    let db = pad(est_b_unique);

    let results: Vec<(bidi::BidiOutcome, usize)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (p, (ap, bp)) in a_parts.iter().zip(&b_parts).enumerate() {
            // Cap live threads: spawn in waves.
            handles.push(scope.spawn(move || {
                let n = ap.len().max(bp.len());
                let mut params = CsParams::tuned_bidi(n.max(64), da, db);
                params.seed ^= p as u64; // independent matrices per partition
                let out = bidi::run(ap, bp, &params, opts);
                (out, p)
            }));
            if handles.len() >= threads {
                // Simple wave barrier keeps ≤ `threads` workers alive.
                // (join consumes; collect results as we go)
            }
        }
        handles.into_iter().map(|h| h.join().expect("partition worker")).collect()
    });

    let mut a_minus_b = Vec::new();
    let mut b_minus_a = Vec::new();
    let mut total_bytes = 0usize;
    let mut total_msgs = 0usize;
    let mut converged = true;
    let mut bytes_stats = Stats::new();
    for (out, _p) in results {
        a_minus_b.extend(out.a_minus_b);
        b_minus_a.extend(out.b_minus_a);
        total_bytes += out.comm.total_bytes();
        total_msgs += out.comm.rounds();
        converged &= out.converged;
        bytes_stats.push(out.comm.total_bytes() as f64);
    }
    a_minus_b.sort_unstable();
    b_minus_a.sort_unstable();
    ParallelOutcome {
        a_minus_b,
        b_minus_a,
        total_bytes,
        total_msgs,
        partitions: parts,
        converged,
        bytes_stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn partition_is_disjoint_cover() {
        let ids: Vec<u64> = (0..10_000u64).collect();
        let parts = partition(&ids, 8, 1);
        assert_eq!(parts.len(), 8);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 10_000);
        // Roughly balanced.
        for p in &parts {
            assert!((1_000..1_550).contains(&p.len()), "part size {}", p.len());
        }
    }

    #[test]
    fn parallel_setx_exact() {
        let (a, b) = synth::overlap_pair(12_000, 120, 150, 3);
        let out = setx(&a, &b, 120, 150, 8, 4, BidiOptions::default());
        assert!(out.converged);
        assert_eq!(out.a_minus_b, synth::difference(&a, &b));
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert_eq!(out.partitions, 8);
    }

    #[test]
    fn partitioning_overhead_is_modest() {
        // §7.3: "the increase in communication cost due to this partitioning should be
        // tiny". With Poisson padding it is bounded; assert < 2.2× the single-partition
        // cost at this scale (the padding term dominates at small per-partition d).
        let (a, b) = synth::overlap_pair(12_000, 200, 200, 5);
        let single = setx(&a, &b, 200, 200, 1, 1, BidiOptions::default());
        let multi = setx(&a, &b, 200, 200, 8, 4, BidiOptions::default());
        assert!(single.converged && multi.converged);
        let ratio = multi.total_bytes as f64 / single.total_bytes as f64;
        assert!(ratio < 2.2, "partitioning overhead ratio {ratio}");
    }
}
