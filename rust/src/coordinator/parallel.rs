//! Partitioned parallel SetX (§7.3's scale-out remark, PBS-style).
//!
//! Hash-partition the universe with a shared seed; each partition is an independent
//! bidirectional SetX instance (the same sans-io [`crate::protocol::session`] engine the
//! TCP and in-memory frontends drive), so partitions run concurrently with no data
//! dependency. The communication overhead of partitioning is tiny (per-partition headers),
//! and the per-partition matrices have a fixed row count — which is exactly what lets the
//! AOT-compiled dense-block artifacts accelerate encoding (see [`crate::runtime`]).
//!
//! Concurrency model: a **bounded worker pool**. Exactly `min(threads, parts)` OS threads
//! are spawned; each pulls the next unclaimed partition index from a shared atomic counter
//! until none remain, so big-partition stragglers never serialize the tail the way fixed
//! chunking would. The pool instruments a live-worker high-water mark
//! ([`ParallelOutcome::peak_workers`]) so the `threads` cap is a *tested* invariant, not a
//! comment.

use crate::hash::hash_u64;
use crate::metrics::Stats;
use crate::protocol::bidi::{self, BidiOptions};
use crate::protocol::CsParams;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Aggregated outcome across partitions.
#[derive(Clone, Debug)]
pub struct ParallelOutcome {
    pub a_minus_b: Vec<u64>,
    pub b_minus_a: Vec<u64>,
    pub total_bytes: usize,
    pub total_msgs: usize,
    pub partitions: usize,
    pub converged: bool,
    /// Per-partition byte statistics (for the ablation table).
    pub bytes_stats: Stats,
    /// High-water mark of concurrently-live partition workers — always ≤ the `threads`
    /// argument of [`setx`] (the regression guard for the bounded pool).
    pub peak_workers: usize,
}

/// Partition a set by `hash(id) % parts`. `parts == 0` is clamped to a single partition
/// (degenerate but well-defined: everything lands in partition 0, no `hash % 0` panic).
pub fn partition(ids: &[u64], parts: usize, seed: u64) -> Vec<Vec<u64>> {
    let parts = parts.max(1);
    let mut out = vec![Vec::with_capacity(ids.len() / parts + 1); parts];
    for &id in ids {
        out[(hash_u64(id, seed) % parts as u64) as usize].push(id);
    }
    out
}

/// Run bidirectional SetX over `parts` hash partitions on a worker pool of at most
/// `threads` OS threads (both arguments are clamped to ≥ 1; `threads` is additionally
/// clamped to `parts` — idle workers would be pointless).
pub fn setx(
    a: &[u64],
    b: &[u64],
    est_a_unique: usize,
    est_b_unique: usize,
    parts: usize,
    threads: usize,
    opts: BidiOptions,
) -> ParallelOutcome {
    let parts = parts.max(1);
    let threads = threads.clamp(1, parts);
    let part_seed = 0x9a27_11;
    let a_parts = partition(a, parts, part_seed);
    let b_parts = partition(b, parts, part_seed);

    // Per-partition d estimate: uniques split evenly; pad for Poisson spread
    // (mean + 3σ + 4), exactly how PBS provisions sub-sketches.
    let pad = |d: usize| -> usize {
        let mu = d as f64 / parts as f64;
        (mu + 3.0 * mu.sqrt() + 4.0).ceil() as usize
    };
    let da = pad(est_a_unique);
    let db = pad(est_b_unique);

    // Bounded pool: `threads` workers race on `next` for partition indices; `active`
    // and `peak` instrument how many are ever live at once.
    let next = AtomicUsize::new(0);
    let active = AtomicUsize::new(0);
    let peak = AtomicUsize::new(0);
    let results: Vec<bidi::BidiOutcome> = std::thread::scope(|scope| {
        let worker = || {
            let mut local = Vec::new();
            let mut p = next.fetch_add(1, Ordering::Relaxed);
            while p < parts {
                let live = active.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(live, Ordering::SeqCst);
                let (ap, bp) = (&a_parts[p], &b_parts[p]);
                let n = ap.len().max(bp.len());
                let mut params = CsParams::tuned_bidi(n.max(64), da, db);
                params.seed ^= p as u64; // independent matrices per partition
                local.push(bidi::run(ap, bp, &params, opts));
                active.fetch_sub(1, Ordering::SeqCst);
                p = next.fetch_add(1, Ordering::Relaxed);
            }
            local
        };
        let handles: Vec<_> = (0..threads).map(|_| scope.spawn(worker)).collect();
        handles.into_iter().flat_map(|h| h.join().expect("partition worker")).collect()
    });

    let mut a_minus_b = Vec::new();
    let mut b_minus_a = Vec::new();
    let mut total_bytes = 0usize;
    let mut total_msgs = 0usize;
    let mut converged = true;
    let mut bytes_stats = Stats::new();
    for out in results {
        a_minus_b.extend(out.a_minus_b);
        b_minus_a.extend(out.b_minus_a);
        total_bytes += out.comm.total_bytes();
        total_msgs += out.comm.rounds();
        converged &= out.converged;
        bytes_stats.push(out.comm.total_bytes() as f64);
    }
    a_minus_b.sort_unstable();
    b_minus_a.sort_unstable();
    ParallelOutcome {
        a_minus_b,
        b_minus_a,
        total_bytes,
        total_msgs,
        partitions: parts,
        converged,
        bytes_stats,
        peak_workers: peak.into_inner(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn partition_is_disjoint_cover() {
        let ids: Vec<u64> = (0..10_000u64).collect();
        let parts = partition(&ids, 8, 1);
        assert_eq!(parts.len(), 8);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, 10_000);
        // Roughly balanced.
        for p in &parts {
            assert!((1_000..1_550).contains(&p.len()), "part size {}", p.len());
        }
    }

    #[test]
    fn partition_zero_parts_clamps_to_one() {
        let ids: Vec<u64> = (0..100u64).collect();
        let parts = partition(&ids, 0, 7);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].len(), 100);
        // And the full pipeline tolerates parts = 0 / threads = 0 end-to-end.
        let (a, b) = synth::overlap_pair(1_000, 20, 20, 8);
        let out = setx(&a, &b, 20, 20, 0, 0, BidiOptions::default());
        assert!(out.converged);
        assert_eq!(out.partitions, 1);
        assert_eq!(out.peak_workers, 1);
        assert_eq!(out.a_minus_b, synth::difference(&a, &b));
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
    }

    #[test]
    fn parallel_setx_exact() {
        let (a, b) = synth::overlap_pair(12_000, 120, 150, 3);
        let out = setx(&a, &b, 120, 150, 8, 4, BidiOptions::default());
        assert!(out.converged);
        assert_eq!(out.a_minus_b, synth::difference(&a, &b));
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert_eq!(out.partitions, 8);
    }

    #[test]
    fn worker_pool_honors_thread_cap() {
        // Regression for the seed's unbounded spawn: with 64 partitions and a cap of 4,
        // the live-worker high-water mark must never exceed 4.
        let (a, b) = synth::overlap_pair(6_000, 120, 120, 13);
        let out = setx(&a, &b, 120, 120, 64, 4, BidiOptions::default());
        assert!(out.converged);
        assert_eq!(out.a_minus_b, synth::difference(&a, &b));
        assert_eq!(out.b_minus_a, synth::difference(&b, &a));
        assert!(
            (1..=4).contains(&out.peak_workers),
            "thread cap violated: peak {} workers",
            out.peak_workers
        );
    }

    #[test]
    fn partitioning_overhead_is_modest() {
        // §7.3: "the increase in communication cost due to this partitioning should be
        // tiny". With Poisson padding it is bounded; assert < 2.2× the single-partition
        // cost at this scale (the padding term dominates at small per-partition d).
        let (a, b) = synth::overlap_pair(12_000, 200, 200, 5);
        let single = setx(&a, &b, 200, 200, 1, 1, BidiOptions::default());
        let multi = setx(&a, &b, 200, 200, 8, 4, BidiOptions::default());
        assert!(single.converged && multi.converged);
        let ratio = multi.total_bytes as f64 / single.total_bytes as f64;
        assert!(ratio < 2.2, "partitioning overhead ratio {ratio}");
    }
}
