//! The `commonsense` CLI: the unified `setx` driver, experiment harnesses, the l-tuner,
//! the multi-client reconciliation daemon (`serve`) with its verifying load generator
//! (`loadgen`), and a one-shot client role (`connect`).
//!
//! (Arg parsing is hand-rolled: the image's offline crate set has no clap — DESIGN.md §4.)

use commonsense::coordinator::{connect, serve};
use commonsense::data::synth;
use commonsense::experiments;
use commonsense::server::loadgen::{self, LoadgenConfig};
use commonsense::server::SetxServer;
use commonsense::setx::multi::{net as multi_net, MultiReport};
use commonsense::setx::transport::TcpTransport;
use commonsense::setx::{parallel, transport, DiffSize, Mode, Setx, SetxReport};
use std::net::TcpListener;

fn usage() -> ! {
    eprintln!(
        "commonsense — CS.DC'25 CommonSense SetX reproduction

USAGE:
  commonsense setx --transport <mem|tcp|parallel> [--common N] [--a-unique X] [--b-unique Y]
                   [--mode <auto|uni|bidi>] [--explicit-d D] [--parts P] [--threads T]
                                             (one front door, three transports; d is
                                              estimated in the handshake unless
                                              --explicit-d is given)
  commonsense serve [--listen ADDR] [--workers W] [--max-inflight M] [--pool-capacity C]
                    [--no-pool] [--store-capacity C] [--no-store] [--sessions K]
                    [--tenants T] [--common N] [--client-unique X]
                    [--server-unique Y] [--seed S] [--estimate-d]
                    [--metrics-addr ADDR] [--slow-ms MS]
                                             (multi-tenant daemon: keeps T host sets
                                              (namespaces 0..T) online until killed, or
                                              until K sessions when --sessions is given;
                                              final stats as one JSON line. --metrics-addr
                                              serves live Prometheus text on a side
                                              socket; --slow-ms dumps the session trace
                                              of anything slower to stderr)
  commonsense loadgen [--addr ADDR] [--clients N] [--rounds R] [--tenants T] [--common N]
                      [--client-unique X] [--server-unique Y] [--seed S]
                      [--busy-retries K] [--disconnect-pct P] [--estimate-d]
                                             (N concurrent verified clients spread over T
                                              tenants against a `commonsense serve` with
                                              the same workload flags — including --seed
                                              and --tenants; exits non-zero on any
                                              mismatch. --disconnect-pct injects seeded
                                              connection drops into P% of attempts to
                                              exercise the retry layer)
  commonsense connect --addr ADDR            (one client, one sync, same workload flags)
  commonsense multi [--parties N] [--common C] [--unique U] [--seed S]
                    [--host --listen ADDR [--deadline-ms D] | --join --addr ADDR --party I]
                                             (N-party intersection ∩ᵢSᵢ: in-process by
                                              default; --host runs the star coordinator
                                              (party 0) over TCP, --join dials in as
                                              spoke I — all sides synthesize the same
                                              workload from the shared flags and verify
                                              against the exactly-known answer)
  commonsense exp <fig2a|fig2b|table2|examples|ablations|all> [--scale N] [--instances K] [--eth-accounts N]
  commonsense tune [--n N] [--d D] [--bidi] [--trials K]
  commonsense selftest                       (quick end-to-end sanity run)

Defaults: --transport mem, --common 50000 (serve/loadgen/connect: 20000), --a-unique 200,
          --b-unique 300, --parts 16, --threads 4, --scale 50000, --instances 5,
          --eth-accounts 300000, --n 100000, --d 1000, --workers 4, --max-inflight 64,
          --clients 8, --rounds 2, --tenants 1, --client-unique 100, --server-unique 200,
          --seed 42, --busy-retries 3, --disconnect-pct 0, --store-capacity 8,
          --parties 3, --unique 100,
          --deadline-ms 10000. serve/loadgen/connect must share the workload flags
          (including --seed and --tenants) and declare the exactly-known d (one shared
          matrix geometry, the decoder-pool sweet spot) unless --estimate-d is given."
    );
    std::process::exit(2)
}

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(arg) = it.next() {
        if let Some(name) = arg.strip_prefix("--") {
            let value = if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), value);
        } else {
            positional.push(arg);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn get(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{name}")))
            .unwrap_or(default)
    }

    fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn print_multi_report(report: &MultiReport) {
    println!(
        "multi: |∩| = {}, {} of {} spokes completed, {} B total",
        report.intersection.len(),
        report.completed(),
        report.parties.len(),
        report.total_bytes()
    );
    for p in &report.parties {
        match &p.error {
            None => println!(
                "  party {}: {} B, attempts {}, synced = {}",
                p.party,
                p.total_bytes(),
                p.attempts,
                p.synced
            ),
            Some(e) => println!("  party {}: {} B, FAILED: {e}", p.party, p.total_bytes()),
        }
    }
}

fn print_report(who: &str, report: &SetxReport) {
    println!(
        "{who}: |unique| = {}, |∩| = {}, {:?} in {} attempt(s), {} rounds, converged = {}",
        report.local_unique.len(),
        report.intersection.len(),
        report.kind,
        report.attempts,
        report.rounds,
        report.converged
    );
    println!(
        "{who}: {} B total (sent {} B / received {} B) — {}",
        report.total_bytes(),
        report.bytes_sent(),
        report.bytes_received(),
        report.breakdown()
    );
}

/// Build the demo endpoint: mode/diff from flags, everything else defaults. Flag and
/// config mistakes exit through `usage()` like every other CLI error.
fn demo_setx(set: &[u64], args: &Args) -> Setx {
    let mut builder = Setx::builder(set);
    builder = match args.str("mode", "auto").as_str() {
        "uni" => builder.mode(Mode::Uni),
        "bidi" => builder.mode(Mode::Bidi),
        "auto" => builder.mode(Mode::Auto),
        other => {
            eprintln!("unknown --mode {other}");
            usage();
        }
    };
    if args.has("explicit-d") {
        builder = builder.diff_size(DiffSize::Explicit(args.get("explicit-d", 0)));
    }
    builder.build().unwrap_or_else(|e| {
        eprintln!("invalid config: {e}");
        usage();
    })
}

/// Shared `serve`/`loadgen`/`connect` workload shape from CLI flags: both ends of the
/// fleet must be built from the same flags so their config fingerprints (and, with the
/// default explicit d, their negotiated matrix geometry) match.
fn fleet_config(args: &Args) -> LoadgenConfig {
    LoadgenConfig {
        // Clamped ≥ 1: `connect` is fleet client 0, and a zero-session loadgen would
        // vacuously report `verified = true`.
        clients: args.get("clients", 8).max(1),
        rounds: args.get("rounds", 2).max(1),
        common: args.get("common", 20_000),
        client_unique: args.get("client-unique", 100),
        server_unique: args.get("server-unique", 200),
        seed: args.get("seed", 42) as u64,
        busy_retries: args.get("busy-retries", 3),
        disconnect_rate: args.get("disconnect-pct", 0) as f64 / 100.0,
        estimate_diff: args.has("estimate-d"),
        tenants: args.get("tenants", 1).max(1),
        tracing: true,
    }
}

fn main() -> anyhow::Result<()> {
    let args = parse_args();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    match cmd {
        "setx" => {
            let common = args.get("common", 50_000);
            let au = args.get("a-unique", 200);
            let bu = args.get("b-unique", 300);
            let (a, b) = synth::overlap_pair(common, au, bu, 42);
            let alice = demo_setx(&a, &args);
            let bob = demo_setx(&b, &args);
            let transport_kind = args.str("transport", "mem");
            println!(
                "setx over {transport_kind}: |A| = {}, |B| = {} (true: |A\\B| = {au}, |B\\A| = {bu})",
                a.len(),
                b.len()
            );
            let t0 = std::time::Instant::now();
            match transport_kind.as_str() {
                "mem" => {
                    let (ra, rb) = alice.run_pair(&bob)?;
                    print_report("alice", &ra);
                    print_report("bob", &rb);
                }
                "tcp" => {
                    // Loopback demo: server thread + client in-process. For two real
                    // hosts, use `commonsense serve` / `commonsense connect`.
                    let listener = TcpListener::bind("127.0.0.1:0")?;
                    let addr = listener.local_addr()?;
                    let bob2 = bob.clone();
                    let server = std::thread::spawn(move || serve(&listener, &bob2));
                    let ra = connect(addr, &alice)?;
                    let rb = server.join().expect("server thread")?;
                    print_report("alice", &ra);
                    print_report("bob", &rb);
                }
                "parallel" => {
                    let parts = args.get("parts", 16);
                    let threads = args.get("threads", 4);
                    let out = parallel::run_partitioned(&alice, &bob, parts, threads)?;
                    println!("{} partitions, peak workers {}", out.partitions, out.peak_workers);
                    print_report("alice", &out.client);
                    print_report("bob", &out.server);
                }
                other => {
                    eprintln!("unknown --transport {other}");
                    usage();
                }
            }
            println!("wall: {:?}", t0.elapsed());
        }
        "exp" => {
            let what = args.positional.get(1).map(String::as_str).unwrap_or("all");
            let scale = args.get("scale", 50_000);
            let instances = args.get("instances", 5);
            let eth = args.get("eth-accounts", 300_000);
            let fr = [0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5];
            let bu: Vec<usize> = [0.0001, 0.0003, 0.001, 0.003, 0.01, 0.1, 0.3]
                .iter()
                .map(|f| ((scale as f64 * f) as usize).max(2))
                .collect();
            match what {
                "fig2a" => {
                    experiments::fig2a(scale, &fr, instances, true);
                }
                "fig2b" => {
                    experiments::fig2b(scale, scale / 100, &bu, instances, true);
                }
                "table2" | "ethereum" => {
                    experiments::ethereum(eth, true);
                }
                "examples" => experiments::examples(scale, true),
                "ablations" => experiments::ablations(scale.min(20_000), true),
                "all" => {
                    experiments::fig2a(scale, &fr, instances, true);
                    experiments::fig2b(scale, scale / 100, &bu, instances, true);
                    experiments::ethereum(eth, true);
                    experiments::examples(scale, true);
                    experiments::ablations(scale.min(20_000), true);
                }
                _ => usage(),
            }
        }
        "tune" => {
            let n = args.get("n", 100_000);
            let d = args.get("d", 1_000);
            let trials = args.get("trials", 20);
            experiments::tune_l(n, d, args.has("bidi"), trials, true);
        }
        "serve" => {
            // The multi-client daemon (crate::server::SetxServer). The host set comes
            // from the shared serve/loadgen workload flags so a `commonsense loadgen`
            // with the same flags speaks the same config fingerprint.
            let addr = args.str("listen", "127.0.0.1:7700");
            let cfg = fleet_config(&args);
            let (hosts, _, _) = cfg.tenant_workload();
            let endpoint = cfg.endpoint(&hosts[0]).unwrap_or_else(|e| {
                eprintln!("invalid config: {e}");
                usage();
            });
            let workers = args.get("workers", 4);
            let pool_capacity = if args.has("no-pool") {
                0
            } else {
                args.get("pool-capacity", 4 * workers.max(1))
            };
            let store_capacity =
                if args.has("no-store") { 0 } else { args.get("store-capacity", 8) };
            let sessions = args.get("sessions", 0);
            let mut builder = SetxServer::builder(endpoint)
                .workers(workers)
                .max_inflight_sessions(args.get("max-inflight", 64))
                .pool_capacity(pool_capacity)
                .sketch_store_capacity(store_capacity);
            if args.has("metrics-addr") {
                builder = builder.metrics_addr(args.str("metrics-addr", "127.0.0.1:0"));
            }
            if args.has("slow-ms") {
                let slow = std::time::Duration::from_millis(args.get("slow-ms", 1_000) as u64);
                builder = builder.slow_session_threshold(slow);
            }
            // Tenant 0 is the builder endpoint's set; the rest ride along by namespace.
            for (ns, host) in hosts.iter().enumerate().skip(1) {
                builder = builder.tenant(ns as u32, host.clone());
            }
            let server = builder.bind(&addr)?;
            println!(
                "serving {} tenant(s), |B| = {} each on {} (workers {workers}, max inflight {}, \
                 pool capacity {}, sketch store capacity {store_capacity}, {})",
                hosts.len(),
                hosts[0].len(),
                server.local_addr(),
                args.get("max-inflight", 64),
                pool_capacity,
                if sessions == 0 {
                    "until killed".to_string()
                } else {
                    format!("until {sessions} sessions")
                }
            );
            if let Some(maddr) = server.metrics_addr() {
                println!("metrics: http://{maddr}/metrics (Prometheus text)");
            }
            let mut last_done = 0u64;
            loop {
                std::thread::sleep(std::time::Duration::from_millis(200));
                let stats = server.stats();
                let done = stats.sessions_served + stats.sessions_failed;
                if done != last_done {
                    last_done = done;
                    println!("{}", stats.to_json());
                }
                if sessions > 0 && done >= sessions as u64 {
                    break;
                }
            }
            let stats = server.shutdown();
            println!("{}", stats.to_json());
            if stats.sessions_failed > 0 {
                std::process::exit(1);
            }
        }
        "loadgen" => {
            let addr = args.str("addr", "127.0.0.1:7700");
            let cfg = fleet_config(&args);
            println!(
                "loadgen: {} clients × {} rounds over {} tenant(s) against {addr} \
                 (|common| = {}, d = {})",
                cfg.clients,
                cfg.rounds,
                cfg.tenants,
                cfg.common,
                cfg.true_d()
            );
            let report = loadgen::run(&addr, &cfg);
            println!(
                "loadgen: {} ok / {} failed ({} gave up) / {} busy-rejections, \
                 {} retries, {} B total, {:.1} sessions/s, verified = {}",
                report.sessions_ok,
                report.sessions_failed,
                report.gave_up,
                report.busy_rejections,
                report.retries,
                report.total_bytes,
                report.sessions_per_sec(),
                report.verified()
            );
            println!(
                "loadgen: session latency p50 = {:.3} ms, p95 = {:.3} ms, p99 = {:.3} ms \
                 over {} timed sessions",
                report.p50_ns() as f64 / 1e6,
                report.p95_ns() as f64 / 1e6,
                report.p99_ns() as f64 / 1e6,
                report.latency.count()
            );
            for failure in &report.failures {
                eprintln!("loadgen failure: {failure}");
            }
            if !report.verified() {
                std::process::exit(1);
            }
        }
        "connect" => {
            // One client, one verified sync against a `commonsense serve` daemon started
            // with the same workload flags (it is loadgen client 0).
            let addr = args.str("addr", "127.0.0.1:7700");
            let cfg = fleet_config(&args);
            let (host, clients, expected) = cfg.workload();
            let alice = cfg.endpoint(&clients[0]).unwrap_or_else(|e| {
                eprintln!("invalid config: {e}");
                usage();
            });
            println!(
                "client connecting to {addr} (|A| = {}, host |B| = {})",
                clients[0].len(),
                host.len()
            );
            let report = alice.run(&mut TcpTransport::connect(&addr)?)?;
            print_report("client", &report);
            if report.intersection != expected {
                eprintln!("intersection MISMATCH against the exactly-known answer");
                std::process::exit(1);
            }
            println!("intersection verified ({} elements)", expected.len());
        }
        "multi" => {
            // N-party intersection. Every side synthesizes the full workload from the
            // shared flags (like serve/loadgen), so each role holds its own set *and*
            // the exactly-known answer to verify against.
            let parties = args.get("parties", 3).max(2);
            let common = args.get("common", 20_000);
            let unique = args.get("unique", 100);
            let seed = args.get("seed", 42) as u64;
            let sets = synth::overlap_n(parties, common, unique, seed);
            let expected = sets
                .iter()
                .skip(1)
                .fold(sets[0].clone(), |acc, s| synth::intersect(&acc, s));
            let learned = if args.has("join") {
                let addr = args.str("addr", "127.0.0.1:7800");
                let id = args.get("party", 1);
                if id == 0 || id >= parties {
                    eprintln!("--party must be in 1..{parties} (party 0 is the host)");
                    usage();
                }
                let endpoint = Setx::builder(&sets[id]).build().unwrap_or_else(|e| {
                    eprintln!("invalid config: {e}");
                    usage();
                });
                let cfg = *endpoint.config();
                println!(
                    "party {id}/{parties} joining {addr} (|S| = {}, |∩| expected = {})",
                    sets[id].len(),
                    expected.len()
                );
                let report = multi_net::join_round(
                    &addr,
                    &cfg,
                    sets[id].clone(),
                    id as u32,
                    parties as u32,
                )?;
                print_report(&format!("party {id}"), &report);
                report.intersection.clone()
            } else if args.has("host") {
                let addr = args.str("listen", "127.0.0.1:7800");
                let deadline =
                    std::time::Duration::from_millis(args.get("deadline-ms", 10_000) as u64);
                let endpoint = Setx::builder(&sets[0]).build().unwrap_or_else(|e| {
                    eprintln!("invalid config: {e}");
                    usage();
                });
                let cfg = *endpoint.config();
                let listener = TcpListener::bind(&addr)?;
                println!(
                    "hosting a {parties}-party round on {} (|C| = {}, join deadline {deadline:?})",
                    listener.local_addr()?,
                    sets[0].len()
                );
                let report = multi_net::host_round(
                    &listener,
                    &cfg,
                    sets[0].clone(),
                    parties as u32,
                    deadline,
                )?;
                print_multi_report(&report);
                report.intersection
            } else {
                println!(
                    "in-process {parties}-party round (|S| = {} each, |∩| = {})",
                    sets[0].len(),
                    expected.len()
                );
                let report = Setx::multi(&sets)?;
                print_multi_report(&report);
                report.intersection
            };
            if learned != expected {
                eprintln!("intersection MISMATCH against the exactly-known answer");
                std::process::exit(1);
            }
            println!("intersection verified ({} elements)", expected.len());
        }
        "selftest" => {
            let (a, b) = synth::overlap_pair(10_000, 100, 150, 7);
            let alice = Setx::builder(&a).build().expect("config");
            let bob = Setx::builder(&b).build().expect("config");
            let (mut ta, mut tb) = transport::mem_pair();
            let a2 = alice.clone();
            let join = std::thread::spawn(move || a2.run(&mut ta));
            let rb = bob.run(&mut tb)?;
            let ra = join.join().expect("alice thread")?;
            println!(
                "setx selftest: attempts={} rounds={} bytes={} (exact={})",
                ra.attempts,
                ra.rounds,
                ra.total_bytes(),
                ra.local_unique == synth::difference(&a, &b)
                    && rb.local_unique == synth::difference(&b, &a)
            );
            match commonsense::runtime::Runtime::load_default() {
                Ok(rt) => println!(
                    "runtime selftest: platform={} artifacts l={} nb={} steps={}",
                    rt.platform(),
                    rt.shapes.l,
                    rt.shapes.nb,
                    rt.shapes.steps
                ),
                Err(e) => println!("runtime selftest skipped: {e:#}"),
            }
        }
        _ => usage(),
    }
    Ok(())
}
