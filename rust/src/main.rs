//! The `commonsense` CLI: experiment drivers, the l-tuner, and TCP serve/connect roles.
//!
//! (Arg parsing is hand-rolled: the image's offline crate set has no clap — DESIGN.md §4.)

use commonsense::coordinator::{connect_initiator, parallel, serve_responder};
use commonsense::data::synth;
use commonsense::experiments;
use commonsense::protocol::bidi::BidiOptions;
use commonsense::protocol::CsParams;
use std::net::TcpListener;

fn usage() -> ! {
    eprintln!(
        "commonsense — CS.DC'25 CommonSense SetX reproduction

USAGE:
  commonsense exp <fig2a|fig2b|table2|examples|ablations|all> [--scale N] [--instances K] [--eth-accounts N]
  commonsense tune [--n N] [--d D] [--bidi] [--trials K]
  commonsense serve --listen ADDR            (responder; set = synthetic demo workload)
  commonsense connect --addr ADDR            (initiator; set = synthetic demo workload)
  commonsense parallel [--common N] [--a-unique X] [--b-unique Y] [--parts P] [--threads T]
                                             (partitioned SetX on the bounded worker pool)
  commonsense selftest                       (quick end-to-end sanity run)

Defaults: --scale 50000, --instances 5, --eth-accounts 300000, --n 100000, --d 1000."
    );
    std::process::exit(2)
}

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(arg) = it.next() {
        if let Some(name) = arg.strip_prefix("--") {
            let value = if it.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), value);
        } else {
            positional.push(arg);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn get(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{name}")))
            .unwrap_or(default)
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn main() -> anyhow::Result<()> {
    let args = parse_args();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    match cmd {
        "exp" => {
            let what = args.positional.get(1).map(String::as_str).unwrap_or("all");
            let scale = args.get("scale", 50_000);
            let instances = args.get("instances", 5);
            let eth = args.get("eth-accounts", 300_000);
            let fr = [0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5];
            let bu: Vec<usize> = [0.0001, 0.0003, 0.001, 0.003, 0.01, 0.1, 0.3]
                .iter()
                .map(|f| ((scale as f64 * f) as usize).max(2))
                .collect();
            match what {
                "fig2a" => {
                    experiments::fig2a(scale, &fr, instances, true);
                }
                "fig2b" => {
                    experiments::fig2b(scale, scale / 100, &bu, instances, true);
                }
                "table2" | "ethereum" => {
                    experiments::ethereum(eth, true);
                }
                "examples" => experiments::examples(scale, true),
                "ablations" => experiments::ablations(scale.min(20_000), true),
                "all" => {
                    experiments::fig2a(scale, &fr, instances, true);
                    experiments::fig2b(scale, scale / 100, &bu, instances, true);
                    experiments::ethereum(eth, true);
                    experiments::examples(scale, true);
                    experiments::ablations(scale.min(20_000), true);
                }
                _ => usage(),
            }
        }
        "tune" => {
            let n = args.get("n", 100_000);
            let d = args.get("d", 1_000);
            let trials = args.get("trials", 20);
            experiments::tune_l(n, d, args.has("bidi"), trials, true);
        }
        "serve" => {
            let addr = args.flags.get("listen").cloned().unwrap_or_else(|| "127.0.0.1:7700".into());
            let (_, b) = synth::overlap_pair(args.get("common", 20_000), 100, 200, 42);
            let listener = TcpListener::bind(&addr)?;
            println!("responder listening on {addr} (|B| = {})", b.len());
            let report = serve_responder(&listener, &b, BidiOptions::default())?;
            println!(
                "session done: |B\\A| = {}, sent {} B, received {} B, converged = {}",
                report.unique.len(),
                report.bytes_sent,
                report.bytes_received,
                report.converged
            );
        }
        "connect" => {
            let addr = args.flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7700".into());
            let common = args.get("common", 20_000);
            let (a, _) = synth::overlap_pair(common, 100, 200, 42);
            let params = CsParams::tuned_bidi(common + 300, 100, 200);
            println!("initiator connecting to {addr} (|A| = {})", a.len());
            let report = connect_initiator(&addr, &a, &params, BidiOptions::default())?;
            println!(
                "session done: |A\\B| = {}, sent {} B, received {} B, converged = {}",
                report.unique.len(),
                report.bytes_sent,
                report.bytes_received,
                report.converged
            );
        }
        "parallel" => {
            let common = args.get("common", 50_000);
            let au = args.get("a-unique", 200);
            let bu = args.get("b-unique", 200);
            let parts = args.get("parts", 16);
            let threads = args.get("threads", 4);
            let (a, b) = synth::overlap_pair(common, au, bu, 42);
            println!(
                "parallel setx: |A| = {}, |B| = {}, {parts} partitions on ≤ {threads} workers",
                a.len(),
                b.len()
            );
            let t0 = std::time::Instant::now();
            let out = parallel::setx(&a, &b, au, bu, parts, threads, BidiOptions::default());
            println!(
                "done in {:?}: |A\\B| = {}, |B\\A| = {}, {} B in {} msgs, peak workers {}, converged = {}",
                t0.elapsed(),
                out.a_minus_b.len(),
                out.b_minus_a.len(),
                out.total_bytes,
                out.total_msgs,
                out.peak_workers,
                out.converged
            );
        }
        "selftest" => {
            let (a, b) = synth::overlap_pair(10_000, 100, 150, 7);
            let params = CsParams::tuned_bidi(10_250, 100, 150);
            let out = commonsense::protocol::bidi::run(&a, &b, &params, BidiOptions::default());
            println!(
                "bidi selftest: converged={} rounds={} bytes={} (exact={})",
                out.converged,
                out.rounds,
                out.comm.total_bytes(),
                out.a_minus_b == synth::difference(&a, &b)
                    && out.b_minus_a == synth::difference(&b, &a)
            );
            match commonsense::runtime::Runtime::load_default() {
                Ok(rt) => println!(
                    "runtime selftest: platform={} artifacts l={} nb={} steps={}",
                    rt.platform(),
                    rt.shapes.l,
                    rt.shapes.nb,
                    rt.shapes.steps
                ),
                Err(e) => println!("runtime selftest skipped: {e:#}"),
            }
        }
        _ => usage(),
    }
    Ok(())
}
