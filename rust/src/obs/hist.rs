//! [`LogHistogram`]: a fixed-size, mergeable, power-of-two-bucket latency histogram.
//!
//! Sixty-four buckets cover the whole `u64` range — bucket `i` holds values in
//! `[2^i, 2^(i+1) - 1]` (with 0 folded into bucket 0) — so recording is one
//! `leading_zeros` plus an increment, and two histograms merge by adding bucket
//! counts. That makes the type safe to keep per shard (per tenant, per thread) and sum
//! at snapshot time, exactly like the server's byte counters: the shard-sum invariant
//! extends to histograms because merge is associative and commutative, and
//! `merge(a, b)` equals recording the concatenation of both push streams (property
//! test below).
//!
//! Quantiles are read from the bucket boundaries: `quantile(q)` returns the *upper*
//! bound of the bucket holding the q-th ranked sample, i.e. a conservative estimate
//! that is never more than 2× the true value. For latency reporting (p50/p95/p99 of
//! session wall time in nanoseconds) that resolution matches the noise floor of any
//! real deployment.
//!
//! [`AtomicHistogram`] is the lock-free shard the server's poller threads update
//! concurrently; `snapshot()` materializes it as a plain [`LogHistogram`].

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of power-of-two buckets (covers the full `u64` range).
pub const BUCKETS: usize = 64;

/// Fixed-size power-of-two-bucket histogram. `Copy` on purpose: at 528 bytes it rides
/// inside snapshot structs ([`crate::server::TenantStats`]) without heap traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LogHistogram {
    counts: [u64; BUCKETS],
    count: u64,
    /// Saturating sum of every recorded value (the Prometheus `_sum` series).
    sum: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { counts: [0; BUCKETS], count: 0, sum: 0 }
    }
}

/// Bucket index for a value: `floor(log2(max(v, 1)))`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    63 - (v | 1).leading_zeros() as usize
}

/// Inclusive upper bound of bucket `idx` (`2^(idx+1) - 1`, saturating at `u64::MAX`).
#[inline]
pub fn bucket_upper(idx: usize) -> u64 {
    if idx >= BUCKETS - 1 {
        u64::MAX
    } else {
        (2u64 << idx) - 1
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Fold another histogram into this one. Equivalent to having recorded both push
    /// streams into a single histogram (in any order).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += *b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Saturating sum of every recorded value.
    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Upper bound of the bucket holding the q-th ranked sample (`q` clamped to
    /// `[0, 1]`). Returns 0 for an empty histogram — never NaN, never a panic.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return bucket_upper(idx);
            }
        }
        u64::MAX
    }

    /// `(inclusive upper bound, count)` per non-empty bucket, ascending — the
    /// Prometheus `_bucket` series before cumulation.
    pub fn buckets(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(idx, &c)| (bucket_upper(idx), c))
    }
}

/// Lock-free histogram shard: the concurrent sibling of [`LogHistogram`], updated by
/// the server's poller threads with relaxed atomics and snapshotted for exposition.
#[derive(Debug)]
pub struct AtomicHistogram {
    counts: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for AtomicHistogram {
    fn default() -> Self {
        AtomicHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }
}

impl AtomicHistogram {
    /// Record one sample (relaxed ordering — counters, not synchronization).
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Materialize the current counts as a plain histogram.
    pub fn snapshot(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for (i, c) in self.counts.iter().enumerate() {
            h.counts[i] = c.load(Ordering::Relaxed);
        }
        h.count = self.count.load(Ordering::Relaxed);
        h.sum = self.sum.load(Ordering::Relaxed);
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::Xoshiro256;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(u64::MAX), 63);
        assert_eq!(bucket_upper(0), 1);
        assert_eq!(bucket_upper(1), 3);
        assert_eq!(bucket_upper(62), (2u64 << 62) - 1);
        assert_eq!(bucket_upper(63), u64::MAX);
        // Every value lands in a bucket whose bounds contain it.
        for v in [0u64, 1, 2, 5, 1023, 1024, 1 << 40, u64::MAX] {
            let idx = bucket_index(v);
            assert!(v <= bucket_upper(idx));
            if idx > 0 {
                assert!(v > bucket_upper(idx - 1));
            }
        }
    }

    #[test]
    fn quantiles_are_conservative_and_never_nan() {
        let mut h = LogHistogram::new();
        assert_eq!(h.quantile(0.5), 0, "empty histogram quantile is the 0 sentinel");
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum(), 500_500);
        // The true p50 is 500; the bucket upper bound 511 is within 2×.
        let p50 = h.quantile(0.5);
        assert!((500..=1023).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((990..=1023).contains(&p99), "p99={p99}");
        // Degenerate q values clamp instead of panicking.
        assert_eq!(h.quantile(-1.0), 1);
        assert_eq!(h.quantile(2.0), h.quantile(1.0));
    }

    /// The property the shard-sum invariant rests on: merging two histograms is
    /// indistinguishable from recording the concatenation of both push streams.
    #[test]
    fn merge_equals_concatenated_pushes() {
        let mut rng = Xoshiro256::seed_from_u64(0x0b5_4157);
        for round in 0..50 {
            let mut a = LogHistogram::new();
            let mut b = LogHistogram::new();
            let mut concat = LogHistogram::new();
            let n = (rng.next_u64() % 200) as usize;
            for _ in 0..n {
                // Spread samples across the whole range via a random bit width.
                let v = rng.next_u64() >> (rng.next_u64() % 64);
                if rng.next_u64() % 2 == 0 {
                    a.record(v);
                } else {
                    b.record(v);
                }
                concat.record(v);
            }
            let mut merged = a;
            merged.merge(&b);
            assert_eq!(merged, concat, "round {round}");
            // Merge is commutative.
            let mut flipped = b;
            flipped.merge(&a);
            assert_eq!(flipped, concat, "round {round} (flipped)");
        }
    }

    #[test]
    fn atomic_shard_snapshots_match_plain_recording() {
        let shard = AtomicHistogram::default();
        let mut plain = LogHistogram::new();
        for v in [0u64, 1, 7, 4096, 1 << 33] {
            shard.record(v);
            plain.record(v);
        }
        assert_eq!(shard.snapshot(), plain);
    }
}
