//! Observability: session tracing and latency histograms for the whole SetX stack.
//!
//! The crate accounts for every *byte* through [`crate::metrics::CommLog`]; this module
//! adds the *time* axis with zero dependencies and zero wire impact:
//!
//! * [`Clock`] — a monotonic nanosecond clock behind a trait, so the sans-io layers
//!   ([`crate::protocol::session::Session`], the setx endpoint, the multi-party
//!   coordinator) never call `Instant::now()` directly (CI lints for it) and tests can
//!   inject a [`ManualClock`] for fully deterministic timelines.
//! * [`SessionTrace`] — a timestamped timeline of [`SpanEvent`]s recording every phase
//!   transition of a session: handshake → estimate → sketch encode → decoder build →
//!   one [`SpanKind::Attempt`] span per ladder rung → one [`SpanKind::Round`] marker per
//!   payload frame → confirm. The trace rides on [`crate::setx::SetxReport::trace`] and
//!   feeds the server's slow-session log.
//! * [`Tracer`] — the recording half: monotone-clamped `open`/`close` edges, a
//!   `disabled` mode that compiles to a branch (the histogram-off ablation), and
//!   `absorb` for merging an inner session's timeline into its endpoint's.
//! * [`hist::LogHistogram`] — the mergeable power-of-two-bucket histogram behind every
//!   latency figure (`loadgen` p50/p95/p99, the server's per-tenant shards, the
//!   Prometheus exposition).
//!
//! ## Trace timeline (one successful two-attempt session)
//!
//! ```text
//! Handshake  ├────────────┤
//! Estimate     ├───┤
//! Attempt(0)              ├──────────────┤
//!   SketchEncode            ├──┤
//!   DecoderBuild                 ├──┤
//!   Round                    ·  ·   ·  ·      (one marker per payload frame)
//!   Confirm                              ·
//! Attempt(1)                              ├─────────┤
//!   …
//! ```
//!
//! Well-formedness (checked by [`SessionTrace::is_well_formed`] and property-tested in
//! `rust/tests/trace_properties.rs`): timestamps are non-decreasing, and for every
//! [`SpanKind`] the open/close edges balance like parentheses. The span *counts* tie to
//! the report by construction — `Attempt` spans equal `report.attempts` and `Round`
//! markers equal `report.rounds` — because they are emitted at the same choke points
//! that advance the ladder and charge the [`crate::metrics::CommLog`].

pub mod hist;

pub use hist::{AtomicHistogram, LogHistogram};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::{Duration, Instant};

/// A monotonic nanosecond clock. Implementations must be cheap (called per frame) and
/// non-decreasing per instance; the absolute origin is arbitrary.
pub trait Clock: Send + Sync {
    /// Nanoseconds since this clock's (arbitrary) origin.
    fn now_ns(&self) -> u64;
}

/// The real wall clock: monotonic nanoseconds since the clock was created.
#[derive(Debug)]
pub struct MonotonicClock {
    origin: Instant,
}

impl MonotonicClock {
    pub fn new() -> Self {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// A hand-advanced clock for deterministic tests: `now_ns` returns exactly what the
/// test last set, so traces and histograms come out bit-identical across runs.
#[derive(Debug, Default)]
pub struct ManualClock {
    ns: AtomicU64,
}

impl ManualClock {
    pub fn new(start_ns: u64) -> Self {
        ManualClock { ns: AtomicU64::new(start_ns) }
    }

    /// Move the clock forward by `delta_ns`.
    pub fn advance(&self, delta_ns: u64) {
        self.ns.fetch_add(delta_ns, Ordering::Relaxed);
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.ns.load(Ordering::Relaxed)
    }
}

/// The process-wide default clock (one shared origin, so timestamps from different
/// tracers in the same process are directly comparable and merge cleanly).
pub fn default_clock() -> Arc<dyn Clock> {
    static CLOCK: OnceLock<Arc<dyn Clock>> = OnceLock::new();
    CLOCK.get_or_init(|| Arc::new(MonotonicClock::new())).clone()
}

/// What a span measures. `Attempt` carries the 0-based ladder-rung index so each rung
/// is its own span; `Round` is a per-payload-frame marker (sketch and residue frames —
/// exactly what [`crate::metrics::CommLog::payload_frames`] counts).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// `EstHello` exchange up to the negotiated verdict.
    Handshake,
    /// Estimator construction / difference estimation inside the handshake.
    Estimate,
    /// One own-set sketch encode (the initiator's dominant local cost).
    SketchEncode,
    /// One decoder (CSR) construction or cache checkout.
    DecoderBuild,
    /// One ladder rung, open from its first frame to its verdict.
    Attempt(u32),
    /// One payload frame (sketch or residue) charged to the comm log.
    Round,
    /// One `Confirm` frame exchanged.
    Confirm,
    /// Multi-party: the coordinator's join barrier.
    MultiJoin,
    /// Multi-party: the collect barrier (shared geometry out → all sketches in).
    MultiCollect,
    /// Multi-party: the constraint barrier (intersection commit).
    MultiConstraint,
    /// Multi-party: the final confirm barrier.
    MultiFinal,
}

/// Whether the event opens or closes its span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanEdge {
    Open,
    Close,
}

/// One timestamped edge in a session timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    pub kind: SpanKind,
    pub edge: SpanEdge,
    /// Nanoseconds on the recording tracer's clock (shared origin under
    /// [`default_clock`]).
    pub at_ns: u64,
}

/// Per-phase wall-time breakdown extracted from a [`SessionTrace`] (closed spans only;
/// `Attempt` rungs sum into `attempts`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseDurations {
    pub handshake: Duration,
    pub estimate: Duration,
    pub sketch_encode: Duration,
    pub decoder_build: Duration,
    /// Summed over every ladder rung.
    pub attempts: Duration,
    pub confirm: Duration,
    /// First event to last event.
    pub total: Duration,
}

/// A timestamped timeline of span edges — the full "where did the time go" record of
/// one session, cheap enough to keep on every [`crate::setx::SetxReport`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SessionTrace {
    pub events: Vec<SpanEvent>,
}

impl SessionTrace {
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Merge another timeline into this one, keeping timestamps sorted (stable, so
    /// same-timestamp edges keep their per-source order and balance is preserved).
    pub fn merge(&mut self, other: &SessionTrace) {
        if other.events.is_empty() {
            return;
        }
        self.events.extend_from_slice(&other.events);
        self.events.sort_by_key(|e| e.at_ns);
    }

    /// Number of spans (open edges) whose kind matches `pred`.
    pub fn count_spans(&self, pred: impl Fn(SpanKind) -> bool) -> usize {
        self.events
            .iter()
            .filter(|e| e.edge == SpanEdge::Open && pred(e.kind))
            .count()
    }

    /// Timeline sanity: timestamps non-decreasing, and per kind the open/close edges
    /// balance like parentheses (never more closes than opens, none left open).
    pub fn is_well_formed(&self) -> bool {
        let mut last = 0u64;
        let mut depth: Vec<(SpanKind, i64)> = Vec::new();
        for e in &self.events {
            if e.at_ns < last {
                return false;
            }
            last = e.at_ns;
            let slot = match depth.iter_mut().find(|(k, _)| *k == e.kind) {
                Some(s) => s,
                None => {
                    depth.push((e.kind, 0));
                    depth.last_mut().expect("just pushed")
                }
            };
            match e.edge {
                SpanEdge::Open => slot.1 += 1,
                SpanEdge::Close => {
                    slot.1 -= 1;
                    if slot.1 < 0 {
                        return false;
                    }
                }
            }
        }
        depth.iter().all(|(_, d)| *d == 0)
    }

    /// Fold closed spans into a per-phase wall-time breakdown.
    pub fn phase_durations(&self) -> PhaseDurations {
        let mut out = PhaseDurations::default();
        // Open-edge timestamp stacks, one per kind seen (kinds are few; linear scan).
        let mut open: Vec<(SpanKind, Vec<u64>)> = Vec::new();
        for e in &self.events {
            let slot = match open.iter_mut().find(|(k, _)| *k == e.kind) {
                Some(s) => s,
                None => {
                    open.push((e.kind, Vec::new()));
                    open.last_mut().expect("just pushed")
                }
            };
            match e.edge {
                SpanEdge::Open => slot.1.push(e.at_ns),
                SpanEdge::Close => {
                    let Some(start) = slot.1.pop() else { continue };
                    let d = Duration::from_nanos(e.at_ns.saturating_sub(start));
                    match e.kind {
                        SpanKind::Handshake => out.handshake += d,
                        SpanKind::Estimate => out.estimate += d,
                        SpanKind::SketchEncode => out.sketch_encode += d,
                        SpanKind::DecoderBuild => out.decoder_build += d,
                        SpanKind::Attempt(_) => out.attempts += d,
                        SpanKind::Confirm => out.confirm += d,
                        _ => {}
                    }
                }
            }
        }
        if let (Some(first), Some(last)) = (self.events.first(), self.events.last()) {
            out.total = Duration::from_nanos(last.at_ns.saturating_sub(first.at_ns));
        }
        out
    }

    /// Human-readable dump (one line per edge, microsecond offsets from the first
    /// event) — what the server's slow-session log prints.
    pub fn render(&self) -> String {
        let origin = self.events.first().map(|e| e.at_ns).unwrap_or(0);
        let mut out = String::with_capacity(self.events.len() * 32);
        for e in &self.events {
            let edge = match e.edge {
                SpanEdge::Open => "open ",
                SpanEdge::Close => "close",
            };
            let us = (e.at_ns - origin) / 1_000;
            out.push_str(&format!("  +{us:>9}us {edge} {:?}\n", e.kind));
        }
        out
    }
}

/// The recording half of a trace: a clock plus a monotone-clamped event sink.
///
/// Disabled tracers ([`Tracer::disabled`], the `SetxBuilder::tracing(false)` ablation)
/// skip the clock read entirely, so the overhead of tracing-off is one branch per
/// call site.
#[derive(Clone)]
pub struct Tracer {
    clock: Arc<dyn Clock>,
    enabled: bool,
    last_ns: u64,
    trace: SessionTrace,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("enabled", &self.enabled)
            .field("events", &self.trace.events.len())
            .finish()
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    /// An enabled tracer on the process-wide [`default_clock`].
    pub fn new() -> Tracer {
        Tracer::with_clock(default_clock())
    }

    /// An enabled tracer on an injected clock (deterministic tests use
    /// [`ManualClock`]).
    pub fn with_clock(clock: Arc<dyn Clock>) -> Tracer {
        Tracer { clock, enabled: true, last_ns: 0, trace: SessionTrace::default() }
    }

    /// A tracer that records nothing (the tracing-off ablation).
    pub fn disabled() -> Tracer {
        Tracer { clock: default_clock(), enabled: false, last_ns: 0, trace: SessionTrace::default() }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// A fresh tracer sharing this one's clock and enablement — for an inner session
    /// whose timeline is later [`Tracer::absorb`]ed back.
    pub fn child(&self) -> Tracer {
        Tracer {
            clock: self.clock.clone(),
            enabled: self.enabled,
            last_ns: 0,
            trace: SessionTrace::default(),
        }
    }

    /// Monotone-clamped timestamp: never before the previous event of this tracer
    /// (guards against clocks that are monotonic per call site but merged timelines).
    fn stamp(&mut self) -> u64 {
        let t = self.clock.now_ns().max(self.last_ns);
        self.last_ns = t;
        t
    }

    pub fn open(&mut self, kind: SpanKind) {
        if !self.enabled {
            return;
        }
        let at_ns = self.stamp();
        self.trace.events.push(SpanEvent { kind, edge: SpanEdge::Open, at_ns });
    }

    pub fn close(&mut self, kind: SpanKind) {
        if !self.enabled {
            return;
        }
        let at_ns = self.stamp();
        self.trace.events.push(SpanEvent { kind, edge: SpanEdge::Close, at_ns });
    }

    /// A zero-duration marker span (open + close at one timestamp) — per-frame events
    /// like [`SpanKind::Round`] and [`SpanKind::Confirm`].
    pub fn instant(&mut self, kind: SpanKind) {
        if !self.enabled {
            return;
        }
        let at_ns = self.stamp();
        self.trace.events.push(SpanEvent { kind, edge: SpanEdge::Open, at_ns });
        self.trace.events.push(SpanEvent { kind, edge: SpanEdge::Close, at_ns });
    }

    /// Merge an inner timeline (an absorbed session's) into this tracer's.
    pub fn absorb(&mut self, other: &SessionTrace) {
        if !self.enabled {
            return;
        }
        self.trace.merge(other);
        if let Some(last) = self.trace.events.last() {
            self.last_ns = self.last_ns.max(last.at_ns);
        }
    }

    pub fn trace(&self) -> &SessionTrace {
        &self.trace
    }

    /// Take the recorded timeline out (the tracer keeps recording from empty).
    pub fn take(&mut self) -> SessionTrace {
        std::mem::take(&mut self.trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_gives_deterministic_timelines() {
        let clock = Arc::new(ManualClock::new(100));
        let mut t = Tracer::with_clock(clock.clone());
        t.open(SpanKind::Handshake);
        clock.advance(50);
        t.instant(SpanKind::Round);
        clock.advance(25);
        t.close(SpanKind::Handshake);
        let trace = t.take();
        assert!(trace.is_well_formed());
        assert_eq!(
            trace.events,
            vec![
                SpanEvent { kind: SpanKind::Handshake, edge: SpanEdge::Open, at_ns: 100 },
                SpanEvent { kind: SpanKind::Round, edge: SpanEdge::Open, at_ns: 150 },
                SpanEvent { kind: SpanKind::Round, edge: SpanEdge::Close, at_ns: 150 },
                SpanEvent { kind: SpanKind::Handshake, edge: SpanEdge::Close, at_ns: 175 },
            ]
        );
        let pd = trace.phase_durations();
        assert_eq!(pd.handshake, Duration::from_nanos(75));
        assert_eq!(pd.total, Duration::from_nanos(75));
    }

    #[test]
    fn stamps_clamp_monotone_even_if_the_clock_regresses() {
        // A ManualClock that is *set backwards* between events models clock skew; the
        // tracer's clamp keeps the timeline sorted anyway.
        let clock = Arc::new(ManualClock::new(1_000));
        let mut t = Tracer::with_clock(clock.clone());
        t.open(SpanKind::Attempt(0));
        let fresh = ManualClock::new(10); // earlier origin
        t.clock = Arc::new(fresh);
        t.close(SpanKind::Attempt(0));
        assert!(t.trace().is_well_formed());
        assert_eq!(t.trace().events[1].at_ns, 1_000);
    }

    #[test]
    fn well_formedness_rejects_imbalance_and_disorder() {
        let mut trace = SessionTrace::default();
        trace.events.push(SpanEvent { kind: SpanKind::Round, edge: SpanEdge::Close, at_ns: 5 });
        assert!(!trace.is_well_formed(), "close without open");

        let mut trace = SessionTrace::default();
        trace.events.push(SpanEvent { kind: SpanKind::Round, edge: SpanEdge::Open, at_ns: 9 });
        trace.events.push(SpanEvent { kind: SpanKind::Round, edge: SpanEdge::Close, at_ns: 3 });
        assert!(!trace.is_well_formed(), "timestamps regress");

        let mut trace = SessionTrace::default();
        trace.events.push(SpanEvent { kind: SpanKind::Round, edge: SpanEdge::Open, at_ns: 1 });
        assert!(!trace.is_well_formed(), "span left open");
    }

    #[test]
    fn merge_interleaves_by_timestamp_and_stays_well_formed() {
        let clock = Arc::new(ManualClock::new(0));
        let mut outer = Tracer::with_clock(clock.clone());
        let mut inner = outer.child();
        outer.open(SpanKind::Attempt(0));
        clock.advance(10);
        inner.open(SpanKind::DecoderBuild);
        clock.advance(10);
        inner.close(SpanKind::DecoderBuild);
        clock.advance(10);
        let inner_trace = inner.take();
        outer.absorb(&inner_trace);
        outer.close(SpanKind::Attempt(0));
        let trace = outer.take();
        assert!(trace.is_well_formed());
        // The inner span sits inside the attempt in timestamp order.
        let kinds: Vec<(SpanKind, SpanEdge)> =
            trace.events.iter().map(|e| (e.kind, e.edge)).collect();
        assert_eq!(
            kinds,
            vec![
                (SpanKind::Attempt(0), SpanEdge::Open),
                (SpanKind::DecoderBuild, SpanEdge::Open),
                (SpanKind::DecoderBuild, SpanEdge::Close),
                (SpanKind::Attempt(0), SpanEdge::Close),
            ]
        );
        let pd = trace.phase_durations();
        assert_eq!(pd.decoder_build, Duration::from_nanos(10));
        assert_eq!(pd.attempts, Duration::from_nanos(30));
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let mut t = Tracer::disabled();
        t.open(SpanKind::Handshake);
        t.instant(SpanKind::Round);
        t.close(SpanKind::Handshake);
        assert!(t.trace().is_empty());
        assert!(!t.is_enabled());
    }

    #[test]
    fn render_is_one_line_per_edge() {
        let clock = Arc::new(ManualClock::new(5_000));
        let mut t = Tracer::with_clock(clock.clone());
        t.open(SpanKind::Handshake);
        clock.advance(2_000);
        t.close(SpanKind::Handshake);
        let text = t.trace().render();
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains("open  Handshake"));
        assert!(text.contains("+        2us close Handshake"));
    }
}
